"""Render EXPERIMENTS.md §Dry-run / §Roofline tables from var/dryrun.json,
or the benchmark-trajectory table from the machine-readable
``var/BENCH_<name>.json`` records `benchmarks.run` writes.

  PYTHONPATH=src python -m benchmarks.report [--json var/dryrun.json]
  PYTHONPATH=src python -m benchmarks.report --bench [--var var]
"""
from __future__ import annotations

import argparse
import json
import pathlib


def fmt_bytes(n) -> str:
    if not n:
        return "0"
    for unit in ("B", "KB", "MB", "GB", "TB"):
        if abs(n) < 1024:
            return f"{n:.1f}{unit}"
        n /= 1024
    return f"{n:.1f}PB"


def fmt_t(x: float) -> str:
    if x == 0:
        return "0"
    if x < 1e-6:
        return f"{x*1e9:.1f}ns"
    if x < 1e-3:
        return f"{x*1e6:.1f}us"
    if x < 1:
        return f"{x*1e3:.2f}ms"
    return f"{x:.2f}s"


ARCH_ORDER = ["qwen3-moe-30b-a3b", "deepseek-v3-671b", "mamba2-780m",
              "whisper-large-v3", "qwen1.5-110b", "qwen3-32b", "stablelm-3b",
              "granite-20b", "qwen2-vl-72b", "jamba-v0.1-52b"]
SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def key(r):
    return (ARCH_ORDER.index(r["arch"]), SHAPE_ORDER.index(r["shape"]))


def render(records: list[dict]) -> str:
    out = []
    base = [r for r in records if not r.get("policy")]
    single = sorted([r for r in base if r["mesh"] == "16x16"], key=key)
    multi = sorted([r for r in base if r["mesh"] == "2x16x16"], key=key)

    out.append("### Dry-run matrix (single-pod 16x16 = 256 chips)\n")
    out.append("| arch | shape | status | compile | args/dev | temp/dev | "
               "HLO flops (raw) | collectives (loop-aware) |")
    out.append("|---|---|---|---|---|---|---|---|")
    for r in single:
        if r["status"] != "ok":
            out.append(f"| {r['arch']} | {r['shape']} | {r['status']}: "
                       f"{r.get('reason','')[:60]} | | | | | |")
            continue
        mem = r.get("memory", {})
        cnts = r.get("collectives", {}).get("counts", {})
        cstr = " ".join(f"{k.split('-')[-1] if k.startswith('all') else k}"
                        f":{v}" for k, v in sorted(cnts.items()))
        out.append(
            f"| {r['arch']} | {r['shape']} | ok | {r['compile_s']:.0f}s | "
            f"{fmt_bytes(mem.get('argument_size_in_bytes'))} | "
            f"{fmt_bytes(mem.get('temp_size_in_bytes'))} | "
            f"{r.get('cost',{}).get('flops',0):.2e} | "
            f"{fmt_bytes(r.get('collectives',{}).get('total_bytes',0))} "
            f"({cstr}) |")

    out.append("\n### Multi-pod (2x16x16 = 512 chips) compile proof\n")
    ok = sum(1 for r in multi if r["status"] == "ok")
    sk = sum(1 for r in multi if r["status"] == "skipped")
    out.append(f"{ok} cells compiled, {sk} skipped (long_500k on "
               f"full-attention archs); 0 failures. Per-cell: ")
    out.append("| arch | shape | compile | collectives |")
    out.append("|---|---|---|---|")
    for r in multi:
        if r["status"] != "ok":
            continue
        out.append(f"| {r['arch']} | {r['shape']} | {r['compile_s']:.0f}s | "
                   f"{fmt_bytes(r.get('collectives',{}).get('total_bytes',0))} |")

    out.append("\n### Roofline (single-pod, analytic flops/bytes + "
               "HLO-parsed collectives)\n")
    out.append("| arch | shape | t_compute | t_memory | t_collective | "
               "bottleneck | useful-FLOPs ratio | roofline frac |")
    out.append("|---|---|---|---|---|---|---|---|")
    for r in single:
        if r["status"] != "ok" or "roofline" not in r:
            continue
        t = r["roofline"]
        out.append(
            f"| {r['arch']} | {r['shape']} | {fmt_t(t['t_compute_s'])} | "
            f"{fmt_t(t['t_memory_s'])} | {fmt_t(t['t_collective_s'])} | "
            f"{r['bottleneck'].replace('t_','').replace('_s','')} | "
            f"{min(r.get('useful_flops_ratio',0), 99):.2f} | "
            f"{r.get('roofline_fraction',0)*100:.1f}% |")
    return "\n".join(out)


def render_bench(var: pathlib.Path) -> str:
    """Markdown table over every var/BENCH_*.json record (the cross-PR
    perf-trajectory view; rows keep the derived CSV column verbatim)."""
    paths = sorted(var.glob("BENCH_*.json"))
    if not paths:
        return (f"no BENCH_*.json under {var}/ — run "
                "`python -m benchmarks.run` first")
    out = ["### Benchmark records (machine-readable trajectory)\n",
           "| benchmark | status | row | us/call | derived |",
           "|---|---|---|---|---|"]
    for path in paths:
        try:
            r = json.loads(path.read_text())
        except (json.JSONDecodeError, OSError) as e:
            out.append(f"| {path.stem.removeprefix('BENCH_')} | "
                       f"unreadable ({type(e).__name__}) | | | |")
            continue
        if r.get("status") != "ok" or not r.get("rows"):
            out.append(f"| {r.get('benchmark', path.stem)} | "
                       f"{r.get('status', '?')} | | | |")
            continue
        for rr in r["rows"]:
            out.append(f"| {r['benchmark']} | ok | {rr['name']} | "
                       f"{rr['us_per_call']:.0f} | {rr['derived']} |")
    return "\n".join(out)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--json", default="var/dryrun.json")
    ap.add_argument("--bench", action="store_true",
                    help="render var/BENCH_*.json records instead of the "
                         "dry-run tables")
    ap.add_argument("--var", default="var",
                    help="directory holding BENCH_*.json (with --bench)")
    args = ap.parse_args()
    if args.bench:
        print(render_bench(pathlib.Path(args.var)))
        return
    records = json.loads(pathlib.Path(args.json).read_text())
    print(render(records))


if __name__ == "__main__":
    main()
