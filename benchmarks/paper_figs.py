"""Paper-artifact benchmarks: one function per table/figure.

Each returns CSV rows `name,us_per_call,derived` where `derived` carries the
figure's headline quantity, so EXPERIMENTS.md can quote the CSV directly.
"""
from __future__ import annotations

import json

import numpy as np

from benchmarks.common import get_problem, policy_sweeps, row, timeit


# ---------------------------------------------------------------------------
def fig1_carbon_series() -> list[str]:
    """Fig. 1: MCI variation vs flat datacenter power."""
    from repro.core.carbon import caiso_2021, projection
    from repro.sched.traces import fleet_power_traces
    us = timeit(lambda: (caiso_2021(48), fleet_power_traces(48)))
    sig = caiso_2021(48)
    tr = fleet_power_traces(48)
    total = sum(t.usage for t in tr.values())
    flatness = float(total.std() / total.mean())
    t2050 = projection(2050, "CA").peak_to_trough()
    return [row("fig1_carbon_series", us,
                f"trough/peak today={sig.peak_to_trough():.2f};"
                f" 2050={t2050:.2f}; power flatness(cv)={flatness:.3f}")]


# ---------------------------------------------------------------------------
def table5_lasso() -> list[str]:
    """Table V: Lasso CV quality for both batch services."""
    from repro.core.penalty import build_batch_model
    from repro.sched.traces import fleet_power_traces, make_job_trace
    traces = fleet_power_traces(48)
    rows = []
    for name, kind, nsamp in (("AITraining", "batch_noslo", 303),
                              ("DataPipeline", "batch_slo", 162)):
        jobs = make_job_trace(kind, hours=48,
                              total_power=1.05 * float(
                                  np.mean(traces[name].usage)),
                              num_jobs=10_000, seed=hash(name) % 97)
        import time
        t0 = time.perf_counter()
        model, fit, data = build_batch_model(name, traces[name], jobs,
                                             num_samples=min(nsamp, 120))
        us = (time.perf_counter() - t0) * 1e6
        rows.append(row(
            f"table5_lasso_{name}", us,
            f"N={data.X.shape[0]}; MAE={fit.cv_mae_mean:.1f};"
            f" MAEvar={fit.cv_mae_var:.1f}; R2={fit.r2:.3f};"
            f" paper_R2={'0.789' if name == 'AITraining' else '0.864'}"))
    return rows


# ---------------------------------------------------------------------------
def fig6_penalty_curves() -> list[str]:
    """Fig. 6: calibrated penalty vs uniform curtailment depth."""
    import jax.numpy as jnp
    p = get_problem()
    rows = []
    for i, m in enumerate(p.models):
        depths = np.linspace(0, 0.5, 6)
        pens = [float(m.penalty(jnp.asarray(f * m.usage))) for f in depths]
        us = timeit(lambda m=m: m.penalty(jnp.asarray(0.3 * m.usage)))
        rows.append(row(f"fig6_penalty_{m.name}", us,
                        "C(10..50%)=" + "/".join(f"{x:.2f}"
                                                 for x in pens[1:])))
    return rows


# ---------------------------------------------------------------------------
def fig7_day_dynamics() -> list[str]:
    """Fig. 7: CR1 day trace — paper: carbon ↓4.6%, perf ≈4% capacity.

    λ is bisected so total carbon reduction lands in the paper's band; the
    per-service split is then reported against the paper's values."""
    from repro.core.policies import cr1_spec
    from repro.core.solver import solve_slsqp
    p = get_problem()
    lo, hi = 1.2, 1.8
    best = None
    for _ in range(8):
        lam = 0.5 * (lo + hi)
        r = solve_slsqp(cr1_spec(p, lam), maxiter=250)
        best = (lam, r)
        if r.carbon_reduction_pct > 4.6:
            lo = lam
        else:
            hi = lam
        if abs(r.carbon_reduction_pct - 4.6) < 0.4:
            break
    lam, r = best
    per = {n: (round(float(c), 2), round(float(q), 2))
           for n, c, q in zip(
               p.names, 100 * r.per_carbon / p.total_carbon_baseline,
               100 * r.per_penalty / p.entitlements.sum())}
    us = timeit(lambda: solve_slsqp(cr1_spec(p, lam), maxiter=250),
                repeats=1, warmup=0)
    return [row("fig7_day_dynamics", us,
                f"lambda*={lam:.3f}; carbon={r.carbon_reduction_pct:.2f}%"
                f" (paper 4.6); penalty={r.total_penalty_pct:.2f}%"
                f" (paper ~4); per-service(c%,p%)={per}")]


# ---------------------------------------------------------------------------
def fig8_pareto() -> list[str]:
    """Fig. 8: Pareto frontiers; headline = CR1 vs best-baseline carbon at
    matched penalty (paper: 1.5–2x)."""
    from repro.core.metrics import pareto_frontier
    import time
    t0 = time.perf_counter()
    sweep = policy_sweeps()
    us = (time.perf_counter() - t0) * 1e6
    rows = []
    by = {}
    for r in sweep:
        by.setdefault(r["policy"], []).append(r)
    # efficiency ratio: carbon at ~matched penalty in the 1-5% band.
    def carbon_at(policy, pen_target):
        cands = [r for r in by.get(policy, ())]
        if not cands:
            return 0.0
        best = min(cands, key=lambda r: abs(r["penalty_pct"] - pen_target))
        return best["carbon_pct"]

    for pen_t in (2.0, 4.0):
        cr1 = carbon_at("CR1", pen_t)
        base = max(carbon_at(b, pen_t) for b in ("B1", "B2", "B3", "B4"))
        ratio = cr1 / max(base, 1e-9)
        rows.append(row(f"fig8_pareto_pen{pen_t:g}", us,
                        f"CR1={cr1:.2f}% best-baseline={base:.2f}%"
                        f" ratio={ratio:.2f} (paper 1.5-2x)"))
    for pol, rs in sorted(by.items()):
        pts = sorted((r["carbon_pct"], r["penalty_pct"]) for r in rs)
        frontier = "; ".join(f"({c:.1f},{q:.1f})" for c, q in pts[:6])
        rows.append(row(f"fig8_frontier_{pol}", 0.0, frontier))
    return rows


# ---------------------------------------------------------------------------
def fig9_breakdown() -> list[str]:
    """Fig. 9: per-service penalty/carbon split at 0.5/2/8% targets."""
    sweep = policy_sweeps()
    p = get_problem()
    rows = []
    for target in (0.5, 2.0, 8.0):
        for pol in ("CR1", "CR2", "CR3", "B1", "B2", "B3", "B4"):
            cands = [r for r in sweep if r["policy"] == pol]
            best = min(cands, key=lambda r: abs(r["carbon_pct"] - target))
            # A policy "achieves" the target within ±30% (paper drops bars
            # for B3/B4/CR3 at 8%).
            if abs(best["carbon_pct"] - target) > 0.3 * target + 0.2:
                rows.append(row(f"fig9_{target:g}pct_{pol}", 0.0,
                                "unachievable (no bar — paper-consistent)"))
                continue
            pens = np.asarray(best["per_penalty"])
            cars = np.asarray(best["per_carbon"])
            split = "/".join(f"{x:.2f}" for x in
                             100 * cars / p.total_carbon_baseline)
            psplit = "/".join(f"{x:.2f}" for x in
                              100 * pens / p.entitlements.sum())
            rows.append(row(f"fig9_{target:g}pct_{pol}", 0.0,
                            f"carbon%[{split}] pen%[{psplit}]"))
    return rows


# ---------------------------------------------------------------------------
def fig10_entropy() -> list[str]:
    """Fig. 10: fairness entropies over each policy's sweep."""
    from repro.core.metrics import box_stats, capacity_scaled_entropy
    sweep = policy_sweeps()
    p = get_problem()
    rows = []
    by = {}
    for r in sweep:
        by.setdefault(r["policy"], []).append(r)
    for pol, rs in sorted(by.items()):
        ents_p = [capacity_scaled_entropy(np.asarray(r["per_penalty"]),
                                          p.entitlements) for r in rs]
        ents_c = [capacity_scaled_entropy(np.asarray(r["per_carbon"]),
                                          p.entitlements) for r in rs]
        sp, sc = box_stats(np.asarray(ents_p)), box_stats(np.asarray(ents_c))
        rows.append(row(f"fig10_entropy_{pol}", 0.0,
                        f"pen_median={sp['median']:.2f}"
                        f" [{sp['min']:.2f},{sp['max']:.2f}];"
                        f" carbon_median={sc['median']:.2f}"
                        f" [{sc['min']:.2f},{sc['max']:.2f}] (max=2)"))
    return rows


# ---------------------------------------------------------------------------
def fig11_future() -> list[str]:
    """Fig. 11: fixed Fig.-7 load shift applied to 2024/2050 state MCIs."""
    from repro.core.carbon import STATES, caiso_2021, projection
    from repro.core.policies import cr1_spec
    from repro.core.solver import solve_slsqp
    p = get_problem()
    r = solve_slsqp(cr1_spec(p, 1.45), maxiter=250)
    D = r.D
    us = timeit(lambda: projection(2050, "CA"))
    rows = []
    gains = {}
    for year in (2024, 2050):
        vals = []
        for st in STATES[:10]:
            sig = projection(year, st)
            base = float((p.usage.sum(0) * sig.mci).sum())
            red = 100 * float((D.sum(0) * sig.mci).sum()) / base
            vals.append((st, red))
        gains[year] = vals
    mean24 = np.mean([v for _, v in gains[2024]])
    mean50 = np.mean([v for _, v in gains[2050]])
    top = max(gains[2050], key=lambda x: x[1])
    rows.append(row("fig11_future", us,
                    f"mean2024={mean24:.2f}% mean2050={mean50:.2f}%"
                    f" growth={mean50 / max(mean24, 1e-9):.2f}x"
                    f" best2050={top[0]}:{top[1]:.2f}%"))
    return rows
