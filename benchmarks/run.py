"""Benchmark harness — one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV (stdout). Usage:
  PYTHONPATH=src python -m benchmarks.run [--only fig8]
"""
from __future__ import annotations

import argparse
import sys
import traceback


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None)
    args = ap.parse_args()

    from benchmarks import paper_figs, perf_micro
    benches = [
        ("fig1_carbon_series", paper_figs.fig1_carbon_series),
        ("table5_lasso", paper_figs.table5_lasso),
        ("fig6_penalty_curves", paper_figs.fig6_penalty_curves),
        ("fig7_day_dynamics", paper_figs.fig7_day_dynamics),
        ("fig8_pareto", paper_figs.fig8_pareto),
        ("fig9_breakdown", paper_figs.fig9_breakdown),
        ("fig10_entropy", paper_figs.fig10_entropy),
        ("fig11_future", paper_figs.fig11_future),
        ("solver_scale", perf_micro.solver_scale),
        ("fleet_cr3_scale", perf_micro.fleet_cr3_scale),
        ("fleet_shard_scale", perf_micro.fleet_shard_scale),
        ("streaming_resolve", perf_micro.streaming_resolve),
        ("kernel_micro", perf_micro.kernel_micro),
        ("train_throughput", perf_micro.train_throughput),
    ]
    print("name,us_per_call,derived")
    failures = 0
    for name, fn in benches:
        if args.only and args.only not in name:
            continue
        try:
            fn()
        except Exception:  # noqa: BLE001
            failures += 1
            traceback.print_exc()
            print(f"{name},0,FAILED", flush=True)
    sys.exit(1 if failures else 0)


if __name__ == "__main__":
    main()
