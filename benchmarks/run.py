"""Benchmark harness — one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV (stdout) and, per benchmark,
writes a machine-readable ``var/BENCH_<name>.json`` record (wall times,
problem sizes and objective/parity numbers parsed from the CSV rows,
plus host metadata) so the performance trajectory is tracked across PRs
— diff two checkouts' ``var/BENCH_*.json`` instead of eyeballing
stdout. Usage:

  PYTHONPATH=src python -m benchmarks.run [--only fig8] [--no-json]
"""
from __future__ import annotations

import argparse
import json
import re
import sys
import time
import traceback

#: value with an optional unit suffix the benchmarks emit (%, x, pp, ms,
#: us, s, ...): group 1 is the numeric part.
_NUM = re.compile(r"^([+-]?\d+(?:\.\d+)?(?:[eE][+-]?\d+)?)([a-zA-Z%/]{0,3})$")


def _parse_rows(rows) -> list[dict]:
    """CSV rows `name,us_per_call,derived` -> JSON records. The derived
    column's `key=value` tokens (objective, parity, speedup, latencies,
    problem sizes) are lifted into a dict — numeric wherever the value is
    a number with at most a short unit suffix — so trajectories diff
    structurally."""
    out = []
    for line in rows or []:
        name, us, derived = str(line).split(",", 2)
        numbers = {}
        for tok in derived.replace(";", " ").split():
            if "=" in tok:
                k, v = tok.split("=", 1)
                m = _NUM.match(v)
                numbers[k] = float(m.group(1)) if m else v
        out.append({"name": name, "us_per_call": float(us),
                    "derived": derived, "numbers": numbers})
    return out


def _write_json(bench: str, status: str, rows, elapsed_s: float) -> None:
    import os
    import tempfile

    from benchmarks.common import VAR
    from repro.obs.events import host_meta
    VAR.mkdir(exist_ok=True)
    record = {
        "benchmark": bench,
        "status": status,
        "elapsed_s": round(elapsed_s, 3),
        "rows": _parse_rows(rows),
        # the shared obs fingerprint: platform/devices/jax+jaxlib
        # versions/pallas-interpret flag — diffable across checkouts
        "host": host_meta(),
        "unix_time": int(time.time()),
    }
    # temp-file + os.replace (the fleetcache pattern): an interrupted run
    # must never leave a truncated record for report.py --bench to choke on
    fd, tmp = tempfile.mkstemp(dir=VAR, prefix=f"BENCH_{bench}.",
                               suffix=".tmp")
    with os.fdopen(fd, "w") as f:
        f.write(json.dumps(record, indent=1))
    os.replace(tmp, VAR / f"BENCH_{bench}.json")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None)
    ap.add_argument("--no-json", action="store_true",
                    help="skip writing var/BENCH_<name>.json records")
    args = ap.parse_args()

    from benchmarks import paper_figs, perf_micro, scenario_ensemble
    benches = [
        ("fig1_carbon_series", paper_figs.fig1_carbon_series),
        ("table5_lasso", paper_figs.table5_lasso),
        ("fig6_penalty_curves", paper_figs.fig6_penalty_curves),
        ("fig7_day_dynamics", paper_figs.fig7_day_dynamics),
        ("fig8_pareto", paper_figs.fig8_pareto),
        ("fig9_breakdown", paper_figs.fig9_breakdown),
        ("fig10_entropy", paper_figs.fig10_entropy),
        ("fig11_future", paper_figs.fig11_future),
        ("solver_scale", perf_micro.solver_scale),
        ("fleet_cr3_scale", perf_micro.fleet_cr3_scale),
        ("fleet_shard_scale", perf_micro.fleet_shard_scale),
        ("fleet_region_scale", perf_micro.fleet_region_scale),
        ("streaming_resolve", perf_micro.streaming_resolve),
        ("streaming_day", perf_micro.streaming_day),
        ("scenario_ensemble", scenario_ensemble.scenario_ensemble),
        ("kernel_micro", perf_micro.kernel_micro),
        ("al_step_micro", perf_micro.al_step_micro),
        ("train_throughput", perf_micro.train_throughput),
    ]
    # One span event per benchmark lands in var/BENCH_events.jsonl so a
    # whole harness run renders with `python -m repro.obs.report` (same
    # reporting side-channel rules as the JSON records: never fail a
    # benchmark over it).
    writer = None
    if not args.no_json:
        try:
            from benchmarks.common import VAR
            from repro.obs.events import EventWriter
            VAR.mkdir(exist_ok=True)
            writer = EventWriter(str(VAR / "BENCH_events.jsonl"),
                                 tags={"harness": "benchmarks.run"})
        except Exception as e:  # noqa: BLE001 — reporting side-channel
            print(f"# BENCH_events.jsonl not opened: {e}", file=sys.stderr)

    print("name,us_per_call,derived")
    failures = 0
    for name, fn in benches:
        if args.only and args.only not in name:
            continue
        t0 = time.perf_counter()
        try:
            rows = fn()
            status = "ok"
        except Exception:  # noqa: BLE001
            failures += 1
            traceback.print_exc()
            print(f"{name},0,FAILED", flush=True)
            rows, status = [], "failed"
        elapsed = time.perf_counter() - t0
        if not args.no_json:
            # a JSON-record failure (read-only var/, disk full) must not
            # fail a benchmark that ran, nor abort the remaining ones
            try:
                _write_json(name, status, rows, elapsed)
            except Exception as e:  # noqa: BLE001 — reporting side-channel
                print(f"# BENCH_{name}.json not written: {e}",
                      file=sys.stderr)
        if writer is not None:
            try:
                from repro.obs.events import SpanEvent
                writer.write(SpanEvent(name=f"bench.{name}",
                                       elapsed_s=elapsed,
                                       meta={"status": status}))
            except Exception as e:  # noqa: BLE001 — reporting side-channel
                print(f"# BENCH_events.jsonl append failed: {e}",
                      file=sys.stderr)
    if writer is not None:
        writer.close()
    sys.exit(1 if failures else 0)


if __name__ == "__main__":
    main()
