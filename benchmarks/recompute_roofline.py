"""Recompute roofline terms for dry-run records using the analytic models
(flops, HBM bytes, ICI bytes) — no recompilation needed. HLO-parsed
collective bytes stay recorded raw under "collectives".

  PYTHONPATH=src python -m benchmarks.recompute_roofline var/dryrun.json ...
"""
from __future__ import annotations

import dataclasses
import json
import pathlib
import sys


def recompute(path: str) -> None:
    from repro.configs import get_config, shape_by_name
    from repro.launch.analytics import (analytic_record, cell_ici_bytes)
    from repro.launch.dryrun import PEAK_FLOPS, roofline_terms

    p = pathlib.Path(path)
    records = json.loads(p.read_text())
    for r in records:
        if r.get("status") != "ok":
            continue
        cfg = get_config(r["arch"])
        override = (r.get("policy") or {}).get("override") or {}
        moe_over = {k[4:]: v for k, v in override.items()
                    if k.startswith("moe.")}
        plain = {k: v for k, v in override.items() if "." not in k}
        if moe_over and cfg.moe is not None:
            cfg = dataclasses.replace(
                cfg, moe=dataclasses.replace(cfg.moe, **moe_over))
        if plain:
            cfg = dataclasses.replace(cfg, **plain)
        shape = shape_by_name(r["shape"])
        pods = 2 if r["mesh"].startswith("2x") else 1
        chips = 256 * pods
        fsdp = (r.get("policy") or {}).get("fsdp_weights", True)
        ana = analytic_record(cfg, shape, chips)
        ana["ici_bytes_per_device"] = cell_ici_bytes(
            cfg, shape, data=16, model=16, fsdp_weights=fsdp, pods=pods)
        r["analytic"] = ana
        r["roofline"] = roofline_terms(ana["flops"],
                                       ana["hbm_bytes_per_device"],
                                       ana["ici_bytes_per_device"], chips)
        terms = r["roofline"]
        r["bottleneck"] = max(terms, key=terms.get)
        r["step_time_s"] = max(terms.values())
        n_active = cfg.active_param_count()
        toks = shape.global_batch * (shape.seq_len
                                     if shape.kind != "decode" else 1)
        mult = 6.0 if shape.kind == "train" else 2.0
        r["model_flops"] = mult * n_active * toks
        r["useful_flops_ratio"] = r["model_flops"] / max(ana["flops"], 1.0)
        r["roofline_fraction"] = (r["model_flops"] / r["step_time_s"]
                                  / (chips * PEAK_FLOPS))
    p.write_text(json.dumps(records, indent=1))
    print(f"recomputed {path}")


if __name__ == "__main__":
    for path in sys.argv[1:] or ["var/dryrun.json"]:
        recompute(path)
