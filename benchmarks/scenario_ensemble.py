"""Scenario-ensemble scale benchmark: batched vs sequential evaluation.

The ensemble runner (`repro.core.ensemble.evaluate_ensemble`) solves S
scenarios as ONE vmapped XLA call; the alternative is a Python loop of S
`api.solve` calls. This measures both at S ∈ {16, 64, 256} × W=512 for
CR1 (+ one CR2 row), reporting per-scenario latency, the speedup, and
the batched-vs-loop parity in percentage points.

CPU caveat: the batched win on CPU comes from fusing S small (W, T) ops
into (S, W, T) ops plus dropping S-1 dispatch/host-sync round-trips —
measured ≈2-3x on the 2-core CI box. The structural property that
transfers to TPU/many-core is ONE dispatch for the whole ensemble with
MXU-shaped batched operands (where the ≥5x target of the ISSUE-5
acceptance applies); the loop column here is measured fully at S ≤ 64
and extrapolated (marked `est`) at S=256 to keep the benchmark under
control.
"""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import row, timeit


def scenario_ensemble() -> list[str]:
    from repro.core.api import CR1, CR2, SolveContext
    from repro.core.ensemble import evaluate_ensemble
    from repro.core.fleet_solver import synthetic_fleet
    from repro.core.scenario import (CambiumMix, DuckPerturb,
                                     resolve_scenarios)

    rows = []
    W, steps = 512, 60
    p = synthetic_fleet(W)
    cr1 = CR1(lam=1.45)
    ctx = SolveContext(steps=steps)
    from repro.core.api import solve
    solve(p, cr1, ctx=ctx)    # warm the loop lane's trace (fair timing)
    loop_per_scn = None
    for S in (16, 64, 256):
        stack = resolve_scenarios(
            [DuckPerturb(n_scenarios=S - S // 2, seed=0),
             CambiumMix(n_scenarios=S // 2, seed=1)], p)
        evaluate_ensemble(p, cr1, stack, ctx=ctx)          # compile
        us_b = timeit(lambda: evaluate_ensemble(p, cr1, stack, ctx=ctx),
                      repeats=2, warmup=0)
        if S <= 64:
            t0 = time.perf_counter()
            r_loop = evaluate_ensemble(p, cr1, stack, ctx=ctx,
                                       batched=False)
            us_l = (time.perf_counter() - t0) * 1e6
            loop_per_scn = us_l / S
            r_b = evaluate_ensemble(p, cr1, stack, ctx=ctx)
            parity = float(np.abs(r_b.carbon_reduction_pct
                                  - r_loop.carbon_reduction_pct).max())
            loop_note = f"loop={us_l / 1e3:.0f}ms parity={parity:.2e}pp"
        else:
            us_l = loop_per_scn * S
            loop_note = f"loop~{us_l / 1e3:.0f}ms(est)"
        rows.append(row(
            f"scenario_ensemble_S{S}_W{W}", us_b,
            f"batched={us_b / 1e3:.0f}ms ({us_b / S / 1e3:.1f}ms/scn) "
            f"{loop_note} speedup={us_l / max(us_b, 1e-9):.2f}x "
            f"one-XLA-call"))
    # CR2 (equality-constrained family) coverage + risk-report latency
    S = 16
    stack = DuckPerturb(n_scenarios=S, seed=2).generate(p)
    cr2 = CR2(cap_frac=0.8, outer=2)
    ctx2 = SolveContext(steps=50)
    evaluate_ensemble(p, cr2, stack, ctx=ctx2)             # compile
    us_b = timeit(lambda: evaluate_ensemble(p, cr2, stack, ctx=ctx2),
                  repeats=1, warmup=0)
    res = evaluate_ensemble(p, cr2, stack, ctx=ctx2)
    us_rep = timeit(lambda: res.report(), repeats=3)
    rep = res.report()
    rows.append(row(
        f"scenario_ensemble_cr2_S{S}_W{W}", us_b,
        f"{us_b / S / 1e3:.1f}ms/scn report={us_rep / 1e3:.1f}ms "
        f"carbon_p50={rep.carbon_quantiles['p50']:.2f}% "
        f"cvar25={rep.carbon_cvar:.2f}% "
        f"slo_prob={rep.slo_violation_prob:.2f}"))
    return rows
