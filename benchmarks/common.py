"""Shared benchmark utilities: timing + the policy sweep cache.

Every benchmark prints `name,us_per_call,derived` CSV rows (one per paper
table/figure artifact); heavyweight policy sweeps are solved once and cached
in var/ for the figure-level benchmarks to share.
"""
from __future__ import annotations

import json
import pathlib
import time
from typing import Callable

import numpy as np

VAR = pathlib.Path(__file__).resolve().parents[1] / "var"


def timeit(fn: Callable, repeats: int = 3, warmup: int = 1) -> float:
    """Median wall-time per call in microseconds.

    `fn()`'s result is blocked on (`jax.block_until_ready`, a no-op for
    host values) before the clock stops — jax dispatch is async, so
    timing the bare call measures enqueue latency, not the computation.
    """
    import jax
    for _ in range(warmup):
        jax.block_until_ready(fn())
    times = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        jax.block_until_ready(fn())
        times.append((time.perf_counter() - t0) * 1e6)
    return float(np.median(times))


def row(name: str, us: float, derived: str) -> str:
    line = f"{name},{us:.1f},{derived}"
    print(line, flush=True)
    return line


def get_problem():
    from repro.core.carbon import caiso_2021
    from repro.core.fleetcache import cached_paper_fleet
    from repro.core.policies import DRProblem
    fleet = cached_paper_fleet()
    models = tuple(fleet[n]
                   for n in ("RTS1", "RTS2", "AITraining", "DataPipeline"))
    return DRProblem(models=models, mci=caiso_2021(48).mci)


def _res_to_dict(r, policy: str, hyper: float) -> dict:
    return {
        "policy": policy, "hyper": hyper, "name": r.name,
        "carbon_pct": r.carbon_reduction_pct,
        "penalty_pct": r.total_penalty_pct,
        "per_penalty": r.per_penalty.tolist(),
        "per_carbon": r.per_carbon.tolist(),
        "violations": {k: float(v) for k, v in r.violations.items()},
    }


def policy_sweeps(problem=None, force: bool = False) -> list[dict]:
    """Solve every policy over its hyperparameter grid once; cache JSON.
    This is the data behind Figs. 8, 9 and 10."""
    path = VAR / "policy_sweep.json"
    if path.exists() and not force:
        return json.loads(path.read_text())
    from repro.core.api import CR3, solve
    from repro.core.baselines import (b1_adjustments, b2_spec,
                                      b3_adjustments, b4_spec)
    from repro.core.fleet_solver import FleetProblem
    from repro.core.policies import PolicySpec, cr1_spec, cr2_spec
    from repro.core.solver import evaluate, solve_slsqp
    p = problem or get_problem()
    out: list[dict] = []

    def closed(D, name):
        spec = PolicySpec(name=name, problem=p,
                          objective=lambda D_: p.total_penalty(D_),
                          use_preservation=False)
        return evaluate(spec, D, solver="closed", nit=0)

    for lam in (1.0, 1.2, 1.3, 1.4, 1.45, 1.5, 1.55, 1.6, 1.8, 2.2):
        r = solve_slsqp(cr1_spec(p, lam), maxiter=250)
        out.append(_res_to_dict(r, "CR1", lam))
    for cap in (0.84, 0.82, 0.80, 0.78, 0.76, 0.74):
        r = solve_slsqp(cr2_spec(p, cap), maxiter=250)
        out.append(_res_to_dict(r, "CR2", cap))
    # CR3 through the unified fleet API — the same engine the benchmarks
    # time (vmapped best responses + Eq.-6 clearing); per-workload figure
    # metrics come from the per-problem evaluator on the fleet solution.
    fp = FleetProblem.from_problem(p)
    for tax in (0.18, 0.20, 0.24, 0.30):
        rf = solve(fp, CR3(rho=0.02, tax_frac=tax, clearing_iters=3))
        spec = PolicySpec(name=f"CR3(tax={tax:g})", problem=p,
                          objective=lambda D_: p.total_penalty(D_))
        r = evaluate(spec, rf.D, solver="fleet-engine", nit=rf.iters)
        out.append(_res_to_dict(r, "CR3", tax))
    for F in np.linspace(0.55, 0.9, 8):
        out.append(_res_to_dict(closed(b1_adjustments(p, F), f"B1({F:.2f})"),
                                "B1", float(F)))
    for lam in (1.0, 1.3, 1.6, 2.0):
        r = solve_slsqp(b2_spec(p, lam), maxiter=150)
        out.append(_res_to_dict(r, "B2", lam))
    for depth in (0.1, 0.2, 0.3, 0.4, 0.5, 0.6):
        out.append(_res_to_dict(
            closed(b3_adjustments(p, depth, max_cut=0.3), f"B3({depth})"),
            "B3", depth))
    for lam in (0.02, 0.05, 0.1, 0.3):
        r = solve_slsqp(b4_spec(p, lam), maxiter=150)
        out.append(_res_to_dict(r, "B4", lam))
    VAR.mkdir(exist_ok=True)
    path.write_text(json.dumps(out, indent=1))
    return out
