"""Performance micro-benchmarks: fleet solver scaling + kernels.

These are the beyond-paper performance artifacts: the vectorized CR1 fleet
solver vs the paper's SLSQP, and the Pallas kernels vs their jnp oracles
(interpret mode on CPU — wall-times are NOT TPU numbers; the derived column
carries the structural quantities that transfer)."""
from __future__ import annotations

import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import get_problem, row, timeit


def solver_scale() -> list[str]:
    """SLSQP (paper) vs the unified fleet API at growing W."""
    from repro.core.api import CR1, CR2, solve
    from repro.core.fleet_solver import FleetProblem, synthetic_fleet
    from repro.core.policies import cr1_spec
    from repro.core.solver import solve_slsqp
    rows = []
    p = get_problem()
    t0 = time.perf_counter()
    r_ref = solve_slsqp(cr1_spec(p, 1.4), maxiter=250)
    us_slsqp = (time.perf_counter() - t0) * 1e6
    rows.append(row("solver_slsqp_W4", us_slsqp,
                    f"carbon={r_ref.carbon_reduction_pct:.2f}%"
                    f" pen={r_ref.total_penalty_pct:.2f}% (paper solver)"))
    fp4 = FleetProblem.from_problem(p)
    cr1 = CR1(lam=1.4)
    solve(fp4, cr1)  # compile
    us4 = timeit(lambda: solve(fp4, cr1), repeats=3)
    r4 = solve(fp4, cr1)
    rows.append(row("solver_fleet_W4", us4,
                    f"carbon={r4.carbon_reduction_pct:.2f}%"
                    f" pen={r4.total_penalty_pct:.2f}%"
                    f" (matches SLSQP within "
                    f"{abs(r4.carbon_reduction_pct - r_ref.carbon_reduction_pct):.2f}pp)"))
    for W in (64, 1024, 4096):
        fp = synthetic_fleet(W)
        solve(fp, cr1)
        us = timeit(lambda: solve(fp, cr1), repeats=2)
        r = solve(fp, cr1)
        per_w = us / W
        rows.append(row(f"solver_fleet_W{W}", us,
                        f"carbon={r.carbon_reduction_pct:.2f}%"
                        f" {per_w:.1f}us/workload"
                        f" viol={r.preservation_violation:.1e}"))
    # fair policy at fleet scale (CR2 — beyond paper)
    fp = synthetic_fleet(256)
    cr2 = CR2()
    solve(fp, cr2)
    us = timeit(lambda: solve(fp, cr2), repeats=1)
    r = solve(fp, cr2)
    rows.append(row("solver_fleet_cr2_W256", us,
                    f"carbon={r.carbon_reduction_pct:.2f}%"
                    f" pen={r.total_penalty_pct:.2f}%"
                    f" viol={r.preservation_violation:.1e}"))
    return rows


def fleet_cr3_scale() -> list[str]:
    """Decentralized CR3 wall-clock vs fleet size W — the taxes-and-rebates
    policy at fleet scale (vmapped best responses, one XLA call per clearing
    round; CPU numbers, structure transfers to TPU)."""
    from repro.core.api import CR1, CR3, SolveContext, solve, sweep
    from repro.core.fleet_solver import synthetic_fleet
    rows = []
    cr3 = CR3(outer=2, clearing_iters=2)
    ctx = SolveContext(steps=300)
    for W in (4, 64, 512):
        fp = synthetic_fleet(W)
        solve(fp, cr3, ctx=ctx)            # compile
        us = timeit(lambda: solve(fp, cr3, ctx=ctx), repeats=2, warmup=0)
        r = solve(fp, cr3, ctx=ctx)
        rows.append(row(f"fleet_cr3_W{W}", us,
                        f"carbon={r.carbon_reduction_pct:.2f}%"
                        f" pen={r.total_penalty_pct:.2f}%"
                        f" rho={r.extras['rho']:.4f}"
                        f" {us / W:.1f}us/workload"
                        f" viol={r.preservation_violation:.1e}"))
    # vmapped λ-sweep: the whole Fig.-8 CR1 frontier in one compile
    fp = synthetic_fleet(64)
    grid = [CR1(lam=lam) for lam in (1.0, 1.2, 1.45, 1.6, 2.2)]
    sweep(fp, grid, ctx=ctx)   # compile
    us = timeit(lambda: sweep(fp, grid, ctx=ctx), repeats=2, warmup=0)
    rows.append(row("fleet_cr1_sweep5_W64", us,
                    f"{us / len(grid):.0f}us/point; one XLA call for the"
                    f" {len(grid)}-point Pareto sweep"))
    return rows


def _tiled_fleet(base, W: int, seed: int = 0):
    """Blow a synthetic fleet up to W rows by tiling + per-row rescale —
    array-level construction so 100k-workload inputs build in O(ms), not
    a 100k-iteration python model loop."""
    from repro.core.fleet_solver import FleetProblem
    reps = -(-W // base.W)
    rng = np.random.default_rng(seed)
    scale = rng.uniform(0.5, 2.0, size=(W, 1))

    def tile(a, scaled):
        out = np.tile(np.asarray(a), (reps,) + (1,) * (np.ndim(a) - 1))[:W]
        return out * (scale if out.ndim == 2 else scale[:, 0]) \
            if scaled else out

    return FleetProblem(
        usage=tile(base.usage, True), entitlement=tile(base.entitlement, True),
        k=tile(base.k, False), rts_coeffs=tile(base.rts_coeffs, False),
        betas=tile(base.betas, False), x2_kind=tile(base.x2_kind, False),
        jobs=tile(base.jobs, True), is_batch=tile(base.is_batch, False),
        mci=np.asarray(base.mci), day_hours=base.day_hours,
        max_curtail_frac=base.max_curtail_frac)


def fleet_shard_scale() -> list[str]:
    """Device-sharded fleet engine at W ∈ {1k, 10k, 100k}: sharded vs
    single-device CR1 latency and objective parity, per-device rows bounded
    by W/n_devices (+ padding). Multi-device on CPU needs
    `XLA_FLAGS=--xla_force_host_platform_device_count=8`; with one device
    the single-device numbers still run and the sharded column is skipped.
    """
    from repro.core.api import CR1, SolveContext, solve
    from repro.core.fleet_solver import synthetic_fleet
    from repro.launch.mesh import make_fleet_mesh
    rows = []
    n_dev = len(jax.devices())
    mesh = make_fleet_mesh() if n_dev > 1 else None
    base = synthetic_fleet(1024)
    lam = 1.45
    cr1 = CR1(lam=lam)
    for W, steps in ((1_000, 300), (10_000, 150), (100_000, 60)):
        fp = _tiled_fleet(base, W)
        ctx1 = SolveContext(steps=steps)
        solve(fp, cr1, ctx=ctx1)          # compile
        us1 = timeit(lambda: solve(fp, cr1, ctx=ctx1), repeats=2, warmup=0)
        r1 = solve(fp, cr1, ctx=ctx1)
        obj1 = lam * r1.total_penalty_pct - r1.carbon_reduction_pct
        if mesh is None:
            rows.append(row(f"fleet_shard_W{W}", us1,
                            f"single-device only ({n_dev} device); carbon="
                            f"{r1.carbon_reduction_pct:.2f}%"))
            continue
        ctx8 = SolveContext(steps=steps, mesh=mesh)
        solve(fp, cr1, ctx=ctx8)   # compile
        us8 = timeit(lambda: solve(fp, cr1, ctx=ctx8), repeats=2, warmup=0)
        r8 = solve(fp, cr1, ctx=ctx8)
        obj8 = lam * r8.total_penalty_pct - r8.carbon_reduction_pct
        rows_dev = -(-W // n_dev)
        rows.append(row(
            f"fleet_shard_W{W}", us8,
            f"sharded({n_dev})={us8 / 1e3:.0f}ms vs 1dev={us1 / 1e3:.0f}ms"
            f" speedup={us1 / max(us8, 1e-9):.2f}x"
            f" obj_gap={abs(obj8 - obj1):.2e}pp"
            f" rows/dev={rows_dev}"
            f" carbon={r8.carbon_reduction_pct:.2f}%"))
    return rows


def streaming_resolve() -> list[str]:
    """Rolling-horizon streaming: warm-started re-solves vs cold solves.

    Per tick the online controller must re-solve the full horizon against a
    revised MCI forecast. This measures (a) wall-clock latency and (b)
    solution quality (CR1 objective, in percentage points) of the
    warm-started re-solve at a fraction of the cold inner-step budget —
    the ISSUE-2 acceptance artifact: gap <= 0.1 pp at >= 3x fewer steps."""
    from repro.core.api import CR1, SolveContext, solve
    from repro.core.carbon import ForecastStream
    from repro.core.fleet_solver import synthetic_fleet
    from repro.core.streaming import RollingHorizonSolver

    rows = []
    lam, cold_steps, warm_steps = 1.45, 600, 150
    cr1 = CR1(lam=lam)
    for W in (16, 256):
        p = synthetic_fleet(W)
        stream = ForecastStream.caiso(n_ticks=6, horizon=p.T)
        # donate stays off: we capture per-tick engine states below and
        # re-time them, which a donated (in-place) tick would invalidate.
        rhs = RollingHorizonSolver(p, stream, policy=cr1,
                                   cold_steps=cold_steps,
                                   warm_steps=warm_steps)

        # Per-tick warm objectives + engine states, captured while plans
        # are still attached (history keeps the full plan only on the
        # latest tick).
        def obj(r):
            return lam * r.total_penalty_pct - r.carbon_reduction_pct

        warm_objs, states = {}, {}

        def grab(tk):
            warm_objs[tk.tick] = obj(tk.plan)
            states[tk.tick] = tk.plan.state

        rep = rhs.run(6, on_tick=grab)   # compiles cold + warm traces

        # Quality: worst-tick objective gap, warm(150) vs cold(600), on the
        # identical per-tick windowed problem (obj = lam*pen_pct −
        # carbon_pct, so the gap is already in percentage points).
        gap = -np.inf
        for tk in rep.ticks[1:]:
            p_t = rhs._window_problem(tk.tick, stream.forecast(tk.tick))
            cold = solve(p_t, cr1, ctx=SolveContext(steps=cold_steps))
            gap = max(gap, warm_objs[tk.tick] - obj(cold))

        # Latency on the last window: warm tick seeded exactly as the
        # controller does (previous tick's state shifted one hour) vs a
        # cold solve.
        last = rep.ticks[-1].tick
        p_t = rhs._window_problem(last, stream.forecast(last))
        warm0 = states[last - 1].shifted(1)
        us_cold = timeit(lambda: solve(p_t, cr1,
                                       ctx=SolveContext(steps=cold_steps)),
                         repeats=3, warmup=0)
        us_warm = timeit(lambda: solve(p_t, cr1,
                                       ctx=SolveContext(steps=warm_steps,
                                                        warm=warm0)),
                         repeats=3, warmup=0)
        rows.append(row(
            f"streaming_resolve_W{W}", us_warm,
            f"warm({warm_steps})={us_warm / 1e3:.0f}ms vs"
            f" cold({cold_steps})={us_cold / 1e3:.0f}ms"
            f" speedup={us_cold / max(us_warm, 1e-9):.2f}x"
            f" steps_ratio={cold_steps / warm_steps:.1f}x"
            f" obj_gap={max(gap, 0.0):.4f}pp"
            f" realized={rep.realized_reduction_pct:.2f}%"
            f" fc_err={rep.forecast_error_pct:.2f}%"))
    return rows


def kernel_micro() -> list[str]:
    """Kernels vs jnp references (interpret mode — correctness + structure)."""
    rows = []
    key = jax.random.PRNGKey(0)

    from repro.kernels.flash_attention.ops import flash_attention
    from repro.kernels.flash_attention.ref import attention_ref
    B, S, H, KV, Dh = 1, 512, 4, 2, 64
    q = jax.random.normal(key, (B, S, H, Dh), jnp.bfloat16)
    k = jax.random.normal(key, (B, S, KV, Dh), jnp.bfloat16)
    v = jax.random.normal(key, (B, S, KV, Dh), jnp.bfloat16)
    out = flash_attention(q, k, v, causal=True)
    ref = attention_ref(q, k, v, causal=True)
    err = float(jnp.abs(out.astype(jnp.float32)
                        - ref.astype(jnp.float32)).max())
    us = timeit(lambda: flash_attention(q, k, v, causal=True).block_until_ready(),
                repeats=2)
    vmem_kb = (128 * Dh * 2 * 3 + 128 * Dh * 4) / 1024
    rows.append(row("kernel_flash_attention", us,
                    f"maxerr={err:.1e} tile=(128x{Dh})"
                    f" vmem~{vmem_kb:.0f}KB/program (interpret)"))

    from repro.kernels.rmsnorm.ops import rmsnorm
    from repro.kernels.rmsnorm.ref import rmsnorm_ref
    x = jax.random.normal(key, (16, 256, 1024), jnp.bfloat16)
    s = jnp.ones((1024,))
    err = float(jnp.abs(rmsnorm(x, s).astype(jnp.float32)
                        - rmsnorm_ref(x, s).astype(jnp.float32)).max())
    us = timeit(lambda: rmsnorm(x, s).block_until_ready(), repeats=2)
    rows.append(row("kernel_rmsnorm", us, f"maxerr={err:.1e} (interpret)"))

    from repro.kernels.ssd_scan.ops import ssd_scan
    from repro.kernels.ssd_scan.ref import ssd_scan_ref
    st_ = jax.random.normal(key, (2, 16, 8, 64, 128))
    dec = jnp.abs(jax.random.normal(key, (2, 16, 8))) * 0.5
    hp, hl = ssd_scan(st_, dec)
    hp_r, hl_r = ssd_scan_ref(st_, dec)
    err = float(jnp.abs(hp - hp_r).max())
    us = timeit(lambda: jax.block_until_ready(ssd_scan(st_, dec)), repeats=2)
    rows.append(row("kernel_ssd_scan", us,
                    f"maxerr={err:.1e} state=(64x128)f32=32KB VMEM-resident"))

    from repro.kernels.dr_features.ops import dr_features
    from repro.core.fleet_solver import synthetic_fleet, fleet_penalties
    fp = synthetic_fleet(1024)
    d = jnp.asarray(0.1 * fp.usage)
    us_k = timeit(lambda: dr_features(d, jnp.asarray(fp.usage),
                                      jnp.asarray(fp.jobs)).block_until_ready(),
                  repeats=2)
    pen_j = jax.jit(lambda D: fleet_penalties(fp, D, use_kernel=False))
    pen_j(d).block_until_ready()
    us_j = timeit(lambda: pen_j(d).block_until_ready(), repeats=3)
    rows.append(row("kernel_dr_features_W1024", us_k,
                    f"jnp_fleet_penalties={us_j:.0f}us;"
                    f" one-HBM-pass vs 4 cumsum intermediates"))
    return rows


def al_step_micro() -> list[str]:
    """Fused AL inner-step kernel (kernels/al_step) vs the generic
    autodiff engine: full CR1 solve latency + objective parity, and the
    raw fused-chunk step rate (interpret mode on CPU — the structural
    win is steps-per-HBM-round-trip, which transfers to TPU)."""
    from repro.core.api import CR1, SolveContext, solve
    from repro.core.engine import EngineConfig
    from repro.core.fleet_solver import _bounds, synthetic_fleet
    from repro.kernels.al_step.ops import make_fused_inner, pack_rows

    rows = []
    W, steps, lam = 256, 120, 1.45
    p = synthetic_fleet(W)
    cr1 = CR1(lam=lam)

    def obj(r):
        return lam * r.total_penalty_pct - r.carbon_reduction_pct

    ctx_g = SolveContext(steps=steps, use_kernel=False)
    ctx_k = SolveContext(steps=steps, use_kernel=True)
    solve(p, cr1, ctx=ctx_g)          # compile both traces
    solve(p, cr1, ctx=ctx_k)
    us_g = timeit(lambda: solve(p, cr1, ctx=ctx_g), repeats=2, warmup=0)
    us_k = timeit(lambda: solve(p, cr1, ctx=ctx_k), repeats=2, warmup=0)
    gap = abs(obj(solve(p, cr1, ctx=ctx_g)) - obj(solve(p, cr1, ctx=ctx_k)))
    rows.append(row(
        f"al_step_fused_solve_W{W}", us_k,
        f"fused={us_k / 1e3:.0f}ms vs generic={us_g / 1e3:.0f}ms"
        f" obj_gap={gap:.4f}pp steps={steps} (interpret)"))

    # Raw chunk throughput: one jitted fused_inner = inner_steps/k_steps
    # kernel calls, x + Adam moments VMEM-resident within each chunk.
    inner, k = 64, 8
    cfg = EngineConfig(inner_steps=inner, outer_steps=1)
    lo, hi = _bounds(p)
    rowp = pack_rows(jnp.asarray(p.rts_coeffs), jnp.asarray(p.betas),
                     jnp.asarray(p.k), jnp.asarray(p.x2_kind),
                     jnp.asarray(p.is_batch))
    cvec = -0.01 * jnp.asarray(p.mci, jnp.float32)[None, :]
    fused = make_fused_inner(
        jnp.asarray(p.usage, jnp.float32), jnp.asarray(p.jobs, jnp.float32),
        lo.astype(jnp.float32), hi.astype(jnp.float32), rowp, cvec,
        mode="cr1", cfg=cfg, step_scale=1.0, coef0=lam, k_steps=k,
        day_hours=p.day_hours)
    zl = jnp.zeros(0)
    f = jax.jit(lambda x: fused(x, zl, zl, jnp.asarray(10.0)))
    x0 = jnp.zeros((W, p.T), jnp.float32)
    f(x0)                              # compile
    us = timeit(lambda: f(x0), repeats=3, warmup=0)
    rows.append(row(
        f"al_step_chunk_W{W}", us,
        f"{inner / (us / 1e6):.0f} fused steps/s k={k}"
        f" calls/inner-loop={-(-inner // k)} (interpret)"))
    return rows


def streaming_day() -> list[str]:
    """Whole-day scan (`run_scanned`) vs the per-tick step() loop: same
    warm-started rolling-horizon day, one XLA dispatch instead of
    n_ticks — the ISSUE-6 acceptance artifact (parity < 0.01 pp)."""
    from repro.core.carbon import ForecastStream
    from repro.core.fleet_solver import synthetic_fleet
    from repro.core.streaming import RollingHorizonSolver

    rows = []
    W, n_ticks, cold, warm = 32, 12, 300, 75
    p = synthetic_fleet(W)

    def mk():
        return RollingHorizonSolver(
            p, ForecastStream.caiso(n_ticks=n_ticks, horizon=p.T, seed=7),
            policy="cr1", cold_steps=cold, warm_steps=warm)

    rep_l = mk().run(n_ticks)          # compiles cold + warm tick traces
    rep_s = mk().run_scanned(n_ticks)  # compiles the day-scan trace
    us_loop = timeit(lambda: mk().run(n_ticks), repeats=2, warmup=0)
    us_scan = timeit(lambda: mk().run_scanned(n_ticks), repeats=2,
                     warmup=0)
    gap = abs(rep_l.realized_reduction_pct - rep_s.realized_reduction_pct)
    rows.append(row(
        f"streaming_day_W{W}", us_scan,
        f"scan({n_ticks}ticks)={us_scan / 1e3:.0f}ms vs"
        f" loop={us_loop / 1e3:.0f}ms"
        f" speedup={us_loop / max(us_scan, 1e-9):.2f}x"
        f" dispatches=1"
        f" parity={gap:.4f}pp"
        f" realized={rep_s.realized_reduction_pct:.2f}%"))
    return rows


def train_throughput() -> list[str]:
    """End-to-end reduced-model training throughput on CPU (the example
    driver's speed — sanity, not a TPU number)."""
    from repro.configs import get_config, reduced
    from repro.configs.base import ShapeCell
    from repro.data.pipeline import synthetic_batch
    from repro.launch.steps import make_train_step
    from repro.optim.adamw import AdamWConfig, adamw_init
    from repro.models import transformer as tf
    cfg = reduced(get_config("stablelm-3b"), layers=2, d_model=128)
    shape = ShapeCell("bench", 128, 8, "train")
    params = tf.init_params(cfg, jax.random.PRNGKey(0))
    opt_cfg = AdamWConfig(total_steps=100)
    opt = adamw_init(params, opt_cfg)
    step = jax.jit(make_train_step(cfg, opt_cfg))
    batch = synthetic_batch(cfg, shape, 0)
    p, o, loss = step(params, opt, batch)   # compile
    us = timeit(lambda: jax.block_until_ready(step(p, o, batch)), repeats=3)
    toks = shape.global_batch * shape.seq_len
    return [row("train_step_reduced", us,
                f"{toks / (us / 1e6):.0f} tok/s loss={float(loss):.3f}")]


def fleet_region_scale() -> list[str]:
    """Multi-region engine at R ∈ {1, 2, 4} × W ∈ {1k, 10k}: CR1 solve
    latency with per-region segment-summed norms vs the degenerate R=1
    path (which canonicalizes onto the single-region engine, so its row
    doubles as the refactor's zero-overhead check), plus the host-side
    migration post-stage (`fleet_migration`) timed separately — it runs
    once per committed plan, not per solver step. R>1 rows also time the
    coupled in-loop migration solve (`SolveContext(coupled_migration=
    True)`) against the post-stage pipeline end to end: the carbon delta
    it buys and what the joint (D, y) refine costs in wall-clock."""
    from repro.core.api import CR1, SolveContext, solve
    from repro.core.carbon import regional_traces
    from repro.core.fleet_solver import (RegionTopology, regional_fleet,
                                         synthetic_fleet)
    from repro.core.migration import fleet_migration
    rows = []
    states = ("CA", "TX", "NY", "FL")
    base = synthetic_fleet(256)
    lam = 1.45
    cr1 = CR1(lam=lam)
    for W, steps in ((1_000, 200), (10_000, 80)):
        for R in (1, 2, 4):
            mcis, _ = regional_traces(states[:R], 2050, hours=base.T,
                                      utc_offsets="auto")
            fleets = [_tiled_fleet(base, W // R, seed=r) for r in range(R)]
            p = regional_fleet(fleets, mcis)        # no topology: pure solve
            ctx = SolveContext(steps=steps)
            solve(p, cr1, ctx=ctx)                  # compile
            us = timeit(lambda: solve(p, cr1, ctx=ctx), repeats=2, warmup=0)
            res = solve(p, cr1, ctx=ctx)
            derived = (f"R={R} W={p.W} steps={steps}"
                       f" carbon={res.carbon_reduction_pct:.2f}%")
            if R > 1:
                ent = float(np.asarray(p.entitlement).sum())
                bw = np.full((R, R), 0.05 * ent / (R - 1))
                np.fill_diagonal(bw, 0.0)
                pt = dataclasses.replace(p, topology=RegionTopology(
                    cost=np.full((R, R), 2.0), bandwidth=bw))
                D = np.asarray(res.D)
                plan = fleet_migration(pt, D)       # warm numpy caches
                us_mig = timeit(lambda: fleet_migration(pt, D),
                                repeats=2, warmup=0)
                derived += (f" mig_ms={us_mig / 1e3:.0f}"
                            f" mig_net={plan.net_saved:.0f}")
            rows.append(row(f"fleet_region_R{R}_W{W}", us, derived))
            if R > 1:
                post = solve(pt, cr1, ctx=ctx)          # compile + result
                us_post = timeit(lambda: solve(pt, cr1, ctx=ctx),
                                 repeats=1, warmup=0)
                cctx = dataclasses.replace(ctx, coupled_migration=True)
                coup = solve(pt, cr1, ctx=cctx)         # compile + result
                us_coup = timeit(lambda: solve(pt, cr1, ctx=cctx),
                                 repeats=1, warmup=0)
                delta = (coup.carbon_reduction_pct
                         - post.carbon_reduction_pct)
                rows.append(row(
                    f"fleet_region_coupled_R{R}_W{W}", us_coup,
                    f"R={R} W={p.W} post_ms={us_post / 1e3:.0f}"
                    f" post={post.carbon_reduction_pct:.2f}%"
                    f" coupled={coup.carbon_reduction_pct:.2f}%"
                    f" delta={delta:+.3f}pp"
                    f" used={bool(coup.extras.get('coupled_migration'))}"))
    return rows
