"""Fused AL inner-step kernel (kernels/al_step) vs its jnp oracle, the
chunked `fused_inner` dispatcher, and the fused-vs-generic solve paths.

Tolerance strategy (see the note in `kernels/al_step/ref.py`): the
analytic subgradient is discontinuous at the batch-penalty hinges, so a
1-ulp arithmetic difference (Pallas interpret mode associates cumsum
reductions differently than plain XLA) can flip an indicator and grow
into O(1) iterate differences over a few steps. Bitwise-tight (<=1e-5)
kernel-vs-oracle checks therefore use hinge-stable inputs — RTS-only
fleets (smooth cubic penalty, no hinges) for multi-step/vmap/padding
coverage, batch rows only for a single step from a hinge-stable point —
while mixed-fleet semantics are checked at the solve level against the
independent autodiff engine path with a pp-scale tolerance.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.api import CR1, CR2, SolveContext, solve
from repro.core.engine import EngineConfig
from repro.core.fleet_solver import _bounds, synthetic_fleet
from repro.kernels.al_step.kernel import al_step_pallas
from repro.kernels.al_step.ops import make_fused_inner, pack_rows
from repro.kernels.al_step.ref import al_step_ref

TOL = 1e-5


def _rts_only(p):
    """Recast every batch row as an RTS row (smooth cubic penalty only):
    hinge-free inputs for bitwise-tight kernel-vs-oracle parity."""
    W = p.W
    cubic = np.array([2e-4, 1.5e-3, 0.04], np.float64)
    rts = np.where(np.asarray(p.is_batch)[:, None], cubic, p.rts_coeffs)
    return dataclasses.replace(p, is_batch=np.zeros(W, bool),
                               betas=np.zeros((W, 3)), rts_coeffs=rts)


def _raw_inputs(p, mode, seed=1, stable=False):
    """Random-but-feasible (x, m, v, usage, jobs, lo, hi, rowp, cvec,
    scal) in the kernel's packed layout. `stable=True` keeps x strictly
    positive and away from hinge boundaries (cumsums and the batch
    penalty argument z stay clearly one-sided for one step)."""
    rng = np.random.default_rng(seed)
    lo, hi = (np.asarray(a, np.float32) for a in _bounds(p))
    if stable:
        x = np.clip(0.25 * np.asarray(p.usage) + 0.01, lo, hi)
    else:
        x = np.clip(rng.normal(0.0, 0.3, lo.shape), lo, hi)
    x = x.astype(np.float32)
    m = rng.normal(0.0, 0.01, x.shape).astype(np.float32)
    v = np.abs(rng.normal(0.0, 1e-4, x.shape)).astype(np.float32)
    refs = (np.abs(rng.normal(1.0, 0.2, p.W)).astype(np.float32)
            if mode == "cr2" else None)
    row10 = pack_rows(jnp.asarray(p.rts_coeffs), jnp.asarray(p.betas),
                      jnp.asarray(p.k), jnp.asarray(p.x2_kind),
                      jnp.asarray(p.is_batch), refs=refs)
    lam = (rng.normal(0.0, 0.5, (p.W, 1)).astype(np.float32)
           if mode == "cr2" else np.zeros((p.W, 1), np.float32))
    rowp = jnp.concatenate([row10, jnp.asarray(lam),
                            jnp.zeros((p.W, 1), jnp.float32)], axis=1)
    cvec = rng.normal(-0.5, 0.2, (1, p.T)).astype(np.float32)
    # [coef0, mu, inv_scale, lr_scale, t0, 0, 0, 0]
    scal = np.array([[1.45, 10.0, 0.8, 0.02, 3.0, 0, 0, 0]], np.float32)
    arrs = (x, m, v, np.asarray(p.usage, np.float32),
            np.asarray(p.jobs, np.float32), lo, hi)
    return tuple(jnp.asarray(a) for a in arrs) + (rowp, jnp.asarray(cvec),
                                                  jnp.asarray(scal))


@pytest.mark.parametrize("mode", ["cr1", "cr2"])
@pytest.mark.parametrize("k_steps", [1, 4, 7])
def test_al_step_matches_ref_rts(mode, k_steps):
    """Hinge-free multi-step parity: kernel == oracle to <=1e-5 on the
    iterate AND both Adam moments."""
    p = _rts_only(synthetic_fleet(12, hours=48, seed=0))
    args = _raw_inputs(p, mode, seed=k_steps)
    out = al_step_pallas(*args, mode=mode, k_steps=k_steps, interpret=True)
    ref = al_step_ref(*args, mode=mode, k_steps=k_steps)
    for o, r, name in zip(out, ref, "xmv"):
        np.testing.assert_allclose(np.asarray(o), np.asarray(r), rtol=TOL,
                                   atol=TOL, err_msg=name)


@pytest.mark.parametrize("mode", ["cr1", "cr2"])
def test_al_step_batch_rows_single_step(mode):
    """Mixed RTS+batch fleet, one step from a hinge-stable point: the
    hinged batch gradient path agrees to <=1e-5 too."""
    p = synthetic_fleet(12, hours=48, seed=0)
    assert np.asarray(p.is_batch).any()          # exercise both branches
    args = _raw_inputs(p, mode, seed=5, stable=True)
    out = al_step_pallas(*args, mode=mode, k_steps=1, interpret=True)
    ref = al_step_ref(*args, mode=mode, k_steps=1)
    for o, r, name in zip(out, ref, "xmv"):
        np.testing.assert_allclose(np.asarray(o), np.asarray(r), rtol=TOL,
                                   atol=TOL, err_msg=name)


@pytest.mark.parametrize("W", [5, 130])
def test_al_step_padding(W):
    """Row padding (W -> block_w multiples) is inert: padded rows never
    leak into the true rows and outputs slice back to (W, T)."""
    p = _rts_only(synthetic_fleet(W, hours=48, seed=2))
    args = _raw_inputs(p, "cr1", seed=0)
    out = al_step_pallas(*args, mode="cr1", k_steps=2, interpret=True)
    ref = al_step_ref(*args, mode="cr1", k_steps=2)
    assert out[0].shape == (W, p.T)
    np.testing.assert_allclose(np.asarray(out[0]), np.asarray(ref[0]),
                               rtol=TOL, atol=TOL)


def test_al_step_bf16_moments():
    """bf16 moment storage: kernel and oracle share the cast points, so
    parity stays tight; moment dtypes round-trip."""
    p = _rts_only(synthetic_fleet(8, hours=48, seed=1))
    x, m, v, *rest = _raw_inputs(p, "cr1", seed=3)
    m, v = m.astype(jnp.bfloat16), v.astype(jnp.bfloat16)
    out = al_step_pallas(x, m, v, *rest, mode="cr1", k_steps=4,
                         interpret=True)
    ref = al_step_ref(x, m, v, *rest, mode="cr1", k_steps=4)
    assert out[1].dtype == out[2].dtype == jnp.bfloat16
    for o, r in zip(out, ref):
        np.testing.assert_allclose(np.asarray(o, np.float32),
                                   np.asarray(r, np.float32),
                                   rtol=TOL, atol=TOL)


def test_al_step_vmap_over_scalars():
    """The sweep/ensemble lanes vmap the packed scalars (per-λ coef0):
    batched kernel == per-lane oracle."""
    p = _rts_only(synthetic_fleet(8, hours=48, seed=4))
    x, m, v, u, j, lo, hi, rowp, cvec, scal = _raw_inputs(p, "cr1", seed=2)
    scals = jnp.stack([scal.at[0, 0].set(c) for c in (0.5, 1.45, 3.0)])

    def run(s):
        return al_step_pallas(x, m, v, u, j, lo, hi, rowp, cvec, s,
                              mode="cr1", k_steps=3, interpret=True)[0]

    batched = jax.vmap(run)(scals)
    for i in range(3):
        ref = al_step_ref(x, m, v, u, j, lo, hi, rowp, cvec, scals[i],
                          mode="cr1", k_steps=3)[0]
        np.testing.assert_allclose(np.asarray(batched[i]), np.asarray(ref),
                                   rtol=TOL, atol=TOL)


def test_al_step_rejects_bad_mode():
    p = _rts_only(synthetic_fleet(4, hours=48, seed=0))
    args = _raw_inputs(p, "cr1")
    with pytest.raises(ValueError, match="cr1|cr2"):
        al_step_ref(*args, mode="cr3", k_steps=1)


@pytest.mark.parametrize("steps,k_steps", [(13, 5), (8, 8), (6, 16)])
def test_fused_inner_chunking_matches_oracle_path(steps, k_steps):
    """`make_fused_inner` splits inner_steps into full chunks + remainder
    inside a lax.scan; the Pallas route must equal the oracle route for
    uneven splits, exact fits, and k_steps > inner_steps (clamped)."""
    p = _rts_only(synthetic_fleet(8, hours=48, seed=6))
    lo, hi = _bounds(p)
    cfg = EngineConfig(inner_steps=steps, outer_steps=1)
    row = pack_rows(jnp.asarray(p.rts_coeffs), jnp.asarray(p.betas),
                    jnp.asarray(p.k), jnp.asarray(p.x2_kind),
                    jnp.asarray(p.is_batch))
    cvec = -0.01 * jnp.asarray(p.mci, jnp.float32)[None, :]
    kw = dict(mode="cr1", cfg=cfg, step_scale=1.0, coef0=1.45,
              k_steps=k_steps, day_hours=p.day_hours)
    mk = lambda **o: make_fused_inner(           # noqa: E731
        jnp.asarray(p.usage, jnp.float32), jnp.asarray(p.jobs, jnp.float32),
        lo.astype(jnp.float32), hi.astype(jnp.float32), row, cvec,
        **kw, **o)
    x0 = jnp.zeros((p.W, p.T), jnp.float32)
    zl = jnp.zeros(0)
    mu = jnp.asarray(10.0)
    a = mk(interpret=True)(x0, zl, zl, mu)
    b = mk(use_ref=True)(x0, zl, zl, mu)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=TOL,
                               atol=TOL)


@pytest.mark.slow
@pytest.mark.parametrize("policy,steps", [(CR1(lam=1.45), 200),
                                          (CR2(cap_frac=0.78, outer=3),
                                           120)])
def test_fused_solve_matches_generic_engine(policy, steps):
    """Semantic check on the real mixed fleet: the fused-kernel solve and
    the generic autodiff engine land on the same optimum (pp scale) —
    independent gradient implementations, so hinge-chaos tolerance."""
    p = synthetic_fleet(16, hours=48, seed=0)
    a = solve(p, policy, ctx=SolveContext(use_kernel=False, steps=steps))
    b = solve(p, policy, ctx=SolveContext(use_kernel=True, steps=steps))
    assert abs(a.carbon_reduction_pct - b.carbon_reduction_pct) < 0.05
    assert abs(a.total_penalty_pct - b.total_penalty_pct) < 0.05
    assert b.preservation_violation < 1e-3
