"""EDD scheduler simulator invariants."""
import numpy as np
import pytest

from repro.sched.edd import (EDDScheduler, dr_shaped_curtailments,
                             mixed_curtailments, random_walk_curtailments)
from repro.sched.traces import JobTrace, make_job_trace


def small_trace():
    return JobTrace(
        arrival=np.array([0.0, 0.0, 1.0, 2.0]),
        power=np.array([1.0, 1.0, 1.0, 2.0]),
        duration=np.array([1.0, 2.0, 1.0, 1.0]),
        slo=np.array([1.0, np.inf, 2.0, 1.0]))


def test_capacity_never_violated():
    trace = make_job_trace("batch_slo", hours=24, num_jobs=500,
                           total_power=10.0)
    cap = np.full(24, 10.0)
    res = EDDScheduler().run(trace, cap)
    assert (res.utilization <= cap + 1e-9).all()


def test_ample_capacity_zero_waiting():
    trace = small_trace()
    res = EDDScheduler().run(trace, np.full(8, 100.0))
    assert res.total_waiting == 0.0
    assert res.total_tardiness == 0.0
    assert np.allclose(res.start, trace.arrival)


def test_curtailment_increases_waiting():
    trace = make_job_trace("batch_noslo", hours=24, num_jobs=800,
                           total_power=10.0, seed=1)
    s = EDDScheduler()
    base = s.run(trace, np.full(24, 10.5))
    cut = s.run(trace, np.full(24, 10.5) * 0.6)
    assert cut.total_waiting > base.total_waiting


def test_edd_prefers_earlier_due_date():
    # Two jobs arrive together; capacity fits only one per hour.
    trace = JobTrace(arrival=np.array([0.0, 0.0]),
                     power=np.array([1.0, 1.0]),
                     duration=np.array([1.0, 1.0]),
                     slo=np.array([8.0, 1.0]))
    res = EDDScheduler().run(trace, np.full(8, 1.0))
    assert res.start[1] < res.start[0]   # tighter SLO goes first


def test_tardiness_counts_only_slo_jobs():
    trace = small_trace()
    res = EDDScheduler().run(trace, np.full(8, 0.5))  # starved
    assert res.tardiness[1] == 0.0       # no-SLO job never tardy
    assert res.total_tardiness >= 0.0


def test_random_walk_positive_mean():
    usage = np.full(48, 10.0)
    ds = random_walk_curtailments(usage, 16, seed=0)
    assert ds.shape == (16, 48)
    assert (ds.mean(axis=1) > 0).all()
    assert (np.abs(ds) <= 0.5 * usage + 1e-9).all()


def test_dr_shaped_within_bounds():
    usage = np.full(48, 10.0)
    ds = dr_shaped_curtailments(usage, 16, seed=0)
    assert (ds <= 0.5 * usage + 1e-9).all()
    assert (ds >= -0.5 * usage - 1e-9).all()


def test_mixed_count():
    usage = np.full(48, 10.0)
    assert mixed_curtailments(usage, 15).shape == (15, 48)
