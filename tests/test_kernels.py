"""Per-kernel shape/dtype sweeps against the pure-jnp oracles
(interpret=True executes the Pallas kernel body on CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis_compat import given, hnp, settings, st

from repro.kernels.dr_features.ops import dr_features
from repro.kernels.dr_features.ref import dr_features_ref
from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.rmsnorm.ops import rmsnorm
from repro.kernels.rmsnorm.ref import rmsnorm_ref
from repro.kernels.ssd_scan.ops import ssd_scan
from repro.kernels.ssd_scan.ref import ssd_scan_ref

KEY = jax.random.PRNGKey(0)


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------
FA_CASES = [
    # (B, Sq, Skv, H, KV, Dh, causal, dtype, blocks)
    (2, 128, 128, 4, 2, 64, True, jnp.float32, 64),
    (1, 200, 200, 4, 4, 64, True, jnp.float32, 64),   # ragged seq
    (2, 64, 256, 8, 2, 128, False, jnp.float32, 64),  # cross-attn
    (1, 256, 256, 4, 1, 64, True, jnp.bfloat16, 128), # MQA bf16
    (1, 96, 96, 2, 2, 32, True, jnp.float32, 32),     # small dims
    (2, 128, 512, 4, 4, 64, False, jnp.bfloat16, 128),
]


@pytest.mark.parametrize("case", FA_CASES)
def test_flash_attention_matches_ref(case):
    B, Sq, Skv, H, KV, Dh, causal, dt, blk = case
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (B, Sq, H, Dh), dt)
    k = jax.random.normal(ks[1], (B, Skv, KV, Dh), dt)
    v = jax.random.normal(ks[2], (B, Skv, KV, Dh), dt)
    out = flash_attention(q, k, v, causal=causal, block_q=blk, block_k=blk)
    ref = attention_ref(q, k, v, causal=causal)
    tol = 2e-2 if dt == jnp.bfloat16 else 1e-5
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               rtol=tol, atol=tol)


def test_flash_attention_matches_model_path():
    """Kernel and the model's chunked-jnp attention agree (same math)."""
    from repro.models.attention import flash_attention_jnp
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (2, 128, 4, 64))
    k = jax.random.normal(ks[1], (2, 128, 2, 64))
    v = jax.random.normal(ks[2], (2, 128, 2, 64))
    a = flash_attention(q, k, v, causal=True, block_q=64, block_k=64)
    b = flash_attention_jnp(q, k, v, causal=True, chunk=64)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                               rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# rmsnorm
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("shape,dtype", [
    ((4, 64, 256), jnp.float32),
    ((3, 37, 512), jnp.float32),     # ragged rows
    ((2, 128, 1024), jnp.bfloat16),
    ((1, 1, 128), jnp.float32),
])
def test_rmsnorm_matches_ref(shape, dtype):
    x = jax.random.normal(KEY, shape, dtype)
    s = jax.random.normal(jax.random.PRNGKey(1), (shape[-1],),
                          jnp.float32) * 0.1 + 1.0
    out = rmsnorm(x, s)
    ref = rmsnorm_ref(x, s)
    tol = 2e-2 if dtype == jnp.bfloat16 else 1e-5
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               rtol=tol, atol=tol)


# ---------------------------------------------------------------------------
# ssd_scan
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("dims", [(2, 6, 4, 16, 32), (1, 12, 2, 8, 16),
                                  (3, 3, 8, 32, 64)])
def test_ssd_scan_matches_ref(dims):
    B, NC, H, P, N = dims
    ks = jax.random.split(KEY, 2)
    st_ = jax.random.normal(ks[0], (B, NC, H, P, N))
    dec = jnp.abs(jax.random.normal(ks[1], (B, NC, H))) * 0.5
    hp, hl = ssd_scan(st_, dec)
    hp_r, hl_r = ssd_scan_ref(st_, dec)
    np.testing.assert_allclose(np.asarray(hp), np.asarray(hp_r),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(hl), np.asarray(hl_r),
                               rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# dr_features — hypothesis sweep vs core.features oracle
# ---------------------------------------------------------------------------
@given(hnp.arrays(np.float32, (11, 48),
                  elements=st.floats(-8, 8, allow_nan=False, width=32)))
@settings(max_examples=15, deadline=None)
def test_dr_features_matches_core(d):
    u = np.abs(d) + 1.0
    j = np.abs(d) * 3 + 0.5
    out = np.asarray(dr_features(jnp.asarray(d), jnp.asarray(u),
                                 jnp.asarray(j)))
    ref = np.asarray(dr_features_ref(jnp.asarray(d), jnp.asarray(u),
                                     jnp.asarray(j)))
    np.testing.assert_allclose(out, ref, rtol=2e-4, atol=2e-4)


def _grad_pair(d, u, j, w):
    """Gradient of w·features(d) through the kernel's analytic custom VJP
    and through plain jnp autodiff of the oracle."""
    u, j, w = jnp.asarray(u), jnp.asarray(j), jnp.asarray(w)

    def loss(fn):
        return lambda dd: (fn(dd, u, j) * w).sum()

    return (jax.grad(loss(dr_features))(jnp.asarray(d)),
            jax.grad(loss(dr_features_ref))(jnp.asarray(d)))


def test_dr_features_grad_matches_autodiff():
    """The hand-written backward pass (strict-> hinge subgradients +
    reverse cumsums) must equal autodiff of the jnp oracle away from
    exact hinge ties."""
    rng = np.random.default_rng(7)
    d = rng.normal(0.0, 1.0, (6, 48)).astype(np.float32)
    d[np.abs(d) < 1e-3] += 0.01              # keep off measure-zero ties
    u = (np.abs(rng.normal(2.0, 0.3, d.shape)) + 0.5).astype(np.float32)
    j = (np.abs(rng.normal(1.0, 0.2, d.shape)) + 0.1).astype(np.float32)
    g_k, g_r = _grad_pair(d, u, j, rng.normal(size=4).astype(np.float32))
    np.testing.assert_allclose(np.asarray(g_k), np.asarray(g_r),
                               rtol=1e-4, atol=1e-4)


def test_dr_features_grad_at_hinge_crossings():
    """Rows engineered so the running sums cross zero mid-horizon (the
    active/inactive hinge boundary the analytic VJP gates on): both
    directions of the crossing, no entry exactly at the tie."""
    T = 48
    up_down = np.r_[np.full(T // 2, 0.7), np.full(T // 2, -0.9)]
    down_up = -up_down
    d = np.stack([up_down, down_up]).astype(np.float32)
    u = np.full(d.shape, 2.0, np.float32)
    j = np.full(d.shape, 1.5, np.float32)
    assert (np.cumsum(d, axis=1) > 0).any() \
        and (np.cumsum(d, axis=1) < 0).any()
    g_k, g_r = _grad_pair(d, u, j, np.ones(4, np.float32))
    np.testing.assert_allclose(np.asarray(g_k), np.asarray(g_r),
                               rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("W,T", [(1, 24), (130, 48), (1000, 48)])
def test_dr_features_shapes(W, T):
    d = jnp.ones((W, T))
    u = jnp.ones((W, T)) * 2
    j = jnp.ones((W, T))
    assert dr_features(d, u, j).shape == (W, 4)
