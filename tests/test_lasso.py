"""Lasso (FISTA + 10-fold CV) tests."""
import numpy as np
import pytest

from repro.core.lasso import fit_lasso_cv, lasso_fista, soft_threshold


def test_soft_threshold():
    import jax.numpy as jnp
    x = jnp.asarray([-3.0, -0.5, 0.0, 0.5, 3.0])
    out = np.asarray(soft_threshold(x, 1.0))
    assert np.allclose(out, [-2.0, 0.0, 0.0, 0.0, 2.0])


def test_recovers_sparse_coefficients():
    rng = np.random.default_rng(0)
    n, F = 200, 6
    X = rng.standard_normal((n, F))
    true = np.array([3.0, 0.0, -2.0, 0.0, 0.0, 0.0])
    y = X @ true + 0.05 * rng.standard_normal(n) + 1.5
    fit = fit_lasso_cv(X, y, folds=5)
    assert set(fit.selected) >= {0, 2}
    assert abs(fit.coef[0] - 3.0) < 0.2
    assert abs(fit.coef[2] + 2.0) < 0.2
    assert abs(fit.intercept - 1.5) < 0.2
    assert fit.r2 > 0.95


def test_heavy_regularization_zeroes_out():
    import jax.numpy as jnp
    rng = np.random.default_rng(1)
    X = rng.standard_normal((50, 4))
    y = X[:, 0] * 0.01
    w, b = lasso_fista(jnp.asarray(X), jnp.asarray(y), jnp.asarray(100.0))
    assert float(np.abs(np.asarray(w)).max()) == pytest.approx(0.0)


def test_cv_quality_reported():
    rng = np.random.default_rng(2)
    X = rng.standard_normal((100, 3))
    y = X @ np.array([1.0, 2.0, 0.0]) + 0.1 * rng.standard_normal(100)
    fit = fit_lasso_cv(X, y, folds=10)
    assert fit.cv_mae_mean < 0.5
    assert fit.cv_mae_var >= 0.0
    pred = fit.predict(X)
    assert np.corrcoef(pred, y)[0, 1] > 0.99
