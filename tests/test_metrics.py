"""Fairness metric tests (paper §VI-E)."""
import numpy as np
import pytest
from hypothesis_compat import given, hnp, settings, st

from repro.core.metrics import (box_stats, capacity_scaled_entropy,
                                jain_index, max_min_ratio, pareto_frontier)


def test_entropy_max_at_proportional():
    """Entropy = log2(4) = 2 exactly when losses ∝ entitlements."""
    E = np.array([10.0, 20.0, 30.0, 40.0])
    losses = 0.1 * E
    assert capacity_scaled_entropy(losses, E) == pytest.approx(2.0)


def test_entropy_low_when_concentrated():
    E = np.ones(4)
    losses = np.array([1.0, 0.0, 0.0, 0.0])
    assert capacity_scaled_entropy(losses, E) == pytest.approx(0.0)


def test_entropy_zero_dr_is_fair():
    E = np.ones(4)
    assert capacity_scaled_entropy(np.zeros(4), E) == pytest.approx(2.0)


@given(hnp.arrays(np.float64, (4,), elements=st.floats(0, 100)))
@settings(max_examples=50, deadline=None)
def test_entropy_bounded(vals):
    E = np.array([10.0, 20.0, 30.0, 40.0])
    e = capacity_scaled_entropy(vals, E)
    assert -1e-9 <= e <= 2.0 + 1e-9


def test_pareto_frontier():
    carbon = np.array([1.0, 2.0, 3.0, 2.5])
    pen = np.array([1.0, 1.5, 4.0, 1.2])
    idx = pareto_frontier(carbon, pen)
    # (2.5, 1.2) dominates (2.0, 1.5); (1,1) kept (lowest pen), (3,4) kept
    # (highest carbon).
    assert 3 in idx and 0 in idx and 2 in idx and 1 not in idx


def test_jain_proportional_and_concentrated():
    E = np.array([10.0, 20.0, 30.0, 40.0])
    assert jain_index(0.1 * E, E) == pytest.approx(1.0)
    assert jain_index(np.array([1.0, 0, 0, 0]), np.ones(4)) \
        == pytest.approx(0.25)


def test_max_min_ratio_basic():
    E = np.ones(4)
    assert max_min_ratio(np.ones(4), E) == pytest.approx(1.0)
    assert max_min_ratio(np.array([2.0, 1, 1, 1]), E) == pytest.approx(2.0)


def test_fairness_all_zero_is_fair():
    """No DR anywhere = trivially fair (1.0), never NaN or a raise."""
    E = np.ones(4)
    assert jain_index(np.zeros(4), E) == 1.0
    assert max_min_ratio(np.zeros(4), E) == 1.0


def test_fairness_empty_axis():
    """Zero workloads: 1.0, not numpy's zero-size reduction ValueError
    (max_min_ratio used to raise) or a 0/0 NaN (jain_index)."""
    assert jain_index(np.zeros(0), np.zeros(0)) == 1.0
    assert max_min_ratio(np.zeros(0), np.zeros(0)) == 1.0
    # (S, 0) ensemble stack -> per-scenario 1.0s of the right shape.
    stacked_j = jain_index(np.zeros((3, 0)), np.zeros(0))
    stacked_m = max_min_ratio(np.zeros((3, 0)), np.zeros(0))
    assert stacked_j.shape == (3,) and np.all(stacked_j == 1.0)
    assert stacked_m.shape == (3,) and np.all(stacked_m == 1.0)


def test_fairness_nan_propagates():
    """A non-finite share must surface as NaN, not read as 'fair'.
    (The old `den > eps` guard compared False on NaN and returned 1.0.)"""
    E = np.ones(4)
    bad = np.array([1.0, np.nan, 2.0, 3.0])
    assert np.isnan(jain_index(bad, E))
    assert np.isnan(max_min_ratio(bad, E))
    # Only the poisoned row of a stack goes NaN; healthy rows keep
    # their finite index.
    V = np.array([[1.0, 2, 3, 4], [1.0, np.nan, 3, 4]])
    j, m = jain_index(V, E), max_min_ratio(V, E)
    assert np.isfinite(j[0]) and np.isnan(j[1])
    assert np.isfinite(m[0]) and np.isnan(m[1])
    assert np.isnan(jain_index(np.array([1.0, np.inf, 1, 1]), E))


def test_box_stats():
    s = box_stats(np.arange(101, dtype=float))
    assert s["median"] == 50 and s["q1"] == 25 and s["q3"] == 75
    assert s["min"] == 0 and s["max"] == 100
