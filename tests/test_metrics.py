"""Fairness metric tests (paper §VI-E)."""
import numpy as np
import pytest
from hypothesis_compat import given, hnp, settings, st

from repro.core.metrics import (box_stats, capacity_scaled_entropy,
                                pareto_frontier)


def test_entropy_max_at_proportional():
    """Entropy = log2(4) = 2 exactly when losses ∝ entitlements."""
    E = np.array([10.0, 20.0, 30.0, 40.0])
    losses = 0.1 * E
    assert capacity_scaled_entropy(losses, E) == pytest.approx(2.0)


def test_entropy_low_when_concentrated():
    E = np.ones(4)
    losses = np.array([1.0, 0.0, 0.0, 0.0])
    assert capacity_scaled_entropy(losses, E) == pytest.approx(0.0)


def test_entropy_zero_dr_is_fair():
    E = np.ones(4)
    assert capacity_scaled_entropy(np.zeros(4), E) == pytest.approx(2.0)


@given(hnp.arrays(np.float64, (4,), elements=st.floats(0, 100)))
@settings(max_examples=50, deadline=None)
def test_entropy_bounded(vals):
    E = np.array([10.0, 20.0, 30.0, 40.0])
    e = capacity_scaled_entropy(vals, E)
    assert -1e-9 <= e <= 2.0 + 1e-9


def test_pareto_frontier():
    carbon = np.array([1.0, 2.0, 3.0, 2.5])
    pen = np.array([1.0, 1.5, 4.0, 1.2])
    idx = pareto_frontier(carbon, pen)
    # (2.5, 1.2) dominates (2.0, 1.5); (1,1) kept (lowest pen), (3,4) kept
    # (highest carbon).
    assert 3 in idx and 0 in idx and 2 in idx and 1 not in idx


def test_box_stats():
    s = box_stats(np.arange(101, dtype=float))
    assert s["median"] == 50 and s["q1"] == 25 and s["q3"] == 75
    assert s["min"] == 0 and s["max"] == 100
