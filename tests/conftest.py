import os
import sys

import numpy as np
import pytest

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

# NOTE: no xla_force_host_platform_device_count here — smoke tests must see
# a single device. Multi-device tests spawn subprocesses (see _subproc).


@pytest.fixture(scope="session")
def paper_fleet():
    """Cached calibrated four-service fleet (shared across the session)."""
    from repro.core.fleetcache import cached_paper_fleet
    return cached_paper_fleet()


@pytest.fixture(scope="session")
def dr_problem(paper_fleet):
    from repro.core.carbon import caiso_2021
    from repro.core.policies import DRProblem
    models = tuple(paper_fleet[n]
                   for n in ("RTS1", "RTS2", "AITraining", "DataPipeline"))
    return DRProblem(models=models, mci=caiso_2021(48).mci)


@pytest.fixture()
def rng():
    return np.random.default_rng(0)


def run_in_subprocess(code: str, devices: int = 8, timeout: int = 420) -> str:
    """Run `code` in a fresh python with N host devices. Returns stdout;
    raises on nonzero exit."""
    import subprocess
    env = dict(os.environ)
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "")
                        + f" --xla_force_host_platform_device_count={devices}")
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    res = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, env=env, timeout=timeout)
    if res.returncode != 0:
        raise AssertionError(
            f"subprocess failed:\nSTDOUT:{res.stdout[-3000:]}\n"
            f"STDERR:{res.stderr[-3000:]}")
    return res.stdout
