"""Runtime tests: checkpoint roundtrip, fault-tolerant restart, stragglers,
elastic resize, gradient compression."""
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, reduced
from repro.configs.base import ShapeCell
from repro.data.pipeline import DataConfig, PrefetchingLoader, synthetic_batch
from repro.models import transformer as tf
from repro.optim.adamw import AdamWConfig, adamw_init
from repro.runtime.checkpoint import CheckpointManager
from repro.runtime.compression import _dequantize, _quantize, init_error
from repro.runtime.ft import FailurePlan, FTConfig, FaultTolerantRunner

CFG = reduced(get_config("stablelm-3b"), layers=2, d_model=64)
SHAPE = ShapeCell("t", 32, 4, "train")


def tiny_state():
    params = tf.init_params(CFG, jax.random.PRNGKey(0))
    opt = adamw_init(params, AdamWConfig())
    return params, opt


# ---------------------------------------------------------------------------
# checkpoint
# ---------------------------------------------------------------------------
def test_checkpoint_roundtrip(tmp_path):
    params, opt = tiny_state()
    mgr = CheckpointManager(tmp_path)
    mgr.save(7, {"params": params, "opt": opt}, blocking=True)
    like = jax.eval_shape(lambda: {"params": params, "opt": opt})
    restored, step = mgr.restore(like)
    assert step == 7
    for a, b in zip(jax.tree.leaves(params),
                    jax.tree.leaves(restored["params"])):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_gc_keeps_latest(tmp_path):
    params, opt = tiny_state()
    mgr = CheckpointManager(tmp_path, keep=2)
    for s in (1, 2, 3, 4):
        mgr.save(s, {"params": params, "opt": opt}, blocking=True)
    assert mgr.all_steps() == [3, 4]
    assert mgr.latest_step() == 4


def test_checkpoint_async_overlaps(tmp_path):
    params, opt = tiny_state()
    mgr = CheckpointManager(tmp_path)
    t0 = time.time()
    mgr.save(1, {"params": params, "opt": opt})   # non-blocking
    submit_time = time.time() - t0
    mgr.wait()
    assert submit_time < 1.0                      # snapshot is cheap
    assert mgr.latest_step() == 1


# ---------------------------------------------------------------------------
# fault tolerance
# ---------------------------------------------------------------------------
def make_runner(tmp_path, plan=None, ckpt_every=5):
    from repro.launch.steps import make_train_step
    opt_cfg = AdamWConfig(total_steps=50)
    step_fn = jax.jit(make_train_step(CFG, opt_cfg))
    mgr = CheckpointManager(tmp_path)
    return FaultTolerantRunner(
        step_fn, mgr, FTConfig(checkpoint_every=ckpt_every),
        plan), mgr


def batches():
    step = 0
    while True:
        yield synthetic_batch(CFG, SHAPE, step)
        step += 1


@pytest.mark.slow
def test_ft_runner_trains(tmp_path):
    runner, mgr = make_runner(tmp_path)
    params, opt = tiny_state()
    p, o, losses = runner.run(params, opt, batches(), num_steps=12)
    assert len(losses) == 12
    assert losses[-1] < losses[0]              # tiny model memorizes fast
    assert mgr.latest_step() == 12


@pytest.mark.slow
def test_ft_runner_recovers_from_failure(tmp_path):
    plan = FailurePlan(fail_steps=(7,))
    runner, mgr = make_runner(tmp_path, plan, ckpt_every=5)
    params, opt = tiny_state()
    p, o, losses = runner.run(params, opt, batches(), num_steps=15)
    events = [e["event"] for e in runner.events]
    assert "failure" in events
    assert "restored" in events
    # Completed the full budget despite the failure.
    assert mgr.latest_step() == 15


@pytest.mark.slow
def test_ft_runner_flags_stragglers(tmp_path):
    plan = FailurePlan(slow_steps=tuple(range(20, 24)), slow_seconds=0.4)
    runner, mgr = make_runner(tmp_path, plan, ckpt_every=50)
    params, opt = tiny_state()
    runner.run(params, opt, batches(), num_steps=26)
    events = [e["event"] for e in runner.events]
    assert "straggler" in events


# ---------------------------------------------------------------------------
# gradient compression
# ---------------------------------------------------------------------------
def test_quantize_dequantize_error_bound():
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal(1000).astype(np.float32))
    q, scale = _quantize(x)
    back = _dequantize(q, scale, x.shape, x.dtype)
    # int8 symmetric: error ≤ scale/2 per block.
    max_scale = float(scale.max())
    assert float(jnp.abs(back - x).max()) <= max_scale * 0.51


def test_compressed_psum_matches_uncompressed(tmp_path):
    """2-pod shard_map: compressed all-reduce ≈ exact mean within int8
    tolerance, and error feedback captures the residual."""
    from conftest import run_in_subprocess
    run_in_subprocess("""
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import Mesh, PartitionSpec as P
from repro.runtime.compression import compressed_psum_pod, init_error
mesh = jax.make_mesh((2,), ("pod",))
g = {"w": jnp.asarray(np.random.default_rng(0).standard_normal((2, 64)).astype(np.float32))}
e = init_error(g)
def f(g, e):
    out, new_e = compressed_psum_pod(g, e, "pod")
    return out, new_e
try:
    shard_map = jax.shard_map
except AttributeError:
    from jax.experimental.shard_map import shard_map
fn = shard_map(f, mesh=mesh, in_specs=(P("pod"), P("pod")),
               out_specs=(P("pod"), P("pod")))
out, new_e = fn(g, e)
exact = (np.asarray(g["w"])[0] + np.asarray(g["w"])[1]) / 2
got = np.asarray(out["w"])
err = np.abs(got[0] - exact).max()
assert err < 0.05, f"compression error too big: {err}"
resid = np.asarray(new_e["w"])
assert np.abs(resid).max() < 0.05
print("OK", err)
""", devices=2)


# ---------------------------------------------------------------------------
# elastic resize
# ---------------------------------------------------------------------------
@pytest.mark.slow
def test_elastic_resize_preserves_params():
    from conftest import run_in_subprocess
    run_in_subprocess("""
import jax, jax.numpy as jnp, numpy as np
from repro.configs import get_config, reduced
from repro.configs.base import ShapeCell
from repro.data.pipeline import synthetic_batch
from repro.optim.adamw import AdamWConfig, adamw_init
from repro.runtime.elastic import build, mesh_from_devices, resize
from repro.models import transformer as tf
cfg = reduced(get_config("stablelm-3b"), layers=2, d_model=64)
params = tf.init_params(cfg, jax.random.PRNGKey(0))
opt = adamw_init(params, AdamWConfig())
devs = jax.devices()
m1 = mesh_from_devices(devs, data=4, model=2)      # 8 chips
st = build(cfg, m1, params, opt)
batch = synthetic_batch(cfg, ShapeCell("t", 32, 4, "train"), 0)
p, o, loss1 = st.step_fn(st.params, st.opt_state, batch)
st.params, st.opt_state = p, o
# shrink to 4 chips (simulated node loss / CR power cut)
m2 = mesh_from_devices(devs, data=2, model=2)
st2 = resize(st, cfg, m2)
before = jax.tree.leaves(jax.tree.map(np.asarray, st.params))
after = jax.tree.leaves(jax.tree.map(np.asarray, st2.params))
for a, b in zip(before, after):
    np.testing.assert_array_equal(a, b)
p2, o2, loss2 = st2.step_fn(st2.params, st2.opt_state, batch)
assert np.isfinite(float(loss2))
print("resize OK", float(loss1), float(loss2))
""", devices=8)


# ---------------------------------------------------------------------------
# data pipeline
# ---------------------------------------------------------------------------
def test_loader_deterministic_and_sharded():
    b0 = synthetic_batch(CFG, SHAPE, 3, DataConfig(host_index=0, host_count=2))
    b0b = synthetic_batch(CFG, SHAPE, 3, DataConfig(host_index=0, host_count=2))
    b1 = synthetic_batch(CFG, SHAPE, 3, DataConfig(host_index=1, host_count=2))
    np.testing.assert_array_equal(b0["tokens"], b0b["tokens"])
    assert not np.array_equal(b0["tokens"], b1["tokens"])
    assert b0["tokens"].shape[0] == SHAPE.global_batch // 2


def test_prefetching_loader_yields(tmp_path):
    loader = PrefetchingLoader(CFG, SHAPE, DataConfig())
    b = next(iter(loader))
    assert b["tokens"].shape == (SHAPE.global_batch, SHAPE.seq_len)
    loader.set_throttle(0.5)
    b2 = next(iter(loader))
    assert b2["tokens"].shape == b["tokens"].shape
    loader.close()
