"""Penalty model tests (paper §IV, Eq. 1–2)."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import penalty as pen


def test_rts_polynomial_published_coefficients():
    """f_RTS1(δ) = 6.3δ³ − 13δ² + 51.6δ at δ=0.2 ⇒ ≈ 9.85 (% latency)."""
    m = pen.PenaltyModel(name="RTS1", kind="realtime",
                         usage=np.ones(1), entitlement=1.0, k=1.0,
                         params=pen.RTS_COEFFS["RTS1"])
    d = jnp.asarray([0.2])
    expected = 6.3 * 0.2**3 - 13 * 0.2**2 + 51.6 * 0.2
    assert float(m.raw_loss(d)) == pytest.approx(expected, rel=1e-6)


def test_rts2_monotone_on_curtailment_range():
    m = pen.PenaltyModel(name="RTS2", kind="realtime",
                         usage=np.ones(1), entitlement=1.0, k=1.0,
                         params=pen.RTS_COEFFS["RTS2"])
    deltas = np.linspace(0, 0.5, 20)
    losses = [float(m.raw_loss(jnp.asarray([x]))) for x in deltas]
    assert all(b >= a - 1e-9 for a, b in zip(losses, losses[1:]))


def test_k_calibration_property(paper_fleet):
    """C_i at the calibration curtailment equals the 15% entitlement loss
    (the defining property of k — §IV ¶4)."""
    for name in ("RTS1", "RTS2"):
        m = paper_fleet[name]
        d = m.calibration_curtailment()
        got = float(m.penalty(jnp.asarray(d)))
        want = pen.CALIBRATION_CAP * m.entitlement
        assert got == pytest.approx(want, rel=1e-3)


def test_batch_penalty_positive_part(paper_fleet):
    """Eq. 2: batch penalty is clamped at zero (boost can't earn credit)."""
    m = paper_fleet["AITraining"]
    d = -0.2 * m.usage          # pure boost
    assert float(m.penalty(jnp.asarray(d))) == pytest.approx(0.0, abs=1e-6)


def test_batch_penalty_increases_with_curtailment(paper_fleet):
    m = paper_fleet["DataPipeline"]
    c1 = float(m.penalty(jnp.asarray(0.2 * m.usage)))
    c2 = float(m.penalty(jnp.asarray(0.4 * m.usage)))
    assert c2 > c1 >= 0.0


def test_published_feature_selection(paper_fleet):
    assert paper_fleet["AITraining"].feature_names == (
        "waiting_time_power", "num_jobs_delayed")
    assert paper_fleet["DataPipeline"].feature_names == (
        "waiting_time_power", "waiting_time_squared")


def test_fleet_composition(paper_fleet):
    kinds = {m.kind for m in paper_fleet.values()}
    assert kinds == {"realtime", "batch_slo", "batch_noslo"}
    for m in paper_fleet.values():
        assert m.entitlement > float(np.max(m.usage))
