"""DR policy tests: constraints, efficiency ordering, fairness (paper §V-VI)."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.baselines import (b1_adjustments, b2_spec, b3_adjustments,
                                  b4_spec)
from repro.core.metrics import capacity_scaled_entropy
from repro.core.policies import (PolicySpec, cr1_spec, cr2_spec,
                                 cr2_reference_losses, cr3_fiscal_balance,
                                 cr3_workload_spec)
from repro.core.solver import evaluate, solve_cr3, solve_slsqp


def _eval_closed(problem, D, name):
    spec = PolicySpec(name=name, problem=problem,
                      objective=lambda D_: problem.total_penalty(D_),
                      use_preservation=False)
    return evaluate(spec, D, solver="closed", nit=0)


@pytest.fixture(scope="module")
def cr1_result(dr_problem):
    return solve_slsqp(cr1_spec(dr_problem, 1.4), maxiter=250)


def test_cr1_respects_constraints(dr_problem, cr1_result):
    r = cr1_result
    assert r.violations["capacity"] == pytest.approx(0.0, abs=1e-6)
    assert r.violations["box"] <= 1e-6
    assert r.violations["preservation"] <= 1e-3
    assert r.carbon_reduction_pct > 0


@pytest.mark.slow
def test_cr1_lambda_sweeps_tradeoff(dr_problem, cr1_result):
    aggressive = cr1_result
    conservative = solve_slsqp(cr1_spec(dr_problem, 2.6), maxiter=200)
    assert aggressive.carbon_reduction_pct > conservative.carbon_reduction_pct
    assert aggressive.total_penalty_pct >= conservative.total_penalty_pct


def test_cr1_more_efficient_than_b1(dr_problem, cr1_result):
    """The paper's headline: CR1 beats proportional capping at equal
    penalty (1.5–2x the carbon per unit performance loss)."""
    # Find a B1 F with a similar penalty level.
    target_pen = cr1_result.total_penalty_pct
    best = None
    for F in np.linspace(0.55, 0.95, 41):
        D = b1_adjustments(dr_problem, F)
        r = _eval_closed(dr_problem, D, f"B1({F:.2f})")
        if best is None or abs(r.total_penalty_pct - target_pen) < \
                abs(best.total_penalty_pct - target_pen):
            best = r
    # At matched penalty, CR1 eliminates strictly more carbon.
    assert cr1_result.carbon_reduction / max(cr1_result.total_penalty, 1e-9) \
        > best.carbon_reduction / max(best.total_penalty, 1e-9)


@pytest.mark.slow
def test_cr2_matches_reference_losses(dr_problem):
    cap = 0.78
    r = solve_slsqp(cr2_spec(dr_problem, cap), maxiter=250)
    refs = cr2_reference_losses(dr_problem, cap)
    # Equality constraint held (scaled residual reported by evaluate).
    assert r.violations["eq0"] <= 0.05
    assert r.carbon_reduction_pct > 0
    # Fairness: per-workload penalties track the cap references. 8% of
    # the largest reference: SLSQP converges (nit < maxiter, eq0 ~ 1e-7
    # scaled) to an optimum whose smallest-penalty workload sits 5-7%
    # off the closed-form reference depending on the cached EDD fleet
    # calibration, so a 5% band is flaky at the margin.
    assert np.allclose(r.per_penalty, refs,
                       atol=0.08 * max(refs.max(), 1.0))


@pytest.mark.slow
def test_cr2_fairer_than_cr1(dr_problem, cr1_result):
    r2 = solve_slsqp(cr2_spec(dr_problem, 0.78), maxiter=250)
    e1 = capacity_scaled_entropy(cr1_result.per_penalty,
                                 dr_problem.entitlements)
    e2 = capacity_scaled_entropy(r2.per_penalty, dr_problem.entitlements)
    assert e2 > e1


@pytest.mark.slow
def test_cr3_fiscal_balance(dr_problem):
    r, rho = solve_cr3(dr_problem, rho=0.02)
    paid, collected = cr3_fiscal_balance(dr_problem, r.D, rho)
    assert paid <= collected + 1e-6           # Eq. 6
    assert r.total_penalty >= 0


def test_cr3_equal_taxes(dr_problem):
    """Eq. 7: the tax rate is uniform by construction; rebates differ."""
    taxes = 0.2 * dr_problem.entitlements
    rates = taxes / dr_problem.entitlements
    assert np.allclose(rates, rates[0])


def test_b1_proportional_and_fair(dr_problem):
    D = b1_adjustments(dr_problem, 0.7)
    r = _eval_closed(dr_problem, D, "B1")
    ent = capacity_scaled_entropy(r.per_penalty, dr_problem.entitlements)
    assert ent > 1.85                          # near-uniform (max = 2)
    # Eq. 9: only usage above the cap is cut.
    L = 0.7 * dr_problem.entitlements[:, None]
    assert np.allclose(r.D, np.maximum(dr_problem.usage - L, 0.0))


def test_b2_caps_only_realtime(dr_problem):
    r = solve_slsqp(b2_spec(dr_problem, 1.2), maxiter=150)
    batch = dr_problem.batch_mask
    # capping-only + preservation freezes batch rows (§VI-D).
    assert np.abs(r.D[batch]).max() <= 1e-4
    assert (r.D >= -1e-9).all()


def test_b3_priority_order(dr_problem):
    D = b3_adjustments(dr_problem, depth=0.25, max_cut=0.2,
                       priority=["RTS1", "RTS2"])
    i_rts1 = dr_problem.names.index("RTS1")
    i_rts2 = dr_problem.names.index("RTS2")
    # Lowest priority (RTS2) is cut to its max (20%) before RTS1 is touched.
    assert np.abs(D[i_rts2]).sum() > 0
    cut_frac_rts1 = D[i_rts1].max() / dr_problem.entitlements[i_rts1]
    assert cut_frac_rts1 <= 0.051              # only the 5% remainder
    # Batch never curtailed by B3.
    assert np.abs(D[dr_problem.batch_mask]).max() == 0.0


@pytest.mark.slow
def test_b4_protects_realtime(dr_problem):
    r = solve_slsqp(b4_spec(dr_problem, 0.05), maxiter=150)
    rts = ~dr_problem.batch_mask
    assert np.abs(r.D[rts]).max() <= 1e-6
    # SLO guard: pipeline penalty stays negligible.
    i_dp = dr_problem.names.index("DataPipeline")
    assert r.per_penalty[i_dp] <= 0.02 * dr_problem.entitlements[i_dp]
