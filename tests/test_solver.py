"""Solver tests: SLSQP vs Adam-AL agreement, projections, metrics."""
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis_compat import given, hnp, settings, st

from repro.core.policies import cr1_spec
from repro.core.solver import AdamALConfig, solve_adam, solve_slsqp


@pytest.mark.slow
def test_solvers_agree_on_cr1(dr_problem):
    """The fleet-scale Adam-AL solver must track the paper's SLSQP within a
    few percent of objective value (it's the same problem)."""
    spec = cr1_spec(dr_problem, 1.2)
    r1 = solve_slsqp(spec, maxiter=250)
    r2 = solve_adam(spec)
    assert r2.objective <= r1.objective * 0.9 + 0.5  # no worse than ~SLSQP
    assert abs(r1.carbon_reduction_pct - r2.carbon_reduction_pct) < 3.0


def test_adam_respects_all_constraints(dr_problem):
    r = solve_adam(cr1_spec(dr_problem, 1.2))
    assert r.violations["capacity"] <= 1e-4
    assert r.violations["box"] <= 1e-5
    assert r.violations["preservation"] <= 0.05


def test_projection_preservation(dr_problem):
    rng = np.random.default_rng(0)
    D = jnp.asarray(rng.normal(size=(dr_problem.W, dr_problem.T)))
    P = dr_problem.project_preservation(D)
    res = np.asarray(dr_problem.preservation_residual(P))
    assert np.abs(res).max() < 1e-4
    # Realtime rows untouched.
    rts = ~dr_problem.batch_mask
    assert np.allclose(np.asarray(P)[rts], np.asarray(D)[rts])


@given(hnp.arrays(np.float64, (2, 48),
                  elements=st.floats(-5, 5, allow_nan=False)))
@settings(max_examples=20, deadline=None)
def test_day_sums_zero_after_projection(dr_problem, D_extra):
    rng = np.random.default_rng(1)
    D = rng.normal(size=(dr_problem.W, dr_problem.T))
    D[:2] = D_extra
    P = np.asarray(dr_problem.project_preservation(jnp.asarray(D)))
    sums = P[:, :24].sum(axis=1), P[:, 24:48].sum(axis=1)
    for s in sums:
        assert np.abs(s[dr_problem.batch_mask]).max() < 1e-6


def test_reported_percentages_consistent(dr_problem):
    r = solve_slsqp(cr1_spec(dr_problem, 1.4), maxiter=150)
    assert r.carbon_reduction_pct == pytest.approx(
        100 * r.carbon_reduction / dr_problem.total_carbon_baseline)
    assert r.total_penalty == pytest.approx(float(r.per_penalty.sum()),
                                            rel=1e-5)
