"""Shared-engine tests: analytic optima, fleet CR1/CR2/CR3 vs the SLSQP
reference stack, penalty gradients, and fleet-scale CR3."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.api import CR1, CR2, CR3, SolveContext, solve, sweep
from repro.core.engine import EngineConfig, al_minimize, al_minimize_batched
from repro.core.fleet_solver import (FleetProblem, fleet_penalties,
                                     synthetic_fleet)


@pytest.fixture(scope="module")
def fp4(dr_problem):
    return FleetProblem.from_problem(dr_problem)


# ---------------------------------------------------------------------------
# Engine core on analytic problems
# ---------------------------------------------------------------------------
def test_engine_eq_constrained_qp():
    """min ||x − c||² s.t. Σx = 1 has closed form x = c + (1 − Σc)/n."""
    c = jnp.asarray([2.0, -1.0, 0.5, 0.5])

    def obj(x, _):
        return ((x - c) ** 2).sum()

    def eq(x, _):
        return jnp.atleast_1d(x.sum() - 1.0)

    x, aux = al_minimize(obj, lambda x: x, jnp.zeros(4), eq_residual=eq,
                         cfg=EngineConfig(inner_steps=300, outer_steps=6,
                                          lr=0.05, mu0=1.0))
    expect = np.asarray(c) + (1.0 - float(c.sum())) / 4.0
    np.testing.assert_allclose(np.asarray(x), expect, atol=1e-2)
    # The converged multiplier is the KKT multiplier 2(Σc − 1)/n · n = ...
    assert np.isfinite(float(aux["lam_eq"][0]))


def test_engine_ineq_constrained():
    """min ||x + 1||² s.t. x ≥ 0 → x* = 0 (constraint active)."""
    def obj(x, _):
        return ((x + 1.0) ** 2).sum()

    def g(x, _):
        return x

    x, _ = al_minimize(obj, lambda x: x, jnp.full((3,), 2.0),
                       ineq_residual=g,
                       cfg=EngineConfig(inner_steps=300, outer_steps=6,
                                        lr=0.05, mu0=1.0))
    np.testing.assert_allclose(np.asarray(x), np.zeros(3), atol=2e-2)


def test_engine_moment_dtype_f32_is_default_path():
    """moment_dtype='float32' must be byte-for-byte the legacy engine (the
    up/down casts are no-ops)."""
    def obj(x, _):
        return ((x - 0.3) ** 2).sum()

    def run(cfg):
        return al_minimize(obj, lambda x: x, jnp.zeros(5), cfg=cfg)[0]

    a = run(EngineConfig(inner_steps=80, outer_steps=2))
    b = run(EngineConfig(inner_steps=80, outer_steps=2,
                         moment_dtype="float32"))
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_engine_moment_dtype_bf16_tracks_f32():
    """bf16 Adam moments with the f32 master copy of x land near the f32
    optimum (moments only steer step sizes; precision loss is benign)."""
    c = jnp.asarray([2.0, -1.0, 0.5, 0.5])

    def obj(x, _):
        return ((x - c) ** 2).sum()

    def eq(x, _):
        return jnp.atleast_1d(x.sum() - 1.0)

    def run(mdt):
        cfg = EngineConfig(inner_steps=300, outer_steps=6, lr=0.05,
                           mu0=1.0, moment_dtype=mdt)
        return al_minimize(obj, lambda x: x, jnp.zeros(4), eq_residual=eq,
                           cfg=cfg)[0]

    x32, xbf = run("float32"), run("bfloat16")
    assert xbf.dtype == jnp.float32          # master copy stays f32
    np.testing.assert_allclose(np.asarray(xbf), np.asarray(x32), atol=5e-2)
    expect = np.asarray(c) + (1.0 - float(c.sum())) / 4.0
    np.testing.assert_allclose(np.asarray(xbf), expect, atol=5e-2)


def test_engine_moment_dtype_x64_reference_lane():
    """Parity lane for the mixed-precision knob: under x64, float64 vs
    float32 moments agree tightly on a fleet CR1 solve — the moment
    precision isn't load-bearing at these step counts. Subprocess so x64
    never leaks into this process's jit caches."""
    from conftest import run_in_subprocess
    run_in_subprocess("""
import jax
jax.config.update("jax_enable_x64", True)
import numpy as np
from repro.core.api import CR1, SolveContext, solve
from repro.core.fleet_solver import synthetic_fleet

p = synthetic_fleet(6, hours=48, seed=0)
res = {m: solve(p, CR1(lam=1.45),
                ctx=SolveContext(steps=200, moment_dtype=m))
       for m in ("float64", "float32", "bfloat16")}
r64 = res["float64"].carbon_reduction_pct
assert abs(res["float32"].carbon_reduction_pct - r64) < 1e-3, res
assert abs(res["bfloat16"].carbon_reduction_pct - r64) < 0.05, res
print("ok")
""", devices=1)


def test_engine_batched_sweep_matches_unbatched():
    """vmapped hyper sweep = per-hyper solves (the compile-once Pareto path)."""
    def obj(x, h):
        return ((x - h) ** 2).sum()

    def project(x):
        return jnp.clip(x, 0.0, 1.0)

    cfg = EngineConfig(inner_steps=200, outer_steps=1, lr=0.05)
    hypers = jnp.asarray([0.2, 0.5, 2.0])
    xs = al_minimize_batched(obj, project, jnp.zeros(2), hypers, cfg=cfg)
    assert xs.shape == (3, 2)
    np.testing.assert_allclose(np.asarray(xs[:, 0]), [0.2, 0.5, 1.0],
                               atol=1e-2)
    for h, x in zip(hypers, xs):
        one, _ = al_minimize(obj, project, jnp.zeros(2), hyper=h, cfg=cfg)
        np.testing.assert_allclose(np.asarray(x), np.asarray(one), atol=1e-5)


def test_engine_batched_returns_stacked_aux_and_warm_starts():
    """A sweep must surface its solver state (stacked EngineState) so the
    next tick's sweep can warm-start lane-by-lane — and re-entering that
    state with a tiny budget must stay at each lane's optimum."""
    from repro.core.engine import EngineState

    def obj(x, h):
        return ((x - h) ** 2).sum()

    def project(x):
        return jnp.clip(x, 0.0, 1.0)

    hypers = jnp.asarray([0.2, 0.5, 2.0])
    cfg = EngineConfig(inner_steps=200, outer_steps=1, lr=0.05)
    xs, aux = al_minimize_batched(obj, project, jnp.zeros(2), hypers,
                                  cfg=cfg, return_aux=True)
    state = aux["state"]
    assert isinstance(state, EngineState)
    assert state.x.shape == (3, 2)        # leading sweep axis on every leaf
    assert state.mu.shape == (3,)
    np.testing.assert_allclose(np.asarray(xs), np.asarray(state.x))

    # a short warm budget stays at each lane's optimum (fresh Adam moments
    # wiggle the first steps, so compare to the optima, not bitwise to xs)
    warm_xs = al_minimize_batched(
        obj, project, jnp.zeros(2), hypers, init=state,
        cfg=EngineConfig(inner_steps=50, outer_steps=1, lr=0.05))
    np.testing.assert_allclose(np.asarray(warm_xs[:, 0]), [0.2, 0.5, 1.0],
                               atol=2e-2)

    # positional return unchanged for existing callers
    xs_only = al_minimize_batched(obj, project, jnp.zeros(2), hypers,
                                  cfg=cfg)
    np.testing.assert_allclose(np.asarray(xs_only), np.asarray(xs),
                               atol=1e-6)


# ---------------------------------------------------------------------------
# fleet_penalties is the single penalty path — its gradients must be exact
# ---------------------------------------------------------------------------
def test_fleet_penalties_grad_matches_finite_differences(fp4, rng):
    from jax.experimental import enable_x64
    with enable_x64(True):
        D0 = jnp.asarray(rng.uniform(-0.5, 0.5, size=(fp4.W, fp4.T)))

        def f(D):
            return fleet_penalties(fp4, D).sum()

        g = jax.grad(f)(D0)
        eps = 1e-5
        for _ in range(12):
            i, t = int(rng.integers(fp4.W)), int(rng.integers(fp4.T))
            e = np.zeros((fp4.W, fp4.T))
            e[i, t] = eps
            fd = (f(D0 + jnp.asarray(e)) - f(D0 - jnp.asarray(e))) / (2 * eps)
            assert abs(float(fd) - float(g[i, t])) < 1e-6


def test_fleet_penalties_kernel_path_grad_matches_jnp(fp4, rng):
    """The Pallas feature kernel's custom VJP must agree with the jnp path
    (the engine differentiates through it on TPU)."""
    D0 = jnp.asarray(rng.uniform(-0.5, 0.5, size=(fp4.W, fp4.T)))
    g_jnp = jax.grad(lambda D: fleet_penalties(fp4, D, use_kernel=False)
                     .sum())(D0)
    g_ker = jax.grad(lambda D: fleet_penalties(fp4, D, use_kernel=True)
                     .sum())(D0)
    np.testing.assert_allclose(np.asarray(g_ker), np.asarray(g_jnp),
                               rtol=1e-5, atol=1e-6)


# ---------------------------------------------------------------------------
# DRProblem <-> FleetProblem round trip
# ---------------------------------------------------------------------------
def test_problem_round_trip(dr_problem, fp4, rng):
    p2 = fp4.to_problem()
    assert p2.names == dr_problem.names
    assert (p2.batch_mask == dr_problem.batch_mask).all()
    D = jnp.asarray(rng.uniform(-1, 1, size=(fp4.W, fp4.T)))
    np.testing.assert_allclose(
        np.asarray(p2.penalties(D, smooth=0.0)),
        np.asarray(fleet_penalties(fp4, D)), rtol=1e-5, atol=1e-5)
    fp2 = FleetProblem.from_problem(p2)
    np.testing.assert_allclose(fp2.usage, fp4.usage)
    np.testing.assert_allclose(fp2.betas, fp4.betas)
    np.testing.assert_allclose(fp2.k, fp4.k, rtol=1e-12)


# ---------------------------------------------------------------------------
# Policy adapters vs the SLSQP validation reference (4-workload paper fleet)
# ---------------------------------------------------------------------------
@pytest.mark.slow
def test_cr1_fleet_matches_slsqp_per_workload(dr_problem, fp4):
    from repro.core.policies import cr1_spec
    from repro.core.solver import solve_slsqp
    ref = solve_slsqp(cr1_spec(dr_problem, 1.4), maxiter=250)
    got = solve(fp4, CR1(lam=1.4))
    pens = np.asarray(fleet_penalties(fp4, jnp.asarray(got.D)))
    assert abs(got.carbon_reduction_pct - ref.carbon_reduction_pct) < 1.5
    assert abs(got.total_penalty_pct - ref.total_penalty_pct) < 1.5
    # Per-workload penalties agree within 3% of each entitlement.
    np.testing.assert_array_less(
        np.abs(pens - ref.per_penalty) / np.asarray(fp4.entitlement), 0.03)


@pytest.mark.slow
def test_cr2_fleet_matches_slsqp_per_workload(dr_problem, fp4):
    """RTS rows match the SLSQP stack's penalties; batch rows land at or
    below them (the preservation projection bounds attainable deferral
    penalties — fairer than required, never unfairer)."""
    from repro.core.policies import cr2_spec
    from repro.core.solver import solve_slsqp
    ref = solve_slsqp(cr2_spec(dr_problem, 0.78), maxiter=250)
    got = solve(fp4, CR2(cap_frac=0.78))
    pens = np.asarray(fleet_penalties(fp4, jnp.asarray(got.D)))
    assert abs(got.carbon_reduction_pct - ref.carbon_reduction_pct) < 1.5
    assert abs(got.total_penalty_pct - ref.total_penalty_pct) < 1.5
    E = np.asarray(fp4.entitlement)
    rts = ~np.asarray(fp4.is_batch)
    np.testing.assert_array_less(
        np.abs(pens - ref.per_penalty)[rts] / E[rts], 0.01)
    assert (pens[~rts] <= ref.per_penalty[~rts] + 0.05).all()


@pytest.mark.slow
def test_cr3_fleet_matches_slsqp_reference(dr_problem, fp4):
    """Acceptance: decentralized fleet CR3 within 2% of the paper-stack
    CR3 on carbon reduction and total penalty, and fiscally balanced."""
    from repro.core.policies import cr3_fiscal_balance
    from repro.core.solver import solve_cr3
    ref, rho_ref = solve_cr3(dr_problem, rho=0.02)
    got = solve(fp4, CR3(rho=0.02))
    rho_got = got.extras["rho"]
    assert abs(got.carbon_reduction_pct - ref.carbon_reduction_pct) < 2.0
    assert abs(got.total_penalty_pct - ref.total_penalty_pct) < 2.0
    paid, collected = cr3_fiscal_balance(dr_problem, got.D, rho_got)
    assert paid <= collected + 1e-6              # Eq. 6
    assert got.preservation_violation < 1e-3


def test_cr1_sweep_matches_single_solves(fp4):
    lams = [1.2, 1.6]
    ctx = SolveContext(steps=300)
    got = sweep(fp4, [CR1(lam=lam) for lam in lams], ctx=ctx)
    for lam, r in zip(lams, got):
        one = solve(fp4, CR1(lam=lam), ctx=ctx)
        assert abs(r.carbon_reduction_pct - one.carbon_reduction_pct) < 1e-4
        assert abs(r.total_penalty_pct - one.total_penalty_pct) < 1e-4


@pytest.mark.slow
def test_cr3_fleet_scales_to_512_workloads():
    p = synthetic_fleet(512)
    r = solve(p, CR3(outer=2, clearing_iters=2),
              ctx=SolveContext(steps=150))
    assert r.D.shape == (512, 48)
    assert np.isfinite(r.carbon_reduction_pct)
    assert r.preservation_violation < 1e-3
    assert r.extras["rho"] > 0
    # box respected
    hi = np.minimum(0.5 * p.entitlement[:, None], p.usage)
    assert (r.D <= hi + 1e-4).all()
    assert (r.D[~p.is_batch] >= -1e-5).all()     # RTS curtail-only
