"""Rolling-horizon streaming DR: forecast streams, engine warm starts,
warm-vs-cold re-solve quality, and the online control loop."""
import dataclasses

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.api import CR1, CR2, SolveContext, solve
from repro.core.carbon import ForecastStream, caiso_2021
from repro.core.engine import EngineConfig, EngineState, al_minimize
from repro.core.fleet_solver import synthetic_fleet, synthetic_regional_fleet
from repro.core.streaming import RollingHorizonSolver


# ---------------------------------------------------------------------------
# ForecastStream
# ---------------------------------------------------------------------------
def test_forecast_stream_shapes_and_determinism():
    s = ForecastStream.caiso(n_ticks=6, horizon=48, seed=3)
    assert s.n_ticks >= 6
    f0 = s.forecast(2)
    assert f0.shape == (48,)
    assert (f0 >= 0).all()
    np.testing.assert_array_equal(f0, s.forecast(2))   # re-issue == same
    assert not np.array_equal(f0, s.forecast(3))       # revisions differ


def test_forecast_error_grows_with_lead_time():
    s = ForecastStream.caiso(n_ticks=40, horizon=48, seed=0,
                             revision_sigma=0.05)
    near, far = [], []
    for t in range(40):
        f = s.forecast(t)
        actual = s.actual[t:t + 48]
        rel = np.abs(f / np.maximum(actual, 1e-9) - 1.0)
        near.append(rel[0])
        far.append(rel[-1])
    # committed-hour (nowcast) error is small; day-ahead tail error larger
    assert np.mean(near) < 0.05
    assert np.mean(far) > 2.0 * np.mean(near)


def test_forecast_stream_replay_mode():
    snaps = np.arange(3 * 8, dtype=float).reshape(3, 8)
    s = ForecastStream(actual=np.ones(16), horizon=8, replay=snaps)
    assert s.n_ticks == 3
    np.testing.assert_array_equal(s.forecast(1), snaps[1])
    with pytest.raises(IndexError):
        s.forecast(3)
    with pytest.raises(ValueError):
        ForecastStream(actual=np.ones(16), horizon=8,
                       replay=np.ones((3, 7)))


def test_forecast_stream_replay_clamped_to_realized_hours():
    """Regression: more replay snapshots than realized hours must clamp
    `n_ticks` — previously `forecast()` succeeded on ticks whose
    `realized()` hour did not exist, crashing mid-run with IndexError."""
    snaps = np.ones((5, 8))
    s = ForecastStream(actual=np.ones(3), horizon=8, replay=snaps)
    assert s.n_ticks == 3                       # min(replay rows, actual)
    assert s.forecast(2).shape == (8,)
    assert s.realized(2) == 1.0
    with pytest.raises(IndexError):
        s.forecast(3)                           # beyond the realized range
    with pytest.raises(IndexError):
        s.realized(3)
    # a full run over n_ticks never touches a missing realized hour
    for t in range(s.n_ticks):
        s.forecast(t)
        s.realized(t)


def test_forecast_stream_realized_is_actual():
    sig = caiso_2021(60)
    s = ForecastStream(actual=sig.mci, horizon=48)
    assert s.realized(5) == float(sig.mci[5])


def test_forecast_stream_replay_tick_boundary():
    """The last valid tick is n_ticks - 1 exactly; n_ticks itself must
    raise for both forecast() and realized() in replay mode."""
    snaps = np.arange(4 * 6, dtype=float).reshape(4, 6)
    s = ForecastStream(actual=np.ones(4), horizon=6, replay=snaps)
    assert s.n_ticks == 4
    np.testing.assert_array_equal(s.forecast(s.n_ticks - 1), snaps[3])
    assert s.realized(s.n_ticks - 1) == 1.0
    with pytest.raises(IndexError):
        s.forecast(s.n_ticks)
    with pytest.raises(IndexError):
        s.forecast(-1)


def test_forecast_stream_horizon_longer_than_actual():
    """Revision mode with horizon > len(actual) supports zero ticks (no
    full horizon exists) and says so via IndexError, not a crash deep in
    the revision model."""
    s = ForecastStream(actual=np.ones(10), horizon=48)
    assert s.n_ticks == 0
    with pytest.raises(IndexError, match=r"\[0, 0\)"):
        s.forecast(0)
    # replay mode: snapshots may cover a longer horizon than the realized
    # series; ticks clamp to the realized hours
    s2 = ForecastStream(actual=np.ones(2), horizon=48,
                        replay=np.ones((5, 48)))
    assert s2.n_ticks == 2
    assert s2.forecast(1).shape == (48,)
    with pytest.raises(IndexError):
        s2.forecast(2)


# ---------------------------------------------------------------------------
# Engine warm starts
# ---------------------------------------------------------------------------
def test_engine_state_shifted_rolls_time_axis():
    st = EngineState(x=jnp.asarray([[1.0, 2.0, 3.0], [4.0, 5.0, 6.0]]),
                     lam_eq=jnp.asarray([7.0]), lam_in=jnp.zeros(0),
                     mu=jnp.asarray(0.5))
    sh = st.shifted(1)
    np.testing.assert_allclose(np.asarray(sh.x),
                               [[2.0, 3.0, 0.0], [5.0, 6.0, 0.0]])
    np.testing.assert_allclose(np.asarray(sh.lam_eq), [7.0])   # carried
    assert float(sh.mu) == 0.5


def test_engine_warm_start_preserves_optimum():
    """A converged state re-entered with a tiny budget stays converged."""
    c = jnp.asarray([2.0, -1.0, 0.5, 0.5])

    def obj(x, _):
        return ((x - c) ** 2).sum()

    def eq(x, _):
        return jnp.atleast_1d(x.sum() - 1.0)

    _, aux = al_minimize(obj, lambda x: x, jnp.zeros(4), eq_residual=eq,
                         cfg=EngineConfig(inner_steps=300, outer_steps=6,
                                          lr=0.05, mu0=1.0))
    x2, aux2 = al_minimize(obj, lambda x: x, jnp.zeros(4), eq_residual=eq,
                           init=aux["state"],
                           cfg=EngineConfig(inner_steps=25, outer_steps=1,
                                            lr=0.05, mu0=1.0))
    expect = np.asarray(c) + (1.0 - float(c.sum())) / 4.0
    np.testing.assert_allclose(np.asarray(x2), expect, atol=1e-2)
    assert isinstance(aux2["state"], EngineState)


def test_engine_cold_state_equals_default_path():
    """init=EngineState.cold(...) is byte-for-byte the legacy cold solve."""
    def obj(x, _):
        return ((x - 0.3) ** 2).sum()

    cfg = EngineConfig(inner_steps=50, outer_steps=2, mu0=2.0)

    def g(x, _):
        return x

    x_a, _ = al_minimize(obj, lambda x: x, jnp.zeros(3), ineq_residual=g,
                         cfg=cfg)
    x_b, _ = al_minimize(obj, lambda x: x, jnp.zeros(3), ineq_residual=g,
                         init=EngineState.cold(jnp.zeros(3), n_in=3,
                                               mu0=2.0), cfg=cfg)
    np.testing.assert_array_equal(np.asarray(x_a), np.asarray(x_b))


# ---------------------------------------------------------------------------
# Warm-started fleet re-solves on a shifted horizon
# ---------------------------------------------------------------------------
@pytest.mark.slow
def test_warm_resolve_matches_cold_on_shifted_horizon():
    """Shift the window one hour, warm-start at 1/4 the budget: the re-solve
    must reach the cold solve's CR1 objective (pp units) to 0.1 pp."""
    lam = 1.45
    p = synthetic_fleet(8)
    prev = solve(p, CR1(lam=lam), ctx=SolveContext(steps=600))
    shifted = dataclasses.replace(
        p, mci=np.roll(p.mci, -1), usage=np.roll(p.usage, -1, axis=1),
        jobs=np.roll(p.jobs, -1, axis=1))
    warm = solve(shifted, CR1(lam=lam),
                 ctx=SolveContext(steps=150, warm=prev.state.shifted(1)))
    cold = solve(shifted, CR1(lam=lam), ctx=SolveContext(steps=600))

    def obj(r):
        return lam * r.total_penalty_pct - r.carbon_reduction_pct

    assert obj(warm) <= obj(cold) + 0.1
    assert warm.preservation_violation < 1e-3


# ---------------------------------------------------------------------------
# RollingHorizonSolver control loop
# ---------------------------------------------------------------------------
@pytest.mark.slow
def test_rolling_horizon_cr1_commits_and_accounts():
    p = synthetic_fleet(6)
    stream = ForecastStream.caiso(n_ticks=4, horizon=p.T, seed=1)
    rhs = RollingHorizonSolver(p, stream, policy="cr1",
                               cold_steps=300, warm_steps=80)
    report = rhs.run(4)
    assert report.committed.shape == (6, 4)
    # tick 0 cold, then warm budgets
    assert [t.inner_steps for t in report.ticks] == [300, 80, 80, 80]
    assert report.total_inner_steps == 300 + 3 * 80
    # committed hours respect the fleet box of their window
    for tk in report.ticks:
        u_t = np.roll(p.usage, -tk.tick, axis=1)[:, 0]
        hi = np.minimum(0.5 * p.entitlement, u_t)
        assert (tk.committed <= hi + 1e-4).all()
        assert (tk.committed[~p.is_batch] >= -1e-5).all()
    # ledger identities
    assert report.realized_carbon == pytest.approx(
        sum(t.committed.sum() * t.realized_mci for t in report.ticks))
    assert 0 < report.realized_reduction_pct < 100
    assert np.isfinite(report.forecast_error_pct)


def test_rolling_horizon_validates_inputs():
    p = synthetic_fleet(2)
    stream = ForecastStream.caiso(n_ticks=2, horizon=24)
    with pytest.raises(ValueError):
        RollingHorizonSolver(p, stream)          # horizon mismatch
    stream48 = ForecastStream.caiso(n_ticks=2, horizon=p.T)
    # unknown policy names fail at construction, naming the registry's
    # choices — not as an opaque failure at the first step()
    with pytest.raises(ValueError,
                       match="registered policies.*cr1.*cr2.*cr3"):
        RollingHorizonSolver(p, stream48, policy="cr9")
    with pytest.raises(TypeError, match="DRPolicy"):
        RollingHorizonSolver(p, stream48, policy=1.45)
    rhs = RollingHorizonSolver(p, stream48, cold_steps=50, warm_steps=20)
    with pytest.raises(RuntimeError):
        rhs.report()                             # nothing committed yet


def test_rolling_horizon_accepts_policy_objects():
    """A DRPolicy object IS the configuration: string names resolve to the
    equivalent object via the registry + legacy knobs."""
    p = synthetic_fleet(2)
    stream = ForecastStream.caiso(n_ticks=2, horizon=p.T)
    by_name = RollingHorizonSolver(p, stream, policy="cr2", cap_frac=0.8,
                                   outer=2)
    assert by_name.policy == CR2(cap_frac=0.8, outer=2)
    by_obj = RollingHorizonSolver(p, stream, policy=CR2(cap_frac=0.8,
                                                        outer=2))
    assert by_obj.policy == by_name.policy


def test_adaptive_warm_budget_scales_with_revision_magnitude():
    """ROADMAP adaptive-warm-budgets item: a quiet stream (tiny forecast
    revisions) must spend fewer inner steps per warm tick than the fixed
    budget, at an objective gap < 0.01 pp; a violently revised stream
    keeps the full warm budget."""
    lam = 1.45
    p = synthetic_fleet(6, seed=0)

    def run(adaptive, sigma):
        stream = ForecastStream.caiso(n_ticks=4, horizon=p.T, seed=3,
                                      revision_sigma=sigma)
        rhs = RollingHorizonSolver(p, stream, policy=CR1(lam=lam),
                                   cold_steps=300, warm_steps=120,
                                   adaptive_warm=adaptive)
        objs = {}
        rep = rhs.run(4, on_tick=lambda tk: objs.__setitem__(
            tk.tick, lam * tk.plan.total_penalty_pct
            - tk.plan.carbon_reduction_pct))
        return rep, objs

    fixed, objs_f = run(False, 0.002)
    adapt, objs_a = run(True, 0.002)
    # quiet ticks: every warm budget strictly below the fixed 120
    assert [t.inner_steps for t in fixed.ticks] == [300, 120, 120, 120]
    warm_a = [t.inner_steps for t in adapt.ticks][1:]
    assert all(30 <= s < 120 for s in warm_a), warm_a
    assert adapt.total_inner_steps < fixed.total_inner_steps
    # ...at a per-tick objective gap below 0.01 pp
    gaps = [abs(objs_a[k] - objs_f[k]) for k in objs_f]
    assert max(gaps) < 0.01, gaps
    # violent revisions keep the full budget
    noisy, _ = run(True, 0.5)
    assert [t.inner_steps for t in noisy.ticks][1:] == [120, 120, 120]


def test_adaptive_warm_budget_validates_and_defaults():
    p = synthetic_fleet(2, seed=0)
    stream = ForecastStream.caiso(n_ticks=2, horizon=p.T)
    rhs = RollingHorizonSolver(p, stream, warm_steps=100,
                               adaptive_warm=True)
    assert rhs.warm_steps_min == 25          # warm_steps // 4
    with pytest.raises(ValueError, match="revision_ref"):
        RollingHorizonSolver(p, stream, adaptive_warm=True,
                             revision_ref=0.0)
    # a floor above the warm budget would invert the adaptive scaling
    with pytest.raises(ValueError, match="warm_steps_min"):
        RollingHorizonSolver(p, stream, warm_steps=100,
                             adaptive_warm=True, warm_steps_min=200)
    with pytest.raises(ValueError, match="warm_steps_min"):
        RollingHorizonSolver(p, stream, warm_steps_min=0)


# ---------------------------------------------------------------------------
# Whole-day scan: run_scanned() / api.solve_day — one dispatch per day
# ---------------------------------------------------------------------------
@pytest.mark.slow
@pytest.mark.parametrize("policy,use_kernel", [
    ("cr1", False), ("cr1", True), ("cr2", False), ("cr2", True)])
def test_run_scanned_matches_per_tick_loop(policy, use_kernel):
    """Acceptance: the scanned day reproduces the per-tick step() loop to
    <0.01 pp realized carbon, with identical tick accounting — on both
    the generic engine and the fused al_step kernel."""
    p = synthetic_fleet(8, seed=0)

    def mk():
        return ForecastStream.caiso(n_ticks=4, horizon=p.T, seed=3)

    kw = dict(policy=policy, outer=2, cold_steps=160, warm_steps=40,
              use_kernel=use_kernel)
    loop = RollingHorizonSolver(p, mk(), **kw).run(4)
    scan = RollingHorizonSolver(p, mk(), **kw).run_scanned(4)
    assert abs(scan.realized_reduction_pct
               - loop.realized_reduction_pct) < 0.01
    assert scan.committed.shape == loop.committed.shape == (8, 4)
    np.testing.assert_allclose(scan.committed, loop.committed, atol=5e-3)
    assert [t.inner_steps for t in scan.ticks] \
        == [t.inner_steps for t in loop.ticks]
    assert [t.forecast_mci for t in scan.ticks] \
        == [t.forecast_mci for t in loop.ticks]
    # plan retention contract: full plan on the latest tick only
    assert scan.ticks[-1].plan is not None
    assert all(t.plan is None for t in scan.ticks[:-1])


def test_run_scanned_is_one_dispatch(monkeypatch):
    """The whole day funnels through ONE jitted day-scan call (the tick
    loop is inside lax.scan, not Python)."""
    import repro.core.api as api
    p = synthetic_fleet(4, seed=0)
    stream = ForecastStream.caiso(n_ticks=4, horizon=p.T, seed=1)
    calls = []
    orig = api._day_cr1
    monkeypatch.setattr(
        api, "_day_cr1",
        lambda *a, **k: (calls.append(1), orig(*a, **k))[1])
    rep = RollingHorizonSolver(p, stream, policy="cr1", cold_steps=60,
                               warm_steps=15).run_scanned(4)
    assert len(calls) == 1
    assert [t.inner_steps for t in rep.ticks] == [60, 15, 15, 15]
    assert rep.total_inner_steps == 60 + 3 * 15


def test_run_scanned_warm_continuation():
    """Mixed schedules work: per-tick steps, then a scanned remainder —
    the scan warm-starts from (and updates) the solver state."""
    p = synthetic_fleet(4, seed=0)
    stream = ForecastStream.caiso(n_ticks=6, horizon=p.T, seed=2)
    rhs = RollingHorizonSolver(p, stream, policy="cr1", cold_steps=80,
                               warm_steps=20)
    rhs.step()
    rhs.step()
    rep = rhs.run_scanned(4)
    assert len(rep.ticks) == 6
    # ticks 2..5 came from the scan, all warm-budgeted
    assert [t.inner_steps for t in rep.ticks] == [80] + [20] * 5
    assert rhs._tick == 6
    # a second scanned day keeps chaining
    st = rep.ticks[-1].plan.state
    assert st is rhs._state


def test_run_scanned_guards():
    p = synthetic_fleet(2, seed=0)

    def mk():
        return ForecastStream.caiso(n_ticks=2, horizon=p.T)

    with pytest.raises(ValueError, match="adaptive_warm"):
        RollingHorizonSolver(p, mk(), adaptive_warm=True).run_scanned(2)
    with pytest.raises(NotImplementedError, match="CR1/CR2"):
        RollingHorizonSolver(p, mk(), policy="cr3", cold_steps=20,
                             warm_steps=5).run_scanned(2)
    with pytest.raises(ValueError, match="n_ticks"):
        RollingHorizonSolver(p, mk()).run_scanned(0)
    # multi-region scanned days run off-mesh too (mesh parity is covered
    # in test_multiregion)
    pr = synthetic_regional_fleet(4, ["CA", "TX"], hours=p.T, seed=0)
    streams = [ForecastStream(actual=np.tile(m, 2), horizon=p.T, seed=i)
               for i, m in enumerate(np.asarray(pr.mci))]
    rep = RollingHorizonSolver(pr, streams, cold_steps=20,
                               warm_steps=5).run_scanned(2)
    assert len(rep.ticks) == 2


def test_solve_day_validates_inputs():
    from repro.core.api import DayResult, solve_day
    p = synthetic_fleet(3, seed=0)
    stack = np.stack([np.asarray(p.mci)] * 2)
    with pytest.raises(TypeError, match="FleetProblem"):
        solve_day(object(), "cr1", stack)
    with pytest.raises(ValueError, match="mci_stack"):
        solve_day(p, "cr1", stack[:, :10])
    # multi-region stacks must match the (R, T) forecast shape
    pr = synthetic_regional_fleet(4, ["CA", "TX"], hours=p.T, seed=0)
    rstack = np.stack([np.asarray(pr.mci)] * 2)
    with pytest.raises(ValueError, match="mci_stack"):
        solve_day(pr, "cr1", rstack[:, :1, :10])
    with pytest.raises(NotImplementedError, match="host-side"):
        solve_day(p, "b1", stack)
    day = solve_day(p, CR1(lam=1.45), stack, cold_steps=40)
    assert isinstance(day, DayResult)
    assert day.committed.shape == (2, 3)
    assert day.inner_steps == (40, 10)
    # warm chaining across days: every tick at the warm budget
    day2 = solve_day(p, CR1(lam=1.45), stack,
                     ctx=SolveContext(warm=day.last.state), cold_steps=40)
    assert day2.inner_steps == (10, 10)
    assert np.isfinite(day2.committed).all()


@pytest.mark.slow
def test_rolling_horizon_multiregion_run_and_scan():
    """Multi-region streaming: one ForecastStream per region, per-region
    committed accounting, and run_scanned parity with the step() loop."""
    p = synthetic_regional_fleet(8, ["CA", "TX"], hours=24, seed=5)

    def mk():
        return [ForecastStream(actual=np.tile(m, 2), horizon=p.T,
                               revision_sigma=0.03, seed=i)
                for i, m in enumerate(np.asarray(p.mci))]

    kw = dict(policy="cr1", cold_steps=120, warm_steps=40)
    loop = RollingHorizonSolver(p, mk(), **kw).run(3)
    tk = loop.ticks[0]
    assert tk.committed_by_region is not None
    assert tk.committed_by_region.shape == (2,)
    assert np.asarray(tk.realized_mci).shape == (2,)
    assert tk.committed_by_region.sum() == pytest.approx(
        tk.committed.sum())
    assert 0 < loop.realized_reduction_pct < 100
    scan = RollingHorizonSolver(p, mk(), **kw).run_scanned(3)
    assert abs(scan.realized_reduction_pct
               - loop.realized_reduction_pct) < 0.01
    np.testing.assert_allclose(scan.committed, loop.committed, atol=5e-3)
    # one stream per region is enforced
    with pytest.raises(ValueError, match="forecast stream"):
        RollingHorizonSolver(p, mk()[:1])


@pytest.mark.slow
def test_rolling_horizon_cr2_carries_multipliers():
    p = synthetic_fleet(4)
    stream = ForecastStream.caiso(n_ticks=3, horizon=p.T, seed=2)
    rhs = RollingHorizonSolver(p, stream, policy="cr2",
                               cold_steps=200, warm_steps=60, outer=2)
    report = rhs.run(3)
    assert report.committed.shape == (4, 3)
    # the CR2 fairness multipliers (one per workload) ride the state
    st = report.ticks[-1].plan.state
    assert st.lam_eq.shape == (4,)
    assert np.isfinite(np.asarray(st.lam_eq)).all()
