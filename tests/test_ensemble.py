"""Ensemble evaluation (`repro.core.ensemble`): batched-vs-loop parity,
the risk-report layer, and the batched rolling-horizon ensemble."""
import numpy as np
import pytest

from repro.core.api import B1, CR1, CR2, CR3, SolveContext, ensemble, solve
from repro.core.ensemble import (comparison_table, compare_policies,
                                 evaluate_ensemble, run_streaming_ensemble)
from repro.core.fleet_solver import synthetic_fleet
from repro.core.scenario import (CambiumMix, DuckPerturb, FleetJitter,
                                 FlexMixShift, ForecastRegime,
                                 RenewableDrought, ScenarioStack,
                                 resolve_scenarios)
from repro.core.streaming import RollingHorizonSolver


@pytest.fixture(scope="module")
def fleet():
    return synthetic_fleet(6, seed=1)


@pytest.fixture(scope="module")
def mixed_stack(fleet):
    """MCI + fleet overlays in one stack (exercises the vmapped problem
    fields jointly)."""
    return resolve_scenarios(
        [DuckPerturb(n_scenarios=2, seed=1),
         FleetJitter(n_scenarios=2, seed=2),
         FlexMixShift(n_scenarios=2, seed=3)], fleet)


# ---------------------------------------------------------------------------
# Batched lane == sequential api.solve loop (the core parity contract)
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("policy", [CR1(lam=1.4),
                                    CR2(cap_frac=0.8, outer=2)])
def test_batched_matches_solve_loop(policy, fleet, mixed_stack):
    ctx = SolveContext(steps=120)
    got = evaluate_ensemble(fleet, policy, mixed_stack, ctx=ctx)
    ref = evaluate_ensemble(fleet, policy, mixed_stack, ctx=ctx,
                            batched=False)
    assert got.batched and not ref.batched
    assert got.D.shape == (mixed_stack.S, fleet.W, fleet.T)
    assert np.abs(got.carbon_reduction_pct
                  - ref.carbon_reduction_pct).max() < 0.01
    assert np.abs(got.total_penalty_pct
                  - ref.total_penalty_pct).max() < 0.01
    np.testing.assert_allclose(got.D, ref.D, atol=1e-3)
    # per-scenario loop results match a direct api.solve of the
    # materialized scenario problem exactly
    s = 2
    direct = solve(mixed_stack.problem(fleet, s), policy, ctx=ctx)
    assert ref.carbon_reduction_pct[s] == direct.carbon_reduction_pct
    np.testing.assert_array_equal(ref.D[s], direct.D)


def test_cr2_jobs_only_overlay_recomputes_references(fleet):
    """Regression: `cr2_reference_fleet` depends on jobs (Table-IV
    features), so a jobs-only overlay must get per-scenario fairness
    targets in the batched lane — sharing the base reference broke the
    <0.01 pp parity contract by ~0.03 pp."""
    jobs = np.stack([0.5 * np.asarray(fleet.jobs),
                     2.0 * np.asarray(fleet.jobs)])
    stack = ScenarioStack(jobs=jobs)
    ctx = SolveContext(steps=100)
    pol = CR2(cap_frac=0.8, outer=2)
    got = evaluate_ensemble(fleet, pol, stack, ctx=ctx)
    ref = evaluate_ensemble(fleet, pol, stack, ctx=ctx, batched=False)
    assert np.abs(got.carbon_reduction_pct
                  - ref.carbon_reduction_pct).max() < 0.01
    assert np.abs(got.total_penalty_pct
                  - ref.total_penalty_pct).max() < 0.01


def test_fallback_policies_loop_with_identical_semantics(fleet):
    stack = DuckPerturb(n_scenarios=2, seed=5).generate(fleet)
    ctx = SolveContext(steps=60)
    for policy in (B1(F=0.8), CR3(outer=1, clearing_iters=1)):
        res = evaluate_ensemble(fleet, policy, stack, ctx=ctx)
        assert not res.batched
        for s in range(stack.S):
            direct = solve(stack.problem(fleet, s), policy, ctx=ctx)
            np.testing.assert_array_equal(res.D[s], direct.D)
            assert res.extras[s] == direct.extras


def test_batched_flag_forces_and_rejects(fleet):
    stack = DuckPerturb(n_scenarios=2, seed=0).generate(fleet)
    with pytest.raises(ValueError, match="no batched ensemble lane"):
        evaluate_ensemble(fleet, B1(), stack, batched=True)
    with pytest.raises(ValueError, match="no batched ensemble lane"):
        evaluate_ensemble(fleet, CR1(), stack, batched=True,
                          ctx=SolveContext(steps=30, shift=1))
    # api.ensemble is the same entry point
    a = ensemble(fleet, CR1(lam=1.3), stack, ctx=SolveContext(steps=60))
    b = evaluate_ensemble(fleet, CR1(lam=1.3), stack,
                          ctx=SolveContext(steps=60))
    np.testing.assert_allclose(a.D, b.D, atol=1e-12)


def test_ensemble_determinism(fleet):
    """Same generator spec + seed -> bitwise-identical ensemble outcomes."""
    ctx = SolveContext(steps=60)
    a = evaluate_ensemble(fleet, CR1(lam=1.45),
                          CambiumMix(n_scenarios=3, seed=9), ctx=ctx)
    b = evaluate_ensemble(fleet, CR1(lam=1.45),
                          CambiumMix(n_scenarios=3, seed=9), ctx=ctx)
    np.testing.assert_array_equal(a.D, b.D)
    np.testing.assert_array_equal(a.carbon_reduction_pct,
                                  b.carbon_reduction_pct)
    assert a.labels == b.labels


# ---------------------------------------------------------------------------
# Risk layer
# ---------------------------------------------------------------------------
def test_report_stats_are_coherent(fleet, mixed_stack):
    res = evaluate_ensemble(fleet, CR1(lam=1.4), mixed_stack,
                            ctx=SolveContext(steps=100))
    rep = res.report(slo_frac=0.05, cvar_alpha=0.25)
    q = rep.carbon_quantiles
    assert q["p5"] <= q["p25"] <= q["p50"] <= q["p75"] <= q["p95"]
    # CVaR of the bad tail bounds the median from the bad side
    assert rep.carbon_cvar <= q["p50"] + 1e-9
    assert rep.penalty_cvar >= rep.penalty_quantiles["p50"] - 1e-9
    assert 0.0 < rep.jain_min <= rep.jain_quantiles["p50"] <= 1.0 + 1e-9
    assert rep.maxmin_median >= 1.0
    assert 0.0 <= rep.slo_violation_prob <= 1.0
    assert rep.workload_slo_prob.shape == (fleet.W,)
    assert (rep.workload_slo_prob >= 0).all()
    assert (rep.workload_slo_prob <= 1).all()
    # any-workload breach prob dominates each per-workload prob
    assert rep.slo_violation_prob >= rep.workload_slo_prob.max() - 1e-9
    assert len(rep.worst_scenarios) == max(1, int(np.ceil(0.25 * res.S)))
    assert set(rep.worst_scenarios) <= set(res.labels)
    assert any("CVaR" in ln for ln in rep.lines())
    d = rep.as_dict()
    assert isinstance(d["workload_slo_prob"], list)


def test_slo_threshold_moves_violation_prob(fleet, mixed_stack):
    res = evaluate_ensemble(fleet, CR1(lam=1.4), mixed_stack,
                            ctx=SolveContext(steps=100))
    loose = res.report(slo_frac=1e6).slo_violation_prob
    tight = res.report(slo_frac=1e-9).slo_violation_prob
    assert loose == 0.0
    assert tight >= res.report(slo_frac=0.05).slo_violation_prob


def test_compare_policies_table(fleet):
    stack = DuckPerturb(n_scenarios=3, seed=2).generate(fleet)
    reps = compare_policies(fleet, [CR1(lam=1.4), B1(F=0.8)], stack,
                            ctx=SolveContext(steps=60))
    assert set(reps) == {"cr1", "b1"}
    table = comparison_table(reps)
    assert len(table) == 4                     # header + rule + 2 rows
    assert "cr1" in table[2] and "b1" in table[3]
    # duplicate families get disambiguated keys
    reps2 = compare_policies(fleet, [CR1(lam=1.2), CR1(lam=1.6)], stack,
                             ctx=SolveContext(steps=60))
    assert set(reps2) == {"cr1", "cr1#1"}


# ---------------------------------------------------------------------------
# Rolling-horizon ensemble (batched warm-started ticks)
# ---------------------------------------------------------------------------
def test_streaming_ensemble_matches_solo_controllers(fleet):
    streams = ForecastRegime(n_scenarios=2, seed=5,
                             sigma=(0.02, 0.06)).streams(fleet, n_ticks=3)
    rep = run_streaming_ensemble(fleet, CR1(lam=1.45), streams, n_ticks=3,
                                 cold_steps=200, warm_steps=60)
    assert rep.batched
    assert rep.committed.shape == (2, fleet.W, 3)
    assert rep.total_inner_steps == 200 + 2 * 60
    for s, st in enumerate(streams):
        solo = RollingHorizonSolver(fleet, st, policy=CR1(lam=1.45),
                                    cold_steps=200, warm_steps=60).run(3)
        np.testing.assert_allclose(rep.committed[s], solo.committed,
                                   atol=1e-4)
        assert abs(rep.realized_reduction_pct[s]
                   - solo.realized_reduction_pct) < 0.01
    risk = rep.risk(cvar_alpha=0.5)
    assert risk["cvar50"] <= risk["p50"] + 1e-9
    assert np.isfinite(risk["mean"])


def test_streaming_ensemble_cr2_and_fallback(fleet):
    streams = ForecastRegime(n_scenarios=2, seed=1).streams(fleet,
                                                            n_ticks=2)
    rep2 = run_streaming_ensemble(fleet, CR2(cap_frac=0.8, outer=2),
                                  streams, n_ticks=2, cold_steps=80,
                                  warm_steps=40)
    assert rep2.batched
    assert rep2.total_inner_steps == (80 + 40) * 2       # steps * outer
    # closed-form baseline rides the sequential fallback
    repb = run_streaming_ensemble(fleet, B1(F=0.8), streams, n_ticks=2)
    assert not repb.batched
    assert repb.committed.shape == (2, fleet.W, 2)


def test_streaming_ensemble_validates_inputs(fleet):
    streams = ForecastRegime(n_scenarios=2, seed=0).streams(fleet,
                                                            n_ticks=2)
    with pytest.raises(ValueError, match=">= 1 stream"):
        run_streaming_ensemble(fleet, CR1(), [])
    with pytest.raises(ValueError, match="n_ticks"):
        run_streaming_ensemble(fleet, CR1(), streams, n_ticks=10 ** 6)
    bad = ForecastRegime(n_scenarios=1, seed=0).streams(
        synthetic_fleet(2, seed=0, hours=24), n_ticks=2)
    with pytest.raises(ValueError, match="horizon"):
        run_streaming_ensemble(fleet, CR1(), bad)


# ---------------------------------------------------------------------------
# Multi-region ensembles (ISSUE 8): batched lane + streaming groups
# ---------------------------------------------------------------------------
@pytest.fixture(scope="module")
def regional():
    import dataclasses

    from repro.core.fleet_solver import synthetic_regional_fleet
    del dataclasses
    return synthetic_regional_fleet(8, ["CA", "TX"], hours=24, seed=2)


def test_regional_divergence_batched_matches_loop(regional):
    """`RegionalDivergence` through the one-dispatch batched lane — with
    the per-scenario migration post-stage credited — matches the
    sequential api.solve loop to <0.01 pp."""
    from repro.core.scenario import RegionalDivergence
    gen = RegionalDivergence(n_scenarios=3, seed=0)
    ctx = SolveContext(steps=120)
    got = evaluate_ensemble(regional, CR1(lam=1.45), gen, ctx=ctx)
    ref = evaluate_ensemble(regional, CR1(lam=1.45), gen, ctx=ctx,
                            batched=False)
    assert got.batched and not ref.batched
    assert got.D.shape == (3, regional.W, regional.T)
    assert np.abs(got.carbon_reduction_pct
                  - ref.carbon_reduction_pct).max() < 0.01
    assert np.abs(got.total_penalty_pct
                  - ref.total_penalty_pct).max() < 0.01
    # the migration credit is really in there: every scenario's extras
    # carry a per-scenario plan on this positive-bandwidth topology
    assert all("migration" in e for e in got.extras)


def test_streaming_ensemble_multiregion_matches_solo(regional):
    """Multi-region streaming ensembles: S groups of R streams batch as
    (S, R, T) forecast stacks through the one-dispatch lane and match
    per-scenario solo RollingHorizonSolver runs to <0.01 pp."""
    regime = ForecastRegime(n_scenarios=2, seed=0, sigma=(0.02, 0.05))
    rep = run_streaming_ensemble(regional, CR1(lam=1.45), regime,
                                 n_ticks=3, cold_steps=150, warm_steps=50)
    assert rep.batched
    assert rep.committed.shape == (2, regional.W, 3)
    for g, ens_red in zip(regime.streams(regional, n_ticks=3),
                          rep.realized_reduction_pct):
        assert len(g) == regional.R
        solo = RollingHorizonSolver(regional, g, policy=CR1(lam=1.45),
                                    cold_steps=150, warm_steps=50).run(3)
        assert abs(ens_red - solo.realized_reduction_pct) < 0.01


def test_streaming_ensemble_multiregion_validates_groups(regional):
    full = ForecastRegime(n_scenarios=1, seed=0).streams(regional,
                                                         n_ticks=2)
    short = [g[:1] for g in full]              # one stream, two regions
    with pytest.raises(ValueError, match="per region"):
        run_streaming_ensemble(regional, CR1(), short, n_ticks=2)
