"""Device-sharded fleet engine: parity vs single-device, W padding, and the
donated-buffer streaming tick.

Multi-device cases run in subprocesses with 8 virtual CPU devices
(`conftest.run_in_subprocess`) — the main pytest process must stay
single-device. `scripts/ci.sh` also runs this file in its multi-device
lane on every PR.
"""
import numpy as np
import pytest

from conftest import run_in_subprocess


# ---------------------------------------------------------------------------
# Host-side helpers (no mesh needed)
# ---------------------------------------------------------------------------
def test_pad_fleet_rows_are_inert():
    import jax.numpy as jnp
    from repro.core.fleet_solver import (_bounds, fleet_penalties, pad_fleet,
                                         synthetic_fleet)
    p = synthetic_fleet(13)
    pp, W = pad_fleet(p, 8)
    assert (W, pp.W) == (13, 16)
    assert pp.usage.shape == (16, p.T)
    # true rows untouched
    np.testing.assert_array_equal(pp.usage[:13], p.usage)
    # pad rows: box pinned to [0, 0], zero penalties, finite divisors
    lo, hi = _bounds(pp)
    assert float(np.abs(np.asarray(hi)[13:]).max()) == 0.0
    assert float(np.abs(np.asarray(lo)[13:]).max()) == 0.0
    D = jnp.asarray(np.r_[0.1 * p.usage, np.zeros((3, p.T))])
    pens = np.asarray(fleet_penalties(pp, D))
    assert np.isfinite(pens).all()
    assert (pens[13:] == 0).all()


def test_pad_fleet_divisible_is_passthrough():
    from repro.core.fleet_solver import pad_fleet, synthetic_fleet
    p = synthetic_fleet(16)
    pp, W = pad_fleet(p, 8)
    assert (W, pp.W) == (16, 16)
    np.testing.assert_array_equal(pp.usage, p.usage)
    assert pp.upper is not None          # materialized for the spec tree


def test_pad_state_noop_when_already_padded():
    import jax.numpy as jnp
    from repro.core.engine import EngineState
    from repro.core.fleet_solver import _pad_state
    st = EngineState.cold(jnp.ones((8, 4)), n_eq=8)
    assert _pad_state(st, 8) is st
    padded = _pad_state(EngineState.cold(jnp.ones((5, 4)), n_eq=5), 8)
    assert padded.x.shape == (8, 4)
    assert padded.lam_eq.shape == (8,)
    np.testing.assert_array_equal(np.asarray(padded.x[5:]), 0.0)


# ---------------------------------------------------------------------------
# Sharded == single-device (8 virtual devices)
# ---------------------------------------------------------------------------
def test_sharded_parity_paper_fleet(paper_fleet):
    """Acceptance: all three policies on the 4-workload paper fleet (padded
    4 -> 8 rows) match the single-device solve to <0.01 pp."""
    run_in_subprocess("""
import numpy as np
from repro.core.api import CR1, CR2, CR3, SolveContext, solve
from repro.core.carbon import caiso_2021
from repro.core.fleet_solver import from_models
from repro.core.fleetcache import cached_paper_fleet
from repro.launch.mesh import make_fleet_mesh

fleet = cached_paper_fleet()
models = tuple(fleet[n] for n in ("RTS1", "RTS2", "AITraining",
                                  "DataPipeline"))
p = from_models(models, caiso_2021(48).mci)
mesh = make_fleet_mesh()
assert len(mesh.devices.ravel()) == 8

a = solve(p, CR1(lam=1.4), ctx=SolveContext(steps=300))
b = solve(p, CR1(lam=1.4), ctx=SolveContext(steps=300, mesh=mesh))
gap = abs((1.4 * a.total_penalty_pct - a.carbon_reduction_pct)
          - (1.4 * b.total_penalty_pct - b.carbon_reduction_pct))
assert gap < 0.01, f"CR1 gap {gap}"
assert b.D.shape == (4, 48)
assert b.state.x.shape == (8, 48)      # padded state for re-solve chaining

a = solve(p, CR2(outer=3), ctx=SolveContext(steps=200))
b = solve(p, CR2(outer=3), ctx=SolveContext(steps=200, mesh=mesh))
assert abs(a.carbon_reduction_pct - b.carbon_reduction_pct) < 0.01
assert abs(a.total_penalty_pct - b.total_penalty_pct) < 0.01

cr3 = CR3(outer=2, clearing_iters=3)
a = solve(p, cr3, ctx=SolveContext(steps=200))
b = solve(p, cr3, ctx=SolveContext(steps=200, mesh=mesh))
assert abs(a.carbon_reduction_pct - b.carbon_reduction_pct) < 0.01
assert abs(a.total_penalty_pct - b.total_penalty_pct) < 0.01
# identical Eq.-6 clearing trajectory
assert abs(a.extras["rho"] - b.extras["rho"]) < 1e-9
assert b.extras["balanced"] == a.extras["balanced"]
# pad rows are inert: their allowance constraints stay feasible, so their
# multipliers stay exactly zero (no growth to leak into chained re-solves)
assert float(np.abs(np.asarray(b.state.lam_in)[4:]).max()) == 0.0
print("OK")
""")


def test_sharded_parity_synthetic_mixed_and_padding():
    """Synthetic mixed fleet: W=13 (not divisible by 8) pads to 16 and still
    matches the single-device solve; warm re-solves accept both padded and
    unpadded states."""
    run_in_subprocess("""
import numpy as np
from repro.core.api import CR1, SolveContext, solve
from repro.core.fleet_solver import synthetic_fleet
from repro.launch.mesh import make_fleet_mesh

mesh = make_fleet_mesh()
p = synthetic_fleet(13)
cr1 = CR1(lam=1.45)
a = solve(p, cr1, ctx=SolveContext(steps=300))
b = solve(p, cr1, ctx=SolveContext(steps=300, mesh=mesh))
assert b.D.shape == (13, 48)
gap = abs((1.45 * a.total_penalty_pct - a.carbon_reduction_pct)
          - (1.45 * b.total_penalty_pct - b.carbon_reduction_pct))
assert gap < 0.01, f"gap {gap}"

# warm chaining: unpadded state (from the single-device solve) pads on
# entry; padded state (from the sharded solve) passes straight through.
w1 = solve(p, cr1, ctx=SolveContext(steps=100, mesh=mesh, warm=a.state))
w2 = solve(p, cr1, ctx=SolveContext(steps=100, mesh=mesh, warm=b.state))
assert np.abs(w1.D - w2.D).max() < 1e-4
print("OK")
""")


def test_sharded_donated_streaming_tick():
    """The fused donated-buffer streaming tick (shift + mu reset + re-solve
    in one XLA call, state buffers donated) commits the same plan as the
    legacy unfused path, and its warm re-solves keep the streaming_resolve
    objective gap vs a cold solve at the full budget."""
    run_in_subprocess("""
import numpy as np
from repro.core.api import CR1, SolveContext, solve
from repro.core.carbon import ForecastStream
from repro.core.fleet_solver import synthetic_fleet
from repro.core.streaming import RollingHorizonSolver
from repro.launch.mesh import make_fleet_mesh

lam, cold, warm = 1.45, 400, 120
p = synthetic_fleet(8)
mesh = make_fleet_mesh()

rep_plain = RollingHorizonSolver(
    p, ForecastStream.caiso(n_ticks=4, horizon=p.T, seed=5),
    policy=CR1(lam=lam), cold_steps=cold, warm_steps=warm).run(4)
rep_don = RollingHorizonSolver(
    p, ForecastStream.caiso(n_ticks=4, horizon=p.T, seed=5),
    policy=CR1(lam=lam), cold_steps=cold, warm_steps=warm, mesh=mesh,
    donate=True).run(4)
assert np.abs(rep_plain.committed - rep_don.committed).max() < 1e-5
assert [t.inner_steps for t in rep_don.ticks] == [cold, warm, warm, warm]

# warm-vs-cold objective gap on the last window (PR-2 criterion)
stream = ForecastStream.caiso(n_ticks=4, horizon=p.T, seed=5)
rhs = RollingHorizonSolver(p, stream, policy=CR1(lam=lam),
                           cold_steps=cold, warm_steps=warm, mesh=mesh)
rhs.run(4)
last = rhs._history[-1]
p_t = rhs._window_problem(last.tick, stream.forecast(last.tick))
cold_r = solve(p_t, CR1(lam=lam), ctx=SolveContext(steps=cold, mesh=mesh))
obj = lambda r: lam * r.total_penalty_pct - r.carbon_reduction_pct
gap = obj(last.plan) - obj(cold_r)
assert gap <= 0.1, f"warm obj gap {gap}"
print("OK")
""")


def test_sharded_fused_kernel_parity():
    """The fused al_step kernel inside the W-axis shard_map body: each
    device runs the kernel on its local row block (W=13 -> 16 padded, 2
    rows/device). Must match the single-device fused solve to <0.01 pp —
    the kernel math is row-independent, so shard tiling cannot move the
    optimum."""
    run_in_subprocess("""
import numpy as np
from repro.core.api import CR1, CR2, SolveContext, solve
from repro.core.fleet_solver import synthetic_fleet
from repro.launch.mesh import make_fleet_mesh

mesh = make_fleet_mesh()
p = synthetic_fleet(13)

obj = lambda r: 1.45 * r.total_penalty_pct - r.carbon_reduction_pct
a1 = solve(p, CR1(lam=1.45), ctx=SolveContext(steps=250, use_kernel=True))
b1 = solve(p, CR1(lam=1.45),
           ctx=SolveContext(steps=250, use_kernel=True, mesh=mesh))
gap = abs(obj(a1) - obj(b1))
assert gap < 0.01, f"CR1 fused shard gap {gap}"
assert b1.D.shape == (13, 48)

a2 = solve(p, CR2(outer=2), ctx=SolveContext(steps=150, use_kernel=True))
b2 = solve(p, CR2(outer=2),
           ctx=SolveContext(steps=150, use_kernel=True, mesh=mesh))
assert abs(a2.carbon_reduction_pct - b2.carbon_reduction_pct) < 0.01
assert abs(a2.total_penalty_pct - b2.total_penalty_pct) < 0.01

# bf16 moments thread through the sharded path too
c1 = solve(p, CR1(lam=1.45),
           ctx=SolveContext(steps=250, use_kernel=True, mesh=mesh,
                            moment_dtype="bfloat16"))
gap = abs(obj(c1) - obj(b1))
assert gap < 0.05, f"bf16 shard gap {gap}"
print("OK")
""")


def test_sharded_ensemble_parity():
    """Acceptance (ISSUE 5): `evaluate_ensemble` with `ctx.mesh` — the
    scenario axis vmapped INSIDE the W-axis shard_map — matches the
    sequential single-device `api.solve` loop to <0.01 pp for CR1 and
    CR2, with W=13 exercising inert-row padding of the scenario
    overlays (usage/entitlement/jobs/upper stacks)."""
    run_in_subprocess("""
import numpy as np
from repro.core.api import CR1, CR2, SolveContext
from repro.core.ensemble import evaluate_ensemble
from repro.core.fleet_solver import synthetic_fleet
from repro.core.scenario import (DuckPerturb, FleetJitter, FlexMixShift,
                                 resolve_scenarios)
from repro.launch.mesh import make_fleet_mesh

mesh = make_fleet_mesh()
assert len(mesh.devices.ravel()) == 8
p = synthetic_fleet(13)
stack = resolve_scenarios([DuckPerturb(n_scenarios=2, seed=1),
                           FleetJitter(n_scenarios=1, seed=2),
                           FlexMixShift(n_scenarios=1, seed=3)], p)

for pol, steps in ((CR1(lam=1.45), 300), (CR2(cap_frac=0.8, outer=2), 200)):
    r8 = evaluate_ensemble(p, pol, stack,
                           ctx=SolveContext(steps=steps, mesh=mesh))
    r1 = evaluate_ensemble(p, pol, stack, ctx=SolveContext(steps=steps),
                           batched=False)
    assert r8.batched and not r1.batched
    assert r8.D.shape == (4, 13, 48)
    gc = np.abs(r8.carbon_reduction_pct - r1.carbon_reduction_pct).max()
    gp = np.abs(r8.total_penalty_pct - r1.total_penalty_pct).max()
    assert gc < 0.01, f"{pol.name} carbon gap {gc}"
    assert gp < 0.01, f"{pol.name} penalty gap {gp}"
print("OK")
""")


def test_sharded_2d_mesh_multiregion_parity():
    """Acceptance (ISSUE 7): a 2-D (REGION_AXIS, FLEET_AXIS) mesh from
    `make_fleet_mesh(regions=2)` — the W axis sharded over BOTH axes —
    matches the single-device solve to <0.01 pp for a single-region
    fleet and for a multi-region R=2 fleet under all three policies,
    and the host-side migration post-stage rides the sharded solve."""
    run_in_subprocess("""
import dataclasses
import numpy as np
from repro.core.api import CR1, CR2, CR3, SolveContext, solve
from repro.core.fleet_solver import synthetic_fleet, synthetic_regional_fleet
from repro.launch.mesh import (FLEET_AXIS, REGION_AXIS, fleet_axes,
                               fleet_device_count, make_fleet_mesh)

mesh = make_fleet_mesh(regions=2)
assert mesh.axis_names == (REGION_AXIS, FLEET_AXIS)
assert fleet_axes(mesh) == (REGION_AXIS, FLEET_AXIS)
assert fleet_device_count(mesh) == 8
try:
    make_fleet_mesh(regions=3)
except ValueError as e:
    assert "divide" in str(e)
else:
    raise AssertionError("regions=3 must reject 8 devices")

# single-region fleet on the 2-D mesh: W=13 pads to 16 over 2x4 devices
p = synthetic_fleet(13)
a = solve(p, CR1(lam=1.45), ctx=SolveContext(steps=300))
b = solve(p, CR1(lam=1.45), ctx=SolveContext(steps=300, mesh=mesh))
gap = abs((1.45 * a.total_penalty_pct - a.carbon_reduction_pct)
          - (1.45 * b.total_penalty_pct - b.carbon_reduction_pct))
assert gap < 0.01, f"single-region 2-D gap {gap}"
assert b.D.shape == (13, 48)

# multi-region R=2 fleet (no topology: keep the comparison pure solve)
pr = dataclasses.replace(
    synthetic_regional_fleet(13, ["CA", "TX"], hours=48, seed=0,
                             utc_offsets="auto"),
    topology=None)
for pol, steps in ((CR1(lam=1.45), 300), (CR2(cap_frac=0.8, outer=2), 200),
                   (CR3(outer=2, clearing_iters=2), 200)):
    a = solve(pr, pol, ctx=SolveContext(steps=steps))
    b = solve(pr, pol, ctx=SolveContext(steps=steps, mesh=mesh))
    gc = abs(a.carbon_reduction_pct - b.carbon_reduction_pct)
    gp = abs(a.total_penalty_pct - b.total_penalty_pct)
    assert gc < 0.01, f"{pol.name} 2-D carbon gap {gc}"
    assert gp < 0.01, f"{pol.name} 2-D penalty gap {gp}"
    assert b.D.shape == (13, 48)
    # the same multi-region problem also accepts the 1-D fleet mesh
    c = solve(pr, pol, ctx=SolveContext(steps=steps, mesh=make_fleet_mesh()))
    assert abs(a.carbon_reduction_pct - c.carbon_reduction_pct) < 0.01

# migration post-stage (host-side) rides the sharded solve: same credit
# as off-mesh up to the D parity tolerance
pm = synthetic_regional_fleet(13, ["CA", "TX"], hours=48, seed=0,
                              utc_offsets="auto")
rm1 = solve(pm, CR1(lam=1.45), ctx=SolveContext(steps=300))
rm8 = solve(pm, CR1(lam=1.45), ctx=SolveContext(steps=300, mesh=mesh))
assert rm8.extras["migration"].net_saved > 0.0
assert abs(rm1.extras["migration"].net_saved
           - rm8.extras["migration"].net_saved) \
    < 0.05 * rm1.extras["migration"].net_saved + 1e-6
print("OK")
""")


def test_sharded_scanned_day_runs_on_mesh():
    """The whole-day `run_scanned` scan now accepts `mesh=` (the PR-6
    guard is lifted): the day scan inside the fleet shard_map commits
    the same plans as the unsharded per-tick loop."""
    run_in_subprocess("""
import numpy as np
from repro.core.api import CR1
from repro.core.carbon import ForecastStream
from repro.core.fleet_solver import synthetic_fleet
from repro.core.streaming import RollingHorizonSolver
from repro.launch.mesh import make_fleet_mesh

p = synthetic_fleet(13)
mk = lambda: ForecastStream.caiso(n_ticks=4, horizon=p.T, seed=5)
plain = RollingHorizonSolver(p, mk(), policy=CR1(lam=1.45),
                             cold_steps=300, warm_steps=100).run(4)
mesh = make_fleet_mesh()
scan = RollingHorizonSolver(p, mk(), policy=CR1(lam=1.45),
                            cold_steps=300, warm_steps=100,
                            mesh=mesh).run_scanned(4)
assert np.abs(plain.committed - scan.committed).max() < 1e-3
assert abs(plain.realized_reduction_pct
           - scan.realized_reduction_pct) < 0.01
print("OK")
""")


def test_sharded_sweep_parity():
    """Acceptance: `sweep(p, grid, ctx=SolveContext(mesh=...))` — the
    hyper axis vmapped INSIDE the W-axis shard_map — matches per-policy
    single-device solves to <0.01 pp on 8 virtual devices, for both the
    CR1 and CR2 families, with W=13 exercising inert-row padding."""
    run_in_subprocess("""
import numpy as np
from repro.core.api import CR1, CR2, SolveContext, solve, sweep
from repro.core.fleet_solver import synthetic_fleet
from repro.launch.mesh import make_fleet_mesh

mesh = make_fleet_mesh()
p = synthetic_fleet(13)

grid = [1.0, 1.45, 2.2]
sharded = sweep(p, [CR1(lam=l) for l in grid],
                ctx=SolveContext(steps=300, mesh=mesh))
for l, r8 in zip(grid, sharded):
    r1 = solve(p, CR1(lam=l), ctx=SolveContext(steps=300))
    gap = abs((l * r8.total_penalty_pct - r8.carbon_reduction_pct)
              - (l * r1.total_penalty_pct - r1.carbon_reduction_pct))
    assert gap < 0.01, f"CR1 lam={l} gap {gap}"
    assert r8.D.shape == (13, 48)

caps = [0.74, 0.8]
sharded = sweep(p, [CR2(cap_frac=c, outer=2) for c in caps],
                ctx=SolveContext(steps=200, mesh=mesh))
for c, r8 in zip(caps, sharded):
    r1 = solve(p, CR2(cap_frac=c, outer=2), ctx=SolveContext(steps=200))
    assert abs(r8.carbon_reduction_pct - r1.carbon_reduction_pct) < 0.01, c
    assert abs(r8.total_penalty_pct - r1.total_penalty_pct) < 0.01, c
print("OK")
""")


def test_sharded_scanned_day_multiregion_parity():
    """Acceptance (ISSUE 8): multi-region `run_scanned`/`solve_day` under
    BOTH the 1-D fleet mesh and the 2-D (region, fleet) mesh — per-tick
    per-region norms ride the scan as row-sharded stacks — match the
    unsharded per-tick loop to <0.01 pp realized carbon."""
    run_in_subprocess("""
import dataclasses
import numpy as np
from repro.core.api import CR1, CR2
from repro.core.fleet_solver import synthetic_regional_fleet
from repro.core.scenario import ForecastRegime
from repro.core.streaming import RollingHorizonSolver
from repro.launch.mesh import make_fleet_mesh

pr = dataclasses.replace(
    synthetic_regional_fleet(13, ["CA", "TX"], hours=48, seed=0,
                             utc_offsets="auto"),
    topology=None)
mk = lambda: ForecastRegime(n_scenarios=1, seed=5,
                            sigma=(0.03, 0.03)).streams(pr, n_ticks=4)[0]
for pol, cold, warm in ((CR1(lam=1.45), 300, 100),
                        (CR2(cap_frac=0.8, outer=2), 150, 50)):
    plain = RollingHorizonSolver(pr, mk(), policy=pol, cold_steps=cold,
                                 warm_steps=warm).run(4)
    for mesh in (make_fleet_mesh(), make_fleet_mesh(regions=2)):
        scan = RollingHorizonSolver(pr, mk(), policy=pol, cold_steps=cold,
                                    warm_steps=warm,
                                    mesh=mesh).run_scanned(4)
        gap = abs(plain.realized_reduction_pct
                  - scan.realized_reduction_pct)
        assert gap < 0.01, f"{pol.name} {mesh.axis_names} gap {gap}"
        assert np.abs(plain.committed - scan.committed).max() < 1e-2
print("OK")
""")


def test_sharded_scanned_day_r1_regional_bitwise():
    """The degenerate R=1 regional fleet mesh-scans bitwise-identically
    to the plain single-region fleet (the `_single_region_view`
    canonicalization reaches the day scan too)."""
    run_in_subprocess("""
import numpy as np
from repro.core.api import CR1
from repro.core.carbon import ForecastStream
from repro.core.fleet_solver import regional_fleet, synthetic_fleet
from repro.core.streaming import RollingHorizonSolver
from repro.launch.mesh import make_fleet_mesh

fp = synthetic_fleet(13)
pr = regional_fleet([fp], np.asarray(fp.mci)[None])
mk = lambda: ForecastStream.caiso(n_ticks=3, horizon=fp.T, seed=5)
mesh = make_fleet_mesh()
a = RollingHorizonSolver(fp, mk(), policy=CR1(lam=1.45), cold_steps=200,
                         warm_steps=60, mesh=mesh).run_scanned(3)
b = RollingHorizonSolver(pr, mk(), policy=CR1(lam=1.45), cold_steps=200,
                         warm_steps=60, mesh=mesh).run_scanned(3)
np.testing.assert_array_equal(a.committed, b.committed)
assert a.realized_reduction_pct == b.realized_reduction_pct
print("OK")
""")
