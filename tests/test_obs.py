"""`repro.obs` tests: the in-solve telemetry contract (telemetry-off
bitwise-identical to pre-PR, telemetry-on plans bitwise-identical to
telemetry-off), the trace surfaces through `solve`/`sweep`/`ensemble`/
`solve_day`, the streaming tick ledger (one-dispatch contract intact
with the ledger enabled), the JSONL schema pin, span timing, and the
report CLI round trip."""
import dataclasses
import json

import numpy as np
import pytest

from repro.core.api import (CR1, CR2, CR3, SolveContext, solve, solve_day,
                            sweep)
from repro.core.fleet_solver import synthetic_fleet
from repro.obs import (SCHEMA_VERSION, ConvergenceTrace, EventWriter,
                       SpanEvent, TelemetryConfig, TickEvent, host_meta,
                       read_events, span)
from repro.obs.report import main as report_main

from conftest import run_in_subprocess


@pytest.fixture(scope="module")
def fp():
    return synthetic_fleet(6, seed=3)


# ---------------------------------------------------------------------------
# In-solve telemetry: bitwise parity + trace content
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("policy", [CR1(lam=1.4), CR2(cap_frac=0.12)],
                         ids=["cr1", "cr2"])
def test_telemetry_on_is_bitwise_off(fp, policy):
    """The ISSUE acceptance bar: telemetry-on plans/states are bitwise
    identical to telemetry-off — the trace rides the scan as extra aux
    outputs, it never perturbs the solve."""
    off = solve(fp, policy, ctx=SolveContext(steps=120))
    on = solve(fp, policy,
               ctx=SolveContext(steps=120,
                                telemetry=TelemetryConfig(every=10)))
    np.testing.assert_array_equal(off.D, on.D)
    np.testing.assert_array_equal(np.asarray(off.state.x),
                                  np.asarray(on.state.x))
    assert off.carbon_reduction_pct == on.carbon_reduction_pct
    assert off.extras.get("telemetry") is None


@pytest.mark.parametrize("policy", [CR1(lam=1.4), CR2(cap_frac=0.12)],
                         ids=["cr1", "cr2"])
def test_telemetry_trace_content(fp, policy):
    r = solve(fp, policy,
              ctx=SolveContext(steps=120,
                               telemetry=TelemetryConfig(every=10)))
    trace = r.extras["telemetry"]
    assert isinstance(trace, ConvergenceTrace)
    # every=10 over (outer * inner) total steps: steps 10, 20, ...
    assert trace.n_samples == trace.step.shape[0] > 0
    assert trace.step[0] == 10 and np.all(np.diff(trace.step) == 10)
    assert np.all(np.isfinite(trace.objective))
    assert np.all(trace.grad_norm >= 0)
    if policy.name == "cr1":
        # unconstrained lane: no residuals, violation pinned at 0
        assert np.all(trace.violation == 0.0)
    else:
        assert np.all(trace.violation >= 0.0)
        assert trace.mu[-1] >= trace.mu[0]   # mu schedule grows
    d = next(trace.samples())
    assert set(d) == {"step", "objective", "grad_norm", "violation",
                      "dx", "mu"}
    json.dumps(d)   # samples are ledger-ready


def test_telemetry_mesh_parity_subprocess():
    """Sharded telemetry all-reduces to the solo trace (objective psum,
    violation pmax), and the sharded plan stays bitwise the solo plan."""
    run_in_subprocess("""
import numpy as np
from repro.core.api import CR1, SolveContext, solve
from repro.core.fleet_solver import synthetic_fleet
from repro.launch.mesh import make_fleet_mesh
from repro.obs import TelemetryConfig

p = synthetic_fleet(8, seed=3)
tel = TelemetryConfig(every=15)
solo = solve(p, CR1(lam=1.4), ctx=SolveContext(steps=60, telemetry=tel))
mesh = make_fleet_mesh()
assert len(mesh.devices.ravel()) == 2
sh = solve(p, CR1(lam=1.4),
           ctx=SolveContext(steps=60, telemetry=tel, mesh=mesh))
np.testing.assert_array_equal(solo.D, sh.D)
t0, t1 = solo.extras["telemetry"], sh.extras["telemetry"]
np.testing.assert_array_equal(t0.step, t1.step)
np.testing.assert_allclose(t0.objective, t1.objective, rtol=1e-6)
np.testing.assert_allclose(t0.violation, t1.violation, rtol=1e-6,
                           atol=1e-12)
print("mesh telemetry OK")
""", devices=2)


def test_telemetry_refuses_fused_kernel(fp):
    with pytest.raises(NotImplementedError, match="telemetry"):
        solve(fp, CR1(lam=1.4),
              ctx=SolveContext(steps=40, use_kernel=True,
                               telemetry=TelemetryConfig(every=10)))


def test_telemetry_config_validates():
    with pytest.raises(ValueError):
        TelemetryConfig(every=0)


def test_sweep_loop_lane_carries_traces(fp):
    """Telemetry forces the per-policy loop (the vmapped lane has no
    trace plumbing); each result carries its own trace."""
    rs = sweep(fp, [CR1(lam=1.2), CR1(lam=1.6)],
               ctx=SolveContext(steps=60,
                                telemetry=TelemetryConfig(every=10)))
    assert len(rs) == 2
    for r in rs:
        assert r.extras["telemetry"].n_samples > 0
    # traces differ across lambdas — they are per-solve, not shared
    assert not np.array_equal(rs[0].extras["telemetry"].objective,
                              rs[1].extras["telemetry"].objective)


def test_ensemble_telemetry_forces_loop(fp):
    from repro.core.ensemble import evaluate_ensemble
    from repro.core.scenario import DuckPerturb, resolve_scenarios

    stack = resolve_scenarios([DuckPerturb(n_scenarios=2, seed=0)], fp)
    got = evaluate_ensemble(
        fp, CR1(lam=1.4), stack,
        ctx=SolveContext(steps=60, telemetry=TelemetryConfig(every=10)))
    assert not got.batched
    assert all(e["telemetry"].n_samples > 0 for e in got.extras)
    with pytest.raises(ValueError, match="telemetry"):
        evaluate_ensemble(
            fp, CR1(lam=1.4), stack, batched=True,
            ctx=SolveContext(steps=60, telemetry=TelemetryConfig(every=10)))


@pytest.mark.parametrize("policy", [CR1(lam=1.4), CR2(cap_frac=0.12)],
                         ids=["cr1", "cr2"])
def test_solve_day_traces_per_tick(fp, policy):
    rng = np.random.default_rng((7, 2))
    base = np.asarray(fp.mci, float)
    stack = np.stack([np.roll(base, -i) * (1 + 0.01 * rng.standard_normal(
        base.shape)) for i in range(4)])
    off = solve_day(fp, policy, stack, cold_steps=60, warm_steps=20)
    on = solve_day(fp, policy, stack, cold_steps=60, warm_steps=20,
                   ctx=SolveContext(telemetry=TelemetryConfig(every=10)))
    np.testing.assert_array_equal(off.committed, on.committed)
    traces = on.last.extras["telemetry"]
    assert len(traces) == 4            # tick 0 + 3 warm ticks
    # cold budget is 3x the warm budget, so (whatever the policy's outer
    # multiplier) tick 0 carries 3x the samples of each warm tick
    warm_n = traces[1].n_samples
    assert warm_n > 0
    assert traces[0].n_samples == 3 * warm_n
    assert all(t.n_samples == warm_n for t in traces[1:])
    assert "telemetry" not in off.last.extras


# ---------------------------------------------------------------------------
# Streaming ledger: events + one-dispatch contract
# ---------------------------------------------------------------------------
def test_streaming_ledger_round_trip(fp, tmp_path, capsys):
    from repro.core.carbon import ForecastStream
    from repro.core.streaming import RollingHorizonSolver

    path = tmp_path / "run.jsonl"
    stream = ForecastStream.caiso(n_ticks=3, horizon=fp.T, seed=1)
    solver = RollingHorizonSolver(fp, stream, policy="cr1", cold_steps=60,
                                  warm_steps=15, events=str(path),
                                  telemetry=TelemetryConfig(every=15))
    solver.run(3)
    recs = read_events(path)
    assert recs[0]["kind"] == "header"
    assert recs[0]["schema"] == SCHEMA_VERSION
    assert recs[0]["tags"]["policy"] == "cr1"
    ticks = [r for r in recs if r["kind"] == "tick"]
    assert [t["tick"] for t in ticks] == [0, 1, 2]
    assert ticks[0]["cold"] and not ticks[1]["cold"]
    assert ticks[0]["warm_steps"] == 60 and ticks[1]["warm_steps"] == 15
    assert ticks[0]["revision"] == 0.0 and ticks[1]["revision"] > 0
    assert all(t["latency_s"] > 0 and t["dispatches"] == 1 for t in ticks)
    assert ticks[0]["recompiles"] > 0     # cold tick compiles
    assert ticks[2]["recompiles"] == 0    # second warm tick: cache hit
    tel = [r for r in recs if r["kind"] == "telemetry"]
    assert sorted({t["tick"] for t in tel}) == [0, 1, 2]
    # the schema-pinned round trip: report CLI renders it and exits 0
    assert report_main([str(path)]) == 0
    out = capsys.readouterr().out
    assert "tick ledger (3 ticks)" in out and "convergence" in out


def test_run_scanned_one_dispatch_with_ledger(fp, tmp_path, monkeypatch):
    """The ledger must not cost dispatches: a scanned day with events +
    telemetry on still funnels through ONE day-scan call, and a second
    same-shape day is provably compile-free (recompile_guard(0)) —
    emission is host-side after the solve."""
    import repro.core.api as api
    from repro.analysis import recompile_guard
    from repro.core.carbon import ForecastStream
    from repro.core.streaming import RollingHorizonSolver

    path = tmp_path / "day.jsonl"
    stream = ForecastStream.caiso(n_ticks=12, horizon=fp.T, seed=2)
    solver = RollingHorizonSolver(fp, stream, policy="cr1", cold_steps=60,
                                  warm_steps=15, events=str(path),
                                  telemetry=TelemetryConfig(every=15))
    solver.run_scanned(4)   # day 1: cold scan compiles
    solver.run_scanned(4)   # day 2: warm continuation compiles (new
    #                         static combo: first_shift=1, reset_mu)
    calls = []
    orig = api._day_cr1
    monkeypatch.setattr(
        api, "_day_cr1",
        lambda *a, **k: (calls.append(1), orig(*a, **k))[1])
    with recompile_guard(0, label="scanned day with ledger"):
        solver.run_scanned(4)   # day 3: provably compile-free
    assert len(calls) == 1
    recs = read_events(path)
    ticks = [r for r in recs if r["kind"] == "tick"]
    assert len(ticks) == 12
    # the one dispatch lands on each day's first tick, 0 elsewhere
    assert [t["dispatches"] for t in ticks] == [1, 0, 0, 0] * 3
    assert sum(t["recompiles"] for t in ticks[8:]) == 0
    # in-solve traces landed for every scanned tick
    tel_ticks = {r["tick"] for r in recs if r["kind"] == "telemetry"}
    assert tel_ticks == set(range(12))


# ---------------------------------------------------------------------------
# Events: schema pin, atomic append, host metadata
# ---------------------------------------------------------------------------
def test_event_writer_appends_without_second_header(tmp_path):
    path = tmp_path / "ev.jsonl"
    with EventWriter(str(path), tags={"a": 1}) as w:
        w.write(SpanEvent(name="x", elapsed_s=0.5))
    with EventWriter(str(path)) as w:   # reopen: header already present
        w.write(SpanEvent(name="y", elapsed_s=0.25))
    recs = read_events(path)
    assert [r["kind"] for r in recs] == ["header", "span", "span"]
    assert recs[0]["tags"] == {"a": 1}


def test_read_events_schema_pin(tmp_path):
    bad = tmp_path / "bad.jsonl"
    bad.write_text(json.dumps({"kind": "header", "schema": 999,
                               "host": {}}) + "\n")
    with pytest.raises(ValueError, match="schema"):
        read_events(bad)
    headerless = tmp_path / "nohdr.jsonl"
    headerless.write_text(json.dumps({"kind": "span", "name": "x",
                                      "elapsed_s": 1.0}) + "\n")
    with pytest.raises(ValueError, match="header"):
        read_events(headerless)
    assert report_main([str(bad)]) == 1   # CLI surfaces it as exit 1


def test_host_meta_fields():
    meta = host_meta()
    assert {"platform", "n_devices", "device_kind", "jax", "jaxlib",
            "pallas_interpret"} <= set(meta)
    assert meta["n_devices"] >= 1


# ---------------------------------------------------------------------------
# Spans
# ---------------------------------------------------------------------------
def test_span_times_device_work(tmp_path):
    import jax.numpy as jnp

    path = tmp_path / "spans.jsonl"
    with EventWriter(str(path)) as w:
        with span("mul", writer=w, meta={"n": 64}) as sp:
            y = sp.bind(jnp.ones(64) * 3)
        assert sp.elapsed_s > 0
        np.testing.assert_array_equal(np.asarray(y), 3 * np.ones(64))
        # the event is written even when the body raises
        with pytest.raises(RuntimeError, match="boom"):
            with span("fails", writer=w):
                raise RuntimeError("boom")
    recs = read_events(path)
    assert [r["name"] for r in recs[1:]] == ["mul", "fails"]
    assert recs[1]["meta"] == {"n": 64}


def test_tick_event_dataclass_round_trip(tmp_path):
    path = tmp_path / "t.jsonl"
    ev = TickEvent(tick=3, revision=0.02, warm_steps=40, cold=False,
                   objective_proxy=11.5, latency_s=0.2,
                   committed_carbon=[1.0, 2.0], realized_carbon=[1.1, 1.9],
                   migration_credit=0.3, recompiles=0, dispatches=1)
    with EventWriter(str(path)) as w:
        w.write(ev)
    rec = read_events(path)[1]
    assert rec["kind"] == "tick" and rec["tick"] == 3
    assert rec["committed_carbon"] == [1.0, 2.0]
