"""Vectorized fleet solver tests (beyond-paper scaling path), exercised
through the unified policy API (`repro.core.api`)."""
import dataclasses
import warnings

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.api import CR1, CR2, CR3, SolveContext, solve
from repro.core.fleet_solver import (FleetProblem, fleet_penalties,
                                     from_models, synthetic_fleet)


@pytest.fixture(scope="module")
def fp4(dr_problem):
    return from_models(dr_problem.models, dr_problem.mci)


def test_vectorized_penalties_match_per_workload(dr_problem, fp4):
    rng = np.random.default_rng(0)
    D = jnp.asarray(rng.uniform(-1, 1, size=(dr_problem.W, dr_problem.T)))
    vec = np.asarray(fleet_penalties(fp4, D))
    ref = np.asarray(dr_problem.penalties(D, smooth=0.0))
    np.testing.assert_allclose(vec, ref, rtol=1e-4, atol=1e-4)


def test_kernel_path_matches_jnp_path(fp4):
    rng = np.random.default_rng(1)
    D = jnp.asarray(rng.uniform(-1, 1, size=(fp4.W, fp4.T)))
    a = np.asarray(fleet_penalties(fp4, D, use_kernel=False))
    b = np.asarray(fleet_penalties(fp4, D, use_kernel=True))
    np.testing.assert_allclose(a, b, rtol=1e-4, atol=1e-4)


@pytest.mark.slow
def test_fleet_solver_matches_slsqp(dr_problem, fp4):
    from repro.core.policies import cr1_spec
    from repro.core.solver import solve_slsqp
    ref = solve_slsqp(cr1_spec(dr_problem, 1.4), maxiter=250)
    got = solve(fp4, CR1(lam=1.4))
    assert abs(got.carbon_reduction_pct - ref.carbon_reduction_pct) < 1.5
    assert abs(got.total_penalty_pct - ref.total_penalty_pct) < 1.5
    assert got.preservation_violation < 1e-3


def test_fleet_scales_to_many_workloads():
    p = synthetic_fleet(256)
    r = solve(p, CR1(lam=1.4), ctx=SolveContext(steps=300))
    assert r.carbon_reduction_pct > 0
    assert r.preservation_violation < 1e-3
    assert r.D.shape == (256, 48)
    # box bounds
    hi = np.minimum(0.5 * p.entitlement[:, None], p.usage)
    assert (r.D <= hi + 1e-5).all()
    rts = ~p.is_batch
    assert (r.D[rts] >= -1e-6).all()       # RTS curtail-only


@pytest.mark.parametrize("W", [3, 10])
def test_mixed_fleet_round_trip(W):
    """from_problem/to_problem round-trips a mixed RTS/batch fleet: models,
    masks, and penalties all survive both directions."""
    fp = synthetic_fleet(W, seed=W)
    assert fp.is_batch.any() and (~fp.is_batch).any()   # genuinely mixed
    p = fp.to_problem()
    assert p.W == W
    assert p.names == fp.names
    rng = np.random.default_rng(W)
    D = jnp.asarray(rng.uniform(-0.3, 0.3, size=(W, fp.T))
                    * fp.usage)
    np.testing.assert_allclose(np.asarray(p.penalties(D, smooth=0.0)),
                               np.asarray(fleet_penalties(fp, D)),
                               rtol=1e-5, atol=1e-5)
    fp2 = FleetProblem.from_problem(p)
    for field in ("usage", "entitlement", "k", "rts_coeffs", "betas",
                  "x2_kind", "jobs", "mci"):
        np.testing.assert_allclose(getattr(fp2, field),
                                   getattr(fp, field), rtol=1e-12,
                                   err_msg=field)
    np.testing.assert_array_equal(fp2.is_batch, fp.is_batch)
    assert fp2.names == fp.names


def test_from_problem_rejects_non_default_semantics():
    fp = synthetic_fleet(3)
    p = fp.to_problem(preservation="inequality")
    with pytest.raises(ValueError, match="preservation"):
        FleetProblem.from_problem(p)


def test_cr3_unbalanced_clearing_warns():
    """When clearing_iters runs out with rebates still exceeding taxes
    (Eq. 6 unmet), the result must say so instead of silently returning
    the last rho."""
    p = synthetic_fleet(4)
    # Entitlements below peak usage make the allowance unmeetable without
    # deep curtailment, and a huge rho prices those rebates far beyond the
    # tax pool; one clearing iteration can at most halve rho.
    tight = dataclasses.replace(p, entitlement=0.6 * p.usage.max(axis=1))
    with pytest.warns(RuntimeWarning, match="did not converge"):
        r = solve(tight, CR3(rho=1e4, tax_frac=0.1, outer=2,
                             clearing_iters=1),
                  ctx=SolveContext(steps=100))
    assert not r.extras["balanced"] and not r.balanced
    assert r.extras["fiscal_deficit"] > 0
    assert r.fiscal_deficit == r.extras["fiscal_deficit"]
    assert r.extras["rho"] < 1e4                      # it did try


def test_cr3_balanced_clearing_reports_clean(fp4):
    with warnings.catch_warnings():
        warnings.simplefilter("error", RuntimeWarning)
        r = solve(fp4, CR3(rho=0.02, outer=2, clearing_iters=8),
                  ctx=SolveContext(steps=150))
    assert r.extras["balanced"] and r.balanced
    assert r.extras["fiscal_deficit"] == 0.0
    assert r.extras["rho"] > 0


def test_cr2_fleet_hits_rts_targets(dr_problem, fp4):
    """Vectorized CR2: real-time workloads meet their cap-reference penalty
    targets exactly; batch lands at-or-below target (the preservation
    projection bounds attainable deferral penalties — fairer than required,
    never unfairer)."""
    from repro.core.fleet_solver import cr2_reference_fleet
    r = solve(fp4, CR2(cap_frac=0.78))
    refs = cr2_reference_fleet(fp4, 0.78)
    pens = np.asarray(fleet_penalties(fp4, jnp.asarray(r.D)))
    rts = ~fp4.is_batch
    np.testing.assert_allclose(pens[rts], refs[rts], rtol=0.05, atol=0.02)
    assert (pens[fp4.is_batch] <= refs[fp4.is_batch] + 0.05).all()
    assert r.carbon_reduction_pct > 0
    assert r.preservation_violation < 1e-3
