"""`hypothesis` import shim: property tests skip cleanly when it's absent.

`hypothesis` is an optional dev dependency (see requirements.txt). Test
modules import `given`/`settings`/`st`/`hnp` from here instead of from
hypothesis directly, so collection succeeds without it: hand-computed
tests still run, and @given property tests become zero-arg skippers.
"""
from __future__ import annotations

import pytest

try:
    from hypothesis import given, settings, strategies as st
    from hypothesis.extra import numpy as hnp
    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:
    HAVE_HYPOTHESIS = False

    class _AnyStrategy:
        """Stands in for strategy builders; only ever passed to `given`."""

        def __getattr__(self, name):
            return self

        def __call__(self, *args, **kwargs):
            return self

    st = _AnyStrategy()
    hnp = _AnyStrategy()

    def given(*args, **kwargs):
        def deco(fn):
            # Zero-arg wrapper: pytest must not treat hypothesis-supplied
            # arguments as fixtures.
            def skipper():
                pytest.skip("hypothesis not installed")
            skipper.__name__ = fn.__name__
            skipper.__doc__ = fn.__doc__
            return skipper
        return deco

    def settings(*args, **kwargs):
        return lambda fn: fn
