"""First-class policy API (`repro.core.api`): parity with the legacy
entry points (bitwise on CPU), the vmapped sweep lane vs a loop of
`solve()` calls, policy-object round-trips (stable cache keys), the
registry, and the legacy-shim deprecation contract.

`scripts/ci.sh` re-runs this file under `-W error::DeprecationWarning`
(the deprecation lane): every shim call below is wrapped in an explicit
warning capture, so any *stray* DeprecationWarning — a shim warning
twice, or the new API leaking through a shim — fails the lane."""
import dataclasses
import json
import warnings

import numpy as np
import pytest

from repro.core import fleet_solver as fs
from repro.core.api import (B1, B3, CR1, CR2, CR3, POLICY_REGISTRY,
                            DRPolicy, SolveContext, resolve_policy, solve,
                            sweep)
from repro.core.fleet_solver import FleetSolveResult, synthetic_fleet


@pytest.fixture(scope="module")
def fp():
    return synthetic_fleet(5, seed=3)


def _shim(fn, *args, **kwargs):
    """Call a legacy shim, asserting it warns exactly once, and swallow
    the warning so the deprecation lane's error filter stays quiet."""
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        out = fn(*args, **kwargs)
    dep = [x for x in w if issubclass(x.category, DeprecationWarning)
           and "repro.core.api" in str(x.message)]
    assert len(dep) == 1, \
        f"{fn.__name__} emitted {len(dep)} DeprecationWarnings, want 1"
    return out


def _same_result(a: FleetSolveResult, b: FleetSolveResult) -> None:
    np.testing.assert_array_equal(a.D, b.D)
    assert a.carbon_reduction_pct == b.carbon_reduction_pct
    assert a.total_penalty_pct == b.total_penalty_pct
    assert a.iters == b.iters
    assert a.preservation_violation == b.preservation_violation
    np.testing.assert_array_equal(np.asarray(a.state.x),
                                  np.asarray(b.state.x))
    np.testing.assert_array_equal(np.asarray(a.state.lam_eq),
                                  np.asarray(b.state.lam_eq))
    np.testing.assert_array_equal(np.asarray(a.state.lam_in),
                                  np.asarray(b.state.lam_in))


# ---------------------------------------------------------------------------
# solve() parity with the legacy entry points — bitwise on CPU
# ---------------------------------------------------------------------------
def test_cr1_solve_matches_legacy_bitwise(fp):
    new = solve(fp, CR1(lam=1.4), ctx=SolveContext(steps=120))
    old = _shim(fs.solve_cr1_fleet, fp, lam=1.4, steps=120)
    _same_result(new, old)
    assert new.extras == {}


def test_cr2_solve_matches_legacy_bitwise(fp):
    new = solve(fp, CR2(cap_frac=0.8, outer=2), ctx=SolveContext(steps=100))
    old = _shim(fs.solve_cr2_fleet, fp, cap_frac=0.8, steps=100, outer=2)
    _same_result(new, old)
    assert new.iters == 200                      # steps * outer


def test_cr3_solve_matches_legacy_bitwise_incl_extras(fp):
    new = solve(fp, CR3(outer=2, clearing_iters=2),
                ctx=SolveContext(steps=100))
    old, rho_old = _shim(fs.solve_cr3_fleet, fp, steps=100, outer=2,
                         clearing_iters=2)
    _same_result(new, old)
    assert new.extras["rho"] == rho_old
    assert new.extras["balanced"] == old.balanced
    assert new.extras["fiscal_deficit"] == old.fiscal_deficit
    # compat properties read through to extras
    assert new.balanced == new.extras["balanced"]
    assert new.fiscal_deficit == new.extras["fiscal_deficit"]


def test_warm_start_via_context_matches_legacy(fp):
    cold = solve(fp, CR1(lam=1.45), ctx=SolveContext(steps=120))
    new = solve(fp, CR1(lam=1.45),
                ctx=SolveContext(steps=60, warm=cold.state))
    old = _shim(fs.solve_cr1_fleet, fp, lam=1.45, steps=60,
                warm=cold.state)
    _same_result(new, old)


def test_policy_default_step_budgets(fp):
    """ctx.steps=None uses the policy's default budget (the legacy
    per-entry-point defaults)."""
    assert CR1.default_steps == 600
    assert CR2.default_steps == 400
    assert CR3.default_steps == 600
    assert SolveContext().resolved_steps(CR1()) == 600
    assert SolveContext(steps=42).resolved_steps(CR1()) == 42


# ---------------------------------------------------------------------------
# sweep() — one vmapped XLA call vs a python loop of solve()
# ---------------------------------------------------------------------------
def test_cr1_sweep_matches_solve_loop(fp):
    grid = [1.0, 1.45, 2.0]
    ctx = SolveContext(steps=100)
    got = sweep(fp, [CR1(lam=lam) for lam in grid], ctx=ctx)
    for lam, r in zip(grid, got):
        ref = solve(fp, CR1(lam=lam), ctx=ctx)
        np.testing.assert_allclose(r.D, ref.D, atol=1e-5)
        assert abs(r.carbon_reduction_pct
                   - ref.carbon_reduction_pct) < 1e-3
        assert abs(r.total_penalty_pct - ref.total_penalty_pct) < 1e-3


def test_cr1_sweep_matches_legacy_sweep(fp):
    grid = [1.0, 1.45, 2.0]
    got = sweep(fp, [CR1(lam=lam) for lam in grid],
                ctx=SolveContext(steps=100))
    old = _shim(fs.solve_cr1_fleet_sweep, fp, grid, steps=100)
    for r, ro in zip(got, old):
        np.testing.assert_array_equal(r.D, ro.D)


def test_cr2_sweep_matches_solve_loop(fp):
    caps = [0.74, 0.8]
    ctx = SolveContext(steps=80)
    got = sweep(fp, [CR2(cap_frac=c, outer=2) for c in caps], ctx=ctx)
    for c, r in zip(caps, got):
        ref = solve(fp, CR2(cap_frac=c, outer=2), ctx=ctx)
        np.testing.assert_allclose(r.D, ref.D, atol=1e-4)
        assert abs(r.carbon_reduction_pct
                   - ref.carbon_reduction_pct) < 1e-2


def test_cr3_sweep_matches_solve_loop(fp):
    """Lockstep clearing: every lane follows exactly its solo-`solve()`
    ρ-update trajectory (balanced lanes freeze). Tolerances are looser
    than CR1/CR2 — unbalanced-lane re-solves amplify vmap low-bit noise
    through the warm restarts."""
    pols = [CR3(tax_frac=t, outer=2, clearing_iters=2)
            for t in (0.18, 0.3)]
    ctx = SolveContext(steps=80)
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", RuntimeWarning)
        got = sweep(fp, pols, ctx=ctx)
        refs = [solve(fp, pl, ctx=ctx) for pl in pols]
    for r, ref in zip(got, refs):
        assert abs(r.carbon_reduction_pct
                   - ref.carbon_reduction_pct) < 0.05
        assert abs(r.total_penalty_pct - ref.total_penalty_pct) < 0.05
        np.testing.assert_allclose(r.extras["rho"], ref.extras["rho"],
                                   rtol=1e-3)
        assert r.extras["balanced"] == ref.extras["balanced"]
        assert r.iters == ref.iters              # same clearing rounds


def test_sweep_mixed_families_falls_back_to_loop(fp):
    ctx = SolveContext(steps=60)
    got = sweep(fp, [CR1(lam=1.4), B1(F=0.8)], ctx=ctx)
    ref0 = solve(fp, CR1(lam=1.4), ctx=ctx)
    ref1 = solve(fp, B1(F=0.8), ctx=ctx)
    np.testing.assert_array_equal(got[0].D, ref0.D)
    np.testing.assert_array_equal(got[1].D, ref1.D)


def test_sweep_fallback_shares_warm_read_only_and_drops_donate(fp):
    """A warm context forces the fallback loop; the shared warm state must
    be reused read-only by every policy — donating it would invalidate the
    buffers after the first solve and crash the second."""
    cold = solve(fp, CR1(lam=1.4), ctx=SolveContext(steps=60))
    got = sweep(fp, [CR1(lam=1.0), CR1(lam=1.5)],
                ctx=SolveContext(steps=30, warm=cold.state, donate=True))
    for lam, r in zip((1.0, 1.5), got):
        ref = solve(fp, CR1(lam=lam),
                    ctx=SolveContext(steps=30, warm=cold.state))
        np.testing.assert_array_equal(r.D, ref.D)


def test_configured_policy_knob_mapping():
    """The shared string->policy resolver: legacy knobs configure the CR
    families (outer defaults to 4, the historical streaming budget),
    other registered names get default hypers, objects pass through."""
    from repro.core.api import configured_policy
    assert configured_policy("cr1", lam=1.2) == CR1(lam=1.2)
    assert configured_policy("cr2", cap_frac=0.8) == CR2(cap_frac=0.8,
                                                         outer=4)
    assert configured_policy("cr3", rho=0.03, outer=2) == \
        CR3(rho=0.03, tax_frac=0.2, outer=2)
    assert configured_policy("b1") == B1()
    pl = CR1(lam=9.9)
    assert configured_policy(pl, lam=1.0) is pl
    with pytest.raises(ValueError, match="registered policies"):
        configured_policy("cr9")


def test_sweep_warm_stacked_states_refine(fp):
    """Warm refinement sweeps (ISSUE 7 satellite): a stacked warm
    `EngineState` (one lane per policy, e.g. the previous sweep's states
    via `stack_states`) rides the vmapped sweep — each lane warm-starts
    from its own state and matches the per-lane warm `solve()`."""
    from repro.core.api import stack_states
    grid = [1.0, 1.45, 2.0]
    pols = [CR1(lam=lam) for lam in grid]
    first = sweep(fp, pols, ctx=SolveContext(steps=80))
    warm = stack_states([r.state for r in first])
    got = sweep(fp, pols, ctx=SolveContext(steps=40, warm=warm))
    for lam, r0, r in zip(grid, first, got):
        ref = solve(fp, CR1(lam=lam),
                    ctx=SolveContext(steps=40, warm=r0.state))
        np.testing.assert_allclose(r.D, ref.D, atol=1e-5)
        assert abs(r.carbon_reduction_pct
                   - ref.carbon_reduction_pct) < 1e-3
    caps = [0.74, 0.8]
    pols2 = [CR2(cap_frac=c, outer=2) for c in caps]
    first2 = sweep(fp, pols2, ctx=SolveContext(steps=60))
    got2 = sweep(fp, pols2, ctx=SolveContext(
        steps=30, warm=stack_states([r.state for r in first2])))
    for c, r0, r in zip(caps, first2, got2):
        ref = solve(fp, CR2(cap_frac=c, outer=2),
                    ctx=SolveContext(steps=30, warm=r0.state))
        np.testing.assert_allclose(r.D, ref.D, atol=1e-4)


def test_sweep_warm_cold_stack_is_bitwise_cold(fp):
    """A stacked COLD state through the warm lane is bitwise the cold
    sweep — the `init=` thread adds no numeric drift."""
    import jax.numpy as jnp

    from repro.core.api import stack_states
    from repro.core.engine import EngineState
    from repro.core.fleet_solver import CR1_MU0
    pols = [CR1(lam=lam) for lam in (1.0, 1.5)]
    cold = sweep(fp, pols, ctx=SolveContext(steps=60))
    states = stack_states([
        EngineState.cold(jnp.zeros(fp.usage.shape), mu0=CR1_MU0)
        for _ in pols])
    warm = sweep(fp, pols, ctx=SolveContext(steps=60, warm=states))
    for a, b in zip(cold, warm):
        np.testing.assert_array_equal(a.D, b.D)


def test_sweep_empty_and_nonuniform(fp):
    assert sweep(fp, []) == []
    # non-uniform static knob (CR2.outer) -> loop fallback, same results
    ctx = SolveContext(steps=50)
    got = sweep(fp, [CR2(cap_frac=0.8, outer=1),
                     CR2(cap_frac=0.8, outer=2)], ctx=ctx)
    assert got[0].iters == 50 and got[1].iters == 100


# ---------------------------------------------------------------------------
# Baseline wrappers
# ---------------------------------------------------------------------------
def test_b1_b3_match_closed_form_baselines(fp):
    from repro.core.baselines import b1_adjustments, b3_adjustments
    dp = fp.to_problem()
    np.testing.assert_allclose(solve(fp, B1(F=0.8)).D,
                               b1_adjustments(dp, 0.8), atol=1e-12)
    np.testing.assert_allclose(solve(fp, B3(depth=0.3)).D,
                               b3_adjustments(dp, 0.3), atol=1e-12)


# ---------------------------------------------------------------------------
# Policy objects: registry, resolution, stable cache keys
# ---------------------------------------------------------------------------
def test_registry_names_and_string_solve(fp):
    assert {"cr1", "cr2", "cr3", "b1", "b3"} <= set(POLICY_REGISTRY)
    r = solve(fp, "b1")                       # default-hyper string solve
    np.testing.assert_array_equal(r.D, solve(fp, B1()).D)
    with pytest.raises(ValueError, match="registered policies.*cr1"):
        solve(fp, "cr9")
    with pytest.raises(TypeError, match="FleetProblem"):
        solve(fp.to_problem(), CR1())


def test_resolve_policy_accepts_objects_classes_and_names():
    assert resolve_policy("cr2") == CR2()
    assert resolve_policy(CR1) == CR1()       # class -> default instance
    pl = CR3(tax_frac=0.25)
    assert resolve_policy(pl) is pl
    assert isinstance(pl, DRPolicy)
    with pytest.raises(TypeError, match="DRPolicy"):
        resolve_policy(3.14)


@pytest.mark.parametrize("policy", [
    CR1(lam=1.3), CR2(cap_frac=0.76, outer=4),
    CR3(rho=0.03, tax_frac=0.25, outer=2, clearing_iters=5),
    B1(F=0.8), B3(depth=0.4, max_cut=0.3)])
def test_policy_asdict_round_trip_stable_cache_keys(policy):
    """Hyperparameters are exactly the dataclass fields: asdict
    round-trips through the constructor and json-serializes into a
    stable, order-independent cache key (the fleetcache pattern)."""
    d = dataclasses.asdict(policy)
    assert type(policy)(**d) == policy
    key = json.dumps({"policy": policy.name, **d}, sort_keys=True)
    assert key == json.dumps(
        {"policy": policy.name, **dataclasses.asdict(type(policy)(**d))},
        sort_keys=True)
    # execution concerns never leak into the policy's identity
    assert not ({"mesh", "warm", "donate", "steps"} & set(d))


# ---------------------------------------------------------------------------
# Legacy-shim deprecation contract (ci.sh re-runs this file with
# -W error::DeprecationWarning)
# ---------------------------------------------------------------------------
def test_every_legacy_entry_point_warns_exactly_once(fp):
    # each call inside _shim asserts exactly one DeprecationWarning
    _shim(fs.solve_cr1_fleet, fp, lam=1.4, steps=30)
    _shim(fs.solve_cr1_fleet_sweep, fp, [1.4], steps=30)
    _shim(fs.solve_cr2_fleet, fp, steps=30, outer=1)
    _shim(fs.solve_cr3_fleet, fp, steps=30, outer=1, clearing_iters=1)


def test_new_api_is_deprecation_free(fp):
    with warnings.catch_warnings():
        warnings.simplefilter("error", DeprecationWarning)
        solve(fp, CR1(lam=1.4), ctx=SolveContext(steps=30))
        sweep(fp, [CR1(lam=1.4)], ctx=SolveContext(steps=20))
        solve(fp, B1())
