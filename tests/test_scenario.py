"""Scenario-generation layer (`repro.core.scenario`): registry, stack
shapes/validation/concat, tuple-seeded determinism, and the carbon.py
grid-event hooks the generators randomize."""
import os

import numpy as np
import pytest

from repro.core import carbon
from repro.core.fleet_solver import synthetic_fleet
from repro.core.scenario import (SCENARIO_REGISTRY, CambiumMix, DuckPerturb,
                                 EveningRampSpike, FleetJitter, FlexMixShift,
                                 ForecastRegime, RenewableDrought,
                                 ScenarioGenerator, ScenarioStack,
                                 ZeroMciWindow, resolve_scenarios)


@pytest.fixture(scope="module")
def fleet():
    return synthetic_fleet(5, seed=3)


ALL_GENERATORS = [DuckPerturb, RenewableDrought, EveningRampSpike,
                  ZeroMciWindow, CambiumMix, ForecastRegime, FleetJitter,
                  FlexMixShift]


# ---------------------------------------------------------------------------
# Grid-event hooks (carbon.py)
# ---------------------------------------------------------------------------
def test_apply_drought_fills_the_trough():
    mci = carbon.caiso_2021(48).mci
    out = carbon.apply_drought(mci, day=0, n_days=1, severity=0.8)
    assert out.shape == mci.shape
    # day 0 lifted toward its peak, day 1 untouched
    assert out[:24].min() > mci[:24].min()
    assert np.isclose(out[:24].max(), mci[:24].max())
    np.testing.assert_array_equal(out[24:], mci[24:])
    # severity 1.0 erases the trough entirely
    flat = carbon.apply_drought(mci, day=0, severity=1.0)
    np.testing.assert_allclose(flat[:24], mci[:24].max())


def test_apply_evening_spike_is_local_and_multiplicative():
    mci = carbon.caiso_2021(48).mci
    out = carbon.apply_evening_spike(mci, hour=19, magnitude=1.5, width=1.5)
    assert np.isclose(out[19], 1.5 * mci[19])
    assert out[19] > mci[19]
    np.testing.assert_allclose(out[40:], mci[40:], rtol=1e-6)


def test_apply_zero_window_clamps():
    mci = carbon.caiso_2021(48).mci
    out = carbon.apply_zero_window(mci, start=12, length=3)
    assert (out[12:15] == 0).all()
    np.testing.assert_array_equal(out[:12], mci[:12])
    np.testing.assert_array_equal(out[15:], mci[15:])


# ---------------------------------------------------------------------------
# Registry + generator protocol
# ---------------------------------------------------------------------------
def test_registry_holds_every_generator():
    assert {"duck_perturb", "renewable_drought", "evening_ramp_spike",
            "zero_mci_window", "cambium_mix", "forecast_regime",
            "fleet_jitter", "flex_mix_shift"} <= set(SCENARIO_REGISTRY)
    for cls in ALL_GENERATORS:
        assert SCENARIO_REGISTRY[cls.name] is cls
        assert isinstance(cls(), ScenarioGenerator)


def test_resolve_scenarios_accepts_names_objects_stacks(fleet):
    by_name = resolve_scenarios("duck_perturb", fleet)
    by_obj = resolve_scenarios(DuckPerturb(), fleet)
    np.testing.assert_array_equal(by_name.mci, by_obj.mci)
    assert resolve_scenarios(by_obj, fleet) is by_obj
    with pytest.raises(ValueError, match="duck_perturb"):
        resolve_scenarios("not_a_generator", fleet)
    with pytest.raises(TypeError, match="ScenarioStack"):
        resolve_scenarios(3.14, fleet)


@pytest.mark.parametrize("cls", ALL_GENERATORS)
def test_generators_are_deterministic_and_well_shaped(cls, fleet):
    gen = cls(n_scenarios=4, seed=11)
    a = gen.generate(fleet)
    b = cls(n_scenarios=4, seed=11).generate(fleet)
    assert a.S == 4
    a.validate(fleet)
    assert len(a.labels) == 4
    for f, v in a.overlay_fields().items():
        # bitwise reproducible under the same (seed, s) tuples
        np.testing.assert_array_equal(v, getattr(b, f), err_msg=f)
        assert not np.isnan(v).any()
    # different seeds produce different scenarios
    c = cls(n_scenarios=4, seed=12).generate(fleet)
    assert any(not np.array_equal(v, getattr(c, f))
               for f, v in a.overlay_fields().items())
    # scenarios within a stack differ from each other
    for f, v in a.overlay_fields().items():
        if f == "mci" or cls is not FlexMixShift:
            assert not np.array_equal(v[0], v[1])
            break


@pytest.mark.parametrize("cls", ALL_GENERATORS)
def test_generators_reject_empty_ensembles(cls):
    with pytest.raises(ValueError, match="n_scenarios"):
        cls(n_scenarios=0)
    with pytest.raises(ValueError, match="n_scenarios"):
        cls(n_scenarios=-1)


def test_mci_generators_stay_nonnegative(fleet):
    for cls in (DuckPerturb, RenewableDrought, EveningRampSpike,
                ZeroMciWindow, CambiumMix, ForecastRegime):
        st = cls(n_scenarios=6, seed=0).generate(fleet)
        assert st.mci.shape == (6, fleet.T)
        assert (st.mci >= 0).all(), cls.name


def test_fleet_generators_overlay_per_workload_fields(fleet):
    st = FleetJitter(n_scenarios=3, seed=0).generate(fleet)
    assert st.usage.shape == (3, fleet.W, fleet.T)
    assert st.entitlement.shape == (3, fleet.W)
    assert (st.usage > 0).all() and (st.entitlement > 0).all()
    mix = FlexMixShift(n_scenarios=3, seed=0).generate(fleet)
    assert mix.upper.shape == (3, fleet.W, fleet.T)
    # the operational cap is a fraction of that scenario's usage
    assert (mix.upper <= mix.usage + 1e-12).all()


# ---------------------------------------------------------------------------
# ScenarioStack mechanics
# ---------------------------------------------------------------------------
def test_stack_validation_and_problem_materialization(fleet):
    st = DuckPerturb(n_scenarios=3, seed=0).generate(fleet)
    p1 = st.problem(fleet, 1)
    np.testing.assert_array_equal(p1.mci, st.mci[1])
    np.testing.assert_array_equal(p1.usage, fleet.usage)  # not overlaid
    with pytest.raises(ValueError, match="shape"):
        ScenarioStack(mci=np.ones((3, fleet.T + 1))).validate(fleet)
    with pytest.raises(ValueError, match="disagree|empty"):
        ScenarioStack(mci=np.ones((3, 48)), usage=np.ones((2, 5, 48)))
    with pytest.raises(ValueError, match="disagree|empty"):
        ScenarioStack()


def test_stack_concat_mixes_generators(fleet):
    a = DuckPerturb(n_scenarios=2, seed=0).generate(fleet)
    b = FleetJitter(n_scenarios=3, seed=0).generate(fleet)
    mix = ScenarioStack.concat([a, b], fleet)
    mix.validate(fleet)
    assert mix.S == 5
    # a's scenarios keep base usage; b's keep base mci
    np.testing.assert_array_equal(mix.usage[0], fleet.usage)
    np.testing.assert_array_equal(mix.mci[2:],
                                  np.broadcast_to(fleet.mci, (3, fleet.T)))
    np.testing.assert_array_equal(mix.mci[:2], a.mci)
    np.testing.assert_array_equal(mix.usage[2:], b.usage)
    assert mix.labels == a.labels + b.labels
    # sequence form of resolve_scenarios concats the same way
    mix2 = resolve_scenarios([a, b], fleet)
    np.testing.assert_array_equal(mix.mci, mix2.mci)


def test_forecast_regime_streams_match_generate(fleet):
    reg = ForecastRegime(n_scenarios=3, seed=4)
    streams = reg.streams(fleet, n_ticks=5)
    assert len(streams) == 3
    sigmas = {st.revision_sigma for st in streams}
    assert len(sigmas) == 3            # distinct regimes
    for st in streams:
        assert st.horizon == fleet.T
        assert st.n_ticks >= 5
    # generate() serves each stream's tick-0 forecast
    stack = reg.generate(fleet)
    np.testing.assert_allclose(stack.mci[0], streams[0].forecast(0))


# ---------------------------------------------------------------------------
# carbon.projection tuple-seeding regression (ISSUE-5 satellite)
# ---------------------------------------------------------------------------
def test_projection_tuple_seeding_kills_additive_collisions():
    """Regression: `default_rng(seed + idx)` collided distinct
    (seed, state) pairs — STATES[10]="NY" at seed=8 and STATES[17]="MA"
    at seed=1 both seeded rng(18) and (neither being in the solar_rank
    table) drew identical penetration AND noise, i.e. identical series.
    Tuple seeding keeps every (seed, year, state) stream distinct."""
    a = carbon.projection(2050, "NY", seed=8)
    b = carbon.projection(2050, "MA", seed=1)
    assert not np.allclose(a.mci, b.mci)
    # same (seed, year, state) stays bitwise reproducible
    np.testing.assert_array_equal(a.mci,
                                  carbon.projection(2050, "NY", seed=8).mci)
    # the same state across years must differ too
    y24 = carbon.projection(2024, "NY", seed=8)
    assert not np.allclose(a.mci, y24.mci)


def test_projection_unlisted_state_is_process_stable():
    """States outside `STATES` must hash stably (crc32), not with the
    per-process-salted builtin hash(): the same (seed, year, state) has
    to reproduce bitwise across interpreter runs."""
    import subprocess
    import sys
    code = ("import os, sys; sys.path.insert(0, 'src'); "
            "from repro.core.carbon import projection; "
            "print(projection(2050, 'NJ', seed=0).mci.tobytes().hex())")
    outs = set()
    for hashseed in ("0", "5"):
        env = dict(os.environ, PYTHONHASHSEED=hashseed)
        outs.add(subprocess.run(
            [sys.executable, "-c", code], capture_output=True, text=True,
            env=env, cwd=os.path.join(os.path.dirname(__file__), ".."),
            check=True).stdout.strip())
    assert len(outs) == 1, "projection('NJ') varies with PYTHONHASHSEED"
    # and an unlisted state cannot collide onto a listed state's stream
    assert not np.allclose(carbon.projection(2050, "NJ", seed=0).mci,
                           carbon.projection(2050, "NY", seed=0).mci)
