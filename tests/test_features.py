"""Table-IV feature tests: hand-computed values + hypothesis properties."""
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis_compat import given, hnp, settings, st

from repro.core import features as feat


def test_waiting_time_power_hand_computed():
    # d = [2, -1, 3]: cumsums [2, 1, 4] all positive -> 7.
    d = jnp.asarray([2.0, -1.0, 3.0])
    assert float(feat.waiting_time_power(d)) == pytest.approx(7.0)
    # d = [-5, 1, 1]: cumsums [-5, -4, -3] -> positive parts all 0.
    d = jnp.asarray([-5.0, 1.0, 1.0])
    assert float(feat.waiting_time_power(d)) == pytest.approx(0.0)


def test_waiting_time_jobs_hand_computed():
    d = jnp.asarray([1.0, -1.0])
    u = jnp.asarray([2.0, 2.0])
    j = jnp.asarray([4.0, 4.0])
    # rates = [2, -2]; cumsum [2, 0]; positive parts sum = 2.
    assert float(feat.waiting_time_jobs(d, u, j)) == pytest.approx(2.0)


def test_num_jobs_delayed_ignores_boosts():
    d = jnp.asarray([1.0, -3.0])
    u = jnp.ones(2)
    j = jnp.ones(2) * 5
    assert float(feat.num_jobs_delayed(d, u, j)) == pytest.approx(5.0)


def test_total_tardiness_lags_by_slo():
    u = jnp.ones(6)
    j = jnp.ones(6)
    d = jnp.asarray([1.0, 0, 0, 0, 0, 0])
    # With SLO=4, only cum terms up to index T-1-4 contribute.
    t = float(feat.total_tardiness(d, u, j, slo_hours=4))
    assert t == pytest.approx(2.0)  # cum=[1,1] over the 2 surviving hours
    assert float(feat.total_tardiness(d, u, j, slo_hours=6)) == 0.0


def test_feature_matrix_shape_and_selection():
    d = jnp.ones((5, 48))
    u = jnp.ones((5, 48))
    j = jnp.ones((5, 48))
    X = feat.feature_matrix(d, u, j)
    assert X.shape == (5, 5)
    X4 = feat.feature_matrix(d, u, j, include_tardiness=False)
    assert X4.shape == (5, 4)
    sel = feat.selected_features("AITraining", d, u, j)
    assert sel.shape == (5, 2)


finite_d = hnp.arrays(np.float64, (24,),
                      elements=st.floats(-10, 10, allow_nan=False))


@given(finite_d)
@settings(max_examples=30, deadline=None)
def test_features_nonnegative(d):
    """All Table-IV features are positive-part constructions ⇒ ≥ 0."""
    dj = jnp.asarray(d)
    u = jnp.ones(24) * 2.0
    j = jnp.ones(24) * 3.0
    assert float(feat.waiting_time_power(dj)) >= 0
    assert float(feat.waiting_time_jobs(dj, u, j)) >= 0
    assert float(feat.waiting_time_squared(dj, u, j)) >= 0
    assert float(feat.num_jobs_delayed(dj, u, j)) >= 0
    assert float(feat.total_tardiness(dj, u, j, 4)) >= 0


@given(finite_d)
@settings(max_examples=30, deadline=None)
def test_pure_curtailment_monotone(d):
    """Scaling a pure-curtailment vector up never decreases queue features."""
    d = np.abs(d)
    u = jnp.ones(24) * 20.0
    j = jnp.ones(24) * 3.0
    f1 = float(feat.waiting_time_power(jnp.asarray(d)))
    f2 = float(feat.waiting_time_power(jnp.asarray(2 * d)))
    assert f2 >= f1 - 1e-9


@given(finite_d)
@settings(max_examples=20, deadline=None)
def test_smooth_upper_bounds_relu(d):
    """Softplus smoothing upper-bounds the exact positive part."""
    dj = jnp.asarray(d)
    exact = float(feat.waiting_time_power(dj, smooth=0.0))
    smooth = float(feat.waiting_time_power(dj, smooth=0.5))
    assert smooth >= exact - 1e-6


def test_zero_adjustment_zero_features():
    d = jnp.zeros(48)
    u = jnp.ones(48)
    j = jnp.ones(48)
    X = feat.feature_matrix(d, u, j)
    assert float(jnp.abs(X).max()) == 0.0
