"""Multi-region fleet engine: (region × workload) data model, per-region
MCI pricing, cross-region load migration, and R=1 degeneracy.

Acceptance (ISSUE 7): an R=3 fleet tracking three Cambium state mixes
beats the best single-signal solve on fleet-wide carbon at equal total
curtailment; R=1 is bitwise-identical to the single-region engine; a
zero-bandwidth topology decomposes into independent per-region solves.
The 2-D mesh parity lanes live in tests/test_fleet_sharding.py."""
import dataclasses

import numpy as np
import pytest

from repro.core.api import CR1, CR2, CR3, SolveContext, solve, sweep
from repro.core.carbon import regional_traces
from repro.core.fleet_solver import (RegionTopology, _single_region_view,
                                     regional_fleet, synthetic_fleet,
                                     synthetic_regional_fleet)
from repro.core.migration import MigrationPlan, fleet_migration


# ---------------------------------------------------------------------------
# Data model
# ---------------------------------------------------------------------------
def test_region_topology_validates_shapes():
    with pytest.raises(ValueError, match="cost/bandwidth"):
        RegionTopology(cost=np.zeros((2, 2)),
                       bandwidth=np.zeros((3, 3))).validate(2, 24)
    with pytest.raises(ValueError, match="ceiling"):
        RegionTopology(cost=np.zeros((2, 2)), bandwidth=np.zeros((2, 2)),
                       ceiling=np.zeros(3)).validate(2, 24)
    RegionTopology(cost=np.zeros((2, 2)), bandwidth=np.zeros((2, 2)),
                   ceiling=np.zeros((2, 24))).validate(2, 24)


def test_regional_fleet_composes_and_validates():
    mcis, labels = regional_traces(["CA", "TX"], 2050, hours=48)
    assert mcis.shape == (2, 48) and len(labels) == 2
    fleets = [synthetic_fleet(3, seed=0), synthetic_fleet(4, seed=1)]
    p = regional_fleet(fleets, mcis)
    assert p.is_multiregion and p.R == 2 and p.W == 7
    np.testing.assert_array_equal(np.asarray(p.region),
                                  [0, 0, 0, 1, 1, 1, 1])
    with pytest.raises(ValueError, match="one trace per fleet"):
        regional_fleet(fleets, mcis[0])
    with pytest.raises(ValueError, match="single-region"):
        regional_fleet([p], mcis[:1])


def test_single_region_view_canonicalizes_degenerate_r1():
    fp = synthetic_fleet(4, seed=2)
    pr = regional_fleet([fp], np.asarray(fp.mci)[None])
    assert pr.is_multiregion and pr.R == 1
    view = _single_region_view(pr)
    assert not view.is_multiregion
    assert view.region is None and view.topology is None
    np.testing.assert_array_equal(np.asarray(view.mci),
                                  np.asarray(pr.mci)[0])


# ---------------------------------------------------------------------------
# R=1 bitwise parity with the single-region engine
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("policy", [
    CR1(lam=1.45), CR2(cap_frac=0.8, outer=2),
    CR3(outer=2, clearing_iters=2)])
def test_r1_regional_solve_is_bitwise_single_region(policy):
    """The degenerate R=1 fleet takes the exact pre-refactor code path:
    D, reported metrics, and engine state are byte-for-byte equal."""
    fp = synthetic_fleet(5, seed=3)
    pr = regional_fleet([fp], np.asarray(fp.mci)[None])
    ctx = SolveContext(steps=100)
    a = solve(fp, policy, ctx=ctx)
    b = solve(pr, policy, ctx=ctx)
    np.testing.assert_array_equal(a.D, b.D)
    assert a.carbon_reduction_pct == b.carbon_reduction_pct
    assert a.total_penalty_pct == b.total_penalty_pct
    np.testing.assert_array_equal(np.asarray(a.state.x),
                                  np.asarray(b.state.x))


def test_r1_regional_sweep_is_bitwise_single_region():
    fp = synthetic_fleet(5, seed=3)
    pr = regional_fleet([fp], np.asarray(fp.mci)[None])
    pols = [CR1(lam=lam) for lam in (1.0, 1.45)]
    ctx = SolveContext(steps=80)
    for a, b in zip(sweep(fp, pols, ctx=ctx), sweep(pr, pols, ctx=ctx)):
        np.testing.assert_array_equal(a.D, b.D)


# ---------------------------------------------------------------------------
# bandwidth=0: the joint solve decomposes into per-region solves
# ---------------------------------------------------------------------------
@pytest.mark.slow
@pytest.mark.parametrize("policy", [
    CR1(lam=1.45), CR2(cap_frac=0.8, outer=2)])
def test_zero_bandwidth_decomposes_into_per_region_solves(policy):
    """Per-region normalization makes the joint multi-region problem
    row-separable across regions: with no migration the R=2 solve must
    reproduce the two independent single-region solves."""
    mcis, _ = regional_traces(["CA", "TX"], 2050, hours=48)
    fleets = [synthetic_fleet(5, seed=3), synthetic_fleet(6, seed=7)]
    joint = regional_fleet(fleets, mcis)       # no topology: no migration
    assert joint.topology is None
    ctx = SolveContext(steps=300)
    res = solve(joint, policy, ctx=ctx)
    assert "migration" not in res.extras
    region = np.asarray(joint.region)
    for r, f in enumerate(fleets):
        indep = solve(dataclasses.replace(f, mci=mcis[r]), policy, ctx=ctx)
        np.testing.assert_allclose(np.asarray(res.D)[region == r],
                                   np.asarray(indep.D), atol=1e-4)


# ---------------------------------------------------------------------------
# Migration: feasibility, accounting, and the solve() credit
# ---------------------------------------------------------------------------
@pytest.mark.slow
def test_migration_plan_is_feasible_and_credited():
    """`solve()` on a topology with positive bandwidth leaves D untouched
    (equal total curtailment) and credits the net migration saving; the
    plan respects every link cap, supply, and headroom exactly."""
    p = synthetic_regional_fleet(9, ["CA", "TX", "NY"], hours=24, seed=0)
    ctx = SolveContext(steps=300)
    res = solve(p, CR1(lam=1.45), ctx=ctx)
    off = solve(dataclasses.replace(p, topology=None), CR1(lam=1.45),
                ctx=ctx)
    np.testing.assert_array_equal(res.D, off.D)
    plan = res.extras["migration"]
    assert isinstance(plan, MigrationPlan)
    assert plan.net_saved > 0.0
    wmci = np.asarray(p.mci)[np.asarray(p.region)]
    base = float((np.asarray(p.usage) * wmci).sum())
    assert res.carbon_reduction_pct == pytest.approx(
        off.carbon_reduction_pct + 100.0 * plan.net_saved / base)
    # exact feasibility after the repair pass
    y = plan.y
    bw = np.asarray(p.topology.bandwidth)
    assert (y >= 0.0).all()
    assert (y <= bw[:, :, None] + 1e-9).all()
    assert np.abs(np.trace(y.sum(axis=2))) == 0.0    # no self-flows
    residual = np.asarray(p.usage) - np.asarray(res.D)
    is_batch = np.asarray(p.is_batch, bool)
    movable = np.zeros((p.R, p.T))
    np.add.at(movable, np.asarray(p.region)[is_batch],
              np.maximum(residual[is_batch], 0.0))
    assert (y.sum(axis=1) <= movable + 1e-6).all()   # supply caps
    # the same plan comes from the public helper
    again = fleet_migration(p, np.asarray(res.D))
    np.testing.assert_allclose(again.y, y, atol=1e-12)


def test_zero_bandwidth_topology_yields_zero_plan():
    p = synthetic_regional_fleet(
        6, ["CA", "TX"], hours=24, seed=1,
        topology=RegionTopology(cost=np.full((2, 2), 2.0),
                                bandwidth=np.zeros((2, 2))))
    res = solve(p, CR1(lam=1.45), ctx=SolveContext(steps=150))
    assert "migration" not in res.extras
    plan = fleet_migration(p, np.asarray(res.D))
    assert plan.moved_total == 0.0 and plan.net_saved == 0.0


# ---------------------------------------------------------------------------
# Acceptance: R=3 fleet beats the best single-signal solve
# ---------------------------------------------------------------------------
@pytest.mark.slow
def test_r3_regional_beats_best_single_signal_solve():
    """Headline: pricing each region on its own Cambium trace (plus
    migration) eliminates more fleet-wide carbon than pricing the whole
    fleet on ANY single region's trace, at equal total curtailment.

    `utc_offsets="auto"` rolls each state trace onto the shared UTC
    clock the fleet actually runs on — the duck-curve troughs land at
    different hours per region, which is exactly the timing diversity a
    single shared signal cannot express.  Comparison is at equal total
    curtailment: a feasible plan scaled down uniformly stays feasible
    (the box shrinks toward 0 and batch day-sums stay zero), so each
    single-signal solve is down-scaled to the multi solve's curtailment
    and its realized reduction scales with it.
    """
    base_p = synthetic_regional_fleet(9, ["CA", "TX", "NY"], hours=48,
                                      seed=0, utc_offsets="auto")
    ent = float(np.asarray(base_p.entitlement).sum())
    bw = np.full((3, 3), 0.15 * ent / 2)
    np.fill_diagonal(bw, 0.0)
    p = dataclasses.replace(
        base_p, topology=RegionTopology(cost=np.full((3, 3), 1.0),
                                        bandwidth=bw))
    wmci = np.asarray(p.mci)[np.asarray(p.region)]
    base = float((np.asarray(p.usage) * wmci).sum())
    ctx = SolveContext(steps=400)
    multi = solve(p, CR1(lam=1.45), ctx=ctx)
    multi_curtail = float(np.asarray(multi.D).sum())
    assert 100.0 * multi.extras["migration"].net_saved / base > 1.0
    best = -np.inf
    for r in range(p.R):
        single = dataclasses.replace(p, mci=np.asarray(p.mci)[r],
                                     region=None, topology=None)
        rs = solve(single, CR1(lam=1.45), ctx=ctx)
        realized = 100.0 * float((np.asarray(rs.D) * wmci).sum()) / base
        curtail = float(np.asarray(rs.D).sum())
        # every single signal curtails at least as much as the multi
        # solve here, so scaling down to multi_curtail is feasible
        assert curtail >= multi_curtail
        best = max(best, realized * multi_curtail / curtail)
    assert multi.carbon_reduction_pct > best + 0.5


# ---------------------------------------------------------------------------
# RegionReductions layer (ISSUE 8): one reduction vocabulary for every lane
# ---------------------------------------------------------------------------
def test_region_totals_matches_manual_scatter():
    from repro.core.regional import region_totals
    p = synthetic_regional_fleet(7, ["CA", "TX"], hours=24, seed=3)
    region = np.asarray(p.region)
    vals = np.asarray(p.usage)
    ref = np.zeros((p.R, p.T))
    np.add.at(ref, region, vals)
    np.testing.assert_allclose(region_totals(region, vals, p.R), ref)
    ref1 = np.bincount(region, weights=vals[:, 0], minlength=p.R)
    np.testing.assert_allclose(region_totals(region, vals[:, 0], p.R), ref1)
    # masked subsets stay index-aligned (the migration `movable` idiom)
    m = np.asarray(p.is_batch, bool)
    refm = np.zeros((p.R, p.T))
    np.add.at(refm, region[m], vals[m])
    np.testing.assert_allclose(region_totals(region[m], vals[m], p.R), refm)


def test_regional_norms_decompose_per_region():
    """Per-region CR1 norms scattered to rows equal each region's
    standalone single-region scalars — the algebra behind the
    bandwidth=0 decomposition."""
    import dataclasses as dc

    from repro.core.fleet_solver import _single_region_view
    from repro.core.regional import cr1_norms, pad_row_norms, CR1_NORM_FILLS
    p = synthetic_regional_fleet(8, ["CA", "TX"], hours=24, seed=4)
    pen_w, car_w, step_w = (np.asarray(a) for a in cr1_norms(p))
    region = np.asarray(p.region)
    for r in range(p.R):
        rows = region == r
        sub = _single_region_view(dc.replace(
            p, usage=np.asarray(p.usage)[rows],
            entitlement=np.asarray(p.entitlement)[rows],
            jobs=np.asarray(p.jobs)[rows],
            upper=None if p.upper is None else np.asarray(p.upper)[rows],
            rts_coeffs=np.asarray(p.rts_coeffs)[rows],
            betas=np.asarray(p.betas)[rows], k=np.asarray(p.k)[rows],
            x2_kind=np.asarray(p.x2_kind)[rows],
            is_batch=np.asarray(p.is_batch)[rows],
            mci=np.asarray(p.mci)[r][None], region=np.zeros(rows.sum(), int),
            topology=None))
        s_pen, s_car, s_step = (np.asarray(a) for a in cr1_norms(sub))
        np.testing.assert_allclose(pen_w[rows], s_pen, rtol=1e-6)
        np.testing.assert_allclose(car_w[rows], s_car, rtol=1e-6)
        np.testing.assert_allclose(step_w[rows, 0], s_step, rtol=1e-6)
    # pad rows are inert: zero weights, unit step divisor
    padded = pad_row_norms((pen_w, car_w, step_w), p.W + 3, CR1_NORM_FILLS)
    assert np.all(np.asarray(padded[0])[p.W:] == 0.0)
    assert np.all(np.asarray(padded[1])[p.W:] == 0.0)
    assert np.all(np.asarray(padded[2])[p.W:] == 1.0)


# ---------------------------------------------------------------------------
# stack_states: multi-region warm refinement sweeps (ISSUE 8 satellite)
# ---------------------------------------------------------------------------
def test_stack_states_r2_cold_stack_roundtrip_and_warm_sweep():
    """R=2 cold-stack regression: stacking per-lane states is a bitwise
    round-trip, and the stacked warm refinement sweep matches per-policy
    warm solves (CR1's vmap lane is bitwise vs solo on one device)."""
    import jax

    from repro.core.api import stack_states
    p = dataclasses.replace(
        synthetic_regional_fleet(10, ["CA", "TX"], hours=24, seed=1),
        topology=None)
    pols = [CR1(lam=1.0), CR1(lam=1.45)]
    cold = sweep(p, pols, ctx=SolveContext(steps=100))
    st = stack_states([r.state for r in cold])
    for i, r in enumerate(cold):
        for got, want in zip(jax.tree_util.tree_leaves(st),
                             jax.tree_util.tree_leaves(r.state)):
            np.testing.assert_array_equal(np.asarray(got)[i],
                                          np.asarray(want))
    warm = sweep(p, pols, ctx=SolveContext(steps=40, warm=st))
    for pl, w, c in zip(pols, warm, cold):
        solo = solve(p, pl, ctx=SolveContext(steps=40, warm=c.state))
        np.testing.assert_array_equal(w.D, solo.D)
        assert w.carbon_reduction_pct == solo.carbon_reduction_pct


def test_stack_states_rejects_mismatched_lanes():
    from repro.core.api import stack_states
    p2 = dataclasses.replace(
        synthetic_regional_fleet(10, ["CA", "TX"], hours=24, seed=1),
        topology=None)
    p1 = synthetic_fleet(4, seed=0, hours=24)
    a = solve(p2, CR1(lam=1.45), ctx=SolveContext(steps=30))
    b = solve(p1, CR1(lam=1.45), ctx=SolveContext(steps=30))
    with pytest.raises(ValueError, match="stack_states"):
        stack_states([a.state, b.state])
    with pytest.raises(ValueError, match="at least one"):
        stack_states([])


# ---------------------------------------------------------------------------
# Coupled in-loop migration (ISSUE 8 tentpole)
# ---------------------------------------------------------------------------
@pytest.mark.slow
def test_coupled_migration_matches_or_beats_post_stage():
    """Headline: `SolveContext(coupled_migration=True)` on the R=3
    CA/TX/NY fleet never loses to the host-side post-stage on fleet-wide
    carbon at equal total curtailment — and for CR1 at these settings the
    coupled candidate actually wins and carries a feasible plan."""
    from repro.core.migration import region_aggregates
    p = synthetic_regional_fleet(60, ["CA", "TX", "NY"], hours=48, seed=7)
    ctx = SolveContext(steps=300)
    cctx = dataclasses.replace(ctx, coupled_migration=True)
    for pol in (CR1(lam=1.45), CR2(cap_frac=0.8, outer=2)):
        post = solve(p, pol, ctx=ctx)
        coup = solve(p, pol, ctx=cctx)
        assert coup.carbon_reduction_pct >= post.carbon_reduction_pct
        tot_post = float(np.asarray(post.D).sum())
        tot_coup = float(np.asarray(coup.D).sum())
        assert abs(tot_coup - tot_post) <= 2e-3 * max(abs(tot_post), 1.0)
        if coup.extras.get("coupled_migration"):
            plan = coup.extras["migration"]
            y = plan.y
            bw = np.asarray(p.topology.bandwidth)
            assert (y >= 0.0).all()
            assert (y <= bw[:, :, None]).all()
            assert np.abs(np.trace(y.sum(axis=2))) == 0.0
            movable, headroom = region_aggregates(p, np.asarray(coup.D))
            assert (y.sum(axis=1) <= movable * (1 + 1e-9) + 1e-9).all()
            assert (y.sum(axis=0) <= headroom + 1e-9).all()
    # the CR1 coupled candidate wins outright at these settings
    cr1 = solve(p, CR1(lam=1.45), ctx=cctx)
    assert cr1.extras.get("coupled_migration") is True
    assert cr1.carbon_reduction_pct > solve(
        p, CR1(lam=1.45), ctx=ctx).carbon_reduction_pct


def test_coupled_migration_zero_bandwidth_is_pure_solve():
    """bandwidth=0 leaves no links for the coupled solve — it must fall
    back to the plain (migration-free) result bitwise, preserving the
    per-region decomposition."""
    top = RegionTopology(cost=np.full((2, 2), 2.0),
                         bandwidth=np.zeros((2, 2)))
    p = synthetic_regional_fleet(6, ["CA", "TX"], hours=24, seed=1,
                                 topology=top)
    ctx = SolveContext(steps=120)
    plain = solve(p, CR1(lam=1.45), ctx=ctx)
    coup = solve(p, CR1(lam=1.45),
                 ctx=dataclasses.replace(ctx, coupled_migration=True))
    np.testing.assert_array_equal(plain.D, coup.D)
    assert plain.carbon_reduction_pct == coup.carbon_reduction_pct
    assert "migration" not in coup.extras


# ---------------------------------------------------------------------------
# Migration edge cases (ISSUE 8 satellite)
# ---------------------------------------------------------------------------
def test_single_region_topology_is_exact_noop():
    """A degenerate 1-region topology (even with positive self-bandwidth)
    has no off-diagonal links: solve() with and without it — post-stage
    or coupled — is bitwise the plain single-region solve."""
    fp = synthetic_fleet(5, seed=3)
    pr = regional_fleet([fp], np.asarray(fp.mci)[None])
    top = RegionTopology(cost=np.zeros((1, 1)), bandwidth=np.ones((1, 1)))
    pt = dataclasses.replace(pr, topology=top)
    ctx = SolveContext(steps=120)
    a = solve(pr, CR1(lam=1.45), ctx=ctx)
    b = solve(pt, CR1(lam=1.45), ctx=ctx)
    c = solve(pt, CR1(lam=1.45),
              ctx=dataclasses.replace(ctx, coupled_migration=True))
    np.testing.assert_array_equal(a.D, b.D)
    np.testing.assert_array_equal(a.D, c.D)
    assert a.carbon_reduction_pct == b.carbon_reduction_pct
    assert a.carbon_reduction_pct == c.carbon_reduction_pct
    assert "migration" not in b.extras and "migration" not in c.extras
    assert fleet_migration(pt, np.asarray(b.D)).moved_total == 0.0


def test_toll_dominated_links_are_never_used():
    """Links whose toll meets or exceeds the maximum carbon spread can
    never be profitable: the planner moves nothing through them, in the
    post-stage and in the coupled solve alike."""
    from repro.core.migration import plan_migration
    base = synthetic_regional_fleet(6, ["CA", "TX"], hours=24, seed=1)
    mci = np.asarray(base.mci, float)
    spread = float(np.abs(mci[0] - mci[1]).max())
    top = RegionTopology(cost=np.full((2, 2), spread),
                         bandwidth=np.full((2, 2), 1e3))
    p = dataclasses.replace(base, topology=top)
    plan = plan_migration(mci, np.ones((2, base.T)),
                          np.full((2, base.T), np.inf), top)
    assert plan.moved_total == 0.0 and plan.net_saved == 0.0
    res = solve(p, CR1(lam=1.45), ctx=SolveContext(steps=120))
    off = solve(dataclasses.replace(p, topology=None), CR1(lam=1.45),
                ctx=SolveContext(steps=120))
    if "migration" in res.extras:
        assert res.extras["migration"].moved_total == 0.0
    assert res.carbon_reduction_pct == off.carbon_reduction_pct
    coup = solve(p, CR1(lam=1.45),
                 ctx=SolveContext(steps=120, coupled_migration=True))
    if "migration" in coup.extras:
        assert coup.extras["migration"].moved_total == 0.0
    assert coup.carbon_reduction_pct >= res.carbon_reduction_pct


def test_repair_respects_caps_under_adversarial_rounding():
    """`_repair` projects an over-cap AL iterate (tiny epsilon overshoots
    AND gross violations) onto the exact constraint set: link caps hold
    exactly, supply/headroom to float rounding, unprofitable links drop
    to zero."""
    from repro.core.migration import _repair
    rng = np.random.default_rng(0)
    R, T = 3, 8
    mci = rng.uniform(100.0, 500.0, (R, T))
    cost = rng.uniform(0.0, 50.0, (R, R))
    np.fill_diagonal(cost, 0.0)
    margin = mci[:, None, :] - mci[None, :, :] - cost[:, :, None]
    bw = rng.uniform(0.0, 2.0, (R, R))
    np.fill_diagonal(bw, 0.0)
    cap = np.broadcast_to(bw[:, :, None], (R, R, T)).copy()
    movable = rng.uniform(0.0, 1.5, (R, T))
    headroom = rng.uniform(0.0, 1.0, (R, T))
    y = cap * (1.0 + 1e-7) + rng.uniform(0.0, 1.0, cap.shape)
    out = _repair(y, margin, cap, movable, headroom)
    assert (out >= 0.0).all()
    assert (out <= cap).all()                       # link caps: exact
    assert (out[margin <= 0.0] == 0.0).all()        # unprofitable: dropped
    assert (out.sum(axis=1) <= movable * (1 + 1e-9) + 1e-12).all()
    assert (out.sum(axis=0) <= headroom * (1 + 1e-9) + 1e-12).all()
