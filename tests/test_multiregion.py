"""Multi-region fleet engine: (region × workload) data model, per-region
MCI pricing, cross-region load migration, and R=1 degeneracy.

Acceptance (ISSUE 7): an R=3 fleet tracking three Cambium state mixes
beats the best single-signal solve on fleet-wide carbon at equal total
curtailment; R=1 is bitwise-identical to the single-region engine; a
zero-bandwidth topology decomposes into independent per-region solves.
The 2-D mesh parity lanes live in tests/test_fleet_sharding.py."""
import dataclasses

import numpy as np
import pytest

from repro.core.api import CR1, CR2, CR3, SolveContext, solve, sweep
from repro.core.carbon import regional_traces
from repro.core.fleet_solver import (RegionTopology, _single_region_view,
                                     regional_fleet, synthetic_fleet,
                                     synthetic_regional_fleet)
from repro.core.migration import MigrationPlan, fleet_migration


# ---------------------------------------------------------------------------
# Data model
# ---------------------------------------------------------------------------
def test_region_topology_validates_shapes():
    with pytest.raises(ValueError, match="cost/bandwidth"):
        RegionTopology(cost=np.zeros((2, 2)),
                       bandwidth=np.zeros((3, 3))).validate(2, 24)
    with pytest.raises(ValueError, match="ceiling"):
        RegionTopology(cost=np.zeros((2, 2)), bandwidth=np.zeros((2, 2)),
                       ceiling=np.zeros(3)).validate(2, 24)
    RegionTopology(cost=np.zeros((2, 2)), bandwidth=np.zeros((2, 2)),
                   ceiling=np.zeros((2, 24))).validate(2, 24)


def test_regional_fleet_composes_and_validates():
    mcis, labels = regional_traces(["CA", "TX"], 2050, hours=48)
    assert mcis.shape == (2, 48) and len(labels) == 2
    fleets = [synthetic_fleet(3, seed=0), synthetic_fleet(4, seed=1)]
    p = regional_fleet(fleets, mcis)
    assert p.is_multiregion and p.R == 2 and p.W == 7
    np.testing.assert_array_equal(np.asarray(p.region),
                                  [0, 0, 0, 1, 1, 1, 1])
    with pytest.raises(ValueError, match="one trace per fleet"):
        regional_fleet(fleets, mcis[0])
    with pytest.raises(ValueError, match="single-region"):
        regional_fleet([p], mcis[:1])


def test_single_region_view_canonicalizes_degenerate_r1():
    fp = synthetic_fleet(4, seed=2)
    pr = regional_fleet([fp], np.asarray(fp.mci)[None])
    assert pr.is_multiregion and pr.R == 1
    view = _single_region_view(pr)
    assert not view.is_multiregion
    assert view.region is None and view.topology is None
    np.testing.assert_array_equal(np.asarray(view.mci),
                                  np.asarray(pr.mci)[0])


# ---------------------------------------------------------------------------
# R=1 bitwise parity with the single-region engine
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("policy", [
    CR1(lam=1.45), CR2(cap_frac=0.8, outer=2),
    CR3(outer=2, clearing_iters=2)])
def test_r1_regional_solve_is_bitwise_single_region(policy):
    """The degenerate R=1 fleet takes the exact pre-refactor code path:
    D, reported metrics, and engine state are byte-for-byte equal."""
    fp = synthetic_fleet(5, seed=3)
    pr = regional_fleet([fp], np.asarray(fp.mci)[None])
    ctx = SolveContext(steps=100)
    a = solve(fp, policy, ctx=ctx)
    b = solve(pr, policy, ctx=ctx)
    np.testing.assert_array_equal(a.D, b.D)
    assert a.carbon_reduction_pct == b.carbon_reduction_pct
    assert a.total_penalty_pct == b.total_penalty_pct
    np.testing.assert_array_equal(np.asarray(a.state.x),
                                  np.asarray(b.state.x))


def test_r1_regional_sweep_is_bitwise_single_region():
    fp = synthetic_fleet(5, seed=3)
    pr = regional_fleet([fp], np.asarray(fp.mci)[None])
    pols = [CR1(lam=lam) for lam in (1.0, 1.45)]
    ctx = SolveContext(steps=80)
    for a, b in zip(sweep(fp, pols, ctx=ctx), sweep(pr, pols, ctx=ctx)):
        np.testing.assert_array_equal(a.D, b.D)


# ---------------------------------------------------------------------------
# bandwidth=0: the joint solve decomposes into per-region solves
# ---------------------------------------------------------------------------
@pytest.mark.slow
@pytest.mark.parametrize("policy", [
    CR1(lam=1.45), CR2(cap_frac=0.8, outer=2)])
def test_zero_bandwidth_decomposes_into_per_region_solves(policy):
    """Per-region normalization makes the joint multi-region problem
    row-separable across regions: with no migration the R=2 solve must
    reproduce the two independent single-region solves."""
    mcis, _ = regional_traces(["CA", "TX"], 2050, hours=48)
    fleets = [synthetic_fleet(5, seed=3), synthetic_fleet(6, seed=7)]
    joint = regional_fleet(fleets, mcis)       # no topology: no migration
    assert joint.topology is None
    ctx = SolveContext(steps=300)
    res = solve(joint, policy, ctx=ctx)
    assert "migration" not in res.extras
    region = np.asarray(joint.region)
    for r, f in enumerate(fleets):
        indep = solve(dataclasses.replace(f, mci=mcis[r]), policy, ctx=ctx)
        np.testing.assert_allclose(np.asarray(res.D)[region == r],
                                   np.asarray(indep.D), atol=1e-4)


# ---------------------------------------------------------------------------
# Migration: feasibility, accounting, and the solve() credit
# ---------------------------------------------------------------------------
@pytest.mark.slow
def test_migration_plan_is_feasible_and_credited():
    """`solve()` on a topology with positive bandwidth leaves D untouched
    (equal total curtailment) and credits the net migration saving; the
    plan respects every link cap, supply, and headroom exactly."""
    p = synthetic_regional_fleet(9, ["CA", "TX", "NY"], hours=24, seed=0)
    ctx = SolveContext(steps=300)
    res = solve(p, CR1(lam=1.45), ctx=ctx)
    off = solve(dataclasses.replace(p, topology=None), CR1(lam=1.45),
                ctx=ctx)
    np.testing.assert_array_equal(res.D, off.D)
    plan = res.extras["migration"]
    assert isinstance(plan, MigrationPlan)
    assert plan.net_saved > 0.0
    wmci = np.asarray(p.mci)[np.asarray(p.region)]
    base = float((np.asarray(p.usage) * wmci).sum())
    assert res.carbon_reduction_pct == pytest.approx(
        off.carbon_reduction_pct + 100.0 * plan.net_saved / base)
    # exact feasibility after the repair pass
    y = plan.y
    bw = np.asarray(p.topology.bandwidth)
    assert (y >= 0.0).all()
    assert (y <= bw[:, :, None] + 1e-9).all()
    assert np.abs(np.trace(y.sum(axis=2))) == 0.0    # no self-flows
    residual = np.asarray(p.usage) - np.asarray(res.D)
    is_batch = np.asarray(p.is_batch, bool)
    movable = np.zeros((p.R, p.T))
    np.add.at(movable, np.asarray(p.region)[is_batch],
              np.maximum(residual[is_batch], 0.0))
    assert (y.sum(axis=1) <= movable + 1e-6).all()   # supply caps
    # the same plan comes from the public helper
    again = fleet_migration(p, np.asarray(res.D))
    np.testing.assert_allclose(again.y, y, atol=1e-12)


def test_zero_bandwidth_topology_yields_zero_plan():
    p = synthetic_regional_fleet(
        6, ["CA", "TX"], hours=24, seed=1,
        topology=RegionTopology(cost=np.full((2, 2), 2.0),
                                bandwidth=np.zeros((2, 2))))
    res = solve(p, CR1(lam=1.45), ctx=SolveContext(steps=150))
    assert "migration" not in res.extras
    plan = fleet_migration(p, np.asarray(res.D))
    assert plan.moved_total == 0.0 and plan.net_saved == 0.0


# ---------------------------------------------------------------------------
# Acceptance: R=3 fleet beats the best single-signal solve
# ---------------------------------------------------------------------------
@pytest.mark.slow
def test_r3_regional_beats_best_single_signal_solve():
    """Headline: pricing each region on its own Cambium trace (plus
    migration) eliminates more fleet-wide carbon than pricing the whole
    fleet on ANY single region's trace, at equal total curtailment.

    `utc_offsets="auto"` rolls each state trace onto the shared UTC
    clock the fleet actually runs on — the duck-curve troughs land at
    different hours per region, which is exactly the timing diversity a
    single shared signal cannot express.  Comparison is at equal total
    curtailment: a feasible plan scaled down uniformly stays feasible
    (the box shrinks toward 0 and batch day-sums stay zero), so each
    single-signal solve is down-scaled to the multi solve's curtailment
    and its realized reduction scales with it.
    """
    base_p = synthetic_regional_fleet(9, ["CA", "TX", "NY"], hours=48,
                                      seed=0, utc_offsets="auto")
    ent = float(np.asarray(base_p.entitlement).sum())
    bw = np.full((3, 3), 0.15 * ent / 2)
    np.fill_diagonal(bw, 0.0)
    p = dataclasses.replace(
        base_p, topology=RegionTopology(cost=np.full((3, 3), 1.0),
                                        bandwidth=bw))
    wmci = np.asarray(p.mci)[np.asarray(p.region)]
    base = float((np.asarray(p.usage) * wmci).sum())
    ctx = SolveContext(steps=400)
    multi = solve(p, CR1(lam=1.45), ctx=ctx)
    multi_curtail = float(np.asarray(multi.D).sum())
    assert 100.0 * multi.extras["migration"].net_saved / base > 1.0
    best = -np.inf
    for r in range(p.R):
        single = dataclasses.replace(p, mci=np.asarray(p.mci)[r],
                                     region=None, topology=None)
        rs = solve(single, CR1(lam=1.45), ctx=ctx)
        realized = 100.0 * float((np.asarray(rs.D) * wmci).sum()) / base
        curtail = float(np.asarray(rs.D).sum())
        # every single signal curtails at least as much as the multi
        # solve here, so scaling down to multi_curtail is feasible
        assert curtail >= multi_curtail
        best = max(best, realized * multi_curtail / curtail)
    assert multi.carbon_reduction_pct > best + 0.5
