"""Per-arch reduced-config smoke tests: one forward/train step on CPU,
asserting output shapes + no NaNs (assignment requirement), plus
decode-vs-forward consistency and SSD correctness."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config, reduced
from repro.models import common, encdec, ssm
from repro.models import transformer as tf
from repro.optim.adamw import AdamWConfig, adamw_init, adamw_update, \
    apply_updates

KEY = jax.random.PRNGKey(0)


def make_batch(cfg, B=2, S=16):
    if cfg.family == "encdec":
        return {"frames": jax.random.normal(
                    KEY, (B, cfg.encoder_seq, cfg.d_model), jnp.float32),
                "tokens": jnp.ones((B, S), jnp.int32),
                "labels": jnp.ones((B, S), jnp.int32)}
    if cfg.family == "vlm":
        sv = 4
        return {"tokens": jnp.ones((B, S - sv), jnp.int32),
                "vision_embeds": jax.random.normal(KEY, (B, sv, cfg.d_model),
                                                   jnp.float32),
                "mrope_positions": jnp.ones((3, B, S), jnp.int32),
                "labels": jnp.ones((B, S - sv), jnp.int32)}
    return {"tokens": jnp.ones((B, S), jnp.int32),
            "labels": jnp.ones((B, S), jnp.int32)}


@pytest.mark.parametrize("arch_id", ARCH_IDS)
@pytest.mark.slow
def test_arch_smoke_forward_and_train_step(arch_id):
    cfg = reduced(get_config(arch_id))
    mod = encdec if cfg.family == "encdec" else tf
    params = mod.init_params(cfg, KEY)
    batch = make_batch(cfg)
    B, S = 2, 16
    logits = mod.forward(params, cfg, batch)
    exp_s = S if cfg.family != "vlm" else S  # vision tokens prepended
    assert logits.shape[0] == B and logits.shape[-1] == cfg.vocab_size
    assert bool(jnp.isfinite(logits).all()), "NaN/Inf in logits"
    # one real optimizer step
    opt_cfg = AdamWConfig(total_steps=10)
    opt = adamw_init(params, opt_cfg)
    loss, grads = jax.value_and_grad(
        lambda p: mod.loss_fn(p, cfg, batch))(params)
    assert bool(jnp.isfinite(loss))
    updates, opt = adamw_update(grads, opt, params, opt_cfg)
    new_params = apply_updates(params, updates)
    delta = max(float(jnp.abs(a - b).max()) for a, b in
                zip(jax.tree.leaves(params), jax.tree.leaves(new_params)))
    assert np.isfinite(delta) and delta > 0, "params did not move"


@pytest.mark.parametrize("arch_id", ["stablelm-3b", "granite-20b",
                                     "qwen3-moe-30b-a3b", "mamba2-780m",
                                     "jamba-v0.1-52b", "deepseek-v3-671b"])
@pytest.mark.slow
def test_decode_matches_forward(arch_id):
    """Teacher-forced forward and step-by-step decode agree on logits —
    the serving-path correctness invariant."""
    cfg = reduced(get_config(arch_id))
    if cfg.moe is not None:  # scatter/einsum equivalence tested elsewhere
        cfg = dataclasses.replace(cfg)
    params = tf.init_params(cfg, KEY)
    B, S = 2, 8
    tokens = jax.random.randint(KEY, (B, S), 0, cfg.vocab_size)
    full = tf.forward(params, cfg, {"tokens": tokens})
    cache = tf.init_cache(cfg, B, S + 4)
    step_logits = []
    for t in range(S):
        lg, cache = tf.decode_step(params, cfg, cache, tokens[:, t:t + 1], t)
        step_logits.append(lg[:, 0])
    stepped = jnp.stack(step_logits, axis=1)
    np.testing.assert_allclose(np.asarray(stepped), np.asarray(full),
                               rtol=2e-2, atol=2e-2)


@pytest.mark.slow
def test_whisper_decode_matches_forward():
    cfg = reduced(get_config("whisper-large-v3"))
    params = encdec.init_params(cfg, KEY)
    B, S = 2, 8
    frames = jax.random.normal(KEY, (B, cfg.encoder_seq, cfg.d_model))
    tokens = jax.random.randint(KEY, (B, S), 0, cfg.vocab_size)
    full = encdec.forward(params, cfg, {"frames": frames, "tokens": tokens})
    enc_out = encdec.encode(params, cfg, frames)
    cache = encdec.start_cache(params, cfg, enc_out, B, S + 4)
    outs = []
    for t in range(S):
        lg, cache = encdec.decode_step(params, cfg, cache,
                                       tokens[:, t:t + 1], t)
        outs.append(lg[:, 0])
    np.testing.assert_allclose(np.asarray(jnp.stack(outs, 1)),
                               np.asarray(full), rtol=2e-2, atol=2e-2)


def test_ssd_chunked_matches_sequential():
    """Chunked SSD == naive sequential recurrence (the SSD identity)."""
    B, S, H, P, N = 2, 32, 4, 8, 16
    key = jax.random.PRNGKey(1)
    ks = jax.random.split(key, 4)
    xh = jax.random.normal(ks[0], (B, S, H, P))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, H)))
    A = -jnp.exp(jax.random.normal(ks[2], (H,)) * 0.3)
    Bm = jax.random.normal(ks[3], (B, S, 1, N))
    Cm = jax.random.normal(ks[0], (B, S, 1, N))
    y_chunk, h_chunk = ssm.ssd_chunked(xh, dt, A, Bm, Cm, chunk=8)

    # sequential reference
    h = jnp.zeros((B, H, P, N))
    ys = []
    for t in range(S):
        dA = jnp.exp(dt[:, t] * A[None, :])
        h = h * dA[..., None, None] + jnp.einsum(
            "bhp,bhn->bhpn", xh[:, t] * dt[:, t, :, None], Bm[:, t, 0][:, None])
        ys.append(jnp.einsum("bhpn,bhn->bhp", h, Cm[:, t, 0][:, None]))
    y_seq = jnp.stack(ys, axis=1)
    np.testing.assert_allclose(np.asarray(y_chunk), np.asarray(y_seq),
                               rtol=1e-3, atol=1e-3)
    np.testing.assert_allclose(np.asarray(h_chunk), np.asarray(h),
                               rtol=1e-3, atol=1e-3)


def test_param_count_sane():
    cfg = get_config("qwen3-32b")
    n = cfg.param_count()
    assert 25e9 < n < 40e9        # ~32B params
    moe = get_config("qwen3-moe-30b-a3b")
    assert 25e9 < moe.param_count() < 36e9
    assert 2e9 < moe.active_param_count() < 5e9   # ~3B active


def test_mamba_long_context_flag():
    assert get_config("mamba2-780m").long_context_ok
    assert get_config("jamba-v0.1-52b").long_context_ok
    assert not get_config("qwen3-32b").long_context_ok


@pytest.mark.slow
def test_int8_kv_cache_decode_close_to_exact():
    """int8 KV cache (serving memory optimization) stays within quantization
    tolerance of the exact decode path."""
    import dataclasses
    cfg = reduced(get_config("stablelm-3b"))
    cfgq = dataclasses.replace(cfg, kv_quant=True)
    params = tf.init_params(cfg, KEY)
    B, S = 2, 8
    tokens = jax.random.randint(KEY, (B, S), 0, cfg.vocab_size)

    def run(c):
        cache = tf.init_cache(c, B, S + 2)
        outs = []
        for t in range(S):
            lg, cache = tf.decode_step(params, c, cache, tokens[:, t:t + 1], t)
            outs.append(lg[:, 0])
        return jnp.stack(outs, 1)

    full, quant = run(cfg), run(cfgq)
    probs_diff = float(jnp.abs(jax.nn.softmax(full)
                               - jax.nn.softmax(quant)).max())
    assert probs_diff < 2e-2
    # cache footprint halves (+ scale overhead)
    cache_q = tf.init_cache(cfgq, B, S)
    cache_f = tf.init_cache(cfg, B, S)
    bytes_q = sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(cache_q))
    bytes_f = sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(cache_f))
    assert bytes_q < 0.6 * bytes_f
