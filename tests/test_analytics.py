"""Analytic FLOPs model sanity: matches 6·N·D within the expected envelope."""
import pytest

from repro.configs import get_config, shape_by_name
from repro.launch.analytics import cell_flops, cell_hbm_bytes, forward_flops


def test_dense_train_flops_near_8nd():
    """Full remat training ≈ 8·N·D for a dense LM (4 passes × 2·N·D) plus
    attention-quadratic overhead."""
    cfg = get_config("qwen3-32b")
    shape = shape_by_name("train_4k")
    got = cell_flops(cfg, shape)
    tokens = shape.global_batch * shape.seq_len
    nd8 = 8 * cfg.param_count() * tokens
    assert 0.9 * nd8 < got < 1.6 * nd8


def test_moe_uses_active_params():
    cfg = get_config("qwen3-moe-30b-a3b")
    shape = shape_by_name("train_4k")
    got = cell_flops(cfg, shape)
    tokens = shape.global_batch * shape.seq_len
    lower = 6 * cfg.active_param_count() * tokens
    upper = 6 * cfg.param_count() * tokens
    assert lower < got < upper     # active ≪ flops ≪ total (dispatch adds)


def test_decode_linear_in_context():
    cfg = get_config("qwen3-32b")
    d32 = shape_by_name("decode_32k")
    f = forward_flops(cfg, d32)
    # per sequence: dominated by weights (2·N) + attention (S·H·Dh terms)
    per_seq = f / d32.global_batch
    assert per_seq > 2 * cfg.active_param_count() * 0.9


def test_mla_decode_cache_smaller_than_gqa():
    """DeepSeek's MLA latent cache beats an equivalent GQA cache by >10x —
    the reason the arch exists."""
    ds = get_config("deepseek-v3-671b")
    qw = get_config("qwen1.5-110b")
    shape = shape_by_name("decode_32k")
    ds_bytes = cell_hbm_bytes(ds, shape, 256)
    m = ds.mla
    latent_per_tok = (m.kv_lora_rank + m.qk_rope_head_dim) * 2
    gqa_equiv = 2 * ds.num_heads * 128 * 2
    assert gqa_equiv / latent_per_tok > 10
    assert ds_bytes > 0 and cell_hbm_bytes(qw, shape, 256) > 0


def test_einsum_dispatch_costs_more_than_scatter():
    import dataclasses
    cfg = get_config("qwen3-moe-30b-a3b")
    shape = shape_by_name("train_4k")
    f_einsum = cell_flops(cfg, shape)
    cfg_s = dataclasses.replace(
        cfg, moe=dataclasses.replace(cfg.moe, dispatch="scatter"))
    f_scatter = cell_flops(cfg_s, shape)
    assert f_einsum > 1.1 * f_scatter   # the GShard dispatch overhead


def test_long_context_ssm_flops_context_independent():
    cfg = get_config("mamba2-780m")
    f_short = forward_flops(cfg, shape_by_name("decode_32k"))
    f_long = forward_flops(cfg, shape_by_name("long_500k"))
    per_tok_short = f_short / 128
    per_tok_long = f_long / 1
    assert per_tok_long == pytest.approx(per_tok_short, rel=1e-6)
