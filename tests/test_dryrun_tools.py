"""Dry-run tooling tests: loop-aware HLO collective parsing + roofline."""
import pytest

from repro.launch.dryrun import (HBM_BW, ICI_BW, PEAK_FLOPS,
                                 parse_collectives, roofline_terms)

# Post-optimization style: operands are bare %names; result shape precedes
# the op; while bodies are separate computations multiplied by trip count.
HLO_SAMPLE = """
%add.1 (a: f32[], b: f32[]) -> f32[] {
  ROOT %r = f32[] add(%a, %b)
}

%body.2 (arg: (s32[], f32[256,128])) -> (s32[], f32[256,128]) {
  %ar = f32[256,128]{1,0} all-reduce(%x), channel_id=3, replica_groups=[16,16]<=[256], use_global_device_ids=true, to_apply=%add.1
  %cp = f32[64]{0} collective-permute(%y), channel_id=4
}

%cond.3 (arg: (s32[], f32[256,128])) -> pred[] {
  %c = s32[] constant(8)
  %lt = pred[] compare(%i, %c), direction=LT
}

ENTRY %main.4 (p0: f32[1024,512]) -> f32[2048,512] {
  %ag = f32[2048,512]{1,0} all-gather(%p0), channel_id=1, replica_groups=[128,2]<=[256], dimensions={0}
  %wh = (s32[], f32[256,128]) while(%init), condition=%cond.3, body=%body.2
}
"""


def test_parse_collectives_loop_aware():
    out = parse_collectives(HLO_SAMPLE)
    b = out["bytes_by_op"]
    # all-gather: out 2048*512*4 bytes, ring factor (2-1)/2.
    assert b["all-gather"] == int(2048 * 512 * 4 * 0.5)
    # all-reduce inside the while body: trip count 8, group 16,
    # 2*out*(15/16) each iteration.
    ar_once = 2 * 256 * 128 * 4 * (15 / 16)
    assert b["all-reduce"] == pytest.approx(8 * ar_once, rel=0.01)
    # collective-permute: point-to-point, out bytes, ×8 iterations.
    assert b["collective-permute"] == 8 * 64 * 4
    assert out["counts"]["all-reduce"] == 8
    assert out["total_bytes"] == sum(b.values())


def test_parse_ignores_done_ops():
    text = ("ENTRY %m {\n"
            "  %d = f32[64]{0} all-gather-done(%s)\n"
            "}\n")
    assert parse_collectives(text)["total_bytes"] == 0


def test_parse_start_counted_once():
    text = ("ENTRY %m {\n"
            "  %s = f32[64]{0} all-gather-start(%p), replica_groups=[1,2]<=[2]\n"
            "  %d = f32[64]{0} all-gather-done(%s)\n"
            "}\n")
    out = parse_collectives(text)
    assert out["counts"]["all-gather"] == 1
    assert out["total_bytes"] == int(64 * 4 * 0.5)


def test_roofline_terms():
    t = roofline_terms(flops=197e12 * 256, hbm_bytes_per_dev=819e9,
                       coll_bytes_per_dev=50e9, chips=256)
    assert t["t_compute_s"] == pytest.approx(1.0)
    assert t["t_memory_s"] == pytest.approx(1.0)
    assert t["t_collective_s"] == pytest.approx(1.0)


def test_hardware_constants():
    assert PEAK_FLOPS == 197e12 and HBM_BW == 819e9 and ICI_BW == 50e9
