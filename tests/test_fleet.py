"""FleetCoordinator unit tests: entitlement headroom and streaming plans."""
import numpy as np
import pytest

from repro.core.carbon import caiso_2021
from repro.core.fleet import FleetJob, _penalty_model, _usage_trace
from repro.core.fleetcache import cached_paper_fleet
from repro.power.model import JobPowerModel


@pytest.fixture(scope="module")
def templates():
    return cached_paper_fleet(hours=48)


def _serve_job(t_compute=0.01, t_step=0.02):
    return FleetJob("serve-x", "serve",
                    JobPowerModel("s", chips=64, t_compute_s=t_compute,
                                  t_step_s=t_step))


def test_serve_entitlement_headroom_from_dynamic_fraction(templates):
    """Regression for the dead-code headroom bug: entitlement must carry a
    cushion of 7.5% x 1/max(dynamic_fraction, 0.5) above peak usage."""
    job = _serve_job()
    model = _penalty_model(job, 48, templates)
    usage = _usage_trace(job, 48)
    headroom = 1.0 / max(job.power.dynamic_fraction, 0.5)
    expect = float(usage.max() * (1.0 + 0.075 * headroom))
    assert model.entitlement == pytest.approx(expect, rel=1e-12)
    # this job is static-heavy (dyn < 0.5), so it books the full 15%
    assert job.power.dynamic_fraction < 0.5
    assert model.entitlement == pytest.approx(float(usage.max()) * 1.15,
                                              rel=1e-12)


def test_entitlement_cushion_shrinks_for_dynamic_jobs(templates):
    """A fully utilized (high dynamic-fraction) job books a smaller cushion
    than a static-heavy one: it can shed load on request instead."""
    static_heavy = _serve_job(t_compute=0.01, t_step=0.02)   # util 0.5
    dynamic = _serve_job(t_compute=0.02, t_step=0.02)        # util 1.0
    assert dynamic.power.dynamic_fraction > \
        static_heavy.power.dynamic_fraction
    m_static = _penalty_model(static_heavy, 48, templates)
    m_dyn = _penalty_model(dynamic, 48, templates)
    peak_s = _usage_trace(static_heavy, 48).max()
    peak_d = _usage_trace(dynamic, 48).max()
    assert m_dyn.entitlement / peak_d < m_static.entitlement / peak_s


@pytest.mark.slow
def test_plan_streaming_emits_online_schedules():
    from repro.core.fleet import FleetCoordinator
    from repro.core.streaming import StreamingReport
    jobs = [
        FleetJob("train-a", "train",
                 JobPowerModel("t", chips=128, t_compute_s=0.4,
                               t_step_s=0.5)),
        FleetJob("serve-b", "serve",
                 JobPowerModel("s", chips=64, t_compute_s=0.01,
                               t_step_s=0.02)),
    ]
    coord = FleetCoordinator(jobs, caiso_2021(48), lam=1.3)
    schedules, report = coord.plan_streaming(n_ticks=3, cold_steps=200,
                                             warm_steps=60)
    assert isinstance(report, StreamingReport)
    assert set(schedules) == {"train-a", "serve-b"}
    for s in schedules.values():
        assert s.throttle.shape == (3,)            # committed hours only
        assert (s.throttle > 0).all() and (s.throttle <= 1.0 + 1e-9).all()
        assert s.power_cut_np.shape == (3,)
    # warm ticks ran at the reduced budget
    assert [t.inner_steps for t in report.ticks] == [200, 60, 60]
    # committed cuts stay inside each job's dynamic (deliverable) range, so
    # no throttle saturates and the carbon ledger never credits
    # unenforceable curtailment
    for job in jobs:
        usage = _usage_trace(job, 48)
        cap = 0.95 * job.power.dynamic_fraction * usage[np.arange(3) % 48]
        assert (schedules[job.name].power_cut_np <= cap + 1e-6).all()
        assert (schedules[job.name].throttle > 0).all()
