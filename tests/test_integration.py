"""End-to-end integration: training loss goes down, DR throttling enforces
budgets, serving QoS responds to power caps, fleet coordination plans."""
import jax
import numpy as np
import pytest

from repro.configs import get_config, reduced
from repro.configs.base import ShapeCell
from repro.core.carbon import caiso_2021
from repro.core.fleet import FleetCoordinator, FleetJob
from repro.launch.train import train
from repro.power.model import ChipPower, JobPowerModel
from repro.runtime.ft import FailurePlan

CFG = reduced(get_config("stablelm-3b"), layers=2, d_model=64)
SHAPE = ShapeCell("t", 64, 4, "train")


@pytest.mark.slow
def test_training_loss_decreases(tmp_path):
    report = train(CFG, SHAPE, steps=40, ckpt_dir=str(tmp_path))
    losses = report["losses"]
    assert len(losses) == 40
    assert np.mean(losses[-5:]) < np.mean(losses[:5])


@pytest.mark.slow
def test_training_with_injected_failure_completes(tmp_path):
    report = train(CFG, SHAPE, steps=30, ckpt_dir=str(tmp_path),
                   failure_plan=FailurePlan(fail_steps=(13,)))
    assert report["steps"] >= 30
    assert any(e["event"] == "restored" for e in report["events"])


@pytest.mark.slow
def test_dr_throttled_training(tmp_path):
    throttle = np.asarray([1.0, 0.4, 1.0, 0.4])
    report = train(CFG, SHAPE, steps=24, ckpt_dir=str(tmp_path),
                   throttle=throttle)
    assert report["steps"] >= 24            # work completes (preservation)


def test_fleet_coordinator_plans():
    jobs = [
        FleetJob("train-qwen3", "train",
                 JobPowerModel("t", chips=256, t_compute_s=0.4,
                               t_step_s=0.5)),
        FleetJob("serve-stablelm", "serve",
                 JobPowerModel("s", chips=64, t_compute_s=0.01,
                               t_step_s=0.02)),
        FleetJob("pipeline", "data",
                 JobPowerModel("d", chips=32, t_compute_s=0.2,
                               t_step_s=0.4)),
    ]
    coord = FleetCoordinator(jobs, caiso_2021(48), lam=1.3)
    schedules, result = coord.plan()
    assert set(schedules) == {"train-qwen3", "serve-stablelm", "pipeline"}
    for s in schedules.values():
        assert s.throttle.shape == (48,)
        assert (s.throttle > 0).all() and (s.throttle <= 1.0 + 1e-9).all()
    assert result.carbon_reduction_pct >= 0
    # Batch preservation honored for the training job's adjustments.
    tr = schedules["train-qwen3"].power_cut_np
    assert abs(tr[:24].sum()) < 0.05 * np.abs(tr).sum() + 1e-6


def test_fleet_coordinator_policy_resolution():
    """String policies resolve through the registry with the coordinator's
    knobs — CR2/CR3 keep the historical streaming outer=4 budget, every
    registered name means the same policy as elsewhere, and unregistered
    names keep the legacy CR1 fallback."""
    from repro.core.api import B1, CR1, CR2, CR3
    sig = caiso_2021(24)
    coord = FleetCoordinator([], sig, policy="cr2", cap_frac=0.8)
    assert coord._policy_obj() == CR2(cap_frac=0.8, outer=4)
    assert FleetCoordinator([], sig, policy="cr3")._policy_obj() \
        == CR3(outer=4)
    assert FleetCoordinator([], sig, policy="b1")._policy_obj() == B1()
    assert FleetCoordinator([], sig, policy="nope", lam=1.3)._policy_obj() \
        == CR1(lam=1.3)
    assert FleetCoordinator([], sig, policy=CR1(lam=1.2))._policy_obj() \
        == CR1(lam=1.2)


def test_power_model_roundtrip():
    m = JobPowerModel("x", chips=256, t_compute_s=0.4, t_step_s=0.5,
                      chip=ChipPower())
    assert 0 < m.utilization <= 1
    assert m.power_np > 0
    th = m.throttle_for_power_cut(0.1)
    assert 0 <= th < 1
    assert m.throttle_for_power_cut(0.0) == 1.0
    # Cuts beyond the dynamic range saturate (idle floor).
    assert m.throttle_for_power_cut(0.99) == 0.0


@pytest.mark.slow
def test_serving_qos_degrades_under_power_cap():
    from repro.launch.serve import Request, serve_requests
    from repro.models import transformer as tf
    params = tf.init_params(CFG, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    reqs1 = [Request(rid=i, prompt=rng.integers(0, 100, 8).astype(np.int32),
                     max_new=4) for i in range(8)]
    reqs2 = [Request(rid=i, prompt=r.prompt.copy(), max_new=4)
             for i, r in enumerate(reqs1)]
    fast = serve_requests(params, CFG, reqs1, max_batch=8, max_len=32)
    slow = serve_requests(params, CFG, reqs2, max_batch=2, max_len=32)
    # Power-capped serving (smaller admitted batch) has worse tail latency.
    assert slow.p(95) > fast.p(95) * 1.2
    # Same tokens either way (QoS, not correctness, degrades).
    for a, b in zip(reqs1, reqs2):
        assert a.tokens == b.tokens
