"""Fleet-cache robustness: atomic writes and corrupt-cache recovery."""
import numpy as np
import pytest

from repro.core import fleetcache
from repro.core.penalty import PenaltyModel


def _tiny_fleet(hours=6):
    usage = np.linspace(1.0, 2.0, hours)
    return {
        "RTS1": PenaltyModel(name="RTS1", kind="realtime", usage=usage,
                             entitlement=3.0, k=0.5,
                             params=(0.1, 0.2, 0.3)),
        "Batch": PenaltyModel(name="Batch", kind="batch_noslo", usage=usage,
                              entitlement=4.0, k=0.7,
                              params=(0.0, 0.1, 0.2),
                              jobs=np.ones(hours),
                              feature_names=("waiting_time_power",
                                             "num_jobs_delayed")),
    }


@pytest.fixture()
def cache_env(tmp_path, monkeypatch):
    """Redirect the cache dir and stub the expensive build."""
    calls = {"builds": 0}

    def fake_build(**kwargs):
        calls["builds"] += 1
        return _tiny_fleet(kwargs.get("hours", 6))

    monkeypatch.setattr(fleetcache, "_CACHE_DIR", tmp_path)
    monkeypatch.setattr(fleetcache, "build_paper_fleet", fake_build)
    return tmp_path, calls


def test_cache_roundtrip_and_atomic_layout(cache_env):
    tmp_path, calls = cache_env
    fleet = fleetcache.cached_paper_fleet(hours=6)
    assert calls["builds"] == 1
    # exactly the final cache file on disk — no stray temp files
    files = sorted(f.name for f in tmp_path.iterdir())
    assert files == ["fleet_h6_p100_s160_j10000_r0.npz"]
    again = fleetcache.cached_paper_fleet(hours=6)
    assert calls["builds"] == 1            # served from cache
    for name in fleet:
        np.testing.assert_array_equal(again[name].usage, fleet[name].usage)
        assert again[name].params == fleet[name].params
        assert again[name].kind == fleet[name].kind


def test_corrupt_cache_rebuilds_instead_of_crashing(cache_env):
    """Regression: a truncated .npz (e.g. a killed CI worker mid-savez)
    must trigger a rebuild + atomic rewrite, not poison every later run."""
    tmp_path, calls = cache_env
    fleetcache.cached_paper_fleet(hours=6)
    path = tmp_path / "fleet_h6_p100_s160_j10000_r0.npz"
    # truncate: the classic partial-write corruption
    path.write_bytes(path.read_bytes()[:40])
    with pytest.warns(RuntimeWarning, match="corrupt fleet cache"):
        fleet = fleetcache.cached_paper_fleet(hours=6)
    assert calls["builds"] == 2
    assert set(fleet) == {"RTS1", "Batch"}
    # the rewrite healed the cache
    fleetcache.cached_paper_fleet(hours=6)
    assert calls["builds"] == 2


def test_garbage_cache_file_rebuilds(cache_env):
    tmp_path, calls = cache_env
    path = tmp_path / "fleet_h6_p100_s160_j10000_r0.npz"
    path.write_bytes(b"not a zip archive at all")
    with pytest.warns(RuntimeWarning, match="corrupt fleet cache"):
        fleetcache.cached_paper_fleet(hours=6)
    assert calls["builds"] == 1
