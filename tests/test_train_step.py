"""Train-step semantics: gradient accumulation equivalence + sharded-vs-
single-device numerical equivalence (the strongest sharding correctness
check: same math on 1 and 8 devices)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, reduced
from repro.launch.steps import make_train_step
from repro.models import transformer as tf
from repro.optim.adamw import AdamWConfig, adamw_init

CFG = reduced(get_config("stablelm-3b"), layers=2, d_model=64)


def _setup():
    params = tf.init_params(CFG, jax.random.PRNGKey(0))
    oc = AdamWConfig(total_steps=10)
    opt = adamw_init(params, oc)
    key = jax.random.PRNGKey(1)
    batch = {"tokens": jax.random.randint(key, (8, 32), 0, 256),
             "labels": jax.random.randint(key, (8, 32), 0, 256)}
    return params, oc, opt, batch


@pytest.mark.slow
def test_grad_accum_equivalent():
    params, oc, opt, batch = _setup()
    s1 = jax.jit(make_train_step(CFG, oc, grad_accum=1))
    s4 = jax.jit(make_train_step(CFG, oc, grad_accum=4))
    p1, _, l1 = s1(params, opt, batch)
    p4, _, l4 = s4(params, opt, batch)
    assert abs(float(l1) - float(l4)) < 1e-4
    for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p4)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-4, atol=1e-5)


def test_grad_accum_rejects_indivisible():
    params, oc, opt, batch = _setup()
    s3 = make_train_step(CFG, oc, grad_accum=3)
    with pytest.raises(AssertionError):
        s3(params, opt, batch)


@pytest.mark.slow
def test_sharded_step_matches_single_device():
    from conftest import run_in_subprocess
    run_in_subprocess("""
import jax, jax.numpy as jnp, numpy as np
from repro.configs import get_config, reduced
from repro.configs.base import ShapeCell
from repro.launch.mesh import make_test_mesh
from repro.launch.steps import make_step_bundle
from repro.launch.steps import make_train_step
from repro.models import transformer as tf
from repro.optim.adamw import AdamWConfig, adamw_init

cfg = reduced(get_config("stablelm-3b"), layers=2, d_model=64)
params = tf.init_params(cfg, jax.random.PRNGKey(0))
oc = AdamWConfig(total_steps=10)
opt = adamw_init(params, oc)
key = jax.random.PRNGKey(1)
batch = {"tokens": jax.random.randint(key, (8, 32), 0, 256),
         "labels": jax.random.randint(key, (8, 32), 0, 256)}
# single device
p1, _, l1 = jax.jit(make_train_step(cfg, oc))(params, opt, batch)
# 2x2x2 sharded with the production partition rules
mesh = make_test_mesh(data=2, model=2, pod=2)
bundle = make_step_bundle(cfg, ShapeCell("t", 32, 8, "train"), mesh)
step = jax.jit(bundle.fn, in_shardings=bundle.in_shardings,
               out_shardings=bundle.out_shardings)
with mesh:
    p8, _, l8 = step(params, opt, batch)
assert abs(float(l1) - float(l8)) < 2e-3, (float(l1), float(l8))
for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p8)):
    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                               rtol=3e-2, atol=3e-3)
print("sharded == single-device OK", float(l1), float(l8))
""", devices=8)
