"""`repro.analysis` tests: drlint rule detections + suppressions, the
CLI contract, the checkify sanitizer lane (parity, NaN injection,
unsupported-combo refusals), and `recompile_guard` one-trace claims
(warm vs cold `solve()`, `run_scanned` across consecutive days).

Every drlint rule gets at least one positive-detection test against a
synthetic bad snippet; the clean-tree test pins the invariant that the
shipped `src/repro` lints clean (CI runs the same check via
`scripts/ci.sh`)."""
import dataclasses
import pathlib
import textwrap

import numpy as np
import pytest

from repro.analysis import (RecompileError, SanitizeError, check_all_finite,
                            checked_jit, recompile_guard)
from repro.analysis.lint import lint_paths, main as lint_main
from repro.analysis.rules import RULES, lint_source
from repro.core.api import CR1, CR2, CR3, SolveContext, solve, sweep
from repro.core.fleet_solver import synthetic_fleet

SRC = pathlib.Path(__file__).resolve().parents[1] / "src" / "repro"


def _lint(source: str, path: str = "src/repro/core/example.py"):
    return lint_source(path, textwrap.dedent(source))


def _rules(violations):
    return [v.rule for v in violations]


# ---------------------------------------------------------------------------
# drlint: one positive detection per rule
# ---------------------------------------------------------------------------
def test_rule_registry_complete():
    assert set(RULES) == {
        "jit-host-leak", "donation-twin", "check-rep-justification",
        "tuple-seed", "np-on-traced", "deprecated-shim",
        "adhoc-partition-spec", "host-sync-in-jit"}


def test_jit_host_leak_float_and_item():
    vs = _lint("""
        import jax

        @jax.jit
        def f(x):
            y = float(x)
            return y + x.sum().item()
    """)
    assert _rules(vs) == ["jit-host-leak", "jit-host-leak"]
    assert "float()" in vs[0].message and ".item()" in vs[1].message


def test_jit_host_leak_traced_branch():
    vs = _lint("""
        import jax
        import jax.numpy as jnp

        @jax.jit
        def f(x):
            if jnp.any(x > 0):
                return x
            return -x
    """)
    assert _rules(vs) == ["jit-host-leak"]
    assert "lax.cond" in vs[0].message


def test_jit_host_leak_static_metadata_is_legal():
    vs = _lint("""
        import jax

        @jax.jit
        def f(x, n_eq):
            k = int(x.shape[0])
            if n_eq:
                return x[:k]
            return x
    """)
    assert vs == []


def test_jit_host_leak_only_in_reachable_functions():
    # Same float() call, but nothing jits `f` — host-side code is free
    # to concretize.
    vs = _lint("""
        def f(x):
            return float(x)
    """)
    assert vs == []


def test_donation_twin_missing_sibling():
    vs = _lint("""
        import jax

        def impl(p, lam, warm, steps):
            return warm

        _run_donated = jax.jit(impl, static_argnames=("steps",),
                               donate_argnums=(2,))
    """)
    assert _rules(vs) == ["donation-twin"]
    assert "non-donated jit" in vs[0].message


def test_donation_twin_ok_and_static_donation_flagged():
    ok = _lint("""
        import jax

        def impl(p, lam, warm, steps):
            return warm

        _STATIC = ("steps",)
        _run = jax.jit(impl, static_argnames=_STATIC)
        _run_donated = jax.jit(impl, static_argnames=_STATIC,
                               donate_argnums=(2,))
    """)
    assert ok == []
    bad = _lint("""
        import jax

        def impl(p, lam, warm, steps):
            return warm

        _run = jax.jit(impl, static_argnames=("steps",))
        _run_donated = jax.jit(impl, static_argnames=("steps",),
                               donate_argnums=(3,))
    """)
    assert _rules(bad) == ["donation-twin"]
    assert "static" in bad[0].message


def test_check_rep_needs_pallas_comment():
    bad = _lint("""
        from jax.experimental.shard_map import shard_map

        def build(mesh, body, specs):
            return shard_map(body, mesh=mesh, in_specs=specs,
                             out_specs=specs, check_rep=False)
    """)
    assert _rules(bad) == ["check-rep-justification"]
    ok = _lint("""
        from jax.experimental.shard_map import shard_map

        def build(mesh, body, specs):
            # check_rep=False: body dispatches the al_step pallas_call,
            # which has no shard_map replication rule.
            return shard_map(body, mesh=mesh, in_specs=specs,
                             out_specs=specs, check_rep=False)
    """)
    assert ok == []


def test_tuple_seed_arithmetic_flagged():
    bad = _lint("""
        import numpy as np

        def batch(seed, step, host):
            return np.random.default_rng(seed * 4093 + step)
    """)
    assert _rules(bad) == ["tuple-seed"]
    ok = _lint("""
        import numpy as np
        import jax

        def batch(seed, step, host):
            key = jax.random.PRNGKey(seed)
            return np.random.default_rng((seed, step, host))
    """)
    assert ok == []


def test_np_on_traced():
    bad = _lint("""
        import jax
        import numpy as np

        @jax.jit
        def f(x):
            return np.sum(x)
    """)
    assert _rules(bad) == ["np-on-traced"]
    # Metadata queries stay legal on tracers.
    ok = _lint("""
        import jax
        import numpy as np

        @jax.jit
        def f(x):
            return x.reshape(np.shape(x)[0], -1)
    """)
    assert ok == []


def test_deprecated_shim():
    bad = _lint("""
        from repro.core.fleet_solver import solve_cr1_fleet

        def run(p):
            return solve_cr1_fleet(p, lam=1.4)
    """)
    assert _rules(bad) == ["deprecated-shim"]
    # The shims' own module is exempt (definitions + parity docs).
    ok = _lint("""
        def caller(p):
            return solve_cr1_fleet(p, lam=1.4)
    """, path="src/repro/core/fleet_solver.py")
    assert ok == []


def test_adhoc_partition_spec():
    bad = _lint("""
        from jax.sharding import PartitionSpec as P

        def specs():
            return P("fleet"), P(None, "region")
    """)
    assert _rules(bad) == ["adhoc-partition-spec", "adhoc-partition-spec"]
    # Named axis constants are the sanctioned spelling.
    ok = _lint("""
        from jax.sharding import PartitionSpec as P
        from repro.launch.mesh import FLEET_AXIS

        def specs():
            return P(FLEET_AXIS)
    """)
    assert ok == []
    # Out of scope outside core/ (training scaffolding owns its axes).
    out_of_scope = _lint("""
        from jax.sharding import PartitionSpec as P
        SPEC = P("data", "model")
    """, path="src/repro/launch/sharding.py")
    assert out_of_scope == []


def test_host_sync_in_jit():
    bad = _lint("""
        import jax
        from repro import obs

        @jax.jit
        def f(x):
            y = x * 2
            jax.block_until_ready(y)
            with obs.span("inner"):
                z = jax.device_get(y)
            return z
    """)
    assert _rules(bad) == ["host-sync-in-jit"] * 3
    assert "block_until_ready" in bad[0].message
    # Host-side timing around (not inside) jitted code is the contract.
    ok = _lint("""
        import jax
        from repro import obs

        @jax.jit
        def f(x):
            return x * 2

        def bench(x):
            with obs.span("solve") as s:
                s.bind(f(x))
            return jax.device_get(f(x))
    """)
    assert ok == []


# ---------------------------------------------------------------------------
# drlint: suppression mechanics
# ---------------------------------------------------------------------------
def test_suppression_with_rationale_honored():
    vs = _lint("""
        import jax

        @jax.jit
        def f(flag):
            # drlint: disable=jit-host-leak -- static jit argument
            return bool(flag)
    """)
    assert vs == []


def test_suppression_same_line_and_multi_rule():
    vs = _lint("""
        import jax
        import numpy as np

        @jax.jit
        def f(x):
            return float(np.sum(x))  # drlint: disable=jit-host-leak,np-on-traced -- host-side debug helper
    """)
    assert vs == []


def test_suppression_without_rationale_is_a_violation():
    vs = _lint("""
        import jax

        @jax.jit
        def f(flag):
            # drlint: disable=jit-host-leak
            return bool(flag)
    """)
    assert _rules(vs) == ["suppression-rationale"]


def test_suppression_does_not_reach_two_lines_down():
    vs = _lint("""
        import jax

        @jax.jit
        def f(flag):
            # drlint: disable=jit-host-leak -- too far away
            y = 1
            return bool(flag)
    """)
    assert _rules(vs) == ["jit-host-leak"]


# ---------------------------------------------------------------------------
# drlint: tree + CLI contract
# ---------------------------------------------------------------------------
def test_shipped_tree_lints_clean():
    """The invariant CI enforces: src/repro has zero unsuppressed
    violations (and every suppression in it carries a rationale)."""
    vs = lint_paths([str(SRC)])
    assert vs == [], "\n".join(v.format() for v in vs)


def test_cli_exit_and_format(tmp_path, capsys):
    bad = tmp_path / "bad.py"
    bad.write_text(textwrap.dedent("""
        import numpy as np
        rng = np.random.default_rng(7 * 1000 + 3)
    """))
    rc = lint_main([str(bad)])
    out = capsys.readouterr().out
    assert rc == 1
    assert f"{bad}:3:" in out and "tuple-seed" in out

    good = tmp_path / "good.py"
    good.write_text("x = 1\n")
    assert lint_main([str(good)]) == 0
    assert lint_main(["--list-rules"]) == 0


# ---------------------------------------------------------------------------
# Sanitizer lane
# ---------------------------------------------------------------------------
@pytest.fixture(scope="module")
def fp():
    return synthetic_fleet(6, seed=3)


@pytest.mark.parametrize("policy", [CR1(lam=1.4), CR2(cap_frac=0.12)],
                         ids=["cr1", "cr2"])
def test_sanitize_parity(fp, policy):
    """sanitize=True is the same solve with guards — bitwise plan/state
    parity with the unchecked lane."""
    plain = solve(fp, policy, ctx=SolveContext(steps=60))
    checked = solve(fp, policy, ctx=SolveContext(steps=60, sanitize=True))
    np.testing.assert_array_equal(plain.D, checked.D)
    np.testing.assert_array_equal(np.asarray(plain.state.x),
                                  np.asarray(checked.state.x))
    assert plain.carbon_reduction_pct == checked.carbon_reduction_pct


@pytest.mark.parametrize("policy", [CR1(lam=1.4), CR2(cap_frac=0.12)],
                         ids=["cr1", "cr2"])
def test_sanitize_catches_injected_nan(fp, policy):
    """A poisoned carbon trace must raise SanitizeError naming the AL
    check — the unchecked lane silently returns a NaN plan."""
    mci = np.asarray(fp.mci, float).copy()
    mci[3] = np.nan
    poisoned = dataclasses.replace(fp, mci=mci)
    silent = solve(poisoned, policy, ctx=SolveContext(steps=40))
    assert np.isnan(np.asarray(silent.D)).any()   # the failure mode
    with pytest.raises(SanitizeError, match="non-finite"):
        solve(poisoned, policy, ctx=SolveContext(steps=40, sanitize=True))


@pytest.mark.parametrize("policy", [CR1(lam=1.4), CR2(cap_frac=0.12)],
                         ids=["cr1", "cr2"])
def test_sanitize_day_scan_parity_and_nan(fp, policy):
    """The checkify lane extends to solo `solve_day` scans: bitwise
    committed-matrix parity, and a NaN in any tick's forecast row fires
    `SanitizeError` instead of poisoning the rest of the day."""
    from repro.core.api import solve_day

    rng = np.random.default_rng((11, 4))
    base = np.asarray(fp.mci, float)
    stack = np.stack([np.roll(base, -i) * (1 + 0.01 * rng.standard_normal(
        base.shape)) for i in range(3)])
    plain = solve_day(fp, policy, stack, cold_steps=40, warm_steps=10)
    checked = solve_day(fp, policy, stack, cold_steps=40, warm_steps=10,
                        ctx=SolveContext(sanitize=True))
    np.testing.assert_array_equal(plain.committed, checked.committed)
    poisoned = stack.copy()
    poisoned[1, 5] = np.nan   # warm tick 1's horizon
    with pytest.raises(SanitizeError, match="non-finite"):
        solve_day(fp, policy, poisoned, cold_steps=40, warm_steps=10,
                  ctx=SolveContext(sanitize=True))


def test_sanitize_refuses_unsupported_combos(fp):
    with pytest.raises(NotImplementedError, match="no sanitized lane"):
        solve(fp, CR3(), ctx=SolveContext(sanitize=True))
    with pytest.raises(NotImplementedError, match="solo debug lane"):
        solve(fp, CR1(lam=1.4), ctx=SolveContext(sanitize=True, donate=True))
    with pytest.raises(NotImplementedError, match="solo-solve debug lane"):
        sweep(fp, [CR1(lam=1.2), CR1(lam=1.6)],
              ctx=SolveContext(sanitize=True))


def test_check_all_finite_unit():
    import jax.numpy as jnp

    def f(x):
        y = x * 2
        check_all_finite("unit", y=y)
        return y

    g = checked_jit(f)
    err, out = g(jnp.ones(4))
    err.throw()   # clean input: no error
    np.testing.assert_array_equal(np.asarray(out), 2 * np.ones(4))
    err, _ = g(jnp.array([1.0, np.inf, 3.0, 4.0]))
    with pytest.raises(SanitizeError, match="non-finite values in y"):
        err.throw()


# ---------------------------------------------------------------------------
# recompile_guard: the one-trace claims
# ---------------------------------------------------------------------------
def test_recompile_guard_measures_and_fires():
    import jax
    import jax.numpy as jnp

    @jax.jit
    def f(x):
        return x * 3

    with recompile_guard(None) as stats:
        f(jnp.ones(5))
    assert stats.compiled   # fresh trace measured

    with recompile_guard(0):
        f(jnp.ones(5))      # warm: cache hit, no trace

    with pytest.raises(RecompileError, match="jit cache missed"):
        with recompile_guard(0, label="forced retrace"):
            f(jnp.ones(7))  # new shape forces a retrace


def test_warm_and_cold_solve_share_one_trace(fp):
    """`solve()` cold passes `EngineState.cold(...)` — the same pytree
    shape a warm state has — so cold and warm re-solves hit one jit
    entry."""
    ctx = SolveContext(steps=40)
    first = solve(fp, CR1(lam=1.4), ctx=ctx)          # compiles once
    with recompile_guard(0, label="warm+cold solve"):
        solve(fp, CR1(lam=1.4), ctx=ctx)              # cold again
        solve(fp, CR1(lam=1.4),
              ctx=dataclasses.replace(ctx, warm=first.state))  # warm


def test_run_scanned_compiles_once_across_days(fp):
    """Consecutive same-length day scans reuse one trace; the solver's
    own `guard_recompiles` enforces it from day 2 on (and a bare
    guard(0) around day 3 re-checks it from the outside)."""
    from repro.core.streaming import ForecastStream, RollingHorizonSolver

    actual = np.tile(np.asarray(fp.mci), 3)[:fp.T + 16]
    stream = ForecastStream(actual=actual, horizon=fp.T, seed=0)
    solver = RollingHorizonSolver(fp, stream, policy=CR1(lam=1.4),
                                  cold_steps=60, warm_steps=20,
                                  guard_recompiles=True)
    solver.run_scanned(4)                  # day 1: compiles
    solver.run_scanned(4)                  # day 2: guarded by the solver
    with recompile_guard(0, label="day 3"):
        solver.run_scanned(4)              # day 3: provably compile-free


def test_run_guard_ticks(fp):
    """Per-tick warm re-solves after the first warm tick run under the
    solver's guard — a drifting static argument would raise."""
    from repro.core.streaming import ForecastStream, RollingHorizonSolver

    actual = np.tile(np.asarray(fp.mci), 3)[:fp.T + 16]
    stream = ForecastStream(actual=actual, horizon=fp.T, seed=1)
    solver = RollingHorizonSolver(fp, stream, policy=CR1(lam=1.4),
                                  cold_steps=60, warm_steps=20,
                                  adaptive_warm=False,
                                  guard_recompiles=True)
    report = solver.run(5)
    assert len(report.ticks) == 5
