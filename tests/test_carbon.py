"""Carbon signal tests (paper Fig. 1 / Fig. 11 statistics)."""
import numpy as np
import pytest

from repro.core import carbon


def test_caiso_trough_fraction_matches_paper():
    sig = carbon.caiso_2021(48)
    # Paper: "the trough can be as low as 66% of the peak in today's grid".
    assert 0.55 <= sig.peak_to_trough() <= 0.78


def test_projection_2050_deepens_trough():
    today = carbon.caiso_2021(48).peak_to_trough()
    y2050 = carbon.projection(2050, "CA").peak_to_trough()
    assert y2050 < today
    # Paper: trough as low as 40% of peak by 2050 (CA is solar-heavy).
    assert y2050 <= 0.45


def test_projection_2024_between_today_and_2050():
    t24 = carbon.projection(2024, "CA").peak_to_trough()
    t50 = carbon.projection(2050, "CA").peak_to_trough()
    assert t50 < t24 < 0.9


def test_projection_rejects_unknown_year():
    with pytest.raises(ValueError):
        carbon.projection(2030)


def test_carbon_accounting_identity():
    """CF(D) = −⟨mci, Σ_i d_i⟩ exactly (paper §V definition)."""
    rng = np.random.default_rng(0)
    mci = rng.uniform(200, 450, 48)
    D = rng.normal(size=(4, 48))
    cf = carbon.carbon_footprint_delta(mci, D)
    manual = -(mci * D.sum(axis=0)).sum()
    assert np.isclose(cf, manual)
    assert np.isclose(carbon.carbon_reduction(mci, D), -cf)


def test_curtail_at_high_mci_reduces_carbon():
    sig = carbon.caiso_2021(48)
    d = np.zeros(48)
    d[np.argmax(sig.mci)] = 1.0       # curtail 1 NP at the dirtiest hour
    assert carbon.carbon_reduction(sig.mci, d) > 0


def test_state_profiles_differ():
    a = carbon.projection(2050, "CA").mci
    b = carbon.projection(2050, "NY").mci
    assert not np.allclose(a, b)
