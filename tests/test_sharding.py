"""Sharding rule tests: spec legality, legalization, small-mesh compiles."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import get_config, reduced
from repro.launch import sharding as sh
from repro.launch.steps import params_shape_of


def test_param_specs_cover_all_leaves():
    cfg = reduced(get_config("deepseek-v3-671b"))
    shapes = params_shape_of(cfg)
    specs = sh.param_specs(shapes)
    s_leaves = jax.tree_util.tree_flatten(shapes)[0]
    p_leaves = jax.tree_util.tree_flatten(
        specs, is_leaf=lambda x: isinstance(x, P))[0]
    assert len(s_leaves) == len(p_leaves)
    for shp, spec in zip(s_leaves, p_leaves):
        assert len(spec) <= len(shp.shape)


def test_moe_experts_sharded_on_model_axis():
    cfg = reduced(get_config("qwen3-moe-30b-a3b"))
    shapes = params_shape_of(cfg)
    specs = sh.param_specs(shapes)
    moe_spec = specs["blocks"]["l0"]["moe"]["w_gate"]
    # stacked (L, E, d, f): experts on "model", d on "data".
    assert tuple(moe_spec) == (None, "model", "data", None)


def test_attention_tp_pattern():
    cfg = reduced(get_config("qwen3-32b"))
    specs = sh.param_specs(params_shape_of(cfg))
    blk = specs["blocks"]["l0"]["attn"]
    assert tuple(blk["wq"]["w"]) == (None, "data", "model")
    assert tuple(blk["wo"]["w"]) == (None, "model", "data")
    ffn = specs["blocks"]["l0"]["ffn"]
    assert tuple(ffn["w_gate"]["w"]) == (None, "data", "model")
    assert tuple(ffn["w_down"]["w"]) == (None, "model", "data")


def test_legalize_drops_nondivisible():
    shapes = {"t": jax.ShapeDtypeStruct((50281, 64), jnp.float32)}
    specs = {"t": P("model", "data")}
    mesh_like = type("M", (), {"shape": {"model": 16, "data": 16}})()
    out = sh.legalize(shapes, specs, mesh_like)
    assert tuple(out["t"]) == (None, "data")   # 50281 % 16 != 0, 64 % 16 == 0


def test_norm_scales_replicated():
    cfg = reduced(get_config("stablelm-3b"))
    specs = sh.param_specs(params_shape_of(cfg))
    assert tuple(specs["ln_f"]["scale"]) == (None,)


@pytest.mark.slow
def test_small_mesh_compile_with_policies():
    """seq_shard / fsdp knobs still produce compilable programs."""
    from conftest import run_in_subprocess
    run_in_subprocess("""
import jax
from repro.configs import get_config, reduced, base
from repro.launch.mesh import make_test_mesh
from repro.launch.sharding import ShardingPolicy
from repro.launch.steps import lower_cell
mesh = make_test_mesh(data=2, model=2, pod=2)
cfg = reduced(get_config("qwen3-moe-30b-a3b"), layers=2, d_model=64)
for policy in (ShardingPolicy(), ShardingPolicy(fsdp_embed=False)):
    lowered, _ = lower_cell(cfg, base.ShapeCell("t", 64, 8, "train"), mesh,
                            policy)
    lowered.compile()
print("OK")
""", devices=8)
