"""In-solve convergence telemetry: configuration + host-side trace view.

The capture itself happens inside the jitted AL loop
(`repro.core.engine.al_minimize`, `EngineConfig.telemetry_every`): per-
step scalars ride the inner `lax.scan` as stacked ys and come back as
one extra aux output of the SAME dispatch — no host callbacks, no extra
device round-trips. This module is the host-side half: `TelemetryConfig`
is the user-facing knob (`SolveContext(telemetry=TelemetryConfig(...))`)
and `ConvergenceTrace` is the numpy view of one solve's trace that
lands in `result.extras["telemetry"]`.

Deliberately import-light: no `repro.core` (or jax) imports at module
scope, so `repro.obs` never participates in the core import cycle and
the report CLI can load traces without touching the engine.
"""
from __future__ import annotations

import dataclasses
from typing import Iterator

import numpy as np

__all__ = ["TelemetryConfig", "ConvergenceTrace"]


@dataclasses.dataclass(frozen=True)
class TelemetryConfig:
    """Knobs for in-solve convergence telemetry.

    Attributes:
      every: sample the trace every `every` inner steps (>= 1). The
        sample count is `total_inner_steps // every`, fixed at trace
        time — `every` is a static jit argument, so changing it
        recompiles (pick one cadence per run).
    """
    every: int = 10

    def __post_init__(self) -> None:
        if int(self.every) < 1:
            raise ValueError(
                f"TelemetryConfig.every must be >= 1, got {self.every}")


@dataclasses.dataclass(frozen=True)
class ConvergenceTrace:
    """One solve's convergence trace (host numpy arrays, one row/sample).

    All arrays share shape `(n_samples,)`. `objective` is the augmented
    Lagrangian value (not the raw objective — multiplier/penalty terms
    included), `grad_norm` the l2 gradient norm, `violation` the worst
    constraint residual at the post-step iterate (0 for unconstrained
    solves), `dx` the mean per-coordinate |Δx| of the step, `mu` the
    penalty weight of the round the sample came from.
    """
    step: np.ndarray
    objective: np.ndarray
    grad_norm: np.ndarray
    violation: np.ndarray
    dx: np.ndarray
    mu: np.ndarray
    step_scale: float

    @classmethod
    def from_aux(cls, tel: dict) -> "ConvergenceTrace":
        """Build from the engine's `aux["telemetry"]` dict (one solve)."""
        g2 = np.asarray(tel["grad_sq"], dtype=np.float64)
        return cls(
            step=np.asarray(tel["step"]),
            objective=np.asarray(tel["objective"]),
            grad_norm=np.sqrt(np.maximum(g2, 0.0)),
            violation=np.asarray(tel["violation"]),
            dx=np.asarray(tel["dx"]),
            mu=np.asarray(tel["mu"]),
            step_scale=float(np.asarray(tel["step_scale"]).mean()),
        )

    @classmethod
    def split(cls, tel: dict) -> tuple["ConvergenceTrace", ...]:
        """Split a stacked telemetry dict (leading lane axis) into traces.

        Day scans / loops stack per-tick telemetry along axis 0; this
        peels one `ConvergenceTrace` per lane.
        """
        leaves = {k: np.asarray(v) for k, v in tel.items()}
        n = leaves["step"].shape[0]
        return tuple(
            cls.from_aux({k: v[i] for k, v in leaves.items()})
            for i in range(n))

    @property
    def n_samples(self) -> int:
        return int(self.step.shape[0])

    def samples(self) -> Iterator[dict]:
        """Yield one JSON-able dict per sample (for the event ledger)."""
        for i in range(self.n_samples):
            yield {
                "step": int(self.step[i]),
                "objective": float(self.objective[i]),
                "grad_norm": float(self.grad_norm[i]),
                "violation": float(self.violation[i]),
                "dx": float(self.dx[i]),
                "mu": float(self.mu[i]),
            }

    def summary(self) -> dict:
        """Condensed first/last view (for logs and reports)."""
        if not self.n_samples:
            return {"n_samples": 0}
        return {
            "n_samples": self.n_samples,
            "first_objective": float(self.objective[0]),
            "last_objective": float(self.objective[-1]),
            "last_grad_norm": float(self.grad_norm[-1]),
            "last_violation": float(self.violation[-1]),
            "step_scale": self.step_scale,
        }
