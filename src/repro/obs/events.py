"""Structured run events: typed records + an atomic JSONL ledger.

One schema for everything the repo measures — streaming tick ledgers
(`core.streaming`), span timings (`obs.spans`), per-solve convergence
samples, and benchmark runs (`benchmarks/run.py`) all write through
`EventWriter`, so a single `python -m repro.obs.report run.jsonl` can
render any of them.

File format: one JSON object per line. The first record is always a
header (`{"kind": "header", "schema": N, "host": {...}}`) written when
the writer opens an empty file; `read_events` refuses files whose
header is missing or whose schema doesn't match `SCHEMA_VERSION` —
the pin that keeps old ledgers from being silently misread.

Appends are atomic: the fd is opened `O_APPEND` and each record goes
down in a single `os.write`, so concurrent writers (benchmark
subprocesses, a solver thread) interleave whole lines, never bytes.
"""
from __future__ import annotations

import dataclasses
import json
import os
from typing import Any, ClassVar, Optional

__all__ = ["SCHEMA_VERSION", "host_meta", "TickEvent", "SpanEvent",
           "TelemetryEvent", "EventWriter", "read_events"]

SCHEMA_VERSION = 1


def host_meta() -> dict:
    """Host/device fingerprint stamped into ledger headers + BENCH json.

    Imports jax lazily so report-side consumers (and tests) can call
    into `obs.events` without initializing a backend.
    """
    import jax

    devices = jax.devices()
    try:
        import jaxlib
        jaxlib_version = jaxlib.__version__
    except Exception:  # pragma: no cover - jaxlib always ships with jax
        jaxlib_version = None
    return {
        "platform": jax.default_backend(),
        "n_devices": len(devices),
        "device_kind": devices[0].device_kind if devices else None,
        "jax": jax.__version__,
        "jaxlib": jaxlib_version,
        "pallas_interpret": os.environ.get("REPRO_PALLAS_INTERPRET", ""),
    }


@dataclasses.dataclass(frozen=True)
class TickEvent:
    """One streaming tick of the rolling-horizon ledger."""
    kind: ClassVar[str] = "tick"
    tick: int
    revision: float              # ‖forecast − previous shifted‖ / ‖prev‖
    warm_steps: int              # inner-step budget actually spent
    cold: bool                   # True on the cold (tick-0 / reset) solve
    objective_proxy: Optional[float]  # carbon_reduction_pct of the plan
    latency_s: float             # wall-clock of the solve (0.0 when the
                                 # tick rode a day-scan's single dispatch)
    committed_carbon: list       # per-region kgCO2 committed this tick
    realized_carbon: list        # per-region kgCO2 at realized MCI
    migration_credit: float      # net kgCO2 saved by cross-region moves
    recompiles: int              # jit traces attributed to this tick
    dispatches: int              # device dispatches attributed to it


@dataclasses.dataclass(frozen=True)
class SpanEvent:
    """One timed span (compute-synchronized; see `obs.spans.span`)."""
    kind: ClassVar[str] = "span"
    name: str
    elapsed_s: float
    meta: Optional[dict] = None


@dataclasses.dataclass(frozen=True)
class TelemetryEvent:
    """One in-solve convergence sample, tagged with its tick."""
    kind: ClassVar[str] = "telemetry"
    tick: int
    step: int
    objective: float
    grad_norm: float
    violation: float
    dx: float
    mu: float


class EventWriter:
    """Append-only JSONL ledger with a schema-versioned header.

    Usage::

        with EventWriter("run.jsonl", tags={"policy": "cr1"}) as w:
            w.write(TickEvent(...))

    The header (schema version + `host_meta()` + tags) is written only
    when the file is empty, so re-opening an existing ledger appends
    events under the original header.
    """

    def __init__(self, path, *, tags: dict | None = None):
        self.path = os.fspath(path)
        parent = os.path.dirname(self.path)
        if parent:
            os.makedirs(parent, exist_ok=True)
        self._fd = os.open(self.path,
                           os.O_WRONLY | os.O_CREAT | os.O_APPEND, 0o644)
        if os.fstat(self._fd).st_size == 0:
            self._write_record({"kind": "header", "schema": SCHEMA_VERSION,
                                "host": host_meta(), "tags": tags or {}})

    def _write_record(self, rec: dict) -> None:
        os.write(self._fd, (json.dumps(rec) + "\n").encode())

    def write(self, event: Any) -> None:
        """Append one event (a typed record dataclass, or a plain dict)."""
        if dataclasses.is_dataclass(event) and not isinstance(event, type):
            rec = {"kind": type(event).kind, **dataclasses.asdict(event)}
        elif isinstance(event, dict):
            if "kind" not in event:
                raise ValueError("dict events need an explicit 'kind'")
            rec = event
        else:
            raise TypeError(
                f"EventWriter.write wants an event dataclass or dict, "
                f"got {type(event).__name__}")
        self._write_record(rec)

    def close(self) -> None:
        if self._fd is not None:
            os.close(self._fd)
            self._fd = None

    def __enter__(self) -> "EventWriter":
        return self

    def __exit__(self, *exc) -> None:
        self.close()


def read_events(path) -> list[dict]:
    """Read a JSONL ledger, validating the schema pin.

    Returns every record (header first). Raises `ValueError` when the
    file has no header record or the header's schema version is not
    `SCHEMA_VERSION`.
    """
    records = []
    with open(os.fspath(path), "r", encoding="utf-8") as fh:
        for line in fh:
            line = line.strip()
            if line:
                records.append(json.loads(line))
    if not records or records[0].get("kind") != "header":
        raise ValueError(
            f"{path}: not an event ledger (first record must be a "
            f"'header'; found "
            f"{records[0].get('kind') if records else 'empty file'!r})")
    schema = records[0].get("schema")
    if schema != SCHEMA_VERSION:
        raise ValueError(
            f"{path}: ledger schema {schema!r} != supported "
            f"{SCHEMA_VERSION} — re-record or use a matching reader")
    return records
