"""Span timing + profiler hooks: measure compute, not dispatch.

JAX dispatch is asynchronous — `fn(x)` returns as soon as the work is
*enqueued*, so a naive `perf_counter` pair around it times the Python
overhead, not the solve (the PR 6 benchmark-timing lesson). `span`
generalizes the fix: bind the outputs you care about to the span and it
calls `jax.block_until_ready` on them before reading the clock on
exit::

    with obs.span("cr1-solve", writer=w) as sp:
        sp.bind(solve(problem, CR1(lam=1.45)).D)
    print(sp.elapsed_s)

These are HOST-side tools. Never call `span` (or anything else that
blocks on device work) inside jit-traced code — the drlint rule
`host-sync-in-jit` fires on exactly that; in-solve telemetry rides the
dispatch as stacked aux outputs instead (`repro.obs.telemetry`).

`profile(dir)` wraps `jax.profiler.trace` for a TensorBoard-loadable
device trace of any lane, and `compile_count()` re-exports
`analysis.recompile`'s counters in pure-measurement mode.
"""
from __future__ import annotations

import contextlib
import dataclasses
import time
from typing import Any, Optional

from repro.obs.events import EventWriter, SpanEvent

__all__ = ["span", "SpanScope", "profile", "compile_count"]


@dataclasses.dataclass
class SpanScope:
    """Live handle yielded by `span`; read `elapsed_s` after the block."""
    name: str
    elapsed_s: float = 0.0
    _bound: tuple = ()

    def bind(self, *values: Any) -> Any:
        """Attach outputs to synchronize on at span exit.

        Returns the single value (or the tuple) unchanged, so call
        sites can wrap an expression in-line::

            result = sp.bind(solve(...))
        """
        self._bound = self._bound + values
        return values[0] if len(values) == 1 else values


@contextlib.contextmanager
def span(name: str, *, writer: Optional[EventWriter] = None,
         meta: dict | None = None):
    """Time a block with monotonic clocks, device-synchronized on exit.

    Any values passed to the scope's `.bind(...)` get
    `jax.block_until_ready` before the closing timestamp, so the span
    covers the device compute those values depend on — not just the
    time to enqueue it. With no bound values the span is a plain
    wall-clock timer (fine for host-side work like JSONL parsing).

    When `writer` is given, a `SpanEvent` is appended to the ledger on
    exit (including on exception — the partial timing is still real).
    """
    scope = SpanScope(name=name)
    t0 = time.perf_counter()
    try:
        yield scope
    finally:
        if scope._bound:
            import jax
            jax.block_until_ready(scope._bound)
        scope.elapsed_s = time.perf_counter() - t0
        if writer is not None:
            writer.write(SpanEvent(name=name, elapsed_s=scope.elapsed_s,
                                   meta=meta))


@contextlib.contextmanager
def profile(logdir):
    """Device-level profiler around any lane (TensorBoard trace).

    Thin wrapper over `jax.profiler.trace(logdir)` so call sites only
    touch `repro.obs`::

        with obs.profile("var/profile"):
            solve_day(problem, CR1(lam=1.45), mci_stack)
    """
    import jax

    with jax.profiler.trace(str(logdir)):
        yield


def compile_count(label: str = ""):
    """Count jit traces/lowerings in a region without asserting a budget.

    Pure-measurement alias for `analysis.recompile.recompile_guard(None)`
    — yields a live `RecompileStats`; read `.traces` / `.lowerings`
    after the block. Nestable inside (or around) a failing-mode guard.
    """
    from repro.analysis.recompile import recompile_guard

    return recompile_guard(None, label=label)
