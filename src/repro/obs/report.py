"""Run report CLI: render a JSONL event ledger as a terminal summary.

    python -m repro.obs.report var/run.jsonl

Sections (each rendered only when the ledger has matching events):

  * header      — schema, host fingerprint, tags
  * convergence — per-tick ASCII curves of the in-solve telemetry
                  (objective + grad norm sparklines, final violation)
  * tick ledger — revision / budget / latency / carbon table with
                  totals, committed vs realized drift, migration credit
  * spans       — per-name count / total / mean wall time
  * recompiles  — dispatch + trace audit: which ticks compiled, which
                  rode the warm cache

This is the same reader a future coordinator's REST surface would
serve; keep it free of jax imports so it runs anywhere the ledger
lands.
"""
from __future__ import annotations

import argparse
import sys
from collections import defaultdict

from repro.obs.events import read_events

__all__ = ["main", "render"]

_BLOCKS = "▁▂▃▄▅▆▇█"


def _sparkline(values, width: int = 48) -> str:
    """Downsample to `width` columns and map onto block glyphs."""
    vals = [float(v) for v in values]
    if not vals:
        return ""
    if len(vals) > width:
        # bucket-mean downsample, preserving endpoints
        out = []
        for i in range(width):
            lo = i * len(vals) // width
            hi = max(lo + 1, (i + 1) * len(vals) // width)
            out.append(sum(vals[lo:hi]) / (hi - lo))
        vals = out
    lo, hi = min(vals), max(vals)
    if hi - lo <= 0:
        return _BLOCKS[0] * len(vals)
    return "".join(
        _BLOCKS[min(len(_BLOCKS) - 1,
                    int((v - lo) / (hi - lo) * (len(_BLOCKS) - 1)))]
        for v in vals)


def _fmt(x, nd: int = 3) -> str:
    if x is None:
        return "-"
    ax = abs(x)
    if ax != 0 and (ax >= 1e5 or ax < 10 ** -nd):
        return f"{x:.{nd}g}"
    return f"{x:,.{nd}f}"


def _render_header(out, header: dict) -> None:
    host = header.get("host", {})
    tags = header.get("tags") or {}
    out.append(f"ledger schema v{header.get('schema')}")
    out.append(
        "host: "
        f"{host.get('platform', '?')} x{host.get('n_devices', '?')} "
        f"({host.get('device_kind', '?')}), jax {host.get('jax', '?')}"
        + (f", jaxlib {host['jaxlib']}" if host.get("jaxlib") else "")
        + (f", pallas_interpret={host['pallas_interpret']}"
           if host.get("pallas_interpret") else ""))
    if tags:
        out.append("tags: " + ", ".join(f"{k}={v}"
                                        for k, v in sorted(tags.items())))


def _render_convergence(out, tel_events: list[dict]) -> None:
    by_tick = defaultdict(list)
    for ev in tel_events:
        by_tick[ev.get("tick", 0)].append(ev)
    out.append("")
    out.append(f"== convergence ({len(tel_events)} samples, "
               f"{len(by_tick)} solves) ==")
    for tick in sorted(by_tick):
        rows = sorted(by_tick[tick], key=lambda e: e["step"])
        obj = [e["objective"] for e in rows]
        gn = [e["grad_norm"] for e in rows]
        viol = [e["violation"] for e in rows]
        out.append(f"tick {tick}: {len(rows)} samples, "
                   f"steps {rows[0]['step']}..{rows[-1]['step']}, "
                   f"mu {_fmt(rows[0]['mu'])} -> {_fmt(rows[-1]['mu'])}")
        out.append(f"  objective {_sparkline(obj)}  "
                   f"{_fmt(obj[0])} -> {_fmt(obj[-1])}")
        out.append(f"  grad norm {_sparkline(gn)}  "
                   f"{_fmt(gn[0])} -> {_fmt(gn[-1])}")
        if any(v > 0 for v in viol):
            out.append(f"  violation {_sparkline(viol)}  "
                       f"max {_fmt(max(viol))}, final {_fmt(viol[-1])}")
        else:
            out.append("  violation 0 throughout (unconstrained lane)")


def _render_ticks(out, ticks: list[dict]) -> None:
    ticks = sorted(ticks, key=lambda e: e["tick"])
    out.append("")
    out.append(f"== tick ledger ({len(ticks)} ticks) ==")
    out.append("  tick  mode  steps  revision  latency_s  committed  "
               "realized  credit  recompiles")
    tot_c = tot_r = tot_m = 0.0
    for ev in ticks:
        c = sum(ev.get("committed_carbon") or [0.0])
        r = sum(ev.get("realized_carbon") or [0.0])
        m = ev.get("migration_credit") or 0.0
        tot_c, tot_r, tot_m = tot_c + c, tot_r + r, tot_m + m
        out.append(
            f"  {ev['tick']:>4d}  {'cold' if ev.get('cold') else 'warm'}"
            f"  {ev.get('warm_steps', 0):>5d}"
            f"  {_fmt(ev.get('revision'), 3):>8s}"
            f"  {_fmt(ev.get('latency_s'), 3):>9s}"
            f"  {_fmt(c, 1):>9s}  {_fmt(r, 1):>8s}"
            f"  {_fmt(m, 1):>6s}  {ev.get('recompiles', 0):>10d}")
    out.append(f"  total committed {_fmt(tot_c, 1)} kgCO2, realized "
               f"{_fmt(tot_r, 1)} kgCO2 "
               f"(drift {_fmt(tot_r - tot_c, 1)}), migration credit "
               f"{_fmt(tot_m, 1)} kgCO2")
    regions = max(len(ev.get("committed_carbon") or []) for ev in ticks)
    if regions > 1:
        per = [sum((ev.get("realized_carbon") or [0.0] * regions)[i]
                   for ev in ticks) for i in range(regions)]
        out.append("  realized by region: "
                   + ", ".join(f"r{i}={_fmt(v, 1)}"
                               for i, v in enumerate(per)))


def _render_spans(out, spans: list[dict]) -> None:
    agg = defaultdict(list)
    for ev in spans:
        agg[ev["name"]].append(float(ev["elapsed_s"]))
    out.append("")
    out.append(f"== spans ({len(spans)} events) ==")
    out.append("  name                          n     total_s      mean_s")
    for name in sorted(agg, key=lambda n: -sum(agg[n])):
        ts = agg[name]
        out.append(f"  {name:<28s} {len(ts):>3d}  {sum(ts):>10.4f}"
                   f"  {sum(ts) / len(ts):>10.4f}")


def _render_recompile_audit(out, ticks: list[dict]) -> None:
    traced = [ev for ev in sorted(ticks, key=lambda e: e["tick"])
              if ev.get("recompiles", 0) > 0]
    warm_traced = [ev for ev in traced if not ev.get("cold")]
    dispatches = sum(ev.get("dispatches", 0) for ev in ticks)
    out.append("")
    out.append("== recompile audit ==")
    out.append(f"  {dispatches} dispatch(es) over {len(ticks)} ticks, "
               f"{sum(ev.get('recompiles', 0) for ev in ticks)} jit "
               f"trace(s) in {len(traced)} tick(s)")
    if warm_traced:
        at = ", ".join(str(ev["tick"]) for ev in warm_traced)
        out.append(f"  WARNING: warm tick(s) {at} recompiled — a static "
                   f"argument drifted (see analysis.recompile_guard)")
    elif ticks:
        out.append("  warm ticks all rode the jit cache (compiles only "
                   "on cold/first solves)")


def render(records: list[dict]) -> str:
    """Format a parsed ledger (header-first record list) as the report."""
    out: list[str] = []
    _render_header(out, records[0])
    by_kind = defaultdict(list)
    for rec in records[1:]:
        by_kind[rec.get("kind")].append(rec)
    if by_kind["telemetry"]:
        _render_convergence(out, by_kind["telemetry"])
    if by_kind["tick"]:
        _render_ticks(out, by_kind["tick"])
        _render_recompile_audit(out, by_kind["tick"])
    if by_kind["span"]:
        _render_spans(out, by_kind["span"])
    if not records[1:]:
        out.append("(no events)")
    return "\n".join(out)


def main(argv=None) -> int:
    parser = argparse.ArgumentParser(
        prog="python -m repro.obs.report",
        description="Render a repro JSONL event ledger as a terminal "
                    "summary (convergence curves, tick ledger, spans, "
                    "recompile audit).")
    parser.add_argument("ledger", help="path to a run .jsonl file")
    args = parser.parse_args(argv)
    try:
        records = read_events(args.ledger)
    except (OSError, ValueError) as e:
        print(f"error: {e}", file=sys.stderr)
        return 1
    print(render(records))
    return 0


if __name__ == "__main__":
    sys.exit(main())
