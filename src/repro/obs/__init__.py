"""repro.obs — structured observability for the fleet DR engine.

Four pieces, one schema:

  * `TelemetryConfig` / `ConvergenceTrace` — in-solve convergence
    telemetry, captured INSIDE the jitted AL loop as stacked aux
    outputs and surfaced as `result.extras["telemetry"]`
    (`SolveContext(telemetry=TelemetryConfig(every=10))`).
  * `EventWriter` / `read_events` — atomic, schema-versioned JSONL
    ledger of typed events (streaming ticks, spans, telemetry
    samples, benchmark runs).
  * `span` / `profile` / `compile_count` — host-side timing that
    synchronizes on device work before reading the clock, plus
    profiler and compile-counter hooks.
  * `python -m repro.obs.report run.jsonl` — terminal report
    (convergence curves, tick ledger, recompile audit).

Import discipline: `repro.obs` never imports `repro.core`, so the core
engine can depend on it without cycles.
"""
from repro.obs.events import (SCHEMA_VERSION, EventWriter, SpanEvent,
                              TelemetryEvent, TickEvent, host_meta,
                              read_events)
from repro.obs.spans import SpanScope, compile_count, profile, span
from repro.obs.telemetry import ConvergenceTrace, TelemetryConfig

__all__ = [
    "SCHEMA_VERSION", "EventWriter", "SpanEvent", "TelemetryEvent",
    "TickEvent", "host_meta", "read_events",
    "SpanScope", "compile_count", "profile", "span",
    "ConvergenceTrace", "TelemetryConfig",
]
