"""Fault-tolerant training loop: checkpoint/restart, retry, stragglers,
elastic resize.

At thousand-node scale the failure model is: (a) transient step failures
(preemption glitches, flaky collectives) — retried in place; (b) node loss —
the jit'd step raises, we restore the latest checkpoint and continue (on a
real cluster the coordinator re-schedules onto spares first); (c) persistent
shrink — `ElasticTrainer.resize()` rebuilds the mesh at the new size and
reshards the checkpoint onto it.

Straggler mitigation: per-step wall-time watchdog. Steps slower than
`straggler_factor` × the trailing median are counted; after
`straggler_patience` consecutive slow steps the runner triggers a
checkpoint + resize (dropping the slow host) rather than letting the whole
pod run at straggler speed — the standard large-run playbook.

Failure injection (`FailurePlan`) drives the integration tests.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Iterable, Iterator

import jax
import numpy as np

from repro.runtime.checkpoint import CheckpointManager


@dataclasses.dataclass
class FailurePlan:
    """Deterministic failure injection for tests."""
    fail_steps: tuple[int, ...] = ()          # raise once at these steps
    slow_steps: tuple[int, ...] = ()          # sleep to look like stragglers
    slow_seconds: float = 0.15

    def check(self, step: int, already_failed: set[int]) -> None:
        if step in self.fail_steps and step not in already_failed:
            already_failed.add(step)
            raise RuntimeError(f"injected node failure at step {step}")
        if step in self.slow_steps:
            time.sleep(self.slow_seconds)


@dataclasses.dataclass
class FTConfig:
    checkpoint_every: int = 20
    max_retries: int = 3
    straggler_factor: float = 2.5
    straggler_patience: int = 3
    keep: int = 3


class FaultTolerantRunner:
    """Wraps a jit'd train_step with checkpointing, retry and straggler
    accounting. The step function signature is
    (params, opt_state, batch) -> (params, opt_state, loss)."""

    def __init__(self, step_fn: Callable, ckpt: CheckpointManager,
                 cfg: FTConfig = FTConfig(),
                 failure_plan: FailurePlan | None = None):
        self.step_fn = step_fn
        self.ckpt = ckpt
        self.cfg = cfg
        self.plan = failure_plan or FailurePlan()
        self._failed: set[int] = set()
        self.step_times: list[float] = []
        self.events: list[dict] = []
        self.straggler_strikes = 0

    # ------------------------------------------------------------------
    def run(self, params: Any, opt_state: Any, batches: Iterable,
            start_step: int = 0, num_steps: int = 100,
            shardings: tuple = (None, None)) -> tuple[Any, Any, list[float]]:
        losses: list[float] = []
        state = {"params": params, "opt": opt_state}
        it = iter(batches)
        step = start_step
        while step < start_step + num_steps:
            batch = next(it)
            try:
                t0 = time.time()
                self.plan.check(step, self._failed)
                p, o, loss = self.step_fn(state["params"], state["opt"],
                                          batch)
                loss = float(loss)
                if not np.isfinite(loss):
                    raise FloatingPointError(f"non-finite loss at {step}")
                state["params"], state["opt"] = p, o
                dt = time.time() - t0
                self._track_straggler(step, dt)
                losses.append(loss)
                if (step + 1) % self.cfg.checkpoint_every == 0:
                    self.ckpt.save(step + 1, state)
                step += 1
            except Exception as e:  # noqa: BLE001 — restart path
                self.events.append({"step": step, "event": "failure",
                                    "error": str(e)})
                state, step = self._recover(state, params, opt_state,
                                            shardings)
        self.ckpt.save(step, state, blocking=True)
        return state["params"], state["opt"], losses

    def _recover(self, state, params0, opt0, shardings):
        """Restore latest checkpoint (or initial state) after a failure."""
        latest = self.ckpt.latest_step()
        if latest is None:
            self.events.append({"step": 0, "event": "restart_from_init"})
            return {"params": params0, "opt": opt0}, 0
        tree_like = jax.eval_shape(lambda: state)
        sh = ({"params": shardings[0], "opt": shardings[1]}
              if shardings[0] is not None else None)
        restored, step = self.ckpt.restore(tree_like, latest, sh)
        self.events.append({"step": step, "event": "restored"})
        return restored, step

    def _track_straggler(self, step: int, dt: float) -> None:
        self.step_times.append(dt)
        hist = self.step_times[-50:]
        if len(hist) < 8:
            return
        med = float(np.median(hist))
        if dt > self.cfg.straggler_factor * med:
            self.straggler_strikes += 1
            self.events.append({"step": step, "event": "straggler",
                                "dt": dt, "median": med})
        else:
            self.straggler_strikes = 0
        if self.straggler_strikes >= self.cfg.straggler_patience:
            self.events.append({"step": step, "event": "straggler_escalate"})
            self.straggler_strikes = 0
