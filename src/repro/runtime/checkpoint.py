"""Sharded checkpointing with async save and resharding restore.

Layout: <dir>/step_<N>/
  meta.json           — tree structure, shapes, dtypes, step, config hash
  <leaf-path>.npy     — one array per leaf (per-host shards on real multi-
                        host systems; the full array on single-process CPU)

Design points that matter at 1000+ nodes:
  * async: `save()` snapshots to host RAM synchronously (cheap) and writes
    to disk on a background thread — training continues during the write.
  * atomic: writes go to step_<N>.tmp then rename, so a crash mid-write
    never corrupts the latest checkpoint.
  * resharding restore: `restore(..., shardings=...)` device_puts each leaf
    with the *target* sharding — the mesh may differ from the one that
    saved (elastic resize path).
  * GC: keep the most recent `keep` checkpoints.
"""
from __future__ import annotations

import concurrent.futures
import json
import os
import pathlib
import shutil
import threading
from typing import Any

import jax
import numpy as np

_SEP = "."


def _flatten(tree: Any) -> dict[str, Any]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = _SEP.join(str(getattr(k, "key", getattr(k, "idx", k)))
                        for k in path)
        flat[key] = leaf
    return flat


class CheckpointManager:
    def __init__(self, directory: str | os.PathLike, keep: int = 3):
        self.dir = pathlib.Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.keep = keep
        self._pool = concurrent.futures.ThreadPoolExecutor(max_workers=1)
        self._pending: concurrent.futures.Future | None = None
        self._lock = threading.Lock()

    # ------------------------------------------------------------------
    def save(self, step: int, tree: Any, blocking: bool = False) -> None:
        """Snapshot to host memory now; write to disk asynchronously."""
        host = {k: np.asarray(v) for k, v in _flatten(tree).items()}
        meta = {
            "step": step,
            "leaves": {k: {"shape": list(v.shape), "dtype": str(v.dtype)}
                       for k, v in host.items()},
            "treedef": jax.tree_util.tree_structure(tree).serialize_using_proto().hex(),
        }
        self.wait()
        self._pending = self._pool.submit(self._write, step, host, meta)
        if blocking:
            self.wait()

    def _write(self, step: int, host: dict[str, np.ndarray],
               meta: dict) -> None:
        tmp = self.dir / f"step_{step:08d}.tmp"
        final = self.dir / f"step_{step:08d}"
        if tmp.exists():
            shutil.rmtree(tmp)
        tmp.mkdir(parents=True)
        for key, arr in host.items():
            np.save(tmp / f"{key}.npy", arr)
        (tmp / "meta.json").write_text(json.dumps(meta))
        if final.exists():
            shutil.rmtree(final)
        tmp.rename(final)
        self._gc()

    def wait(self) -> None:
        with self._lock:
            if self._pending is not None:
                self._pending.result()
                self._pending = None

    def _gc(self) -> None:
        steps = sorted(self.all_steps())
        for s in steps[: -self.keep]:
            shutil.rmtree(self.dir / f"step_{s:08d}", ignore_errors=True)

    # ------------------------------------------------------------------
    def all_steps(self) -> list[int]:
        return sorted(int(p.name.split("_")[1]) for p in self.dir.glob("step_*")
                      if p.is_dir() and not p.name.endswith(".tmp"))

    def latest_step(self) -> int | None:
        # Flush any in-flight async save: a restore after `save()` returned
        # must see that checkpoint (the recovery path depends on it).
        self.wait()
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, tree_like: Any, step: int | None = None,
                shardings: Any = None) -> tuple[Any, int]:
        """Restore into the structure of `tree_like`. `shardings` (optional
        matching pytree of NamedSharding) reshard onto the current mesh."""
        if step is None:
            step = self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.dir}")
        path = self.dir / f"step_{step:08d}"
        flat_keys = list(_flatten(tree_like))
        arrays = {k: np.load(path / f"{k}.npy") for k in flat_keys}
        leaves_like, treedef = jax.tree_util.tree_flatten(tree_like)
        flat_sh = (jax.tree_util.tree_flatten(shardings)[0]
                   if shardings is not None else [None] * len(leaves_like))
        out_leaves = []
        for key, like, sh in zip(flat_keys, leaves_like, flat_sh):
            arr = arrays[key].astype(like.dtype)
            out_leaves.append(jax.device_put(arr, sh) if sh is not None
                              else jax.numpy.asarray(arr))
        return jax.tree_util.tree_unflatten(treedef, out_leaves), step
