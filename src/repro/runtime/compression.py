"""Cross-pod gradient compression with error feedback.

Across pods, the baseline all-reduces fp32/bf16 gradients over the slower
inter-pod links. This module implements int8 block-quantized all-reduce with
error feedback (residual carried to the next step), cutting cross-pod bytes
~4x (bf16) / ~8x (fp32) at negligible quality cost — the classic
distributed-optimization trick the task calls for.

Used inside `shard_map(..., axis_names={"pod"})`: the pod axis is manual (we
control the collective), data/model stay under GSPMD.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

Array = jax.Array
BLOCK = 256


def _quantize(x: Array) -> tuple[Array, Array]:
    """Symmetric per-block int8. Returns (q: int8, scale: f32 per block)."""
    flat = x.astype(jnp.float32).reshape(-1)
    pad = (-flat.shape[0]) % BLOCK
    flat = jnp.pad(flat, (0, pad))
    blocks = flat.reshape(-1, BLOCK)
    scale = jnp.max(jnp.abs(blocks), axis=1, keepdims=True) / 127.0
    q = jnp.round(blocks / jnp.maximum(scale, 1e-12)).astype(jnp.int8)
    return q, scale


def _dequantize(q: Array, scale: Array, shape, dtype) -> Array:
    flat = (q.astype(jnp.float32) * scale).reshape(-1)
    n = 1
    for s in shape:
        n *= s
    return flat[:n].reshape(shape).astype(dtype)


def compressed_psum_pod(grads: Any, error: Any, axis: str = "pod",
                        ) -> tuple[Any, Any]:
    """All-reduce `grads` over `axis` in int8 with error feedback.

    Returns (mean-reduced grads, new error residuals). Must run inside a
    shard_map where `axis` is a manual axis.
    """
    n = jax.lax.psum(1, axis)  # axis size (jax.lax.axis_size is newer-jax)

    def one(g, e):
        target = g.astype(jnp.float32) + e.astype(jnp.float32)
        q, scale = _quantize(target)
        # int8 values summed in int32; scales reduced alongside.
        qsum = jax.lax.psum(q.astype(jnp.int32), axis)
        ssum = jax.lax.psum(scale, axis)  # conservative shared scale
        approx_local = _dequantize(q, scale, g.shape, jnp.float32)
        new_e = (target - approx_local).astype(e.dtype)
        # Dequantize the sum with the mean scale (all pods used similar
        # magnitudes; error feedback absorbs the mismatch).
        mean_scale = ssum / n
        out = _dequantize(qsum.astype(jnp.float32) / n, mean_scale,
                          g.shape, g.dtype)
        return out, new_e

    flat_g, tdef = jax.tree.flatten(grads)
    flat_e = jax.tree.leaves(error)
    outs = [one(g, e) for g, e in zip(flat_g, flat_e)]
    g_out = jax.tree.unflatten(tdef, [o[0] for o in outs])
    e_out = jax.tree.unflatten(tdef, [o[1] for o in outs])
    return g_out, e_out


def init_error(params: Any) -> Any:
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.bfloat16), params)
