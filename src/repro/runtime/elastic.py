"""Elastic scaling: rebuild the mesh at a new size and reshard state.

On a real cluster this runs when the scheduler grows/shrinks the job (or
Carbon Responder's DR schedule changes the chip budget — the fleet
coordinator calls `resize` when a training workload's power allocation
drops). The flow:

  1. checkpoint (or snapshot in host RAM),
  2. build the new mesh from the surviving devices,
  3. re-derive shardings from the same partition rules on the new mesh,
  4. restore with resharding device_put,
  5. re-jit the step (same step fn; XLA recompiles for the new topology).

On CPU we exercise the full path with host-platform device counts.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import numpy as np
from jax.sharding import Mesh

from repro.configs.base import ArchConfig
from repro.launch import sharding as sh
from repro.launch.steps import make_train_step
from repro.optim.adamw import AdamWConfig


def mesh_from_devices(devices, data: int, model: int) -> Mesh:
    n = data * model
    return Mesh(np.asarray(devices[:n]).reshape(data, model),
                ("data", "model"))


@dataclasses.dataclass
class ElasticState:
    mesh: Mesh
    params: Any
    opt_state: Any
    step_fn: Callable


def build(cfg: ArchConfig, mesh: Mesh, params: Any, opt_state: Any,
          opt_cfg: AdamWConfig = AdamWConfig(),
          policy: sh.ShardingPolicy = sh.ShardingPolicy()) -> ElasticState:
    pspecs = sh.param_specs(jax.eval_shape(lambda: params), policy)
    psh = sh.to_named(pspecs, mesh)
    osh = sh.to_named({"m": pspecs, "v": pspecs,
                       "step": jax.sharding.PartitionSpec()}, mesh)
    params = jax.device_put(params, psh)
    opt_state = jax.device_put(opt_state, osh)
    step = jax.jit(make_train_step(cfg, opt_cfg),
                   in_shardings=(psh, osh, None),
                   out_shardings=(psh, osh, None),
                   donate_argnums=(0, 1))
    return ElasticState(mesh=mesh, params=params, opt_state=opt_state,
                        step_fn=step)


def resize(state: ElasticState, cfg: ArchConfig, new_mesh: Mesh,
           opt_cfg: AdamWConfig = AdamWConfig(),
           policy: sh.ShardingPolicy = sh.ShardingPolicy()) -> ElasticState:
    """Reshard live state onto `new_mesh` and re-jit. Works for both grow
    and shrink; param values are preserved exactly."""
    host_params = jax.tree.map(np.asarray, state.params)
    host_opt = jax.tree.map(np.asarray, state.opt_state)
    return build(cfg, new_mesh, host_params, host_opt, opt_cfg, policy)
