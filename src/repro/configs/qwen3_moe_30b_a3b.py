"""qwen3-moe-30b-a3b [hf:Qwen/Qwen3-30B-A3B]: 48L d=2048 32H (GQA kv=4)
MoE 128 experts top-8, per-expert d_ff=768, vocab 151936, qk_norm."""
from repro.configs.base import ArchConfig, MoEConfig

CONFIG = ArchConfig(
    name="qwen3-moe-30b-a3b", family="moe",
    num_layers=48, d_model=2048, num_heads=32, num_kv_heads=4,
    head_dim=128, d_ff=768, vocab_size=151936, qk_norm=True,
    moe=MoEConfig(num_experts=128, top_k=8, d_expert_ff=768),
)
