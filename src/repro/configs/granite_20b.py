"""granite-20b [arXiv:2405.04324]: 52L d=6144 48H MQA (kv=1) d_ff=24576
vocab 49152, llama-style blocks."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="granite-20b", family="dense",
    num_layers=52, d_model=6144, num_heads=48, num_kv_heads=1,
    d_ff=24576, vocab_size=49152,
)
