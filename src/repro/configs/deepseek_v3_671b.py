"""deepseek-v3-671b [arXiv:2412.19437]: 61L d=7168 128H, MLA, MoE 256
routed top-8 + 1 shared (per-expert d_ff=2048), vocab 129280, MTP.

Simplification vs the release: all 61 layers are MoE (the release keeps the
first 3 dense) — keeps the scanned stack homogeneous; noted in DESIGN.md.
"""
from repro.configs.base import ArchConfig, MLAConfig, MoEConfig

CONFIG = ArchConfig(
    name="deepseek-v3-671b", family="moe",
    num_layers=61, d_model=7168, num_heads=128, num_kv_heads=128,
    head_dim=128, d_ff=2048, vocab_size=129280,
    mla=MLAConfig(q_lora_rank=1536, kv_lora_rank=512,
                  qk_nope_head_dim=128, qk_rope_head_dim=64, v_head_dim=128),
    moe=MoEConfig(num_experts=256, top_k=8, d_expert_ff=2048, num_shared=1),
    mtp_depth=1,
)
