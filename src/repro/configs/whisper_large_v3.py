"""whisper-large-v3 [arXiv:2212.04356]: enc-dec, 32+32L d=1280 20H
d_ff=5120 vocab 51866. Conv frontend stubbed (input_specs provides frame
embeddings, 1500 positions)."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="whisper-large-v3", family="encdec",
    num_layers=32, encoder_layers=32, encoder_seq=1500,
    d_model=1280, num_heads=20, num_kv_heads=20,
    d_ff=5120, vocab_size=51866, tie_embeddings=True,
)
