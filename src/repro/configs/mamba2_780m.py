"""mamba2-780m [arXiv:2405.21060]: 48L d=1536 attention-free SSD,
ssm_state=128, vocab 50280. Runs long_500k (O(1) state)."""
from repro.configs.base import ArchConfig, SSMConfig

CONFIG = ArchConfig(
    name="mamba2-780m", family="ssm",
    num_layers=48, d_model=1536, num_heads=48, num_kv_heads=0,
    d_ff=0, vocab_size=50280,
    ssm=SSMConfig(d_state=128, head_dim=64, expand=2, conv_kernel=4,
                  chunk=256, n_groups=1),
    long_context_ok=True, tie_embeddings=True,
)
