"""qwen2-vl-72b [arXiv:2409.12191]: 80L d=8192 64H (GQA kv=8) d_ff=29568
vocab 152064, M-RoPE (t/h/w sections), QKV bias. Vision frontend stubbed:
input_specs provides patch embeddings + 3-component position ids."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="qwen2-vl-72b", family="vlm",
    num_layers=80, d_model=8192, num_heads=64, num_kv_heads=8,
    head_dim=128, d_ff=29568, vocab_size=152064, qkv_bias=True,
    mrope_sections=(16, 24, 24), vision_tokens_frac=0.25,
)
