"""stablelm-3b [hf:stabilityai]: 32L d=2560 32H (kv=32) d_ff=6912
vocab 50304. (Release uses 25% partial rotary; we apply full RoPE —
backbone-equivalent, noted in DESIGN.md.)"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="stablelm-3b", family="dense",
    num_layers=32, d_model=2560, num_heads=32, num_kv_heads=32,
    d_ff=6912, vocab_size=50304,
)
