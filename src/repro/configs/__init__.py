"""Architecture registry + input specs + reduced smoke configs.

`get_config(arch_id)` returns the full assigned config; `reduced(cfg)`
shrinks it to a CPU-smoke size preserving the family structure;
`input_specs(cfg, shape)` returns ShapeDtypeStruct stand-ins for every model
input of a (arch × shape) cell (no device allocation — the dry-run pattern).
"""
from __future__ import annotations

import dataclasses
import importlib

import jax
import jax.numpy as jnp

from repro.configs.base import (  # noqa: F401
    ArchConfig, MLAConfig, MoEConfig, SSMConfig, ShapeCell, SHAPES,
    shape_by_name,
)

_MODULES = {
    "qwen3-moe-30b-a3b": "qwen3_moe_30b_a3b",
    "deepseek-v3-671b": "deepseek_v3_671b",
    "mamba2-780m": "mamba2_780m",
    "whisper-large-v3": "whisper_large_v3",
    "qwen1.5-110b": "qwen1_5_110b",
    "qwen3-32b": "qwen3_32b",
    "stablelm-3b": "stablelm_3b",
    "granite-20b": "granite_20b",
    "qwen2-vl-72b": "qwen2_vl_72b",
    "jamba-v0.1-52b": "jamba_v0_1_52b",
}

ARCH_IDS = tuple(_MODULES)


def get_config(arch_id: str) -> ArchConfig:
    if arch_id not in _MODULES:
        raise KeyError(f"unknown arch {arch_id!r}; known: {ARCH_IDS}")
    mod = importlib.import_module(f"repro.configs.{_MODULES[arch_id]}")
    return mod.CONFIG


def reduced(cfg: ArchConfig, layers: int = 2, d_model: int = 64,
            vocab: int = 256) -> ArchConfig:
    """Same-family tiny config for CPU smoke tests."""
    heads = max(2, min(4, cfg.num_heads))
    kv = 0 if cfg.family == "ssm" else max(1, min(2, cfg.num_kv_heads))
    updates: dict = dict(
        num_layers=layers, d_model=d_model, num_heads=heads,
        num_kv_heads=kv, head_dim=d_model // heads,
        d_ff=0 if cfg.d_ff == 0 else d_model * 2,
        vocab_size=vocab, dtype="float32", param_dtype="float32",
        remat=False,
    )
    if cfg.mrope_sections is not None:
        half = (d_model // heads) // 2
        t = max(1, half // 4)
        hw = (half - t) // 2
        updates["mrope_sections"] = (t, hw, half - t - hw)
    if cfg.moe is not None:
        # capacity_factor high enough that smoke tests never drop tokens —
        # decode (per-token groups) and forward (sequence groups) then agree.
        updates["moe"] = dataclasses.replace(
            cfg.moe, num_experts=4, top_k=2, d_expert_ff=d_model,
            capacity_factor=8.0)
    if cfg.ssm is not None:
        updates["ssm"] = dataclasses.replace(
            cfg.ssm, d_state=16, head_dim=16, chunk=8)
    if cfg.mla is not None:
        updates["mla"] = MLAConfig(q_lora_rank=32, kv_lora_rank=16,
                                   qk_nope_head_dim=16, qk_rope_head_dim=8,
                                   v_head_dim=16)
        updates["head_dim"] = 16
    if cfg.family == "hybrid":
        updates["num_layers"] = max(layers, cfg.attn_layer_period)
        updates["attn_layer_offset"] = min(cfg.attn_layer_offset,
                                           updates["num_layers"] - 1)
    if cfg.family == "encdec":
        updates["encoder_layers"] = layers
        updates["encoder_seq"] = 16
    return dataclasses.replace(cfg, **updates)


def input_specs(cfg: ArchConfig, shape: ShapeCell | str,
                ) -> dict[str, jax.ShapeDtypeStruct]:
    """ShapeDtypeStruct stand-ins for every model input of the cell."""
    if isinstance(shape, str):
        shape = shape_by_name(shape)
    B, S = shape.global_batch, shape.seq_len
    i32 = jnp.int32
    sds = jax.ShapeDtypeStruct
    adtype = {"bfloat16": jnp.bfloat16, "float32": jnp.float32}[cfg.dtype]

    if shape.kind == "train":
        if cfg.family == "encdec":
            return {"frames": sds((B, cfg.encoder_seq, cfg.d_model), adtype),
                    "tokens": sds((B, S), i32),
                    "labels": sds((B, S), i32)}
        if cfg.family == "vlm":
            sv = int(S * cfg.vision_tokens_frac)
            st = S - sv
            return {"tokens": sds((B, st), i32),
                    "vision_embeds": sds((B, sv, cfg.d_model), adtype),
                    "mrope_positions": sds((3, B, S), i32),
                    "labels": sds((B, st), i32)}
        return {"tokens": sds((B, S), i32), "labels": sds((B, S), i32)}

    if shape.kind == "prefill":
        if cfg.family == "encdec":
            return {"frames": sds((B, cfg.encoder_seq, cfg.d_model), adtype),
                    "tokens": sds((B, S), i32)}
        if cfg.family == "vlm":
            sv = int(S * cfg.vision_tokens_frac)
            return {"tokens": sds((B, S - sv), i32),
                    "vision_embeds": sds((B, sv, cfg.d_model), adtype),
                    "mrope_positions": sds((3, B, S), i32)}
        return {"tokens": sds((B, S), i32)}

    # decode: one new token against a cache of S tokens.
    from repro.models import encdec as encdec_mod
    from repro.models import transformer as tf
    if cfg.family == "encdec":
        cache = jax.eval_shape(
            lambda: encdec_mod.init_cache(cfg, B, S))
    else:
        cache = jax.eval_shape(lambda: tf.init_cache(cfg, B, S))
    return {"token": sds((B, 1), i32), "cache": cache}
