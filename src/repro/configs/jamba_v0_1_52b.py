"""jamba-v0.1-52b [arXiv:2403.19887]: 32L d=4096, Mamba:attn 7:1
(attn at offset 4 of each 8-layer block), MoE 16e top-2 every other layer,
d_ff=14336, vocab 65536. Runs long_500k (KV only in 4/32 layers).

Adaptation: Jamba's Mamba-1 blocks are implemented in the Mamba-2 SSD form
(TPU-idiomatic block-matrix scan) — see DESIGN.md §Hardware adaptation.
"""
from repro.configs.base import ArchConfig, MoEConfig, SSMConfig

CONFIG = ArchConfig(
    name="jamba-v0.1-52b", family="hybrid",
    num_layers=32, d_model=4096, num_heads=32, num_kv_heads=8,
    head_dim=128, d_ff=14336, vocab_size=65536,
    attn_layer_period=8, attn_layer_offset=4,
    moe=MoEConfig(num_experts=16, top_k=2, d_expert_ff=14336, layer_period=2),
    ssm=SSMConfig(d_state=64, head_dim=64, expand=2, conv_kernel=4,
                  chunk=256, n_groups=1),
    long_context_ok=True,
)
