"""qwen3-32b [hf:Qwen/Qwen3 family]: 64L d=5120 64H (GQA kv=8) d_ff=25600
vocab 151936, qk_norm."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="qwen3-32b", family="dense",
    num_layers=64, d_model=5120, num_heads=64, num_kv_heads=8,
    head_dim=128, d_ff=25600, vocab_size=151936, qk_norm=True,
)
