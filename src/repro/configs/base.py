"""Architecture configuration schema for the assigned model zoo.

Every assigned architecture is an `ArchConfig`; the model builders in
`repro.models` consume it. Families:

  dense   — decoder-only transformer (GQA/MQA, RoPE, SwiGLU)
  moe     — decoder-only with mixture-of-experts FFN (top-k routing)
  ssm     — Mamba-2 (SSD) attention-free stack
  hybrid  — Jamba-style interleave of Mamba + attention (+ MoE)
  encdec  — Whisper-style encoder–decoder (audio frontend stubbed)
  vlm     — decoder-only with M-RoPE + vision-patch stub (Qwen2-VL)
"""
from __future__ import annotations

import dataclasses
from typing import Literal, Sequence

Family = Literal["dense", "moe", "ssm", "hybrid", "encdec", "vlm"]


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int
    d_expert_ff: int            # per-expert hidden width
    num_shared: int = 0         # shared (always-on) experts
    layer_period: int = 1       # MoE every Nth layer (1 = every layer)
    capacity_factor: float = 1.25
    router_dtype: str = "float32"
    dispatch: str = "einsum"    # "einsum" (GShard one-hot) | "scatter"


@dataclasses.dataclass(frozen=True)
class MLAConfig:
    """DeepSeek-V3 multi-head latent attention."""
    q_lora_rank: int = 1536
    kv_lora_rank: int = 512
    qk_nope_head_dim: int = 128
    qk_rope_head_dim: int = 64
    v_head_dim: int = 128


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    """Mamba-2 (SSD) block parameters."""
    d_state: int = 128
    head_dim: int = 64
    expand: int = 2
    conv_kernel: int = 4
    chunk: int = 256
    n_groups: int = 1


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: Family
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int | None = None          # default d_model // num_heads
    # attention options
    qk_norm: bool = False                # qwen3 family
    qkv_bias: bool = False               # qwen1.5 / qwen2 family
    rope_theta: float = 1e6
    mrope_sections: tuple[int, int, int] | None = None   # qwen2-vl (t,h,w)
    mla: MLAConfig | None = None         # deepseek-v3
    # FFN / MoE
    moe: MoEConfig | None = None
    # SSM / hybrid
    ssm: SSMConfig | None = None
    attn_layer_period: int = 0           # hybrid: every Nth layer is attn
    attn_layer_offset: int = 4           # hybrid: offset within period
    # encoder–decoder
    encoder_layers: int = 0
    encoder_seq: int = 1500              # whisper frame positions
    # VLM stub
    vision_tokens_frac: float = 0.25     # share of seq that is patch embeds
    # multi-token prediction (deepseek)
    mtp_depth: int = 0
    # numerics
    dtype: str = "bfloat16"              # activation/compute dtype
    param_dtype: str = "float32"
    kv_quant: bool = False               # int8 KV cache (serving)
    remat: bool = True
    # notes for DESIGN.md / skips
    long_context_ok: bool = False        # can run long_500k decode
    tie_embeddings: bool = False

    @property
    def dh(self) -> int:
        return self.head_dim if self.head_dim else self.d_model // self.num_heads

    def param_count(self) -> int:
        """Approximate parameter count (embeddings + blocks), for roofline
        MODEL_FLOPS = 6·N·D accounting."""
        d, L = self.d_model, self.num_layers
        n = self.vocab_size * d  # embedding
        if not self.tie_embeddings:
            n += self.vocab_size * d
        dh = self.dh

        def attn_params() -> int:
            if self.mla is not None:
                m = self.mla
                qk_head = m.qk_nope_head_dim + m.qk_rope_head_dim
                p = d * m.q_lora_rank + m.q_lora_rank * self.num_heads * qk_head
                p += d * (m.kv_lora_rank + m.qk_rope_head_dim)
                p += m.kv_lora_rank * self.num_heads * (
                    m.qk_nope_head_dim + m.v_head_dim)
                p += self.num_heads * m.v_head_dim * d
                return p
            q = d * self.num_heads * dh
            kv = 2 * d * self.num_kv_heads * dh
            o = self.num_heads * dh * d
            return q + kv + o

        def ffn_params(layer: int) -> int:
            if self.moe is not None and layer % self.moe.layer_period == 0:
                e = self.moe
                per = 3 * d * e.d_expert_ff
                return (e.num_experts + e.num_shared) * per + d * e.num_experts
            return 3 * d * self.d_ff

        def ssm_params() -> int:
            s = self.ssm
            d_in = s.expand * d
            nheads = d_in // s.head_dim
            p = d * (2 * d_in + 2 * s.n_groups * s.d_state + nheads)  # in_proj
            p += s.conv_kernel * (d_in + 2 * s.n_groups * s.d_state)  # conv
            p += nheads * 2                                          # A, D
            p += d_in * d                                            # out_proj
            return p

        for layer in range(L):
            if self.family == "ssm":
                n += ssm_params()
            elif self.family == "hybrid":
                if self.attn_layer_period and \
                        layer % self.attn_layer_period == self.attn_layer_offset:
                    n += attn_params()
                else:
                    n += ssm_params()
                n += ffn_params(layer)
            else:
                n += attn_params() + ffn_params(layer)
        if self.family == "encdec":
            # encoder blocks + decoder cross-attention
            n += self.encoder_layers * (attn_params() + 3 * d * self.d_ff)
            n += L * attn_params()  # cross-attn in each decoder layer
        return n

    def active_param_count(self) -> int:
        """Active params per token (MoE top-k + shared only)."""
        if self.moe is None:
            return self.param_count()
        e = self.moe
        total = self.param_count()
        moe_layers = sum(1 for layer in range(self.num_layers)
                         if layer % e.layer_period == 0
                         and (self.family != "hybrid"))
        if self.family == "hybrid":
            moe_layers = sum(1 for layer in range(self.num_layers)
                             if layer % e.layer_period == 0)
        per = 3 * self.d_model * e.d_expert_ff
        inactive = moe_layers * (e.num_experts - e.top_k) * per
        return total - inactive


# Shape cells assigned to every LM architecture.
@dataclasses.dataclass(frozen=True)
class ShapeCell:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


SHAPES = (
    ShapeCell("train_4k", 4096, 256, "train"),
    ShapeCell("prefill_32k", 32768, 32, "prefill"),
    ShapeCell("decode_32k", 32768, 128, "decode"),
    ShapeCell("long_500k", 524288, 1, "decode"),
)


def shape_by_name(name: str) -> ShapeCell:
    for s in SHAPES:
        if s.name == name:
            return s
    raise KeyError(name)
