"""GSPMD partition rules for params, optimizer state, batches, and caches.

Baseline strategy (the paper-era "what a production fleet runs"):
  * TP ("model"): attention heads / FFN hidden / MoE experts / vocab.
  * FSDP ("data"): the d_model-sized dim of every large weight (ZeRO-style;
    GSPMD inserts the all-gathers) + batch data parallelism.
  * DP ("pod"): pure data parallelism across pods — params replicated,
    gradients all-reduced over ICI/DCN (where gradient compression applies).

Rules are name-based over the param tree path; every leaf gets an explicit
PartitionSpec so the dry-run is deterministic (no GSPMD guessing at the
top level).
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ArchConfig
from repro.launch.mesh import dp_axes, has_pod_axis


@dataclasses.dataclass(frozen=True)
class ShardingPolicy:
    """Hillclimb knobs for the §Perf iterations."""
    fsdp_embed: bool = True        # shard embedding d over "data"
    fsdp_weights: bool = True      # ZeRO weight sharding over "data";
                                   # False = replicate + grad all-reduce
                                   # (cheaper collectives when memory allows)
    fsdp_pod: bool = False         # extend FSDP over the pod axis (ZeRO-3)
    seq_shard_prefill: bool = False  # shard prefill sequence over "model"
    expert_axis: str = "model"     # mesh axis for MoE expert parallelism
    ssm_tp: bool = True            # TP-shard fused SSM projections (their
                                   # z/x/B/C/dt concat boundaries misalign
                                   # with shard boundaries -> re-layout
                                   # all-gathers; the §Perf hillclimb turns
                                   # this off)


def _leaf_rule(path: str, ndim: int, policy: ShardingPolicy) -> P:
    """PartitionSpec for a parameter leaf (ignoring any leading stack axes —
    callers prepend Nones)."""
    fsdp = "data" if policy.fsdp_weights else None
    if fsdp and policy.fsdp_pod:
        fsdp = ("data", "pod")    # ZeRO-3 across pods too (legalized away
                                  # on single-pod meshes)
    tp = "model"
    name = path.split("/")[-1]
    parent = path.split("/")[-2] if "/" in path else ""

    # Embeddings: (vocab, d) — vocab over TP, d over FSDP.
    if name == "table":
        return P(tp, fsdp if policy.fsdp_embed else None)
    # Norm scales / scalar-ish leaves: replicate.
    if name in ("scale", "A_log", "D", "dt_bias", "b", "conv_b"):
        return P(*([None] * ndim))
    # MoE stacked experts: (E, d_in, d_out).
    if parent == "moe" and name in ("w_gate", "w_up"):
        return P(policy.expert_axis, fsdp, None)
    if parent == "moe" and name == "w_down":
        return P(policy.expert_axis, None, fsdp)
    if parent == "router":
        return P(fsdp, None)
    # SSM fused projections: TP only when ssm_tp (see policy docstring).
    if parent == "in_proj":
        return P(fsdp, tp if policy.ssm_tp else None)
    if parent == "out_proj":
        return P(tp if policy.ssm_tp else None, fsdp)
    # Projections whose OUTPUT is the TP dim.
    if parent in ("wq", "wk", "wv", "wuq", "wuk", "wuv", "w_gate", "w_up"):
        return P(fsdp, tp)
    # Projections whose INPUT is the TP dim.
    if parent in ("wo", "w_down"):
        return P(tp, fsdp)
    # Low-rank/latent projections (MLA down-projections), small dense maps.
    if parent in ("wdq", "wdkv", "wkr", "proj"):
        return P(fsdp, None)
    if name == "conv_w":
        return P(None, tp if policy.ssm_tp else None)
    return P(*([None] * ndim))


def param_specs(params_shape: Any, policy: ShardingPolicy = ShardingPolicy(),
                ) -> Any:
    """Map a params (shape-)tree to a PartitionSpec tree."""

    def visit(path_keys, leaf) -> P:
        names = [getattr(k, "key", str(k)) for k in path_keys]
        path = "/".join(names)
        ndim = len(leaf.shape)
        stacked = "blocks" in names or "dec_blocks" in names \
            or "enc_blocks" in names
        base = _leaf_rule(path, ndim - (1 if stacked else 0), policy)
        spec = tuple(base)
        if stacked:
            spec = (None,) + spec
        spec = spec[:ndim] if len(spec) > ndim else spec
        spec = spec + (None,) * (ndim - len(spec))
        return P(*spec)

    return jax.tree_util.tree_map_with_path(visit, params_shape)


def batch_specs(batch_shape: dict, mesh: Mesh) -> dict:
    """PartitionSpecs for a train/prefill input batch."""
    dp = dp_axes(mesh)
    dp = dp if len(dp) > 1 else dp[0]

    def visit(path_keys, leaf) -> P:
        name = getattr(path_keys[-1], "key", str(path_keys[-1]))
        ndim = len(leaf.shape)
        if name == "mrope_positions":          # (3, B, S)
            return P(None, dp, None)
        if leaf.shape[0] == 1:                 # un-shardable batch of 1
            return P(*([None] * ndim))
        return P(dp, *([None] * (ndim - 1)))

    return jax.tree_util.tree_map_with_path(visit, batch_shape)


def cache_specs(cache_shape: Any, mesh: Mesh, batch: int) -> Any:
    """PartitionSpecs for decode caches.

    KV-like leaves (stacked (G, B, S, ...)): batch over DP when divisible,
    cache length over "model" (decode attention reduces over S — GSPMD
    inserts the partial-softmax collectives). SSM states: batch over DP,
    heads over "model".
    """
    dp = dp_axes(mesh)
    dp_size = int(np.prod([mesh.shape[a] for a in dp]))
    dp = dp if len(dp) > 1 else dp[0]
    b_axis = dp if batch % dp_size == 0 and batch >= dp_size else None

    def visit(path_keys, leaf) -> P:
        name = getattr(path_keys[-1], "key", str(path_keys[-1]))
        ndim = len(leaf.shape)
        if name in ("k", "v", "enc_k", "enc_v",
                    "k_q", "k_s", "v_q", "v_s"):   # (G,B,S,KV,Dh|1)
            if b_axis is None:
                return P(None, None, ("data", "model"), None, None)
            return P(None, b_axis, "model", None, None)
        if name in ("c_kv", "k_rope"):             # (G,B,S,r)
            if b_axis is None:
                return P(None, None, ("data", "model"), None)
            return P(None, b_axis, "model", None)
        if name == "h":                            # (G,B,H,Pd,N)
            return P(None, b_axis, "model", None, None)
        if name == "conv":                         # (G,B,K-1,convdim)
            return P(None, b_axis, None, "model")
        return P(*([None] * ndim))

    return jax.tree_util.tree_map_with_path(visit, cache_shape)


def legalize(shapes: Any, specs: Any, mesh: Mesh) -> Any:
    """Drop sharding on any dim the mesh axes don't divide evenly (e.g. a
    50280-token vocab over 16 model shards): jax requires explicit argument
    shardings to tile exactly. Falls back to replication on that dim."""

    def visit(shape_leaf, spec: P) -> P:
        dims = shape_leaf.shape
        out = []
        for i, axis in enumerate(tuple(spec) + (None,) * (len(dims)
                                                          - len(spec))):
            if axis is None:
                out.append(None)
                continue
            axes = axis if isinstance(axis, tuple) else (axis,)
            if any(a not in mesh.shape for a in axes):
                # axis absent from this mesh (e.g. "pod" on single-pod):
                # keep only the axes that exist.
                axes = tuple(a for a in axes if a in mesh.shape)
                if not axes:
                    out.append(None)
                    continue
                axis = axes if len(axes) > 1 else axes[0]
            size = int(np.prod([mesh.shape[a] for a in axes]))
            out.append(axis if dims[i] % size == 0 else None)
        return P(*out)

    shape_leaves, treedef = jax.tree_util.tree_flatten(shapes)
    spec_leaves = treedef.flatten_up_to(specs)
    out = [visit(s, p) for s, p in zip(shape_leaves, spec_leaves)]
    return jax.tree_util.tree_unflatten(treedef, out)


def to_named(tree_specs: Any, mesh: Mesh) -> Any:
    return jax.tree.map(lambda s: NamedSharding(mesh, s), tree_specs,
                        is_leaf=lambda x: isinstance(x, P))


def logits_spec(mesh: Mesh, batch: int, vocab: int) -> P:
    dp = dp_axes(mesh)
    dp_size = int(np.prod([mesh.shape[a] for a in dp]))
    dp = dp if len(dp) > 1 else dp[0]
    v_axis = "model" if vocab % mesh.shape["model"] == 0 else None
    if batch % dp_size == 0 and batch >= dp_size:
        return P(dp, None, v_axis)
    return P(None, None, v_axis)
