"""Production mesh construction.

Defined as functions (never module-level constants) so importing this module
never touches jax device state — required for the dry-run's
xla_force_host_platform_device_count trick to work.

Production target: TPU v5e pods, 256 chips each.
  single-pod:  (16, 16)      axes (data, model)
  multi-pod:   (2, 16, 16)   axes (pod, data, model)

Fleet-DR sharding: the (W, T) fleet solves in `repro.core.fleet_solver`
are row-separable over workloads, so they shard W over a 1-D mesh
(`make_fleet_mesh`, axis `FLEET_AXIS`). On CPU CI that mesh comes from
`XLA_FLAGS=--xla_force_host_platform_device_count=N` virtual devices.
"""
from __future__ import annotations

import jax
import numpy as np

#: Mesh axis name the fleet DR engine shards workloads over.
FLEET_AXIS = "fleet"


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_test_mesh(data: int = 2, model: int = 2, pod: int = 1):
    """Small mesh for CPU tests (requires the host-device env flag)."""
    if pod > 1:
        return jax.make_mesh((pod, data, model), ("pod", "data", "model"))
    return jax.make_mesh((data, model), ("data", "model"))


def make_fleet_mesh(n_devices: int | None = None):
    """1-D mesh over `n_devices` (default: all) for W-axis fleet sharding.

    Used by `repro.core.api.solve(..., ctx=SolveContext(mesh=...))`:
    workloads, per-workload multipliers, and Adam moments shard over
    `FLEET_AXIS`; the MCI trace and solver scalars stay replicated.
    """
    devs = jax.devices()
    n = len(devs) if n_devices is None else n_devices
    return jax.sharding.Mesh(np.asarray(devs[:n]), (FLEET_AXIS,))


def fleet_axis(mesh) -> str:
    """Mesh axis the fleet solvers shard W over: `FLEET_AXIS` when present,
    else the sole axis of a 1-D mesh."""
    if FLEET_AXIS in mesh.axis_names:
        return FLEET_AXIS
    if len(mesh.axis_names) == 1:
        return mesh.axis_names[0]
    raise ValueError(
        f"fleet sharding needs a {FLEET_AXIS!r} axis or a 1-D mesh; got "
        f"axes {mesh.axis_names}")


def dp_axes(mesh) -> tuple[str, ...]:
    """Axes that shard the batch (pure data parallelism)."""
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


def has_pod_axis(mesh) -> bool:
    return "pod" in mesh.axis_names
