"""Production mesh construction.

Defined as functions (never module-level constants) so importing this module
never touches jax device state — required for the dry-run's
xla_force_host_platform_device_count trick to work.

Production target: TPU v5e pods, 256 chips each.
  single-pod:  (16, 16)      axes (data, model)
  multi-pod:   (2, 16, 16)   axes (pod, data, model)
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_test_mesh(data: int = 2, model: int = 2, pod: int = 1):
    """Small mesh for CPU tests (requires the host-device env flag)."""
    if pod > 1:
        return jax.make_mesh((pod, data, model), ("pod", "data", "model"))
    return jax.make_mesh((data, model), ("data", "model"))


def dp_axes(mesh) -> tuple[str, ...]:
    """Axes that shard the batch (pure data parallelism)."""
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


def has_pod_axis(mesh) -> bool:
    return "pod" in mesh.axis_names
