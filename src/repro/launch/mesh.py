"""Production mesh construction.

Defined as functions (never module-level constants) so importing this module
never touches jax device state — required for the dry-run's
xla_force_host_platform_device_count trick to work.

Production target: TPU v5e pods, 256 chips each.
  single-pod:  (16, 16)      axes (data, model)
  multi-pod:   (2, 16, 16)   axes (pod, data, model)

Fleet-DR sharding: the (W, T) fleet solves in `repro.core.fleet_solver`
are row-separable over workloads, so they shard W over a 1-D mesh
(`make_fleet_mesh`, axis `FLEET_AXIS`). Multi-region fleets
(`FleetProblem` with an (R, T) `mci`) can instead use a 2-D
(REGION_AXIS, FLEET_AXIS) mesh — `make_fleet_mesh(regions=R)` — where
the W axis shards over *both* axes: a region-sorted fleet then lands
each region's row block on one REGION_AXIS slice, so region-local
reductions never cross the region axis. Per-region normalizers enter
sharded bodies as row-sharded vectors (`repro.core.regional.norm_specs`
builds the PartitionSpecs, including the stacked day-scan/sweep
variants); cross-region migration either runs as a host-side
post-stage on gathered aggregates (`repro.core.migration`) or — with
`SolveContext(coupled_migration=True)` — as an unsharded joint refine
(its (D, y) objective is not row-separable, so it stays off-mesh; see
`repro.core.api._coupled_migrate`). On CPU CI these meshes come from
`XLA_FLAGS=--xla_force_host_platform_device_count=N` virtual devices.
"""
from __future__ import annotations

import math

import jax
import numpy as np

#: Mesh axis name the fleet DR engine shards workloads over.
FLEET_AXIS = "fleet"

#: Mesh axis name for the region dimension of a 2-D fleet mesh.
REGION_AXIS = "region"


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_test_mesh(data: int = 2, model: int = 2, pod: int = 1):
    """Small mesh for CPU tests (requires the host-device env flag)."""
    if pod > 1:
        return jax.make_mesh((pod, data, model), ("pod", "data", "model"))
    return jax.make_mesh((data, model), ("data", "model"))


def make_fleet_mesh(n_devices: int | None = None, *, regions: int | None = None):
    """Mesh over `n_devices` (default: all) for W-axis fleet sharding.

    Used by `repro.core.api.solve(..., ctx=SolveContext(mesh=...))`:
    workloads, per-workload multipliers, and Adam moments shard over
    `FLEET_AXIS`; the MCI trace and solver scalars stay replicated.

    With `regions=R` the same devices form a 2-D
    `(REGION_AXIS, FLEET_AXIS)` mesh of shape (R, n // R) for
    multi-region fleets: a region-sorted fleet's W axis shards over
    both axes, so each region's row block lands on one REGION_AXIS
    slice. `regions=None` (the default) keeps today's 1-D layout.
    """
    devs = jax.devices()
    n = len(devs) if n_devices is None else n_devices
    if regions is None:
        return jax.sharding.Mesh(np.asarray(devs[:n]), (FLEET_AXIS,))
    if regions < 1 or n % regions:
        raise ValueError(
            f"regions={regions} must divide the device count {n}")
    grid = np.asarray(devs[:n]).reshape(regions, n // regions)
    return jax.sharding.Mesh(grid, (REGION_AXIS, FLEET_AXIS))


def fleet_axis(mesh) -> str:
    """Mesh axis the fleet solvers shard W over: `FLEET_AXIS` when present,
    else the sole axis of a 1-D mesh."""
    if FLEET_AXIS in mesh.axis_names:
        return FLEET_AXIS
    if len(mesh.axis_names) == 1:
        return mesh.axis_names[0]
    raise ValueError(
        f"fleet sharding needs a {FLEET_AXIS!r} axis or a 1-D mesh; got "
        f"axes {mesh.axis_names}")


def fleet_axes(mesh):
    """Axis name(s) the fleet solvers shard W over.

    Returns the plain string from `fleet_axis` for 1-D meshes (so
    existing `PartitionSpec`s — and their compiled-cache keys — are
    byte-identical to the pre-2-D-mesh ones) and the
    `(REGION_AXIS, FLEET_AXIS)` tuple for 2-D fleet meshes, where the
    W dimension shards over both axes.
    """
    names = mesh.axis_names
    if REGION_AXIS in names and FLEET_AXIS in names:
        return (REGION_AXIS, FLEET_AXIS)
    return fleet_axis(mesh)


def fleet_device_count(mesh) -> int:
    """Number of devices the W axis shards over (pad multiple)."""
    axes = fleet_axes(mesh)
    if isinstance(axes, str):
        return mesh.shape[axes]
    return math.prod(mesh.shape[a] for a in axes)


def dp_axes(mesh) -> tuple[str, ...]:
    """Axes that shard the batch (pure data parallelism)."""
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


def has_pod_axis(mesh) -> bool:
    return "pod" in mesh.axis_names
