import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=512")
# NOTE: the two lines above MUST run before any other import (jax locks the
# device count on first init). Everything below is ordinary code.

"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

For each cell we record:
  * compile success, wall time
  * memory_analysis()  — bytes per device (proves the sharding fits)
  * cost_analysis()    — HLO FLOPs / bytes accessed
  * collective bytes   — parsed from the compiled HLO (all-gather,
    all-reduce, reduce-scatter, all-to-all, collective-permute)
  * roofline terms for TPU v5e (197 TFLOP/s bf16, 819 GB/s HBM,
    ~50 GB/s/link ICI) — see EXPERIMENTS.md §Roofline.

Results are appended to a JSON file incrementally so a crashed run resumes.

Usage:
  python -m repro.launch.dryrun --arch qwen3-32b --shape train_4k
  python -m repro.launch.dryrun --all [--multi-pod]
  python -m repro.launch.dryrun --all --both-meshes
"""
import argparse
import json
import pathlib
import re
import time
import traceback
from typing import Any

# Hardware constants (TPU v5e).
PEAK_FLOPS = 197e12          # bf16 FLOP/s per chip
HBM_BW = 819e9               # bytes/s per chip
ICI_BW = 50e9                # bytes/s per link (~per chip, one direction)

_COLLECTIVE_RE = re.compile(
    r"=\s*(?:\(([^)]*)\)|(\S+))\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(")
_SHAPE_RE = re.compile(r"\b(f64|f32|bf16|f16|s64|s32|s16|s8|u64|u32|u16|u8|"
                       r"pred)\[([0-9,]*)\]")
_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_LIST_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")
_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s64": 8, "s32": 4,
          "s16": 2, "s8": 1, "u64": 8, "u32": 4, "u16": 2, "u8": 1, "pred": 1}


def _shape_bytes(ty: str, dims: str) -> int:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _BYTES[ty]


def _group_size(line: str) -> int:
    m = _GROUPS_RE.search(line)             # iota form [n_groups,group_size]
    if m:
        return max(int(m.group(2)), 1)
    m = _GROUPS_LIST_RE.search(line)        # explicit {{0,1,...},...}
    if m:
        return max(len(m.group(1).split(",")), 1)
    return 1


def _line_collective_bytes(line: str) -> tuple[str, float] | None:
    """(op, per-device ICI bytes) for one instruction line, else None.

    Post-optimization HLO prints operand names without shapes, so we read
    the RESULT shape (before the op name) and convert to bytes moved per
    participating device for a ring implementation of group size g:
      all-gather        : out·(g−1)/g
      reduce-scatter    : out·(g−1)     (input = out·g)
      all-reduce        : 2·out·(g−1)/g (RS + AG phases)
      all-to-all        : out·(g−1)/g
      collective-permute: out           (point-to-point)
    """
    m = _COLLECTIVE_RE.search(line)
    if not m:
        return None
    lhs = line.split("=")[0]
    if "-done" in lhs:
        return None
    op = m.group(3)
    shapes_str = m.group(1) if m.group(1) is not None else m.group(2)
    out_bytes = sum(_shape_bytes(t, d)
                    for t, d in _SHAPE_RE.findall(shapes_str))
    g = _group_size(line)
    ring = (g - 1) / g if g > 1 else 0.0
    if op == "all-reduce":
        return op, 2 * out_bytes * ring
    if op == "reduce-scatter":
        return op, out_bytes * (g - 1)
    if op == "collective-permute":
        return op, float(out_bytes)
    return op, out_bytes * ring


_COMP_HEAD_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w\.\-]+)\s*(?:\([^)]*\))?\s*"
                           r"(?:->\s*\S+\s*)?\{")
_CALLEE_RE = re.compile(r"(?:body|to_apply|calls)=%?([\w\.\-]+)")
_COND_RE = re.compile(r"condition=%?([\w\.\-]+)")
_CONST_RE = re.compile(r"constant\((\d+)\)")


def parse_collectives(hlo_text: str) -> dict[str, Any]:
    """Loop-aware per-device ICI bytes for the whole compiled module.

    XLA prints each `while` (lax.scan) body once; we build the computation
    graph, parse each loop's trip count from its condition's comparison
    constant, and multiply body collectives accordingly — otherwise an
    80-layer scanned stack under-reports collectives by 80x.
    """
    comps: dict[str, list[str]] = {}
    entry = None
    cur: list[str] | None = None
    for raw in hlo_text.splitlines():
        line = raw.strip()
        # Computation header: "[ENTRY] %name (args...) -> ret {" — args may
        # nest parens (tuples), so detect by "ends with { and is not an
        # instruction (no ' = ')".
        if line.endswith("{") and " = " not in line.split("(")[0]:
            toks = line.split("(")[0].split()
            name = None
            for t in toks:
                if t not in ("ENTRY", "HloModule") and not t.startswith("//"):
                    name = t.lstrip("%").rstrip()
                    break
            if name:
                cur = []
                comps[name] = cur
                if line.startswith("ENTRY"):
                    entry = name
                continue
        if line == "}":
            cur = None
            continue
        if cur is not None:
            cur.append(line)

    def trip_count(cond_name: str) -> int:
        consts = [int(c) for ln in comps.get(cond_name, ())
                  for c in _CONST_RE.findall(ln)]
        return max(consts) if consts else 1

    memo: dict[str, tuple[dict[str, float], dict[str, float]]] = {}

    def walk(name: str) -> tuple[dict[str, float], dict[str, float]]:
        if name in memo:
            return memo[name]
        memo[name] = ({}, {})                 # cycle guard
        totals: dict[str, float] = {}
        counts: dict[str, float] = {}
        for line in comps.get(name, ()):
            got = _line_collective_bytes(line)
            if got:
                op, nbytes = got
                totals[op] = totals.get(op, 0.0) + nbytes
                counts[op] = counts.get(op, 0.0) + 1
            mult = 1
            callee_m = _CALLEE_RE.search(line)
            if callee_m and " while(" in line:
                cond_m = _COND_RE.search(line)
                mult = trip_count(cond_m.group(1)) if cond_m else 1
            if callee_m:
                sub_t, sub_c = walk(callee_m.group(1))
                for op, v in sub_t.items():
                    totals[op] = totals.get(op, 0.0) + mult * v
                for op, v in sub_c.items():
                    counts[op] = counts.get(op, 0.0) + mult * v
        memo[name] = (totals, counts)
        return memo[name]

    totals, counts = walk(entry) if entry else ({}, {})
    return {"bytes_by_op": {k: int(v) for k, v in totals.items()},
            "counts": {k: int(v) for k, v in counts.items()},
            "total_bytes": int(sum(totals.values()))}


def roofline_terms(flops: float, hbm_bytes_per_dev: float,
                   coll_bytes_per_dev: float, chips: int) -> dict[str, float]:
    """flops is GLOBAL; bytes terms are already per-device (the SPMD module
    is a per-device program; the analytic bytes model divides by chips)."""
    return {
        "t_compute_s": flops / (chips * PEAK_FLOPS),
        "t_memory_s": hbm_bytes_per_dev / HBM_BW,
        "t_collective_s": coll_bytes_per_dev / ICI_BW,
    }


def run_cell(arch_id: str, shape_name: str, multi_pod: bool,
             policy_kwargs: dict | None = None,
             arch_override: dict | None = None) -> dict:
    import dataclasses as _dc

    import jax
    from repro.configs import get_config, input_specs, shape_by_name
    from repro.launch.mesh import make_production_mesh
    from repro.launch.sharding import ShardingPolicy
    from repro.launch.steps import lower_cell

    cfg = get_config(arch_id)
    shape = shape_by_name(shape_name)
    if arch_override:
        moe_over = {k[4:]: v for k, v in arch_override.items()
                    if k.startswith("moe.")}
        plain = {k: v for k, v in arch_override.items() if "." not in k}
        if moe_over and cfg.moe is not None:
            cfg = _dc.replace(cfg, moe=_dc.replace(cfg.moe, **moe_over))
        if plain:
            cfg = _dc.replace(cfg, **plain)
    rec: dict[str, Any] = {
        "arch": arch_id, "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "policy": {**(policy_kwargs or {}),
                   **({"override": arch_override} if arch_override else {})},
    }
    if shape_name == "long_500k" and not cfg.long_context_ok:
        rec["status"] = "skipped"
        rec["reason"] = ("full-attention arch: 500k-token KV cache is "
                        ">TB-scale; see DESIGN.md §4")
        return rec
    policy = ShardingPolicy(**(policy_kwargs or {}))
    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = mesh.devices.size
    t0 = time.time()
    try:
        lowered, bundle = lower_cell(cfg, shape, mesh, policy)
        rec["lower_s"] = round(time.time() - t0, 1)
        t1 = time.time()
        compiled = lowered.compile()
        rec["compile_s"] = round(time.time() - t1, 1)
        rec["status"] = "ok"
    except Exception as e:  # noqa: BLE001 — record and move on
        rec["status"] = "failed"
        rec["error"] = f"{type(e).__name__}: {e}"[:2000]
        rec["traceback"] = traceback.format_exc()[-2000:]
        return rec

    try:
        mem = compiled.memory_analysis()
        rec["memory"] = {
            k: int(getattr(mem, k))
            for k in ("argument_size_in_bytes", "output_size_in_bytes",
                      "temp_size_in_bytes", "generated_code_size_in_bytes")
            if hasattr(mem, k)}
        per_dev = (rec["memory"].get("argument_size_in_bytes", 0)
                   + rec["memory"].get("temp_size_in_bytes", 0))
        rec["memory"]["per_device_total_bytes"] = per_dev
    except Exception as e:  # noqa: BLE001
        rec["memory"] = {"error": str(e)[:300]}

    try:
        cost = compiled.cost_analysis()
        cost = cost[0] if isinstance(cost, (list, tuple)) else cost
        rec["cost"] = {k: float(cost[k]) for k in ("flops", "bytes accessed")
                       if k in cost}
    except Exception as e:  # noqa: BLE001
        rec["cost"] = {"error": str(e)[:300]}

    try:
        text = compiled.as_text()
        rec["collectives"] = parse_collectives(text)
        rec["hlo_lines"] = text.count("\n")
    except Exception as e:  # noqa: BLE001
        rec["collectives"] = {"error": str(e)[:300]}

    # Roofline from the ANALYTIC model (XLA:CPU cost analysis counts while
    # bodies once — see launch/analytics.py; raw HLO values kept above).
    from repro.launch.analytics import analytic_record
    ana = analytic_record(cfg, shape, chips)
    rec["analytic"] = ana
    coll = rec.get("collectives", {}).get("total_bytes", 0)
    rec["roofline"] = roofline_terms(ana["flops"],
                                     ana["hbm_bytes_per_device"],
                                     float(coll), chips)
    terms = rec["roofline"]
    rec["bottleneck"] = max(terms, key=terms.get)
    rec["step_time_s"] = max(terms.values())
    # Useful-FLOPs ratio: MODEL_FLOPS = 6·N·D (training) / 2·N·D (fwd) over
    # ACTIVE params — catches remat/dispatch/attention-quadratic overheads.
    n_active = cfg.active_param_count()
    toks = shape.global_batch * (shape.seq_len if shape.kind != "decode"
                                 else 1)
    mult = 6.0 if shape.kind == "train" else 2.0
    rec["model_flops"] = mult * n_active * toks
    rec["useful_flops_ratio"] = rec["model_flops"] / max(ana["flops"], 1.0)
    # Roofline fraction: useful model flops per second vs chip peak.
    rec["roofline_fraction"] = (rec["model_flops"] / rec["step_time_s"]
                                / (chips * PEAK_FLOPS))
    return rec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--policy", default="{}",
                    help="JSON ShardingPolicy overrides")
    ap.add_argument("--arch-override", default="{}",
                    help="JSON ArchConfig overrides, e.g."
                         " '{\"remat\": false, \"moe.dispatch\":"
                         " \"scatter\", \"param_dtype\":"
                         " \"bfloat16\"}'")
    ap.add_argument("--moe-dispatch", default=None,
                    choices=[None, "einsum", "scatter"])
    ap.add_argument("--out", default="var/dryrun.json")
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()

    from repro.configs import ARCH_IDS, SHAPES

    out = pathlib.Path(args.out)
    out.parent.mkdir(parents=True, exist_ok=True)
    records: list[dict] = []
    if out.exists():
        records = json.loads(out.read_text())

    def key_of(r: dict) -> tuple:
        return (r["arch"], r["shape"], r["mesh"],
                json.dumps(r.get("policy", {}), sort_keys=True))

    done = {key_of(r) for r in records if r.get("status") != "failed"}
    policy_kwargs = json.loads(args.policy)
    arch_override = json.loads(args.arch_override) or None

    if args.moe_dispatch:
        import dataclasses as _dc
        import repro.configs as _cfgs
        _orig = _cfgs.get_config

        def patched(arch_id):
            c = _orig(arch_id)
            if c.moe is not None:
                c = _dc.replace(c, moe=_dc.replace(
                    c.moe, dispatch=args.moe_dispatch))
            return c
        _cfgs.get_config = patched
        import repro.launch.steps  # noqa: F401

    cells: list[tuple[str, str, bool]] = []
    meshes = [True, False] if args.both_meshes else [args.multi_pod]
    if args.all:
        for mp in meshes:
            for aid in ARCH_IDS:
                for s in SHAPES:
                    cells.append((aid, s.name, mp))
    else:
        for mp in meshes:
            cells.append((args.arch, args.shape, mp))

    for aid, sname, mp in cells:
        probe = {"arch": aid, "shape": sname,
                 "mesh": "2x16x16" if mp else "16x16",
                 "policy": {**policy_kwargs,
                            **({"override": arch_override}
                               if arch_override else {})}}
        if not args.force and key_of(probe) in done:
            print(f"skip (done): {aid} × {sname} × {probe['mesh']}")
            continue
        print(f"=== {aid} × {sname} × {probe['mesh']} ===", flush=True)
        rec = run_cell(aid, sname, mp, policy_kwargs, arch_override)
        rec_summary = {k: rec.get(k) for k in
                       ("status", "lower_s", "compile_s", "bottleneck")}
        print(f"    -> {rec_summary}", flush=True)
        records = [r for r in records if key_of(r) != key_of(rec)]
        records.append(rec)
        out.write_text(json.dumps(records, indent=1))


if __name__ == "__main__":
    main()
