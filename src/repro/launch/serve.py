"""DR-aware serving driver: batched decode with admission control.

A real-time (RTS) fleet workload: requests arrive, are batched, prefilled
once and decoded step-by-step. Carbon Responder's power cap maps to an
admission/batch-size limit; the resulting queueing delay is the QoS
degradation the Dynamo penalty curves price (§IV-A1).

`serve_requests` is the example driver (examples/serve_rts.py); `ServeStats`
reports latency percentiles so the QoS ↔ power trade-off is observable.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.launch.steps import model_module
from repro.models import transformer as tf


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray            # (S,) int32
    max_new: int = 8
    arrival_s: float = 0.0
    done_s: float | None = None
    tokens: list[int] = dataclasses.field(default_factory=list)


@dataclasses.dataclass
class ServeStats:
    latencies_s: np.ndarray
    throughput_tok_s: float
    batch_size_used: int

    def p(self, q: float) -> float:
        return float(np.percentile(self.latencies_s, q))


def greedy_decode(params, cfg: ArchConfig, prompts: np.ndarray,
                  max_new: int, max_len: int) -> np.ndarray:
    """Batched prefill + greedy decode. prompts: (B, S)."""
    B, S = prompts.shape
    logits = tf.forward(params, cfg, {"tokens": jnp.asarray(prompts)})
    next_tok = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
    cache = tf.init_cache(cfg, B, max_len)
    # Warm the cache by replaying the prompt through decode steps (simple,
    # correct; a production system would fill the cache from prefill).
    for t in range(S):
        _, cache = tf.decode_step(params, cfg, cache,
                                  jnp.asarray(prompts[:, t:t + 1]), t)
    out = [next_tok]
    for i in range(max_new - 1):
        logits, cache = tf.decode_step(params, cfg, cache,
                                       out[-1][:, None], S + i)
        out.append(jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32))
    return np.stack([np.asarray(t) for t in out], axis=1)


def serve_requests(params, cfg: ArchConfig, requests: Sequence[Request],
                   max_batch: int, max_len: int = 128) -> ServeStats:
    """Admission-controlled batched serving. `max_batch` is the power knob:
    CR power caps shrink it, queueing delay rises, QoS degrades."""
    t0 = time.time()
    pending = list(requests)
    total_tokens = 0
    while pending:
        batch = pending[:max_batch]
        pending = pending[max_batch:]
        prompts = np.stack([r.prompt for r in batch])
        toks = greedy_decode(params, cfg, prompts,
                             max_new=batch[0].max_new, max_len=max_len)
        now = time.time()
        for r, row in zip(batch, toks):
            r.tokens = row.tolist()
            r.done_s = now
        total_tokens += toks.size
    lat = np.asarray([r.done_s - t0 + r.arrival_s for r in requests])
    return ServeStats(latencies_s=lat,
                      throughput_tok_s=total_tokens / max(time.time() - t0,
                                                          1e-9),
                      batch_size_used=max_batch)
