"""Step builders: train / prefill / decode, with explicit shardings.

`make_step(cfg, shape, mesh, ...)` returns (fn, example_inputs, in_shardings,
out_shardings) ready for `jax.jit(...).lower(...)` — the single entry point
shared by the dry-run, the trainers, and the serving loop.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs import ArchConfig, ShapeCell, input_specs, shape_by_name
from repro.launch import sharding as sh
from repro.launch.mesh import dp_axes
from repro.models import encdec, transformer as tf
from repro.optim.adamw import AdamWConfig, adamw_init, adamw_update, \
    apply_updates

Array = jax.Array


def model_module(cfg: ArchConfig):
    return encdec if cfg.family == "encdec" else tf


def make_loss_fn(cfg: ArchConfig) -> Callable:
    mod = model_module(cfg)
    return lambda params, batch: mod.loss_fn(params, cfg, batch)


def make_train_step(cfg: ArchConfig, opt_cfg: AdamWConfig,
                    grad_accum: int = 1) -> Callable:
    """Train step with optional gradient accumulation.

    grad_accum > 1 splits the global batch into `grad_accum` microbatches
    scanned sequentially: per-microbatch activation memory drops by the
    same factor, and the gradient all-reduce/reduce-scatter happens once
    per step regardless — the standard lever for scaling tokens/step
    without scaling collective traffic.
    """
    loss_fn = make_loss_fn(cfg)

    def train_step(params, opt_state, batch):
        if grad_accum <= 1:
            loss, grads = jax.value_and_grad(loss_fn)(params, batch)
        else:
            def split(x):
                b = x.shape[0]
                assert b % grad_accum == 0, (b, grad_accum)
                return x.reshape(grad_accum, b // grad_accum, *x.shape[1:])

            def split_batch(bt):
                out = {}
                for k, v in bt.items():
                    if k == "mrope_positions":     # (3, B, S)
                        out[k] = jnp.moveaxis(split(jnp.moveaxis(v, 0, 1)),
                                              1, 2)
                    else:
                        out[k] = split(v)
                return out

            micro = split_batch(batch)

            def body(carry, mb):
                loss_acc, grads_acc = carry
                loss_i, grads_i = jax.value_and_grad(loss_fn)(params, mb)
                grads_acc = jax.tree.map(lambda a, g: a + g, grads_acc,
                                         grads_i)
                return (loss_acc + loss_i, grads_acc), None

            zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                                 params)
            (loss_sum, grads_sum), _ = jax.lax.scan(
                body, (jnp.zeros((), jnp.float32), zeros), micro)
            loss = loss_sum / grad_accum
            grads = jax.tree.map(lambda g: g / grad_accum, grads_sum)
        updates, opt_state = adamw_update(grads, opt_state, params, opt_cfg)
        params = apply_updates(params, updates)
        return params, opt_state, loss

    return train_step


def make_prefill_step(cfg: ArchConfig) -> Callable:
    mod = model_module(cfg)

    def prefill_step(params, batch):
        if cfg.family == "encdec":
            return mod.forward(params, cfg, batch)
        return tf.forward(params, cfg, batch)

    return prefill_step


def make_decode_step(cfg: ArchConfig) -> Callable:
    mod = model_module(cfg)

    def decode_step(params, cache, token, length):
        return mod.decode_step(params, cfg, cache, token, length)

    return decode_step


@dataclasses.dataclass
class StepBundle:
    """Everything needed to jit/lower one (arch × shape × mesh) cell."""
    fn: Callable
    args_shape: tuple            # ShapeDtypeStructs (or arrays) per argument
    in_shardings: tuple
    out_shardings: Any
    donate_argnums: tuple = ()


def params_shape_of(cfg: ArchConfig) -> Any:
    mod = model_module(cfg)
    return jax.eval_shape(
        lambda: mod.init_params(cfg, jax.random.PRNGKey(0)))


def make_step_bundle(cfg: ArchConfig, shape: ShapeCell | str, mesh: Mesh,
                     policy: sh.ShardingPolicy = sh.ShardingPolicy(),
                     opt_cfg: AdamWConfig = AdamWConfig(),
                     ) -> StepBundle:
    if isinstance(shape, str):
        shape = shape_by_name(shape)
    specs = input_specs(cfg, shape)
    params_shape = params_shape_of(cfg)
    pspecs = sh.legalize(params_shape, sh.param_specs(params_shape, policy),
                         mesh)
    psh = sh.to_named(pspecs, mesh)

    if shape.kind == "train":
        if cfg.param_dtype == "bfloat16":
            # bf16 params imply the memory-lean optimizer variant.
            opt_cfg = dataclasses.replace(opt_cfg, moment_dtype="bfloat16")
        opt_shape = jax.eval_shape(lambda: adamw_init(params_shape, opt_cfg))
        ospecs = {"m": pspecs, "v": pspecs, "step": P()}
        osh = sh.to_named(ospecs, mesh)
        bsh = sh.to_named(sh.batch_specs(specs, mesh), mesh)
        fn = make_train_step(cfg, opt_cfg)
        return StepBundle(
            fn=fn,
            args_shape=(params_shape, opt_shape, specs),
            in_shardings=(psh, osh, bsh),
            out_shardings=(psh, osh, NamedSharding(mesh, P())),
            donate_argnums=(0, 1))

    if shape.kind == "prefill":
        bspecs = sh.batch_specs(specs, mesh)
        if policy.seq_shard_prefill:
            # Sequence parallelism on inputs: activations enter sharded
            # (B over dp, S over model); GSPMD gathers K/V inside attention.
            from jax.sharding import PartitionSpec as _P
            dp = sh.dp_axes(mesh)
            dp = dp if len(dp) > 1 else dp[0]
            for key_ in ("tokens",):
                if key_ in bspecs:
                    bspecs[key_] = _P(dp, "model")
        bsh = sh.to_named(sh.legalize(specs, bspecs, mesh), mesh)
        fn = make_prefill_step(cfg)
        out = NamedSharding(mesh, sh.logits_spec(mesh, shape.global_batch,
                                                 cfg.vocab_size))
        return StepBundle(fn=fn, args_shape=(params_shape, specs),
                          in_shardings=(psh, bsh), out_shardings=out)

    # decode
    cache_shape = specs["cache"]
    cspecs = sh.legalize(cache_shape,
                         sh.cache_specs(cache_shape, mesh,
                                        shape.global_batch), mesh)
    csh = sh.to_named(cspecs, mesh)
    dp = dp_axes(mesh)
    dp_size = int(np.prod([mesh.shape[a] for a in dp]))
    b_ok = shape.global_batch % dp_size == 0 and shape.global_batch >= dp_size
    tok_spec = P(dp if len(dp) > 1 else dp[0], None) if b_ok else P(None, None)
    tsh = NamedSharding(mesh, tok_spec)
    lsh = NamedSharding(mesh, P())
    fn = make_decode_step(cfg)
    logits_sh = NamedSharding(
        mesh, sh.logits_spec(mesh, shape.global_batch, cfg.vocab_size))
    length = jax.ShapeDtypeStruct((), jnp.int32)
    return StepBundle(
        fn=fn,
        args_shape=(params_shape, cache_shape, specs["token"], length),
        in_shardings=(psh, csh, tsh, lsh),
        out_shardings=(logits_sh, csh),
        donate_argnums=(1,))


def lower_cell(cfg: ArchConfig, shape: ShapeCell | str, mesh: Mesh,
               policy: sh.ShardingPolicy = sh.ShardingPolicy()):
    """jit + lower one cell (no compile). Returns (lowered, bundle)."""
    bundle = make_step_bundle(cfg, shape, mesh, policy)
    jitted = jax.jit(bundle.fn, in_shardings=bundle.in_shardings,
                     out_shardings=bundle.out_shardings,
                     donate_argnums=bundle.donate_argnums)
    with mesh:
        lowered = jitted.lower(*bundle.args_shape)
    return lowered, bundle
