"""DR-aware training driver.

Runs a real training loop (CPU-sized configs train end-to-end in this
container; full configs target TPU pods) with:
  * jit'd AdamW train step with explicit shardings,
  * fault-tolerant runner (checkpoint/restart, straggler watchdog),
  * optional Carbon Responder throttle schedule — the DR enforcement path:
    a steps-per-hour budget scaled by the fleet coordinator's schedule.

Example (the ~100M end-to-end driver used by examples/train_fleet_dr.py):
  PYTHONPATH=src python -m repro.launch.train --arch stablelm-3b \
      --reduced --steps 200 --batch 8 --seq 128 --dr-lambda 1.45
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import time
from typing import Any

import jax
import numpy as np

from repro.configs import get_config, reduced
from repro.configs.base import ShapeCell
from repro.data.pipeline import DataConfig, PrefetchingLoader
from repro.launch.steps import make_train_step, model_module
from repro.optim.adamw import AdamWConfig, adamw_init
from repro.runtime.checkpoint import CheckpointManager
from repro.runtime.ft import FailurePlan, FTConfig, FaultTolerantRunner


def train(cfg, shape: ShapeCell, steps: int, ckpt_dir: str,
          opt_cfg: AdamWConfig | None = None,
          throttle: np.ndarray | None = None,
          failure_plan: FailurePlan | None = None,
          seconds_per_hour: float = 5.0,
          log_every: int = 20) -> dict[str, Any]:
    """Returns a report dict with losses, events, and throughput."""
    opt_cfg = opt_cfg or AdamWConfig(total_steps=steps)
    mod = model_module(cfg)
    params = mod.init_params(cfg, jax.random.PRNGKey(0))
    opt_state = adamw_init(params, opt_cfg)
    step_fn = jax.jit(make_train_step(cfg, opt_cfg), donate_argnums=(0, 1))
    loader = PrefetchingLoader(cfg, shape, DataConfig())
    ckpt = CheckpointManager(ckpt_dir)
    runner = FaultTolerantRunner(step_fn, ckpt,
                                 FTConfig(checkpoint_every=max(steps // 5, 10)),
                                 failure_plan)

    losses: list[float] = []
    t_start = time.time()
    if throttle is None:
        params, opt_state, losses = runner.run(
            params, opt_state, loader, num_steps=steps)
    else:
        # DR enforcement: each simulated "hour" gets a step budget scaled
        # by the CR throttle for that hour.
        base_budget = max(1, steps // len(throttle))
        done = 0
        hour = 0
        while done < steps:
            budget = max(1, int(round(base_budget
                                      * throttle[hour % len(throttle)])))
            budget = min(budget, steps - done)
            params, opt_state, ls = runner.run(
                params, opt_state, loader, start_step=done,
                num_steps=budget)
            losses.extend(ls)
            done += budget
            hour += 1
    loader.close()
    ckpt.wait()
    wall = time.time() - t_start
    return {
        "losses": losses,
        "final_loss": losses[-1] if losses else float("nan"),
        "steps": len(losses),
        "wall_s": wall,
        "steps_per_s": len(losses) / max(wall, 1e-9),
        "events": runner.events,
        "params": params,
    }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="stablelm-3b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--layers", type=int, default=4)
    ap.add_argument("--d-model", type=int, default=256)
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ckpt-dir", default="var/ckpt")
    ap.add_argument("--dr-lambda", type=float, default=None,
                    help="enable CR1 throttling with this λ")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduced(cfg, layers=args.layers, d_model=args.d_model,
                      vocab=4096)
    shape = ShapeCell("cli", args.seq, args.batch, "train")

    throttle = None
    if args.dr_lambda is not None:
        from repro.core.carbon import caiso_2021
        from repro.core.fleet import FleetCoordinator, FleetJob
        from repro.power.model import JobPowerModel
        job = FleetJob(name=args.arch, role="train",
                       power=JobPowerModel(name=args.arch, chips=256,
                                           t_compute_s=0.4, t_step_s=0.5))
        coord = FleetCoordinator([job], caiso_2021(48), lam=args.dr_lambda)
        schedules, result = coord.plan()
        throttle = schedules[args.arch].throttle
        print(f"DR plan: carbon ↓{result.carbon_reduction_pct:.2f}%, "
              f"penalty {result.total_penalty_pct:.2f}%; "
              f"mean throttle {throttle.mean():.3f}")

    report = train(cfg, shape, args.steps, args.ckpt_dir, throttle=throttle)
    report.pop("params")
    print(json.dumps({k: (v if not isinstance(v, list) else v[-5:])
                      for k, v in report.items()}, default=str, indent=1))


if __name__ == "__main__":
    main()
