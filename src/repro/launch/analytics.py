"""Analytic FLOPs / HBM-bytes model per (arch × shape) cell.

Why this exists: XLA:CPU's HloCostAnalysis counts `while` (lax.scan) bodies
ONCE — it ignores trip counts — so a scanned 80-layer stack under-reports
flops by ~80x on the CPU dry-run backend (verified: flops(L=2) ≈ flops(L=4)).
The roofline table therefore uses this analytic per-op model (the standard
napkin: exact matmul dims summed over the real schedule), with the raw
cost_analysis values recorded alongside for reference. On a real TPU backend
cost_analysis would be authoritative.

FLOP conventions: matmul (m,k)@(k,n) = 2mkn. Training = fwd + 2x bwd
(+1x fwd recompute under full remat) = 4x fwd. Decode counts one new token
against an S-token cache.

Bytes model (per device): parameter traffic (weights read per pass +
optimizer read/write), activation traffic (~c reads+writes of each layer
boundary), KV-cache traffic for decode. Reported per device for the given
chip count.
"""
from __future__ import annotations

import dataclasses
import math

from repro.configs.base import ArchConfig, ShapeCell
from repro.models.moe import GROUP_SIZE, _capacity


def _attn_flops_token(cfg: ArchConfig, s_ctx: int) -> float:
    """Per-token attention flops with context length s_ctx (fwd)."""
    d, H, KV, Dh = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.dh
    if cfg.mla is not None:
        m = cfg.mla
        qk_head = m.qk_nope_head_dim + m.qk_rope_head_dim
        proj = 2 * d * m.q_lora_rank + 2 * m.q_lora_rank * H * qk_head
        proj += 2 * d * (m.kv_lora_rank + m.qk_rope_head_dim)
        proj += 2 * m.kv_lora_rank * H * (m.qk_nope_head_dim + m.v_head_dim)
        proj += 2 * H * m.v_head_dim * d
        quad = 2 * s_ctx * H * qk_head + 2 * s_ctx * H * m.v_head_dim
        return proj + quad
    proj = 2 * d * (H + 2 * KV) * Dh + 2 * H * Dh * d
    quad = 2 * s_ctx * H * Dh * 2          # QK^T and A·V
    return proj + quad


def _ffn_flops_token(cfg: ArchConfig, layer: int) -> float:
    if cfg.moe is not None and (cfg.family != "hybrid"
                                and layer % cfg.moe.layer_period == 0
                                or cfg.family == "hybrid"
                                and layer % cfg.moe.layer_period
                                == cfg.moe.layer_period - 1):
        e = cfg.moe
        d, f = cfg.d_model, e.d_expert_ff
        expert = e.top_k * 6 * d * f + e.num_shared * 6 * d * f
        router = 2 * d * e.num_experts
        if e.dispatch == "einsum":
            C = _capacity(GROUP_SIZE, e)
            # dispatch (gsec,gsd->egcd) + combine: 2 einsums of
            # 2·E·C·d flops per token each.
            dispatch = 2 * (2 * e.num_experts * C * d)
            return expert + router + dispatch
        return expert + router
    if cfg.d_ff == 0:
        return 0.0
    return 6 * cfg.d_model * cfg.d_ff


def _ssm_flops_token(cfg: ArchConfig) -> float:
    s = cfg.ssm
    d = cfg.d_model
    d_in = s.expand * d
    H = d_in // s.head_dim
    P, N, G, Q = s.head_dim, s.d_state, s.n_groups, s.chunk
    proj = 2 * d * (2 * d_in + 2 * G * N + H) + 2 * d_in * d
    conv = 2 * s.conv_kernel * (d_in + 2 * G * N)
    # SSD per token: scores C·Bᵀ (Q·N per head), L∘scores·X (Q·P),
    # states B⊗x (N·P), y_off C·h (N·P)
    ssd = H * (2 * Q * N + 2 * Q * P + 2 * N * P + 2 * N * P)
    return proj + conv + ssd


def _layer_flops_token(cfg: ArchConfig, layer: int, s_ctx: int) -> float:
    if cfg.family == "ssm":
        return _ssm_flops_token(cfg)
    if cfg.family == "hybrid":
        is_attn = (cfg.attn_layer_period and
                   layer % cfg.attn_layer_period == cfg.attn_layer_offset)
        mix = (_attn_flops_token(cfg, s_ctx) if is_attn
               else _ssm_flops_token(cfg))
        return mix + _ffn_flops_token(cfg, layer)
    return _attn_flops_token(cfg, s_ctx) + _ffn_flops_token(cfg, layer)


def forward_flops(cfg: ArchConfig, shape: ShapeCell) -> float:
    """Global forward flops for the cell (decode = one token/sequence)."""
    B, S = shape.global_batch, shape.seq_len
    V, d = cfg.vocab_size, cfg.d_model
    if shape.kind == "decode":
        tokens = B
        s_ctx = S
    else:
        tokens = B * S
        s_ctx = S / 2          # causal: average context length
    total = 0.0
    for layer in range(cfg.num_layers):
        total += _layer_flops_token(cfg, layer, s_ctx
                                    if cfg.family != "ssm" else 0)
    if cfg.family == "encdec":
        # encoder over its own frames + cross-attention inside decoder.
        enc_tokens = B * cfg.encoder_seq
        enc = cfg.encoder_layers * (_attn_flops_token(cfg, cfg.encoder_seq / 2)
                                    + 6 * d * cfg.d_ff)
        total_enc = enc * enc_tokens
        H, Dh = cfg.num_heads, cfg.dh
        cross_per_tok = (2 * d * H * Dh + 2 * H * Dh * d
                         + 4 * cfg.encoder_seq * H * Dh)
        total += cfg.num_layers * cross_per_tok
        head = 2 * d * V
        if shape.kind == "decode":
            return total * tokens + head * tokens + total_enc * 0.0
        return total * tokens + head * tokens + total_enc
    head = 2 * d * V
    per_tok = total + head
    flops = per_tok * tokens
    if cfg.mtp_depth and shape.kind == "train":
        # one extra layer + head over the sequence
        flops += (_layer_flops_token(cfg, 0, s_ctx) + head + 4 * d * d) \
            * tokens
    return flops


def cell_flops(cfg: ArchConfig, shape: ShapeCell) -> float:
    f = forward_flops(cfg, shape)
    if shape.kind == "train":
        mult = 3.0 + (1.0 if cfg.remat else 0.0)   # fwd + bwd(2x) + remat
        return f * mult
    return f


def _param_bytes(cfg: ArchConfig) -> float:
    bpp = {"float32": 4, "bfloat16": 2}[cfg.param_dtype]
    return cfg.param_count() * bpp


def cell_hbm_bytes(cfg: ArchConfig, shape: ShapeCell, chips: int) -> float:
    """Per-device HBM traffic per step (approximate)."""
    B, S = shape.global_batch, shape.seq_len
    d = cfg.d_model
    pbytes = _param_bytes(cfg)
    act_bpp = 2 if cfg.dtype == "bfloat16" else 4
    if shape.kind == "train":
        # weights: fwd + bwd + remat reads + grad write;
        # optimizer: read p,m,v + write p,m,v (moments follow param dtype —
        # the bf16-moments option halves this traffic and footprint).
        passes = 3 + (1 if cfg.remat else 0)
        mom_bpp = 4 if cfg.param_dtype == "float32" else 2
        opt = 6 * cfg.param_count() * mom_bpp
        weight_traffic = passes * pbytes + opt
        tokens = B * S
        act = 8 * tokens * d * act_bpp * cfg.num_layers
        return (weight_traffic + act) / chips
    if shape.kind == "prefill":
        tokens = B * S
        act = 4 * tokens * d * act_bpp * cfg.num_layers
        return (pbytes + act) / chips
    # decode: all weights once + full KV cache read + tiny activations.
    kv = 0.0
    for layer in range(cfg.num_layers):
        if cfg.family == "ssm" or (
                cfg.family == "hybrid" and not (
                cfg.attn_layer_period and
                layer % cfg.attn_layer_period == cfg.attn_layer_offset)):
            s_cfg = cfg.ssm
            d_in = s_cfg.expand * d
            H = d_in // s_cfg.head_dim
            kv += B * H * s_cfg.head_dim * s_cfg.d_state * 4
        elif cfg.mla is not None:
            m = cfg.mla
            kv += B * S * (m.kv_lora_rank + m.qk_rope_head_dim) * act_bpp
        else:
            kv_bpp = (1 + 4 / cfg.dh) if cfg.kv_quant else act_bpp
            kv += B * S * 2 * cfg.num_kv_heads * cfg.dh * kv_bpp
    act = 8 * B * d * act_bpp * cfg.num_layers
    return (pbytes + kv + act) / chips


def analytic_record(cfg: ArchConfig, shape: ShapeCell, chips: int) -> dict:
    return {
        "flops": cell_flops(cfg, shape),
        "hbm_bytes_per_device": cell_hbm_bytes(cfg, shape, chips),
        "param_count": cfg.param_count(),
        "active_param_count": cfg.active_param_count(),
    }


# ---------------------------------------------------------------------------
# Analytic per-device ICI collective bytes
# ---------------------------------------------------------------------------
def cell_ici_bytes(cfg: ArchConfig, shape: ShapeCell, data: int, model: int,
                   fsdp_weights: bool = True, pods: int = 1) -> float:
    """Per-device ICI bytes per step for the baseline sharding strategy.

    Terms (ring costs, g = group size):
      FSDP weight all-gather: P·(g−1)/g per pass (fwd, bwd, remat)
      gradient reduce-scatter (FSDP) or all-reduce (replicated): P·(g−1)/g
        or 2·P·(g−1)/g
      Megatron TP: ~2 activation all-reduces per layer per pass over the
        "model" group
      MoE all-to-all: dispatched tokens ·d ·2 (dispatch+combine) per MoE
        layer per pass
      cross-pod gradient all-reduce when pods > 1 (pure DP across pods).

    The HLO-parsed numbers are recorded raw alongside; XLA:CPU decomposes
    collectives into loop-carried permute chains that defeat byte attribution
    (over-counts ~10x), so the roofline uses this model on all three axes.
    """
    B, S = shape.global_batch, shape.seq_len
    pbytes = _param_bytes(cfg)
    act_bpp = 2 if cfg.dtype == "bfloat16" else 4
    ring_d = (data - 1) / data if data > 1 else 0.0
    ring_m = (model - 1) / model if model > 1 else 0.0
    ring_p = (pods - 1) / pods if pods > 1 else 0.0
    passes = 3 if (shape.kind == "train" and cfg.remat) else \
        (2 if shape.kind == "train" else 1)

    if shape.kind == "decode":
        tokens_per_dp = max(B // (data * pods), 1)
    else:
        tokens_per_dp = B * S // (data * pods)
    act = tokens_per_dp * cfg.d_model * act_bpp

    total = 0.0
    if shape.kind == "train":
        if fsdp_weights:
            total += passes * pbytes * ring_d       # weight all-gathers
            total += pbytes * ring_d                # grad reduce-scatter
        else:
            total += 2 * pbytes * ring_d            # grad all-reduce
        if pods > 1:
            total += 2 * pbytes * ring_p            # cross-pod grad AR
    # TP activation collectives (attention + FFN outputs per layer).
    tp_per_layer = 2 * 2 * act * ring_m
    total += cfg.num_layers * tp_per_layer * max(passes, 1)
    if cfg.family == "encdec":
        enc_act = B * cfg.encoder_seq // (data * pods) * cfg.d_model * act_bpp
        total += cfg.encoder_layers * 2 * 2 * enc_act * ring_m * passes
    # MoE all-to-all (einsum or scatter — tokens must reach their experts).
    if cfg.moe is not None:
        moe_layers = sum(
            1 for l in range(cfg.num_layers)
            if (cfg.family != "hybrid" and l % cfg.moe.layer_period == 0)
            or (cfg.family == "hybrid"
                and l % cfg.moe.layer_period == cfg.moe.layer_period - 1))
        a2a = tokens_per_dp * cfg.d_model * act_bpp * 2 * ring_m
        total += moe_layers * a2a * max(passes, 1)
    return total
