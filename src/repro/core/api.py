"""First-class DR policies and the fleet engine's single entry point.

The paper frames Carbon Responder as ONE framework with three alternative
policies — Efficient (CR1), Fair-Centralized (CR2), Fair-Decentralized
(CR3). This module makes that framing literal:

  * Policies are frozen dataclasses — `CR1(lam=...)`,
    `CR2(cap_frac=..., outer=...)`, `CR3(rho=..., tax_frac=...,
    clearing_iters=...)`, plus the closed-form baseline wrappers
    `B1(F=...)` / `B3(depth=...)` — values you can put in a list, sweep,
    compare for equality, and serialize with `dataclasses.asdict` into
    stable cache keys. Only *hyperparameters* are dataclass fields;
    execution concerns never leak into a policy's identity.

  * Each policy owns its engine backend: the objective/constraint pieces,
    the fleet-global normalizers, and the `EngineConfig` it feeds the
    shared projected-Adam + augmented-Lagrangian loop
    (`repro.core.engine.al_minimize`). CR3 additionally owns its Eq.-6
    fiscal-clearing outer loop (the coordinator lowering the carbon
    price ρ until taxes cover rebates).

  * `solve(problem, policy, ctx=SolveContext(...))` is the single entry
    point. `SolveContext` bundles everything orthogonal to policy
    semantics: device `mesh` (W-axis sharding), `donate`d buffers, the
    fused streaming tick (`shift`/`reset_mu`), `warm` starts, kernel
    dispatch, and the inner-`steps` budget (None = the policy's default).
    Every policy returns the same `FleetSolveResult`; policy-specific
    outputs (CR3's clearing ρ, fiscal balance) ride `result.extras`.

  * `sweep(problem, policies, ctx=...)` runs a whole policy grid. A
    same-family grid rides ONE XLA call: the hyper axis is vmapped
    through the engine (the Fig.-8 Pareto pattern), and with `ctx.mesh`
    the vmap nests *inside* the W-axis shard_map so fleet-scale Pareto
    fronts run sharded too (the ROADMAP's sharded-sweep follow-up, for
    every single-call policy family at once). Mixed-family grids,
    non-uniform static knobs, warm/donated contexts, and CR3-with-mesh
    fall back to an equivalent loop of `solve()` calls.

  * `POLICY_REGISTRY` maps policy names ("cr1", "cr2", "cr3", "b1",
    "b3") to their classes, so string-typed configs (CLI flags, the
    streaming controller) resolve to policy objects in one place, and
    `solve(p, "cr1")` works for quick default-hyper runs.

  * `ensemble(problem, policy, scenarios, ctx=...)` evaluates one
    policy across S Monte Carlo grid/fleet scenarios
    (`repro.core.scenario`) the same way `sweep` runs a policy grid:
    CR1/CR2 ride ONE vmapped XLA call over the scenario axis (nesting
    inside the W-axis shard_map under `ctx.mesh`), and the result's
    `.report()` distills quantile/CVaR/fairness risk
    (`repro.core.ensemble`).

Sharding contract, padding semantics, and the donated streaming tick are
documented on `repro.core.fleet_solver` (data model) and
`repro.core.engine` (loop); the policy backends here only assemble those
pieces. The legacy `fleet_solver.solve_cr{1,2,3}_fleet` entry points are
deprecated shims over this module.
"""
from __future__ import annotations

import dataclasses
import functools
import warnings
from typing import Any, ClassVar, Protocol, Sequence, runtime_checkable

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.analysis.sanitize import checked_jit
from repro.core.engine import (EngineConfig, EngineState, al_minimize,
                               al_minimize_sharded)
from repro.core.fleet_solver import (CR1_MU0, CR2_MU0, CR3_MU0,
                                     FleetProblem, FleetSolveResult,
                                     _bounds, _enter_tick, _fleet_specs,
                                     _jit_view, _pad_state, _projection,
                                     _report, _single_region_view,
                                     cr2_reference_fleet, fleet_penalties,
                                     pad_fleet, resolve_use_kernel)
from repro.core.regional import (CR1_NORM_FILLS, CR2_NORM_FILLS,
                                 cr1_norms as _cr1_norms,
                                 cr2_norms as _cr2_norms,
                                 cr3_reg_scale as _cr3_reg_scale,
                                 norm_specs as _norm_specs,
                                 pad_row_norms as _pad_row_norms,
                                 region_sum as _rsum,
                                 region_totals as _region_totals)
from repro.launch.mesh import fleet_axes, fleet_device_count
# repro.obs is import-light and never imports repro.core (no cycle).
from repro.obs.telemetry import ConvergenceTrace, TelemetryConfig

Array = jax.Array

__all__ = ["B1", "B3", "CR1", "CR2", "CR3", "DRPolicy", "DayResult",
           "POLICY_REGISTRY", "SolveContext", "configured_policy",
           "ensemble", "resolve_policy", "solve", "solve_day",
           "stack_states", "sweep"]


# ---------------------------------------------------------------------------
# Execution context + policy protocol
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class SolveContext:
    """Execution concerns of a fleet solve, bundled once for every policy.

    Attributes:
      mesh: optional 1-D device mesh (`repro.launch.mesh.make_fleet_mesh`)
        — the solve shards the W axis over it (W padded to the device
        count with inert rows; `result.state` keeps the padded shape so
        re-solves chain without re-padding).
      donate: route through a `jax.jit(donate_argnums)` twin that reuses
        the warm state's buffers in place. The passed `warm` state becomes
        invalid afterwards.
      shift: roll the warm plan this many hours inside the solve's own
        XLA call (the rolling-horizon window slide).
      reset_mu: restart the AL μ schedule at the policy's μ0 inside the
        same call (the per-tick reset; multipliers keep their prices).
      warm: a previous result's `.state` to warm-start from (cold start
        when None).
      use_kernel: Pallas kernel dispatch — None = auto (kernels on TPU,
        jnp elsewhere). Covers both the `dr_features` penalty kernel and
        the fused `al_step` inner-loop kernel (CR1/CR2 hot path).
      steps: inner Adam steps per multiplier round; None = the policy's
        `default_steps`.
      moment_dtype: storage dtype for the engine's Adam moments
        ("float32" or "bfloat16") — threaded to `EngineConfig` on the
        CR1/CR2 solo and sharded paths and `solve_day`; x always keeps a
        float32 master copy. Sweeps/ensembles stay float32.
      coupled_migration: move cross-region migration INTO the solve.
        After the base (per-region) solve, curtailment and interconnect
        flows refine *jointly* under the same AL engine — per-link
        bandwidth caps in the projection, tolls in the objective, supply
        and ceiling limits as coupled inequality residuals — then the
        flows pass `core.migration`'s exact-feasibility repair. The
        host-side post-stage stays the validation reference: the coupled
        plan is kept only at equal total curtailment and when it beats
        the post-stage on fleet-wide carbon, so enabling this never
        loses carbon. CR1/CR2 multi-region only; everything else falls
        back to the post-stage.
      sanitize: route the solve through a `checkify`-wrapped twin of the
        same jitted impl: the AL loop emits non-finite guards on the
        gradient, iterate, and multipliers (`EngineConfig.sanitize`),
        so a NaN/inf raises `repro.analysis.SanitizeError` naming the
        first failing check instead of silently corrupting the plan
        and every warm re-solve chained after it. Debug lane: CR1/CR2
        solo and `solve_day` day-scan lanes (mesh/donate/
        coupled_migration raise `NotImplementedError`), <2x wall-clock
        of the unchecked lane.
      telemetry: in-solve convergence telemetry
        (`repro.obs.TelemetryConfig`). CR1/CR2 engine lanes sample
        (objective, grad norm, max violation, |Δx|, μ) every
        `telemetry.every` inner steps INSIDE the jitted AL loop — the
        trace rides the same dispatch as stacked aux outputs (no host
        callbacks) and lands in `result.extras["telemetry"]` as a
        `repro.obs.ConvergenceTrace` (`solve_day`: one trace per tick).
        None (default) compiles zero telemetry code: the off path is
        bitwise the pre-telemetry engine, and the on path's plan is
        bitwise the off path's. Incompatible with `use_kernel` (the
        fused Pallas inner loop is opaque — raises); under a sweep it
        forces the per-policy loop lane.
    """
    mesh: Any = None
    donate: bool = False
    shift: int = 0
    reset_mu: bool = False
    warm: EngineState | None = None
    use_kernel: bool | None = None
    steps: int | None = None
    moment_dtype: str = "float32"
    coupled_migration: bool = False
    sanitize: bool = False
    telemetry: TelemetryConfig | None = None

    def resolved_steps(self, policy: "DRPolicy") -> int:
        return self.steps if self.steps is not None else policy.default_steps


@runtime_checkable
class DRPolicy(Protocol):
    """A demand-response policy: a frozen hyperparameter record that knows
    how to solve a `FleetProblem` under a `SolveContext`.

    Implementations are frozen dataclasses whose *fields are exactly the
    policy's hyperparameters* (so `dataclasses.asdict` is a stable cache
    key) with `name`/`default_steps` as ClassVars and a
    `solve(problem, ctx)` method returning a `FleetSolveResult`."""

    name: ClassVar[str]
    default_steps: ClassVar[int]

    def solve(self, problem: FleetProblem,
              ctx: SolveContext) -> FleetSolveResult: ...


#: Policy name -> policy class; the one place string-typed configs resolve.
POLICY_REGISTRY: dict[str, type] = {}


def _register(cls):
    POLICY_REGISTRY[cls.name] = cls
    return cls


def resolve_policy(policy) -> DRPolicy:
    """Coerce a registry name, policy class, or policy object to an object."""
    if isinstance(policy, str):
        try:
            return POLICY_REGISTRY[policy]()
        except KeyError:
            raise ValueError(
                f"unknown policy {policy!r}; registered policies: "
                f"{', '.join(sorted(POLICY_REGISTRY))}") from None
    if isinstance(policy, type):
        policy = policy()
    if not isinstance(policy, DRPolicy):
        raise TypeError(
            f"policy must be a DRPolicy (e.g. CR1(lam=1.45)) or a "
            f"registered name; got {type(policy).__name__}")
    return policy


def configured_policy(policy, *, lam: float = 1.45, cap_frac: float = 0.78,
                      rho: float = 0.02, tax_frac: float = 0.2,
                      outer: int = 4) -> DRPolicy:
    """`resolve_policy` with the legacy keyword knobs: registry names
    become objects configured from the matching knobs (CR1: `lam`; CR2:
    `cap_frac`/`outer`; CR3: `rho`/`tax_frac`/`outer` — `outer` defaults
    to 4, the historical streaming-controller budget); other registered
    names get default hypers; `DRPolicy` objects pass through unchanged
    (the knobs are ignored). The one place string-typed configs with
    per-policy knobs (`RollingHorizonSolver`, `FleetCoordinator`) turn
    into policy values."""
    if not isinstance(policy, str):
        return resolve_policy(policy)
    if policy not in POLICY_REGISTRY:
        raise ValueError(
            f"unknown policy {policy!r}; registered policies: "
            f"{', '.join(sorted(POLICY_REGISTRY))}")
    by_name = {
        "cr1": lambda: CR1(lam=lam),
        "cr2": lambda: CR2(cap_frac=cap_frac, outer=outer),
        "cr3": lambda: CR3(rho=rho, tax_frac=tax_frac, outer=outer),
    }
    return by_name.get(policy, POLICY_REGISTRY[policy])()


def _require_sanitizable(policy, ctx: SolveContext) -> None:
    """`sanitize=True` covers the CR1/CR2 solo engine lanes — the paths
    with checkify-wrapped jit twins. Everything else fails loudly here
    rather than silently skipping the guards the caller asked for."""
    name = getattr(policy, "name", type(policy).__name__)
    if name not in ("cr1", "cr2"):
        raise NotImplementedError(
            f"SolveContext(sanitize=True) supports CR1/CR2 (the checkify-"
            f"twinned engine lanes); policy {name!r} has no sanitized lane")
    for field, flag in (("mesh", ctx.mesh is not None),
                        ("donate", ctx.donate),
                        ("coupled_migration", ctx.coupled_migration)):
        if flag:
            raise NotImplementedError(
                f"SolveContext(sanitize=True) is a solo debug lane; "
                f"combining it with {field} is not supported — drop "
                f"{field} while sanitizing")


def _tel_every(ctx: SolveContext) -> int:
    """`EngineConfig.telemetry_every` value for this context (0 = off)."""
    return 0 if ctx.telemetry is None else int(ctx.telemetry.every)


def _require_telemetry_ok(ctx: SolveContext, use_kernel: bool) -> None:
    """Telemetry needs the generic inner scan — the fused Pallas kernel
    runs all k steps in one opaque call, so per-step samples cannot be
    captured. Fail loudly instead of silently dropping the trace."""
    if ctx.telemetry is not None and use_kernel:
        raise NotImplementedError(
            "SolveContext(telemetry=...) is incompatible with the fused "
            "al_step kernel (use_kernel=True): the kernel's inner loop "
            "is opaque to per-step telemetry — drop use_kernel (or the "
            "telemetry) for this solve")


def solve(problem: FleetProblem, policy, *,
          ctx: SolveContext | None = None) -> FleetSolveResult:
    """Solve `problem` under `policy` — the single fleet entry point.

    `policy` is a `DRPolicy` object (`CR1(lam=1.45)`, ...) or a
    `POLICY_REGISTRY` name for default hypers; `ctx` carries the
    execution concerns (mesh/donate/shift/reset_mu/warm/use_kernel/
    steps). Returns a uniform `FleetSolveResult`; policy-specific outputs
    (e.g. CR3's clearing ρ) live in `result.extras`."""
    if not isinstance(problem, FleetProblem):
        raise TypeError(
            f"solve() takes a FleetProblem (convert a DRProblem with "
            f"FleetProblem.from_problem); got {type(problem).__name__}")
    problem = _single_region_view(problem)
    ctx = ctx or SolveContext()
    policy = resolve_policy(policy)
    if ctx.sanitize:
        _require_sanitizable(policy, ctx)
    res = policy.solve(problem, ctx)
    if ctx.coupled_migration:
        return _coupled_migrate(problem, policy, res, ctx)
    return _maybe_migrate(problem, res)


def sweep(problem: FleetProblem, policies: Sequence, *,
          ctx: SolveContext | None = None) -> list[FleetSolveResult]:
    """Solve `problem` under every policy in `policies`.

    A grid from one policy family with uniform static knobs (e.g.
    `[CR1(lam=l) for l in grid]`, or CR2s sharing `outer`) rides the
    engine's vmap lane as ONE XLA call; with `ctx.mesh` the hyper vmap
    nests inside the W-axis shard_map (sharded Pareto fronts). Everything
    else — mixed families, non-uniform static knobs, donated contexts,
    CR3 with a mesh, `ctx.telemetry` (each solve gets its own
    convergence trace) — falls back to a loop of `solve()` calls with
    identical per-policy semantics, so `sweep` is always safe to call.
    Sweeps are cold solves unless warm-started:
    `ctx.donate`/`shift`/`reset_mu` force the fallback loop, where a
    shared `warm` state is reused read-only by every policy (so `donate`
    is dropped for multi-policy loops — a buffer can only be donated
    once). A *stacked* warm state (leading axis = len(policies), e.g.
    `stack_states([r.state for r in last_sweep])`) instead rides the
    CR1/CR2 vmap lane as a warm-started refinement sweep — each lane
    warm-starts from its own slice, so a Pareto front can be polished
    with a fraction of the cold step budget.

    Results are returned in `policies` order."""
    ctx = ctx or SolveContext()
    if ctx.sanitize:
        raise NotImplementedError(
            "SolveContext(sanitize=True) is a solo-solve debug lane — the "
            "vmapped sweep lanes have no checkify twins (and a silent "
            "fallback would skip the guards you asked for); sanitize "
            "policies one at a time through solve()")
    problem = _single_region_view(problem)
    pols = [resolve_policy(pl) for pl in policies]
    if not pols:
        return []
    fam = type(pols[0])
    stacked = _stacked_warm(ctx.warm, len(pols))
    warm_ok = ctx.warm is None or (stacked and ctx.mesh is None
                                   and fam in (CR1, CR2))
    # ctx.telemetry forces the loop lane: the vmapped sweep impls have
    # no telemetry plumbing, and the loop gives each policy its own
    # per-solve ConvergenceTrace in result.extras anyway.
    vmappable = (all(type(pl) is fam for pl in pols)
                 and hasattr(fam, "_sweep_family")
                 and fam._sweep_uniform(pols)
                 and warm_ok and not ctx.donate
                 and not ctx.shift and not ctx.reset_mu
                 and ctx.telemetry is None)
    if not vmappable:
        if ctx.donate and len(pols) > 1:
            ctx = dataclasses.replace(ctx, donate=False)
        if stacked:
            res = [pl.solve(problem, dataclasses.replace(
                       ctx, warm=jax.tree_util.tree_map(
                           lambda a, i=i: a[i], ctx.warm)))
                   for i, pl in enumerate(pols)]
        else:
            res = [pl.solve(problem, ctx) for pl in pols]
    else:
        res = fam._sweep_family(problem, pols, ctx)
    if ctx.coupled_migration:
        return [_coupled_migrate(problem, pl, r, ctx)
                for pl, r in zip(pols, res)]
    return [_maybe_migrate(problem, r) for r in res]


def stack_states(states: Sequence[EngineState]) -> EngineState:
    """Stack per-lane `EngineState`s (e.g. `[r.state for r in sweep(...)]`)
    along a new leading axis — the warm-start shape `sweep()` expects for
    a warm refinement sweep (`ctx.warm=stack_states(...)`).

    Leaf shapes must agree across lanes; multi-region and mesh-padded
    states keep the same (W, T) leaf layout as single-region ones, but a
    mesh-padded state (W rounded up to the device grid) cannot stack
    with an unpadded one — re-solve on the same mesh, or slice back to
    the true fleet, before stacking. Mismatches raise here with the
    offending lane instead of deep inside `jnp.stack`."""
    states = list(states)
    if not states:
        raise ValueError("stack_states needs at least one EngineState")
    ref = [jnp.shape(leaf) for leaf in jax.tree_util.tree_leaves(states[0])]
    for i, st in enumerate(states[1:], 1):
        shapes = [jnp.shape(leaf) for leaf in jax.tree_util.tree_leaves(st)]
        if shapes != ref:
            raise ValueError(
                f"stack_states: state {i} has leaf shapes {shapes}, but "
                f"state 0 has {ref} — all lanes must come from solves of "
                "the same (identically padded) fleet")
    return jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *states)


def _stacked_warm(warm, n: int) -> bool:
    """True when `warm` is a lane-stacked EngineState for an n-policy
    sweep (fleet plans are always 2-D, so a 3-D x means stacked)."""
    return (isinstance(warm, EngineState) and jnp.ndim(warm.x) == 3
            and warm.x.shape[0] == n)


def _maybe_migrate(p: FleetProblem, res: FleetSolveResult):
    """Cross-region migration post-stage (see `core.migration`): on
    multi-region problems with a usable topology, move curtailed batch
    load along the migration network and credit the net carbon saved.
    The committed plan D is unchanged — total curtailment and every
    penalty stay exactly as solved."""
    if (p.topology is None or not p.is_multiregion
            or not np.any(np.asarray(p.topology.bandwidth) > 0.0)):
        return res
    from repro.core.migration import fleet_migration
    plan = fleet_migration(p, np.asarray(res.D))
    wmci = np.asarray(p.mci)[np.asarray(p.region)]
    carbon_base = float((np.asarray(p.usage) * wmci).sum())
    return dataclasses.replace(
        res,
        carbon_reduction_pct=res.carbon_reduction_pct
        + 100.0 * plan.net_saved / carbon_base,
        extras={**res.extras, "migration": plan})


def _coupled_impl(p: FleetProblem, D0, hyper, refs, fr, to, bw, cost,
                  ceil, *, mode: str, steps: int, outer: int,
                  use_kernel: bool, has_ceiling: bool):
    """Joint (curtailment, interconnect-flow) refinement — the coupled
    in-loop migration solve. The primal is `z = concat([D (W, T),
    y (L, T)])` over the L positive-bandwidth links; one `al_minimize`
    call minimizes the policy objective on D minus the normalized
    toll-adjusted flow value, with link caps in the projection, per-
    region supply (movable batch curtailment >= outflow) and ceiling
    (headroom >= inflow) limits as coupled inequality residuals, and a
    total-curtailment pin back to the base plan `D0` as an equality
    residual (CR2 keeps its per-row fairness equalities alongside).

    The coupling terms segment-sum across rows, so this solve is NOT
    row-separable — it runs as one unsharded call (like the post-stage,
    the coupled refine operates at (R, T)/(L, T) aggregate scale on top
    of the fleet solve; the fused `al_step` kernel only accelerates the
    row-separable base solve that precedes it). Returns (D, y, pens)
    eps-feasible; the caller repairs y exactly via `migration._repair`.
    """
    f32 = jnp.float32
    W, T = p.usage.shape
    L = bw.shape[0]
    mci = jnp.asarray(p.mci, f32)
    R = mci.shape[0]
    region = jnp.asarray(p.region)
    usage = jnp.asarray(p.usage, f32)
    isb = jnp.asarray(p.is_batch)[:, None]
    D0 = jnp.asarray(D0, f32)
    margin = mci[fr] - mci[to] - cost[:, None]            # (L, T)
    flow_norm = 100.0 / (usage * mci[region]).sum()
    if mode == "cr1":
        obj_D, project_D, step_D = _cr1_pieces(p, use_kernel)
        eq_D = None
    else:
        obj_D, eq_D, project_D, step_D = _cr2_pieces(p, refs, use_kernel)

    movable0 = jax.ops.segment_sum(
        jnp.where(isb, jnp.maximum(usage - D0, 0.0), 0.0), region,
        num_segments=R)
    sscale = jnp.maximum(movable0.max(), 1.0)
    curt_scale = jnp.maximum(jnp.abs(D0).sum(), 1.0)
    D0_sum = D0.sum()
    bwcol = bw[:, None]

    def objective(z, hyp):
        D, y = z[:W], z[W:]
        return obj_D(D, hyp) - flow_norm * (y * margin).sum()

    def project(z):
        return jnp.concatenate(
            [project_D(z[:W]), jnp.clip(z[W:], 0.0, bwcol)])

    def eq(z, hyp):
        curt = ((z[:W].sum() - D0_sum) / curt_scale)[None]
        if eq_D is None:
            return curt
        return jnp.concatenate([eq_D(z[:W], hyp), curt])

    def ineq(z, hyp):
        D, y = z[:W], z[W:]
        movable = jax.ops.segment_sum(
            jnp.where(isb, jnp.maximum(usage - D, 0.0), 0.0), region,
            num_segments=R)
        outflow = jax.ops.segment_sum(y, fr, num_segments=R)
        res = ((movable - outflow) / sscale).ravel()
        if has_ceiling:
            load = jax.ops.segment_sum(usage - D, region, num_segments=R)
            inflow = jax.ops.segment_sum(y, to, num_segments=R)
            res = jnp.concatenate(
                [res, ((ceil - load - inflow) / sscale).ravel()])
        return res

    step = jnp.concatenate(
        [jnp.broadcast_to(jnp.asarray(step_D, f32), (W, 1)),
         jnp.full((L, 1), 0.1 * sscale, f32)])
    cfg = EngineConfig(inner_steps=steps, outer_steps=outer, mu0=10.0,
                       mu_growth=3.0)
    z0 = jnp.concatenate([D0, jnp.zeros((L, T), f32)])
    z, _ = al_minimize(objective, project, z0, hyper=hyper,
                       eq_residual=eq, ineq_residual=ineq,
                       step_scale=step, cfg=cfg)
    D = z[:W]
    return D, z[W:], fleet_penalties(p, D, use_kernel)


_COUPLED_STATIC = ("mode", "steps", "outer", "use_kernel", "has_ceiling")
_coupled_run = jax.jit(_coupled_impl, static_argnames=_COUPLED_STATIC)


def _coupled_migrate(p: FleetProblem, policy, res: FleetSolveResult,
                     ctx: SolveContext) -> FleetSolveResult:
    """In-loop coupled migration (see `SolveContext.coupled_migration`):
    jointly refine (D, flows) from the base solve's plan, repair the
    flows to exact feasibility, and keep the refined plan only when it
    preserves total curtailment (1e-3 relative) AND beats the host-side
    post-stage on fleet-wide carbon — otherwise the post-stage result is
    returned, so coupled never loses to the validation reference."""
    from repro.core.migration import (MigrationPlan, _repair,
                                      positive_links, region_aggregates)
    if (p.topology is None or not p.is_multiregion
            or type(policy) not in (CR1, CR2)):
        return _maybe_migrate(p, res)
    fr, to, bw, cost = positive_links(p.topology)
    if fr.size == 0:
        return _maybe_migrate(p, res)
    post = _maybe_migrate(p, res)
    use_kernel = resolve_use_kernel(ctx.use_kernel)
    steps = ctx.resolved_steps(policy)
    R, T = p.R, p.T
    mci = np.asarray(p.mci, float)
    ceiling = p.topology.ceiling
    has_ceiling = ceiling is not None
    if has_ceiling:
        ceil = np.asarray(ceiling, float)
        if ceil.ndim == 1:
            ceil = np.broadcast_to(ceil[:, None], (R, T))
    else:
        ceil = np.zeros((R, T))
    if type(policy) is CR1:
        hyper, refs, mode, outer = policy.lam, None, "cr1", 4
    else:
        refs = jnp.asarray(cr2_reference_fleet(p, policy.cap_frac))
        hyper, mode, outer = None, "cr2", max(4, policy.outer)
    D0 = np.asarray(res.D, float)
    D_f, y_l, pens = _coupled_run(
        _jit_view(p), jnp.asarray(D0, jnp.float32), hyper, refs,
        jnp.asarray(fr), jnp.asarray(to), jnp.asarray(bw, jnp.float32),
        jnp.asarray(cost, jnp.float32), jnp.asarray(ceil, jnp.float32),
        mode=mode, steps=steps, outer=outer, use_kernel=use_kernel,
        has_ceiling=has_ceiling)
    D_f = np.asarray(D_f, float)
    tot0 = float(D0.sum())
    if abs(float(D_f.sum()) - tot0) > 1e-3 * max(abs(tot0), 1.0):
        return post
    # Exact-feasibility repair against the refined plan's aggregates —
    # the same projection the post-stage validates with.
    cost_f = np.asarray(p.topology.cost, float)
    bw_f = np.asarray(p.topology.bandwidth, float).copy()
    np.fill_diagonal(bw_f, 0.0)
    cap = np.broadcast_to(bw_f[:, :, None], (R, R, T))
    grad = mci[:, None, :] - mci[None, :, :]
    margin = grad - cost_f[:, :, None]
    movable, headroom = region_aggregates(p, D_f)
    y = np.zeros((R, R, T))
    y[fr, to] = np.asarray(y_l, float)
    y = _repair(y, margin, cap, movable, headroom)
    plan = MigrationPlan(
        y=y, carbon_saved=float((y * grad).sum()),
        migration_cost=float((y * cost_f[:, :, None]).sum()),
        moved_total=float(y.sum()))
    wmci = mci[np.asarray(p.region)]
    carbon_base = float((np.asarray(p.usage) * wmci).sum())
    cand = _report(p, D_f, np.asarray(pens),
                   iters=res.iters + steps * outer, state=res.state)
    cand = dataclasses.replace(
        cand,
        carbon_reduction_pct=cand.carbon_reduction_pct
        + 100.0 * plan.net_saved / carbon_base,
        extras={**res.extras, "migration": plan,
                "coupled_migration": True})
    if cand.carbon_reduction_pct <= post.carbon_reduction_pct:
        return post
    return cand


def ensemble(problem: FleetProblem, policy, scenarios, *,
             ctx: SolveContext | None = None, batched: bool | None = None):
    """Evaluate `policy` across S Monte Carlo scenarios of `problem`.

    The scenario-ensemble entry point: `scenarios` is a
    `repro.core.scenario.ScenarioStack`, a scenario generator (or
    `SCENARIO_REGISTRY` name), or a sequence of those. CR1/CR2 solve all
    S scenarios as ONE vmapped XLA call (nested inside the W-axis
    shard_map when `ctx.mesh` is set); other policies loop over
    `solve()`. Returns `repro.core.ensemble.EnsembleResult`; call
    `.report()` for the quantile/CVaR/fairness risk summary. Thin
    delegate to `repro.core.ensemble.evaluate_ensemble` (kept lazy —
    the ensemble layer imports this module)."""
    if ctx is not None and ctx.sanitize:
        raise NotImplementedError(
            "SolveContext(sanitize=True) is a solo-solve debug lane — the "
            "vmapped ensemble lanes have no checkify twins; sanitize "
            "single scenarios through solve()")
    from repro.core.ensemble import evaluate_ensemble
    return evaluate_ensemble(problem, policy, scenarios, ctx=ctx,
                             batched=batched)


# ---------------------------------------------------------------------------
# Fused AL inner loop (Pallas al_step kernel) — CR1/CR2 hot path
# ---------------------------------------------------------------------------
def _al_fused_inner(p: FleetProblem, mode: str, cfg: EngineConfig, *,
                    car_norm, step_scale, coef0=0.0, scale=None, refs=None):
    """Build the `fused_inner` hook for `al_minimize`: pack this fleet's
    penalty parameters into the `al_step` kernel layout and return the
    chunked dispatcher (`repro.kernels.al_step.ops.make_fused_inner`).
    One kernel invocation runs k fused projected-Adam steps with x and
    the Adam moments VMEM-resident, instead of ~10 HBM round-trips per
    step. Works under vmap (sweep/ensemble lanes) and inside shard_map
    bodies (pass the local row block as `p`).

    Multi-region fleets hand the kernel per-ROW norms (from
    `regional.cr1_norms`/`cr2_norms`) by *folding* instead of changing
    the kernel's scalar slots: the carbon term becomes a (W, T) cvec
    over each row's region trace; CR1's per-row penalty weight
    `lam·pen_w` folds into col-6 `k` (gradient is linear in k) with
    `coef0 = 1`; CR2's per-row residual scale folds `1/scale_w` into
    both `k` and `refs` (h and coef·dpen are algebraically unchanged)
    with `scale = 1`; the per-row step scale rides rowp col 11. The
    kernel itself stays region-blind, and the single-region path packs
    the exact same arrays as before (bitwise-identical)."""
    from repro.kernels.al_step.ops import make_fused_inner, pack_rows
    lo, hi = _bounds(p)
    f32 = jnp.float32
    mci = jnp.asarray(p.mci, f32)
    k = jnp.asarray(p.k, f32)
    if mci.ndim == 2:
        cvec = -jnp.asarray(car_norm, f32)[:, None] \
            * mci[jnp.asarray(p.region)]
        if mode == "cr1":
            k = k * jnp.asarray(coef0, f32)
            coef0 = 1.0
        else:
            inv_w = 1.0 / jnp.asarray(scale, f32)
            k = k * inv_w
            refs = jnp.asarray(refs, f32) * inv_w
            scale = 1.0
    else:
        cvec = (-car_norm * mci)[None, :]
    row_base = pack_rows(jnp.asarray(p.rts_coeffs), jnp.asarray(p.betas),
                         k, jnp.asarray(p.x2_kind),
                         jnp.asarray(p.is_batch), refs=refs)
    return make_fused_inner(
        jnp.asarray(p.usage, f32), jnp.asarray(p.jobs, f32),
        lo.astype(f32), hi.astype(f32), row_base, cvec, mode=mode, cfg=cfg,
        step_scale=step_scale, coef0=coef0, scale=scale,
        day_hours=p.day_hours)


# ---------------------------------------------------------------------------
# CR1 — Efficient DR (unconstrained trade-off objective)
# ---------------------------------------------------------------------------
def _cr1_pieces(p: FleetProblem, use_kernel: bool, norms=None):
    lo, hi = _bounds(p)
    mci = jnp.asarray(p.mci)
    pen_norm, car_norm, step_scale = \
        _cr1_norms(p) if norms is None else norms

    if mci.ndim == 2:
        wmci = mci[jnp.asarray(p.region)]

        def objective(D: Array, lam) -> Array:
            return ((lam * pen_norm
                     * fleet_penalties(p, D, use_kernel)).sum()
                    - (car_norm[:, None] * D * wmci).sum())
    else:
        def objective(D: Array, lam) -> Array:
            return (lam * pen_norm * fleet_penalties(p, D, use_kernel).sum()
                    - car_norm * (D @ mci).sum())

    project = _projection(p, lo, hi)
    return objective, project, step_scale


def _cr1_cfg(steps: int, moment_dtype: str = "float32",
             sanitize: bool = False,
             telemetry_every: int = 0) -> EngineConfig:
    return EngineConfig(inner_steps=steps, outer_steps=1,
                        moment_dtype=moment_dtype, sanitize=sanitize,
                        telemetry_every=telemetry_every)


def _cr1_impl(p: FleetProblem, lam, state0: EngineState, steps: int,
              use_kernel: bool, shift: int = 0, reset_mu: bool = False,
              moment_dtype: str = "float32", sanitize: bool = False,
              telemetry_every: int = 0, norms=None):
    state0 = _enter_tick(state0, shift, reset_mu, CR1_MU0)
    norms = _cr1_norms(p) if norms is None else norms
    objective, project, step_scale = _cr1_pieces(p, use_kernel, norms=norms)
    cfg = _cr1_cfg(steps, moment_dtype, sanitize, telemetry_every)
    fused = _al_fused_inner(p, "cr1", cfg, car_norm=norms[1],
                            step_scale=step_scale,
                            coef0=lam * norms[0]) if use_kernel else None
    D, aux = al_minimize(objective, project, state0.x, hyper=lam,
                         step_scale=step_scale, init=state0, cfg=cfg,
                         fused_inner=fused)
    out = (D, fleet_penalties(p, D, use_kernel), aux["state"])
    # Static knob: the off path returns the historical 3-tuple, so every
    # telemetry-blind caller (sweeps, ensembles, day scans) is untouched.
    return out + (aux["telemetry"],) if telemetry_every else out


_CR1_STATIC = ("steps", "use_kernel", "shift", "reset_mu", "moment_dtype",
               "sanitize", "telemetry_every")
_cr1_run = jax.jit(_cr1_impl, static_argnames=_CR1_STATIC)
_cr1_run_donated = jax.jit(_cr1_impl, static_argnames=_CR1_STATIC,
                           donate_argnums=(2,))
# The sanitizer twin: same impl, checkify-functionalized user checks
# (`EngineConfig.sanitize` emits them); returns (err, out).
_cr1_run_checked = checked_jit(_cr1_impl, static_argnames=_CR1_STATIC)


def _cr1_impl_sharded(p: FleetProblem, lam, norms, state0: EngineState,
                      mesh, steps: int, use_kernel: bool, shift: int = 0,
                      reset_mu: bool = False,
                      moment_dtype: str = "float32",
                      telemetry_every: int = 0):
    state0 = _enter_tick(state0, shift, reset_mu, CR1_MU0)
    axis = fleet_axes(mesh)
    cfg = _cr1_cfg(steps, moment_dtype, telemetry_every=telemetry_every)

    def build(blk):
        pb, lam_b, norms_b = blk
        objective, project, step_scale = _cr1_pieces(pb, use_kernel,
                                                     norms=norms_b)
        pieces = dict(objective=objective, project=project, hyper=lam_b,
                      step_scale=step_scale)
        if use_kernel:
            pieces["fused_inner"] = _al_fused_inner(
                pb, "cr1", cfg, car_norm=norms_b[1], step_scale=step_scale,
                coef0=lam_b * norms_b[0])
        return pieces

    D, aux = al_minimize_sharded(
        build, (p, lam, norms), mesh=mesh, axis_name=axis,
        data_specs=(_fleet_specs(p, axis), P(), _norm_specs(p, axis)),
        init=state0, cfg=cfg)
    out = (D, fleet_penalties(p, D, use_kernel), aux["state"])
    return out + (aux["telemetry"],) if telemetry_every else out


_CR1_STATIC_SH = ("mesh", "steps", "use_kernel", "shift", "reset_mu",
                  "moment_dtype", "telemetry_every")
_cr1_run_sharded = jax.jit(_cr1_impl_sharded, static_argnames=_CR1_STATIC_SH)
_cr1_run_sharded_donated = jax.jit(_cr1_impl_sharded,
                                   static_argnames=_CR1_STATIC_SH,
                                   donate_argnums=(3,))


@functools.partial(jax.jit, static_argnames=("steps", "use_kernel"))
def _cr1_sweep_run(p: FleetProblem, lams, init: EngineState, steps: int,
                   use_kernel: bool):
    norms = _cr1_norms(p)
    objective, project, step_scale = _cr1_pieces(p, use_kernel, norms=norms)
    cfg = _cr1_cfg(steps)

    def solve_one(lam, st):
        fused = _al_fused_inner(
            p, "cr1", cfg, car_norm=norms[1], step_scale=step_scale,
            coef0=lam * norms[0]) if use_kernel else None
        D, aux = al_minimize(objective, project, st.x,
                             hyper=lam, step_scale=step_scale, init=st,
                             cfg=cfg, fused_inner=fused)
        return D, fleet_penalties(p, D, use_kernel), aux["state"]

    return jax.vmap(solve_one)(lams, init)


@functools.partial(jax.jit, static_argnames=("mesh", "steps", "use_kernel"))
def _cr1_sweep_sharded(p: FleetProblem, lams, norms, mesh, steps: int,
                       use_kernel: bool):
    """The λ grid vmapped INSIDE the W-axis shard_map: every device solves
    its row block for all grid points in one call (sharded Pareto lane)."""
    from jax.experimental.shard_map import shard_map
    axis = fleet_axes(mesh)

    def body(pb, lams_b, norms_b):
        objective, project, step_scale = _cr1_pieces(pb, use_kernel,
                                                     norms=norms_b)
        cfg = _cr1_cfg(steps)

        def solve_one(lam):
            fused = _al_fused_inner(
                pb, "cr1", cfg, car_norm=norms_b[1], step_scale=step_scale,
                coef0=lam * norms_b[0]) if use_kernel else None
            D, _ = al_minimize(objective, project,
                               jnp.zeros(pb.usage.shape), hyper=lam,
                               step_scale=step_scale, cfg=cfg,
                               fused_inner=fused)
            return D, fleet_penalties(pb, D, use_kernel)

        return jax.vmap(solve_one)(lams_b)

    # check_rep=False: `body` may dispatch the fused al_step pallas_call
    # (use_kernel), which has no shard_map replication rule; every output
    # is explicitly spec'd above.
    return shard_map(
        body, mesh=mesh,
        in_specs=(_fleet_specs(p, axis), P(), _norm_specs(p, axis)),
        out_specs=(P(None, axis), P(None, axis)),
        check_rep=False)(p, lams, norms)


@_register
@dataclasses.dataclass(frozen=True)
class CR1:
    """Efficient DR (paper Eq. 3): maximize λ-weighted penalty/carbon
    trade-off over the whole fleet — unconstrained but for the box and
    batch day-preservation, both handled by projection."""

    lam: float = 1.45

    name: ClassVar[str] = "cr1"
    default_steps: ClassVar[int] = 600
    mu0: ClassVar[float] = CR1_MU0

    def solve(self, p: FleetProblem,
              ctx: SolveContext = SolveContext()) -> FleetSolveResult:
        use_kernel = resolve_use_kernel(ctx.use_kernel)
        _require_telemetry_ok(ctx, use_kernel)
        steps = ctx.resolved_steps(self)
        tel = _tel_every(ctx)
        warm = ctx.warm
        if ctx.mesh is None:
            if warm is None:
                warm = EngineState.cold(jnp.zeros(p.usage.shape))
            if ctx.sanitize:
                err, out = _cr1_run_checked(
                    _jit_view(p), self.lam, warm, steps=steps,
                    use_kernel=use_kernel, shift=ctx.shift,
                    reset_mu=ctx.reset_mu, moment_dtype=ctx.moment_dtype,
                    sanitize=True, telemetry_every=tel)
                err.throw()
            else:
                run = _cr1_run_donated if ctx.donate else _cr1_run
                out = run(_jit_view(p), self.lam, warm,
                          steps=steps, use_kernel=use_kernel,
                          shift=ctx.shift, reset_mu=ctx.reset_mu,
                          moment_dtype=ctx.moment_dtype,
                          telemetry_every=tel)
            D, pens, state = out[:3]
            extras = {"telemetry": ConvergenceTrace.from_aux(out[3])} \
                if tel else None
            return _report(p, np.asarray(D), np.asarray(pens), iters=steps,
                           state=state, extras=extras)
        pp, W = pad_fleet(p, fleet_device_count(ctx.mesh))
        norms = _cr1_norms(p)
        if p.is_multiregion:
            norms = _pad_row_norms(norms, pp.W, CR1_NORM_FILLS)
        warm = _pad_state(warm, pp.W) if warm is not None \
            else EngineState.cold(jnp.zeros(pp.usage.shape))
        run = _cr1_run_sharded_donated if ctx.donate else _cr1_run_sharded
        out = run(pp, self.lam, norms, warm, mesh=ctx.mesh,
                  steps=steps, use_kernel=use_kernel,
                  shift=ctx.shift, reset_mu=ctx.reset_mu,
                  moment_dtype=ctx.moment_dtype, telemetry_every=tel)
        D, pens, state = out[:3]
        extras = {"telemetry": ConvergenceTrace.from_aux(out[3])} \
            if tel else None
        return _report(p, np.asarray(D)[:W], np.asarray(pens)[:W],
                       iters=steps, state=state, extras=extras)

    # -- vmapped sweep lane -------------------------------------------------
    @classmethod
    def _sweep_uniform(cls, policies: Sequence["CR1"]) -> bool:
        return True          # λ is the only knob and it is traced

    @classmethod
    def _sweep_family(cls, p: FleetProblem, policies: Sequence["CR1"],
                      ctx: SolveContext) -> list[FleetSolveResult]:
        use_kernel = resolve_use_kernel(ctx.use_kernel)
        steps = ctx.steps if ctx.steps is not None else cls.default_steps
        lams = jnp.asarray([pl.lam for pl in policies], jnp.float32)
        N = len(policies)
        if ctx.mesh is None:
            W = p.W
            init = ctx.warm if ctx.warm is not None else EngineState(
                x=jnp.zeros((N,) + p.usage.shape),
                lam_eq=jnp.zeros((N, 0)), lam_in=jnp.zeros((N, 0)),
                mu=jnp.full((N,), CR1_MU0))
            Ds, pens, states = _cr1_sweep_run(_jit_view(p), lams, init,
                                              steps, use_kernel)
        else:
            pp, W = pad_fleet(p, fleet_device_count(ctx.mesh))
            norms = _cr1_norms(p)
            if p.is_multiregion:
                norms = _pad_row_norms(norms, pp.W, CR1_NORM_FILLS)
            Ds, pens = _cr1_sweep_sharded(pp, lams, norms,
                                          mesh=ctx.mesh, steps=steps,
                                          use_kernel=use_kernel)
            states = None
        return [_report(p, np.asarray(D)[:W], np.asarray(pen)[:W],
                        iters=steps,
                        state=None if states is None else
                        jax.tree_util.tree_map(lambda a, i=i: a[i], states))
                for i, (D, pen) in enumerate(zip(np.asarray(Ds),
                                                 np.asarray(pens)))]


# ---------------------------------------------------------------------------
# CR2 — Fair-Centralized DR (per-workload penalty-equality targets)
# ---------------------------------------------------------------------------
def _cr2_pieces(p: FleetProblem, refs, use_kernel: bool, norms=None):
    lo, hi = _bounds(p)
    mci = jnp.asarray(p.mci)
    car_norm, scale, step_scale = \
        _cr2_norms(p, refs) if norms is None else norms

    if mci.ndim == 2:
        wmci = mci[jnp.asarray(p.region)]

        def objective(D: Array, _) -> Array:
            return -(car_norm[:, None] * D * wmci).sum()
    else:
        def objective(D: Array, _) -> Array:
            return -car_norm * (D @ mci).sum()

    def eq(D: Array, _) -> Array:
        return (fleet_penalties(p, D, use_kernel) - refs) / scale

    return objective, eq, _projection(p, lo, hi), step_scale


def _cr2_cfg(steps: int, outer: int, moment_dtype: str = "float32",
             sanitize: bool = False,
             telemetry_every: int = 0) -> EngineConfig:
    return EngineConfig(inner_steps=steps, outer_steps=outer, mu0=CR2_MU0,
                        mu_growth=2.0, moment_dtype=moment_dtype,
                        sanitize=sanitize, telemetry_every=telemetry_every)


def _cr2_impl(p: FleetProblem, refs, state0: EngineState, steps: int,
              outer: int, use_kernel: bool, shift: int = 0,
              reset_mu: bool = False, moment_dtype: str = "float32",
              sanitize: bool = False, telemetry_every: int = 0,
              norms=None):
    state0 = _enter_tick(state0, shift, reset_mu, CR2_MU0)
    norms = _cr2_norms(p, refs) if norms is None else norms
    objective, eq, project, step_scale = _cr2_pieces(p, refs, use_kernel,
                                                     norms=norms)
    cfg = _cr2_cfg(steps, outer, moment_dtype, sanitize, telemetry_every)
    fused = _al_fused_inner(p, "cr2", cfg, car_norm=norms[0],
                            step_scale=step_scale, scale=norms[1],
                            refs=refs) if use_kernel else None
    D, aux = al_minimize(objective, project, state0.x,
                         eq_residual=eq, step_scale=step_scale, init=state0,
                         cfg=cfg, fused_inner=fused)
    out = (D, fleet_penalties(p, D, use_kernel), aux["state"])
    return out + (aux["telemetry"],) if telemetry_every else out


_CR2_STATIC = ("steps", "outer", "use_kernel", "shift", "reset_mu",
               "moment_dtype", "sanitize", "telemetry_every")
_cr2_run = jax.jit(_cr2_impl, static_argnames=_CR2_STATIC)
_cr2_run_donated = jax.jit(_cr2_impl, static_argnames=_CR2_STATIC,
                           donate_argnums=(2,))
# The sanitizer twin (see `_cr1_run_checked`).
_cr2_run_checked = checked_jit(_cr2_impl, static_argnames=_CR2_STATIC)


def _cr2_impl_sharded(p: FleetProblem, refs, norms, state0: EngineState,
                      mesh, steps: int, outer: int, use_kernel: bool,
                      shift: int = 0, reset_mu: bool = False,
                      moment_dtype: str = "float32",
                      telemetry_every: int = 0):
    state0 = _enter_tick(state0, shift, reset_mu, CR2_MU0)
    axis = fleet_axes(mesh)
    cfg = _cr2_cfg(steps, outer, moment_dtype,
                   telemetry_every=telemetry_every)

    def build(blk):
        pb, refs_b, norms_b = blk
        objective, eq, project, step_scale = _cr2_pieces(
            pb, refs_b, use_kernel, norms=norms_b)
        pieces = dict(objective=objective, project=project, eq_residual=eq,
                      step_scale=step_scale)
        if use_kernel:
            pieces["fused_inner"] = _al_fused_inner(
                pb, "cr2", cfg, car_norm=norms_b[0], step_scale=step_scale,
                scale=norms_b[1], refs=refs_b)
        return pieces

    D, aux = al_minimize_sharded(
        build, (p, refs, norms), mesh=mesh, axis_name=axis,
        data_specs=(_fleet_specs(p, axis), P(axis), _norm_specs(p, axis)),
        init=state0, cfg=cfg)
    out = (D, fleet_penalties(p, D, use_kernel), aux["state"])
    return out + (aux["telemetry"],) if telemetry_every else out


_CR2_STATIC_SH = ("mesh", "steps", "outer", "use_kernel", "shift",
                  "reset_mu", "moment_dtype", "telemetry_every")
_cr2_run_sharded = jax.jit(_cr2_impl_sharded, static_argnames=_CR2_STATIC_SH)
_cr2_run_sharded_donated = jax.jit(_cr2_impl_sharded,
                                   static_argnames=_CR2_STATIC_SH,
                                   donate_argnums=(3,))


@functools.partial(jax.jit, static_argnames=("steps", "outer", "use_kernel"))
def _cr2_sweep_run(p: FleetProblem, refs_stack, init: EngineState,
                   steps: int, outer: int, use_kernel: bool):
    def solve_one(refs, st):
        norms = _cr2_norms(p, refs)
        objective, eq, project, step_scale = _cr2_pieces(p, refs,
                                                         use_kernel,
                                                         norms=norms)
        cfg = _cr2_cfg(steps, outer)
        fused = _al_fused_inner(
            p, "cr2", cfg, car_norm=norms[0], step_scale=step_scale,
            scale=norms[1], refs=refs) if use_kernel else None
        D, aux = al_minimize(objective, project, st.x,
                             eq_residual=eq, step_scale=step_scale,
                             init=st, cfg=cfg, fused_inner=fused)
        return D, fleet_penalties(p, D, use_kernel), aux["state"]

    return jax.vmap(solve_one)(refs_stack, init)


@functools.partial(jax.jit,
                   static_argnames=("mesh", "steps", "outer", "use_kernel"))
def _cr2_sweep_sharded(p: FleetProblem, refs_stack, norms_stack, mesh,
                       steps: int, outer: int, use_kernel: bool):
    from jax.experimental.shard_map import shard_map
    axis = fleet_axes(mesh)

    def body(pb, refs_b, norms_b):
        def solve_one(refs, norms):
            objective, eq, project, step_scale = _cr2_pieces(
                pb, refs, use_kernel, norms=norms)
            cfg = _cr2_cfg(steps, outer)
            fused = _al_fused_inner(
                pb, "cr2", cfg, car_norm=norms[0], step_scale=step_scale,
                scale=norms[1], refs=refs) if use_kernel else None
            D, _ = al_minimize(objective, project,
                               jnp.zeros(pb.usage.shape), eq_residual=eq,
                               step_scale=step_scale, cfg=cfg,
                               fused_inner=fused)
            return D, fleet_penalties(pb, D, use_kernel)

        return jax.vmap(solve_one)(refs_b, norms_b)

    nspec = P() if np.ndim(p.mci) == 1 else P(None, axis)
    # check_rep=False: `body` may dispatch the fused al_step pallas_call
    # (use_kernel), which has no shard_map replication rule; every output
    # is explicitly spec'd above.
    return shard_map(
        body, mesh=mesh,
        in_specs=(_fleet_specs(p, axis), P(None, axis),
                  (nspec, nspec, nspec)),
        out_specs=(P(None, axis), P(None, axis)),
        check_rep=False)(p, refs_stack, norms_stack)


@_register
@dataclasses.dataclass(frozen=True)
class CR2:
    """Fair-Centralized DR (paper Eq. 4): min −carbon s.t.
    C_i(d_i) = C_i(cap_frac·E_i) for every workload — one equality
    multiplier per workload, `outer` AL multiplier rounds."""

    cap_frac: float = 0.78
    outer: int = 6

    name: ClassVar[str] = "cr2"
    default_steps: ClassVar[int] = 400
    mu0: ClassVar[float] = CR2_MU0

    def solve(self, p: FleetProblem,
              ctx: SolveContext = SolveContext()) -> FleetSolveResult:
        use_kernel = resolve_use_kernel(ctx.use_kernel)
        _require_telemetry_ok(ctx, use_kernel)
        steps = ctx.resolved_steps(self)
        tel = _tel_every(ctx)
        warm = ctx.warm
        refs = jnp.asarray(cr2_reference_fleet(p, self.cap_frac))
        if ctx.mesh is None:
            if warm is None:
                warm = EngineState.cold(jnp.zeros(p.usage.shape), n_eq=p.W,
                                        mu0=CR2_MU0)
            if ctx.sanitize:
                err, out = _cr2_run_checked(
                    _jit_view(p), refs, warm, steps=steps,
                    outer=self.outer, use_kernel=use_kernel,
                    shift=ctx.shift, reset_mu=ctx.reset_mu,
                    moment_dtype=ctx.moment_dtype, sanitize=True,
                    telemetry_every=tel)
                err.throw()
            else:
                run = _cr2_run_donated if ctx.donate else _cr2_run
                out = run(_jit_view(p), refs, warm, steps=steps,
                          outer=self.outer, use_kernel=use_kernel,
                          shift=ctx.shift, reset_mu=ctx.reset_mu,
                          moment_dtype=ctx.moment_dtype,
                          telemetry_every=tel)
            D, pens, state = out[:3]
            extras = {"telemetry": ConvergenceTrace.from_aux(out[3])} \
                if tel else None
            return _report(p, np.asarray(D), np.asarray(pens),
                           iters=steps * self.outer, state=state,
                           extras=extras)
        pp, W = pad_fleet(p, fleet_device_count(ctx.mesh))
        norms = _cr2_norms(p, refs)
        if p.is_multiregion:
            norms = _pad_row_norms(norms, pp.W, CR2_NORM_FILLS)
        refs_p = jnp.concatenate([refs, jnp.zeros(pp.W - W, refs.dtype)])
        warm = _pad_state(warm, pp.W) if warm is not None \
            else EngineState.cold(jnp.zeros(pp.usage.shape), n_eq=pp.W,
                                  mu0=CR2_MU0)
        run = _cr2_run_sharded_donated if ctx.donate else _cr2_run_sharded
        out = run(pp, refs_p, norms, warm, mesh=ctx.mesh,
                  steps=steps, outer=self.outer,
                  use_kernel=use_kernel, shift=ctx.shift,
                  reset_mu=ctx.reset_mu,
                  moment_dtype=ctx.moment_dtype, telemetry_every=tel)
        D, pens, state = out[:3]
        extras = {"telemetry": ConvergenceTrace.from_aux(out[3])} \
            if tel else None
        return _report(p, np.asarray(D)[:W], np.asarray(pens)[:W],
                       iters=steps * self.outer, state=state,
                       extras=extras)

    # -- vmapped sweep lane -------------------------------------------------
    @classmethod
    def _sweep_uniform(cls, policies: Sequence["CR2"]) -> bool:
        # `outer` is a static engine knob: one compile needs one value.
        return len({pl.outer for pl in policies}) == 1

    @classmethod
    def _sweep_family(cls, p: FleetProblem, policies: Sequence["CR2"],
                      ctx: SolveContext) -> list[FleetSolveResult]:
        use_kernel = resolve_use_kernel(ctx.use_kernel)
        steps = ctx.steps if ctx.steps is not None else cls.default_steps
        outer = policies[0].outer
        N = len(policies)
        refs = [jnp.asarray(cr2_reference_fleet(p, pl.cap_frac))
                for pl in policies]
        if ctx.mesh is None:
            W = p.W
            init = ctx.warm if ctx.warm is not None else EngineState(
                x=jnp.zeros((N,) + p.usage.shape),
                lam_eq=jnp.zeros((N, p.W)), lam_in=jnp.zeros((N, 0)),
                mu=jnp.full((N,), CR2_MU0))
            Ds, pens, states = _cr2_sweep_run(_jit_view(p), jnp.stack(refs),
                                              init, steps, outer,
                                              use_kernel)
        else:
            pp, W = pad_fleet(p, fleet_device_count(ctx.mesh))
            # per-lane global norms from the TRUE fleet; per-lane padded
            # refs (pad residuals are identically zero).
            norms = [_cr2_norms(p, r) for r in refs]
            if p.is_multiregion:
                norms = [_pad_row_norms(n, pp.W, CR2_NORM_FILLS)
                         for n in norms]
            norms_stack = tuple(jnp.stack([n[i] for n in norms])
                                for i in range(3))
            refs_p = jnp.stack([
                jnp.concatenate([r, jnp.zeros(pp.W - W, r.dtype)])
                for r in refs])
            Ds, pens = _cr2_sweep_sharded(pp, refs_p, norms_stack,
                                          mesh=ctx.mesh, steps=steps,
                                          outer=outer,
                                          use_kernel=use_kernel)
            states = None
        return [_report(p, np.asarray(D)[:W], np.asarray(pen)[:W],
                        iters=steps * outer,
                        state=None if states is None else
                        jax.tree_util.tree_map(lambda a, i=i: a[i], states))
                for i, (D, pen) in enumerate(zip(np.asarray(Ds),
                                                 np.asarray(pens)))]


# ---------------------------------------------------------------------------
# CR3 — Fair-Decentralized DR (taxes and rebates, Eqs. 5–8)
# ---------------------------------------------------------------------------
def _cr3_pieces(p: FleetProblem, use_kernel: bool, reg_scale):
    """Best-response pieces for one device's row block (or the whole fleet).

    Everything here is row-separable; `reg_scale` is the regularizer
    normalizer 1e-3/(W_true·T), passed in so a padded sharded solve
    regularizes identically to the unpadded single-device one. On
    multi-region problems it is the per-row (W, 1) vector
    1e-3/(W_region·T) and ρ is a per-region (R,) price vector, so each
    region's market is exactly its standalone single-region market.

    Numerics, validated against the per-workload SLSQP reference:
      * tiny quadratic regularizer — a selfish workload takes the *minimal*
        adjustment satisfying its allowance; the regularizer breaks the
        zero-penalty plateau of batch models toward that minimal response
        (without it, any deep-feasible point is an equally 'optimal' best
        response with wildly overpaid rebates).
      * day-tangent gradient projection (see engine.al_minimize docs).
      * gentle μ schedule: the KKT multipliers here are O(1e-3), so a stiff
        wall (μ≫1) just makes projected Adam bounce off the boundary.
    """
    lo, hi = _bounds(p)
    usage = jnp.asarray(p.usage)
    E = jnp.asarray(p.entitlement)
    mci = jnp.asarray(p.mci)
    tau = 0.02 * E
    multi = mci.ndim == 2
    if multi:
        region = jnp.asarray(p.region)
        wmci = mci[region]

        def objective(D: Array, hyper) -> Array:
            reg = (reg_scale * (D / E[:, None]) ** 2).sum()
            return (fleet_penalties(p, D, use_kernel) / E).sum() + reg

        def ineq(D: Array, hyper) -> Array:
            rho_, tax_ = hyper
            rebate = rho_[region] * (D * wmci).sum(1)
            peak = tau * jax.nn.logsumexp((usage - D) / tau[:, None],
                                          axis=1)
            return ((1.0 - tax_) * E + rebate - peak) / E
    else:
        def objective(D: Array, hyper) -> Array:
            reg = reg_scale * ((D / E[:, None]) ** 2).sum()
            return (fleet_penalties(p, D, use_kernel) / E).sum() + reg

        def ineq(D: Array, hyper) -> Array:
            rho_, tax_ = hyper
            rebate = rho_ * (D @ mci)
            peak = tau * jax.nn.logsumexp((usage - D) / tau[:, None],
                                          axis=1)
            return ((1.0 - tax_) * E + rebate - peak) / E

    W, T = p.usage.shape
    n_days = max(1, T // p.day_hours)
    span = n_days * p.day_hours
    is_batch = jnp.asarray(p.is_batch)[:, None, None]

    def day_tangent(g: Array) -> Array:
        Gd = g[:, :span].reshape(W, n_days, p.day_hours)
        Gd = jnp.where(is_batch, Gd - Gd.mean(axis=-1, keepdims=True), Gd)
        return jnp.concatenate([Gd.reshape(W, span), g[:, span:]], axis=1)

    step_scale = jnp.maximum(hi - lo, 1e-6).mean(axis=1, keepdims=True)
    return objective, ineq, _projection(p, lo, hi), step_scale, day_tangent


def _cr3_cfg(steps: int, outer: int) -> EngineConfig:
    return EngineConfig(inner_steps=steps, outer_steps=outer, lr=0.005,
                        mu0=CR3_MU0, mu_growth=2.0, beta2=0.99)


def _cr3_impl(p: FleetProblem, rho, tax_frac, reg_scale,
              state0: EngineState, steps: int, outer: int, use_kernel: bool,
              shift: int = 0, reset_mu: bool = False):
    """All W selfish problems in one AL solve. Each workload i minimizes its
    own penalty s.t. the peak-allowance inequality (Eq. 5/8)

        max_t (U_i − d_i) ≤ E_i − T_i + ρ·⟨mci, d_i⟩,   T_i = tax_frac·E_i

    (smooth max as in `policies.cr3_workload_spec`). Objective, residual and
    projection are all row-separable, so this single (W, T) engine call IS
    the vmapped per-workload best response — one XLA call per round.
    """
    state0 = _enter_tick(state0, shift, reset_mu, CR3_MU0)
    objective, ineq, project, step_scale, day_tangent = _cr3_pieces(
        p, use_kernel, reg_scale)
    D, aux = al_minimize(objective, project, state0.x,
                         hyper=(rho, tax_frac), ineq_residual=ineq,
                         step_scale=step_scale, grad_transform=day_tangent,
                         init=state0, cfg=_cr3_cfg(steps, outer))
    return D, fleet_penalties(p, D, use_kernel), aux["state"]


_CR3_STATIC = ("steps", "outer", "use_kernel", "shift", "reset_mu")
_cr3_best_response = jax.jit(_cr3_impl, static_argnames=_CR3_STATIC)
_cr3_best_response_donated = jax.jit(_cr3_impl, static_argnames=_CR3_STATIC,
                                     donate_argnums=(4,))


def _cr3_impl_sharded(p: FleetProblem, rho, tax_frac, reg_scale,
                      state0: EngineState, mesh, steps: int, outer: int,
                      use_kernel: bool, shift: int = 0,
                      reset_mu: bool = False):
    """Sharded best response: the allowance inequality, its multipliers and
    the per-row step scale all live with their rows; only ρ/tax/reg_scale
    are replicated (multi-region: ρ stays a replicated (R,) vector and
    reg_scale shards with its rows). The Eq.-6 fiscal sums live in
    `CR3.solve`."""
    state0 = _enter_tick(state0, shift, reset_mu, CR3_MU0)
    axis = fleet_axes(mesh)

    def build(blk):
        pb, hyper_b, reg_b = blk
        objective, ineq, project, step_scale, day_tangent = _cr3_pieces(
            pb, use_kernel, reg_b)
        return dict(objective=objective, project=project, hyper=hyper_b,
                    ineq_residual=ineq, step_scale=step_scale,
                    grad_transform=day_tangent)

    reg_spec = P() if np.ndim(p.mci) == 1 else P(axis)
    D, aux = al_minimize_sharded(
        build, (p, (rho, tax_frac), reg_scale), mesh=mesh, axis_name=axis,
        data_specs=(_fleet_specs(p, axis), (P(), P()), reg_spec),
        init=state0, cfg=_cr3_cfg(steps, outer))
    return D, fleet_penalties(p, D, use_kernel), aux["state"]


_CR3_STATIC_SH = ("mesh", "steps", "outer", "use_kernel", "shift",
                  "reset_mu")
_cr3_sharded = jax.jit(_cr3_impl_sharded, static_argnames=_CR3_STATIC_SH)
_cr3_sharded_donated = jax.jit(_cr3_impl_sharded,
                               static_argnames=_CR3_STATIC_SH,
                               donate_argnums=(4,))


@functools.partial(jax.jit, static_argnames=("steps", "outer", "use_kernel",
                                             "reset_mu"))
def _cr3_sweep_round(p: FleetProblem, rhos, taxes, reg_scale, states,
                     steps: int, outer: int, use_kernel: bool,
                     reset_mu: bool):
    """One clearing round for every sweep lane: the (ρ, tax) hyper axis
    rides vmap through the same best-response impl the solo solve jits."""
    def one(rho, tax, st):
        return _cr3_impl(p, rho, tax, reg_scale, st, steps, outer,
                         use_kernel, 0, reset_mu)

    return jax.vmap(one)(rhos, taxes, states)


def _cr3_unbalanced_warn(clearing_iters: int, deficit: float, rho: float,
                         caller: str) -> None:
    warnings.warn(
        f"{caller}: fiscal clearing did not converge in "
        f"{clearing_iters} iterations — rebates exceed taxes by "
        f"{deficit:.4g} at rho={rho:.4g} (Eq. 6 unmet)",
        RuntimeWarning, stacklevel=3)


@_register
@dataclasses.dataclass(frozen=True)
class CR3:
    """Fair-Decentralized DR: vmapped selfish best responses + the
    coordinator's fiscal-balance clearing (Eqs. 5–8).

    The coordinator lowers the carbon price ρ until rebates are covered by
    taxes (Eq. 6, `policies.cr3_fiscal_balance` semantics). Each clearing
    round warm-starts from the previous round's engine state (the
    allowance multipliers track the shrinking ρ smoothly); `ctx.warm`
    seeds round 0 the same way for rolling-horizon re-solves.

    With `ctx.mesh`, each best response runs sharded over the fleet axis;
    the Eq.-6 sums (rebates paid vs taxes collected) are the only
    cross-device reductions and happen here, on the gathered true-W
    solution between rounds (rounds after the first always re-enter with
    the μ schedule restarted).

    If `clearing_iters` is exhausted with rebates still exceeding taxes,
    `result.extras` carries `balanced=False` and the remaining
    `fiscal_deficit` (rebates − taxes, NP·kgCO2/MWh), and a
    `RuntimeWarning` is emitted — callers must not treat
    `extras["rho"]` as market-clearing then."""

    rho: float = 0.02
    tax_frac: float = 0.2
    outer: int = 3
    clearing_iters: int = 8

    name: ClassVar[str] = "cr3"
    default_steps: ClassVar[int] = 600
    mu0: ClassVar[float] = CR3_MU0

    def solve(self, p: FleetProblem,
              ctx: SolveContext = SolveContext()) -> FleetSolveResult:
        if p.is_multiregion:
            return self._solve_multiregion(p, ctx)
        use_kernel = resolve_use_kernel(ctx.use_kernel)
        steps = ctx.resolved_steps(self)
        mci = np.asarray(p.mci)
        collected = self.tax_frac * float(np.asarray(p.entitlement).sum())
        rho_cur = float(self.rho)
        if ctx.mesh is None:
            pj, W = _jit_view(p), p.W
            state = ctx.warm if ctx.warm is not None else EngineState.cold(
                jnp.zeros(p.usage.shape), n_in=p.W, mu0=CR3_MU0)
            twin = _cr3_best_response_donated if ctx.donate \
                else _cr3_best_response
        else:
            pj, W = pad_fleet(p, fleet_device_count(ctx.mesh))
            state = _pad_state(ctx.warm, pj.W) if ctx.warm is not None \
                else EngineState.cold(jnp.zeros(pj.usage.shape), n_in=pj.W,
                                      mu0=CR3_MU0)
            twin = _cr3_sharded_donated if ctx.donate else _cr3_sharded
        reg_scale = 1e-3 / (W * p.T)

        def best_response(st, shift_, reset_):
            kw = {} if ctx.mesh is None else {"mesh": ctx.mesh}
            return twin(pj, rho_cur, self.tax_frac, reg_scale, st,
                        steps=steps, outer=self.outer,
                        use_kernel=use_kernel, shift=shift_,
                        reset_mu=reset_, **kw)

        D, pens, state = best_response(state, ctx.shift, ctx.reset_mu)
        D = np.asarray(D)[:W]
        rounds = 1
        paid = rho_cur * float((D @ mci).sum())
        for _ in range(self.clearing_iters):
            if paid <= collected + 1e-9:
                break
            rho_cur *= max(0.5, 0.9 * collected / max(paid, 1e-9))
            # Carry primal + allowance multipliers; restart the μ schedule
            # so every round keeps the gentle wall the best response
            # relies on.
            D, pens, state = best_response(state, 0, True)
            D = np.asarray(D)[:W]
            rounds += 1
            paid = rho_cur * float((D @ mci).sum())
        balanced = paid <= collected + 1e-9
        deficit = 0.0 if balanced else paid - collected
        if not balanced:
            _cr3_unbalanced_warn(self.clearing_iters, deficit, rho_cur,
                                 "CR3.solve")
        return _report(p, D, np.asarray(pens)[:W],
                       iters=steps * self.outer * rounds, state=state,
                       extras={"rho": rho_cur, "balanced": balanced,
                               "fiscal_deficit": deficit})

    def _solve_multiregion(self, p: FleetProblem,
                           ctx: SolveContext) -> FleetSolveResult:
        """Per-region fiscal clearing: each region runs its own Eq.-6
        market (its taxes cover its rebates at its own clearing price
        ρ_r), so `extras["rho"]` is an (R,) vector. Every clearing round
        re-solves the whole fleet in one engine call, but regions that
        already cleared keep their frozen plan/state — each region's
        trajectory is exactly what its standalone single-region solve
        would produce (the zero-bandwidth decomposition tests rely on
        this)."""
        use_kernel = resolve_use_kernel(ctx.use_kernel)
        steps = ctx.resolved_steps(self)
        mci = np.asarray(p.mci)
        region = np.asarray(p.region)
        R = p.R
        wmci = mci[region]
        collected = self.tax_frac * _region_totals(region, p.entitlement, R)
        rho_cur = np.full(R, float(self.rho))
        reg_scale = _cr3_reg_scale(p)
        if ctx.mesh is None:
            pj, W = _jit_view(p), p.W
            state = ctx.warm if ctx.warm is not None else EngineState.cold(
                jnp.zeros(p.usage.shape), n_in=p.W, mu0=CR3_MU0)
            twin = _cr3_best_response_donated if ctx.donate \
                else _cr3_best_response
        else:
            pj, W = pad_fleet(p, fleet_device_count(ctx.mesh))
            reg_scale = jnp.concatenate(
                [reg_scale, jnp.ones((pj.W - W, 1), reg_scale.dtype)])
            state = _pad_state(ctx.warm, pj.W) if ctx.warm is not None \
                else EngineState.cold(jnp.zeros(pj.usage.shape), n_in=pj.W,
                                      mu0=CR3_MU0)
            twin = _cr3_sharded_donated if ctx.donate else _cr3_sharded
        region_pad = np.asarray(pj.region)

        def best_response(st, shift_, reset_):
            kw = {} if ctx.mesh is None else {"mesh": ctx.mesh}
            return twin(pj, jnp.asarray(rho_cur, jnp.float32),
                        self.tax_frac, reg_scale, st, steps=steps,
                        outer=self.outer, use_kernel=use_kernel,
                        shift=shift_, reset_mu=reset_, **kw)

        def paid_of(D):
            return rho_cur * _region_totals(region, (D * wmci).sum(1), R)

        D, pens, state = best_response(state, ctx.shift, ctx.reset_mu)
        D, pens = np.asarray(D)[:W], np.asarray(pens)[:W]
        rounds = 1
        paid = paid_of(D)
        for _ in range(self.clearing_iters):
            active = paid > collected + 1e-9
            if not active.any():
                break
            rho_cur = np.where(
                active,
                rho_cur * np.maximum(0.5, 0.9 * collected
                                     / np.maximum(paid, 1e-9)),
                rho_cur)
            Dn, pensn, staten = best_response(state, 0, True)
            row = active[region]
            D = np.where(row[:, None], np.asarray(Dn)[:W], D)
            pens = np.where(row, np.asarray(pensn)[:W], pens)
            # μ is reset every round so it is round-count independent;
            # lam_eq is empty for CR3 — only x and the allowance
            # multipliers need per-row freezing.
            mask = jnp.asarray(active[region_pad])
            state = EngineState(
                x=jnp.where(mask[:, None], staten.x, state.x),
                lam_eq=staten.lam_eq,
                lam_in=jnp.where(mask, staten.lam_in, state.lam_in),
                mu=staten.mu)
            rounds += 1
            paid = paid_of(D)
        balanced = paid <= collected + 1e-9
        deficit = np.where(balanced, 0.0, paid - collected)
        if not balanced.all():
            worst = int(np.argmax(deficit))
            _cr3_unbalanced_warn(self.clearing_iters,
                                 float(deficit.sum()),
                                 float(rho_cur[worst]),
                                 "CR3.solve (multi-region)")
        return _report(p, D, pens,
                       iters=steps * self.outer * rounds, state=state,
                       extras={"rho": rho_cur,
                               "balanced": bool(balanced.all()),
                               "fiscal_deficit": float(deficit.sum())})

    # -- vmapped sweep lane -------------------------------------------------
    @classmethod
    def _sweep_uniform(cls, policies: Sequence["CR3"]) -> bool:
        # `outer` is static (one compile); per-lane ρ/tax are traced and
        # per-lane clearing_iters ride the host-side lockstep loop.
        return len({pl.outer for pl in policies}) == 1

    @classmethod
    def _sweep_family(cls, p: FleetProblem, policies: Sequence["CR3"],
                      ctx: SolveContext) -> list[FleetSolveResult]:
        if ctx.mesh is not None or p.is_multiregion:
            # vmap-of-shard_map best responses with per-lane host clearing
            # is a ROADMAP follow-up, and multi-region clearing tracks an
            # (R,) price vector per lane; both solve per policy.
            return [pl.solve(p, ctx) for pl in policies]
        use_kernel = resolve_use_kernel(ctx.use_kernel)
        steps = ctx.steps if ctx.steps is not None else cls.default_steps
        outer = policies[0].outer
        N = len(policies)
        mci = np.asarray(p.mci)
        pj = _jit_view(p)
        reg_scale = 1e-3 / (p.W * p.T)
        states = EngineState(
            x=jnp.zeros((N,) + p.usage.shape),
            lam_eq=jnp.zeros((N, 0)), lam_in=jnp.zeros((N, p.W)),
            mu=jnp.full((N,), CR3_MU0))
        rho_cur = np.asarray([pl.rho for pl in policies], float)
        taxes = np.asarray([pl.tax_frac for pl in policies], float)
        iters_cap = np.asarray([pl.clearing_iters for pl in policies])
        collected = taxes * float(np.asarray(p.entitlement).sum())

        def rounds_all(reset_mu):
            return _cr3_sweep_round(
                pj, jnp.asarray(rho_cur, jnp.float32),
                jnp.asarray(taxes, jnp.float32), reg_scale, states,
                steps=steps, outer=outer, use_kernel=use_kernel,
                reset_mu=reset_mu)

        Ds, pens, states = rounds_all(False)
        D_out, pens_out = np.asarray(Ds), np.asarray(pens)
        rounds = np.ones(N, int)
        used = np.zeros(N, int)
        paid = rho_cur * np.einsum("nwt,t->n", D_out, mci)
        while True:
            active = (paid > collected + 1e-9) & (used < iters_cap)
            if not active.any():
                break
            rho_cur = np.where(
                active,
                rho_cur * np.maximum(0.5, 0.9 * collected
                                     / np.maximum(paid, 1e-9)),
                rho_cur)
            # Every lane re-solves in lockstep (one XLA call), but lanes
            # that already cleared keep their frozen solution/state so each
            # lane's trajectory is exactly its solo-`solve()` trajectory.
            Ds, pens, new_states = rounds_all(True)
            sel = active[:, None, None]
            D_out = np.where(sel, np.asarray(Ds), D_out)
            pens_out = np.where(active[:, None], np.asarray(pens), pens_out)
            states = jax.tree_util.tree_map(
                lambda new, old: jnp.where(
                    jnp.asarray(active).reshape((N,) + (1,) * (new.ndim - 1)),
                    new, old),
                new_states, states)
            rounds = rounds + active
            used = used + active
            paid = np.where(active,
                            rho_cur * np.einsum("nwt,t->n", D_out, mci),
                            paid)
        balanced = paid <= collected + 1e-9
        deficit = np.where(balanced, 0.0, paid - collected)
        out = []
        for i, pl in enumerate(policies):
            if not balanced[i]:
                _cr3_unbalanced_warn(pl.clearing_iters, float(deficit[i]),
                                     float(rho_cur[i]), "CR3 sweep")
            state_i = jax.tree_util.tree_map(lambda a: a[i], states)
            out.append(_report(
                p, D_out[i], pens_out[i],
                iters=steps * outer * int(rounds[i]), state=state_i,
                extras={"rho": float(rho_cur[i]),
                        "balanced": bool(balanced[i]),
                        "fiscal_deficit": float(deficit[i])}))
        return out


# ---------------------------------------------------------------------------
# Baseline wrappers — closed-form prior-work policies as DRPolicy values
# ---------------------------------------------------------------------------
@_register
@dataclasses.dataclass(frozen=True)
class B1:
    """Proportional Power Capping (paper §V-B, eBuff-style): cap every
    workload at L_i = F·E_i, d = max(U − L, 0) — the fleet-array form of
    `baselines.b1_adjustments`. Closed form: `ctx` execution knobs are
    no-ops (no engine state to warm/shard)."""

    F: float = 0.75

    name: ClassVar[str] = "b1"
    default_steps: ClassVar[int] = 0

    def solve(self, p: FleetProblem,
              ctx: SolveContext = SolveContext()) -> FleetSolveResult:
        D = np.maximum(
            np.asarray(p.usage)
            - self.F * np.asarray(p.entitlement)[:, None], 0.0)
        pens = np.asarray(fleet_penalties(
            p, jnp.asarray(D), resolve_use_kernel(ctx.use_kernel)))
        return _report(p, D, pens, iters=0)


# ---------------------------------------------------------------------------
# Whole-day scan — a rolling-horizon day as ONE XLA dispatch
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class DayResult:
    """Result of `solve_day`: a whole rolling-horizon day in one dispatch.

    committed: (n_ticks, W) — hour-0 curtailment of every tick's plan (the
      hours the controller actually commits).
    last: the final tick's full `FleetSolveResult` (its `.state` chains
      into the next day's `solve_day`/`solve` warm start).
    inner_steps: per-tick engine iterations (cold budget first unless the
      day itself was warm-started)."""
    committed: np.ndarray
    last: FleetSolveResult
    inner_steps: tuple[int, ...]


def _day_impl(p: FleetProblem, xs, state0: EngineState, tick_solve,
              warm_steps: int, first_steps: int, first_shift: int,
              first_reset: bool, telemetry: bool = False):
    """Shared whole-day loop: tick 0 outside the scan (its step budget /
    shift / mu-reset differ), then `lax.scan` over the remaining forecast
    rows, each iteration fusing window-roll + `EngineState.shifted` +
    mu-reset + warm re-solve. `xs` is any pytree with a leading n_ticks
    axis (per-tick forecasts, plus per-tick norms on the sharded path);
    `tick_solve(p_t, x_t, st, steps, shift, reset_mu) -> (D, pens,
    state)` is a policy impl (pure/traceable) that installs its slice
    `x_t` into the windowed problem.

    With `telemetry` (static), `tick_solve` returns a 4th element — the
    engine's per-solve telemetry dict — and the warm ticks' traces ride
    the scan ys (stacked on a leading (n-1) tick axis), so the whole
    instrumented day is STILL one dispatch. Tick 0's trace stays
    separate: its step budget (and hence sample count) differs. Returns
    `(..., tel0, tel_warm)` where `tel_warm` is None for a 1-tick day.
    """
    usage = jnp.asarray(p.usage)
    jobs = jnp.asarray(p.jobs)
    upper = None if p.upper is None else jnp.asarray(p.upper)
    tmap = jax.tree_util.tree_map

    def roll(a):
        return None if a is None else jnp.roll(a, -1, axis=1)

    out0 = tick_solve(p, tmap(lambda a: a[0], xs), state0,
                      first_steps, first_shift, first_reset)
    D, pens, st = out0[:3]
    tel0 = out0[3] if telemetry else None

    def body(carry, x_t):
        st, usage, jobs, upper, _, _ = carry
        usage, jobs, upper = roll(usage), roll(jobs), roll(upper)
        p_t = dataclasses.replace(p, usage=usage, jobs=jobs, upper=upper)
        out = tick_solve(p_t, x_t, st, warm_steps, 1, True)
        D, pens, st = out[:3]
        ys = (D[:, 0], out[3]) if telemetry else D[:, 0]
        return (st, usage, jobs, upper, D, pens), ys

    carry = (st, usage, jobs, upper, D, pens)
    n = jax.tree_util.tree_leaves(xs)[0].shape[0]
    tel_w = None
    if n > 1:
        carry, ys = jax.lax.scan(body, carry, tmap(lambda a: a[1:], xs))
        committed_w, tel_w = ys if telemetry else (ys, None)
        committed = jnp.concatenate([D[:, 0][None], committed_w], axis=0)
    else:
        committed = D[:, 0][None]
    st, _, _, _, D_last, pens_last = carry
    out = (committed, D_last, pens_last, st)
    return out + (tel0, tel_w) if telemetry else out


def _day_cr1_impl(p: FleetProblem, lam, mci_stack, state0: EngineState,
                  warm_steps: int, first_steps: int, first_shift: int,
                  first_reset: bool, use_kernel: bool, moment_dtype: str,
                  sanitize: bool = False, telemetry_every: int = 0):
    def tick_solve(p_t, mci_t, st, steps, shift, reset_mu):
        p_t = dataclasses.replace(p_t, mci=mci_t)
        return _cr1_impl(p_t, lam, st, steps, use_kernel, shift, reset_mu,
                         moment_dtype, sanitize, telemetry_every)

    return _day_impl(p, mci_stack, state0, tick_solve, warm_steps,
                     first_steps, first_shift, first_reset,
                     telemetry=telemetry_every > 0)


_DAY_CR1_STATIC = ("warm_steps", "first_steps", "first_shift",
                   "first_reset", "use_kernel", "moment_dtype",
                   "sanitize", "telemetry_every")
_day_cr1 = jax.jit(_day_cr1_impl, static_argnames=_DAY_CR1_STATIC)
_day_cr1_donated = jax.jit(_day_cr1_impl, static_argnames=_DAY_CR1_STATIC,
                           donate_argnums=(3,))
# The day scan's sanitizer twin: checkify-functionalized EngineConfig
# guards on every tick solve of the fused day (see `_cr1_run_checked`).
_day_cr1_checked = checked_jit(_day_cr1_impl,
                               static_argnames=_DAY_CR1_STATIC)


def _day_cr2_impl(p: FleetProblem, cap_frac, mci_stack,
                  state0: EngineState, warm_steps: int, first_steps: int,
                  first_shift: int, first_reset: bool, outer: int,
                  use_kernel: bool, moment_dtype: str,
                  sanitize: bool = False, telemetry_every: int = 0):
    E = jnp.asarray(p.entitlement)[:, None]

    def tick_solve(p_t, mci_t, st, steps, shift, reset_mu):
        p_t = dataclasses.replace(p_t, mci=mci_t)
        # Per-window fairness targets, recomputed in-scan (the jnp twin
        # of `cr2_reference_fleet`).
        d_cap = jnp.maximum(jnp.asarray(p_t.usage) - cap_frac * E, 0.0)
        refs = fleet_penalties(p_t, d_cap, use_kernel)
        return _cr2_impl(p_t, refs, st, steps, outer, use_kernel, shift,
                         reset_mu, moment_dtype, sanitize, telemetry_every)

    return _day_impl(p, mci_stack, state0, tick_solve, warm_steps,
                     first_steps, first_shift, first_reset,
                     telemetry=telemetry_every > 0)


_DAY_CR2_STATIC = ("warm_steps", "first_steps", "first_shift",
                   "first_reset", "outer", "use_kernel", "moment_dtype",
                   "sanitize", "telemetry_every")
_day_cr2 = jax.jit(_day_cr2_impl, static_argnames=_DAY_CR2_STATIC)
_day_cr2_donated = jax.jit(_day_cr2_impl, static_argnames=_DAY_CR2_STATIC,
                           donate_argnums=(3,))
# CR2 day-scan sanitizer twin (see `_day_cr1_checked`).
_day_cr2_checked = checked_jit(_day_cr2_impl,
                               static_argnames=_DAY_CR2_STATIC)


def _day_cr1_impl_sharded(p: FleetProblem, lam, mci_stack, norms_stack,
                          state0: EngineState, mesh, warm_steps: int,
                          first_steps: int, first_shift: int,
                          first_reset: bool, use_kernel: bool,
                          moment_dtype: str):
    """The whole-day CR1 scan INSIDE the W-axis shard_map: each device
    scans its row block through every tick of the day, so a full
    rolling-horizon day is still one dispatch on a fleet mesh. Per-tick
    fleet-global norms ride in as a replicated (n, ...) stack computed
    host-side from the TRUE fleet (the in-scan twin of the solo path's
    per-tick `_cr1_norms`)."""
    from jax.experimental.shard_map import shard_map
    axis = fleet_axes(mesh)

    def body(pb, lam_b, mci_s, norms_s, st0):
        def tick_solve(p_t, x_t, st, steps, shift, reset_mu):
            mci_t, norms_t = x_t
            p_t = dataclasses.replace(p_t, mci=mci_t)
            return _cr1_impl(p_t, lam_b, st, steps, use_kernel, shift,
                             reset_mu, moment_dtype, norms=norms_t)

        return _day_impl(pb, (mci_s, norms_s), st0, tick_solve,
                         warm_steps, first_steps, first_shift, first_reset)

    state_specs = EngineState(x=P(axis), lam_eq=P(axis), lam_in=P(axis),
                              mu=P())
    # check_rep=False: the day scan's tick solves may dispatch the fused
    # al_step pallas_call (use_kernel), which has no shard_map
    # replication rule; every output is explicitly spec'd above.
    return shard_map(
        body, mesh=mesh,
        in_specs=(_fleet_specs(p, axis), P(), P(),
                  _norm_specs(p, axis, stacked=True), state_specs),
        out_specs=(P(None, axis), P(axis), P(axis), state_specs),
        check_rep=False)(p, lam, mci_stack, norms_stack, state0)


_DAY_CR1_STATIC_SH = ("mesh", "warm_steps", "first_steps", "first_shift",
                      "first_reset", "use_kernel", "moment_dtype")
_day_cr1_sharded = jax.jit(_day_cr1_impl_sharded,
                           static_argnames=_DAY_CR1_STATIC_SH)
_day_cr1_sharded_donated = jax.jit(_day_cr1_impl_sharded,
                                   static_argnames=_DAY_CR1_STATIC_SH,
                                   donate_argnums=(4,))


def _day_cr2_impl_sharded(p: FleetProblem, cap_frac, mci_stack,
                          norms_stack, state0: EngineState, mesh,
                          warm_steps: int, first_steps: int,
                          first_shift: int, first_reset: bool, outer: int,
                          use_kernel: bool, moment_dtype: str):
    """CR2 twin of `_day_cr1_impl_sharded`: fairness refs are recomputed
    in-scan from the local row block (row-separable), while the fleet-
    global norms (carbon normalizer, residual scale, step scale) ride in
    per tick from the TRUE fleet."""
    from jax.experimental.shard_map import shard_map
    axis = fleet_axes(mesh)

    def body(pb, cap_b, mci_s, norms_s, st0):
        E = jnp.asarray(pb.entitlement)[:, None]

        def tick_solve(p_t, x_t, st, steps, shift, reset_mu):
            mci_t, norms_t = x_t
            p_t = dataclasses.replace(p_t, mci=mci_t)
            d_cap = jnp.maximum(jnp.asarray(p_t.usage) - cap_b * E, 0.0)
            refs = fleet_penalties(p_t, d_cap, use_kernel)
            return _cr2_impl(p_t, refs, st, steps, outer, use_kernel,
                             shift, reset_mu, moment_dtype, norms=norms_t)

        return _day_impl(pb, (mci_s, norms_s), st0, tick_solve,
                         warm_steps, first_steps, first_shift, first_reset)

    state_specs = EngineState(x=P(axis), lam_eq=P(axis), lam_in=P(axis),
                              mu=P())
    # check_rep=False: the day scan's tick solves may dispatch the fused
    # al_step pallas_call (use_kernel), which has no shard_map
    # replication rule; every output is explicitly spec'd above.
    return shard_map(
        body, mesh=mesh,
        in_specs=(_fleet_specs(p, axis), P(), P(),
                  _norm_specs(p, axis, stacked=True), state_specs),
        out_specs=(P(None, axis), P(axis), P(axis), state_specs),
        check_rep=False)(p, cap_frac, mci_stack, norms_stack, state0)


_DAY_CR2_STATIC_SH = ("mesh", "warm_steps", "first_steps", "first_shift",
                      "first_reset", "outer", "use_kernel", "moment_dtype")
_day_cr2_sharded = jax.jit(_day_cr2_impl_sharded,
                           static_argnames=_DAY_CR2_STATIC_SH)
_day_cr2_sharded_donated = jax.jit(_day_cr2_impl_sharded,
                                   static_argnames=_DAY_CR2_STATIC_SH,
                                   donate_argnums=(4,))


def _day_norm_stacks(problem: FleetProblem, mci_stack, policy,
                     W_pad: int | None = None):
    """Per-tick norms for the sharded day scan, computed from the TRUE
    (unpadded) fleet exactly as the solo path computes them inside each
    tick: the tick-t window is the day rolled -t. Single-region fleets
    stack fleet-global scalars (replicated under the mesh); multi-region
    fleets stack the per-row vectors from `regional.cr1_norms`/
    `cr2_norms`, padded to the device-padded `W_pad` with inert fills so
    the tick axis leads and the row axis shards (`norm_specs(...,
    stacked=True)`)."""
    n = mci_stack.shape[0]
    fills = CR1_NORM_FILLS if isinstance(policy, CR1) else CR2_NORM_FILLS
    rolled = problem
    norms = []
    for t in range(n):
        if t:
            rolled = dataclasses.replace(
                rolled,
                usage=np.roll(np.asarray(rolled.usage), -1, axis=1),
                jobs=np.roll(np.asarray(rolled.jobs), -1, axis=1),
                upper=None if rolled.upper is None
                else np.roll(np.asarray(rolled.upper), -1, axis=1))
        p_t = dataclasses.replace(rolled, mci=mci_stack[t])
        if isinstance(policy, CR1):
            nm = _cr1_norms(p_t)
        else:
            refs = jnp.asarray(cr2_reference_fleet(p_t, policy.cap_frac))
            nm = _cr2_norms(p_t, refs)
        if problem.is_multiregion and W_pad is not None:
            nm = _pad_row_norms(nm, W_pad, fills)
        norms.append(nm)
    return tuple(jnp.stack([nm[i] for nm in norms]) for i in range(3))


def solve_day(problem: FleetProblem, policy, mci_stack, *,
              ctx: SolveContext | None = None, cold_steps: int | None = None,
              warm_steps: int | None = None) -> DayResult:
    """Solve a whole rolling-horizon day as ONE donated-buffer XLA call.

    `mci_stack` is the (n_ticks, T) forecast-revision stack — row i is the
    MCI forecast the controller would see at tick i (e.g.
    `ForecastStream.forecast(t)` for consecutive t). Tick 0 solves with
    `cold_steps` (the policy default when None) from `ctx.warm` or a cold
    state; every later tick fuses window-roll + plan shift + mu-reset +
    a `warm_steps` re-solve (default `cold_steps // 4`) inside one
    `lax.scan`. Matches the per-tick `RollingHorizonSolver.step()` loop
    to <0.01 pp realized carbon while issuing a single dispatch.

    Supports CR1/CR2 — the policies whose backends are pure traceable
    engine calls. CR3 clears its fiscal balance in a host-side loop and
    B1/B3 are closed-form per-tick evaluations; both keep the per-tick
    path. With `ctx.mesh` the whole day scan nests INSIDE the W-axis
    shard_map (per-tick norms ride in from the true fleet — replicated
    scalars for single-region, row-sharded `regional` vectors for
    multi-region), so a sharded day is still one dispatch under both
    1-D and 2-D fleet meshes. Multi-region rows of `mci_stack` are
    (R, T) forecast stacks. Migration is not applied per tick — run
    the committed plan through `solve()` for migration credit.

    Debug/observability lanes (solo path only): `ctx.sanitize` routes
    the whole day through a checkify twin — a NaN/inf in ANY tick's
    gradient/iterate/multipliers raises `SanitizeError` naming the
    first failing check. `ctx.telemetry` returns one
    `repro.obs.ConvergenceTrace` per tick in
    `result.last.extras["telemetry"]` (captured inside the same single
    dispatch; incompatible with `use_kernel`/`mesh`).

    Returns `DayResult`; `result.last.state` warm-starts the next day
    (pass it via `ctx.warm` — the first tick then runs `warm_steps` with
    the usual shift/mu-reset instead of a cold solve).
    """
    ctx = ctx or SolveContext()
    policy = resolve_policy(policy)
    if ctx.sanitize:
        # Day scans have checkify twins (`_day_cr1_checked` /
        # `_day_cr2_checked`) on the solo lane; the same CR1/CR2 +
        # no-mesh/donate restrictions as solve() apply.
        _require_sanitizable(policy, ctx)
    if not isinstance(problem, FleetProblem):
        raise TypeError(
            f"solve_day() takes a FleetProblem; got "
            f"{type(problem).__name__}")
    problem = _single_region_view(problem)
    mci_stack = np.asarray(mci_stack, np.float32)
    if np.ndim(problem.mci) == 1 and mci_stack.ndim == 3 \
            and mci_stack.shape[1] == 1:
        mci_stack = mci_stack[:, 0]   # degenerate R=1 stack, canonicalized
    want = np.asarray(problem.mci).shape
    if mci_stack.ndim != len(want) + 1 or mci_stack.shape[1:] != want:
        raise ValueError(
            f"mci_stack must be (n_ticks,) + {want} (one forecast per "
            f"tick); got shape {mci_stack.shape}")
    n = mci_stack.shape[0]
    use_kernel = resolve_use_kernel(ctx.use_kernel)
    _require_telemetry_ok(ctx, use_kernel)
    tel = _tel_every(ctx)
    if tel and ctx.mesh is not None:
        raise NotImplementedError(
            "SolveContext(telemetry=...) on solve_day is a solo-lane "
            "feature for now — the sharded day scan has no telemetry "
            "plumbing; drop the mesh (or the telemetry) for this day")
    if cold_steps is None:
        cold_steps = ctx.resolved_steps(policy)
    if warm_steps is None:
        warm_steps = max(1, cold_steps // 4)
    cold = ctx.warm is None
    first_steps = cold_steps if cold else warm_steps
    first_shift, first_reset = (0, False) if cold else (ctx.shift or 1,
                                                        True)
    stack = jnp.asarray(mci_stack)
    if not isinstance(policy, (CR1, CR2)):
        raise NotImplementedError(
            f"solve_day supports CR1/CR2 (pure scannable engine "
            f"backends); {policy.name} needs host-side control flow — "
            f"use the per-tick solve()/step() loop")
    if ctx.mesh is not None:
        pp, W = pad_fleet(problem, fleet_device_count(ctx.mesh))
        norms_stack = _day_norm_stacks(problem, mci_stack, policy,
                                       W_pad=pp.W)
        state0 = _pad_state(ctx.warm, pp.W) if ctx.warm is not None else (
            EngineState.cold(jnp.zeros(pp.usage.shape))
            if isinstance(policy, CR1) else
            EngineState.cold(jnp.zeros(pp.usage.shape), n_eq=pp.W,
                             mu0=CR2_MU0))
        if isinstance(policy, CR1):
            run = _day_cr1_sharded_donated if ctx.donate \
                else _day_cr1_sharded
            committed, D, pens, state = run(
                pp, policy.lam, stack, norms_stack, state0, mesh=ctx.mesh,
                warm_steps=warm_steps, first_steps=first_steps,
                first_shift=first_shift, first_reset=first_reset,
                use_kernel=use_kernel, moment_dtype=ctx.moment_dtype)
            mult = 1
        else:
            run = _day_cr2_sharded_donated if ctx.donate \
                else _day_cr2_sharded
            committed, D, pens, state = run(
                pp, policy.cap_frac, stack, norms_stack, state0,
                mesh=ctx.mesh, warm_steps=warm_steps,
                first_steps=first_steps, first_shift=first_shift,
                first_reset=first_reset, outer=policy.outer,
                use_kernel=use_kernel, moment_dtype=ctx.moment_dtype)
            mult = policy.outer
        committed = np.asarray(committed)[:, :W]
        D, pens = np.asarray(D)[:W], np.asarray(pens)[:W]
    else:
        pj = _jit_view(problem)
        W = problem.W
        if isinstance(policy, CR1):
            state0 = ctx.warm if ctx.warm is not None else EngineState.cold(
                jnp.zeros(problem.usage.shape))
            if ctx.sanitize:
                err, out = _day_cr1_checked(
                    pj, policy.lam, stack, state0, warm_steps=warm_steps,
                    first_steps=first_steps, first_shift=first_shift,
                    first_reset=first_reset, use_kernel=use_kernel,
                    moment_dtype=ctx.moment_dtype, sanitize=True,
                    telemetry_every=tel)
                err.throw()
            else:
                run = _day_cr1_donated if ctx.donate else _day_cr1
                out = run(
                    pj, policy.lam, stack, state0, warm_steps=warm_steps,
                    first_steps=first_steps, first_shift=first_shift,
                    first_reset=first_reset, use_kernel=use_kernel,
                    moment_dtype=ctx.moment_dtype, telemetry_every=tel)
            mult = 1
        else:
            state0 = ctx.warm if ctx.warm is not None else EngineState.cold(
                jnp.zeros(problem.usage.shape), n_eq=problem.W,
                mu0=CR2_MU0)
            if ctx.sanitize:
                err, out = _day_cr2_checked(
                    pj, policy.cap_frac, stack, state0,
                    warm_steps=warm_steps, first_steps=first_steps,
                    first_shift=first_shift, first_reset=first_reset,
                    outer=policy.outer, use_kernel=use_kernel,
                    moment_dtype=ctx.moment_dtype, sanitize=True,
                    telemetry_every=tel)
                err.throw()
            else:
                run = _day_cr2_donated if ctx.donate else _day_cr2
                out = run(
                    pj, policy.cap_frac, stack, state0,
                    warm_steps=warm_steps, first_steps=first_steps,
                    first_shift=first_shift, first_reset=first_reset,
                    outer=policy.outer, use_kernel=use_kernel,
                    moment_dtype=ctx.moment_dtype, telemetry_every=tel)
            mult = policy.outer
        committed, D, pens, state = out[:4]
        if tel:
            # One ConvergenceTrace per tick: tick 0's trace is separate
            # (different step budget → different sample count), warm
            # ticks come back stacked on a leading (n-1) axis.
            traces = (ConvergenceTrace.from_aux(out[4]),)
            if out[5] is not None:
                traces += ConvergenceTrace.split(out[5])
        committed = np.asarray(committed)
        D, pens = np.asarray(D), np.asarray(pens)
    iters = (first_steps * mult,) + (warm_steps * mult,) * (n - 1)
    # Reporting view: the final tick's rolled window.
    p_last = dataclasses.replace(
        problem, mci=mci_stack[-1],
        usage=np.roll(np.asarray(problem.usage), -(n - 1), axis=1),
        jobs=np.roll(np.asarray(problem.jobs), -(n - 1), axis=1),
        upper=None if problem.upper is None
        else np.roll(np.asarray(problem.upper), -(n - 1), axis=1))
    last = _report(p_last, np.asarray(D), np.asarray(pens),
                   iters=iters[-1], state=state,
                   extras={"telemetry": traces} if tel else None)
    return DayResult(committed=np.asarray(committed), last=last,
                     inner_steps=iters)


@_register
@dataclasses.dataclass(frozen=True)
class B3:
    """Prioritized Power Capping (paper §V-B, Dynamo): curtail RTS
    workloads only, lowest priority (= last RTS row) first, each up to
    `max_cut` depth — the fleet-array form of `baselines.b3_adjustments`
    with row order as the priority order. Closed form like `B1`."""

    depth: float = 0.3
    max_cut: float = 0.2

    name: ClassVar[str] = "b3"
    default_steps: ClassVar[int] = 0

    def solve(self, p: FleetProblem,
              ctx: SolveContext = SolveContext()) -> FleetSolveResult:
        usage = np.asarray(p.usage)
        D = np.zeros_like(usage)
        remaining = float(self.depth)
        rts_rows = [i for i in range(p.W) if not bool(p.is_batch[i])]
        for i in reversed(rts_rows):
            if remaining <= 0:
                break
            c = min(remaining, self.max_cut)
            L = (1.0 - c) * float(p.entitlement[i])
            D[i] = np.maximum(usage[i] - L, 0.0)
            remaining -= c
        pens = np.asarray(fleet_penalties(
            p, jnp.asarray(D), resolve_use_kernel(ctx.use_kernel)))
        return _report(p, D, pens, iters=0)
