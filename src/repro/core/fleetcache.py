"""Disk cache for the calibrated paper fleet.

Building the four-service fleet runs ~160 EDD simulations (≈45 s); tests,
benchmarks and examples share one cached copy keyed by the build settings.

The cache is crash/race-safe: writes go to a temp file in the cache
directory and land via `os.replace` (atomic on POSIX), so parallel
pytest/CI workers racing the first build can never leave a truncated
`.npz` behind; and a corrupt/unreadable cache file falls back to a rebuild
(which atomically replaces it) instead of poisoning every later run.
"""
from __future__ import annotations

import json
import os
import pathlib
import tempfile
import warnings
import zipfile

import numpy as np

from repro.core.penalty import PenaltyModel, build_paper_fleet

_CACHE_DIR = pathlib.Path(
    os.environ.get("REPRO_CACHE_DIR",
                   pathlib.Path(__file__).resolve().parents[3] / "var"))


def _load_cache(path: pathlib.Path) -> dict[str, PenaltyModel] | None:
    """Read a cached fleet; None (rebuild) on any corruption."""
    try:
        with np.load(path, allow_pickle=False) as z:
            meta = json.loads(str(z["meta"]))
            out = {}
            for name, m in meta.items():
                out[name] = PenaltyModel(
                    name=name, kind=m["kind"], usage=z[f"{name}_usage"],
                    entitlement=m["entitlement"], k=m["k"],
                    params=tuple(m["params"]),
                    jobs=z[f"{name}_jobs"] if f"{name}_jobs" in z else None,
                    slo_hours=m["slo_hours"],
                    feature_names=tuple(m["feature_names"])
                    if m["feature_names"] else None)
            return out
    except (zipfile.BadZipFile, OSError, EOFError, ValueError, KeyError,
            json.JSONDecodeError) as e:
        warnings.warn(f"corrupt fleet cache {path} ({e!r}); rebuilding",
                      RuntimeWarning, stacklevel=3)
        return None


def _save_cache(path: pathlib.Path, fleet: dict[str, PenaltyModel]) -> None:
    """Atomic cache write: temp file in the same directory + os.replace."""
    arrays: dict[str, np.ndarray] = {}
    meta: dict[str, dict] = {}
    for name, m in fleet.items():
        arrays[f"{name}_usage"] = m.usage
        if m.jobs is not None:
            arrays[f"{name}_jobs"] = m.jobs
        meta[name] = {
            "kind": m.kind, "entitlement": m.entitlement, "k": m.k,
            "params": list(m.params), "slo_hours": m.slo_hours,
            "feature_names": list(m.feature_names) if m.feature_names else None,
        }
    _CACHE_DIR.mkdir(parents=True, exist_ok=True)
    fd, tmp = tempfile.mkstemp(dir=_CACHE_DIR, prefix=path.stem,
                               suffix=".tmp.npz")
    try:
        with os.fdopen(fd, "wb") as f:
            np.savez(f, meta=np.str_(json.dumps(meta)), **arrays)
        os.replace(tmp, path)
    except BaseException:
        try:
            os.unlink(tmp)
        except OSError:
            pass
        raise


def cached_paper_fleet(hours: int = 48, total_power: float = 100.0,
                       num_samples: int = 160, num_jobs: int = 10_000,
                       seed: int = 0) -> dict[str, PenaltyModel]:
    key = f"fleet_h{hours}_p{total_power:g}_s{num_samples}_j{num_jobs}_r{seed}"
    path = _CACHE_DIR / f"{key}.npz"
    if path.exists():
        cached = _load_cache(path)
        if cached is not None:
            return cached
    fleet = build_paper_fleet(hours=hours, total_power=total_power,
                              num_samples=num_samples, num_jobs=num_jobs,
                              seed=seed)
    _save_cache(path, fleet)
    return fleet
