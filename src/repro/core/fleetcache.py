"""Disk cache for the calibrated paper fleet.

Building the four-service fleet runs ~160 EDD simulations (≈45 s); tests,
benchmarks and examples share one cached copy keyed by the build settings.
"""
from __future__ import annotations

import json
import os
import pathlib

import numpy as np

from repro.core.penalty import PenaltyModel, build_paper_fleet

_CACHE_DIR = pathlib.Path(
    os.environ.get("REPRO_CACHE_DIR",
                   pathlib.Path(__file__).resolve().parents[3] / "var"))


def cached_paper_fleet(hours: int = 48, total_power: float = 100.0,
                       num_samples: int = 160, num_jobs: int = 10_000,
                       seed: int = 0) -> dict[str, PenaltyModel]:
    key = f"fleet_h{hours}_p{total_power:g}_s{num_samples}_j{num_jobs}_r{seed}"
    path = _CACHE_DIR / f"{key}.npz"
    if path.exists():
        z = np.load(path, allow_pickle=False)
        meta = json.loads(str(z["meta"]))
        out = {}
        for name, m in meta.items():
            out[name] = PenaltyModel(
                name=name, kind=m["kind"], usage=z[f"{name}_usage"],
                entitlement=m["entitlement"], k=m["k"],
                params=tuple(m["params"]),
                jobs=z[f"{name}_jobs"] if f"{name}_jobs" in z else None,
                slo_hours=m["slo_hours"],
                feature_names=tuple(m["feature_names"])
                if m["feature_names"] else None)
        return out
    fleet = build_paper_fleet(hours=hours, total_power=total_power,
                              num_samples=num_samples, num_jobs=num_jobs,
                              seed=seed)
    _CACHE_DIR.mkdir(parents=True, exist_ok=True)
    arrays: dict[str, np.ndarray] = {}
    meta: dict[str, dict] = {}
    for name, m in fleet.items():
        arrays[f"{name}_usage"] = m.usage
        if m.jobs is not None:
            arrays[f"{name}_jobs"] = m.jobs
        meta[name] = {
            "kind": m.kind, "entitlement": m.entitlement, "k": m.k,
            "params": list(m.params), "slo_hours": m.slo_hours,
            "feature_names": list(m.feature_names) if m.feature_names else None,
        }
    np.savez(path, meta=np.str_(json.dumps(meta)), **arrays)
    return fleet
