"""Lasso regression in JAX (paper §IV-A2, Table V).

The paper fits batch penalty models with Lasso ("includes feature selection
and regularization"), choosing the l1 weight alpha by 10-fold cross
validation. We implement FISTA (accelerated proximal gradient) on the
standardized design matrix — jit-compiled, vmap-able over folds and alphas so
the whole CV grid solves in one XLA call.

objective:  (1/2n)||y - Xw - b||² + alpha * ||w||₁
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

Array = jax.Array


def soft_threshold(x: Array, thr: Array) -> Array:
    return jnp.sign(x) * jnp.maximum(jnp.abs(x) - thr, 0.0)


@functools.partial(jax.jit, static_argnames=("iters",))
def lasso_fista(X: Array, y: Array, alpha: Array, iters: int = 500
                ) -> tuple[Array, Array]:
    """FISTA for standardized X (zero-mean columns). Returns (w, intercept).

    The intercept is handled closed-form: b = mean(y) when X is centered.
    """
    n = X.shape[0]
    ymean = y.mean()
    yc = y - ymean
    # Lipschitz constant of the smooth part: ||X||²/n.
    L = jnp.linalg.norm(X, ord=2) ** 2 / n + 1e-12
    step = 1.0 / L

    def body(carry, _):
        w, z, t = carry
        grad = X.T @ (X @ z - yc) / n
        w_next = soft_threshold(z - step * grad, step * alpha)
        t_next = 0.5 * (1.0 + jnp.sqrt(1.0 + 4.0 * t * t))
        z_next = w_next + ((t - 1.0) / t_next) * (w_next - w)
        return (w_next, z_next, t_next), None

    w0 = jnp.zeros(X.shape[1], X.dtype)
    (w, _, _), _ = jax.lax.scan(body, (w0, w0, jnp.asarray(1.0, X.dtype)),
                                None, length=iters)
    return w, ymean


@dataclasses.dataclass(frozen=True)
class LassoFit:
    """Fitted Lasso model in the ORIGINAL (unstandardized) feature space."""

    coef: np.ndarray          # (F,) original-scale coefficients
    intercept: float
    alpha: float
    selected: tuple[int, ...]  # indices of non-zero coefficients
    cv_mae_mean: float
    cv_mae_var: float
    r2: float

    def predict(self, X: np.ndarray) -> np.ndarray:
        return np.asarray(X) @ self.coef + self.intercept


def _standardize(X: np.ndarray) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
    mu = X.mean(axis=0)
    sd = X.std(axis=0)
    sd = np.where(sd < 1e-12, 1.0, sd)
    return (X - mu) / sd, mu, sd


def fit_lasso_cv(X: np.ndarray, y: np.ndarray,
                 alphas: Sequence[float] | None = None,
                 folds: int = 10, iters: int = 800, seed: int = 0,
                 ) -> LassoFit:
    """10-fold CV over an alpha grid, then refit on all data (paper method).

    All (fold × alpha) problems are solved in a single vmapped XLA call.
    """
    X = np.asarray(X, dtype=np.float64)
    y = np.asarray(y, dtype=np.float64)
    n, F = X.shape
    Xs, mu, sd = _standardize(X)
    if alphas is None:
        amax = float(np.abs(Xs.T @ (y - y.mean())).max() / n)
        alphas = list(amax * np.logspace(0, -3, 12))
    alphas_arr = np.asarray(alphas)

    rng = np.random.default_rng(seed)
    perm = rng.permutation(n)
    fold_id = np.arange(n) % folds
    fold_of = np.empty(n, dtype=int)
    fold_of[perm] = fold_id

    Xj = jnp.asarray(Xs)
    yj = jnp.asarray(y)

    def fit_one(alpha: Array, mask: Array) -> Array:
        # Mask-out validation rows by zero-weighting them (keeps static shape).
        wgt = mask.astype(Xj.dtype)
        Xw = Xj * wgt[:, None]
        yw = yj * wgt
        # Adjust: center within the training fold.
        ntr = wgt.sum()
        xmean = Xw.sum(0) / ntr
        ymean = yw.sum() / ntr
        Xc = (Xj - xmean) * wgt[:, None]
        yc = (yj - ymean) * wgt
        L = jnp.linalg.norm(Xc, ord=2) ** 2 / ntr + 1e-12
        step = 1.0 / L

        def body(carry, _):
            w, z, t = carry
            grad = Xc.T @ (Xc @ z - yc) / ntr
            w_next = soft_threshold(z - step * grad, step * alpha)
            t_next = 0.5 * (1.0 + jnp.sqrt(1.0 + 4.0 * t * t))
            z_next = w_next + ((t - 1.0) / t_next) * (w_next - w)
            return (w_next, z_next, t_next), None

        w0 = jnp.zeros(F)
        (w, _, _), _ = jax.lax.scan(body, (w0, w0, jnp.asarray(1.0)), None,
                                    length=iters)
        b = ymean - xmean @ w
        return jnp.concatenate([w, b[None]])

    masks = jnp.asarray(np.stack([fold_of != k for k in range(folds)]))
    # vmap over folds then alphas: (A, folds, F+1)
    fits = jax.vmap(lambda a: jax.vmap(lambda m: fit_one(a, m))(masks))(
        jnp.asarray(alphas_arr))
    fits = np.asarray(fits)

    # Validation MAE per (alpha, fold).
    maes = np.zeros((len(alphas_arr), folds))
    for ai in range(len(alphas_arr)):
        for k in range(folds):
            w, b = fits[ai, k, :F], fits[ai, k, F]
            val = fold_of == k
            pred = Xs[val] @ w + b
            maes[ai, k] = np.abs(pred - y[val]).mean()
    mae_mean = maes.mean(axis=1)
    best = int(np.argmin(mae_mean))
    alpha = float(alphas_arr[best])

    # Refit on all data.
    w, b = lasso_fista(Xj, yj, jnp.asarray(alpha), iters=iters)
    w = np.asarray(w)
    b = float(b)
    pred = Xs @ w + b
    ss_res = float(((y - pred) ** 2).sum())
    ss_tot = float(((y - y.mean()) ** 2).sum()) + 1e-12
    # Unstandardize.
    coef = w / sd
    intercept = b - float(mu @ coef)
    selected = tuple(int(i) for i in np.nonzero(np.abs(w) > 1e-8)[0])
    return LassoFit(coef=coef, intercept=intercept, alpha=alpha,
                    selected=selected,
                    cv_mae_mean=float(mae_mean[best]),
                    cv_mae_var=float(maes[best].var()),
                    r2=1.0 - ss_res / ss_tot)
