"""Batched scenario-ensemble evaluation and risk reports.

`repro.core.scenario` materializes S grid/fleet scenarios as a
`ScenarioStack` — per-field overlays with a leading S axis. This module
evaluates a `DRPolicy` across the whole stack:

  * `evaluate_ensemble(problem, policy, scenarios, ctx=...)` — the entry
    point (also exposed as `repro.core.api.ensemble`). For the engine
    policy families whose solve is a single XLA call (CR1/CR2), the S
    axis rides `jax.vmap` through the same `_cr{1,2}_impl` backends
    `api.solve` jits — ONE batched XLA call for the whole ensemble, no
    Python loop over scenarios. With `ctx.mesh`, the scenario vmap nests
    *inside* the W-axis shard_map exactly like `api.sweep`'s policy-grid
    vmap does, so fleet-scale ensembles run sharded too. Every other
    policy (CR3's host-side clearing loop, closed-form baselines, warm/
    donated contexts) falls back to an equivalent sequential loop of
    `api.solve` over the materialized scenarios — `evaluate_ensemble` is
    always safe to call.

  * `run_streaming_ensemble(problem, policy, streams, ...)` — the
    rolling-horizon variant: S independent `ForecastStream`s (e.g. from
    `scenario.ForecastRegime.streams`) drive one batched controller.
    Each tick stacks the S revised forecasts, warm-starts every
    scenario's lane from its own previous `EngineState` (shift + mu
    reset folded into the same batched call), and commits hour 0 per
    scenario — S online controllers for the price of one batched
    re-solve per tick.

  * `EnsembleResult.report()` / `EnsembleReport` — the risk layer:
    quantiles and CVaR of realized carbon reduction and fleet penalty,
    per-workload penalty distributions and SLO-violation probabilities,
    fairness dispersion per scenario (Jain index, max/min share ratio),
    and `compare_policies` tables for benchmarks and examples.

Parity contract (tested in tests/test_ensemble.py and the sharding
suite): the batched lane matches the sequential `api.solve` loop to
<0.01 pp on every scenario, single-device and on a device mesh — vmap
reorders floating-point reductions, so bitwise equality is not promised,
convergence-level equality is.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.core.api import (CR1, CR2, SolveContext, _cr1_impl, _cr1_pieces,
                            _cr2_cfg, _cr2_impl, _cr2_pieces,
                            resolve_policy, solve)
from repro.core.engine import EngineConfig, EngineState, al_minimize
from repro.core.fleet_solver import (CR1_MU0, CR2_MU0, PAD_FILLS,
                                     FleetProblem, _fleet_specs, _jit_view,
                                     cr2_reference_fleet, fleet_penalties,
                                     pad_fleet, resolve_use_kernel)
from repro.core.metrics import jain_index, max_min_ratio
from repro.core.regional import (CR1_NORM_FILLS, CR2_NORM_FILLS, cr1_norms,
                                 cr2_norms, norm_specs, pad_row_norms,
                                 region_totals)
from repro.core.scenario import ScenarioStack, resolve_scenarios
from repro.launch.mesh import fleet_axes, fleet_device_count

__all__ = ["EnsembleReport", "EnsembleResult", "compare_policies",
           "comparison_table", "evaluate_ensemble",
           "run_streaming_ensemble", "StreamingEnsembleReport"]

# ---------------------------------------------------------------------------
# Batched engine lanes (CR1/CR2): vmap over the scenario axis
# ---------------------------------------------------------------------------
def _overlay_args(stack: ScenarioStack) -> tuple[tuple[str, ...], tuple]:
    over = stack.overlay_fields()
    keys = tuple(over)
    return keys, tuple(jnp.asarray(over[k]) for k in keys)


def _cold_states(S: int, shape: tuple[int, int], n_eq: int = 0,
                 n_in: int = 0, mu0: float = EngineConfig.mu0) -> EngineState:
    """S stacked cold EngineStates (leading S axis on every leaf)."""
    return EngineState(x=jnp.zeros((S,) + shape),
                       lam_eq=jnp.zeros((S, n_eq)),
                       lam_in=jnp.zeros((S, n_in)),
                       mu=jnp.full((S,), mu0))


_ENS1_STATIC = ("keys", "steps", "use_kernel", "shift", "reset_mu")


@functools.partial(jax.jit, static_argnames=_ENS1_STATIC)
def _cr1_ens_run(p: FleetProblem, vals, keys, lam, states: EngineState,
                 steps: int, use_kernel: bool, shift: int, reset_mu: bool):
    """All S scenario solves as one vmapped call through the same
    `_cr1_impl` backend `api.solve` jits — warm/cold/streaming alike."""
    def one(vals_s, st):
        ps = dataclasses.replace(p, **dict(zip(keys, vals_s)))
        return _cr1_impl(ps, lam, st, steps, use_kernel, shift, reset_mu)

    return jax.vmap(one)(vals, states)


_ENS2_STATIC = ("keys", "steps", "outer", "use_kernel", "shift", "reset_mu")


@functools.partial(jax.jit, static_argnames=_ENS2_STATIC)
def _cr2_ens_run(p: FleetProblem, vals, keys, refs, states: EngineState,
                 steps: int, outer: int, use_kernel: bool, shift: int,
                 reset_mu: bool):
    def one(vals_s, refs_s, st):
        ps = dataclasses.replace(p, **dict(zip(keys, vals_s)))
        return _cr2_impl(ps, refs_s, st, steps, outer, use_kernel, shift,
                         reset_mu)

    return jax.vmap(one)(vals, refs, states)


def _overlay_specs(keys: tuple[str, ...], axis: str):
    """shard_map specs for stacked overlays: per-workload fields sharded on
    their W axis (dim 1, after the scenario axis), the MCI replicated."""
    return tuple(P() if k == "mci" else P(None, axis) for k in keys)


def _ens_state_specs(axis: str) -> EngineState:
    return EngineState(x=P(None, axis), lam_eq=P(None, axis),
                       lam_in=P(None, axis), mu=P())


@functools.partial(jax.jit,
                   static_argnames=("keys", "mesh", "steps", "use_kernel"))
def _cr1_ens_sharded(p: FleetProblem, vals, keys, lam, norms,
                     states: EngineState, mesh, steps: int,
                     use_kernel: bool):
    """The scenario axis vmapped INSIDE the W-axis shard_map (the
    `api.sweep` sharded-grid pattern): every device solves its row block
    for all S scenarios in one call. Per-scenario global normalizers come
    from the TRUE fleets (computed outside, stacked, replicated)."""
    from jax.experimental.shard_map import shard_map
    axis = fleet_axes(mesh)

    def body(pb, vals_b, norms_b, states_b):
        def one(vals_s, norms_s, st):
            ps = dataclasses.replace(pb, **dict(zip(keys, vals_s)))
            objective, project, step_scale = _cr1_pieces(
                ps, use_kernel, norms=norms_s)
            D, aux = al_minimize(
                objective, project, st.x, hyper=lam,
                step_scale=step_scale, init=st,
                cfg=EngineConfig(inner_steps=steps, outer_steps=1))
            return D, fleet_penalties(ps, D, use_kernel), aux["state"]

        return jax.vmap(one)(vals_b, norms_b, states_b)

    specs = _ens_state_specs(axis)
    return shard_map(
        body, mesh=mesh,
        in_specs=(_fleet_specs(p, axis), _overlay_specs(keys, axis),
                  norm_specs(p, axis, stacked=True), specs),
        out_specs=(P(None, axis), P(None, axis), specs),
    )(p, vals, norms, states)


@functools.partial(jax.jit, static_argnames=("keys", "mesh", "steps",
                                             "outer", "use_kernel"))
def _cr2_ens_sharded(p: FleetProblem, vals, keys, refs, norms,
                     states: EngineState, mesh, steps: int, outer: int,
                     use_kernel: bool):
    from jax.experimental.shard_map import shard_map
    axis = fleet_axes(mesh)

    def body(pb, vals_b, refs_b, norms_b, states_b):
        def one(vals_s, refs_s, norms_s, st):
            ps = dataclasses.replace(pb, **dict(zip(keys, vals_s)))
            objective, eq, project, step_scale = _cr2_pieces(
                ps, refs_s, use_kernel, norms=norms_s)
            D, aux = al_minimize(
                objective, project, st.x, eq_residual=eq,
                step_scale=step_scale, init=st,
                cfg=_cr2_cfg(steps, outer))
            return D, fleet_penalties(ps, D, use_kernel), aux["state"]

        return jax.vmap(one)(vals_b, refs_b, norms_b, states_b)

    specs = _ens_state_specs(axis)
    return shard_map(
        body, mesh=mesh,
        in_specs=(_fleet_specs(p, axis), _overlay_specs(keys, axis),
                  P(None, axis), norm_specs(p, axis, stacked=True), specs),
        out_specs=(P(None, axis), P(None, axis), specs),
    )(p, vals, refs, norms, states)


def _pad_overlays(keys: tuple[str, ...], vals: tuple, W: int, W_pad: int):
    """Pad stacked per-workload overlays with `pad_fleet`'s inert fills
    (`fleet_solver.PAD_FILLS` — shared so the conventions cannot drift)."""
    if W_pad == W:
        return vals
    out = []
    for k, v in zip(keys, vals):
        if k == "mci":
            out.append(v)
            continue
        pad_shape = (v.shape[0], W_pad - W) + v.shape[2:]
        out.append(jnp.concatenate(
            [v, jnp.full(pad_shape, PAD_FILLS[k], v.dtype)], axis=1))
    return tuple(out)


def _cr2_refs(policy, p: FleetProblem, stack: ScenarioStack) -> list:
    """Per-scenario CR2 fairness targets. The reference depends on
    usage/entitlement/jobs (the capped penalty under an equal power cap)
    but never on the MCI, so MCI-only ensembles — every streaming tick —
    compute it once and share it across all S lanes."""
    over = stack.overlay_fields()
    if not {"usage", "entitlement", "jobs"} & set(over):
        return [jnp.asarray(cr2_reference_fleet(p, policy.cap_frac))] \
            * stack.S
    return [jnp.asarray(cr2_reference_fleet(ps, policy.cap_frac))
            for ps in stack.problems(p)]


def _run_batched(policy, p: FleetProblem, stack: ScenarioStack, *,
                 steps: int, use_kernel: bool, mesh=None,
                 init: EngineState | None = None, shift: int = 0,
                 reset_mu: bool = False):
    """One batched XLA call solving all S scenarios under `policy`
    (CR1/CR2). Returns (D (S, W, T) np, pens (S, W) np, states stacked).

    `init` (stacked `EngineState`, e.g. the previous streaming tick's)
    warm-starts every lane; `shift`/`reset_mu` fold the rolling-horizon
    tick entry into the same call. The mesh lane is cold-only (the
    streaming ensemble runs single-device)."""
    S = stack.S
    keys, vals = _overlay_args(stack)
    if mesh is None:
        pj = _jit_view(p)
        if type(policy) is CR1:
            if init is None:
                init = _cold_states(S, p.usage.shape, mu0=CR1_MU0)
            D, pens, states = _cr1_ens_run(
                pj, vals, keys, policy.lam, init, steps=steps,
                use_kernel=use_kernel, shift=shift, reset_mu=reset_mu)
        else:
            refs = jnp.stack(_cr2_refs(policy, p, stack))
            if init is None:
                init = _cold_states(S, p.usage.shape, n_eq=p.W,
                                    mu0=CR2_MU0)
            D, pens, states = _cr2_ens_run(
                pj, vals, keys, refs, init, steps=steps,
                outer=policy.outer, use_kernel=use_kernel, shift=shift,
                reset_mu=reset_mu)
        return np.asarray(D), np.asarray(pens), states
    if init is not None or shift or reset_mu:
        raise ValueError(
            "the sharded ensemble lane is cold-only (no warm/shift/"
            "reset_mu); run the streaming ensemble without a mesh")
    pp, W = pad_fleet(p, fleet_device_count(mesh))
    vals_p = _pad_overlays(keys, vals, W, pp.W)
    if type(policy) is CR1:
        norms = [cr1_norms(ps) for ps in stack.problems(p)]
        if p.is_multiregion:
            norms = [pad_row_norms(n, pp.W, CR1_NORM_FILLS) for n in norms]
        norms_stack = tuple(jnp.stack([n[i] for n in norms])
                            for i in range(3))
        states = _cold_states(S, pp.usage.shape, mu0=CR1_MU0)
        D, pens, states = _cr1_ens_sharded(
            pp, vals_p, keys, policy.lam, norms_stack, states, mesh=mesh,
            steps=steps, use_kernel=use_kernel)
    else:
        refs = _cr2_refs(policy, p, stack)
        norms = [cr2_norms(ps, r)
                 for ps, r in zip(stack.problems(p), refs)]
        if p.is_multiregion:
            norms = [pad_row_norms(n, pp.W, CR2_NORM_FILLS) for n in norms]
        norms_stack = tuple(jnp.stack([n[i] for n in norms])
                            for i in range(3))
        refs_p = jnp.stack([
            jnp.concatenate([r, jnp.zeros(pp.W - W, r.dtype)])
            for r in refs])
        states = _cold_states(S, pp.usage.shape, n_eq=pp.W, mu0=CR2_MU0)
        D, pens, states = _cr2_ens_sharded(
            pp, vals_p, keys, refs_p, norms_stack, states, mesh=mesh,
            steps=steps, outer=policy.outer, use_kernel=use_kernel)
    return np.asarray(D)[:, :W], np.asarray(pens)[:, :W], states


def _batched_capable(policy) -> bool:
    return type(policy) in (CR1, CR2)


# ---------------------------------------------------------------------------
# Ensemble results + the risk layer
# ---------------------------------------------------------------------------
def _quantiles(x: np.ndarray, qs: Sequence[float]) -> dict[str, float]:
    return {f"p{int(q)}": float(np.percentile(x, q)) for q in qs}


def _cvar(x: np.ndarray, alpha: float, worst: str) -> np.ndarray:
    """Mean of the worst `alpha` tail — `worst='low'` for quantities where
    small is bad (carbon reduction), `'high'` where large is bad
    (penalty)."""
    x = np.sort(np.asarray(x, float))
    k = max(1, int(np.ceil(alpha * x.shape[0])))
    tail = x[:k] if worst == "low" else x[-k:]
    return float(tail.mean())


@dataclasses.dataclass(frozen=True)
class EnsembleResult:
    """Per-scenario outcomes of one policy across a scenario stack."""

    policy: Any                          # the DRPolicy evaluated
    labels: tuple[str, ...]              # scenario labels, length S
    carbon_reduction_pct: np.ndarray     # (S,)
    total_penalty_pct: np.ndarray        # (S,)
    penalties: np.ndarray                # (S, W) raw per-workload penalties
    entitlement: np.ndarray              # (S, W) per-scenario entitlements
    preservation_violation: np.ndarray   # (S,)
    D: np.ndarray                        # (S, W, T) adjustment plans
    extras: tuple[dict, ...]             # per-scenario policy extras
    batched: bool                        # one vmapped call vs solve() loop

    @property
    def S(self) -> int:
        return int(self.carbon_reduction_pct.shape[0])

    def penalty_shares(self) -> np.ndarray:
        """(S, W) capacity-scaled penalty shares pen_i/E_i."""
        return np.maximum(self.penalties, 0.0) / self.entitlement

    def report(self, *, slo_frac: float = 0.05, cvar_alpha: float = 0.25,
               quantiles: Sequence[float] = (5, 25, 50, 75, 95),
               ) -> "EnsembleReport":
        """Distill the ensemble into risk metrics (see `EnsembleReport`)."""
        # p5/p50/p95 are always computed — `lines()`/`comparison_table`
        # render them regardless of the caller's quantile choice.
        quantiles = sorted({*quantiles, 5, 50, 95})
        car = self.carbon_reduction_pct
        pen = self.total_penalty_pct
        shares = self.penalty_shares()
        jain = jain_index(self.penalties, self.entitlement, axis=-1)
        mm = max_min_ratio(self.penalties, self.entitlement, axis=-1)
        viol = shares > slo_frac                   # (S, W)
        k = max(1, int(np.ceil(cvar_alpha * self.S)))
        worst = tuple(self.labels[i] for i in np.argsort(car)[:k])
        return EnsembleReport(
            policy=getattr(self.policy, "name", str(self.policy)),
            n_scenarios=self.S,
            carbon_quantiles=_quantiles(car, quantiles),
            carbon_mean=float(car.mean()),
            carbon_cvar=_cvar(car, cvar_alpha, "low"),
            penalty_quantiles=_quantiles(pen, quantiles),
            penalty_mean=float(pen.mean()),
            penalty_cvar=_cvar(pen, cvar_alpha, "high"),
            jain_quantiles=_quantiles(jain, quantiles),
            jain_min=float(jain.min()),
            maxmin_median=float(np.median(mm)),
            slo_frac=slo_frac, cvar_alpha=cvar_alpha,
            slo_violation_prob=float(viol.any(axis=1).mean()),
            workload_slo_prob=viol.mean(axis=0),
            workload_penalty_p95=np.percentile(shares, 95, axis=0),
            worst_scenarios=worst)


@dataclasses.dataclass(frozen=True)
class EnsembleReport:
    """Risk summary of a policy over S scenarios.

    CVaR_α is the expected outcome over the worst α-fraction of
    scenarios — lowest carbon reductions, highest penalties — the
    number an operator signs off on, not the median. SLO violation:
    a workload breaches when its capacity-scaled penalty share
    pen_i/E_i exceeds `slo_frac`; `slo_violation_prob` is the fraction
    of scenarios where ANY workload breaches."""

    policy: str
    n_scenarios: int
    carbon_quantiles: dict[str, float]   # carbon reduction, % of baseline
    carbon_mean: float
    carbon_cvar: float
    penalty_quantiles: dict[str, float]  # fleet penalty, % of entitlement
    penalty_mean: float
    penalty_cvar: float
    jain_quantiles: dict[str, float]     # fairness dispersion per scenario
    jain_min: float
    maxmin_median: float                 # median max/min share ratio
    slo_frac: float
    cvar_alpha: float
    slo_violation_prob: float
    workload_slo_prob: np.ndarray        # (W,) per-workload breach prob
    workload_penalty_p95: np.ndarray     # (W,) p95 penalty share
    worst_scenarios: tuple[str, ...]     # labels of the CVaR tail

    def lines(self) -> list[str]:
        cq, pq, jq = (self.carbon_quantiles, self.penalty_quantiles,
                      self.jain_quantiles)
        a = int(100 * self.cvar_alpha)
        return [
            f"policy {self.policy} over {self.n_scenarios} scenarios:",
            f"  carbon reduction : p50={cq['p50']:.2f}%  "
            f"[p5={cq['p5']:.2f}, p95={cq['p95']:.2f}]  "
            f"CVaR{a}={self.carbon_cvar:.2f}%",
            f"  fleet penalty    : p50={pq['p50']:.2f}%  "
            f"[p5={pq['p5']:.2f}, p95={pq['p95']:.2f}]  "
            f"CVaR{a}={self.penalty_cvar:.2f}%",
            f"  fairness (Jain)  : p50={jq['p50']:.3f}  "
            f"min={self.jain_min:.3f}  "
            f"max/min share p50="
            + (f"{self.maxmin_median:.1f}x" if self.maxmin_median < 9999.5
               else ">=10000x (saturated: some workload pays ~nothing)"),
            f"  SLO (> {100 * self.slo_frac:.0f}% of E_i) breach prob: "
            f"{100 * self.slo_violation_prob:.0f}% of scenarios",
            f"  worst scenarios  : {', '.join(self.worst_scenarios[:3])}",
        ]

    def as_dict(self) -> dict:
        """JSON-ready record (benchmark trajectory files)."""
        d = dataclasses.asdict(self)
        d["workload_slo_prob"] = np.asarray(
            self.workload_slo_prob).tolist()
        d["workload_penalty_p95"] = np.asarray(
            self.workload_penalty_p95).tolist()
        d["worst_scenarios"] = list(self.worst_scenarios)
        return d


def _stack_arrays(base: FleetProblem, stack: ScenarioStack):
    """Per-scenario (mci, usage, entitlement) with the S axis broadcast
    from the base where not overlaid."""
    S = stack.S
    mci = stack.mci if stack.mci is not None else np.broadcast_to(
        np.asarray(base.mci, float), (S,) + np.asarray(base.mci).shape)
    usage = stack.usage if stack.usage is not None else np.broadcast_to(
        np.asarray(base.usage, float), (S, base.W, base.T))
    ent = stack.entitlement if stack.entitlement is not None else \
        np.broadcast_to(np.asarray(base.entitlement, float), (S, base.W))
    return np.asarray(mci, float), np.asarray(usage, float), \
        np.asarray(ent, float)


def _result_from_stacks(base: FleetProblem, stack: ScenarioStack, policy,
                        D: np.ndarray, pens: np.ndarray, batched: bool,
                        ) -> EnsembleResult:
    """Vectorized `fleet_solver._report` over the scenario axis."""
    mci, usage, ent = _stack_arrays(base, stack)
    if mci.ndim == 3:                # multi-region: (S, R, T) MCI stacks
        wmci = mci[:, np.asarray(base.region), :]                # (S, W, T)
        carbon_base = (usage * wmci).sum(axis=(1, 2))            # (S,)
        car = (D * wmci).sum(axis=(1, 2))
    else:
        carbon_base = (usage.sum(axis=1) * mci).sum(axis=1)      # (S,)
        car = np.einsum("swt,st->s", D, mci)
    n_days = max(1, base.T // base.day_hours)
    span = n_days * base.day_hours
    sums = D[:, :, :span].reshape(D.shape[0], base.W, n_days,
                                  base.day_hours).sum(-1)
    is_batch = np.asarray(base.is_batch, bool)
    viol = np.abs(sums[:, is_batch]).max(axis=(1, 2)) if is_batch.any() \
        else np.zeros(D.shape[0])
    labels = tuple(stack.label(s) for s in range(stack.S))
    return EnsembleResult(
        policy=policy, labels=labels,
        carbon_reduction_pct=100 * car / carbon_base,
        total_penalty_pct=100 * pens.sum(axis=1) / ent.sum(axis=1),
        penalties=pens, entitlement=ent, preservation_violation=viol,
        D=D, extras=tuple({} for _ in range(stack.S)), batched=batched)


# ---------------------------------------------------------------------------
# Entry points
# ---------------------------------------------------------------------------
def evaluate_ensemble(problem: FleetProblem, policy, scenarios, *,
                      ctx: SolveContext | None = None,
                      batched: bool | None = None) -> EnsembleResult:
    """Evaluate `policy` on S scenarios of `problem` — the ensemble entry
    point (also `repro.core.api.ensemble`).

    `scenarios` is a `ScenarioStack`, a scenario generator (or
    `SCENARIO_REGISTRY` name), or a sequence of those (concatenated).
    CR1/CR2 run all S scenarios as ONE vmapped XLA call (nested inside
    the W-axis shard_map when `ctx.mesh` is set); other policies and
    warm/donated contexts fall back to a sequential loop of `api.solve`
    with identical semantics — `ctx.telemetry` also takes the loop, so
    each scenario's `ConvergenceTrace` rides its own entry of
    `result.extras`. `batched` forces the lane (True raises if the
    policy has no batched backend; False forces the loop — the
    parity-test hook)."""
    ctx = ctx or SolveContext()
    policy = resolve_policy(policy)
    stack = resolve_scenarios(scenarios, problem)
    can_batch = (_batched_capable(policy) and ctx.warm is None
                 and not ctx.donate and not ctx.shift and not ctx.reset_mu
                 and ctx.telemetry is None)
    if batched is True and not can_batch:
        raise ValueError(
            f"no batched ensemble lane for policy "
            f"{getattr(policy, 'name', policy)!r} under this context "
            "(CR1/CR2, no warm/donate/shift/reset_mu/telemetry)")
    if batched is False or not can_batch:
        probs = list(stack.problems(problem))
        results = [solve(ps, policy,
                         ctx=dataclasses.replace(ctx, donate=False))
                   for ps in probs]
        mci, usage, ent = _stack_arrays(problem, stack)
        uk = resolve_use_kernel(ctx.use_kernel)
        return EnsembleResult(
            policy=policy,
            labels=tuple(stack.label(s) for s in range(stack.S)),
            carbon_reduction_pct=np.asarray(
                [r.carbon_reduction_pct for r in results]),
            total_penalty_pct=np.asarray(
                [r.total_penalty_pct for r in results]),
            # per-workload penalties are not part of FleetSolveResult, so
            # they are evaluated once per scenario on the solved plans
            penalties=np.stack([
                np.asarray(fleet_penalties(ps, jnp.asarray(r.D), uk))
                for ps, r in zip(probs, results)]),
            entitlement=ent,
            preservation_violation=np.asarray(
                [r.preservation_violation for r in results]),
            D=np.stack([r.D for r in results]),
            extras=tuple(r.extras for r in results), batched=False)
    steps = ctx.resolved_steps(policy)
    use_kernel = resolve_use_kernel(ctx.use_kernel)
    D, pens, _ = _run_batched(policy, problem, stack, steps=steps,
                              use_kernel=use_kernel, mesh=ctx.mesh)
    res = _result_from_stacks(problem, stack, policy, D, pens,
                              batched=True)
    return _apply_migration_credit(problem, stack, res)


def _apply_migration_credit(base: FleetProblem, stack: ScenarioStack,
                            res: EnsembleResult) -> EnsembleResult:
    """Per-scenario migration post-stage for the batched lane — exactly
    what `api.solve`'s `_maybe_migrate` applies in the loop lane, so the
    two lanes stay in parity on multi-region problems with a usable
    topology."""
    if (base.topology is None or not base.is_multiregion
            or not np.any(np.asarray(base.topology.bandwidth) > 0.0)):
        return res
    from repro.core.migration import fleet_migration
    car = res.carbon_reduction_pct.copy()
    extras = []
    for s, ps in enumerate(stack.problems(base)):
        plan = fleet_migration(ps, np.asarray(res.D[s]))
        wmci = np.asarray(ps.mci)[np.asarray(ps.region)]
        carbon_base = float((np.asarray(ps.usage) * wmci).sum())
        car[s] += 100.0 * plan.net_saved / carbon_base
        extras.append({"migration": plan})
    return dataclasses.replace(res, carbon_reduction_pct=car,
                               extras=tuple(extras))


def compare_policies(problem: FleetProblem, policies: Sequence, scenarios,
                     *, ctx: SolveContext | None = None,
                     **report_kw) -> dict[str, EnsembleReport]:
    """Risk reports for several policies on the SAME scenario stack —
    the policy-vs-policy comparison feeding `benchmarks/` and examples.
    Keys are registry names (duplicate families get `name#i` suffixes)."""
    stack = resolve_scenarios(scenarios, problem)
    out: dict[str, EnsembleReport] = {}
    for pl in policies:
        pl = resolve_policy(pl)
        rep = evaluate_ensemble(problem, pl, stack, ctx=ctx).report(
            **report_kw)
        key = rep.policy
        if key in out:
            key = f"{key}#{sum(k.split('#')[0] == rep.policy for k in out)}"
        out[key] = rep
    return out


def comparison_table(reports: dict[str, EnsembleReport]) -> list[str]:
    """Fixed-width policy-vs-policy table (one row per report)."""
    a = int(100 * next(iter(reports.values())).cvar_alpha) if reports else 0
    head = (f"{'policy':10s} {'carbon p50':>10s} {'carbon p5':>10s} "
            f"{f'CVaR{a}':>8s} {'pen p50':>8s} {'pen CVaR':>9s} "
            f"{'jain p50':>9s} {'SLO prob':>9s}")
    rows = [head, "-" * len(head)]
    for name, r in reports.items():
        rows.append(
            f"{name:10s} {r.carbon_quantiles['p50']:>9.2f}% "
            f"{r.carbon_quantiles['p5']:>9.2f}% {r.carbon_cvar:>7.2f}% "
            f"{r.penalty_quantiles['p50']:>7.2f}% "
            f"{r.penalty_cvar:>8.2f}% {r.jain_quantiles['p50']:>9.3f} "
            f"{100 * r.slo_violation_prob:>8.0f}%")
    return rows


# ---------------------------------------------------------------------------
# Rolling-horizon ensemble: S streams, one batched controller
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class StreamingEnsembleReport:
    """S rolling-horizon runs, batched: per-scenario committed plans and
    carbon ledgers (the streaming analogue of `EnsembleResult`)."""

    labels: tuple[str, ...]
    committed: np.ndarray          # (S, W, n_ticks)
    realized_carbon: np.ndarray    # (S,) kg CO2 eliminated at actual MCI
    forecast_carbon: np.ndarray    # (S,) same hours at solve-time forecast
    realized_baseline: np.ndarray  # (S,) no-DR carbon of committed hours
    total_inner_steps: int         # engine iterations per scenario lane
    batched: bool

    @property
    def S(self) -> int:
        return int(self.realized_carbon.shape[0])

    @property
    def n_ticks(self) -> int:
        return int(self.committed.shape[-1])

    @property
    def realized_reduction_pct(self) -> np.ndarray:
        return 100.0 * self.realized_carbon / np.maximum(
            self.realized_baseline, 1e-12)

    def risk(self, *, cvar_alpha: float = 0.25,
             quantiles: Sequence[float] = (5, 25, 50, 75, 95),
             ) -> dict[str, float]:
        red = self.realized_reduction_pct
        out = _quantiles(red, quantiles)
        out["mean"] = float(red.mean())
        out[f"cvar{int(100 * cvar_alpha)}"] = _cvar(red, cvar_alpha, "low")
        return out


def run_streaming_ensemble(problem: FleetProblem, policy, streams, *,
                           n_ticks: int | None = None,
                           cold_steps: int = 600, warm_steps: int = 150,
                           use_kernel: bool | None = None,
                           ) -> StreamingEnsembleReport:
    """Drive S independent forecast streams through batched warm-started
    rolling-horizon ticks.

    `streams` is a sequence of `ForecastStream`s (every horizon must equal
    `problem.T`) or a `scenario.ForecastRegime` (its `streams()` factory
    is called with `n_ticks`). Multi-region problems take one stream
    *per region* per scenario — a sequence of R-tuples (exactly what
    `ForecastRegime.streams` yields for a multi-region base) — and the
    scenario axis batches whole (R, T) forecast stacks, so regional
    regimes like `RegionalDivergence` run through the one-dispatch
    batched lane. Per tick, the S revised forecasts stack into one
    scenario axis and the whole ensemble re-solves as one batched XLA
    call, each lane warm-started from its own previous `EngineState`
    (shift + mu reset inside the call) — the `RollingHorizonSolver`
    loop, vmapped over scenarios. Policies without a batched lane fall
    back to S sequential `RollingHorizonSolver` runs. As in
    `RollingHorizonSolver`, only hour 0 of each plan commits, so no
    migration post-stage applies to streaming ticks."""
    from repro.core.scenario import ForecastRegime
    from repro.core.streaming import RollingHorizonSolver
    policy = resolve_policy(policy)
    multi = problem.is_multiregion
    R = problem.R if multi else 1
    if isinstance(streams, ForecastRegime):
        streams = streams.streams(problem, n_ticks=n_ticks or 1)
    groups = []
    for item in streams:
        g = tuple(item) if isinstance(item, (tuple, list)) else (item,)
        if len(g) != R:
            raise ValueError(
                f"need {R} stream(s) per scenario (one per region), "
                f"got {len(g)}")
        groups.append(g)
    groups = tuple(groups)
    if not groups:
        raise ValueError("run_streaming_ensemble needs >= 1 stream")
    for g in groups:
        for st in g:
            if st.horizon != problem.T:
                raise ValueError(
                    f"stream horizon {st.horizon} != problem.T {problem.T}")
    max_ticks = min(st.n_ticks for g in groups for st in g)
    n = max_ticks if n_ticks is None else n_ticks
    if not 0 < n <= max_ticks:
        raise ValueError(f"n_ticks {n} outside (0, {max_ticks}]")
    S = len(groups)
    labels = tuple(
        f"stream[sigma={g[0].revision_sigma:.3f},seed={g[0].seed}]"
        for g in groups)
    base_usage = np.asarray(problem.usage, float)
    if multi:
        region = np.asarray(problem.region)
        onehot = np.zeros((problem.W, R))
        onehot[np.arange(problem.W), region] = 1.0
        usage_by_region = region_totals(region, base_usage, R)  # (R, T)

    if not _batched_capable(policy):
        reports = [RollingHorizonSolver(
            problem, g if multi else g[0], policy=policy,
            cold_steps=cold_steps, warm_steps=warm_steps,
            use_kernel=use_kernel).run(n)
            for g in groups]
        return StreamingEnsembleReport(
            labels=labels,
            committed=np.stack([r.committed for r in reports]),
            realized_carbon=np.asarray(
                [r.realized_carbon for r in reports]),
            forecast_carbon=np.asarray(
                [r.forecast_carbon for r in reports]),
            realized_baseline=np.asarray(
                [r.realized_baseline for r in reports]),
            total_inner_steps=reports[0].total_inner_steps,
            batched=False)

    use_kernel = resolve_use_kernel(use_kernel)
    committed = np.zeros((S, problem.W, n))
    realized = np.zeros(S)
    forecast = np.zeros(S)
    baseline = np.zeros(S)
    states: EngineState | None = None
    total_steps = 0
    for t in range(n):
        if multi:
            mcis = np.stack([[st.forecast(t) for st in g] for g in groups])
        else:
            mcis = np.stack([g[0].forecast(t) for g in groups])
        p_t = dataclasses.replace(
            problem, mci=np.asarray(problem.mci),
            usage=np.roll(problem.usage, -t, axis=1),
            jobs=np.roll(problem.jobs, -t, axis=1),
            upper=None if problem.upper is None
            else np.roll(problem.upper, -t, axis=1))
        steps = cold_steps if states is None else warm_steps
        D, _, states = _run_batched(
            policy, p_t, ScenarioStack(mci=mcis, labels=labels),
            steps=steps, use_kernel=use_kernel, init=states,
            shift=0 if t == 0 else 1, reset_mu=t > 0)
        committed[:, :, t] = D[:, :, 0]
        total_steps += steps * (policy.outer if type(policy) is CR2 else 1)
        if multi:
            real_t = np.asarray(
                [[st.realized(t) for st in g] for g in groups])  # (S, R)
            by_reg = committed[:, :, t] @ onehot                 # (S, R)
            realized += (by_reg * real_t).sum(axis=1)
            forecast += (by_reg * mcis[:, :, 0]).sum(axis=1)
            baseline += (usage_by_region[:, t % base_usage.shape[1]]
                         * real_t).sum(axis=1)
        else:
            real_t = np.asarray([g[0].realized(t) for g in groups])
            realized += committed[:, :, t].sum(axis=1) * real_t
            forecast += committed[:, :, t].sum(axis=1) * mcis[:, 0]
            baseline += real_t * base_usage[:, t % base_usage.shape[1]].sum()
    return StreamingEnsembleReport(
        labels=labels, committed=committed, realized_carbon=realized,
        forecast_carbon=forecast, realized_baseline=baseline,
        total_inner_steps=total_steps, batched=True)
