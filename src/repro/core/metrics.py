"""Fairness and efficiency metrics (paper §VI-E).

Shannon entropy over capacity-scaled shares: p_i ∝ C_i/E_i (performance) or
CF_i/E_i (carbon). Max entropy = log2(W) (= 2 for the paper's 4 workloads)
when losses are exactly proportional to capacity entitlements.
"""
from __future__ import annotations

import numpy as np


def capacity_scaled_entropy(values: np.ndarray, entitlements: np.ndarray,
                            ) -> float:
    """−Σ p log2 p with p_i ∝ max(values_i, 0)/E_i, normalized to sum 1.

    Returns max entropy when `values` is zero everywhere (no DR = trivially
    fair), matching the paper's convention that equal treatment is fair.
    """
    shares = np.maximum(np.asarray(values, float), 0.0) / np.asarray(
        entitlements, float)
    total = shares.sum()
    n = shares.shape[0]
    if total <= 1e-12:
        return float(np.log2(n))
    pnz = shares / total
    pnz = pnz[pnz > 1e-15]
    return float(-(pnz * np.log2(pnz)).sum())


def entropy_over_sweep(results, entitlements: np.ndarray,
                       ) -> dict[str, np.ndarray]:
    """Per-result entropies for a hyperparameter sweep (Fig. 10 box data)."""
    pen = np.asarray([capacity_scaled_entropy(r.per_penalty, entitlements)
                      for r in results])
    car = np.asarray([capacity_scaled_entropy(r.per_carbon, entitlements)
                      for r in results])
    return {"penalty_entropy": pen, "carbon_entropy": car}


def _poison_nonfinite(x: np.ndarray, axis: int,
                      out: np.ndarray) -> np.ndarray:
    """NaN-propagate a fairness reduction: any non-finite share along
    `axis` makes that slice's index NaN. Without this, a NaN share falls
    out of the `den > eps` comparison (NaN compares False) and the
    metric silently reads 1.0 — "perfectly fair" — for a corrupted
    plan."""
    bad = ~np.isfinite(x).all(axis=axis)
    return np.where(bad, np.nan, out)


def jain_index(values: np.ndarray, entitlements: np.ndarray,
               axis: int = -1) -> np.ndarray | float:
    """Jain fairness index (Σx)²/(n·Σx²) over capacity-scaled shares
    x_i = max(values_i, 0)/E_i, along `axis` (ensemble risk reports pass
    (S, W) stacks and get one index per scenario).

    1.0 = perfectly proportional losses; 1/n = one workload bears all.

    Degenerate inputs (reachable from `EnsembleReport` when a scenario
    curtails nothing): all-zero shares (no DR) are trivially fair ->
    1.0; an *empty* axis (zero workloads) likewise -> 1.0; non-finite
    shares (a diverged solve) propagate -> NaN, never a fair-looking
    1.0."""
    x = np.maximum(np.asarray(values, float), 0.0) \
        / np.asarray(entitlements, float)
    n = x.shape[axis]
    if n == 0:
        out = np.ones(np.sum(x, axis=axis).shape)
        return float(out) if np.ndim(out) == 0 else out
    num = x.sum(axis=axis) ** 2
    den = n * (x * x).sum(axis=axis)
    # errstate: non-finite shares make num/den garbage here; the poison
    # mask below overwrites those slots with NaN deliberately.
    with np.errstate(invalid="ignore", divide="ignore"):
        out = np.where(den > 1e-24, num / np.maximum(den, 1e-24), 1.0)
    out = _poison_nonfinite(x, axis, out)
    return float(out) if np.ndim(out) == 0 else out


def max_min_ratio(values: np.ndarray, entitlements: np.ndarray,
                  axis: int = -1) -> np.ndarray | float:
    """Max/min capacity-scaled share along `axis` — the worst-treated vs
    best-treated workload (1.0 = equal treatment; large = concentrated
    burden). Shares are floored at 1e-4 of the max share, capping the
    dispersion at 1e4: zero-loss workloads read as "≥10000x", not inf.

    Degenerate inputs match `jain_index`: all-zero shares -> 1.0, an
    empty axis -> 1.0 (instead of numpy's zero-size reduction
    ValueError), non-finite shares -> NaN."""
    x = np.maximum(np.asarray(values, float), 0.0) \
        / np.asarray(entitlements, float)
    if x.shape[axis] == 0:
        out = np.ones(np.sum(x, axis=axis).shape)
        return float(out) if np.ndim(out) == 0 else out
    top = x.max(axis=axis)
    bot = np.maximum(x.min(axis=axis), 1e-4 * np.maximum(top, 1e-30))
    with np.errstate(invalid="ignore", divide="ignore"):
        out = np.where(top > 1e-24, top / bot, 1.0)
    out = _poison_nonfinite(x, axis, out)
    return float(out) if np.ndim(out) == 0 else out


def box_stats(x: np.ndarray) -> dict[str, float]:
    """1st/2nd/3rd quartiles + min/max (Fig. 10 box-and-whisker)."""
    return {
        "min": float(np.min(x)), "q1": float(np.percentile(x, 25)),
        "median": float(np.median(x)), "q3": float(np.percentile(x, 75)),
        "max": float(np.max(x)),
    }


def pareto_frontier(carbon_pct: np.ndarray, penalty_pct: np.ndarray,
                    ) -> np.ndarray:
    """Indices of non-dominated (max carbon, min penalty) points, sorted by
    carbon reduction (Fig. 8 frontiers)."""
    order = np.argsort(carbon_pct)
    best = np.inf
    keep = []
    for i in order[::-1]:
        if penalty_pct[i] < best - 1e-12:
            keep.append(i)
            best = penalty_pct[i]
    return np.asarray(keep[::-1], dtype=int)
