"""Shared fleet-scale DR optimization engine.

Every gradient-based solver in this repo — CR1/CR2/CR3 at fleet scale
(`fleet_solver.py`) and the generic `PolicySpec` backend
(`solver.solve_adam`) — is the same algorithm: projected Adam on an
augmented Lagrangian. This module is the single implementation:

  * `al_minimize` — the pure, traceable core. Caller supplies
    (objective, projection, eq/ineq constraint residuals); the engine runs
    `outer_steps` rounds of multiplier updates around `inner_steps` of
    bias-corrected Adam, projecting after every step. Box bounds and batch
    day-preservation are handled by the caller's projection (both are cheap
    closed forms); equality residuals h(x)=0 and inequality residuals
    g(x)>=0 get classic AL multiplier + quadratic terms with a growing
    penalty weight mu.

  * `al_minimize_batched` — `vmap` over a stacked hyperparameter axis, so a
    whole Pareto sweep (Fig. 8's lambda or cap grid) compiles once and runs
    as one XLA call. Pass `return_aux=True` to also get the stacked aux
    (including the per-lane `EngineState`) and `init=` a stacked state to
    warm-start every lane of the next sweep.

  * `al_minimize_sharded` — `shard_map` the same loop over the leading
    workload axis of a device mesh, for fleets too large for one device.
    The primal `x`, per-workload multipliers, and the Adam moments all live
    sharded; each device runs the identical AL loop on its row block.

Sharding contract (`al_minimize_sharded`): the caller's problem must be
row-separable — objective a sum of per-row terms, every residual attached
to a row — which holds for CR1/CR3 exactly and CR2 after its global
normalizers are precomputed. Each device then differentiates its *local*
partial objective; because the gradient of a cross-device sum w.r.t. a
local row equals the local gradient, no collective appears in the hot
loop at all. The genuinely global reductions — objective normalizers,
shared step scales, CR3's Eq.-6 fiscal-clearing sums (taxes vs rebates) —
are computed once *outside* the sharded region (or on the gathered
solution) and enter as replicated scalars; for multi-region fleets the
per-region variants of those reductions (segment-summed norms, padding
fills, and the row-sharded specs that carry them into sharded bodies)
live in `repro.core.regional`. The one solve that steps outside this
contract is coupled cross-region migration
(`api.SolveContext(coupled_migration=True)`): its joint (D, y) objective
couples every region's rows through the interconnect flows, so it is
not row-separable and always runs unsharded. Do NOT `psum` inside the
differentiated objective: under `shard_map`, `jax.grad` of a psum'd
scalar multiplies cotangents by the device count (psum's transpose is a
psum), silently scaling every gradient by `n_devices`.

`al_minimize` is deliberately *not* jitted here: adapters wrap it in their
own `jax.jit` entry points (with policy knobs as traced `hyper` arguments),
so repeated solves of the same-shaped problem reuse one trace.

Warm starts (rolling-horizon streaming): `al_minimize` accepts an optional
`init: EngineState` — the `(x, lam_eq, lam_in, mu)` carry of a previous
solve — and always returns the final `EngineState` in `aux["state"]`.
`EngineState` is a registered pytree whose leaves are all arrays, so a
warm re-solve is *the same trace* as a cold solve: cold is just
`EngineState.cold(...)` (zeros) flowing through the identical jitted entry
point. A rolling-horizon controller shifts `state.x` along the time axis,
keeps the multipliers (they price per-workload constraints, not hours),
and re-solves with far fewer inner steps than a cold solve needs.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp

Array = jax.Array
# objective(x, hyper) -> scalar; residual(x, hyper) -> (n,) vector.
Objective = Callable[[Array, Any], Array]
Residual = Callable[[Array, Any], Array]


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    """Knobs for the projected-Adam / augmented-Lagrangian loop."""

    inner_steps: int = 400     # Adam steps per multiplier round
    outer_steps: int = 1       # multiplier rounds (1 = plain projected Adam)
    lr: float = 0.05           # step size, scaled by the caller's step_scale
    mu0: float = 10.0          # initial quadratic constraint weight
    mu_growth: float = 2.0     # mu multiplier per outer round
    mu_max: float = 1e6        # cap — keeps chained warm re-solves finite
    beta1: float = 0.9
    beta2: float = 0.999
    eps: float = 1e-8
    # Storage dtype for the Adam moments (m, v). "bfloat16" halves the
    # optimizer-state footprint; x stays a float32 master copy and every
    # arithmetic step runs in f32 (moments are up-cast on load, down-cast
    # on store). "float32" is bit-identical to the historical behaviour.
    moment_dtype: str = "float32"
    # Emit checkify non-finite guards on the gradient, iterate, and
    # multipliers (`repro.analysis.sanitize`). ONLY legal when the
    # jitted caller wraps the whole solve in `checkify.checkify` — the
    # `SolveContext(sanitize=True)` lanes in `core.api` own that
    # pairing. False compiles zero check code.
    sanitize: bool = False
    # Convergence telemetry: sample (objective, grad norm, constraint
    # violation, step size, mu) every `telemetry_every` inner steps and
    # return the fixed-size trace as `aux["telemetry"]` — captured as
    # stacked scan outputs inside the SAME dispatch (no host callbacks).
    # 0 (default) compiles zero telemetry code: the inner scan body is
    # the historical one, byte for byte. Incompatible with
    # `fused_inner` (the Pallas kernel's k-step loop is opaque).
    telemetry_every: int = 0


@dataclasses.dataclass(frozen=True)
class EngineState:
    """Reusable solver carry: primal point + AL multipliers + penalty weight.

    A pure-array pytree, so adapters jit over it directly and a warm
    re-solve shares the cold solve's trace. Obtain one from
    `aux["state"]` of a previous `al_minimize`, or build a cold start
    with `EngineState.cold`.
    """

    x: Array           # primal iterate (the previous solution)
    lam_eq: Array      # (n_eq,) equality multipliers
    lam_in: Array      # (n_in,) inequality multipliers (>= 0)
    mu: Array          # scalar quadratic penalty weight

    @classmethod
    def cold(cls, x0: Array, n_eq: int = 0, n_in: int = 0,
             mu0: float = EngineConfig.mu0) -> "EngineState":
        """Zero-multiplier start — the classic cold solve."""
        x0 = jnp.asarray(x0)
        return cls(x=x0, lam_eq=jnp.zeros((n_eq,), x0.dtype),
                   lam_in=jnp.zeros((n_in,), x0.dtype),
                   mu=jnp.asarray(mu0, x0.dtype))

    def shifted(self, hours: int = 1, fill: float = 0.0) -> "EngineState":
        """Roll the primal along its trailing (time) axis by `hours` —
        the rolling-horizon warm start. Vacated trailing hours get
        `fill`; multipliers and mu are carried unchanged (they attach to
        workloads/constraints, not to wall-clock hours)."""
        x = jnp.roll(self.x, -hours, axis=-1)
        if hours > 0:
            x = x.at[..., -hours:].set(fill)
        return dataclasses.replace(self, x=x)


jax.tree_util.register_dataclass(
    EngineState, data_fields=["x", "lam_eq", "lam_in", "mu"],
    meta_fields=[])


def _residual_dim(fn: Residual | None, x0: Array, hyper: Any) -> int:
    """Static length of a residual vector (abstract eval — no FLOPs)."""
    if fn is None:
        return 0
    out = jax.eval_shape(
        lambda x, h: jnp.atleast_1d(fn(x, h)).ravel(), x0, hyper)
    return int(out.shape[0])


def al_minimize(objective: Objective, project: Callable[[Array], Array],
                x0: Array, *, hyper: Any = None,
                eq_residual: Residual | None = None,
                ineq_residual: Residual | None = None,
                step_scale: Array | float = 1.0,
                grad_transform: Callable[[Array], Array] | None = None,
                cfg: EngineConfig = EngineConfig(),
                init: EngineState | None = None,
                fused_inner: Callable[[Array, Array, Array, Array], Array]
                | None = None,
                ) -> tuple[Array, dict[str, Array]]:
    """Minimize objective(x, hyper) s.t. eq(x)=0, ineq(x)>=0, x = project(x).

    Pure and traceable: safe to call under `jit`/`vmap`/`grad`-of-solution.
    `hyper` is an arbitrary pytree threaded to the callbacks (traced, so
    sweeping it does not retrace). Returns (x, aux) with the final
    multipliers in aux, plus `aux["state"]`: an `EngineState` to warm-start
    a subsequent solve of the same-shaped problem.

    `init` (optional) warm-starts the whole carry — primal iterate AND
    multipliers AND mu — from a previous solve's `aux["state"]`. When given,
    `x0` is ignored and `init.x` is projected instead; `init.lam_eq`/
    `init.lam_in` must have the residual dimensions of *this* problem.
    Because `EngineState` leaves are plain arrays, warm and cold solves
    share one trace under the caller's `jit`.

    `grad_transform` (optional) preconditions the raw gradient before the
    Adam update — e.g. projection onto the tangent space of an equality
    manifold the post-step projection enforces. Without it, Adam's
    per-coordinate sign normalization can emit near-uniform steps that the
    projection then annihilates (uniform push − day-mean ≈ 0), stalling
    progress along the manifold.

    `fused_inner` (optional) replaces the generic inner Adam scan with a
    caller-supplied fused implementation — e.g. the Pallas `al_step`
    kernel (`repro.kernels.al_step`) that keeps x and the Adam moments
    VMEM-resident. Signature: ``fused_inner(x, lam_eq, lam_in, mu) -> x``;
    it must run exactly `cfg.inner_steps` projected-Adam steps from fresh
    (zero) moments. The multiplier updates between rounds stay generic.

    Telemetry (`cfg.telemetry_every = k > 0`): the inner scan emits per-
    step scalars (AL objective, squared gradient norm, max constraint
    violation at the post-step iterate, mean |Δx|) as stacked scan
    outputs; after the outer scan they are downsampled to every k-th
    step and returned as `aux["telemetry"]` — a dict of `(n_samples,)`
    arrays (`step`, `objective`, `grad_sq`, `violation`, `dx`, `mu`)
    plus the scalar `step_scale` mean. Everything stays inside the one
    jitted dispatch; the gradient comes from `jax.value_and_grad` of the
    same Lagrangian, so the iterate trajectory is bitwise the
    telemetry-off one. `grad_sq` (not the norm) is emitted so the
    sharded lane can `psum` partial sums before the host takes the
    square root.
    """
    n_eq = _residual_dim(eq_residual, x0, hyper)
    n_in = _residual_dim(ineq_residual, x0, hyper)
    tel_every = cfg.telemetry_every
    if tel_every and fused_inner is not None:
        raise ValueError(
            "EngineConfig.telemetry_every is incompatible with "
            "fused_inner: the fused Pallas kernel runs all inner steps "
            "in one opaque call, so per-step telemetry cannot be "
            "captured — drop the kernel or the telemetry for this solve")

    def eq_vec(x: Array) -> Array:
        return jnp.atleast_1d(eq_residual(x, hyper)).ravel()

    def ineq_vec(x: Array) -> Array:
        return jnp.atleast_1d(ineq_residual(x, hyper)).ravel()

    def lagrangian(x: Array, lam_eq: Array, lam_in: Array, mu: Array) -> Array:
        val = objective(x, hyper)
        if n_eq:
            h = eq_vec(x)
            val = val + lam_eq @ h + 0.5 * mu * (h @ h)
        if n_in:
            # AL for g(x) >= 0:  (mu/2)·[max(0, lam/mu − g)² − (lam/mu)²]
            g = ineq_vec(x)
            s = jnp.maximum(lam_in / mu - g, 0.0)
            val = val + 0.5 * mu * (s @ s - (lam_in / mu) @ (lam_in / mu))
        return val

    grad_fn = jax.grad(lagrangian)
    value_and_grad_fn = jax.value_and_grad(lagrangian)

    def max_violation(x: Array) -> Array:
        """Worst constraint residual: max(|h|, relu(−g)); 0 when none."""
        v = jnp.asarray(0.0, x.dtype)
        if n_eq:
            v = jnp.maximum(v, jnp.max(jnp.abs(eq_vec(x))))
        if n_in:
            v = jnp.maximum(v, jnp.max(jnp.maximum(-ineq_vec(x), 0.0)))
        return v

    mdt = jnp.dtype(cfg.moment_dtype)

    def outer_body(carry, _):
        x, lam_eq, lam_in, mu = carry

        def inner(c, _):
            x, m, v, t = c
            g = grad_fn(x, lam_eq, lam_in, mu)
            if grad_transform is not None:
                g = grad_transform(g)
            t = t + 1
            m = cfg.beta1 * m.astype(x.dtype) + (1.0 - cfg.beta1) * g
            v = cfg.beta2 * v.astype(x.dtype) + (1.0 - cfg.beta2) * g * g
            mhat = m / (1.0 - cfg.beta1 ** t)
            vhat = v / (1.0 - cfg.beta2 ** t)
            x = project(x - cfg.lr * step_scale * mhat
                        / (jnp.sqrt(vhat) + cfg.eps))
            if cfg.sanitize:
                from repro.analysis.sanitize import check_all_finite
                check_all_finite("al-inner", grad=g, x=x)
            return (x, m.astype(mdt), v.astype(mdt), t), None

        def inner_tel(c, _):
            # Telemetry twin of `inner`: identical update (the gradient
            # is value_and_grad's grad output — jax.grad IS that grad,
            # so the iterate trajectory is bitwise unchanged) plus
            # stacked per-step scalars as scan ys.
            x0_, m, v, t = c
            L, g = value_and_grad_fn(x0_, lam_eq, lam_in, mu)
            if grad_transform is not None:
                g = grad_transform(g)
            t = t + 1
            m = cfg.beta1 * m.astype(x0_.dtype) + (1.0 - cfg.beta1) * g
            v = cfg.beta2 * v.astype(x0_.dtype) + (1.0 - cfg.beta2) * g * g
            mhat = m / (1.0 - cfg.beta1 ** t)
            vhat = v / (1.0 - cfg.beta2 ** t)
            x = project(x0_ - cfg.lr * step_scale * mhat
                        / (jnp.sqrt(vhat) + cfg.eps))
            if cfg.sanitize:
                from repro.analysis.sanitize import check_all_finite
                check_all_finite("al-inner", grad=g, x=x)
            tel = (L, jnp.sum(g * g), max_violation(x),
                   jnp.mean(jnp.abs(x - x0_)))
            return (x, m.astype(mdt), v.astype(mdt), t), tel

        tel = None
        if fused_inner is not None:
            x = fused_inner(x, lam_eq, lam_in, mu)
            if cfg.sanitize:
                from repro.analysis.sanitize import check_all_finite
                check_all_finite("al-fused-inner", x=x)
        else:
            (x, _, _, _), tel = jax.lax.scan(
                inner_tel if tel_every else inner,
                (x, jnp.zeros(x.shape, mdt), jnp.zeros(x.shape, mdt),
                 0), None, length=cfg.inner_steps)
        if tel_every:
            tel = (*tel, jnp.broadcast_to(mu, (cfg.inner_steps,)))
        if n_eq:
            lam_eq = lam_eq + mu * eq_vec(x)
        if n_in:
            lam_in = jnp.maximum(lam_in - mu * ineq_vec(x), 0.0)
        if cfg.sanitize and (n_eq or n_in):
            from repro.analysis.sanitize import check_all_finite
            check_all_finite("al-multipliers", lam_eq=lam_eq, lam_in=lam_in)
        return (x, lam_eq, lam_in,
                jnp.minimum(mu * cfg.mu_growth, cfg.mu_max)), \
            (tel if tel_every else None)

    if init is None:
        init = EngineState.cold(x0, n_eq, n_in, cfg.mu0)
    carry0 = (project(init.x), init.lam_eq.astype(init.x.dtype),
              init.lam_in.astype(init.x.dtype),
              jnp.asarray(init.mu, init.x.dtype))
    if cfg.sanitize:
        from repro.analysis.sanitize import check_all_finite
        check_all_finite("al-init", x0=carry0[0], lam_eq=carry0[1],
                         lam_in=carry0[2], mu=carry0[3])
    (x, lam_eq, lam_in, mu), tel_ys = jax.lax.scan(
        outer_body, carry0, None, length=cfg.outer_steps)
    aux = {"lam_eq": lam_eq, "lam_in": lam_in, "mu": mu,
           "state": EngineState(x=x, lam_eq=lam_eq, lam_in=lam_in, mu=mu)}
    if tel_every:
        # Flatten (outer, inner) → (outer*inner,) then keep every
        # tel_every-th sample — a fixed-size trace decided at trace time.
        L, g2, viol, dx, mus = (y.reshape(-1) for y in tel_ys)
        sl = slice(tel_every - 1, None, tel_every)
        total = cfg.outer_steps * cfg.inner_steps
        aux["telemetry"] = {
            "step": jnp.arange(1, total + 1, dtype=jnp.int32)[sl],
            "objective": L[sl], "grad_sq": g2[sl],
            "violation": viol[sl], "dx": dx[sl], "mu": mus[sl],
            "step_scale": jnp.asarray(step_scale, x.dtype).mean(),
        }
    return x, aux


def al_minimize_batched(objective: Objective,
                        project: Callable[[Array], Array], x0: Array,
                        hypers: Any, *, init: EngineState | None = None,
                        return_aux: bool = False, **kwargs):
    """vmap `al_minimize` over a stacked hyperparameter axis.

    `hypers` is a pytree whose leaves carry a leading sweep axis; the whole
    sweep shares one trace/compile (the Fig.-8 Pareto pattern). Returns the
    stacked solutions (n_sweep, *x0.shape); with `return_aux=True`, returns
    `(solutions, aux)` where every `aux` leaf — multipliers, mu, and
    `aux["state"]` (an `EngineState` pytree) — carries the same leading
    sweep axis, so a sweep can warm-start the next tick's sweep lane-by-lane
    by passing that stacked state back as `init`.

    `init` (optional) is a stacked `EngineState` (leading sweep axis on
    every leaf, including `mu`), e.g. `aux["state"]` from a previous
    batched solve.
    """
    if init is None:
        def one(h):
            return al_minimize(objective, project, x0, hyper=h, **kwargs)
        xs, aux = jax.vmap(one)(hypers)
    else:
        def one_warm(h, st):
            return al_minimize(objective, project, x0, hyper=h, init=st,
                               **kwargs)
        xs, aux = jax.vmap(one_warm)(hypers, init)
    return (xs, aux) if return_aux else xs


# How each telemetry leaf combines across shards of the workload axis.
# Objective and squared grad norm are partial sums (row-separable
# problems), worst violation is a max, mean |Δx| and step_scale average
# (exact for equal block sizes — pad_fleet guarantees them). `step` and
# `mu` are device-identical and pass through.
_TEL_REDUCE = {"objective": jax.lax.psum, "grad_sq": jax.lax.psum,
               "violation": jax.lax.pmax, "dx": jax.lax.pmean,
               "step_scale": jax.lax.pmean}


def _telemetry_allreduce(tel: dict, axis_name) -> dict:
    """Merge per-shard telemetry into global traces (inside shard_map)."""
    return {k: (_TEL_REDUCE[k](v, axis_name) if k in _TEL_REDUCE else v)
            for k, v in tel.items()}


def al_minimize_sharded(build_pieces: Callable[[Any], dict], data: Any, *,
                        mesh, data_specs: Any, init: EngineState,
                        cfg: EngineConfig = EngineConfig(),
                        axis_name: str | tuple[str, ...] | None = None,
                        ) -> tuple[Array, dict[str, Array]]:
    """Device-parallel `al_minimize`: shard the leading workload axis.

    Runs the identical AL loop on every device's row block of a fleet-scale
    problem, with `x`, per-workload multipliers, and Adam moments all
    sharded over `axis_name` (default: the mesh's only axis). A *tuple*
    of axis names shards the leading axis over several mesh axes at once
    — the 2-D (REGION_AXIS, FLEET_AXIS) fleet mesh from
    `launch.mesh.make_fleet_mesh(regions=...)`, where a region-sorted W
    axis folds over both. The row-separability contract is unchanged:
    nothing here psums, so the device grid's shape is irrelevant to the
    math.

    Args:
      build_pieces: called *inside* `shard_map` with the per-device block of
        `data`; returns a dict of `al_minimize` keyword pieces —
        ``{"objective", "project"}`` required, plus any of ``{"hyper",
        "eq_residual", "ineq_residual", "step_scale", "grad_transform"}``.
        The pieces see only local rows, so the objective each device
        differentiates is its partial sum — exactly the global gradient for
        row-separable problems (see the module docstring for why a psum
        here would be wrong). Global scalars (normalizers, shared step
        scales) must be precomputed by the caller and ride through `data`
        as replicated leaves.
      data: pytree of problem arrays — per-workload leaves lead with W
        (divisible by the axis size; see `fleet_solver.pad_fleet`),
        shared signals (MCI trace, scalars) replicated.
      data_specs: pytree of `PartitionSpec`s matching `data` —
        `P(axis_name)` for per-workload leaves, `P()` for replicated ones.
      init: `EngineState` with global (full-W) arrays; `x`/`lam_eq`/`lam_in`
        are sharded on their leading axis, `mu` replicated.

    Returns (x, aux) exactly like `al_minimize`, with global arrays
    (sharded jax.Arrays over `mesh`).
    """
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    if axis_name is None:
        if len(mesh.axis_names) != 1:
            raise ValueError(
                f"axis_name required for multi-axis mesh {mesh.axis_names}")
        axis_name = mesh.axis_names[0]
    state_specs = EngineState(x=P(axis_name), lam_eq=P(axis_name),
                              lam_in=P(axis_name), mu=P())
    aux_specs = {"lam_eq": P(axis_name), "lam_in": P(axis_name), "mu": P(),
                 "state": state_specs}
    if cfg.telemetry_every:
        # All-reduced inside `body` to device-identical traces → P().
        aux_specs["telemetry"] = {
            k: P() for k in ("step", "objective", "grad_sq", "violation",
                             "dx", "mu", "step_scale")}

    def body(data_blk, state_blk):
        pieces = dict(build_pieces(data_blk))
        objective = pieces.pop("objective")
        project = pieces.pop("project")
        x, aux = al_minimize(objective, project, state_blk.x,
                             init=state_blk, cfg=cfg, **pieces)
        if cfg.telemetry_every:
            # Post-hoc collectives on aux outputs only — never inside the
            # differentiated objective (see module docstring): each
            # device's trace reflects its partial Lagrangian, so sum /
            # max / mean them into the global curves here.
            aux["telemetry"] = _telemetry_allreduce(aux["telemetry"],
                                                    axis_name)
        return x, aux

    # check_rep=False: the body may invoke a pallas_call (the fused
    # al_step kernel), which has no shard_map replication rule; all
    # outputs here are explicitly spec'd, so the check adds nothing.
    return shard_map(body, mesh=mesh, in_specs=(data_specs, state_specs),
                     out_specs=(P(axis_name), aux_specs),
                     check_rep=False)(data, init)
