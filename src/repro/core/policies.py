"""Datacenter DR policies (paper §V): CR1/CR2/CR3 + shared constraints.

A policy takes a `DRProblem` (workload penalty models + carbon signal +
datacenter constraints) and produces an hourly adjustment matrix
D = [d_1 … d_W] (W, T), positive = curtail. Policies differ in objective and
fairness treatment; all share (§V-C):

  * total capacity:  max_t Σ_i (U_it − d_it) ≤ buffer · Σ_i E_i   (Eq. 10)
  * batch preservation: Σ_{t∈day} d_it = 0 for batch workloads — deferred
    work completes within the day (§III-B; Eq. 11 prints the inequality,
    but §VI-C's analysis of B1 — "B1 would have terminated at the yellow
    star, indicating its inability to adjust power under the constraint" —
    is only consistent with the equality form for capping-only policies,
    so the equality is the default and the inequality is an option).
  * curtailment ≤ half the entitlement (§VI-A, idle-power floor), and
    boosts bounded by the entitlement: U−d ≤ E.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Callable, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.penalty import PenaltyModel

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class DRProblem:
    """A demand-response instance over W workloads × T hours."""

    models: tuple[PenaltyModel, ...]
    mci: np.ndarray                    # (T,) marginal carbon intensity
    capacity_buffer: float = 1.2       # Eq. 10
    max_curtail_frac: float = 0.5      # of entitlement (§VI-A)
    day_hours: int = 24
    preservation: str = "equality"     # "equality" | "inequality" | "none"
    smooth: float = 0.25               # softplus temperature for solvers
    rts_boost: bool = False            # allow d<0 for real-time workloads?

    # ---- cached views ------------------------------------------------------
    @functools.cached_property
    def W(self) -> int:
        return len(self.models)

    @functools.cached_property
    def T(self) -> int:
        return int(self.mci.shape[0])

    @functools.cached_property
    def usage(self) -> np.ndarray:      # (W, T)
        return np.stack([m.usage for m in self.models])

    @functools.cached_property
    def entitlements(self) -> np.ndarray:  # (W,)
        return np.asarray([m.entitlement for m in self.models])

    @functools.cached_property
    def batch_mask(self) -> np.ndarray:    # (W,) True where batch
        return np.asarray([m.kind != "realtime" for m in self.models])

    @functools.cached_property
    def names(self) -> tuple[str, ...]:
        return tuple(m.name for m in self.models)

    @property
    def num_days(self) -> int:
        return max(1, self.T // self.day_hours)

    # ---- objective terms ---------------------------------------------------
    def penalties(self, D: Array, smooth: float | None = None) -> Array:
        """(W,) calibrated per-workload penalties C_i(d_i)."""
        s = self.smooth if smooth is None else smooth
        return jnp.stack([m.penalty(D[i], smooth=s)
                          for i, m in enumerate(self.models)])

    def total_penalty(self, D: Array, smooth: float | None = None) -> Array:
        return self.penalties(D, smooth).sum()

    def carbon_reduction_per_workload(self, D: Array) -> Array:
        """(W,) ⟨mci, d_i⟩ — kg CO2 eliminated per workload."""
        return D @ jnp.asarray(self.mci)

    def carbon_reduction(self, D: Array) -> Array:
        return self.carbon_reduction_per_workload(D).sum()

    def peak(self, D: Array) -> Array:
        """Post-DR datacenter peak power max_t Σ_i (U − d)."""
        return (jnp.asarray(self.usage) - D).sum(axis=0).max()

    def soft_peak(self, D: Array, tau: float = 0.05) -> Array:
        """Smooth max for gradient-based solvers."""
        tot = (jnp.asarray(self.usage) - D).sum(axis=0)
        scale = tau * float(self.usage.sum(axis=0).max())
        return scale * jax.nn.logsumexp(tot / scale)

    @property
    def capacity_limit(self) -> float:
        return float(self.capacity_buffer * self.entitlements.sum())

    @property
    def total_carbon_baseline(self) -> float:
        """Operational carbon without DR (normalization for reporting)."""
        return float((self.usage.sum(axis=0) * self.mci).sum())

    # ---- constraint machinery ---------------------------------------------
    def bounds(self) -> tuple[np.ndarray, np.ndarray]:
        """(lower, upper) box bounds for D, shape (W, T).

        Curtailment is capped at half the entitlement (§VI-A) and at usage;
        boosts go up to the entitlement (U−d ≤ E). Real-time workloads are
        curtail-only by default: their latency model rewards extra power
        linearly, which would let an optimizer buy unbounded 'negative
        penalty' — and the paper's own CR1 trace (Fig. 7) shows RTS services
        only ever shedding load (deferred batch absorbs the rebound).
        """
        U, E = self.usage, self.entitlements[:, None]
        upper = np.minimum(self.max_curtail_frac * E, U)
        lower = -(E - U)          # boost until usage hits entitlement
        if not self.rts_boost:
            lower = np.where(self.batch_mask[:, None], lower, 0.0)
        return lower, upper

    def day_sums(self, D: Array) -> Array:
        """(W, n_days) per-day adjustment sums (preservation residuals)."""
        n = self.num_days
        Dd = D[:, : n * self.day_hours].reshape(self.W, n, self.day_hours)
        return Dd.sum(axis=-1)

    def preservation_residual(self, D: Array) -> Array:
        """(n_batch * n_days,) equality residuals (zero when preserved)."""
        sums = self.day_sums(D)
        idx = np.nonzero(self.batch_mask)[0]
        return sums[idx].reshape(-1)

    def project_preservation(self, D: Array) -> Array:
        """Exact projection of batch rows onto Σ_{t∈day} d = 0."""
        n = self.num_days
        Dday = D[:, : n * self.day_hours].reshape(self.W, n, self.day_hours)
        mean = Dday.mean(axis=-1, keepdims=True)
        mask = jnp.asarray(self.batch_mask)[:, None, None]
        Dday = jnp.where(mask, Dday - mean, Dday)
        return jnp.concatenate(
            [Dday.reshape(self.W, n * self.day_hours),
             D[:, n * self.day_hours:]], axis=1)


@dataclasses.dataclass(frozen=True)
class PolicySpec:
    """Solver-agnostic optimization spec produced by each policy.

    objective(D) is minimized subject to:
      eq(D) == 0 for each eq constraint, ineq(D) >= 0 for each,
      lower <= D <= upper elementwise, D[~free] == 0,
      plus the problem's preservation constraint (unless disabled).
    """

    name: str
    problem: DRProblem
    objective: Callable[[Array], Array]
    eq_constraints: tuple[Callable[[Array], Array], ...] = ()
    ineq_constraints: tuple[Callable[[Array], Array], ...] = ()
    free: np.ndarray | None = None      # (W,) bool; None = all free
    lower: np.ndarray | None = None     # override problem bounds
    upper: np.ndarray | None = None
    use_preservation: bool = True


def _capacity_ineq(p: DRProblem) -> Callable[[Array], Array]:
    def g(D: Array) -> Array:
        return jnp.asarray(p.capacity_limit) - p.soft_peak(D)
    return g


# ---------------------------------------------------------------------------
# CR1 — Efficient DR (Eq. 3): min λ C(D) + CF(D); CF change = −carbon_red.
#
# Both terms are normalized (penalty by total entitlement, carbon by the
# no-DR baseline footprint) so λ is unit-free: it trades "% capacity-
# equivalent performance loss" against "% operational carbon". The paper
# reports outcomes in exactly these percentages (§VI-A), and only a
# normalized objective makes its λ = 6.9 a moderate operating point.
# ---------------------------------------------------------------------------
def cr1_spec(p: DRProblem, lam: float) -> PolicySpec:
    pen_norm = 100.0 / float(p.entitlements.sum())
    car_norm = 100.0 / p.total_carbon_baseline

    def obj(D: Array) -> Array:
        return (lam * pen_norm * p.total_penalty(D)
                - car_norm * p.carbon_reduction(D))
    return PolicySpec(name=f"CR1(λ={lam:g})", problem=p, objective=obj,
                      ineq_constraints=(_capacity_ineq(p),))


# ---------------------------------------------------------------------------
# CR2 — Fair & Centralized (Eq. 4): min CF s.t. C_i(d_i) = C_i(cap%).
# ---------------------------------------------------------------------------
def cr2_reference_losses(p: DRProblem, cap_frac: float,
                         upper: np.ndarray | None = None) -> np.ndarray:
    """C_i under a hypothetical equal power cap at cap_frac·E (the fairness
    reference — CR2 'does not actually cap power'). `upper` (optional,
    (W, T)) clips the reference curtailments to a tightened box so the
    equality targets stay attainable under the same bounds the solver
    gets."""
    refs = []
    for i, m in enumerate(p.models):
        d_cap = m.cap_curtailment(cap_frac)
        if upper is not None:
            d_cap = np.minimum(d_cap, upper[i])
        refs.append(float(m.penalty(jnp.asarray(d_cap), smooth=0.0)))
    return np.asarray(refs)


def cr2_spec(p: DRProblem, cap_frac: float,
             upper: np.ndarray | None = None) -> PolicySpec:
    refs = cr2_reference_losses(p, cap_frac, upper)
    scale = float(np.maximum(refs, 1e-3).mean())
    car_norm = 100.0 / p.total_carbon_baseline

    def obj(D: Array) -> Array:
        return -car_norm * p.carbon_reduction(D)

    def eq(D: Array) -> Array:
        return (p.penalties(D) - jnp.asarray(refs)) / scale

    return PolicySpec(name=f"CR2(cap={cap_frac:g})", problem=p, objective=obj,
                      eq_constraints=(eq,),
                      ineq_constraints=(_capacity_ineq(p),), upper=upper)


# ---------------------------------------------------------------------------
# CR3 — Fair & Decentralized (Eqs. 5–8): taxes and rebates.
# ---------------------------------------------------------------------------
def cr3_workload_spec(p: DRProblem, i: int, rho: float,
                      tax_frac: float = 0.2) -> PolicySpec:
    """Workload i's selfish problem: min C_i(d_i) s.t.
    max_t(U_i − d_i) ≤ E_i − T_i + P_i(d_i),  P_i = ρ·⟨mci, d_i⟩,
    T_i = tax_frac·E_i (Eq. 8). Box/preservation as usual."""
    m = p.models[i]
    E = m.entitlement
    T_i = tax_frac * E
    mci = jnp.asarray(p.mci)
    usage = jnp.asarray(m.usage)

    def obj(D: Array) -> Array:
        return p.penalties(D)[i]

    def ineq(D: Array) -> Array:
        d = D[i]
        rebate = rho * (d @ mci)
        # Smooth max over hours for solver friendliness.
        tau = 0.02 * E
        peak_i = tau * jax.nn.logsumexp((usage - d) / tau)
        return (E - T_i + rebate) - peak_i

    free = np.zeros(p.W, dtype=bool)
    free[i] = True
    return PolicySpec(name=f"CR3[w{i}](ρ={rho:g})", problem=p, objective=obj,
                      ineq_constraints=(ineq,), free=free)


def cr3_fiscal_balance(p: DRProblem, D: np.ndarray, rho: float,
                       tax_frac: float = 0.2) -> tuple[float, float]:
    """(Σ P_i, Σ T_i) — Eq. 6 requires ΣP ≤ ΣT."""
    rebates = rho * (np.asarray(D) @ p.mci)
    taxes = tax_frac * p.entitlements
    return float(rebates.sum()), float(taxes.sum())
