"""Cross-region migration of deferrable load (spatial demand response).

The multi-region engine prices each region's curtailment on its own MCI
trace; this module adds the *spatial* lever on top: move deferrable
(batch) load that was curtailed in a dirty-grid region and run it in a
cleaner region the same hour, subject to the `RegionTopology` migration
network (per-link bandwidth caps, per-unit migration toll, per-region
power ceilings).

Runs as a host-side post-stage on gathered region aggregates — NOT
inside the sharded hot loop. The per-workload solve is row-separable
over W (the sharding contract in `core/engine.py` forbids psums inside
the differentiated objective), so the coupled cross-region terms
operate on (R, T) reductions of the committed plan instead: `movable`
(curtailed batch load per region-hour), `headroom` (region ceiling
minus post-DR draw). With R in the tens and T in the hundreds that is
a tiny problem — the same augmented-Lagrangian engine solves it in one
unsharded call, followed by a deterministic feasibility repair so the
reported plan satisfies every cap exactly (the AL solution is only
eps-feasible).

`api.solve`/`sweep` apply this automatically whenever the problem has a
topology with any positive bandwidth; the carbon saved (net of the
migration toll) is credited into `carbon_reduction_pct` and the full
`MigrationPlan` rides `result.extras["migration"]`. With bandwidth 0
the plan is identically zero and the multi-region solve decomposes
into independent per-region solves (regression-tested).

`SolveContext(coupled_migration=True)` instead refines curtailment and
interconnect flows *jointly* inside the AL solve (`api._coupled_migrate`);
this module then serves as the validation reference and supplies the
exact-feasibility `_repair` pass and the `region_aggregates`/
`positive_links` reductions both stages share.
"""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp
import numpy as np

from repro.core.engine import EngineConfig, al_minimize
from repro.core.regional import region_totals

__all__ = ["MigrationPlan", "fleet_migration", "plan_migration",
           "positive_links", "region_aggregates"]


@dataclasses.dataclass(frozen=True)
class MigrationPlan:
    """Feasible cross-region migration schedule and its carbon accounting.

    `y[r, s, t]` is deferrable load (NP) moved from region r to region s
    in hour t. `carbon_saved` is the gross MCI differential captured,
    `migration_cost` the toll paid (both kgCO2-equivalent); the net
    credit is `net_saved`.
    """
    y: np.ndarray              # (R, R, T) feasible migration flows
    carbon_saved: float        # sum y * (mci_from - mci_to)
    migration_cost: float      # sum y * cost[from, to]
    moved_total: float         # sum y

    @property
    def net_saved(self) -> float:
        return self.carbon_saved - self.migration_cost

    def by_region(self) -> np.ndarray:
        """(R,) net outflow per region (moved out minus moved in)."""
        return self.y.sum(axis=(1, 2)) - self.y.sum(axis=(0, 2))


def _zero_plan(R: int, T: int) -> MigrationPlan:
    return MigrationPlan(y=np.zeros((R, R, T)), carbon_saved=0.0,
                         migration_cost=0.0, moved_total=0.0)


def _repair(y: np.ndarray, margin: np.ndarray, cap: np.ndarray,
            movable: np.ndarray, headroom: np.ndarray) -> np.ndarray:
    """Deterministic projection of an eps-feasible AL iterate onto the
    exact constraint set. Order matters: dropping unprofitable links and
    clipping to caps can only shrink flows, outflow scaling preserves
    link caps, and inflow scaling (again only shrinking) preserves both
    — so the output satisfies every constraint simultaneously."""
    y = np.where(margin > 0.0, np.clip(y, 0.0, cap), 0.0)
    out = y.sum(axis=1)                                   # (R, T)
    with np.errstate(divide="ignore", invalid="ignore"):
        f = np.where(out > movable, movable / np.maximum(out, 1e-300), 1.0)
    y = y * np.minimum(f, 1.0)[:, None, :]
    inn = y.sum(axis=0)                                   # (R, T)
    with np.errstate(divide="ignore", invalid="ignore"):
        g = np.where(inn > headroom,
                     np.maximum(headroom, 0.0) / np.maximum(inn, 1e-300),
                     1.0)
    return y * np.minimum(g, 1.0)[None, :, :]


def plan_migration(mci: np.ndarray, movable: np.ndarray,
                   headroom: np.ndarray, topology,
                   *, inner_steps: int = 250,
                   outer_steps: int = 4) -> MigrationPlan:
    """Solve the (R, R, T) migration transport problem.

    maximize   sum_{r,s,t} y[r,s,t] * (mci[r,t] - mci[s,t] - cost[r,s])
    subject to 0 <= y[r,s,t] <= bandwidth[r,s]          (link caps)
               sum_s y[r,s,t] <= movable[r,t]           (supply)
               sum_r y[r,s,t] <= headroom[s,t]          (absorption)

    via the shared AL + projected-Adam engine (box caps in the
    projection, supply/absorption as inequality residuals), then a
    deterministic repair pass for exact feasibility. Zero-bandwidth or
    nowhere-profitable topologies short-circuit to the zero plan.
    """
    mci = np.asarray(mci, float)
    R, T = mci.shape
    cost = np.asarray(topology.cost, float)
    bw = np.asarray(topology.bandwidth, float).copy()
    np.fill_diagonal(bw, 0.0)
    movable = np.maximum(np.asarray(movable, float), 0.0)
    headroom = np.asarray(headroom, float)

    margin = mci[:, None, :] - mci[None, :, :] - cost[:, :, None]  # (R,R,T)
    cap = np.broadcast_to(bw[:, :, None], (R, R, T))
    profitable = (margin > 0.0) & (cap > 0.0)
    if not profitable.any() or movable.max() <= 0.0:
        return _zero_plan(R, T)

    # Uncapped regions absorb at most everything movable that hour.
    total_movable = movable.sum(axis=0)                   # (T,)
    head_eff = np.where(np.isfinite(headroom),
                        np.maximum(headroom, 0.0),
                        total_movable[None, :] * np.ones((R, 1)))

    scale = float(max(movable.max(), 1.0))
    mscale = float(max(np.abs(margin[profitable]).max(), 1e-6))
    margin_j = jnp.asarray(margin / mscale)
    cap_j = jnp.asarray(np.where(np.isfinite(cap), cap, scale))
    movable_j = jnp.asarray(movable)
    head_j = jnp.asarray(head_eff)

    def objective(y, _):
        return -(y * margin_j).sum()

    def project(y):
        return jnp.clip(y, 0.0, cap_j)

    def ineq(y, _):
        supply = (movable_j - y.sum(axis=1)) / scale
        absorb = (head_j - y.sum(axis=0)) / scale
        return jnp.concatenate([supply.ravel(), absorb.ravel()])

    cfg = EngineConfig(inner_steps=inner_steps, outer_steps=outer_steps,
                       lr=0.05, mu0=10.0, mu_growth=3.0)
    y0 = jnp.zeros((R, R, T))
    y, _ = al_minimize(objective, project, y0, ineq_residual=ineq,
                       step_scale=0.1 * scale, cfg=cfg)
    y = _repair(np.asarray(y, float), margin, cap, movable, head_eff)

    grad = mci[:, None, :] - mci[None, :, :]
    return MigrationPlan(
        y=y, carbon_saved=float((y * grad).sum()),
        migration_cost=float((y * cost[:, :, None]).sum()),
        moved_total=float(y.sum()))


def region_aggregates(p, D: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
    """(movable, headroom) region aggregates of a committed plan `D`,
    both (R, T): `movable[r, t]` is the curtailed *batch* load available
    to move (deferrable by construction — RTS loss models are
    latency-coupled and stay put), `headroom[r, t]` the region ceiling
    minus the fleet's post-DR draw (+inf when the topology carries no
    ceiling). The one reduction both migration stages — the host-side
    post-stage and the coupled in-loop refine's repair — price flows
    against."""
    region = np.asarray(p.region)
    R, T = p.R, p.T
    residual = np.asarray(p.usage, float) - np.asarray(D, float)  # (W, T)
    is_batch = np.asarray(p.is_batch, bool)
    movable = region_totals(region[is_batch],
                            np.maximum(residual[is_batch], 0.0), R)
    ceiling = None if p.topology is None else p.topology.ceiling
    if ceiling is None:
        headroom = np.full((R, T), np.inf)
    else:
        load = region_totals(region, residual, R)
        ceil = np.asarray(ceiling, float)
        if ceil.ndim == 1:
            ceil = np.broadcast_to(ceil[:, None], (R, T))
        headroom = ceil - load
    return movable, headroom


def positive_links(topology) -> tuple[np.ndarray, np.ndarray, np.ndarray,
                                      np.ndarray]:
    """Flatten a topology's usable directed links into `(fr, to, bw,
    cost)` vectors over the off-diagonal entries with positive bandwidth
    — the decision variables of the coupled in-loop migration solve
    (zero-bandwidth links can never carry flow, so they are dropped
    before the solve rather than constrained inside it)."""
    bw = np.asarray(topology.bandwidth, float).copy()
    np.fill_diagonal(bw, 0.0)
    fr, to = np.nonzero(bw > 0.0)
    cost = np.asarray(topology.cost, float)[fr, to]
    return fr, to, bw[fr, to], cost


def fleet_migration(p, D: np.ndarray, **plan_kwargs) -> MigrationPlan:
    """Migration post-stage for a solved multi-region `FleetProblem`.

    Region aggregates from the committed plan `D` via
    `region_aggregates`. The plan moves load without changing any
    workload's curtailment D, so total curtailment — and every penalty —
    is untouched; only where the load burns carbon changes.
    """
    if not p.is_multiregion or p.topology is None:
        return _zero_plan(p.R, p.T)
    movable, headroom = region_aggregates(p, D)
    return plan_migration(np.asarray(p.mci, float), movable, headroom,
                          p.topology, **plan_kwargs)
