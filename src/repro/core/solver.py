"""DR optimization solvers.

Two interchangeable backends consume a `PolicySpec`:

  * `solve_slsqp` — scipy Sequential Least Squares Programming, the paper's
    solver (§VI-A: "We solve optimization problems with Scipy's Sequential
    Least Squares Programming"), with JAX-supplied exact gradients. This is
    the **paper-faithful reference**: fine for 4 workloads × 48 hours.

  * `solve_adam` — beyond-paper fleet-scale solver: a thin adapter over the
    shared engine (`repro.core.engine.al_minimize`): jit-compiled projected
    Adam on an augmented Lagrangian. Box bounds and batch-preservation are
    handled by exact projection (both are cheap closed forms); equality /
    inequality constraints get multiplier + quadratic terms. One XLA call
    solves the whole problem; `vmap` over hyperparameters sweeps a Pareto
    frontier in a single compile (see `repro.core.api.sweep`).

Both report final metrics with the *unsmoothed* models so numbers are
comparable across solvers. With the vectorized `FleetProblem` stack (see
`repro.core.fleet_solver`) carrying the production path, the SLSQP solver
here is the *validation reference*: `FleetProblem.from_problem/to_problem`
convert between the two representations so every fleet policy can be
cross-checked against the paper's solver on small instances.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import enable_x64

from repro.core.engine import EngineConfig, al_minimize
from repro.core.policies import DRProblem, PolicySpec

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class SolveResult:
    """Outcome of one policy solve, reported with unsmoothed models."""

    name: str
    solver: str
    D: np.ndarray                    # (W, T)
    objective: float
    carbon_reduction: float          # kg CO2 eliminated (Σ⟨mci, d_i⟩)
    carbon_reduction_pct: float      # % of baseline operational carbon
    total_penalty: float             # NP capacity-equivalent
    total_penalty_pct: float         # % of Σ entitlements
    per_penalty: np.ndarray          # (W,)
    per_carbon: np.ndarray           # (W,)
    peak: float
    violations: dict[str, float]
    nit: int


def evaluate(spec: PolicySpec, D: np.ndarray, solver: str, nit: int,
             objective: float | None = None) -> SolveResult:
    """Final reporting with smooth=0 (the true, kinked models)."""
    p = spec.problem
    Dj = jnp.asarray(D)
    per_pen = np.asarray(p.penalties(Dj, smooth=0.0))
    per_car = np.asarray(p.carbon_reduction_per_workload(Dj))
    lower, upper = p.bounds()
    if spec.lower is not None:
        lower = spec.lower
    if spec.upper is not None:
        upper = spec.upper
    viol = {
        "capacity": max(0.0, float(p.peak(Dj)) - p.capacity_limit),
        "box": float(np.maximum(np.maximum(D - upper, lower - D), 0.0).max()),
    }
    if spec.use_preservation and p.preservation != "none":
        res = np.asarray(p.preservation_residual(Dj))
        viol["preservation"] = (float(np.abs(res).max()) if res.size else 0.0) \
            if p.preservation == "equality" else \
            (float(np.maximum(-res, 0.0).max()) if res.size else 0.0)
    for j, g in enumerate(spec.ineq_constraints):
        viol[f"ineq{j}"] = max(0.0, -float(np.min(np.asarray(g(Dj)))))
    for j, h in enumerate(spec.eq_constraints):
        viol[f"eq{j}"] = float(np.abs(np.asarray(h(Dj))).max())
    total_pen = float(per_pen.sum())
    car = float(per_car.sum())
    return SolveResult(
        name=spec.name, solver=solver, D=np.asarray(D),
        objective=float(objective) if objective is not None
        else float(spec.objective(Dj)),
        carbon_reduction=car,
        carbon_reduction_pct=100.0 * car / p.total_carbon_baseline,
        total_penalty=total_pen,
        total_penalty_pct=100.0 * total_pen / float(p.entitlements.sum()),
        per_penalty=per_pen, per_carbon=per_car,
        peak=float(p.peak(Dj)), violations=viol, nit=nit)


def _spec_bounds(spec: PolicySpec) -> tuple[np.ndarray, np.ndarray]:
    p = spec.problem
    lower, upper = p.bounds()
    if spec.lower is not None:
        lower = spec.lower
    if spec.upper is not None:
        upper = spec.upper
    free = np.ones(p.W, bool) if spec.free is None else spec.free
    lower = np.where(free[:, None], lower, 0.0)
    upper = np.where(free[:, None], upper, 0.0)
    return lower, upper


# ---------------------------------------------------------------------------
# scipy SLSQP (paper-faithful)
# ---------------------------------------------------------------------------
def solve_slsqp(spec: PolicySpec, x0: np.ndarray | None = None,
                maxiter: int = 300, ftol: float = 1e-8) -> SolveResult:
    import scipy.optimize as sopt

    p = spec.problem
    W, T = p.W, p.T
    lower, upper = _spec_bounds(spec)

    def make_con(fn: Callable[[Array], Array], kind: str) -> dict:
        """jit'd (fun, jac) pair in its own scope — no closure rebinding."""
        f = jax.jit(lambda x: jnp.atleast_1d(fn(x.reshape(W, T))))
        j = jax.jit(jax.jacrev(lambda x: jnp.atleast_1d(fn(x.reshape(W, T)))))
        return {"type": kind,
                "fun": lambda x: np.asarray(f(jnp.asarray(x))),
                "jac": lambda x: np.asarray(j(jnp.asarray(x)))}

    with enable_x64(True):
        obj_grad = jax.jit(jax.value_and_grad(
            lambda x: spec.objective(x.reshape(W, T))))

        cons = []
        if spec.use_preservation and p.preservation != "none":
            kind = "eq" if p.preservation == "equality" else "ineq"
            cons.append(make_con(p.preservation_residual, kind))
        for g in spec.ineq_constraints:
            cons.append(make_con(g, "ineq"))
        for h in spec.eq_constraints:
            cons.append(make_con(h, "eq"))

        def fun(x: np.ndarray) -> tuple[float, np.ndarray]:
            v, g = obj_grad(jnp.asarray(x))
            return float(v), np.asarray(g, dtype=np.float64)

        x_init = (np.zeros(W * T) if x0 is None
                  else np.asarray(x0, np.float64).ravel())
        bounds = list(zip(lower.ravel(), upper.ravel()))
        res = sopt.minimize(fun, x_init, jac=True, method="SLSQP",
                            bounds=bounds, constraints=cons,
                            options={"maxiter": maxiter, "ftol": ftol})
    D = res.x.reshape(W, T)
    return evaluate(spec, D, solver="slsqp", nit=int(res.nit))


# ---------------------------------------------------------------------------
# JAX augmented-Lagrangian projected Adam (fleet-scale, beyond paper)
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class AdamALConfig:
    inner_steps: int = 400
    outer_steps: int = 8
    lr: float = 0.05
    mu0: float = 10.0          # initial quadratic weight
    mu_growth: float = 2.0
    seed: int = 0


def solve_adam(spec: PolicySpec, cfg: AdamALConfig = AdamALConfig(),
               x0: np.ndarray | None = None) -> SolveResult:
    p = spec.problem
    W, T = p.W, p.T
    lower, upper = _spec_bounds(spec)
    lo = jnp.asarray(lower, jnp.float32)
    hi = jnp.asarray(upper, jnp.float32)
    # Scale step sizes to the problem's magnitude.
    scale = float(np.maximum(upper - lower, 1e-6).mean())

    eqs = list(spec.eq_constraints)
    preservation_eq = (spec.use_preservation
                       and p.preservation == "equality")
    preservation_ineq = (spec.use_preservation
                         and p.preservation == "inequality")
    if preservation_ineq:
        ineqs = list(spec.ineq_constraints) + [
            lambda D: p.preservation_residual(D)]
    else:
        ineqs = list(spec.ineq_constraints)

    def project(D: Array) -> Array:
        D = jnp.clip(D, lo, hi)
        if preservation_eq:
            # Alternate the two projections; both are cheap and the pair
            # converges fast (verified residuals reported in the result).
            for _ in range(3):
                D = p.project_preservation(D)
                D = jnp.clip(D, lo, hi)
        return D

    eq_residual = None
    if eqs:
        def eq_residual(D: Array, _) -> Array:
            return jnp.concatenate([jnp.atleast_1d(h(D)).ravel()
                                    for h in eqs])

    ineq_residual = None
    if ineqs:
        def ineq_residual(D: Array, _) -> Array:
            return jnp.concatenate([jnp.atleast_1d(g(D)).ravel()
                                    for g in ineqs])

    def objective(D: Array, _) -> Array:
        return spec.objective(D)

    run = jax.jit(lambda D0: al_minimize(
        objective, project, D0,
        eq_residual=eq_residual, ineq_residual=ineq_residual,
        step_scale=scale,
        cfg=EngineConfig(inner_steps=cfg.inner_steps,
                         outer_steps=cfg.outer_steps, lr=cfg.lr,
                         mu0=cfg.mu0, mu_growth=cfg.mu_growth))[0])

    D0 = (jnp.zeros((W, T), jnp.float32) if x0 is None
          else jnp.asarray(x0, jnp.float32))
    D = np.asarray(run(D0), np.float64)
    return evaluate(spec, D, solver="adam-al",
                    nit=cfg.inner_steps * cfg.outer_steps)


# ---------------------------------------------------------------------------
# CR3 driver — decentralized solves + fiscal-balance clearing (Eqs. 5–8)
# ---------------------------------------------------------------------------
def solve_cr3(p: DRProblem, rho: float, tax_frac: float = 0.2,
              solver: str = "slsqp", clearing_iters: int = 8,
              ) -> tuple[SolveResult, float]:
    """Each workload solves its own problem at carbon price ρ; the
    coordinator lowers ρ until taxes cover rebates (Eq. 6). Returns the
    fleet result assembled from the decentralized solutions and the
    market-clearing ρ."""
    from repro.core.policies import cr3_fiscal_balance, cr3_workload_spec

    def solve_all(rho_: float) -> np.ndarray:
        D = np.zeros((p.W, p.T))
        for i in range(p.W):
            s = cr3_workload_spec(p, i, rho_, tax_frac)
            r = solve_slsqp(s) if solver == "slsqp" else solve_adam(s)
            D[i] = r.D[i]
        return D

    rho_cur = rho
    D = solve_all(rho_cur)
    for _ in range(clearing_iters):
        paid, collected = cr3_fiscal_balance(p, D, rho_cur, tax_frac)
        if paid <= collected + 1e-9:
            break
        rho_cur *= max(0.5, 0.9 * collected / max(paid, 1e-9))
        D = solve_all(rho_cur)

    # Report as a fleet outcome.
    spec = PolicySpec(name=f"CR3(ρ={rho:g})", problem=p,
                      objective=lambda D_: p.total_penalty(D_))
    return evaluate(spec, D, solver=solver, nit=clearing_iters), rho_cur
