"""RegionReductions — per-region segment-summed reductions as one layer.

Multi-region fleets price every region on its own normalizers. Each
engine lane (solo solve, sharded solve, sweep, ensemble, day scan,
streaming, CR3 fiscal clearing, the migration stages) needs the same
small family of per-region reductions, and PR 7 grew them as ad-hoc
``mci.ndim`` branches scattered across api/ensemble/migration/streaming.
This module is their single home; every lane consumes it.

Two flavors live here, matching the two places reductions run:

  * **Traced, row-separable** (jnp; safe inside jit/vmap/shard_map
    bodies): ``region_rows`` (the :class:`RegionReductions` view of a
    fleet), ``region_sum`` (segment-sum a per-row quantity and gather
    the per-region total back to rows), the CR1/CR2 normalizer tuples
    ``cr1_norms``/``cr2_norms`` whose multi-region twins are per-row
    (W,)/(W, 1) vectors, and the pad/spec plumbing that lets those
    vectors ride device meshes (``pad_row_norms``, ``norm_specs``).
    Everything stays row-separable so the engine's sharding contract
    (no cross-device reductions inside the differentiated objective)
    holds — per-region totals are scattered back to rows *before* the
    solve and shard with their rows.

  * **Host-side, exact numpy** (``region_totals``): per-region
    accumulation of (W,) or (W, T) row weights — CR3's Eq.-6 fiscal
    sums (taxes collected / rebates paid per region), the migration
    stage's movable/headroom aggregates, and streaming's per-region
    carbon ledgers.

Single-region problems flow through the same functions and get the
fleet-global scalar forms, so callers never branch on region-ness
themselves; the R=1 path is bitwise-identical to the pre-regional
code (same expressions, same evaluation order).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.core.fleet_solver import FleetProblem, _bounds

__all__ = ["CR1_NORM_FILLS", "CR2_NORM_FILLS", "RegionReductions",
           "cr1_norms", "cr2_norms", "cr3_reg_scale", "norm_specs",
           "pad_row_norms", "region_rows", "region_sum", "region_totals"]

#: Pad fills for `pad_row_norms` keeping device-pad rows inert:
#: weights 0 (pad rows contribute nothing), step/scale divisors 1
#: (nothing blows up). Order matches the norms tuples.
CR1_NORM_FILLS = (0.0, 0.0, 1.0)   # (pen_w, car_w, step_w)
CR2_NORM_FILLS = (0.0, 1.0, 1.0)   # (car_w, scale_w, step_w)


class RegionReductions(NamedTuple):
    """Per-row region view of a multi-region fleet (see `region_rows`)."""
    region: jax.Array   #: (W,) int — each row's region id
    wmci: jax.Array     #: (W, T) — each row's region MCI trace
    counts: jax.Array   #: (W,) — row count of each row's region


def region_rows(p: FleetProblem) -> RegionReductions:
    """Per-row region scatter helpers for a multi-region problem:
    `(region, wmci, counts)` with `wmci[w] = mci[region[w]]` (W, T) and
    `counts[w]` the row count of w's region. Segment sums over the
    region ids turn per-region reductions into per-row normalizer
    vectors — the multi-region twin of the fleet-global scalars, still
    row-separable so the sharding contract holds (pad rows carry
    region 0 but their norms are overridden by `pad_row_norms`)."""
    region = jnp.asarray(p.region)
    R = jnp.asarray(p.mci).shape[0]
    counts = jax.ops.segment_sum(jnp.ones(p.W), region, num_segments=R)
    return RegionReductions(region, jnp.asarray(p.mci)[region],
                            counts[region])


def region_sum(x, region, R: int):
    """Per-row view of a per-region sum: segment-sum then gather back."""
    return jax.ops.segment_sum(x, region, num_segments=R)[region]


def region_totals(region, weights, R: int) -> np.ndarray:
    """Exact host-side per-region totals of per-row weights: (W,) weights
    give an (R,) total, (W, T) weights an (R, T) total. `region` may be
    a masked row subset as long as it is index-aligned with `weights`
    (e.g. `region[is_batch]` with `residual[is_batch]`). The one numpy
    accumulation primitive behind CR3's Eq.-6 fiscal sums, migration's
    movable/headroom aggregates, and streaming's per-region ledgers."""
    region = np.asarray(region)
    w = np.asarray(weights, float)
    if w.ndim == 1:
        return np.bincount(region, weights=w, minlength=R)
    out = np.zeros((R,) + w.shape[1:])
    np.add.at(out, region, w)
    return out


def cr1_norms(p: FleetProblem):
    """Fleet-global CR1 reductions (normalizers + shared step scale) —
    computed from the TRUE fleet before any device padding, then passed
    into the sharded solve as replicated scalars.

    Multi-region problems get the per-REGION twin: each region is
    normalized on its own entitlement/carbon/step reductions (scattered
    back to per-row vectors), so with zero migration bandwidth the joint
    solve decomposes exactly into R independent single-region solves."""
    lo, hi = _bounds(p)
    mci = jnp.asarray(p.mci)
    if mci.ndim == 2:
        region, wmci, counts_w = region_rows(p)
        R = mci.shape[0]
        pen_w = 100.0 / region_sum(jnp.asarray(p.entitlement), region, R)
        car_w = 100.0 / region_sum((jnp.asarray(p.usage) * wmci).sum(1),
                                   region, R)
        rowmeans = jnp.maximum(hi - lo, 1e-6).mean(axis=1)
        step_w = (region_sum(rowmeans, region, R) / counts_w)[:, None]
        return pen_w, car_w, step_w
    return (100.0 / jnp.asarray(p.entitlement).sum(),
            100.0 / (jnp.asarray(p.usage).sum(0) * mci).sum(),
            jnp.maximum(hi - lo, 1e-6).mean())


def cr2_norms(p: FleetProblem, refs):
    """Fleet-global CR2 reductions (carbon normalizer, equality-residual
    scale, shared step scale) from the TRUE fleet before padding. Per-
    region twin for multi-region problems, as in `cr1_norms`."""
    lo, hi = _bounds(p)
    mci = jnp.asarray(p.mci)
    if mci.ndim == 2:
        region, wmci, counts_w = region_rows(p)
        R = mci.shape[0]
        car_w = 100.0 / region_sum((jnp.asarray(p.usage) * wmci).sum(1),
                                   region, R)
        scale_w = jnp.maximum(region_sum(refs, region, R) / counts_w, 1e-3)
        rowmeans = jnp.maximum(hi - lo, 1e-6).mean(axis=1)
        step_w = (region_sum(rowmeans, region, R) / counts_w)[:, None]
        return car_w, scale_w, step_w
    return (100.0 / (jnp.asarray(p.usage).sum(0) * mci).sum(),
            jnp.maximum(refs.mean(), 1e-3),
            jnp.maximum(hi - lo, 1e-6).mean())


def cr3_reg_scale(p: FleetProblem):
    """CR3's per-row regularizer normalizer for a multi-region fleet:
    1e-3/(W_region·T) scattered to rows, so each region's market
    regularizes exactly like its standalone single-region market."""
    region = np.asarray(p.region)
    counts = np.bincount(region, minlength=p.R)
    return jnp.asarray((1e-3 / (counts * p.T))[region][:, None])


def pad_row_norms(norms, W_pad: int, fills):
    """Pad per-row multi-region norm vectors to the device-padded W.
    Fill values (`CR1_NORM_FILLS`/`CR2_NORM_FILLS`) keep pad rows inert
    (0 for weights so they contribute nothing, 1 for step/scale divisors
    so nothing blows up)."""
    out = []
    for a, f in zip(norms, fills):
        a = jnp.asarray(a)
        pad = W_pad - a.shape[0]
        out.append(a if pad == 0 else jnp.concatenate(
            [a, jnp.full((pad,) + a.shape[1:], f, a.dtype)]))
    return tuple(out)


def norm_specs(p: FleetProblem, axis, n: int = 3, *, stacked: bool = False):
    """shard_map specs for a norms tuple: replicated scalars for the
    single-region path, row-sharded vectors for multi-region. With
    `stacked=True` the norms carry a leading replicated axis (per-tick
    day-scan stacks, per-lane sweep/ensemble stacks) ahead of the
    sharded row axis."""
    if np.ndim(p.mci) == 1:
        one = P()
    else:
        one = P(None, axis) if stacked else P(axis)
    return (one,) * n
