"""Vectorized fleet-scale DR solvers (beyond-paper), built on one engine.

The paper solves 4 workloads × 48 h with SLSQP. A datacenter fleet has
thousands of workloads; SLSQP's dense QP subproblems scale as O((W·T)³) and
the per-workload python penalty loop doesn't jit. This module stacks every
workload's penalty model into arrays:

  RTS:    C_i = k_i Σ_t f(a_i; d/U)            (cubic polynomial)
  batch:  C_i = (k_i (β₀ + β₁ x₁ + β₂ x₂))⁺    (Table-IV features)

so the whole fleet evaluates as a handful of (W, T) tensor ops — vmapped,
jit-compiled, MXU-shaped (T padded to 128 lanes on TPU), with the Table-IV
features computed by the `dr_features` Pallas kernel on TPU (jnp fallback
elsewhere; see `repro.kernels.dispatch`).

Architecture: all three policies are thin adapters over
`repro.core.engine.al_minimize` — a single projected-Adam +
augmented-Lagrangian loop parameterized by (objective, eq/ineq residuals,
projection). Each adapter is one jitted entry point:

  * CR1 (`solve_cr1_fleet`): unconstrained trade-off objective
    λ·penalty − carbon, projection only; λ is a traced hyperparameter, and
    `solve_cr1_fleet_sweep` vmaps the whole Fig.-8 λ grid through one
    compile.
  * CR2 (`solve_cr2_fleet`): min −carbon s.t. C_i(d_i) = C_i(cap%) — one
    equality multiplier per workload.
  * CR3 (`solve_cr3_fleet`): the paper's decentralized taxes-and-rebates
    game (Eqs. 5–8). All W selfish problems are separable, so one (W, T)
    AL solve with a per-workload peak-allowance inequality IS the vmapped
    best response; a python outer loop lowers the carbon price ρ until
    taxes cover rebates (Eq. 6), one XLA call per clearing round.

`FleetProblem` is a registered JAX pytree (arrays are leaves; `day_hours`
etc. are static), so adapters jit directly over it, and
`FleetProblem.from_problem`/`to_problem` convert to/from the per-workload
`DRProblem` so the SLSQP stack serves as a validation reference.

Device sharding (100k-workload fleets): every adapter takes `mesh=` — a
1-D device mesh (`repro.launch.mesh.make_fleet_mesh`) — and then runs the
same AL loop through `engine.al_minimize_sharded`, sharding the W axis of
the primal, the per-workload multipliers, the Adam moments, and every
per-workload `FleetProblem` field; only the (T,) MCI trace and solver
scalars are replicated. The contract:

  * W is padded to a multiple of the device count with *inert* workloads
    (`pad_fleet`: box [0, 0], k=0, safe divisors) — reported results are
    sliced back to true rows, but `FleetSolveResult.state` keeps the
    padded shape so streaming re-solves can chain without re-padding.
  * Nothing is psum'd in the solver hot loop: the objectives are sums of
    per-workload terms, so each device's local gradient IS the global one.
    The genuinely cross-workload reductions — the objective normalizers
    and shared step scales (`_cr1_norms`/`_cr2_norms`, computed from the
    true fleet before padding) and CR3's Eq.-6 fiscal-clearing sums (taxes
    vs rebates, computed on the gathered solution between best-response
    rounds) — happen outside the sharded region and enter replicated.
  * Streaming ticks fuse into one donated-buffer XLA call: `donate=True`
    routes to a `jax.jit(..., donate_argnums=state)` twin, and
    `shift=`/`reset_mu=` fold the rolling-horizon state shift and the
    per-tick mu restart into the same call, so `RollingHorizonSolver`
    re-solves in place. A donated `EngineState`'s buffers are invalidated
    — don't reuse a state object you passed with `donate=True`.
"""
from __future__ import annotations

import dataclasses
import functools
import warnings
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.core.engine import (EngineConfig, EngineState, al_minimize,
                               al_minimize_sharded)
from repro.core.penalty import PenaltyModel
from repro.launch.mesh import fleet_axis

Array = jax.Array

# Initial AL penalty weights per policy — the single source for both the
# adapters below and the streaming controller's per-tick μ reset
# (`repro.core.streaming.RollingHorizonSolver`). CR3's gentle wall is
# deliberate; see `_cr3_best_response`.
CR1_MU0 = 10.0
CR2_MU0 = 10.0
CR3_MU0 = 0.01


@dataclasses.dataclass(frozen=True)
class FleetProblem:
    """Stacked-workload DR instance (a JAX pytree; jit over it directly)."""
    usage: np.ndarray          # (W, T)
    entitlement: np.ndarray    # (W,)
    k: np.ndarray              # (W,)
    rts_coeffs: np.ndarray     # (W, 3) a3,a2,a1 (zeros for batch)
    betas: np.ndarray          # (W, 3) β0,β1,β2 (zeros for RTS)
    x2_kind: np.ndarray        # (W,) 0=num_jobs_delayed, 1=waiting_sq
    jobs: np.ndarray           # (W, T)
    is_batch: np.ndarray       # (W,) bool
    mci: np.ndarray            # (T,)
    day_hours: int = 24
    max_curtail_frac: float = 0.5
    names: tuple[str, ...] | None = None
    # Optional (W, T) operational cap on curtailment, intersected with the
    # entitlement/usage box — e.g. the dynamic-power range a job can
    # actually shed by throttling (FleetCoordinator realizability). Not a
    # penalty-model property, so `to_problem` drops it.
    upper: np.ndarray | None = None

    @property
    def W(self) -> int:
        return self.usage.shape[0]

    @property
    def T(self) -> int:
        return self.usage.shape[1]

    @classmethod
    def from_problem(cls, p) -> "FleetProblem":
        """Stack a per-workload `DRProblem` into the fleet representation.

        The fleet path implements the default DRProblem subset: equality
        day-preservation, curtail-only RTS, and no datacenter capacity
        inequality (Eq. 10 — never active for the paper fleet's 1.2
        buffer; fleet-scale support is a ROADMAP item). Non-default
        `preservation`/`rts_boost` settings would silently change meaning
        here, so they are rejected."""
        if p.preservation != "equality" or p.rts_boost:
            raise ValueError(
                "FleetProblem supports preservation='equality' and "
                f"rts_boost=False only (got preservation={p.preservation!r},"
                f" rts_boost={p.rts_boost})")
        return from_models(p.models, p.mci, day_hours=p.day_hours,
                           max_curtail_frac=p.max_curtail_frac)

    def to_problem(self, **overrides):
        """Rebuild the per-workload `DRProblem` (SLSQP reference) view."""
        from repro.core.policies import DRProblem
        names = self.names or tuple(f"w{i}" for i in range(self.W))
        models = []
        for i in range(self.W):
            if bool(self.is_batch[i]):
                slo = float(self.x2_kind[i]) > 0.5
                models.append(PenaltyModel(
                    name=names[i],
                    kind="batch_slo" if slo else "batch_noslo",
                    usage=np.asarray(self.usage[i]),
                    entitlement=float(self.entitlement[i]),
                    k=float(self.k[i]),
                    params=tuple(float(b) for b in self.betas[i]),
                    jobs=np.asarray(self.jobs[i]),
                    feature_names=("waiting_time_power",
                                   "waiting_time_squared" if slo
                                   else "num_jobs_delayed")))
            else:
                models.append(PenaltyModel(
                    name=names[i], kind="realtime",
                    usage=np.asarray(self.usage[i]),
                    entitlement=float(self.entitlement[i]),
                    k=float(self.k[i]),
                    params=tuple(float(a) for a in self.rts_coeffs[i])))
        kw = dict(models=tuple(models), mci=np.asarray(self.mci),
                  max_curtail_frac=self.max_curtail_frac,
                  day_hours=self.day_hours)
        kw.update(overrides)
        return DRProblem(**kw)


jax.tree_util.register_dataclass(
    FleetProblem,
    data_fields=["usage", "entitlement", "k", "rts_coeffs", "betas",
                 "x2_kind", "jobs", "is_batch", "mci", "upper"],
    meta_fields=["day_hours", "max_curtail_frac", "names"])


def from_models(models: Sequence[PenaltyModel], mci: np.ndarray,
                day_hours: int = 24, max_curtail_frac: float = 0.5,
                ) -> FleetProblem:
    W = len(models)
    T = mci.shape[0]
    usage = np.stack([m.usage for m in models])
    ent = np.asarray([m.entitlement for m in models])
    k = np.asarray([m.k for m in models])
    rts = np.zeros((W, 3))
    betas = np.zeros((W, 3))
    x2k = np.zeros(W)
    jobs = np.ones((W, T))
    is_batch = np.zeros(W, bool)
    for i, m in enumerate(models):
        if m.kind == "realtime":
            rts[i] = m.params
        else:
            is_batch[i] = True
            betas[i] = m.params
            jobs[i] = m.jobs
            x2k[i] = 1.0 if m.feature_names[1] == "waiting_time_squared" \
                else 0.0
    return FleetProblem(usage=usage, entitlement=ent, k=k, rts_coeffs=rts,
                        betas=betas, x2_kind=x2k, jobs=jobs,
                        is_batch=is_batch, mci=mci, day_hours=day_hours,
                        max_curtail_frac=max_curtail_frac,
                        names=tuple(m.name for m in models))


def synthetic_fleet(num: int, hours: int = 48, seed: int = 0,
                    templates: dict[str, PenaltyModel] | None = None,
                    ) -> FleetProblem:
    """Clone the calibrated paper models into a fleet of `num` workloads
    with randomized scales/mix — the scaling benchmark's input."""
    from repro.core.carbon import caiso_2021
    from repro.core.fleetcache import cached_paper_fleet
    templates = templates or cached_paper_fleet(hours=hours)
    rng = np.random.default_rng(seed)
    names = list(templates)
    models = []
    for i in range(num):
        base = templates[names[i % len(names)]]
        scale = float(rng.uniform(0.2, 3.0))
        models.append(dataclasses.replace(
            base, name=f"{base.name}-{i}", usage=base.usage * scale,
            entitlement=base.entitlement * scale,
            jobs=None if base.jobs is None else base.jobs * scale))
    return from_models(models, caiso_2021(hours).mci)


# ---------------------------------------------------------------------------
# Vectorized penalties (backend-aware kernel dispatch)
# ---------------------------------------------------------------------------
def resolve_use_kernel(flag: bool | None) -> bool:
    """None = auto: Pallas kernel on TPU, jnp path elsewhere."""
    if flag is None:
        from repro.kernels.dispatch import on_tpu
        return on_tpu()
    return bool(flag)


def _features(d: Array, usage: Array, jobs: Array, use_kernel: bool) -> Array:
    """(W, 4): wait_jobs, wait_power, wait_sq, njobs — Table IV."""
    if use_kernel:
        from repro.kernels.dr_features.ops import dr_features
        return dr_features(d, usage, jobs)
    rate = jobs * d / usage
    wait_jobs = jnp.maximum(jnp.cumsum(rate, axis=1), 0).sum(1)
    wait_power = jnp.maximum(jnp.cumsum(d, axis=1), 0).sum(1)
    rate_sq = jobs * d * jnp.abs(d) / usage
    wait_sq = jnp.maximum(jnp.cumsum(rate_sq, axis=1), 0).sum(1)
    njobs = (jobs * jnp.maximum(d, 0) / usage).sum(1)
    return jnp.stack([wait_jobs, wait_power, wait_sq, njobs], axis=1)


def fleet_penalties(p: FleetProblem, D: Array,
                    use_kernel: bool | None = None) -> Array:
    """(W,) calibrated penalties — fully vectorized."""
    use_kernel = resolve_use_kernel(use_kernel)
    usage = jnp.asarray(p.usage)
    delta = D / usage
    a3, a2, a1 = (jnp.asarray(p.rts_coeffs[:, i])[:, None] for i in range(3))
    f_rts = (a3 * delta**3 + a2 * delta**2 + a1 * delta).sum(axis=1)
    X = _features(D, usage, jnp.asarray(p.jobs), use_kernel)
    x1 = X[:, 1]
    x2 = jnp.where(jnp.asarray(p.x2_kind) > 0.5, X[:, 2], X[:, 3])
    b = jnp.asarray(p.betas)
    f_batch = jnp.maximum(b[:, 0] + b[:, 1] * x1 + b[:, 2] * x2, 0.0)
    raw = jnp.where(jnp.asarray(p.is_batch), f_batch, f_rts)
    return jnp.asarray(p.k) * raw


# ---------------------------------------------------------------------------
# Shared adapter plumbing: bounds, projection, reporting
# ---------------------------------------------------------------------------
def _jit_view(p: FleetProblem) -> FleetProblem:
    """Strip reporting-only static metadata (`names`) before jit calls —
    names live in the pytree treedef, so leaving them in would recompile
    the adapters for every same-shaped fleet with different job names."""
    return dataclasses.replace(p, names=None)


#: Read-only +inf `upper` templates by shape — `pad_fleet` runs on every
#: streaming tick, and a 100k-row fleet's no-op cap is ~40 MB we should
#: not reallocate hourly.
_INF_UPPER: dict[tuple[int, int], np.ndarray] = {}


def _inf_upper(shape: tuple[int, int]) -> np.ndarray:
    out = _INF_UPPER.get(shape)
    if out is None:
        out = np.full(shape, np.inf)
        out.setflags(write=False)
        _INF_UPPER[shape] = out
    return out


def pad_fleet(p: FleetProblem, multiple: int) -> tuple[FleetProblem, int]:
    """Pad W up to a multiple of `multiple` with inert workloads.

    Pad rows get usage=0.01 NP, entitlement=1, k=0 and an operational cap
    (`upper`) of 0: their box is [0, 0] so the projection pins them at zero
    curtailment, their penalties and penalty gradients are exactly zero
    (k=0 with finite features), and every division the policies perform
    (by entitlement, by usage, by tau=0.02·E) stays finite. The tiny usage
    keeps CR3's smooth peak (tau·logsumexp(usage/tau) ≈ 0.09·E at D=0)
    well inside the pad allowance for any tax_frac ≲ 0.9, so pad allowance
    constraints stay feasible and their multipliers stay exactly zero —
    even across arbitrarily long chained warm re-solves. `upper` is
    materialized (+inf where the true fleet had none) so the padded pytree
    has a fixed structure. Returns (padded problem, true W); reports and
    fiscal sums must slice rows [:W_true].
    """
    pad = (-p.W) % multiple
    upper = np.asarray(p.upper, float) if p.upper is not None \
        else _inf_upper(p.usage.shape)
    if pad == 0:
        return dataclasses.replace(p, upper=upper, names=None), p.W

    def rows(a, fill):
        a = np.asarray(a)
        return np.concatenate(
            [a, np.full((pad,) + a.shape[1:], fill, dtype=a.dtype)])

    return dataclasses.replace(
        p, usage=rows(p.usage, 0.01), entitlement=rows(p.entitlement, 1.0),
        k=rows(p.k, 0.0), rts_coeffs=rows(p.rts_coeffs, 0.0),
        betas=rows(p.betas, 0.0), x2_kind=rows(p.x2_kind, 0.0),
        jobs=rows(p.jobs, 1.0), is_batch=rows(p.is_batch, False),
        upper=rows(upper, 0.0), names=None), p.W


def _pad_state(state: EngineState, W_pad: int) -> EngineState:
    """Zero-pad a warm start's per-workload leaves to the padded W (no-op
    when already padded — the streaming donation chain relies on that)."""
    W = state.x.shape[0]
    if W == W_pad:
        return state

    def pad(a):
        a = jnp.asarray(a)
        if a.ndim and a.shape[0] == W:
            return jnp.concatenate(
                [a, jnp.zeros((W_pad - W,) + a.shape[1:], a.dtype)])
        return a

    return EngineState(x=pad(state.x), lam_eq=pad(state.lam_eq),
                       lam_in=pad(state.lam_in), mu=state.mu)


def _fleet_specs(p: FleetProblem, axis: str) -> FleetProblem:
    """shard_map PartitionSpecs for a (padded) FleetProblem: every
    per-workload field sharded on its leading W axis, the MCI replicated."""
    row = P(axis)
    return dataclasses.replace(
        p, usage=row, entitlement=row, k=row, rts_coeffs=row, betas=row,
        x2_kind=row, jobs=row, is_batch=row, mci=P(), upper=row)


def _enter_tick(state: EngineState, shift: int, reset_mu: bool,
                mu0: float) -> EngineState:
    """Fused streaming-tick entry, traced inside the solve's own XLA call:
    roll the plan `shift` hours and restart the mu schedule at the policy's
    mu0 (multipliers still carry their constraint prices)."""
    if shift:
        state = state.shifted(shift)
    if reset_mu:
        state = dataclasses.replace(
            state, mu=jnp.full_like(state.mu, mu0))
    return state
@dataclasses.dataclass(frozen=True)
class FleetSolveResult:
    D: np.ndarray
    carbon_reduction_pct: float
    total_penalty_pct: float
    iters: int
    preservation_violation: float
    # Reusable engine carry for warm-started re-solves (rolling horizon).
    state: EngineState | None = None
    # CR3 fiscal clearing (Eq. 6): did taxes cover rebates, and by how much
    # were they short when they didn't? Always balanced for CR1/CR2.
    balanced: bool = True
    fiscal_deficit: float = 0.0


def _bounds(p: FleetProblem) -> tuple[Array, Array]:
    """Box bounds: curtail ≤ min(frac·E, U); batch may boost to U−d ≤ E.
    An operational `p.upper` cap (e.g. throttleable dynamic power)
    tightens the curtail side further."""
    usage = jnp.asarray(p.usage)
    E = jnp.asarray(p.entitlement)[:, None]
    hi = jnp.minimum(p.max_curtail_frac * E, usage)
    if p.upper is not None:
        hi = jnp.minimum(hi, jnp.asarray(p.upper))
    lo = jnp.where(jnp.asarray(p.is_batch)[:, None], -(E - usage), 0.0)
    return lo, hi


def _projection(p: FleetProblem, lo: Array, hi: Array):
    """Alternating clip + batch day-preservation projection (3 rounds)."""
    W, T = p.usage.shape
    n_days = max(1, T // p.day_hours)
    span = n_days * p.day_hours
    is_batch = jnp.asarray(p.is_batch)[:, None, None]

    def project(D: Array) -> Array:
        D = jnp.clip(D, lo, hi)
        for _ in range(3):
            Dd = D[:, :span].reshape(W, n_days, p.day_hours)
            mean = Dd.mean(axis=-1, keepdims=True)
            Dd = jnp.where(is_batch, Dd - mean, Dd)
            D = jnp.clip(jnp.concatenate(
                [Dd.reshape(W, span), D[:, span:]], axis=1), lo, hi)
        return D

    return project


def _report(p: FleetProblem, D: np.ndarray, pens: np.ndarray,
            iters: int, state: EngineState | None = None,
            **extra) -> FleetSolveResult:
    mci = np.asarray(p.mci)
    carbon_base = float((np.asarray(p.usage).sum(0) * mci).sum())
    car = float((D @ mci).sum())
    n_days = max(1, p.T // p.day_hours)
    span = n_days * p.day_hours
    sums = D[:, :span].reshape(p.W, n_days, p.day_hours).sum(-1)
    is_batch = np.asarray(p.is_batch)
    viol = float(np.abs(sums[is_batch]).max()) if is_batch.any() else 0.0
    return FleetSolveResult(
        D=D, carbon_reduction_pct=100 * car / carbon_base,
        total_penalty_pct=100 * float(pens.sum())
        / float(np.asarray(p.entitlement).sum()),
        iters=iters, preservation_violation=viol, state=state, **extra)


# ---------------------------------------------------------------------------
# CR1 — Efficient DR at fleet scale (thin adapter over the engine)
# ---------------------------------------------------------------------------
def _cr1_norms(p: FleetProblem):
    """Fleet-global CR1 reductions (normalizers + shared step scale) —
    computed from the TRUE fleet before any device padding, then passed
    into the sharded solve as replicated scalars."""
    lo, hi = _bounds(p)
    mci = jnp.asarray(p.mci)
    return (100.0 / jnp.asarray(p.entitlement).sum(),
            100.0 / (jnp.asarray(p.usage).sum(0) * mci).sum(),
            jnp.maximum(hi - lo, 1e-6).mean())


def _cr1_pieces(p: FleetProblem, use_kernel: bool, norms=None):
    lo, hi = _bounds(p)
    mci = jnp.asarray(p.mci)
    pen_norm, car_norm, step_scale = \
        _cr1_norms(p) if norms is None else norms

    def objective(D: Array, lam) -> Array:
        return (lam * pen_norm * fleet_penalties(p, D, use_kernel).sum()
                - car_norm * (D @ mci).sum())

    project = _projection(p, lo, hi)
    return objective, project, step_scale


def _cr1_impl(p: FleetProblem, lam, state0: EngineState, steps: int,
              use_kernel: bool, shift: int = 0, reset_mu: bool = False):
    state0 = _enter_tick(state0, shift, reset_mu, CR1_MU0)
    objective, project, step_scale = _cr1_pieces(p, use_kernel)
    D, aux = al_minimize(objective, project, state0.x, hyper=lam,
                         step_scale=step_scale, init=state0,
                         cfg=EngineConfig(inner_steps=steps, outer_steps=1))
    return D, fleet_penalties(p, D, use_kernel), aux["state"]


_CR1_STATIC = ("steps", "use_kernel", "shift", "reset_mu")
_cr1_run = jax.jit(_cr1_impl, static_argnames=_CR1_STATIC)
_cr1_run_donated = jax.jit(_cr1_impl, static_argnames=_CR1_STATIC,
                           donate_argnums=(2,))


def _cr1_impl_sharded(p: FleetProblem, lam, norms, state0: EngineState,
                      mesh, steps: int, use_kernel: bool, shift: int = 0,
                      reset_mu: bool = False):
    state0 = _enter_tick(state0, shift, reset_mu, CR1_MU0)
    axis = fleet_axis(mesh)

    def build(blk):
        pb, lam_b, norms_b = blk
        objective, project, step_scale = _cr1_pieces(pb, use_kernel,
                                                     norms=norms_b)
        return dict(objective=objective, project=project, hyper=lam_b,
                    step_scale=step_scale)

    D, aux = al_minimize_sharded(
        build, (p, lam, norms), mesh=mesh, axis_name=axis,
        data_specs=(_fleet_specs(p, axis), P(), (P(), P(), P())),
        init=state0, cfg=EngineConfig(inner_steps=steps, outer_steps=1))
    return D, fleet_penalties(p, D, use_kernel), aux["state"]


_CR1_STATIC_SH = ("mesh", "steps", "use_kernel", "shift", "reset_mu")
_cr1_run_sharded = jax.jit(_cr1_impl_sharded, static_argnames=_CR1_STATIC_SH)
_cr1_run_sharded_donated = jax.jit(_cr1_impl_sharded,
                                   static_argnames=_CR1_STATIC_SH,
                                   donate_argnums=(3,))


@functools.partial(jax.jit, static_argnames=("steps", "use_kernel"))
def _cr1_sweep(p: FleetProblem, lams, steps: int, use_kernel: bool):
    objective, project, step_scale = _cr1_pieces(p, use_kernel)

    def solve_one(lam):
        D, _ = al_minimize(objective, project, jnp.zeros(p.usage.shape),
                           hyper=lam, step_scale=step_scale,
                           cfg=EngineConfig(inner_steps=steps,
                                            outer_steps=1))
        return D, fleet_penalties(p, D, use_kernel)

    return jax.vmap(solve_one)(lams)


def solve_cr1_fleet(p: FleetProblem, lam: float = 1.45, steps: int = 600,
                    use_kernel: bool | None = None,
                    warm: EngineState | None = None, *,
                    mesh=None, donate: bool = False, shift: int = 0,
                    reset_mu: bool = False) -> FleetSolveResult:
    """CR1 fleet solve. Pass `warm` (a previous result's `.state`, e.g.
    shifted by `EngineState.shifted`) to warm-start: same jit trace as the
    cold solve, typically needing far fewer `steps`.

    `mesh` shards the solve over the mesh's fleet axis (W padded to a
    multiple of the device count; `result.state` keeps the padded shape so
    re-solves chain without re-padding — see the module docstring).
    `donate` routes through a `donate_argnums` twin that reuses the warm
    state's buffers in place (the passed state becomes invalid);
    `shift`/`reset_mu` fold the rolling-horizon shift and per-tick mu
    restart into the same XLA call (the streaming tick path).
    """
    use_kernel = resolve_use_kernel(use_kernel)
    if mesh is None:
        if warm is None:
            warm = EngineState.cold(jnp.zeros(p.usage.shape))
        run = _cr1_run_donated if donate else _cr1_run
        D, pens, state = run(_jit_view(p), lam, warm, steps=steps,
                             use_kernel=use_kernel, shift=shift,
                             reset_mu=reset_mu)
        return _report(p, np.asarray(D), np.asarray(pens), iters=steps,
                       state=state)
    pp, W = pad_fleet(p, mesh.shape[fleet_axis(mesh)])
    norms = _cr1_norms(p)
    warm = _pad_state(warm, pp.W) if warm is not None \
        else EngineState.cold(jnp.zeros(pp.usage.shape))
    run = _cr1_run_sharded_donated if donate else _cr1_run_sharded
    D, pens, state = run(pp, lam, norms, warm, mesh=mesh, steps=steps,
                         use_kernel=use_kernel, shift=shift,
                         reset_mu=reset_mu)
    return _report(p, np.asarray(D)[:W], np.asarray(pens)[:W], iters=steps,
                   state=state)


def solve_cr1_fleet_sweep(p: FleetProblem, lams: Sequence[float],
                          steps: int = 600, use_kernel: bool | None = None,
                          ) -> list[FleetSolveResult]:
    """The Fig.-8 Pareto sweep as ONE XLA call: the λ grid rides a vmap
    axis through the shared engine, so the sweep compiles once."""
    use_kernel = resolve_use_kernel(use_kernel)
    Ds, pens = _cr1_sweep(_jit_view(p), jnp.asarray(lams, jnp.float32),
                          steps, use_kernel)
    return [_report(p, D, pen, iters=steps)
            for D, pen in zip(np.asarray(Ds), np.asarray(pens))]


# ---------------------------------------------------------------------------
# CR2 at fleet scale — fair-centralized with per-workload penalty targets
# ---------------------------------------------------------------------------
def cr2_reference_fleet(p: FleetProblem, cap_frac: float) -> np.ndarray:
    """C_i under a hypothetical equal power cap at cap_frac·E (vectorized
    version of policies.cr2_reference_losses)."""
    L = cap_frac * np.asarray(p.entitlement)[:, None]
    d_cap = np.maximum(np.asarray(p.usage) - L, 0.0)
    return np.asarray(fleet_penalties(p, jnp.asarray(d_cap)))


def _cr2_norms(p: FleetProblem, refs):
    """Fleet-global CR2 reductions (carbon normalizer, equality-residual
    scale, shared step scale) from the TRUE fleet before padding."""
    lo, hi = _bounds(p)
    mci = jnp.asarray(p.mci)
    return (100.0 / (jnp.asarray(p.usage).sum(0) * mci).sum(),
            jnp.maximum(refs.mean(), 1e-3),
            jnp.maximum(hi - lo, 1e-6).mean())


def _cr2_pieces(p: FleetProblem, refs, use_kernel: bool, norms=None):
    lo, hi = _bounds(p)
    mci = jnp.asarray(p.mci)
    car_norm, scale, step_scale = \
        _cr2_norms(p, refs) if norms is None else norms

    def objective(D: Array, _) -> Array:
        return -car_norm * (D @ mci).sum()

    def eq(D: Array, _) -> Array:
        return (fleet_penalties(p, D, use_kernel) - refs) / scale

    return objective, eq, _projection(p, lo, hi), step_scale


def _cr2_cfg(steps: int, outer: int) -> EngineConfig:
    return EngineConfig(inner_steps=steps, outer_steps=outer, mu0=CR2_MU0,
                        mu_growth=2.0)


def _cr2_impl(p: FleetProblem, refs, state0: EngineState, steps: int,
              outer: int, use_kernel: bool, shift: int = 0,
              reset_mu: bool = False):
    state0 = _enter_tick(state0, shift, reset_mu, CR2_MU0)
    objective, eq, project, step_scale = _cr2_pieces(p, refs, use_kernel)
    D, aux = al_minimize(objective, project, state0.x,
                         eq_residual=eq, step_scale=step_scale, init=state0,
                         cfg=_cr2_cfg(steps, outer))
    return D, fleet_penalties(p, D, use_kernel), aux["state"]


_CR2_STATIC = ("steps", "outer", "use_kernel", "shift", "reset_mu")
_cr2_run = jax.jit(_cr2_impl, static_argnames=_CR2_STATIC)
_cr2_run_donated = jax.jit(_cr2_impl, static_argnames=_CR2_STATIC,
                           donate_argnums=(2,))


def _cr2_impl_sharded(p: FleetProblem, refs, norms, state0: EngineState,
                      mesh, steps: int, outer: int, use_kernel: bool,
                      shift: int = 0, reset_mu: bool = False):
    state0 = _enter_tick(state0, shift, reset_mu, CR2_MU0)
    axis = fleet_axis(mesh)

    def build(blk):
        pb, refs_b, norms_b = blk
        objective, eq, project, step_scale = _cr2_pieces(
            pb, refs_b, use_kernel, norms=norms_b)
        return dict(objective=objective, project=project, eq_residual=eq,
                    step_scale=step_scale)

    D, aux = al_minimize_sharded(
        build, (p, refs, norms), mesh=mesh, axis_name=axis,
        data_specs=(_fleet_specs(p, axis), P(axis), (P(), P(), P())),
        init=state0, cfg=_cr2_cfg(steps, outer))
    return D, fleet_penalties(p, D, use_kernel), aux["state"]


_CR2_STATIC_SH = ("mesh", "steps", "outer", "use_kernel", "shift",
                  "reset_mu")
_cr2_run_sharded = jax.jit(_cr2_impl_sharded, static_argnames=_CR2_STATIC_SH)
_cr2_run_sharded_donated = jax.jit(_cr2_impl_sharded,
                                   static_argnames=_CR2_STATIC_SH,
                                   donate_argnums=(3,))


def solve_cr2_fleet(p: FleetProblem, cap_frac: float = 0.78,
                    steps: int = 400, outer: int = 6,
                    use_kernel: bool | None = None,
                    warm: EngineState | None = None, *,
                    mesh=None, donate: bool = False, shift: int = 0,
                    reset_mu: bool = False) -> FleetSolveResult:
    """min −carbon s.t. C_i(d_i) = C_i(cap%) ∀i — augmented Lagrangian with
    one multiplier per workload, everything vectorized over the fleet.

    `warm` carries a previous solve's primal AND its W equality multipliers
    (the per-workload fairness prices), so a rolling re-solve converges in
    a fraction of the cold outer/inner budget. `mesh`/`donate`/`shift`/
    `reset_mu` as in `solve_cr1_fleet`: the per-workload multipliers shard
    with their rows, and the padded equality residuals are identically zero
    so pad multipliers stay 0."""
    use_kernel = resolve_use_kernel(use_kernel)
    refs = jnp.asarray(cr2_reference_fleet(p, cap_frac))
    if mesh is None:
        if warm is None:
            warm = EngineState.cold(jnp.zeros(p.usage.shape), n_eq=p.W,
                                    mu0=CR2_MU0)
        run = _cr2_run_donated if donate else _cr2_run
        D, pens, state = run(_jit_view(p), refs, warm, steps=steps,
                             outer=outer, use_kernel=use_kernel,
                             shift=shift, reset_mu=reset_mu)
        return _report(p, np.asarray(D), np.asarray(pens),
                       iters=steps * outer, state=state)
    pp, W = pad_fleet(p, mesh.shape[fleet_axis(mesh)])
    norms = _cr2_norms(p, refs)
    refs_p = jnp.concatenate([refs, jnp.zeros(pp.W - W, refs.dtype)])
    warm = _pad_state(warm, pp.W) if warm is not None \
        else EngineState.cold(jnp.zeros(pp.usage.shape), n_eq=pp.W,
                              mu0=CR2_MU0)
    run = _cr2_run_sharded_donated if donate else _cr2_run_sharded
    D, pens, state = run(pp, refs_p, norms, warm, mesh=mesh, steps=steps,
                         outer=outer, use_kernel=use_kernel, shift=shift,
                         reset_mu=reset_mu)
    return _report(p, np.asarray(D)[:W], np.asarray(pens)[:W],
                   iters=steps * outer, state=state)


# ---------------------------------------------------------------------------
# CR3 at fleet scale — decentralized taxes and rebates (Eqs. 5–8)
# ---------------------------------------------------------------------------
def _cr3_pieces(p: FleetProblem, use_kernel: bool, reg_scale):
    """Best-response pieces for one device's row block (or the whole fleet).

    Everything here is row-separable; `reg_scale` is the regularizer
    normalizer 1e-3/(W_true·T), passed in so a padded sharded solve
    regularizes identically to the unpadded single-device one.

    Numerics, validated against the per-workload SLSQP reference:
      * tiny quadratic regularizer — a selfish workload takes the *minimal*
        adjustment satisfying its allowance; the regularizer breaks the
        zero-penalty plateau of batch models toward that minimal response
        (without it, any deep-feasible point is an equally 'optimal' best
        response with wildly overpaid rebates).
      * day-tangent gradient projection (see engine.al_minimize docs).
      * gentle μ schedule: the KKT multipliers here are O(1e-3), so a stiff
        wall (μ≫1) just makes projected Adam bounce off the boundary.
    """
    lo, hi = _bounds(p)
    usage = jnp.asarray(p.usage)
    E = jnp.asarray(p.entitlement)
    mci = jnp.asarray(p.mci)
    tau = 0.02 * E

    def objective(D: Array, hyper) -> Array:
        reg = reg_scale * ((D / E[:, None]) ** 2).sum()
        return (fleet_penalties(p, D, use_kernel) / E).sum() + reg

    def ineq(D: Array, hyper) -> Array:
        rho_, tax_ = hyper
        rebate = rho_ * (D @ mci)
        peak = tau * jax.nn.logsumexp((usage - D) / tau[:, None], axis=1)
        return ((1.0 - tax_) * E + rebate - peak) / E

    W, T = p.usage.shape
    n_days = max(1, T // p.day_hours)
    span = n_days * p.day_hours
    is_batch = jnp.asarray(p.is_batch)[:, None, None]

    def day_tangent(g: Array) -> Array:
        Gd = g[:, :span].reshape(W, n_days, p.day_hours)
        Gd = jnp.where(is_batch, Gd - Gd.mean(axis=-1, keepdims=True), Gd)
        return jnp.concatenate([Gd.reshape(W, span), g[:, span:]], axis=1)

    step_scale = jnp.maximum(hi - lo, 1e-6).mean(axis=1, keepdims=True)
    return objective, ineq, _projection(p, lo, hi), step_scale, day_tangent


def _cr3_cfg(steps: int, outer: int) -> EngineConfig:
    return EngineConfig(inner_steps=steps, outer_steps=outer, lr=0.005,
                        mu0=CR3_MU0, mu_growth=2.0, beta2=0.99)


def _cr3_impl(p: FleetProblem, rho, tax_frac, reg_scale,
              state0: EngineState, steps: int, outer: int, use_kernel: bool,
              shift: int = 0, reset_mu: bool = False):
    """All W selfish problems in one AL solve. Each workload i minimizes its
    own penalty s.t. the peak-allowance inequality (Eq. 5/8)

        max_t (U_i − d_i) ≤ E_i − T_i + ρ·⟨mci, d_i⟩,   T_i = tax_frac·E_i

    (smooth max as in `policies.cr3_workload_spec`). Objective, residual and
    projection are all row-separable, so this single (W, T) engine call IS
    the vmapped per-workload best response — one XLA call per round.
    """
    state0 = _enter_tick(state0, shift, reset_mu, CR3_MU0)
    objective, ineq, project, step_scale, day_tangent = _cr3_pieces(
        p, use_kernel, reg_scale)
    D, aux = al_minimize(objective, project, state0.x,
                         hyper=(rho, tax_frac), ineq_residual=ineq,
                         step_scale=step_scale, grad_transform=day_tangent,
                         init=state0, cfg=_cr3_cfg(steps, outer))
    return D, fleet_penalties(p, D, use_kernel), aux["state"]


_CR3_STATIC = ("steps", "outer", "use_kernel", "shift", "reset_mu")
_cr3_best_response = jax.jit(_cr3_impl, static_argnames=_CR3_STATIC)
_cr3_best_response_donated = jax.jit(_cr3_impl, static_argnames=_CR3_STATIC,
                                     donate_argnums=(4,))


def _cr3_impl_sharded(p: FleetProblem, rho, tax_frac, reg_scale,
                      state0: EngineState, mesh, steps: int, outer: int,
                      use_kernel: bool, shift: int = 0,
                      reset_mu: bool = False):
    """Sharded best response: the allowance inequality, its multipliers and
    the per-row step scale all live with their rows; only ρ/tax/reg_scale
    are replicated. The Eq.-6 fiscal sums live in `solve_cr3_fleet`."""
    state0 = _enter_tick(state0, shift, reset_mu, CR3_MU0)
    axis = fleet_axis(mesh)

    def build(blk):
        pb, hyper_b, reg_b = blk
        objective, ineq, project, step_scale, day_tangent = _cr3_pieces(
            pb, use_kernel, reg_b)
        return dict(objective=objective, project=project, hyper=hyper_b,
                    ineq_residual=ineq, step_scale=step_scale,
                    grad_transform=day_tangent)

    D, aux = al_minimize_sharded(
        build, (p, (rho, tax_frac), reg_scale), mesh=mesh, axis_name=axis,
        data_specs=(_fleet_specs(p, axis), (P(), P()), P()),
        init=state0, cfg=_cr3_cfg(steps, outer))
    return D, fleet_penalties(p, D, use_kernel), aux["state"]


_CR3_STATIC_SH = ("mesh", "steps", "outer", "use_kernel", "shift",
                  "reset_mu")
_cr3_sharded = jax.jit(_cr3_impl_sharded, static_argnames=_CR3_STATIC_SH)
_cr3_sharded_donated = jax.jit(_cr3_impl_sharded,
                               static_argnames=_CR3_STATIC_SH,
                               donate_argnums=(4,))


def solve_cr3_fleet(p: FleetProblem, rho: float = 0.02,
                    tax_frac: float = 0.2, steps: int = 600, outer: int = 3,
                    clearing_iters: int = 8,
                    use_kernel: bool | None = None,
                    warm: EngineState | None = None, *,
                    mesh=None, donate: bool = False, shift: int = 0,
                    reset_mu: bool = False,
                    ) -> tuple[FleetSolveResult, float]:
    """Fleet-scale CR3: vmapped best responses + fiscal-balance clearing.

    The coordinator lowers the carbon price ρ until rebates are covered by
    taxes (Eq. 6, `policies.cr3_fiscal_balance` semantics). Returns
    (result, clearing ρ), mirroring `solver.solve_cr3`.

    Each clearing round warm-starts from the previous round's engine state
    (the allowance multipliers track the shrinking ρ smoothly); `warm`
    seeds round 0 the same way for rolling-horizon re-solves.

    With `mesh`, each best response runs sharded over the fleet axis; the
    Eq.-6 sums (rebates paid vs taxes collected) are the only cross-device
    reductions and happen here, on the gathered true-W solution between
    rounds. `donate`/`shift`/`reset_mu` as in `solve_cr1_fleet` (rounds
    after the first always re-enter with the μ schedule restarted).

    If `clearing_iters` is exhausted with rebates still exceeding taxes,
    the result carries `balanced=False` and the remaining `fiscal_deficit`
    (rebates − taxes, NP·kgCO2/MWh), and a `RuntimeWarning` is emitted —
    callers must not treat the returned ρ as market-clearing then."""
    use_kernel = resolve_use_kernel(use_kernel)
    mci = np.asarray(p.mci)
    collected = tax_frac * float(np.asarray(p.entitlement).sum())
    rho_cur = float(rho)
    if mesh is None:
        pj, W = _jit_view(p), p.W
        state = warm if warm is not None else EngineState.cold(
            jnp.zeros(p.usage.shape), n_in=p.W, mu0=CR3_MU0)
        twin = _cr3_best_response_donated if donate else _cr3_best_response
    else:
        pj, W = pad_fleet(p, mesh.shape[fleet_axis(mesh)])
        state = _pad_state(warm, pj.W) if warm is not None \
            else EngineState.cold(jnp.zeros(pj.usage.shape), n_in=pj.W,
                                  mu0=CR3_MU0)
        twin = _cr3_sharded_donated if donate else _cr3_sharded
    reg_scale = 1e-3 / (W * p.T)

    def best_response(st, shift_, reset_):
        kw = {} if mesh is None else {"mesh": mesh}
        return twin(pj, rho_cur, tax_frac, reg_scale, st, steps=steps,
                    outer=outer, use_kernel=use_kernel, shift=shift_,
                    reset_mu=reset_, **kw)

    D, pens, state = best_response(state, shift, reset_mu)
    D = np.asarray(D)[:W]
    rounds = 1
    paid = rho_cur * float((D @ mci).sum())
    for _ in range(clearing_iters):
        if paid <= collected + 1e-9:
            break
        rho_cur *= max(0.5, 0.9 * collected / max(paid, 1e-9))
        # Carry primal + allowance multipliers; restart the μ schedule so
        # every round keeps the gentle wall the best response relies on.
        D, pens, state = best_response(state, 0, True)
        D = np.asarray(D)[:W]
        rounds += 1
        paid = rho_cur * float((D @ mci).sum())
    balanced = paid <= collected + 1e-9
    deficit = 0.0 if balanced else paid - collected
    if not balanced:
        warnings.warn(
            f"solve_cr3_fleet: fiscal clearing did not converge in "
            f"{clearing_iters} iterations — rebates exceed taxes by "
            f"{deficit:.4g} at rho={rho_cur:.4g} (Eq. 6 unmet)",
            RuntimeWarning, stacklevel=2)
    return (_report(p, D, np.asarray(pens)[:W],
                    iters=steps * outer * rounds,
                    state=state, balanced=balanced, fiscal_deficit=deficit),
            rho_cur)
