"""Fleet-scale DR data model and shared solver plumbing.

The paper solves 4 workloads × 48 h with SLSQP. A datacenter fleet has
thousands of workloads; SLSQP's dense QP subproblems scale as O((W·T)³) and
the per-workload python penalty loop doesn't jit. This module stacks every
workload's penalty model into arrays:

  RTS:    C_i = k_i Σ_t f(a_i; d/U)            (cubic polynomial)
  batch:  C_i = (k_i (β₀ + β₁ x₁ + β₂ x₂))⁺    (Table-IV features)

so the whole fleet evaluates as a handful of (W, T) tensor ops — vmapped,
jit-compiled, MXU-shaped (T padded to 128 lanes on TPU), with the Table-IV
features computed by the `dr_features` Pallas kernel on TPU (jnp fallback
elsewhere; see `repro.kernels.dispatch`).

Solving lives in `repro.core.api`: policies are first-class frozen
dataclasses (`CR1(lam=...)`, `CR2(cap_frac=...)`, `CR3(rho=...,
tax_frac=...)`, baseline wrappers `B1`/`B3`) and every solve goes through
one entry point —

    from repro.core.api import CR1, SolveContext, solve
    result = solve(problem, CR1(lam=1.45), ctx=SolveContext(mesh=...))

with `SolveContext` bundling the execution concerns (mesh, donated
buffers, the fused streaming tick, warm starts, kernel dispatch, step
budgets) and `sweep()` running whole policy grids as one vmapped XLA call.
This module keeps what the policies share:

  * `FleetProblem` — the stacked-workload instance, a registered JAX
    pytree (arrays are leaves; `day_hours` etc. are static), plus
    `from_problem`/`to_problem` conversion so the per-workload SLSQP
    stack (`repro.core.solver`) serves as a validation reference.
  * `fleet_penalties` — the vectorized Table-IV/RTS penalty evaluation
    with backend-aware kernel dispatch.
  * `FleetSolveResult` — the uniform result every policy returns.
    Policy-specific outputs ride `result.extras` (CR3 puts its clearing
    `"rho"`, `"balanced"` and `"fiscal_deficit"` there).
  * Device-sharding plumbing (100k-workload fleets): `pad_fleet` pads W
    to a multiple of the device count with *inert* workloads (box [0, 0],
    k=0, safe divisors), `_fleet_specs` builds the shard_map
    PartitionSpecs, `_pad_state`/`_enter_tick` carry warm `EngineState`s
    across padded/streaming re-solves. Reported results are sliced back
    to true rows, but `FleetSolveResult.state` keeps the padded shape so
    streaming re-solves chain without re-padding.

The historical per-policy entry points `solve_cr{1,2,3}_fleet` and
`solve_cr1_fleet_sweep` remain as deprecated shims that delegate to
`api.solve`/`api.sweep` (one `DeprecationWarning` per call); they will be
removed once nothing imports them.
"""
from __future__ import annotations

import dataclasses
import warnings
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.core.engine import EngineState
from repro.core.penalty import PenaltyModel

Array = jax.Array

# Initial AL penalty weights per policy — the single source for the policy
# backends in `repro.core.api` and the streaming controller's per-tick μ
# reset. CR3's gentle wall is deliberate; see `api.CR3`.
CR1_MU0 = 10.0
CR2_MU0 = 10.0
CR3_MU0 = 0.01


@dataclasses.dataclass(frozen=True)
class RegionTopology:
    """Cross-region migration network for a multi-region fleet.

    All matrices are indexed [from_region, to_region]. `bandwidth` caps
    how much deferrable load (NP) can move over a link per hour — zero
    (including the diagonal, which is ignored) disables the link, so
    `bandwidth=0` everywhere reduces the fleet to independent per-region
    solves. `cost` is the carbon toll per unit moved (kgCO2/MWh-NP
    equivalent — network/overhead energy), subtracted from the migration
    margin. `ceiling` is an optional per-region power cap (R,) or (R, T)
    that bounds how much migrated load a region can absorb on top of its
    own; None means uncapped.

    Kept out of every jit trace (`_jit_view`/`pad_fleet` strip it):
    migration planning is a host-side post-stage on gathered region
    aggregates (`repro.core.migration`), not part of the sharded hot
    loop.
    """
    cost: np.ndarray                    # (R, R)
    bandwidth: np.ndarray               # (R, R)
    ceiling: np.ndarray | None = None   # (R,) or (R, T)
    labels: tuple[str, ...] | None = None

    @property
    def R(self) -> int:
        return np.asarray(self.cost).shape[0]

    def validate(self, R: int, T: int) -> None:
        cost = np.asarray(self.cost)
        bw = np.asarray(self.bandwidth)
        if cost.shape != (R, R) or bw.shape != (R, R):
            raise ValueError(
                f"RegionTopology cost/bandwidth must be ({R}, {R}); got "
                f"{cost.shape} / {bw.shape}")
        if self.ceiling is not None:
            ceil = np.asarray(self.ceiling)
            if ceil.shape not in ((R,), (R, T)):
                raise ValueError(
                    f"RegionTopology ceiling must be ({R},) or ({R}, {T}); "
                    f"got {ceil.shape}")
        if self.labels is not None and len(self.labels) != R:
            raise ValueError(
                f"RegionTopology labels must have {R} entries; got "
                f"{len(self.labels)}")


@dataclasses.dataclass(frozen=True)
class FleetProblem:
    """Stacked-workload DR instance (a JAX pytree; jit over it directly).

    Single-region fleets have `mci: (T,)` and `region is None`.
    Multi-region fleets stack per-region signals as `mci: (R, T)` and
    assign every workload a region via `region: (W,) int`; an optional
    `topology` adds the cross-region migration network. R=1 is the
    degenerate case and is canonicalized back to the single-region form
    at the `api.solve`/`sweep`/`solve_day` entry points, so it is
    bitwise-identical to a plain (T,) problem.
    """
    usage: np.ndarray          # (W, T)
    entitlement: np.ndarray    # (W,)
    k: np.ndarray              # (W,)
    rts_coeffs: np.ndarray     # (W, 3) a3,a2,a1 (zeros for batch)
    betas: np.ndarray          # (W, 3) β0,β1,β2 (zeros for RTS)
    x2_kind: np.ndarray        # (W,) 0=num_jobs_delayed, 1=waiting_sq
    jobs: np.ndarray           # (W, T)
    is_batch: np.ndarray       # (W,) bool
    mci: np.ndarray            # (T,) or (R, T) per-region
    day_hours: int = 24
    max_curtail_frac: float = 0.5
    names: tuple[str, ...] | None = None
    # Optional (W, T) operational cap on curtailment, intersected with the
    # entitlement/usage box — e.g. the dynamic-power range a job can
    # actually shed by throttling (FleetCoordinator realizability). Not a
    # penalty-model property, so `to_problem` drops it.
    upper: np.ndarray | None = None
    # Multi-region fields: per-workload region ids (W,) int in [0, R) and
    # the optional migration network. None for single-region fleets.
    region: np.ndarray | None = None
    topology: RegionTopology | None = None

    @property
    def W(self) -> int:
        return self.usage.shape[0]

    @property
    def T(self) -> int:
        return self.usage.shape[1]

    @property
    def R(self) -> int:
        """Number of regions (1 for single-region problems)."""
        mci = np.asarray(self.mci) if isinstance(self.mci, np.ndarray) \
            else self.mci
        return 1 if mci.ndim == 1 else mci.shape[0]

    @property
    def is_multiregion(self) -> bool:
        return np.ndim(self.mci) == 2

    @classmethod
    def from_problem(cls, p) -> "FleetProblem":
        """Stack a per-workload `DRProblem` into the fleet representation.

        The fleet path implements the default DRProblem subset: equality
        day-preservation, curtail-only RTS, and no datacenter capacity
        inequality (Eq. 10 — never active for the paper fleet's 1.2
        buffer; fleet-scale support is a ROADMAP item). Non-default
        `preservation`/`rts_boost` settings would silently change meaning
        here, so they are rejected."""
        if p.preservation != "equality" or p.rts_boost:
            raise ValueError(
                "FleetProblem supports preservation='equality' and "
                f"rts_boost=False only (got preservation={p.preservation!r},"
                f" rts_boost={p.rts_boost})")
        return from_models(p.models, p.mci, day_hours=p.day_hours,
                           max_curtail_frac=p.max_curtail_frac)

    def to_problem(self, **overrides):
        """Rebuild the per-workload `DRProblem` (SLSQP reference) view."""
        from repro.core.policies import DRProblem
        if self.is_multiregion:
            raise ValueError(
                "to_problem() needs a single-region fleet (mci (T,)); the "
                "per-workload SLSQP reference has no region concept")
        names = self.names or tuple(f"w{i}" for i in range(self.W))
        models = []
        for i in range(self.W):
            if bool(self.is_batch[i]):
                slo = float(self.x2_kind[i]) > 0.5
                models.append(PenaltyModel(
                    name=names[i],
                    kind="batch_slo" if slo else "batch_noslo",
                    usage=np.asarray(self.usage[i]),
                    entitlement=float(self.entitlement[i]),
                    k=float(self.k[i]),
                    params=tuple(float(b) for b in self.betas[i]),
                    jobs=np.asarray(self.jobs[i]),
                    feature_names=("waiting_time_power",
                                   "waiting_time_squared" if slo
                                   else "num_jobs_delayed")))
            else:
                models.append(PenaltyModel(
                    name=names[i], kind="realtime",
                    usage=np.asarray(self.usage[i]),
                    entitlement=float(self.entitlement[i]),
                    k=float(self.k[i]),
                    params=tuple(float(a) for a in self.rts_coeffs[i])))
        kw = dict(models=tuple(models), mci=np.asarray(self.mci),
                  max_curtail_frac=self.max_curtail_frac,
                  day_hours=self.day_hours)
        kw.update(overrides)
        return DRProblem(**kw)


jax.tree_util.register_dataclass(
    FleetProblem,
    data_fields=["usage", "entitlement", "k", "rts_coeffs", "betas",
                 "x2_kind", "jobs", "is_batch", "mci", "upper", "region",
                 "topology"],
    meta_fields=["day_hours", "max_curtail_frac", "names"])


def from_models(models: Sequence[PenaltyModel], mci: np.ndarray,
                day_hours: int = 24, max_curtail_frac: float = 0.5,
                ) -> FleetProblem:
    W = len(models)
    T = mci.shape[0]
    usage = np.stack([m.usage for m in models])
    ent = np.asarray([m.entitlement for m in models])
    k = np.asarray([m.k for m in models])
    rts = np.zeros((W, 3))
    betas = np.zeros((W, 3))
    x2k = np.zeros(W)
    jobs = np.ones((W, T))
    is_batch = np.zeros(W, bool)
    for i, m in enumerate(models):
        if m.kind == "realtime":
            rts[i] = m.params
        else:
            is_batch[i] = True
            betas[i] = m.params
            jobs[i] = m.jobs
            x2k[i] = 1.0 if m.feature_names[1] == "waiting_time_squared" \
                else 0.0
    return FleetProblem(usage=usage, entitlement=ent, k=k, rts_coeffs=rts,
                        betas=betas, x2_kind=x2k, jobs=jobs,
                        is_batch=is_batch, mci=mci, day_hours=day_hours,
                        max_curtail_frac=max_curtail_frac,
                        names=tuple(m.name for m in models))


def synthetic_fleet(num: int, hours: int = 48, seed: int = 0,
                    templates: dict[str, PenaltyModel] | None = None,
                    ) -> FleetProblem:
    """Clone the calibrated paper models into a fleet of `num` workloads
    with randomized scales/mix — the scaling benchmark's input."""
    from repro.core.carbon import caiso_2021
    from repro.core.fleetcache import cached_paper_fleet
    templates = templates or cached_paper_fleet(hours=hours)
    rng = np.random.default_rng(seed)
    names = list(templates)
    models = []
    for i in range(num):
        base = templates[names[i % len(names)]]
        scale = float(rng.uniform(0.2, 3.0))
        models.append(dataclasses.replace(
            base, name=f"{base.name}-{i}", usage=base.usage * scale,
            entitlement=base.entitlement * scale,
            jobs=None if base.jobs is None else base.jobs * scale))
    return from_models(models, caiso_2021(hours).mci)


# ---------------------------------------------------------------------------
# Multi-region construction and canonicalization
# ---------------------------------------------------------------------------
def regional_fleet(fleets: Sequence[FleetProblem], mcis: np.ndarray,
                   topology: RegionTopology | None = None) -> FleetProblem:
    """Concatenate R single-region fleets into one (region × workload)
    fleet.

    `fleets[r]` supplies region r's workloads (its own `mci` is ignored)
    and `mcis` is the (R, T) per-region signal stack, e.g. from
    `carbon.regional_traces`. Workloads are kept region-sorted, so a 2-D
    (REGION_AXIS, FLEET_AXIS) mesh lands each region's rows on one
    region slice.
    """
    mcis = np.asarray(mcis, float)
    R = len(fleets)
    if mcis.ndim != 2 or mcis.shape[0] != R:
        raise ValueError(
            f"mcis must be ({R}, T) — one trace per fleet; got {mcis.shape}")
    T = mcis.shape[1]
    if any(f.T != T for f in fleets):
        raise ValueError("every regional fleet must share the trace length")
    if any(f.is_multiregion for f in fleets):
        raise ValueError("regional_fleet composes single-region fleets")
    if topology is not None:
        topology.validate(R, T)

    def cat(field):
        parts = [getattr(f, field) for f in fleets]
        if any(a is None for a in parts):
            if all(a is None for a in parts):
                return None
            parts = [np.asarray(a, float) if a is not None
                     else _inf_upper(f.usage.shape)
                     for f, a in zip(fleets, parts)]
        return np.concatenate([np.asarray(a) for a in parts])

    names = None
    if all(f.names is not None for f in fleets):
        labels = topology.labels if topology is not None \
            and topology.labels is not None else tuple(range(R))
        names = tuple(f"{labels[r]}/{n}"
                      for r, f in enumerate(fleets) for n in f.names)
    region = np.concatenate(
        [np.full(f.W, r, np.int32) for r, f in enumerate(fleets)])
    return FleetProblem(
        usage=cat("usage"), entitlement=cat("entitlement"), k=cat("k"),
        rts_coeffs=cat("rts_coeffs"), betas=cat("betas"),
        x2_kind=cat("x2_kind"), jobs=cat("jobs"), is_batch=cat("is_batch"),
        mci=mcis, day_hours=fleets[0].day_hours,
        max_curtail_frac=fleets[0].max_curtail_frac, names=names,
        upper=cat("upper"), region=region, topology=topology)


def synthetic_regional_fleet(num: int, states: Sequence[str],
                             hours: int = 48, seed: int = 0,
                             year: int = 2050,
                             topology: RegionTopology | None = None,
                             utc_offsets=None) -> FleetProblem:
    """`synthetic_fleet` across R Cambium state mixes: ~num/R workloads
    per region, each region priced on its own `carbon.projection` trace
    (`utc_offsets` passes through to `carbon.regional_traces` — `"auto"`
    rolls each trace onto the coordinator's UTC clock). Default topology:
    uniform bandwidth at 5% of fleet entitlement with a small uniform
    migration toll."""
    from repro.core.carbon import regional_traces
    R = len(states)
    mcis, _ = regional_traces(states, year=year, hours=hours, seed=seed,
                              utc_offsets=utc_offsets)
    per = [num // R + (1 if r < num % R else 0) for r in range(R)]
    fleets = [synthetic_fleet(per[r], hours=hours, seed=seed + r)
              for r in range(R)]
    if topology is None:
        ent = float(sum(np.asarray(f.entitlement).sum() for f in fleets))
        bw = np.full((R, R), 0.05 * ent / max(R - 1, 1))
        np.fill_diagonal(bw, 0.0)
        topology = RegionTopology(
            cost=np.full((R, R), 2.0), bandwidth=bw, labels=tuple(states))
    return regional_fleet(fleets, mcis, topology=topology)


def _single_region_view(p: FleetProblem) -> FleetProblem:
    """Canonicalize the degenerate R=1 multi-region problem to the plain
    single-region form (mci (T,), no region/topology) so it takes the
    exact pre-refactor code path — bitwise-identical results. No-op for
    everything else."""
    if np.ndim(p.mci) == 2 and np.asarray(p.mci).shape[0] == 1:
        return dataclasses.replace(p, mci=np.asarray(p.mci)[0],
                                   region=None, topology=None)
    return p


# ---------------------------------------------------------------------------
# Vectorized penalties (backend-aware kernel dispatch)
# ---------------------------------------------------------------------------
def resolve_use_kernel(flag: bool | None) -> bool:
    """None = auto: Pallas kernel on TPU, jnp path elsewhere."""
    if flag is None:
        from repro.kernels.dispatch import on_tpu
        return on_tpu()
    # use_kernel rides static_argnames in every jitted lane, so `flag`
    # is always a concrete host bool here, never a tracer.
    # drlint: disable=jit-host-leak -- static jit argument, not traced
    return bool(flag)


def _features(d: Array, usage: Array, jobs: Array, use_kernel: bool) -> Array:
    """(W, 4): wait_jobs, wait_power, wait_sq, njobs — Table IV."""
    if use_kernel:
        from repro.kernels.dr_features.ops import dr_features
        return dr_features(d, usage, jobs)
    rate = jobs * d / usage
    wait_jobs = jnp.maximum(jnp.cumsum(rate, axis=1), 0).sum(1)
    wait_power = jnp.maximum(jnp.cumsum(d, axis=1), 0).sum(1)
    rate_sq = jobs * d * jnp.abs(d) / usage
    wait_sq = jnp.maximum(jnp.cumsum(rate_sq, axis=1), 0).sum(1)
    njobs = (jobs * jnp.maximum(d, 0) / usage).sum(1)
    return jnp.stack([wait_jobs, wait_power, wait_sq, njobs], axis=1)


def fleet_penalties(p: FleetProblem, D: Array,
                    use_kernel: bool | None = None) -> Array:
    """(W,) calibrated penalties — fully vectorized."""
    use_kernel = resolve_use_kernel(use_kernel)
    usage = jnp.asarray(p.usage)
    delta = D / usage
    a3, a2, a1 = (jnp.asarray(p.rts_coeffs[:, i])[:, None] for i in range(3))
    f_rts = (a3 * delta**3 + a2 * delta**2 + a1 * delta).sum(axis=1)
    X = _features(D, usage, jnp.asarray(p.jobs), use_kernel)
    x1 = X[:, 1]
    x2 = jnp.where(jnp.asarray(p.x2_kind) > 0.5, X[:, 2], X[:, 3])
    b = jnp.asarray(p.betas)
    f_batch = jnp.maximum(b[:, 0] + b[:, 1] * x1 + b[:, 2] * x2, 0.0)
    raw = jnp.where(jnp.asarray(p.is_batch), f_batch, f_rts)
    return jnp.asarray(p.k) * raw


def cr2_reference_fleet(p: FleetProblem, cap_frac: float) -> np.ndarray:
    """C_i under a hypothetical equal power cap at cap_frac·E (vectorized
    version of policies.cr2_reference_losses) — CR2's fairness targets."""
    L = cap_frac * np.asarray(p.entitlement)[:, None]
    d_cap = np.maximum(np.asarray(p.usage) - L, 0.0)
    return np.asarray(fleet_penalties(p, jnp.asarray(d_cap)))


# ---------------------------------------------------------------------------
# Shared adapter plumbing: bounds, projection, padding, reporting
# ---------------------------------------------------------------------------
def _jit_view(p: FleetProblem) -> FleetProblem:
    """Strip reporting-only static metadata (`names`) before jit calls —
    names live in the pytree treedef, so leaving them in would recompile
    the policy backends for every same-shaped fleet with different job
    names. The migration `topology` is stripped too: it is host-side
    numpy consumed by the `repro.core.migration` post-stage, never by
    the jitted solvers."""
    return dataclasses.replace(p, names=None, topology=None)


#: Read-only +inf `upper` templates by shape — `pad_fleet` runs on every
#: streaming tick, and a 100k-row fleet's no-op cap is ~40 MB we should
#: not reallocate hourly.
_INF_UPPER: dict[tuple[int, int], np.ndarray] = {}


def _inf_upper(shape: tuple[int, int]) -> np.ndarray:
    out = _INF_UPPER.get(shape)
    if out is None:
        out = np.full(shape, np.inf)
        out.setflags(write=False)
        _INF_UPPER[shape] = out
    return out


#: Inert-row fill value per FleetProblem field — the single source for
#: `pad_fleet` AND the scenario-overlay padding in `repro.core.ensemble`
#: (stacked overlays must pad byte-identically or pad rows stop being
#: inert in sharded ensemble lanes). The values are load-bearing; see
#: `pad_fleet`'s docstring for why usage=0.01 specifically.
PAD_FILLS: dict[str, float] = {
    "usage": 0.01, "entitlement": 1.0, "k": 0.0, "rts_coeffs": 0.0,
    "betas": 0.0, "x2_kind": 0.0, "jobs": 1.0, "is_batch": False,
    "upper": 0.0, "region": 0,
}


def pad_fleet(p: FleetProblem, multiple: int) -> tuple[FleetProblem, int]:
    """Pad W up to a multiple of `multiple` with inert workloads.

    Pad rows get usage=0.01 NP, entitlement=1, k=0 and an operational cap
    (`upper`) of 0: their box is [0, 0] so the projection pins them at zero
    curtailment, their penalties and penalty gradients are exactly zero
    (k=0 with finite features), and every division the policies perform
    (by entitlement, by usage, by tau=0.02·E) stays finite. The tiny usage
    keeps CR3's smooth peak (tau·logsumexp(usage/tau) ≈ 0.09·E at D=0)
    well inside the pad allowance for any tax_frac ≲ 0.9, so pad allowance
    constraints stay feasible and their multipliers stay exactly zero —
    even across arbitrarily long chained warm re-solves. `upper` is
    materialized (+inf where the true fleet had none) so the padded pytree
    has a fixed structure. Returns (padded problem, true W); reports and
    fiscal sums must slice rows [:W_true].
    """
    pad = (-p.W) % multiple
    upper = np.asarray(p.upper, float) if p.upper is not None \
        else _inf_upper(p.usage.shape)
    if pad == 0:
        return dataclasses.replace(p, upper=upper, names=None,
                                   topology=None), p.W

    def rows(field, a=None):
        a = np.asarray(getattr(p, field) if a is None else a)
        return np.concatenate(
            [a, np.full((pad,) + a.shape[1:], PAD_FILLS[field],
                        dtype=a.dtype)])

    return dataclasses.replace(
        p, usage=rows("usage"), entitlement=rows("entitlement"),
        k=rows("k"), rts_coeffs=rows("rts_coeffs"), betas=rows("betas"),
        x2_kind=rows("x2_kind"), jobs=rows("jobs"),
        is_batch=rows("is_batch"), upper=rows("upper", upper),
        region=None if p.region is None else rows("region"),
        names=None, topology=None), p.W


def _pad_state(state: EngineState, W_pad: int) -> EngineState:
    """Zero-pad a warm start's per-workload leaves to the padded W (no-op
    when already padded — the streaming donation chain relies on that)."""
    W = state.x.shape[0]
    if W == W_pad:
        return state

    def pad(a):
        a = jnp.asarray(a)
        if a.ndim and a.shape[0] == W:
            return jnp.concatenate(
                [a, jnp.zeros((W_pad - W,) + a.shape[1:], a.dtype)])
        return a

    return EngineState(x=pad(state.x), lam_eq=pad(state.lam_eq),
                       lam_in=pad(state.lam_in), mu=state.mu)


def _fleet_specs(p: FleetProblem, axis) -> FleetProblem:
    """shard_map PartitionSpecs for a (padded) FleetProblem: every
    per-workload field sharded on its leading W axis, the MCI replicated.
    `axis` may be one mesh axis name or a tuple of them (2-D fleet mesh:
    W shards over both)."""
    row = P(axis)
    return dataclasses.replace(
        p, usage=row, entitlement=row, k=row, rts_coeffs=row, betas=row,
        x2_kind=row, jobs=row, is_batch=row, mci=P(), upper=row,
        region=None if p.region is None else row)


def _enter_tick(state: EngineState, shift: int, reset_mu: bool,
                mu0: float) -> EngineState:
    """Fused streaming-tick entry, traced inside the solve's own XLA call:
    roll the plan `shift` hours and restart the mu schedule at the policy's
    mu0 (multipliers still carry their constraint prices)."""
    if shift:
        state = state.shifted(shift)
    if reset_mu:
        state = dataclasses.replace(
            state, mu=jnp.full_like(state.mu, mu0))
    return state


@dataclasses.dataclass(frozen=True)
class FleetSolveResult:
    """Uniform result of one fleet policy solve (any policy)."""
    D: np.ndarray
    carbon_reduction_pct: float
    total_penalty_pct: float
    iters: int
    preservation_violation: float
    # Reusable engine carry for warm-started re-solves (rolling horizon).
    state: EngineState | None = None
    # Policy-specific outputs. CR3's fiscal clearing (Eq. 6) reports
    # "rho" (the clearing carbon price), "balanced" (did taxes cover
    # rebates) and "fiscal_deficit" (rebates − taxes when they didn't,
    # NP·kgCO2/MWh) here; CR1/CR2 leave it empty.
    extras: dict = dataclasses.field(default_factory=dict)

    @property
    def balanced(self) -> bool:
        """CR3 Eq.-6 clearing converged (always True for other policies)."""
        return bool(self.extras.get("balanced", True))

    @property
    def fiscal_deficit(self) -> float:
        """Rebates − taxes left when clearing failed (0.0 when balanced)."""
        return float(self.extras.get("fiscal_deficit", 0.0))


def _bounds(p: FleetProblem) -> tuple[Array, Array]:
    """Box bounds: curtail ≤ min(frac·E, U); batch may boost to U−d ≤ E.
    An operational `p.upper` cap (e.g. throttleable dynamic power)
    tightens the curtail side further."""
    usage = jnp.asarray(p.usage)
    E = jnp.asarray(p.entitlement)[:, None]
    hi = jnp.minimum(p.max_curtail_frac * E, usage)
    if p.upper is not None:
        hi = jnp.minimum(hi, jnp.asarray(p.upper))
    lo = jnp.where(jnp.asarray(p.is_batch)[:, None], -(E - usage), 0.0)
    return lo, hi


def _projection(p: FleetProblem, lo: Array, hi: Array):
    """Alternating clip + batch day-preservation projection (3 rounds)."""
    W, T = p.usage.shape
    n_days = max(1, T // p.day_hours)
    span = n_days * p.day_hours
    is_batch = jnp.asarray(p.is_batch)[:, None, None]

    def project(D: Array) -> Array:
        D = jnp.clip(D, lo, hi)
        for _ in range(3):
            Dd = D[:, :span].reshape(W, n_days, p.day_hours)
            mean = Dd.mean(axis=-1, keepdims=True)
            Dd = jnp.where(is_batch, Dd - mean, Dd)
            D = jnp.clip(jnp.concatenate(
                [Dd.reshape(W, span), D[:, span:]], axis=1), lo, hi)
        return D

    return project


def _report(p: FleetProblem, D: np.ndarray, pens: np.ndarray,
            iters: int, state: EngineState | None = None,
            extras: dict | None = None) -> FleetSolveResult:
    mci = np.asarray(p.mci)
    if mci.ndim == 2:
        wmci = mci[np.asarray(p.region)]
        carbon_base = float((np.asarray(p.usage) * wmci).sum())
        car = float((D * wmci).sum())
    else:
        carbon_base = float((np.asarray(p.usage).sum(0) * mci).sum())
        car = float((D @ mci).sum())
    n_days = max(1, p.T // p.day_hours)
    span = n_days * p.day_hours
    sums = D[:, :span].reshape(p.W, n_days, p.day_hours).sum(-1)
    is_batch = np.asarray(p.is_batch)
    viol = float(np.abs(sums[is_batch]).max()) if is_batch.any() else 0.0
    return FleetSolveResult(
        D=D, carbon_reduction_pct=100 * car / carbon_base,
        total_penalty_pct=100 * float(pens.sum())
        / float(np.asarray(p.entitlement).sum()),
        iters=iters, preservation_violation=viol, state=state,
        extras=extras or {})


# ---------------------------------------------------------------------------
# Deprecated per-policy entry points (thin shims over repro.core.api)
# ---------------------------------------------------------------------------
def _warn_deprecated(old: str, new: str) -> None:
    warnings.warn(
        f"{old} is deprecated; use {new} from repro.core.api",
        DeprecationWarning, stacklevel=3)


def solve_cr1_fleet(p: FleetProblem, lam: float = 1.45, steps: int = 600,
                    use_kernel: bool | None = None,
                    warm: EngineState | None = None, *,
                    mesh=None, donate: bool = False, shift: int = 0,
                    reset_mu: bool = False) -> FleetSolveResult:
    """Deprecated: `api.solve(p, CR1(lam=...), ctx=SolveContext(...))`."""
    from repro.core.api import CR1, SolveContext, solve
    _warn_deprecated("solve_cr1_fleet",
                     "solve(p, CR1(lam=...), ctx=SolveContext(...))")
    return solve(p, CR1(lam=lam), ctx=SolveContext(
        mesh=mesh, donate=donate, shift=shift, reset_mu=reset_mu,
        warm=warm, use_kernel=use_kernel, steps=steps))


def solve_cr1_fleet_sweep(p: FleetProblem, lams: Sequence[float],
                          steps: int = 600, use_kernel: bool | None = None,
                          ) -> list[FleetSolveResult]:
    """Deprecated: `api.sweep(p, [CR1(lam=l) for l in lams], ctx=...)`."""
    from repro.core.api import CR1, SolveContext, sweep
    _warn_deprecated("solve_cr1_fleet_sweep",
                     "sweep(p, [CR1(lam=l) for l in lams], ctx=...)")
    return sweep(p, [CR1(lam=float(lam)) for lam in lams],
                 ctx=SolveContext(steps=steps, use_kernel=use_kernel))


def solve_cr2_fleet(p: FleetProblem, cap_frac: float = 0.78,
                    steps: int = 400, outer: int = 6,
                    use_kernel: bool | None = None,
                    warm: EngineState | None = None, *,
                    mesh=None, donate: bool = False, shift: int = 0,
                    reset_mu: bool = False) -> FleetSolveResult:
    """Deprecated: `api.solve(p, CR2(cap_frac=..., outer=...), ctx=...)`."""
    from repro.core.api import CR2, SolveContext, solve
    _warn_deprecated("solve_cr2_fleet",
                     "solve(p, CR2(cap_frac=...), ctx=SolveContext(...))")
    return solve(p, CR2(cap_frac=cap_frac, outer=outer), ctx=SolveContext(
        mesh=mesh, donate=donate, shift=shift, reset_mu=reset_mu,
        warm=warm, use_kernel=use_kernel, steps=steps))


def solve_cr3_fleet(p: FleetProblem, rho: float = 0.02,
                    tax_frac: float = 0.2, steps: int = 600, outer: int = 3,
                    clearing_iters: int = 8,
                    use_kernel: bool | None = None,
                    warm: EngineState | None = None, *,
                    mesh=None, donate: bool = False, shift: int = 0,
                    reset_mu: bool = False,
                    ) -> tuple[FleetSolveResult, float]:
    """Deprecated: `api.solve(p, CR3(rho=..., tax_frac=...), ctx=...)`.

    The unified API returns a single `FleetSolveResult`; the clearing ρ
    this shim's tuple carried lives in `result.extras["rho"]`."""
    from repro.core.api import CR3, SolveContext, solve
    _warn_deprecated(
        "solve_cr3_fleet",
        "solve(p, CR3(rho=..., tax_frac=...), ctx=SolveContext(...)) "
        "(clearing rho is result.extras['rho'])")
    result = solve(p, CR3(rho=rho, tax_frac=tax_frac, outer=outer,
                          clearing_iters=clearing_iters),
                   ctx=SolveContext(mesh=mesh, donate=donate, shift=shift,
                                    reset_mu=reset_mu, warm=warm,
                                    use_kernel=use_kernel, steps=steps))
    return result, result.extras["rho"]
