"""Vectorized fleet-scale DR solver (beyond-paper).

The paper solves 4 workloads × 48 h with SLSQP. A datacenter fleet has
thousands of workloads; SLSQP's dense QP subproblems scale as O((W·T)³) and
the per-workload python penalty loop doesn't jit. This module stacks every
workload's penalty model into arrays:

  RTS:    C_i = k_i Σ_t f(a_i; d/U)            (cubic polynomial)
  batch:  C_i = (k_i (β₀ + β₁ x₁ + β₂ x₂))⁺    (Table-IV features)

so the whole fleet evaluates as a handful of (W, T) tensor ops — vmapped,
jit-compiled, MXU-shaped (T padded to 128 lanes on TPU), with the Table-IV
features optionally computed by the `dr_features` Pallas kernel. CR1 solves
with projected Adam + exact preservation projection; one XLA call.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.penalty import PenaltyModel

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class FleetProblem:
    """Stacked-workload DR instance."""
    usage: np.ndarray          # (W, T)
    entitlement: np.ndarray    # (W,)
    k: np.ndarray              # (W,)
    rts_coeffs: np.ndarray     # (W, 3) a3,a2,a1 (zeros for batch)
    betas: np.ndarray          # (W, 3) β0,β1,β2 (zeros for RTS)
    x2_kind: np.ndarray        # (W,) 0=num_jobs_delayed, 1=waiting_sq
    jobs: np.ndarray           # (W, T)
    is_batch: np.ndarray       # (W,) bool
    mci: np.ndarray            # (T,)
    day_hours: int = 24
    max_curtail_frac: float = 0.5

    @property
    def W(self) -> int:
        return self.usage.shape[0]

    @property
    def T(self) -> int:
        return self.usage.shape[1]


def from_models(models: Sequence[PenaltyModel], mci: np.ndarray,
                ) -> FleetProblem:
    W = len(models)
    T = mci.shape[0]
    usage = np.stack([m.usage for m in models])
    ent = np.asarray([m.entitlement for m in models])
    k = np.asarray([m.k for m in models])
    rts = np.zeros((W, 3))
    betas = np.zeros((W, 3))
    x2k = np.zeros(W)
    jobs = np.ones((W, T))
    is_batch = np.zeros(W, bool)
    for i, m in enumerate(models):
        if m.kind == "realtime":
            rts[i] = m.params
        else:
            is_batch[i] = True
            betas[i] = m.params
            jobs[i] = m.jobs
            x2k[i] = 1.0 if m.feature_names[1] == "waiting_time_squared" \
                else 0.0
    return FleetProblem(usage=usage, entitlement=ent, k=k, rts_coeffs=rts,
                        betas=betas, x2_kind=x2k, jobs=jobs,
                        is_batch=is_batch, mci=mci)


def synthetic_fleet(num: int, hours: int = 48, seed: int = 0,
                    templates: dict[str, PenaltyModel] | None = None,
                    ) -> FleetProblem:
    """Clone the calibrated paper models into a fleet of `num` workloads
    with randomized scales/mix — the scaling benchmark's input."""
    from repro.core.carbon import caiso_2021
    from repro.core.fleetcache import cached_paper_fleet
    templates = templates or cached_paper_fleet(hours=hours)
    rng = np.random.default_rng(seed)
    names = list(templates)
    models = []
    for i in range(num):
        base = templates[names[i % len(names)]]
        scale = float(rng.uniform(0.2, 3.0))
        models.append(dataclasses.replace(
            base, name=f"{base.name}-{i}", usage=base.usage * scale,
            entitlement=base.entitlement * scale,
            jobs=None if base.jobs is None else base.jobs * scale))
    return from_models(models, caiso_2021(hours).mci)


# ---------------------------------------------------------------------------
# Vectorized penalties
# ---------------------------------------------------------------------------
def _features(d: Array, usage: Array, jobs: Array, use_kernel: bool) -> Array:
    """(W, 4): wait_jobs, wait_power, wait_sq, njobs — Table IV."""
    if use_kernel:
        from repro.kernels.dr_features.ops import dr_features
        return dr_features(d, usage, jobs)
    rate = jobs * d / usage
    wait_jobs = jnp.maximum(jnp.cumsum(rate, axis=1), 0).sum(1)
    wait_power = jnp.maximum(jnp.cumsum(d, axis=1), 0).sum(1)
    rate_sq = jobs * d * jnp.abs(d) / usage
    wait_sq = jnp.maximum(jnp.cumsum(rate_sq, axis=1), 0).sum(1)
    njobs = (jobs * jnp.maximum(d, 0) / usage).sum(1)
    return jnp.stack([wait_jobs, wait_power, wait_sq, njobs], axis=1)


def fleet_penalties(p: FleetProblem, D: Array,
                    use_kernel: bool = False) -> Array:
    """(W,) calibrated penalties — fully vectorized."""
    usage = jnp.asarray(p.usage)
    delta = D / usage
    a3, a2, a1 = (jnp.asarray(p.rts_coeffs[:, i])[:, None] for i in range(3))
    f_rts = (a3 * delta**3 + a2 * delta**2 + a1 * delta).sum(axis=1)
    X = _features(D, usage, jnp.asarray(p.jobs), use_kernel)
    x1 = X[:, 1]
    x2 = jnp.where(jnp.asarray(p.x2_kind) > 0.5, X[:, 2], X[:, 3])
    b = jnp.asarray(p.betas)
    f_batch = jnp.maximum(b[:, 0] + b[:, 1] * x1 + b[:, 2] * x2, 0.0)
    raw = jnp.where(jnp.asarray(p.is_batch), f_batch, f_rts)
    return jnp.asarray(p.k) * raw


@dataclasses.dataclass(frozen=True)
class FleetSolveResult:
    D: np.ndarray
    carbon_reduction_pct: float
    total_penalty_pct: float
    iters: int
    preservation_violation: float


@functools.partial(jax.jit, static_argnames=("steps", "use_kernel", "lam",
                                             "day_hours"))
def _solve_cr1(usage, lo, hi, mci, is_batch_f, k, rts, betas, x2k, jobs,
               ent_sum, carbon_base, lam: float, steps: int,
               use_kernel: bool, day_hours: int = 24):
    W, T = usage.shape
    n_days = T // day_hours

    p_like = FleetProblem(
        usage=usage, entitlement=jnp.zeros(W), k=k, rts_coeffs=rts,
        betas=betas, x2_kind=x2k, jobs=jobs,
        is_batch=is_batch_f > 0.5, mci=mci)

    def penalties(D):
        return fleet_penalties(p_like, D, use_kernel)

    pen_norm = 100.0 / ent_sum
    car_norm = 100.0 / carbon_base

    def objective(D):
        return (lam * pen_norm * penalties(D).sum()
                - car_norm * (D @ mci).sum())

    grad = jax.grad(objective)

    def project(D):
        D = jnp.clip(D, lo, hi)
        for _ in range(3):
            Dd = D.reshape(W, n_days, day_hours)
            mean = Dd.mean(axis=-1, keepdims=True)
            Dd = jnp.where(is_batch_f[:, None, None] > 0.5, Dd - mean, Dd)
            D = jnp.clip(Dd.reshape(W, T), lo, hi)
        return D

    scale = jnp.maximum(hi - lo, 1e-6).mean()

    def body(c, _):
        D, m, v, t = c
        g = grad(D)
        t = t + 1
        m = 0.9 * m + 0.1 * g
        v = 0.999 * v + 0.001 * g * g
        mhat = m / (1 - 0.9 ** t)
        vhat = v / (1 - 0.999 ** t)
        D = project(D - 0.05 * scale * mhat / (jnp.sqrt(vhat) + 1e-8))
        return (D, m, v, t), None

    D0 = jnp.zeros((W, T))
    (D, _, _, _), _ = jax.lax.scan(
        body, (D0, jnp.zeros_like(D0), jnp.zeros_like(D0), 0), None,
        length=steps)
    return D, penalties(D)


def solve_cr1_fleet(p: FleetProblem, lam: float = 1.45, steps: int = 600,
                    use_kernel: bool = False) -> FleetSolveResult:
    usage = jnp.asarray(p.usage)
    E = p.entitlement[:, None]
    hi = jnp.asarray(np.minimum(p.max_curtail_frac * E, p.usage))
    lo = jnp.asarray(np.where(p.is_batch[:, None], -(E - p.usage), 0.0))
    carbon_base = float((p.usage.sum(0) * p.mci).sum())
    D, pens = _solve_cr1(usage, lo, hi, jnp.asarray(p.mci),
                         jnp.asarray(p.is_batch, jnp.float32),
                         jnp.asarray(p.k), jnp.asarray(p.rts_coeffs),
                         jnp.asarray(p.betas), jnp.asarray(p.x2_kind),
                         jnp.asarray(p.jobs), float(p.entitlement.sum()),
                         carbon_base, lam, steps, use_kernel, p.day_hours)
    D = np.asarray(D)
    car = float((D @ p.mci).sum())
    n_days = p.T // p.day_hours
    sums = D.reshape(p.W, n_days, p.day_hours).sum(-1)
    viol = float(np.abs(sums[p.is_batch]).max()) if p.is_batch.any() else 0.0
    return FleetSolveResult(
        D=D, carbon_reduction_pct=100 * car / carbon_base,
        total_penalty_pct=100 * float(np.asarray(pens).sum())
        / float(p.entitlement.sum()),
        iters=steps, preservation_violation=viol)


# ---------------------------------------------------------------------------
# CR2 at fleet scale — fair-centralized with per-workload penalty targets
# ---------------------------------------------------------------------------
def cr2_reference_fleet(p: FleetProblem, cap_frac: float) -> np.ndarray:
    """C_i under a hypothetical equal power cap at cap_frac·E (vectorized
    version of policies.cr2_reference_losses)."""
    L = cap_frac * p.entitlement[:, None]
    d_cap = np.maximum(p.usage - L, 0.0)
    return np.asarray(fleet_penalties(p, jnp.asarray(d_cap)))


def solve_cr2_fleet(p: FleetProblem, cap_frac: float = 0.78,
                    steps: int = 400, outer: int = 6,
                    use_kernel: bool = False) -> FleetSolveResult:
    """min −carbon s.t. C_i(d_i) = C_i(cap%) ∀i — augmented Lagrangian with
    one multiplier per workload, everything vectorized over the fleet."""
    refs = jnp.asarray(cr2_reference_fleet(p, cap_frac))
    scale = jnp.maximum(refs.mean(), 1e-3)
    usage = jnp.asarray(p.usage)
    E = p.entitlement[:, None]
    hi = jnp.asarray(np.minimum(p.max_curtail_frac * E, p.usage))
    lo = jnp.asarray(np.where(p.is_batch[:, None], -(E - p.usage), 0.0))
    carbon_base = float((p.usage.sum(0) * p.mci).sum())
    mci = jnp.asarray(p.mci)
    is_batch_f = jnp.asarray(p.is_batch, jnp.float32)
    W, T = p.W, p.T
    n_days = T // p.day_hours
    car_norm = 100.0 / carbon_base

    def penalties(D):
        return fleet_penalties(p, D, use_kernel)

    def project(D):
        D = jnp.clip(D, lo, hi)
        for _ in range(3):
            Dd = D.reshape(W, n_days, p.day_hours)
            mean = Dd.mean(axis=-1, keepdims=True)
            Dd = jnp.where(is_batch_f[:, None, None] > 0.5, Dd - mean, Dd)
            D = jnp.clip(Dd.reshape(W, T), lo, hi)
        return D

    step_scale = float(np.maximum(np.asarray(hi - lo), 1e-6).mean())

    @jax.jit
    def run(D0):
        def lagrangian(D, lam, mu):
            h = (penalties(D) - refs) / scale
            return (-car_norm * (D @ mci).sum() + lam @ h
                    + 0.5 * mu * (h @ h))

        grad = jax.grad(lagrangian)

        def outer_body(carry, _):
            D, lam, mu = carry

            def inner(c, _):
                D, m, v, t = c
                g = grad(D, lam, mu)
                t = t + 1
                m = 0.9 * m + 0.1 * g
                v = 0.999 * v + 0.001 * g * g
                D = project(D - 0.05 * step_scale
                            * (m / (1 - 0.9 ** t))
                            / (jnp.sqrt(v / (1 - 0.999 ** t)) + 1e-8))
                return (D, m, v, t), None

            (D, _, _, _), _ = jax.lax.scan(
                inner, (D, jnp.zeros_like(D), jnp.zeros_like(D), 0), None,
                length=steps)
            lam = lam + mu * (penalties(D) - refs) / scale
            return (D, lam, mu * 2.0), None

        (D, lam, _), _ = jax.lax.scan(
            outer_body, (D0, jnp.zeros((W,)), jnp.asarray(10.0)), None,
            length=outer)
        return D

    D = np.asarray(run(project(jnp.zeros((W, T)))))
    car = float((D @ p.mci).sum())
    pens = np.asarray(fleet_penalties(p, jnp.asarray(D)))
    sums = D.reshape(W, n_days, p.day_hours).sum(-1)
    viol = float(np.abs(sums[p.is_batch]).max()) if p.is_batch.any() else 0.0
    return FleetSolveResult(
        D=D, carbon_reduction_pct=100 * car / carbon_base,
        total_penalty_pct=100 * float(pens.sum()) / float(p.entitlement.sum()),
        iters=steps * outer, preservation_violation=viol)
