"""Baseline DR policies (paper §V-B): B1–B4, adapted from prior work.

B1 — Proportional Power Capping  [eBuff-style, simple]  (closed form)
B2 — Performant Power Capping    [eBuff]                (optimization)
B3 — Prioritized Power Capping   [Dynamo]               (closed form)
B4 — Load Shaping                [Google CAC]           (optimization)
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.policies import DRProblem, PolicySpec, _capacity_ineq

Array = jax.Array


# ---------------------------------------------------------------------------
# B1 — Proportional Power Capping (Eq. 9): L_i = F·E_i, d = max(U − L, 0).
# Analyzed WITHOUT batch preservation (§VI-C — with it, a capping-only policy
# cannot adjust at all: the yellow-star point).
# ---------------------------------------------------------------------------
def b1_adjustments(p: DRProblem, F: float) -> np.ndarray:
    L = F * p.entitlements[:, None]
    return np.maximum(p.usage - L, 0.0)


# ---------------------------------------------------------------------------
# B2 — Performant Power Capping: min λC(D) + peak(D), capping only (d ≥ 0).
# Batch preservation + d ≥ 0 together freeze batch rows, so B2 ends up
# capping only real-time workloads (matches §VI-D's finding).
# ---------------------------------------------------------------------------
def b2_spec(p: DRProblem, lam: float) -> PolicySpec:
    pen_norm = 100.0 / float(p.entitlements.sum())
    peak_norm = 100.0 / float(p.usage.sum(axis=0).max())

    def obj(D: Array) -> Array:
        return (lam * pen_norm * p.total_penalty(D)
                + peak_norm * p.soft_peak(D))

    lower = np.zeros_like(p.usage)  # capping: no boosts
    return PolicySpec(name=f"B2(λ={lam:g})", problem=p, objective=obj,
                      ineq_constraints=(_capacity_ineq(p),), lower=lower)


# ---------------------------------------------------------------------------
# B3 — Prioritized Power Capping (Dynamo): curtail RTS only, lowest priority
# first, each up to a maximum cut depth.
# ---------------------------------------------------------------------------
def b3_adjustments(p: DRProblem, depth: float, max_cut: float = 0.2,
                   priority: Sequence[str] | None = None) -> np.ndarray:
    """`depth` ∈ [0, n_rts·max_cut]: aggregate cut progression. The lowest
    priority RTS workload is capped first (cap L = (1−c)·E, Eq. 9), up to
    `max_cut`, then the next."""
    if priority is None:  # highest → lowest priority
        priority = [m.name for m in p.models if m.kind == "realtime"]
    order = list(reversed(priority))  # curtail lowest priority first
    D = np.zeros_like(p.usage)
    remaining = depth
    for name in order:
        if remaining <= 0:
            break
        i = p.names.index(name)
        c = min(remaining, max_cut)
        L = (1.0 - c) * p.entitlements[i]
        D[i] = np.maximum(p.usage[i] - L, 0.0)
        remaining -= c
    return D


# ---------------------------------------------------------------------------
# B4 — Load Shaping (Google): protect RTS, shift batch only, keep SLOs.
# min CF(D) + λ·peak(D)  s.t. batch SLOs (C_i ≈ 0 for SLO'd batch).
# ---------------------------------------------------------------------------
def b4_spec(p: DRProblem, lam: float, slo_eps: float = 1e-2) -> PolicySpec:
    free = np.asarray([m.kind != "realtime" for m in p.models])
    car_norm = 100.0 / p.total_carbon_baseline
    peak_norm = 100.0 / float(p.usage.sum(axis=0).max())

    def obj(D: Array) -> Array:
        return (-car_norm * p.carbon_reduction(D)
                + lam * peak_norm * p.soft_peak(D))

    ineqs = [_capacity_ineq(p)]
    for i, m in enumerate(p.models):
        if m.kind == "batch_slo":
            # SLO guard: penalty stays within slo_eps of zero.
            def g(D: Array, i=i) -> Array:
                return slo_eps * p.entitlements[i] - p.penalties(D)[i]
            ineqs.append(g)

    return PolicySpec(name=f"B4(λ={lam:g})", problem=p, objective=obj,
                      ineq_constraints=tuple(ineqs), free=free)
