"""FleetCoordinator: Carbon Responder as a first-class framework feature.

Maps LM jobs (arch × shape × chips) onto CR workloads, solves a DR policy
against the grid's carbon signal, and emits per-job hourly *throttle
schedules* that the training/serving drivers enforce (steps-per-hour budgets
/ admission control) — the datacenter-workload interface of Fig. 2/3.

Workload typing (paper §III-B):
  train  -> "batch without SLOs" (AI-training penalty family)
  serve  -> "real-time" (Dynamo latency polynomials)
  data   -> "batch with SLOs" (pipeline penalty family)
"""
from __future__ import annotations

import dataclasses
from typing import Literal, Sequence

import numpy as np

from repro.core import penalty as pen
from repro.core.carbon import CarbonSignal, ForecastStream
from repro.core.policies import DRProblem, cr1_spec, cr2_spec
from repro.core.solver import SolveResult, solve_adam, solve_slsqp
from repro.power.model import JobPowerModel

Role = Literal["train", "serve", "data"]


@dataclasses.dataclass(frozen=True)
class FleetJob:
    name: str
    role: Role
    power: JobPowerModel
    # usage ripple: serving follows diurnal traffic, training is flat.
    diurnal_amplitude: float | None = None


@dataclasses.dataclass
class ThrottleSchedule:
    """Hourly throughput multipliers for one job."""
    name: str
    throttle: np.ndarray          # (T,) in (0, 1]
    power_cut_np: np.ndarray      # (T,) NP shed

    def at_hour(self, t: int) -> float:
        return float(self.throttle[t % len(self.throttle)])


def _usage_trace(job: FleetJob, hours: int) -> np.ndarray:
    base = job.power.power_np
    amp = job.diurnal_amplitude
    if amp is None:
        amp = 0.05 if job.role == "serve" else 0.01
    t = np.arange(hours)
    return base * (1.0 + amp * np.sin(2 * np.pi * (t - 15) / 24.0))


def _penalty_model(job: FleetJob, hours: int,
                   templates: dict[str, pen.PenaltyModel],
                   ) -> pen.PenaltyModel:
    usage = _usage_trace(job, hours)
    # Entitlement headroom above peak draw scales with the job's *static*
    # power share: a mostly-static job (low dynamic_fraction) cannot shed
    # load on request, so its NP contract books the full 15% cushion; a
    # fully dynamic job can ride out grid events by throttling and books
    # half that.
    headroom = 1.0 / max(job.power.dynamic_fraction, 0.5)
    entitlement = float(usage.max() * (1.0 + 0.075 * headroom))
    if job.role == "serve":
        base = templates["RTS1"]
        return dataclasses.replace(base, name=job.name, usage=usage,
                                   entitlement=entitlement)
    key = "AITraining" if job.role == "train" else "DataPipeline"
    base = templates[key]
    scale = usage.mean() / max(base.usage.mean(), 1e-9)
    jobs_per_hour = (base.jobs if base.jobs is not None
                     else np.ones(hours)) * scale
    return dataclasses.replace(base, name=job.name, usage=usage,
                               entitlement=entitlement,
                               jobs=jobs_per_hour[:hours])


class FleetCoordinator:
    """Coordinates the fleet's DR plan under one policy.

    `policy` is a `repro.core.api.DRPolicy` object (`CR1(lam=...)`, ...)
    or a `POLICY_REGISTRY` name; with a name, the legacy `lam`/`cap_frac`
    knobs configure the policy object (`api.configured_policy`).
    Unregistered names fall back to CR1 (the historical behavior of
    `plan_streaming`)."""

    def __init__(self, jobs: Sequence[FleetJob], signal: CarbonSignal,
                 policy="cr1", lam: float = 1.45,
                 cap_frac: float = 0.78, solver: str = "auto"):
        self.jobs = list(jobs)
        self.signal = signal
        self.policy = policy
        self.lam = lam
        self.cap_frac = cap_frac
        self.solver = solver

    def _policy_obj(self):
        """The configured policy as a first-class `DRPolicy` value."""
        from repro.core.api import CR1, POLICY_REGISTRY, configured_policy
        if isinstance(self.policy, str) and self.policy not in POLICY_REGISTRY:
            return CR1(lam=self.lam)     # legacy unregistered-name fallback
        return configured_policy(self.policy, lam=self.lam,
                                 cap_frac=self.cap_frac)

    def _models(self, hours: int) -> tuple[pen.PenaltyModel, ...]:
        from repro.core.fleetcache import cached_paper_fleet
        templates = cached_paper_fleet(hours=hours)
        return tuple(_penalty_model(j, hours, templates)
                     for j in self.jobs)

    def _dynamic_cap(self, usage: np.ndarray) -> np.ndarray:
        """(W, T) realizable curtailment cap: a job can only shed its
        *dynamic* power by throttling — cuts past that saturate at the
        idle floor (throttle 0, i.e. killing the job for the hour)."""
        dyn = np.asarray([j.power.dynamic_fraction for j in self.jobs])
        return 0.95 * dyn[:, None] * np.asarray(usage)

    @staticmethod
    def _schedule(job: FleetJob, cuts: np.ndarray,
                  usage: np.ndarray) -> ThrottleSchedule:
        """Hourly throttles enforcing `cuts` (NP) against `usage` (NP)."""
        cut_frac = np.clip(np.asarray(cuts) / np.maximum(usage, 1e-9),
                           -1, 1)
        throttle = np.asarray(
            [job.power.throttle_for_power_cut(max(c, 0.0))
             for c in cut_frac])
        return ThrottleSchedule(name=job.name, throttle=throttle,
                                power_cut_np=np.asarray(cuts))

    def plan(self) -> tuple[dict[str, ThrottleSchedule], SolveResult]:
        """Solve the DR problem and emit per-job throttle schedules."""
        hours = self.signal.hours
        models = self._models(hours)
        problem = DRProblem(models=models, mci=self.signal.mci)
        # Tighten the box to the realizable (dynamic-range) cap; CR2's
        # fairness targets are computed under the same tightened box so its
        # penalty-equality constraints remain attainable.
        upper = np.minimum(problem.bounds()[1],
                           self._dynamic_cap(problem.usage))
        pol = self._policy_obj()
        spec = (cr2_spec(problem, pol.cap_frac, upper=upper)
                if pol.name == "cr2"
                else dataclasses.replace(
                    cr1_spec(problem, getattr(pol, "lam", self.lam)),
                    upper=upper))
        use_slsqp = (self.solver == "slsqp"
                     or (self.solver == "auto" and len(self.jobs) <= 8))
        result = (solve_slsqp(spec) if use_slsqp else solve_adam(spec))
        schedules = {
            job.name: self._schedule(job, result.D[i], problem.usage[i])
            for i, job in enumerate(self.jobs)}
        return schedules, result

    def plan_streaming(self, n_ticks: int = 24,
                       stream: ForecastStream | None = None,
                       revision_sigma: float = 0.03, seed: int = 0,
                       cold_steps: int = 600, warm_steps: int = 150):
        """Online operation: rolling-horizon re-solves as forecasts revise.

        Instead of one day-ahead plan, run `n_ticks` hourly re-solves
        (warm-started — see `repro.core.streaming`), committing one hour
        each tick. Returns `(schedules, report)`: per-job throttle
        schedules covering the `n_ticks` *committed* hours, and the
        `StreamingReport` with realized-vs-forecast carbon accounting.

        `stream` defaults to a revision-model stream whose realized series
        periodically extends this coordinator's carbon signal. As in
        `plan`, the solve box is tightened to each job's realizable
        dynamic-power range (`FleetProblem.upper`), so committed cuts are
        deliverable and the carbon ledger is honest."""
        from repro.core.fleet_solver import from_models
        from repro.core.streaming import RollingHorizonSolver
        hours = self.signal.hours
        fp = from_models(self._models(hours), self.signal.mci)
        fp = dataclasses.replace(fp, upper=self._dynamic_cap(fp.usage))
        if stream is None:
            stream = ForecastStream(
                actual=np.resize(self.signal.mci, n_ticks + hours),
                horizon=hours, revision_sigma=revision_sigma, seed=seed)
        solver = RollingHorizonSolver(
            fp, stream, policy=self._policy_obj(), cold_steps=cold_steps,
            warm_steps=warm_steps)
        report = solver.run(n_ticks)
        usage = np.asarray(fp.usage)
        ticks = np.arange(n_ticks) % hours
        schedules = {
            job.name: self._schedule(job, report.committed[i],
                                     usage[i, ticks])
            for i, job in enumerate(self.jobs)}
        return schedules, report
