"""FleetCoordinator: Carbon Responder as a first-class framework feature.

Maps LM jobs (arch × shape × chips) onto CR workloads, solves a DR policy
against the grid's carbon signal, and emits per-job hourly *throttle
schedules* that the training/serving drivers enforce (steps-per-hour budgets
/ admission control) — the datacenter-workload interface of Fig. 2/3.

Workload typing (paper §III-B):
  train  -> "batch without SLOs" (AI-training penalty family)
  serve  -> "real-time" (Dynamo latency polynomials)
  data   -> "batch with SLOs" (pipeline penalty family)
"""
from __future__ import annotations

import dataclasses
from typing import Literal, Sequence

import numpy as np

from repro.core import penalty as pen
from repro.core.carbon import CarbonSignal
from repro.core.policies import DRProblem, cr1_spec, cr2_spec
from repro.core.solver import SolveResult, solve_adam, solve_slsqp
from repro.power.model import JobPowerModel

Role = Literal["train", "serve", "data"]


@dataclasses.dataclass(frozen=True)
class FleetJob:
    name: str
    role: Role
    power: JobPowerModel
    # usage ripple: serving follows diurnal traffic, training is flat.
    diurnal_amplitude: float | None = None


@dataclasses.dataclass
class ThrottleSchedule:
    """Hourly throughput multipliers for one job."""
    name: str
    throttle: np.ndarray          # (T,) in (0, 1]
    power_cut_np: np.ndarray      # (T,) NP shed

    def at_hour(self, t: int) -> float:
        return float(self.throttle[t % len(self.throttle)])


def _usage_trace(job: FleetJob, hours: int) -> np.ndarray:
    base = job.power.power_np
    amp = job.diurnal_amplitude
    if amp is None:
        amp = 0.05 if job.role == "serve" else 0.01
    t = np.arange(hours)
    return base * (1.0 + amp * np.sin(2 * np.pi * (t - 15) / 24.0))


def _penalty_model(job: FleetJob, hours: int,
                   templates: dict[str, pen.PenaltyModel],
                   ) -> pen.PenaltyModel:
    usage = _usage_trace(job, hours)
    headroom = 1.0 / max(job.power.dynamic_fraction + (1.0 - 1.0), 0.5)
    entitlement = float(usage.max() * 1.15)
    if job.role == "serve":
        base = templates["RTS1"]
        return dataclasses.replace(base, name=job.name, usage=usage,
                                   entitlement=entitlement)
    key = "AITraining" if job.role == "train" else "DataPipeline"
    base = templates[key]
    scale = usage.mean() / max(base.usage.mean(), 1e-9)
    jobs_per_hour = (base.jobs if base.jobs is not None
                     else np.ones(hours)) * scale
    return dataclasses.replace(base, name=job.name, usage=usage,
                               entitlement=entitlement,
                               jobs=jobs_per_hour[:hours])


class FleetCoordinator:
    def __init__(self, jobs: Sequence[FleetJob], signal: CarbonSignal,
                 policy: str = "cr1", lam: float = 1.45,
                 cap_frac: float = 0.78, solver: str = "auto"):
        self.jobs = list(jobs)
        self.signal = signal
        self.policy = policy
        self.lam = lam
        self.cap_frac = cap_frac
        self.solver = solver

    def plan(self) -> tuple[dict[str, ThrottleSchedule], SolveResult]:
        """Solve the DR problem and emit per-job throttle schedules."""
        hours = self.signal.hours
        from repro.core.fleetcache import cached_paper_fleet
        templates = cached_paper_fleet(hours=hours)
        models = tuple(_penalty_model(j, hours, templates)
                       for j in self.jobs)
        problem = DRProblem(models=models, mci=self.signal.mci)
        # A job can only shed its *dynamic* power by throttling — cuts past
        # that saturate at the idle floor (throttle 0, i.e. killing the job
        # for the hour). Tighten the box so plans stay realizable; CR2's
        # fairness targets are computed under the same tightened box so its
        # penalty-equality constraints remain attainable.
        dyn = np.asarray([j.power.dynamic_fraction for j in self.jobs])
        upper = np.minimum(problem.bounds()[1],
                           0.95 * dyn[:, None] * problem.usage)
        spec = (cr2_spec(problem, self.cap_frac, upper=upper)
                if self.policy == "cr2"
                else dataclasses.replace(cr1_spec(problem, self.lam),
                                         upper=upper))
        use_slsqp = (self.solver == "slsqp"
                     or (self.solver == "auto" and len(self.jobs) <= 8))
        result = (solve_slsqp(spec) if use_slsqp else solve_adam(spec))
        schedules: dict[str, ThrottleSchedule] = {}
        for i, job in enumerate(self.jobs):
            usage = problem.usage[i]
            cut_frac = np.clip(result.D[i] / np.maximum(usage, 1e-9), -1, 1)
            throttle = np.asarray(
                [job.power.throttle_for_power_cut(max(c, 0.0))
                 for c in cut_frac])
            schedules[job.name] = ThrottleSchedule(
                name=job.name, throttle=throttle, power_cut_np=result.D[i])
        return schedules, result
