"""Grid carbon-intensity signals (paper §I, Fig. 1; §VI-F, Fig. 11).

The paper uses WattTime marginal carbon intensity (MCI) for CAISO 2021 and
NREL Cambium scenario projections for 2024/2050. Those datasets are not
redistributable, so this module synthesizes signals with the *published*
shape statistics:

  - CAISO 2021: diurnal "duck curve" — midday solar trough at ~66% of the
    evening peak (paper: "the trough can be as low as 66% of the peak in
    today's grid").
  - 2050 projection: trough at ~40% of peak (paper: "as low as 40% of the
    peak by 2050"), with some states reaching zero-MCI periods.

All series are hourly, in kg CO2 / MWh, deterministic given a seed.

For *online* operation, `ForecastStream` turns any realized series into a
sequence of revised day-ahead forecasts (persistence + lead-time noise, or
replayed snapshots) — the input signal of the rolling-horizon solver in
`repro.core.streaming`.
"""
from __future__ import annotations

import dataclasses
import zlib
from typing import Sequence

import numpy as np

# Published anchor values (approximate CAISO 2021 marginal intensity range).
CAISO_2021_PEAK = 450.0   # kg CO2/MWh, evening ramp (gas at the margin)
CAISO_2021_TROUGH_FRAC = 0.66
PROJ_2024_TROUGH_FRAC = 0.55
PROJ_2050_TROUGH_FRAC = 0.40

#: US states used for the Fig.-11 style projection sweep (subset is fine —
#: the paper plots "all states"; we model the ones with distinct profiles).
STATES = (
    "CA", "TX", "WA", "AZ", "NV", "NM", "CO", "OR", "UT", "FL",
    "NY", "NC", "GA", "IL", "OH", "PA", "VA", "MA", "MN", "IA",
)


@dataclasses.dataclass(frozen=True)
class CarbonSignal:
    """An hourly marginal-carbon-intensity series.

    Attributes:
      mci: (hours,) kg CO2/MWh marginal carbon intensity.
      label: provenance string.
    """

    mci: np.ndarray
    label: str

    @property
    def hours(self) -> int:
        return int(self.mci.shape[0])

    def peak_to_trough(self) -> float:
        return float(self.mci.min() / self.mci.max())


def _duck_curve(hours: int, peak: float, trough_frac: float,
                solar_center: float = 13.0, solar_width: float = 4.5,
                evening_bump: float = 0.18,
                seed: int | tuple[int, ...] = 0,
                noise: float = 0.02) -> np.ndarray:
    """Synthesize a duck-curve MCI: solar depresses midday marginal intensity,
    evening ramp brings gas peakers to the margin."""
    rng = np.random.default_rng(seed)
    t = np.arange(hours)
    hour_of_day = t % 24
    # Solar depression: gaussian centered early afternoon.
    solar = np.exp(-0.5 * ((hour_of_day - solar_center) / solar_width) ** 2)
    # Evening ramp bump (gas peakers) ~19:00.
    evening = np.exp(-0.5 * ((hour_of_day - 19.0) / 2.0) ** 2)
    base = 1.0 - (1.0 - trough_frac) * solar + evening_bump * evening
    base = base / base.max()
    series = peak * base
    series = series * (1.0 + noise * rng.standard_normal(hours))
    return np.clip(series, 0.0, None)


def caiso_2021(hours: int = 48, seed: int = 0) -> CarbonSignal:
    """CAISO-2021-shaped MCI (paper Fig. 1 'Today'). Two-day default window
    matching the paper's evaluation interval (§VI-A)."""
    mci = _duck_curve(hours, CAISO_2021_PEAK, CAISO_2021_TROUGH_FRAC, seed=seed)
    return CarbonSignal(mci=mci, label="caiso-2021-synthetic")


def projection(year: int, state: str = "CA", hours: int = 48,
               seed: int = 0) -> CarbonSignal:
    """Cambium-style scenario MCI for `year` in {2024, 2050} (paper Fig. 11).

    Per-state variation: solar-heavy states get deeper troughs (some reach
    zero MCI by 2050, per the AEO-2023 analysis cited in the paper).

    Deterministic per (seed, year, state): the rng is tuple-seeded
    `default_rng((seed, year, state_idx))` — additive `seed + idx` seeding
    collided distinct (seed, state) pairs (e.g. seed=8/"NY" and
    seed=1/"MA") onto one stream, so scenario sweeps over states silently
    reused noise realizations. States outside `STATES` hash with crc32
    (stable across processes, unlike `hash()`) into an index range
    disjoint from the listed states'.
    """
    if year not in (2024, 2050):
        raise ValueError(f"unsupported projection year {year}")
    idx = STATES.index(state) if state in STATES \
        else len(STATES) + zlib.crc32(state.encode("utf-8"))
    rng = np.random.default_rng((seed, year, idx))
    # State-specific solar penetration in [0, 1]; CA/AZ/NV/NM highest.
    solar_rank = {"CA": .95, "AZ": .92, "NV": .9, "NM": .88, "TX": .8,
                  "UT": .75, "CO": .7, "FL": .68, "GA": .55, "NC": .5}
    pen = solar_rank.get(state, float(rng.uniform(0.3, 0.6)))
    if year == 2024:
        trough = 1.0 - (1.0 - PROJ_2024_TROUGH_FRAC) * pen
        peak = CAISO_2021_PEAK * 0.95
    else:
        trough = max(0.0, 1.0 - (1.0 - PROJ_2050_TROUGH_FRAC) * pen * 1.55)
        peak = CAISO_2021_PEAK * 0.85
    mci = _duck_curve(hours, peak, trough, solar_width=5.0,
                      seed=(seed, year, idx, 1))
    return CarbonSignal(mci=mci, label=f"cambium-{year}-{state}-synthetic")


#: Standard-time UTC offsets for the Cambium states: a UTC-clocked fleet
#: coordinator sees each region's solar trough `-offset` hours after the
#: local-time trace places it.
STATE_UTC_OFFSETS = {"CA": -8, "OR": -8, "WA": -8, "NV": -8,
                     "AZ": -7, "NM": -7, "UT": -7, "CO": -7,
                     "TX": -6, "MN": -6, "IA": -6, "IL": -6,
                     "NY": -5, "FL": -5, "NC": -5, "GA": -5,
                     "OH": -5, "PA": -5, "VA": -5, "MA": -5}


def regional_traces(states: Sequence[str], year: int = 2050,
                    hours: int = 48, seed: int = 0,
                    utc_offsets=None,
                    ) -> tuple[np.ndarray, tuple[str, ...]]:
    """(R, T) per-region MCI stack for a multi-region `FleetProblem`.

    One Cambium-style `projection` trace per state, stacked in order —
    the `mci` input of `fleet_solver.regional_fleet`. Depth decorrelation
    across regions comes free: each state's solar penetration and noise
    stream differ, so troughs land at different depths (CA near zero by
    2050, NY much flatter). *Timing* decorrelation comes from
    `utc_offsets`: projection traces are local-time, but a fleet
    coordinator schedules on one UTC clock, so pass `"auto"` (the
    `STATE_UTC_OFFSETS` table) or one offset per state to roll each
    trace onto UTC — CA's trough then lags NY's by three hours, which is
    what lets per-region pricing and migration beat any single shared
    signal. `None` (default) keeps the local-time alignment. Returns
    (mcis, labels).
    """
    if not states:
        raise ValueError("states must name at least one region")
    sigs = [projection(year, state=s, hours=hours, seed=seed)
            for s in states]
    mcis = np.stack([s.mci for s in sigs])
    if utc_offsets is not None:
        if isinstance(utc_offsets, str):
            if utc_offsets != "auto":
                raise ValueError(
                    f"utc_offsets must be 'auto', a sequence of "
                    f"{len(states)} ints, or None; got {utc_offsets!r}")
            utc_offsets = [STATE_UTC_OFFSETS.get(s, 0) for s in states]
        if len(utc_offsets) != len(states):
            raise ValueError(
                f"need one UTC offset per state ({len(states)}); got "
                f"{len(utc_offsets)}")
        # local hour h lands at UTC hour h - offset (offsets are negative
        # west of Greenwich), so roll each trace right by -offset
        mcis = np.stack([np.roll(m, -int(off))
                         for m, off in zip(mcis, utc_offsets)])
    return mcis, tuple(s.label for s in sigs)


# ---------------------------------------------------------------------------
# Grid-event hooks (scenario-ensemble building blocks, `repro.core.scenario`)
#
# Deterministic transforms of an hourly MCI series, each modelling one grid
# event the paper's single CAISO-2021 trace cannot express. Scenario
# generators randomize the event parameters (tuple-seeded rngs) and stack S
# transformed series for the vmapped ensemble runner.
# ---------------------------------------------------------------------------
def apply_drought(mci: np.ndarray, day: int, n_days: int = 1,
                  severity: float = 0.7, day_hours: int = 24) -> np.ndarray:
    """Renewable-drought days: fill the midday solar trough back in.

    For `n_days` days starting at `day`, each hour's MCI is lifted toward
    that day's running peak by `severity` (1.0 = no solar at all, the
    trough disappears; 0.0 = no event). Models multi-day wind/solar
    droughts ("dunkelflaute") where gas stays at the margin all day.
    """
    out = np.asarray(mci, float).copy()
    for d in range(day, min(day + n_days, -(-out.shape[0] // day_hours))):
        sl = slice(d * day_hours, min((d + 1) * day_hours, out.shape[0]))
        peak = out[sl].max()
        out[sl] = out[sl] + severity * (peak - out[sl])
    return out


def apply_evening_spike(mci: np.ndarray, hour: int, magnitude: float = 1.4,
                        width: float = 2.0) -> np.ndarray:
    """Evening-ramp spike: multiply MCI by a gaussian bump centred at
    `hour` (absolute hour index), peaking at `magnitude`. Models a steeper
    ramp than forecast — peakers brought online hard."""
    t = np.arange(np.asarray(mci).shape[0], dtype=float)
    bump = 1.0 + (magnitude - 1.0) * np.exp(
        -0.5 * ((t - hour) / max(width, 1e-6)) ** 2)
    return np.asarray(mci, float) * bump


def apply_zero_window(mci: np.ndarray, start: int, length: int,
                      ) -> np.ndarray:
    """Zero-MCI window: clamp hours [start, start+length) to zero marginal
    intensity — curtailed renewables on the margin (the 2050
    deep-decarbonization grids of Fig. 11 reach this today in CAISO
    spring)."""
    out = np.asarray(mci, float).copy()
    out[max(start, 0):max(start, 0) + max(length, 0)] = 0.0
    return out


# ---------------------------------------------------------------------------
# Streaming forecasts (rolling-horizon operation, ROADMAP "Streaming MCI")
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class ForecastStream:
    """Revised MCI forecasts over a sliding horizon — the online DR signal.

    At tick `t` (one tick = one hour), `forecast(t)` returns the current
    `(horizon,)` day-ahead MCI estimate for hours `[t, t + horizon)`;
    `realized(t)` is the actual MCI of hour `t`, known only once it has
    elapsed. Two modes:

      * revision model (default): persistence + lead-time noise over the
        `actual` series — the hour about to be committed is known almost
        exactly, while hours `k` ahead carry multiplicative error growing
        as `revision_sigma * sqrt(k)` (forecast skill decays with lead
        time, the shape WattTime/Cambium day-ahead products exhibit).
        Deterministic given `seed`: re-asking for tick t re-issues the
        *same* revised forecast.
      * replay (`replay=(n_ticks, horizon)` array): serve pre-recorded
        forecast snapshots verbatim — for backtesting against logged
        forecast revisions.
    """

    actual: np.ndarray                 # (n_hours,) realized MCI
    horizon: int = 48                  # forecast window length T
    revision_sigma: float = 0.03       # per-sqrt-hour multiplicative error
    # Tuple seeds namespace one revision model across several streams
    # (`regional` issues (seed, r) per region); a plain int is the
    # single-stream case and keeps its exact historical noise draws.
    seed: int | tuple[int, ...] = 0
    replay: np.ndarray | None = None   # (n_ticks, horizon) snapshots

    def __post_init__(self):
        if self.replay is not None:
            r = np.asarray(self.replay)
            if r.ndim != 2 or r.shape[1] != self.horizon:
                raise ValueError(
                    f"replay must be (n_ticks, horizon={self.horizon}); "
                    f"got {r.shape}")

    @property
    def n_ticks(self) -> int:
        """Ticks for which a full horizon (and its realized hour) exist.

        Replay mode is clamped to `len(actual)`: a stream carrying more
        forecast snapshots than realized hours would otherwise let
        `forecast()` succeed on ticks whose `realized()` hour does not
        exist, crashing the control loop mid-run with an IndexError."""
        if self.replay is not None:
            return min(int(np.asarray(self.replay).shape[0]),
                       int(np.asarray(self.actual).shape[0]))
        return max(0, int(self.actual.shape[0]) - self.horizon + 1)

    def forecast(self, tick: int) -> np.ndarray:
        """(horizon,) MCI forecast issued at `tick` for [tick, tick+T)."""
        if not 0 <= tick < self.n_ticks:
            raise IndexError(f"tick {tick} out of range [0, {self.n_ticks})")
        if self.replay is not None:
            return np.asarray(self.replay[tick], dtype=float).copy()
        window = np.asarray(self.actual[tick:tick + self.horizon], float)
        key = (self.seed,) if isinstance(self.seed, int) \
            else tuple(self.seed)
        rng = np.random.default_rng(key + (tick,))
        # sqrt-lead error growth with a small nowcast floor: even the hour
        # being committed is a forecast, not a meter reading.
        lead = np.arange(self.horizon, dtype=float)
        err = (self.revision_sigma * np.sqrt(lead + 0.25)
               * rng.standard_normal(self.horizon))
        return np.clip(window * (1.0 + err), 0.0, None)

    def realized(self, tick: int) -> float:
        """Actual MCI of hour `tick` (available once the hour elapses)."""
        if not 0 <= tick < int(np.asarray(self.actual).shape[0]):
            raise IndexError(
                f"tick {tick} has no realized hour (actual covers "
                f"[0, {int(np.asarray(self.actual).shape[0])}))")
        return float(self.actual[tick])

    @classmethod
    def caiso(cls, n_ticks: int, horizon: int = 48,
              revision_sigma: float = 0.03, seed: int = 0,
              ) -> "ForecastStream":
        """Stream over a CAISO-2021-shaped actual series long enough for
        `n_ticks` rolling solves of `horizon` hours each."""
        sig = caiso_2021(hours=n_ticks + horizon, seed=seed)
        return cls(actual=sig.mci, horizon=horizon,
                   revision_sigma=revision_sigma, seed=seed)

    @classmethod
    def regional(cls, actuals: np.ndarray, horizon: int = 48,
                 revision_sigma: float = 0.03, seed: int = 0,
                 ) -> tuple["ForecastStream", ...]:
        """R streams over an (R, n_hours) actual stack, sharing ONE
        revision model: every stream carries the same sigma/horizon and a
        `(seed, r)` tuple seed off one base seed, instead of R
        copy-pasted configs whose int seeds can collide between regions.
        The input of a multi-region `RollingHorizonSolver`."""
        actuals = np.asarray(actuals, float)
        if actuals.ndim != 2:
            raise ValueError(f"actuals must be (R, n_hours); got "
                             f"{actuals.shape}")
        return tuple(
            cls(actual=actuals[r], horizon=horizon,
                revision_sigma=revision_sigma, seed=(seed, r))
            for r in range(actuals.shape[0]))


def carbon_footprint_delta(mci: np.ndarray, adjustments: np.ndarray) -> float:
    """Change in operational carbon from adjustment matrix D (paper §V).

    CF(D) = - <mci, sum_i d_i>  — positive d (curtailment) *reduces* carbon,
    so the change in footprint is negative. We return the (signed) footprint
    change; use `carbon_reduction` for the positive-is-better quantity.

    Args:
      mci: (T,) marginal carbon intensity.
      adjustments: (W, T) or (T,) power adjustments in NP (positive=curtail).
    """
    d = np.asarray(adjustments)
    total = d.sum(axis=0) if d.ndim == 2 else d
    return float(-(np.asarray(mci) * total).sum())


def carbon_reduction(mci: np.ndarray, adjustments: np.ndarray) -> float:
    """Operational carbon eliminated by D (positive is better)."""
    return -carbon_footprint_delta(mci, adjustments)
