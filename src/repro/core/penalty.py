"""Workload performance-penalty models (paper §IV).

Two families:

  * Real-time (RTS1/RTS2): cubic latency-degradation polynomials published in
    the paper (fit to Dynamo Fig. 13 profiles):
        f_RTS1(δ) = 6.3δ³ − 13δ² + 51.6δ
        f_RTS2(δ) = −4δ³ − 3.5δ² + 42.5δ
    with δ the power cut as a *fraction* of usage (the paper's Eq. 1 prints
    δ = d/(U×100) while §IV-A1 prints δ = d/U×100; the coefficients are only
    dimensionally sensible for δ ∈ [0, 1] — e.g. f_RTS1(0.2) ≈ 9.9 %% latency
    degradation, matching Dynamo's published curves — so we use the fraction
    and note the notational inconsistency here).

  * Batch (AI training / Data pipeline): Lasso-learned models over Table-IV
    features, trained against the EDD simulator:
        C_i(d) = ( k_i (β₀ + β₁ x₁ + β₂ x₂) )⁺

  Scaling weights k_i convert workload-specific performance loss into the
  datacenter-wide currency (equivalent NP capacity loss) by calibration:
  the penalty of a 15 %% capacity cap ≡ the entitlement lost (0.15·E_i).

Everything here is JAX-differentiable in d, so policies can optimize through
the models.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Mapping, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import features as feat
from repro.core.lasso import LassoFit, fit_lasso_cv
from repro.sched.edd import EDDScheduler, mixed_curtailments
from repro.sched.traces import JobTrace, ServiceTrace, make_job_trace

Array = jax.Array

# Published Dynamo-fit coefficients (paper Eq. 1): a3, a2, a1.
RTS_COEFFS = {
    "RTS1": (6.3, -13.0, 51.6),
    "RTS2": (-4.0, -3.5, 42.5),
}
CALIBRATION_CAP = 0.15  # §IV: "capping 15% capacity"


@dataclasses.dataclass(frozen=True)
class PenaltyModel:
    """A calibrated penalty model C_i(d) for one workload.

    Attributes:
      name: workload name.
      kind: "realtime" | "batch_slo" | "batch_noslo".
      usage: (T,) baseline hourly usage U_i (NP).
      entitlement: capacity entitlement E_i (NP).
      k: calibration weight (NP per unit of raw performance loss).
      params: model parameters — RTS: (a3, a2, a1); batch: (β0, β1, β2).
      jobs: (T,) hourly job counts (batch only; zeros for RTS).
      slo_hours: representative SLO lag for the tardiness feature.
      feature_names: which Table-IV features are x1, x2 (batch only).
    """

    name: str
    kind: str
    usage: np.ndarray
    entitlement: float
    k: float
    params: tuple[float, ...]
    jobs: np.ndarray | None = None
    slo_hours: int = 4
    feature_names: tuple[str, str] | None = None

    # ---- raw (uncalibrated) loss ------------------------------------------
    def raw_loss(self, d: Array, smooth: float = 0.0) -> Array:
        """Workload-specific performance loss (latency-%·hours for RTS;
        waiting/tardiness hours for batch). Differentiable in d."""
        if self.kind == "realtime":
            a3, a2, a1 = self.params
            delta = d / jnp.asarray(self.usage)
            f = a3 * delta**3 + a2 * delta**2 + a1 * delta
            return f.sum(axis=-1)
        b0, b1, b2 = self.params
        x = self._batch_features(d, smooth)
        return b0 + b1 * x[..., 0] + b2 * x[..., 1]

    def _batch_features(self, d: Array, smooth: float = 0.0) -> Array:
        assert self.feature_names is not None and self.jobs is not None
        fns = {
            "waiting_time_jobs": lambda: feat.waiting_time_jobs(
                d, jnp.asarray(self.usage), jnp.asarray(self.jobs), smooth),
            "waiting_time_power": lambda: feat.waiting_time_power(d, smooth),
            "waiting_time_squared": lambda: feat.waiting_time_squared(
                d, jnp.asarray(self.usage), jnp.asarray(self.jobs), smooth),
            "num_jobs_delayed": lambda: feat.num_jobs_delayed(
                d, jnp.asarray(self.usage), jnp.asarray(self.jobs), smooth),
            "total_tardiness": lambda: feat.total_tardiness(
                d, jnp.asarray(self.usage), jnp.asarray(self.jobs),
                self.slo_hours, smooth),
        }
        return jnp.stack([fns[n]() for n in self.feature_names], axis=-1)

    # ---- calibrated penalty (paper Eqs. 1 & 2) ----------------------------
    def penalty(self, d: Array, smooth: float = 0.0) -> Array:
        """C_i(d) in equivalent-NP-capacity units. Batch models take the
        positive part (Eq. 2); RTS is signed (boost improves service)."""
        raw = self.raw_loss(d, smooth)
        if self.kind == "realtime":
            return self.k * raw
        if smooth > 0.0:
            return smooth * jax.nn.softplus(self.k * raw / smooth)
        return jnp.maximum(self.k * raw, 0.0)

    def cap_curtailment(self, cap_frac: float) -> np.ndarray:
        """Curtailment vector from capping power at cap_frac·E (Eq. 9)."""
        # Capping at L = cap_frac·E cuts any usage above L.
        L = cap_frac * self.entitlement
        return np.maximum(self.usage - L, 0.0)

    def calibration_curtailment(self, cap: float = CALIBRATION_CAP
                                ) -> np.ndarray:
        """Uniform loss of `cap` of capacity — the k-calibration reference.

        Entitlements sit above usage (provisioning headroom), so an 85 % cap
        on E barely touches usage; the paper's "entitlement loss when capping
        15 % capacity" is the *capacity taken away*, i.e. d_t = 0.15·E
        (clipped to half of usage, the idle-power floor)."""
        d = np.full_like(self.usage, cap * self.entitlement)
        return np.minimum(d, 0.5 * self.usage)


def calibrate_k(raw_loss_at_cap: float, entitlement: float,
                cap: float = CALIBRATION_CAP) -> float:
    """k_i = capacity loss / performance loss at a (1-cap)·E power cap."""
    if raw_loss_at_cap <= 1e-12:
        return 0.0
    return (cap * entitlement) / raw_loss_at_cap


def build_rts_model(name: str, trace: ServiceTrace) -> PenaltyModel:
    """Penalty model for a real-time service from published coefficients."""
    coeffs = RTS_COEFFS[name if name in RTS_COEFFS else "RTS1"]
    model = PenaltyModel(name=name, kind="realtime", usage=trace.usage,
                         entitlement=trace.entitlement, k=1.0, params=coeffs)
    # Calibrate k against a uniform 15%-of-capacity loss.
    d_cap = model.calibration_curtailment()
    raw = float(model.raw_loss(jnp.asarray(d_cap)))
    k = calibrate_k(raw, trace.entitlement)
    return dataclasses.replace(model, k=k)


@dataclasses.dataclass(frozen=True)
class BatchTrainingData:
    """Simulator-generated supervised data for the Lasso fit."""

    X: np.ndarray          # (N, F) Table-IV features
    y: np.ndarray          # (N,) waiting time (no-SLO) or tardiness (SLO)
    baseline: float        # outcome with d = 0
    feature_names: tuple[str, ...]


def generate_batch_training_data(
        trace: ServiceTrace, jobs: JobTrace, num_samples: int,
        seed: int = 0) -> BatchTrainingData:
    """Run the EDD simulator under sampled curtailments (paper §IV-A2)."""
    # horizon_slack=4: limited free-drain after the window keeps tardiness
    # responsive to sustained curtailment (validated against Table V quality).
    sched = EDDScheduler(horizon_slack=4)
    T = trace.hours
    jobs_per_hour = jobs.jobs_per_hour(T)
    with_slo = trace.kind == "batch_slo"
    ds = mixed_curtailments(trace.usage, num_samples, seed=seed)
    base = sched.run(jobs, trace.usage)
    y0 = base.total_tardiness if with_slo else base.total_waiting
    names = tuple(n for n in feat.FEATURE_NAMES
                  if with_slo or n != "total_tardiness")
    X = np.zeros((num_samples, len(names)))
    y = np.zeros(num_samples)
    dj = jnp.asarray(ds)
    Xall = np.asarray(feat.feature_matrix(
        dj, jnp.asarray(trace.usage), jnp.asarray(jobs_per_hour),
        slo_hours=4, include_tardiness=with_slo))
    X = Xall
    for n in range(num_samples):
        res = sched.run(jobs, trace.usage - ds[n])
        out = res.total_tardiness if with_slo else res.total_waiting
        y[n] = out - y0
    return BatchTrainingData(X=X, y=y, baseline=y0, feature_names=names)


def build_batch_model(name: str, trace: ServiceTrace, jobs: JobTrace,
                      num_samples: int = 160, seed: int = 0,
                      use_published_selection: bool = True,
                      ) -> tuple[PenaltyModel, LassoFit, BatchTrainingData]:
    """Fit the Lasso penalty model for a batch service and calibrate k.

    Returns (model, fit, data). `use_published_selection` restricts the model
    to the paper's published (x1, x2) pair after the full-Lasso fit — the
    full fit is still reported (Table V benchmark checks its CV quality).
    """
    data = generate_batch_training_data(trace, jobs, num_samples, seed)
    fit = fit_lasso_cv(data.X, data.y, seed=seed)
    key = "DataPipeline" if trace.kind == "batch_slo" else "AITraining"
    if use_published_selection:
        sel_names = feat.SELECTED[key]
    else:
        sel = fit.selected[:2] if len(fit.selected) >= 2 else (0, 1)
        sel_names = tuple(data.feature_names[i] for i in sel)
    # Refit OLS-style on the two selected features for the deploy model
    # (paper's Eq. 2 has exactly β0, β1, β2).
    idx = [data.feature_names.index(n) for n in sel_names]
    X2 = data.X[:, idx]
    A = np.concatenate([np.ones((X2.shape[0], 1)), X2], axis=1)
    beta, *_ = np.linalg.lstsq(A, data.y, rcond=None)
    jobs_per_hour = jobs.jobs_per_hour(trace.hours)
    model = PenaltyModel(
        name=name, kind=trace.kind, usage=trace.usage,
        entitlement=trace.entitlement, k=1.0,
        params=(float(beta[0]), float(beta[1]), float(beta[2])),
        jobs=jobs_per_hour, slo_hours=4, feature_names=sel_names)
    d_cap = model.calibration_curtailment()
    raw = float(jnp.maximum(model.raw_loss(jnp.asarray(d_cap)), 0.0))
    k = calibrate_k(raw, trace.entitlement)
    return dataclasses.replace(model, k=k), fit, data


def build_paper_fleet(hours: int = 48, total_power: float = 100.0,
                      num_samples: int = 160, num_jobs: int = 10_000,
                      seed: int = 0) -> dict[str, PenaltyModel]:
    """The paper's four-service fleet (Table II) with calibrated models."""
    from repro.sched.traces import fleet_power_traces
    traces = fleet_power_traces(hours=hours, total_power=total_power, seed=seed)
    out: dict[str, PenaltyModel] = {}
    for name in ("RTS1", "RTS2"):
        out[name] = build_rts_model(name, traces[name])
    for name, kind, n in (("AITraining", "batch_noslo", 303),
                          ("DataPipeline", "batch_slo", 162)):
        jobs = make_job_trace(kind, hours=hours,
                              total_power=1.05 * float(np.mean(traces[name].usage)),
                              num_jobs=num_jobs, seed=seed + hash(name) % 97)
        samples = min(num_samples, n)
        model, _, _ = build_batch_model(name, traces[name], jobs,
                                        num_samples=samples, seed=seed)
        out[name] = model
    return out
