"""Rolling-horizon streaming DR: re-solve as MCI forecasts revise.

The paper plans against a *static* day-ahead marginal-carbon-intensity
trace. A deployed Carbon Responder runs online: every hour the forecast
provider re-issues the day-ahead horizon (WattTime-style revisions), the
coordinator re-solves, commits only the first hour of the new plan, and
the window slides forward. This module is that control loop:

  * `ForecastStream` (`repro.core.carbon`) supplies the revised horizons —
    a persistence + lead-time-noise revision model, or replayed snapshots.
  * `RollingHorizonSolver` holds a `FleetProblem` template plus a
    `DRPolicy` (`repro.core.api`) and, per tick:
      1. slides the usage/jobs window one hour and swaps in the fresh
         `(T,)` forecast,
      2. warm-starts `api.solve(problem, policy, ctx=...)` from the
         previous tick's `EngineState`, shifted one hour along time
         (`EngineState.shifted`) — multipliers carry over as-is since
         they price per-workload constraints, not hours,
      3. commits hour 0 of the new plan and logs forecast vs realized
         carbon for the committed hour.

Because `EngineState` is a pure-array pytree and every tick's problem has
identical shapes, all warm re-solves reuse ONE jitted trace (per policy):
the hot path is a single XLA call per tick — `SolveContext.shift`/
`reset_mu` fold the one-hour state roll and the per-tick mu restart into
that same call, and `donate=True` additionally donates the previous
tick's `EngineState` buffers so XLA re-solves in place
(`jax.jit(donate_argnums)`). The warm start lets each tick run with a
fraction of the cold solve's inner Adam steps
(`benchmarks.perf_micro.streaming_resolve` measures the latency and
solution gap).

Fleet scale: pass `mesh=` (see `repro.launch.mesh.make_fleet_mesh`) to run
every tick's re-solve sharded over the mesh's fleet axis. The engine state
then carries the device-padded workload count between ticks (no per-tick
re-padding), and the donated tick reuses the per-device buffers in place.

Receding-horizon caveat: batch day-preservation is enforced over the
sliding window's 24 h blocks each re-solve (the standard receding-horizon
relaxation); only committed hours are binding, so small per-window
residuals wash out as the window slides.
"""
from __future__ import annotations

import contextlib
import dataclasses
import time
from typing import Callable, Sequence

import numpy as np

from repro.core.api import (DRPolicy, SolveContext, configured_policy,
                            solve)
from repro.core.carbon import ForecastStream
from repro.core.engine import EngineState
from repro.core.fleet_solver import (FleetProblem, FleetSolveResult,
                                     _single_region_view)
from repro.core.regional import region_totals
from repro.obs.events import EventWriter, TelemetryEvent, TickEvent
from repro.obs.telemetry import TelemetryConfig


def _rel_revision(prev: np.ndarray | None, cur: np.ndarray) -> float:
    """Relative forecast-revision magnitude between consecutive horizons:
    `‖cur[:-1] − prev[1:]‖₂ / ‖prev[1:]‖₂` over the re-forecast hours
    both horizons cover (0.0 for the first horizon seen)."""
    if prev is None:
        return 0.0
    tail = prev[..., 1:]
    return float(np.linalg.norm((cur[..., :-1] - tail).ravel())
                 / max(np.linalg.norm(tail.ravel()), 1e-12))


@dataclasses.dataclass(frozen=True)
class TickResult:
    """One committed hour of online operation.

    `plan` (the full-horizon solve: D matrix, engine state, ...) is only
    retained on the *latest* tick — older history entries drop it so a
    long-lived controller holds O(W) per tick, not O(W·T)."""
    tick: int
    committed: np.ndarray        # (W,) NP adjustments enforced this hour
    forecast_mci: float | np.ndarray   # hour-0 forecast ((R,) multi-region)
    realized_mci: float | np.ndarray   # actual MCI once the hour elapsed
    inner_steps: int             # engine iterations spent on this re-solve
    plan: FleetSolveResult | None
    committed_by_region: np.ndarray | None = None  # (R,) multi-region only

    @property
    def forecast_carbon(self) -> float:
        """kg CO2 the plan *expected* to eliminate this hour."""
        if np.ndim(self.forecast_mci):
            return float((self.committed_by_region
                          * np.asarray(self.forecast_mci)).sum())
        return float(self.committed.sum() * self.forecast_mci)

    @property
    def realized_carbon(self) -> float:
        """kg CO2 actually eliminated this hour."""
        if np.ndim(self.realized_mci):
            return float((self.committed_by_region
                          * np.asarray(self.realized_mci)).sum())
        return float(self.committed.sum() * self.realized_mci)


@dataclasses.dataclass(frozen=True)
class StreamingReport:
    """Aggregate of a rolling-horizon run."""
    ticks: tuple[TickResult, ...]
    committed: np.ndarray        # (W, n_ticks)
    realized_carbon: float       # kg CO2 eliminated, priced at actual MCI
    forecast_carbon: float       # same hours priced at solve-time forecasts
    realized_baseline: float     # no-DR carbon of the committed hours
    total_inner_steps: int

    @property
    def realized_reduction_pct(self) -> float:
        return 100.0 * self.realized_carbon / max(self.realized_baseline,
                                                  1e-12)

    @property
    def forecast_error_pct(self) -> float:
        """|forecast − realized| carbon for committed hours, % of realized."""
        return 100.0 * abs(self.forecast_carbon - self.realized_carbon) \
            / max(abs(self.realized_carbon), 1e-12)


class RollingHorizonSolver:
    """Online DR controller: warm-started re-solves over a sliding window.

    Args:
      problem: fleet template; `usage`/`jobs` are treated as periodic
        traces that slide with the window (`np.roll` along time). A
        multi-region problem (`problem.is_multiregion`) is supported:
        pass one `ForecastStream` per region (see `stream`).
      stream: revised-forecast source; `stream.horizon` must equal
        `problem.T`. For a multi-region problem pass a sequence of
        `problem.R` streams (one per region, e.g. from
        `ForecastStream.regional`); each tick then installs the
        stacked `(R, T)` forecast. The per-tick migration post-stage
        is *not* applied inside the loop (the committed hours are the
        streaming deliverable; run `fleet_migration` on the committed
        matrix offline to add the spatial lever).
      policy: a `DRPolicy` object (`CR1(lam=...)`, `CR2(...)`,
        `CR3(...)`, ...) or a `POLICY_REGISTRY` name. Unknown names raise
        `ValueError` (naming the registered choices) here at
        construction, not at the first `step()`.
      legacy policy knobs: `lam` (CR1), `cap_frac` (CR2),
        `rho`/`tax_frac` (CR3) and `outer` configure the policy object
        when `policy` is given by name; they are ignored when a policy
        object is passed.
      cold_steps: inner Adam steps for the tick-0 cold solve.
      warm_steps: inner steps for warm-started re-solves — the streaming
        speedup is `cold_steps / warm_steps` per multiplier round.
      adaptive_warm: scale each warm tick's budget by the forecast
        revision magnitude instead of spending `warm_steps` flat. The
        tick's relative revision `‖mci_t[:-1] − mci_{t−1}[1:]‖₂ /
        ‖mci_{t−1}[1:]‖₂` (the re-forecast hours both horizons cover) is
        mapped linearly onto `[warm_steps_min, warm_steps]` (quantized
        to 4 levels — the budget is a static jit argument, so this
        bounds the trace cache), saturating at `revision_ref`: a quiet
        tick (the forecast barely moved, the shifted warm start is
        already near-optimal) re-solves with `warm_steps_min` inner
        steps, a heavily revised tick gets the full warm budget.
      warm_steps_min: floor for adaptive budgets (default
        `warm_steps // 4`).
      revision_ref: relative revision magnitude that earns the full
        `warm_steps` (default 0.05 — about the day-ahead error of the
        default `ForecastStream` sigma).
      mesh: optional device mesh — every tick's re-solve runs sharded over
        its fleet axis (workloads padded to the device count once; the
        engine state stays padded between ticks).
      donate: donate each tick's incoming `EngineState` to the re-solve
        (in-place buffers, one XLA call per tick). Prior ticks'
        `plan.state` objects become invalid once the next tick runs, so
        leave False when capturing states from `on_tick` callbacks.
      guard_recompiles: enforce the one-trace claim at runtime. The
        first solve of each static configuration — a (steps, shift,
        reset_mu) tick combo, or a day-scan shape — may compile; every
        later solve of the same configuration runs inside
        `repro.analysis.recompile_guard(0)` and raises
        `RecompileError` if the jit cache missed (a drifting static
        argument, shape, or dtype silently turning "one trace per
        tick" into "a compile per tick"). Debug/CI knob; off by
        default because the guard swaps jax-internal counters in and
        out around every solve.
      events: JSONL tick ledger — a path or an open
        `repro.obs.EventWriter`. Every `step()`/`run_scanned()` tick
        appends a typed `TickEvent` (forecast-revision magnitude, warm
        budget spent, solve latency, per-region committed/realized
        carbon, migration credit, recompile + dispatch counts), and any
        in-solve convergence samples append as `TelemetryEvent`s. All
        emission is host-side AFTER the solve returns, so the
        one-dispatch contracts (warm tick, scanned day) are untouched;
        render with `python -m repro.obs.report <path>`.
      telemetry: `repro.obs.TelemetryConfig` — capture in-solve
        convergence traces inside each tick's jitted solve (CR1/CR2,
        no fused kernel; see `SolveContext.telemetry`). Pairs with
        `events` to land the samples in the ledger; without `events`
        the trace is still on `tick.plan.extras["telemetry"]`.

    CR3 note: the policy object's `rho` is the *configured* price, so
    every window re-clears from it — clearing only ever lowers ρ, and
    carrying a lowered price forward would ratchet the fleet onto a
    permanently depressed carbon price after one transient tick.
    `last_rho` exposes the most recent cleared price
    (`plan.extras["rho"]`).
    """

    def __init__(self, problem: FleetProblem,
                 stream: ForecastStream | Sequence[ForecastStream], *,
                 policy: str | DRPolicy = "cr1", lam: float = 1.45,
                 cap_frac: float = 0.78, rho: float = 0.02,
                 tax_frac: float = 0.2, cold_steps: int = 600,
                 warm_steps: int = 150, outer: int = 4,
                 use_kernel: bool | None = None,
                 mesh=None, donate: bool = False,
                 adaptive_warm: bool = False,
                 warm_steps_min: int | None = None,
                 revision_ref: float = 0.05,
                 guard_recompiles: bool = False,
                 events: EventWriter | str | None = None,
                 telemetry: TelemetryConfig | None = None):
        streams = (tuple(stream) if isinstance(stream, (list, tuple))
                   else (stream,))
        # Degenerate R=1 regional problems canonicalize up front so the
        # whole streaming path (accounting included) is bitwise the
        # single-region engine, matching `api.solve`'s contract.
        problem = _single_region_view(problem)
        want = problem.R if problem.is_multiregion else 1
        if len(streams) != want:
            raise ValueError(
                f"need {want} forecast stream(s) for this problem "
                f"(R={problem.R}), got {len(streams)}")
        for s in streams:
            if s.horizon != problem.T:
                raise ValueError(
                    f"stream horizon {s.horizon} != problem.T {problem.T}")
        self.problem = problem
        self.streams = streams
        self.stream = streams[0]
        # Registry names become policy objects configured with the legacy
        # knobs; unknown names fail HERE with the registered choices (an
        # opaque mid-run failure at the first step() otherwise).
        self.policy = configured_policy(policy, lam=lam, cap_frac=cap_frac,
                                        rho=rho, tax_frac=tax_frac,
                                        outer=outer)
        self.last_rho = getattr(self.policy, "rho", None)
        self.cold_steps = cold_steps
        self.warm_steps = warm_steps
        self.adaptive_warm = adaptive_warm
        self.warm_steps_min = max(1, warm_steps // 4) \
            if warm_steps_min is None else warm_steps_min
        if not 0 < self.warm_steps_min <= warm_steps:
            raise ValueError(
                f"warm_steps_min must be in (0, warm_steps={warm_steps}]; "
                f"got {self.warm_steps_min}")
        if revision_ref <= 0:
            raise ValueError(f"revision_ref must be > 0, got {revision_ref}")
        self.revision_ref = revision_ref
        self.use_kernel = use_kernel
        self.mesh = mesh
        self.donate = donate
        self.guard_recompiles = guard_recompiles
        if events is None or isinstance(events, EventWriter):
            self.events = events
        else:
            self.events = EventWriter(
                events, tags={"policy": self.policy.name,
                              "cold_steps": cold_steps,
                              "warm_steps": warm_steps})
        self.telemetry = telemetry
        self._seen_traces: set[tuple] = set()
        self._state: EngineState | None = None
        self._prev_forecast: np.ndarray | None = None
        self._tick = 0
        self._history: list[TickResult] = []

    # -- per-tick plumbing --------------------------------------------------
    @property
    def _n_ticks(self) -> int:
        return min(s.n_ticks for s in self.streams)

    def _forecast(self, tick: int) -> np.ndarray:
        """This tick's revised horizon: `(T,)`, or `(R, T)` stacked over
        the per-region streams for a multi-region problem."""
        if not self.problem.is_multiregion:
            return self.streams[0].forecast(tick)
        return np.stack([s.forecast(tick) for s in self.streams])

    def _realized(self, tick: int) -> float | np.ndarray:
        if not self.problem.is_multiregion:
            return self.streams[0].realized(tick)
        return np.array([s.realized(tick) for s in self.streams])

    def _by_region(self, committed: np.ndarray) -> np.ndarray | None:
        if not self.problem.is_multiregion:
            return None
        return region_totals(self.problem.region, committed,
                             self.problem.R)

    def _window_problem(self, tick: int, mci: np.ndarray) -> FleetProblem:
        """Slide usage/jobs (and any operational cap) to hours
        [tick, tick+T) and install `mci`. The migration topology is
        stripped: only hour 0 of each plan is committed, so the per-tick
        spatial post-stage would price hours that never run."""
        p = self.problem
        return dataclasses.replace(
            p, mci=np.asarray(mci), topology=None,
            usage=np.roll(p.usage, -tick, axis=1),
            jobs=np.roll(p.jobs, -tick, axis=1),
            upper=None if p.upper is None
            else np.roll(p.upper, -tick, axis=1))

    def _solve(self, p: FleetProblem, warm: EngineState | None,
               steps: int, shift: int, reset_mu: bool) -> FleetSolveResult:
        ctx = SolveContext(mesh=self.mesh, donate=self.donate, shift=shift,
                           reset_mu=reset_mu, warm=warm,
                           use_kernel=self.use_kernel, steps=steps,
                           telemetry=self.telemetry)
        plan = solve(p, self.policy, ctx=ctx)
        if "rho" in plan.extras:
            self.last_rho = plan.extras["rho"]
        return plan

    def _traceguard(self, key: tuple):
        """`recompile_guard(0)` for re-solves of an already-compiled
        static configuration (`guard_recompiles=True`); the first solve
        of each `key` — and everything when the knob is off — runs
        unguarded."""
        if not self.guard_recompiles or key not in self._seen_traces:
            self._seen_traces.add(key)
            return contextlib.nullcontext()
        from repro.analysis.recompile import recompile_guard
        return recompile_guard(0, label=f"tick {self._tick} {key[0]}")

    def _warm_budget(self, rel: float) -> int:
        """Inner steps for this warm tick: `warm_steps` flat, or scaled by
        the forecast revision magnitude `rel` under `adaptive_warm` (the
        hours both horizons forecast — hour k of this tick vs hour k+1
        of the previous one; see `_rel_revision`)."""
        if not self.adaptive_warm or self._prev_forecast is None:
            return self.warm_steps
        frac = min(1.0, rel / self.revision_ref)
        # Quantize to 4 budget levels: the step count is a static jit
        # argument, so a continuum of budgets would compile a fresh trace
        # per tick; 4 levels bound the cache at 4 warm traces.
        frac = round(3 * frac) / 3
        return int(round(self.warm_steps_min
                         + (self.warm_steps - self.warm_steps_min) * frac))

    # -- tick ledger --------------------------------------------------------
    def _measure(self):
        """Compile counters (pure measurement) while the ledger is on —
        attributes jit traces to ticks. Nestable inside `_traceguard`'s
        failing-mode guard (hook swap is save/restore)."""
        if self.events is None:
            return contextlib.nullcontext(None)
        from repro.analysis.recompile import recompile_guard
        return recompile_guard(None, label="tick ledger")

    def _emit_tick(self, out: TickResult, *, revision: float,
                   latency_s: float, recompiles: int, dispatches: int,
                   cold: bool, objective_proxy: float | None) -> None:
        """Append one `TickEvent` (host-side, after the solve returned —
        never inside the dispatch)."""
        if self.events is None:
            return
        if out.committed_by_region is not None:
            per = np.asarray(out.committed_by_region, float)
            committed = (per * np.asarray(out.forecast_mci,
                                          float)).tolist()
            realized = (per * np.asarray(out.realized_mci, float)).tolist()
        else:
            tot = float(out.committed.sum())
            committed = [tot * float(out.forecast_mci)]
            realized = [tot * float(out.realized_mci)]
        plan = out.plan
        credit = 0.0
        if plan is not None and "migration" in plan.extras:
            credit = float(plan.extras["migration"].net_saved)
        self.events.write(TickEvent(
            tick=out.tick, revision=float(revision),
            warm_steps=int(out.inner_steps), cold=bool(cold),
            objective_proxy=objective_proxy, latency_s=float(latency_s),
            committed_carbon=committed, realized_carbon=realized,
            migration_credit=credit, recompiles=int(recompiles),
            dispatches=int(dispatches)))
        if plan is not None and self.telemetry is not None:
            trace = plan.extras.get("telemetry")
            if trace is not None and not isinstance(trace, tuple):
                self._emit_trace(out.tick, trace)

    def _emit_trace(self, tick: int, trace) -> None:
        """Append one solve's convergence samples as `TelemetryEvent`s."""
        if self.events is None or trace is None:
            return
        for s in trace.samples():
            self.events.write(TelemetryEvent(tick=tick, **s))

    def step(self) -> TickResult:
        """Ingest the next forecast revision, re-solve, commit hour 0."""
        tick = self._tick
        mci_hat = self._forecast(tick)
        p_t = self._window_problem(tick, mci_hat)
        warm = self._state
        rev = _rel_revision(self._prev_forecast, mci_hat)
        # Warm ticks shift the plan one hour and restart the mu schedule at
        # the policy's mu0 — without the reset, mu compounds by
        # mu_growth^outer per tick and CR2/CR3's walls turn stiff within a
        # handful of ticks (multipliers still carry the constraint prices).
        # Both happen *inside* the solve's jitted call, so a tick is one
        # XLA dispatch (donated when self.donate).
        steps = self.cold_steps if warm is None else self._warm_budget(rev)
        t0 = time.perf_counter()
        with self._traceguard(("tick", steps, warm is not None)), \
                self._measure() as stats:
            plan = self._solve(p_t, warm, steps,
                               shift=0 if warm is None else 1,
                               reset_mu=warm is not None)
        latency = time.perf_counter() - t0
        self._state = plan.state
        self._prev_forecast = mci_hat
        self._tick = tick + 1
        committed = np.asarray(plan.D[:, 0])
        out = TickResult(
            tick=tick, committed=committed,
            forecast_mci=(float(mci_hat[0]) if mci_hat.ndim == 1
                          else mci_hat[:, 0].copy()),
            realized_mci=self._realized(tick),
            inner_steps=plan.iters, plan=plan,
            committed_by_region=self._by_region(committed))
        self._emit_tick(out, revision=rev, latency_s=latency,
                        recompiles=stats.traces if stats else 0,
                        dispatches=1, cold=warm is None,
                        objective_proxy=float(plan.carbon_reduction_pct))
        if self._history:   # bound memory: full plans live on the
            self._history[-1] = dataclasses.replace(   # latest tick only
                self._history[-1], plan=None)
        self._history.append(out)
        return out

    def run(self, n_ticks: int | None = None,
            on_tick: Callable[[TickResult], None] | None = None,
            ) -> StreamingReport:
        """Run `n_ticks` hours (default: all the stream(s) support)."""
        n = self._n_ticks - self._tick if n_ticks is None else n_ticks
        for _ in range(n):
            out = self.step()
            if on_tick is not None:
                on_tick(out)
        return self.report()

    def run_scanned(self, n_ticks: int | None = None) -> StreamingReport:
        """Run `n_ticks` hours as ONE XLA dispatch (`api.solve_day`).

        Precomputes the (n_ticks, T) forecast-revision stack from the
        stream, then folds every tick's window-roll + plan shift +
        mu-reset + warm re-solve into a single `lax.scan` — a 24-tick
        day is one donated-buffer XLA call instead of 24. Matches the
        per-tick `run()` loop to <0.01 pp realized carbon (CR1/CR2
        only; CR3/B1/B3 need host-side per-tick control flow and raise
        `NotImplementedError`). `mesh=` is honoured: the whole day scan
        runs inside the fleet shard_map, including multi-region fleets
        (per-region norms ride the scan as row-sharded stacks).
        Warm-continues from and updates the solver state, so
        `run_scanned(24)` per day and mixed `step()`/`run_scanned()`
        schedules both work.

        `adaptive_warm` is incompatible: the per-tick budget is a
        static jit argument chosen from the revision magnitude at run
        time, which a fixed scan cannot express — use flat
        `warm_steps` here.
        """
        if self.adaptive_warm:
            raise ValueError(
                "run_scanned needs a flat warm budget: adaptive_warm "
                "picks each tick's (static) step count from the forecast "
                "revision at run time, which one fixed scan trace cannot "
                "express — construct with adaptive_warm=False or use run()")
        t0 = self._tick
        n = self._n_ticks - t0 if n_ticks is None else n_ticks
        if n <= 0:
            raise ValueError(f"n_ticks must be >= 1, got {n}")
        from repro.core.api import solve_day
        mci_stack = np.stack([self._forecast(t0 + i) for i in range(n)])
        p_win = self._window_problem(t0, mci_stack[0])
        was_cold = self._state is None
        # Per-tick revision magnitudes, walked over the stack before
        # _prev_forecast advances to the final horizon.
        prev = self._prev_forecast
        revs = []
        for i in range(n):
            revs.append(_rel_revision(prev, mci_stack[i]))
            prev = mci_stack[i]
        ctx = SolveContext(mesh=self.mesh, donate=self.donate,
                           warm=self._state,
                           use_kernel=self.use_kernel, shift=1,
                           reset_mu=self._state is not None,
                           telemetry=self.telemetry)
        t_start = time.perf_counter()
        with self._traceguard(("day", n, self._state is not None)), \
                self._measure() as stats:
            day = solve_day(p_win, self.policy, mci_stack, ctx=ctx,
                            cold_steps=self.cold_steps,
                            warm_steps=self.warm_steps)
        latency = time.perf_counter() - t_start
        self._state = day.last.state
        self._prev_forecast = mci_stack[-1]
        self._tick = t0 + n
        outs = [TickResult(
            tick=t0 + i, committed=day.committed[i],
            forecast_mci=(float(mci_stack[i][0])
                          if mci_stack[i].ndim == 1
                          else mci_stack[i][:, 0].copy()),
            realized_mci=self._realized(t0 + i),
            inner_steps=day.inner_steps[i],
            plan=day.last if i == n - 1 else None,
            committed_by_region=self._by_region(day.committed[i]))
            for i in range(n)]
        if self.events is not None:
            # One dispatch covered the whole day: latency, traces and
            # the dispatch count land on tick 0, the objective proxy on
            # the last tick (the only per-plan metric the scan keeps).
            traces = day.last.extras.get("telemetry", ())
            for i, out in enumerate(outs):
                self._emit_tick(
                    out, revision=revs[i],
                    latency_s=latency if i == 0 else 0.0,
                    recompiles=stats.traces if stats and i == 0 else 0,
                    dispatches=1 if i == 0 else 0,
                    cold=i == 0 and was_cold,
                    objective_proxy=(float(day.last.carbon_reduction_pct)
                                     if i == n - 1 else None))
                if i < len(traces):
                    self._emit_trace(out.tick, traces[i])
        if self._history:   # same memory bound as step()
            self._history[-1] = dataclasses.replace(
                self._history[-1], plan=None)
        self._history.extend(outs)
        return self.report()

    def report(self) -> StreamingReport:
        ticks = tuple(self._history)
        if not ticks:
            raise RuntimeError("no ticks committed yet — call step()/run()")
        committed = np.stack([t.committed for t in ticks], axis=1)
        base_usage = np.asarray(self.problem.usage)
        Tn = base_usage.shape[1]
        if self.problem.is_multiregion:
            region = self.problem.region
            baseline = sum(
                float((np.asarray(t.realized_mci)
                       * region_totals(region,
                                       base_usage[:, t.tick % Tn],
                                       self.problem.R)).sum())
                for t in ticks)
        else:
            baseline = sum(
                t.realized_mci * float(base_usage[:, t.tick % Tn].sum())
                for t in ticks)
        return StreamingReport(
            ticks=ticks, committed=committed,
            realized_carbon=sum(t.realized_carbon for t in ticks),
            forecast_carbon=sum(t.forecast_carbon for t in ticks),
            realized_baseline=float(baseline),
            total_inner_steps=sum(t.inner_steps for t in ticks))
