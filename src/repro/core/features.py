"""Engineered penalty-model features (paper Table IV), in JAX.

All features are functions of the hourly adjustment vector d (positive =
curtail) and are built from positive-part cumulative sums — the queue
integral of deferred work. They are differentiable almost everywhere (relu
compositions), which is what lets the fleet solver optimize through them;
a softplus-smoothed variant is provided for solvers that prefer C¹.

Shapes: d, usage, jobs are (T,) for one workload or (W, T) batched; every
function maps to a scalar per workload ((,) or (W,)).

The Pallas kernel `repro.kernels.dr_features` computes the same quantities
for large fleets; `repro.kernels.dr_features.ref` must match this module
(it is the oracle used in kernel tests).
"""
from __future__ import annotations

import functools
from typing import Callable

import jax
import jax.numpy as jnp

Array = jax.Array


def _pos(x: Array, smooth: float = 0.0) -> Array:
    """Positive part; softplus-smoothed when smooth > 0."""
    if smooth > 0.0:
        return smooth * jax.nn.softplus(x / smooth)
    return jnp.maximum(x, 0.0)


def waiting_time_jobs(d: Array, usage: Array, jobs: Array,
                      smooth: float = 0.0) -> Array:
    """Σ_t ( Σ_{t'<=t} J_{t'} · d_{t'}/U_{t'} )⁺   [job·hour]."""
    rate = jobs * d / usage
    return _pos(jnp.cumsum(rate, axis=-1), smooth).sum(axis=-1)


def waiting_time_power(d: Array, smooth: float = 0.0) -> Array:
    """Σ_t ( Σ_{t'<=t} d_{t'} )⁺   [NP·hour] — selected as x1 for both
    AI training and data pipeline."""
    return _pos(jnp.cumsum(d, axis=-1), smooth).sum(axis=-1)


def waiting_time_squared(d: Array, usage: Array, jobs: Array,
                         smooth: float = 0.0) -> Array:
    """Σ_t ( Σ_{t'<=t} J_{t'} · d_{t'}²/U_{t'} )⁺ — convexity feature,
    selected as x2 for data pipeline.

    Note: the summand uses signed d·|d| rather than d² so that boosts
    (d<0) relieve the queue integral, matching the cumulative-backlog
    semantics of the other features (a pure square would make boosting
    *increase* the penalty, which the paper's fitted model does not do).
    """
    rate = jobs * d * jnp.abs(d) / usage
    return _pos(jnp.cumsum(rate, axis=-1), smooth).sum(axis=-1)


def num_jobs_delayed(d: Array, usage: Array, jobs: Array,
                     smooth: float = 0.0) -> Array:
    """Σ_{t'} J_{t'} · d_{t'}⁺ / U_{t'} — non-cumulative count of affected
    jobs, selected as x2 for AI training."""
    return (jobs * _pos(d, smooth) / usage).sum(axis=-1)


def total_tardiness(d: Array, usage: Array, jobs: Array, slo_hours: int,
                    smooth: float = 0.0) -> Array:
    """Σ_t ( Σ_{t'<=t-SLO} J_{t'} · d_{t'}/U_{t'} )⁺ — overdue queue hours.

    The inner sum lags the outer index by `slo_hours`: work deferred at t'
    only becomes tardy once it has waited SLO hours.
    """
    rate = jobs * d / usage
    cum = jnp.cumsum(rate, axis=-1)
    T = cum.shape[-1]
    if slo_hours >= T:
        return jnp.zeros(cum.shape[:-1], cum.dtype)
    lagged = cum[..., : T - slo_hours]
    return _pos(lagged, smooth).sum(axis=-1)


FEATURE_NAMES = (
    "waiting_time_jobs",
    "waiting_time_power",
    "waiting_time_squared",
    "num_jobs_delayed",
    "total_tardiness",
)


def feature_matrix(d: Array, usage: Array, jobs: Array, slo_hours: int = 4,
                   smooth: float = 0.0, include_tardiness: bool = True,
                   ) -> Array:
    """Stack Table-IV features -> (..., F). F = 5 with tardiness, else 4
    (tardiness is N/A for no-SLO workloads — Table IV)."""
    feats = [
        waiting_time_jobs(d, usage, jobs, smooth),
        waiting_time_power(d, smooth),
        waiting_time_squared(d, usage, jobs, smooth),
        num_jobs_delayed(d, usage, jobs, smooth),
    ]
    if include_tardiness:
        feats.append(total_tardiness(d, usage, jobs, slo_hours, smooth))
    return jnp.stack(feats, axis=-1)


# Selections published in Table IV.
SELECTED = {
    # x1, x2 for each batch workload family.
    "AITraining": ("waiting_time_power", "num_jobs_delayed"),
    "DataPipeline": ("waiting_time_power", "waiting_time_squared"),
}


def selected_features(workload: str, d: Array, usage: Array, jobs: Array,
                      slo_hours: int = 4, smooth: float = 0.0) -> Array:
    """(x1, x2) per Table IV's published selection -> (..., 2)."""
    fns: dict[str, Callable[..., Array]] = {
        "waiting_time_jobs": lambda: waiting_time_jobs(d, usage, jobs, smooth),
        "waiting_time_power": lambda: waiting_time_power(d, smooth),
        "waiting_time_squared": lambda: waiting_time_squared(d, usage, jobs, smooth),
        "num_jobs_delayed": lambda: num_jobs_delayed(d, usage, jobs, smooth),
        "total_tardiness": lambda: total_tardiness(d, usage, jobs, slo_hours, smooth),
    }
    names = SELECTED[workload]
    return jnp.stack([fns[n]() for n in names], axis=-1)
