"""Monte Carlo grid/fleet scenario generation for ensemble evaluation.

The paper stress-tests Carbon Responder on one realized CAISO-2021 trace
plus two Cambium projections (Fig. 11). A production DR controller must be
evaluated across *distributions* of grid futures — renewable droughts,
evening-ramp spikes, zero-MCI solar windows, deep-decarbonization
projection mixes, forecast-error regimes — and across fleet perturbations
(usage/entitlement jitter, flex-fraction and batch/online mix shifts).
This module is the generation layer of that subsystem; the batched
evaluation lives in `repro.core.ensemble`.

Two kinds of object:

  * `ScenarioStack` — S *materialized* scenarios over a base
    `FleetProblem`: per-field overlay arrays with a leading S axis
    (`mci` (S, T), `usage` (S, W, T), `entitlement` (S, W), `jobs`,
    `upper`), `None` meaning "the base problem's field, shared by every
    scenario". The ensemble runner vmaps the overlaid fields straight
    through the fleet engine, so a stack with only an `mci` overlay costs
    S·T scenario floats, not S copies of the fleet. `problem(base, s)`
    materializes one scenario for the loop/parity path, and
    `ScenarioStack.concat` mixes stacks from different generators into
    one ensemble.

  * Scenario *generators* — frozen dataclasses whose fields are exactly
    the distribution's parameters, registered by name in
    `SCENARIO_REGISTRY` (the string-config hook, mirroring
    `api.POLICY_REGISTRY`). `generate(base)` returns a `ScenarioStack`
    and is deterministic: every random draw comes from a tuple-seeded
    `np.random.default_rng((seed, s, ...))`, so scenario `s` of a stack
    is a pure function of the generator's fields — re-generating never
    reshuffles the ensemble, and distinct (seed, s) pairs never collide
    (the additive-seed bug `carbon.projection` used to have).

MCI generators: `DuckPerturb` (shape/peak/trough jitter),
`RenewableDrought`, `EveningRampSpike`, `ZeroMciWindow`, `CambiumMix`
(2024/2050 `carbon.projection` mixes), `ForecastRegime` (per-scenario
`ForecastStream` sigma/seed — also the streaming ensemble's stream
factory), `RegionalDivergence` (per-region grid jitter over a
multi-region base — (S, R, T) overlays). Fleet generators:
`FleetJitter` (usage/entitlement scale), `FlexMixShift` (per-scenario
sheddable fraction via the `upper` operational cap + batch/online
usage mix shift).
"""
from __future__ import annotations

import dataclasses
from typing import ClassVar, Iterator, Protocol, Sequence, runtime_checkable

import numpy as np

from repro.core import carbon
from repro.core.carbon import ForecastStream
from repro.core.fleet_solver import FleetProblem

__all__ = [
    "SCENARIO_REGISTRY", "CambiumMix", "DuckPerturb", "EveningRampSpike",
    "FleetJitter", "FlexMixShift", "ForecastRegime", "RegionalDivergence",
    "RenewableDrought", "ScenarioGenerator", "ScenarioStack",
    "ZeroMciWindow", "resolve_scenarios",
]

#: FleetProblem data fields a scenario may overlay, with the leading-S
#: overlay shape relative to the base problem's (W, T).
OVERLAY_FIELDS = ("mci", "usage", "entitlement", "jobs", "upper")


@dataclasses.dataclass(frozen=True)
class ScenarioStack:
    """S materialized scenarios: per-field overlays with a leading S axis.

    Every non-None field must lead with the same S; `None` means the base
    problem's field is shared across scenarios. `labels` names each
    scenario for reports."""

    mci: np.ndarray | None = None          # (S, T) — (S, R, T) multi-region
    usage: np.ndarray | None = None        # (S, W, T)
    entitlement: np.ndarray | None = None  # (S, W)
    jobs: np.ndarray | None = None         # (S, W, T)
    upper: np.ndarray | None = None        # (S, W, T)
    labels: tuple[str, ...] | None = None

    def __post_init__(self):
        sizes = {np.asarray(v).shape[0] for v in self._overlays().values()}
        if self.labels is not None:
            sizes.add(len(self.labels))
        if len(sizes) != 1:
            raise ValueError(
                f"scenario overlays disagree on S (or the stack is empty): "
                f"leading sizes {sorted(sizes)}")

    def _overlays(self) -> dict[str, np.ndarray]:
        return {f: getattr(self, f) for f in OVERLAY_FIELDS
                if getattr(self, f) is not None}

    @property
    def S(self) -> int:
        for v in self._overlays().values():
            return int(np.asarray(v).shape[0])
        return len(self.labels)

    def overlay_fields(self) -> dict[str, np.ndarray]:
        """Non-None overlays as {field: (S, ...) array} (insertion order
        fixed by `OVERLAY_FIELDS` — stable jit static keys)."""
        return self._overlays()

    def validate(self, base: FleetProblem) -> None:
        shapes = {"mci": (self.S,) + np.asarray(base.mci).shape,
                  "usage": (self.S, base.W, base.T),
                  "entitlement": (self.S, base.W),
                  "jobs": (self.S, base.W, base.T),
                  "upper": (self.S, base.W, base.T)}
        for f, v in self._overlays().items():
            got = np.asarray(v).shape
            if got != shapes[f]:
                raise ValueError(
                    f"scenario overlay {f!r} has shape {got}; want "
                    f"{shapes[f]} for this base fleet")

    def problem(self, base: FleetProblem, s: int) -> FleetProblem:
        """Materialize scenario `s` as a plain FleetProblem (the
        sequential/parity path)."""
        over = {f: np.asarray(v[s]) for f, v in self._overlays().items()}
        return dataclasses.replace(base, **over)

    def problems(self, base: FleetProblem) -> Iterator[FleetProblem]:
        for s in range(self.S):
            yield self.problem(base, s)

    def label(self, s: int) -> str:
        return self.labels[s] if self.labels is not None else f"scenario-{s}"

    @staticmethod
    def concat(stacks: Sequence["ScenarioStack"],
               base: FleetProblem) -> "ScenarioStack":
        """Mix stacks into one ensemble. Fields overlaid by only some
        stacks are materialized from `base` for the others (the batched
        axis must be uniform)."""
        stacks = list(stacks)
        if not stacks:
            raise ValueError("concat of zero scenario stacks")
        fields = {f for st in stacks for f in st._overlays()}
        out: dict[str, np.ndarray] = {}
        for f in fields:
            parts = []
            for st in stacks:
                v = getattr(st, f)
                if v is None:
                    b = getattr(base, f)
                    # a base with no operational cap means "+inf" (the
                    # pad_fleet materialization convention)
                    b = np.full((base.W, base.T), np.inf) \
                        if b is None else np.asarray(b, float)
                    v = np.broadcast_to(b, (st.S,) + b.shape)
                parts.append(np.asarray(v, float))
            out[f] = np.concatenate(parts)
        labels = tuple(st.label(s) for st in stacks for s in range(st.S))
        return ScenarioStack(labels=labels, **out)


@runtime_checkable
class ScenarioGenerator(Protocol):
    """A scenario distribution: a frozen parameter record that knows how
    to materialize a deterministic `ScenarioStack` over a base fleet."""

    name: ClassVar[str]
    n_scenarios: int
    seed: int

    def generate(self, base: FleetProblem) -> ScenarioStack: ...


#: Generator name -> class; the one place string-typed scenario configs
#: (CLI flags, benchmark specs) resolve.
SCENARIO_REGISTRY: dict[str, type] = {}


def _register(cls):
    SCENARIO_REGISTRY[cls.name] = cls
    return cls


def resolve_scenarios(spec, base: FleetProblem) -> ScenarioStack:
    """Coerce a ScenarioStack, generator object, registry name, or sequence
    thereof (concatenated) into one materialized `ScenarioStack`."""
    if isinstance(spec, ScenarioStack):
        stack = spec
    elif isinstance(spec, str):
        try:
            gen = SCENARIO_REGISTRY[spec]()
        except KeyError:
            raise ValueError(
                f"unknown scenario generator {spec!r}; registered: "
                f"{', '.join(sorted(SCENARIO_REGISTRY))}") from None
        stack = gen.generate(base)
    elif isinstance(spec, ScenarioGenerator):
        stack = spec.generate(base)
    elif isinstance(spec, (list, tuple)):
        stack = ScenarioStack.concat(
            [resolve_scenarios(s, base) for s in spec], base)
    else:
        raise TypeError(
            f"scenarios must be a ScenarioStack, a ScenarioGenerator, a "
            f"SCENARIO_REGISTRY name, or a sequence of those; got "
            f"{type(spec).__name__}")
    stack.validate(base)
    return stack


def _rng(seed: int, s: int, stream: int = 0) -> np.random.Generator:
    """The subsystem-wide seeding convention: tuple-seeded, never additive."""
    return np.random.default_rng((seed, s, stream))


class _GeneratorBase:
    """Shared generator validation (dataclasses call `__post_init__` from
    the MRO): an empty ensemble is a caller bug, not an empty stack."""

    def __post_init__(self):
        if self.n_scenarios < 1:
            raise ValueError(
                f"{type(self).__name__}.n_scenarios must be >= 1, got "
                f"{self.n_scenarios}")


# ---------------------------------------------------------------------------
# MCI scenario generators
# ---------------------------------------------------------------------------
@_register
@dataclasses.dataclass(frozen=True)
class DuckPerturb(_GeneratorBase):
    """Duck-curve shape uncertainty: per-scenario peak/trough/solar-center
    jitter around the CAISO-2021 anchors (paper Fig. 1 'Today')."""

    n_scenarios: int = 16
    seed: int = 0
    peak_sigma: float = 0.08       # relative peak-level jitter
    trough_sigma: float = 0.08     # absolute trough-fraction jitter
    center_sigma: float = 1.0      # hours of solar-peak timing jitter

    name: ClassVar[str] = "duck_perturb"

    def generate(self, base: FleetProblem) -> ScenarioStack:
        mcis, labels = [], []
        for s in range(self.n_scenarios):
            r = _rng(self.seed, s)
            peak = carbon.CAISO_2021_PEAK * float(
                np.exp(self.peak_sigma * r.standard_normal()))
            trough = float(np.clip(
                carbon.CAISO_2021_TROUGH_FRAC
                + self.trough_sigma * r.standard_normal(), 0.05, 0.95))
            center = 13.0 + self.center_sigma * float(r.standard_normal())
            mcis.append(carbon._duck_curve(
                base.T, peak, trough, solar_center=center,
                seed=(self.seed, s, 1)))
            labels.append(f"duck{s}[p={peak:.0f},t={trough:.2f}]")
        return ScenarioStack(mci=np.stack(mcis), labels=tuple(labels))


@_register
@dataclasses.dataclass(frozen=True)
class RenewableDrought(_GeneratorBase):
    """Renewable-drought days on top of the base MCI: the midday trough
    fills back toward the peak for 1..`max_days` consecutive days."""

    n_scenarios: int = 16
    seed: int = 0
    severity: tuple[float, float] = (0.4, 0.95)
    max_days: int = 2

    name: ClassVar[str] = "renewable_drought"

    def generate(self, base: FleetProblem) -> ScenarioStack:
        n_days = max(1, base.T // base.day_hours)
        mcis, labels = [], []
        for s in range(self.n_scenarios):
            r = _rng(self.seed, s)
            day = int(r.integers(0, n_days))
            span = int(r.integers(1, self.max_days + 1))
            sev = float(r.uniform(*self.severity))
            mcis.append(carbon.apply_drought(
                base.mci, day, n_days=span, severity=sev,
                day_hours=base.day_hours))
            labels.append(f"drought{s}[d{day}+{span},sev={sev:.2f}]")
        return ScenarioStack(mci=np.stack(mcis), labels=tuple(labels))


@_register
@dataclasses.dataclass(frozen=True)
class EveningRampSpike(_GeneratorBase):
    """Evening-ramp spike events: 1..`max_events` multiplicative gaussian
    bumps at random evening hours (17:00–21:00) of random days."""

    n_scenarios: int = 16
    seed: int = 0
    magnitude: tuple[float, float] = (1.2, 1.9)
    max_events: int = 2

    name: ClassVar[str] = "evening_ramp_spike"

    def generate(self, base: FleetProblem) -> ScenarioStack:
        n_days = max(1, base.T // base.day_hours)
        mcis, labels = [], []
        for s in range(self.n_scenarios):
            r = _rng(self.seed, s)
            mci = np.asarray(base.mci, float)
            n_ev = int(r.integers(1, self.max_events + 1))
            for _ in range(n_ev):
                hour = (int(r.integers(0, n_days)) * base.day_hours
                        + int(r.integers(17, 22)))
                mci = carbon.apply_evening_spike(
                    mci, min(hour, base.T - 1),
                    magnitude=float(r.uniform(*self.magnitude)))
            mcis.append(mci)
            labels.append(f"ramp_spike{s}[{n_ev}ev]")
        return ScenarioStack(mci=np.stack(mcis), labels=tuple(labels))


@_register
@dataclasses.dataclass(frozen=True)
class ZeroMciWindow(_GeneratorBase):
    """Zero-MCI solar windows: curtailed renewables set the marginal
    intensity to zero for a midday window (Fig.-11 2050 grids)."""

    n_scenarios: int = 16
    seed: int = 0
    window: tuple[int, int] = (2, 6)   # window length range, hours

    name: ClassVar[str] = "zero_mci_window"

    def generate(self, base: FleetProblem) -> ScenarioStack:
        n_days = max(1, base.T // base.day_hours)
        mcis, labels = [], []
        for s in range(self.n_scenarios):
            r = _rng(self.seed, s)
            length = int(r.integers(self.window[0], self.window[1] + 1))
            start = (int(r.integers(0, n_days)) * base.day_hours
                     + int(r.integers(10, 16 - min(length, 5))))
            mcis.append(carbon.apply_zero_window(base.mci, start, length))
            labels.append(f"zero_mci{s}[{start}h+{length}]")
        return ScenarioStack(mci=np.stack(mcis), labels=tuple(labels))


@_register
@dataclasses.dataclass(frozen=True)
class CambiumMix(_GeneratorBase):
    """Cambium 2024/2050 projection mix: each scenario draws a (year,
    state) pair and a noise seed through `carbon.projection` — the
    Fig.-11 sweep as a sampled distribution instead of a grid."""

    n_scenarios: int = 16
    seed: int = 0
    years: tuple[int, ...] = (2024, 2050)
    states: tuple[str, ...] = carbon.STATES

    name: ClassVar[str] = "cambium_mix"

    def generate(self, base: FleetProblem) -> ScenarioStack:
        mcis, labels = [], []
        for s in range(self.n_scenarios):
            r = _rng(self.seed, s)
            year = int(self.years[int(r.integers(len(self.years)))])
            state = str(self.states[int(r.integers(len(self.states)))])
            sig = carbon.projection(year, state, hours=base.T,
                                    seed=int(r.integers(2 ** 31)))
            mcis.append(sig.mci)
            labels.append(f"cambium{s}[{year}-{state}]")
        return ScenarioStack(mci=np.stack(mcis), labels=tuple(labels))


@_register
@dataclasses.dataclass(frozen=True)
class ForecastRegime(_GeneratorBase):
    """Forecast-error regimes: per-scenario `ForecastStream` revision
    sigma and seed over the base MCI.

    `generate` evaluates the *planning* risk: each scenario's MCI is the
    tick-0 day-ahead forecast a stream of that regime would issue, so the
    static ensemble measures how plans degrade with forecast skill.
    `streams` is the rolling-horizon hook: the S independent streams the
    streaming ensemble (`ensemble.run_streaming_ensemble`) drives through
    batched warm-started ticks."""

    n_scenarios: int = 16
    seed: int = 0
    sigma: tuple[float, float] = (0.01, 0.08)

    name: ClassVar[str] = "forecast_regime"

    def _params(self, s: int) -> tuple[float, int]:
        r = _rng(self.seed, s)
        return float(r.uniform(*self.sigma)), int(r.integers(2 ** 31))

    def streams(self, base: FleetProblem, n_ticks: int = 1):
        """S independent streams over the base MCI (periodically extended
        to cover `n_ticks` rolling solves of `base.T` hours each). A
        multi-region base gets S *groups* of R streams — one stream per
        region sharing the scenario's revision sigma (seeds offset per
        region so regional errors stay independent), the shape
        `ensemble.run_streaming_ensemble` and `RollingHorizonSolver`
        expect."""
        actual = np.asarray(base.mci, float)
        if actual.ndim == 2:       # multi-region: S groups of R streams
            reps = -(-(n_ticks + base.T - 1) // actual.shape[1])
            tiled = np.tile(actual, (1, max(reps, 1)))
            out = []
            for s in range(self.n_scenarios):
                sig, sd = self._params(s)
                out.append(tuple(
                    ForecastStream(actual=tiled[r], horizon=base.T,
                                   revision_sigma=sig, seed=sd + r)
                    for r in range(tiled.shape[0])))
            return tuple(out)
        reps = -(-(n_ticks + base.T - 1) // actual.shape[0])
        actual = np.tile(actual, max(reps, 1))
        out = []
        for s in range(self.n_scenarios):
            sig, sd = self._params(s)
            out.append(ForecastStream(actual=actual, horizon=base.T,
                                      revision_sigma=sig, seed=sd))
        return tuple(out)

    def generate(self, base: FleetProblem) -> ScenarioStack:
        streams = self.streams(base)
        if base.is_multiregion:
            mcis = np.stack([[st.forecast(0) for st in g]
                             for g in streams])
            labels = tuple(f"forecast{i}[sigma={g[0].revision_sigma:.3f}]"
                           for i, g in enumerate(streams))
        else:
            mcis = np.stack([st.forecast(0) for st in streams])
            labels = tuple(f"forecast{i}[sigma={st.revision_sigma:.3f}]"
                           for i, st in enumerate(streams))
        return ScenarioStack(mci=mcis, labels=labels)


@_register
@dataclasses.dataclass(frozen=True)
class RegionalDivergence(_GeneratorBase):
    """Cross-region grid divergence over a multi-region base: each
    scenario jitters every region's MCI trace independently — a
    per-region level scale plus a per-region midday trough fill — so
    the ensemble spans futures where the regional carbon spread (the
    signal the migration lever arbitrages) widens, narrows, or flips.
    Requires a multi-region base (`mci` of shape (R, T)); overlays are
    (S, R, T)."""

    n_scenarios: int = 16
    seed: int = 0
    level_sigma: float = 0.10    # per-region multiplicative level jitter
    trough_sigma: float = 0.25   # per-region trough-fill severity scale

    name: ClassVar[str] = "regional_divergence"

    def generate(self, base: FleetProblem) -> ScenarioStack:
        if not base.is_multiregion:
            raise ValueError(
                "RegionalDivergence needs a multi-region base problem "
                "(mci of shape (R, T)); build one with "
                "fleet_solver.regional_fleet / synthetic_regional_fleet")
        mci = np.asarray(base.mci, float)
        R = mci.shape[0]
        n_days = max(1, base.T // base.day_hours)
        mcis, labels = [], []
        for s in range(self.n_scenarios):
            rows = []
            for reg in range(R):
                r = _rng(self.seed, s, reg + 1)
                level = float(np.exp(
                    self.level_sigma * r.standard_normal()))
                sev = float(np.clip(
                    self.trough_sigma * abs(r.standard_normal()), 0.0, 0.95))
                row = mci[reg] * level
                if sev > 0.0:
                    row = carbon.apply_drought(
                        row, 0, n_days=n_days, severity=sev,
                        day_hours=base.day_hours)
                rows.append(row)
            mcis.append(np.stack(rows))
            labels.append(f"regional_div{s}")
        return ScenarioStack(mci=np.stack(mcis), labels=tuple(labels))


# ---------------------------------------------------------------------------
# Fleet scenario generators
# ---------------------------------------------------------------------------
@_register
@dataclasses.dataclass(frozen=True)
class FleetJitter(_GeneratorBase):
    """Fleet composition uncertainty: per-workload multiplicative scale
    jitter on usage (jobs track usage, as in `synthetic_fleet`) and —
    independently — on entitlements. Because the two draws are
    independent, usage can exceed its reservation in some scenarios:
    exactly the overload futures the risk report is meant to surface
    (`usage_sigma > 0, entitlement_sigma = 0` jitters demand against
    fixed reservations)."""

    n_scenarios: int = 16
    seed: int = 0
    usage_sigma: float = 0.15
    entitlement_sigma: float = 0.05

    name: ClassVar[str] = "fleet_jitter"

    def generate(self, base: FleetProblem) -> ScenarioStack:
        usage = np.asarray(base.usage, float)
        ent = np.asarray(base.entitlement, float)
        jobs = np.asarray(base.jobs, float)
        us, es, js = [], [], []
        for s in range(self.n_scenarios):
            r = _rng(self.seed, s)
            fu = np.exp(self.usage_sigma * r.standard_normal(base.W))
            fe = np.exp(self.entitlement_sigma * r.standard_normal(base.W))
            us.append(usage * fu[:, None])
            js.append(jobs * fu[:, None])
            es.append(ent * fe)
        labels = tuple(f"fleet_jitter[{s}]"
                       for s in range(self.n_scenarios))
        return ScenarioStack(usage=np.stack(us), entitlement=np.stack(es),
                             jobs=np.stack(js), labels=labels)


@_register
@dataclasses.dataclass(frozen=True)
class FlexMixShift(_GeneratorBase):
    """Flex-fraction and batch/online mix shifts.

    Per scenario: (a) an operational `upper` cap = flex·usage — only a
    drawn fraction of each workload's power is actually sheddable by
    throttling; (b) a batch-share factor scaling batch workloads' usage
    up and online workloads' down (or vice versa), shifting how much of
    the fleet's power is deferrable."""

    n_scenarios: int = 16
    seed: int = 0
    flex: tuple[float, float] = (0.25, 0.7)
    mix_sigma: float = 0.2

    name: ClassVar[str] = "flex_mix_shift"

    def generate(self, base: FleetProblem) -> ScenarioStack:
        usage = np.asarray(base.usage, float)
        jobs = np.asarray(base.jobs, float)
        is_batch = np.asarray(base.is_batch, bool)
        base_upper = None if base.upper is None \
            else np.asarray(base.upper, float)
        us, js, ups, labels = [], [], [], []
        for s in range(self.n_scenarios):
            r = _rng(self.seed, s)
            mix = float(np.exp(self.mix_sigma * r.standard_normal()))
            scale = np.where(is_batch, mix, 1.0 / mix)[:, None]
            u = usage * scale
            flex = r.uniform(*self.flex, size=base.W)[:, None]
            upper = flex * u
            if base_upper is not None:
                upper = np.minimum(upper, base_upper * scale)
            us.append(u)
            js.append(jobs * scale)
            ups.append(upper)
            labels.append(f"flex_mix{s}[batch x{mix:.2f}]")
        return ScenarioStack(usage=np.stack(us), jobs=np.stack(js),
                             upper=np.stack(ups), labels=tuple(labels))
