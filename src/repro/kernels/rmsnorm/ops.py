"""jit'd wrapper for the fused RMSNorm kernel (any leading batch dims)."""
from __future__ import annotations

import functools

import jax

from repro.kernels.dispatch import interpret_default
from repro.kernels.rmsnorm.kernel import rmsnorm_pallas


@functools.partial(jax.jit, static_argnames=("eps", "interpret"))
def _rmsnorm_jit(x, scale, eps: float, interpret: bool):
    shape = x.shape
    flat = x.reshape(-1, shape[-1])
    out = rmsnorm_pallas(flat, scale, eps=eps, interpret=interpret)
    return out.reshape(shape)


def rmsnorm(x, scale, eps: float = 1e-6, interpret: bool | None = None):
    # interpret resolved outside jit so env overrides aren't masked by a
    # trace cached under the `None` key.
    return _rmsnorm_jit(x, scale, eps, interpret_default(interpret))
