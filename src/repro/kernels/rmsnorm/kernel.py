"""Pallas TPU fused RMSNorm kernel.

Fuses the mean-square reduction, rsqrt, and scale multiply in one VMEM pass
(the unfused jnp version reads x twice and materializes the fp32 upcast in
HBM). Grid tiles rows; the feature dim stays resident in VMEM (d_model ≤
8192 ⇒ ≤ 4 MB fp32 per (128, d) tile).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu  # noqa: F401

from repro.kernels.dispatch import tpu_compiler_params


def _rmsnorm_kernel(x_ref, scale_ref, o_ref, *, eps: float):
    x = x_ref[...].astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    y = x * jax.lax.rsqrt(var + eps)
    o_ref[...] = (y * scale_ref[...].astype(jnp.float32)).astype(o_ref.dtype)


def rmsnorm_pallas(x, scale, eps: float = 1e-6, block_rows: int = 128,
                   interpret: bool = True):
    """x: (N, d); scale: (d,) -> (N, d)."""
    N, d = x.shape
    pad = (-N) % block_rows
    xp = jnp.pad(x, ((0, pad), (0, 0)))
    rows = xp.shape[0] // block_rows
    out = pl.pallas_call(
        functools.partial(_rmsnorm_kernel, eps=eps),
        grid=(rows,),
        in_specs=[pl.BlockSpec((block_rows, d), lambda i: (i, 0)),
                  pl.BlockSpec((d,), lambda i: (0,))],
        out_specs=pl.BlockSpec((block_rows, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct(xp.shape, x.dtype),
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel",)),
        interpret=interpret,
    )(xp, scale)
    return out[:N]
