"""Pure-jnp oracle for the fused AL inner-step kernel.

Mirrors `kernel.py` op-for-op on (W, T) arrays: the analytic augmented-
Lagrangian gradient (CR1's fixed-weight penalty or CR2's equality-
multiplier form), a bias-corrected Adam update, and the box +
day-mean-preserving projection — `k_steps` of them per call, carrying
(x, m, v) exactly like one kernel invocation does.

The gradient/projection math is *shared* with the kernel body (it
imports `_pen_and_grad` / `_project` from here) — deliberately: the
analytic subgradient is discontinuous at hinge boundaries, so a 1-ulp
difference between two formulations (e.g. reshape-mean vs matmul-mean
day averaging) can flip an active-hinge indicator after a few steps and
blow a bitwise-tight parity budget on nothing. Kernel-vs-ref therefore
checks the *tiling/padding/memory movement* (what Pallas adds), while
the semantic check against an independent implementation — autodiff of
`fleet_penalties` through the generic engine inner loop — lives in the
fused-vs-generic solve-level tests with an appropriately loose
tolerance.

Gradient convention: hinge boundaries use the strict `>` subgradient
(zero at the tie), matching the analytic custom VJP in
`kernels/dr_features/ops.py` — NOT jnp autodiff of `max`, which emits
0.5 at exact ties (this only differs on measure-zero inputs like the
all-zeros cold start).

Row-parameter packing (see `ops.pack_rows`) — `rowp` is (W, 12) f32:

  col 0-2   rts_coeffs (a3, a2, a1)
  col 3-5   betas (b0, b1, b2)
  col 6     k (annual job volume scale)
  col 7     x2_kind (>0.5: wait_sq, else njobs_delayed)
  col 8     is_batch (>0.5: batch penalty + day-mean projection)
  col 9     refs (CR2 per-workload penalty reference; 0 for CR1)
  col 10    lam_eq (CR2 equality multiplier, refreshed per outer round)
  col 11    step multiplier (per-row learning-rate scale; all-ones when
            the step scale is the fleet-global scalar folded into
            `lr_scale` — x·1.0 is exact, so the scalar path is bitwise
            the pre-col-11 kernel)

Scalar packing — `scal` is (1, 8) f32:

  [coef0, mu, inv_scale, lr_scale, t0, 0, 0, 0]

where `coef0 = lam * pen_norm` (CR1 penalty weight; unused for CR2),
`inv_scale = 1/scale` (CR2 residual normalizer; unused for CR1),
`lr_scale = cfg.lr * step_scale`, and `t0` is the Adam step count already
taken this outer round (bias correction resumes at t0 + 1).

`cvec` is (1, T) — or (W, T) for per-row carbon weights (multi-region
fleets, where each row prices carbon on its region's normalizer and
trace). Multi-region per-ROW penalty weights reach the same scalar slots
by folding: CR1 folds `lam·pen_w` into col-6 `k` (the gradient is linear
in k) with `coef0 = 1`; CR2 folds `1/scale_w` into `k` and `refs` with
`inv_scale = 1` (h and coef·dpen are unchanged algebraically — see
`api._al_fused_inner`). The kernel itself stays region-blind.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def _revcum(x):
    return jnp.flip(jnp.cumsum(jnp.flip(x, axis=1), axis=1), axis=1)


def _pen_and_grad(x, inv_u, ju, rowp):
    """Fleet penalty vector (W, 1) and its analytic gradient (W, T).

    Same math as `fleet_solver.fleet_penalties` + the dr_features custom
    VJP, fused: RTS rows get the cubic smooth penalty, batch rows the
    hinged linear model over the queue-integral features.
    """
    a3, a2, a1 = rowp[:, 0:1], rowp[:, 1:2], rowp[:, 2:3]
    b0, b1, b2 = rowp[:, 3:4], rowp[:, 4:5], rowp[:, 5:6]
    kk, x2k, isb = rowp[:, 6:7], rowp[:, 7:8], rowp[:, 8:9]

    # RTS: pen = k·Σ_t a3·δ³ + a2·δ² + a1·δ, δ = d/usage.
    delta = x * inv_u
    rts_pen = kk * (a3 * delta ** 3 + a2 * delta ** 2
                    + a1 * delta).sum(axis=1, keepdims=True)
    rts_g = kk * (3.0 * a3 * delta ** 2 + 2.0 * a2 * delta + a1) * inv_u

    # Batch: pen = k·max(b0 + b1·wait_power + b2·x2, 0).
    c1 = jnp.cumsum(x, axis=1)
    x1 = jnp.maximum(c1, 0.0).sum(axis=1, keepdims=True)
    c2 = jnp.cumsum(ju * x * jnp.abs(x), axis=1)
    x2s = jnp.maximum(c2, 0.0).sum(axis=1, keepdims=True)
    nj = (ju * jnp.maximum(x, 0.0)).sum(axis=1, keepdims=True)
    x2 = jnp.where(x2k > 0.5, x2s, nj)
    z = b0 + b1 * x1 + b2 * x2
    batch_pen = kk * jnp.maximum(z, 0.0)

    g1 = _revcum((c1 > 0).astype(x.dtype))
    g2s = 2.0 * ju * jnp.abs(x) * _revcum((c2 > 0).astype(x.dtype))
    g2n = ju * (x > 0).astype(x.dtype)
    gx2 = jnp.where(x2k > 0.5, g2s, g2n)
    batch_g = kk * (z > 0).astype(x.dtype) * (b1 * g1 + b2 * gx2)

    pen = jnp.where(isb > 0.5, batch_pen, rts_pen)
    dpen = jnp.where(isb > 0.5, batch_g, rts_g)
    return pen, dpen


def _day_mask(T, day_hours):
    """Static (n_days, T) day-membership mask: mask[d, t] = 1 iff hour t
    belongs to day d (hours past the last whole day belong to none).
    Built with `broadcasted_iota` so the same code runs inside a Pallas
    kernel body (no reshapes, which the TPU vector layout dislikes)."""
    n_days = max(1, T // day_hours)
    span = n_days * day_hours
    drow = jax.lax.broadcasted_iota(jnp.int32, (n_days, T), 0)
    tcol = jax.lax.broadcasted_iota(jnp.int32, (n_days, T), 1)
    return jnp.where((tcol // day_hours == drow) & (tcol < span),
                     jnp.float32(1.0), jnp.float32(0.0))


def _project(x, lo, hi, isb, day_hours):
    """Box clip + 3 rounds of day-mean removal for batch rows — the same
    fixed-point iteration as `fleet_solver._projection`, with day means
    expressed as two matmuls against the static day mask instead of a
    reshape (TPU-layout-friendly; shared by the kernel body)."""
    f32 = jnp.float32
    mask = _day_mask(x.shape[1], day_hours)
    batch_rows = isb > 0.5
    x = jnp.clip(x, lo, hi)
    for _ in range(3):
        mean = jax.lax.dot_general(
            x, mask, (((1,), (1,)), ((), ())),
            preferred_element_type=f32) * (1.0 / day_hours)
        sub = jax.lax.dot_general(
            mean, mask, (((1,), (0,)), ((), ())),
            preferred_element_type=f32)
        x = jnp.clip(jnp.where(batch_rows, x - sub, x), lo, hi)
    return x


def al_step_ref(x, m, v, usage, jobs, lo, hi, rowp, cvec, scal, *,
                mode: str, k_steps: int, beta1: float = 0.9,
                beta2: float = 0.999, eps: float = 1e-8,
                day_hours: int = 24):
    """Run `k_steps` fused projected-Adam AL steps; returns (x, m, v).

    x: (W, T) f32 primal iterate; m/v: (W, T) Adam moments (any float
    dtype — up-cast to f32 for arithmetic, stored back in their dtype);
    cvec: (1, T) carbon gradient term (−car_norm·mci), or (W, T) for
    per-row carbon weights; rowp/scal: packed parameters, see module
    docstring.
    """
    if mode not in ("cr1", "cr2"):
        raise ValueError(f"mode must be cr1|cr2, got {mode!r}")
    f32 = jnp.float32
    x = x.astype(f32)
    mdt = m.dtype
    m = m.astype(f32)
    v = v.astype(f32)
    inv_u = 1.0 / usage.astype(f32)
    ju = jobs.astype(f32) * inv_u
    isb = rowp[:, 8:9]
    refs, lam_eq, stepw = rowp[:, 9:10], rowp[:, 10:11], rowp[:, 11:12]
    coef0, mu = scal[0, 0], scal[0, 1]
    inv_scale, lr_scale, t0 = scal[0, 2], scal[0, 3], scal[0, 4]
    lb1, lb2 = jnp.log(f32(beta1)), jnp.log(f32(beta2))

    for i in range(k_steps):
        pen, dpen = _pen_and_grad(x, inv_u, ju, rowp)
        if mode == "cr1":
            coef = coef0
        else:
            # L = obj + lam_eq·h + (mu/2)·h², h = (pen − refs)/scale
            # ⇒ ∂L/∂pen = (lam_eq + mu·h)/scale.
            h = (pen - refs) * inv_scale
            coef = (lam_eq + mu * h) * inv_scale
        g = coef * dpen + cvec
        t = t0 + f32(i + 1)
        m = beta1 * m + (1.0 - beta1) * g
        v = beta2 * v + (1.0 - beta2) * g * g
        mhat = m / (1.0 - jnp.exp(t * lb1))
        vhat = v / (1.0 - jnp.exp(t * lb2))
        x = _project(x - lr_scale * stepw * mhat / (jnp.sqrt(vhat) + eps),
                     lo, hi, isb, day_hours)
    return x, m.astype(mdt), v.astype(mdt)
