"""Chunked dispatch for the fused AL inner-step kernel.

`make_fused_inner` packages a fleet's static arrays into the kernel's
packed layout once, and returns a `fused_inner(x, lam_eq, lam_in, mu)`
callback for `engine.al_minimize`: a `lax.scan` of `inner_steps /
k_steps` kernel invocations carrying (x, m, v, t) — the Adam step count
threads through so bias correction is identical to one long loop. Fresh
(zero) moments per call match the engine contract (moments reset every
outer multiplier round).

Everything here is pure jnp + `pallas_call`, so the callback is safe
under `jit`, `vmap` (λ/cap sweeps and scenario ensembles batch the
packed scalars), and inside `shard_map` bodies (each device runs the
kernel on its local row block).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.al_step.kernel import al_step_pallas
from repro.kernels.al_step.ref import al_step_ref
from repro.kernels.dispatch import interpret_default


def pack_rows(rts_coeffs, betas, k, x2_kind, is_batch, refs=None):
    """Per-workload penalty parameters -> the (W, 10) static row block
    (cols 0-9 of the kernel's `rowp`; see `ref.py` for the layout).
    `refs=None` fills zeros (CR1 has no per-row reference)."""
    f32 = jnp.float32
    k = jnp.asarray(k, f32)[:, None]
    x2 = jnp.asarray(x2_kind, f32)[:, None]
    isb = jnp.asarray(is_batch, f32)[:, None]
    r = (jnp.zeros_like(k) if refs is None
         else jnp.asarray(refs, f32)[:, None])
    return jnp.concatenate([jnp.asarray(rts_coeffs, f32),
                            jnp.asarray(betas, f32), k, x2, isb, r], axis=1)


def make_fused_inner(usage, jobs, lo, hi, row_base, cvec, *, mode: str,
                     cfg, step_scale, coef0=0.0, scale=None,
                     k_steps: int = 8, block_w: int | None = None,
                     interpret: bool | None = None, use_ref: bool = False,
                     day_hours: int = 24):
    """Build the `fused_inner` hook for `engine.al_minimize`.

    Args:
      usage/jobs/lo/hi: (W, T) fleet constants (bounds from
        `fleet_solver._bounds`).
      row_base: (W, 10) from `pack_rows` (CR2 passes `refs` there).
      cvec: (1, T) carbon gradient term, i.e. `-car_norm * mci[None, :]`,
        or (W, T) per-row carbon weights (multi-region fleets).
      mode: "cr1" (fixed penalty weight `coef0 = lam * pen_norm`) or
        "cr2" (equality-multiplier form; needs `scale`).
      cfg: `EngineConfig` — supplies inner_steps, lr, betas, eps and the
        moment storage dtype.
      step_scale: the adapter's step scale (multiplies cfg.lr). A scalar
        folds into the packed `lr_scale` and rowp col 11 packs ones
        (bitwise the scalar kernel: x·1.0 is exact); a (W, 1) per-row
        vector rides in col 11 with `lr_scale = cfg.lr`.
      k_steps: fused steps per kernel invocation; `inner_steps` need not
        divide evenly — the remainder runs as one short call.
      use_ref: route through the jnp oracle instead of Pallas (parity
        harnesses; identical call structure).

    The returned callback runs exactly `cfg.inner_steps` projected-Adam
    steps from zero moments and returns the new x (f32).
    """
    W, T = usage.shape
    f32 = jnp.float32
    mdt = jnp.dtype(cfg.moment_dtype)
    inv_scale = 0.0 if scale is None else 1.0 / scale
    if jnp.ndim(step_scale) == 0:
        lr_scale = cfg.lr * step_scale
        step_col = jnp.ones((W, 1), f32)
    else:
        lr_scale = jnp.asarray(cfg.lr, f32)
        step_col = jnp.asarray(step_scale, f32).reshape(W, 1)
    steps = int(cfg.inner_steps)
    k_steps = max(1, min(int(k_steps), steps))
    n_full, rem = divmod(steps, k_steps)
    if not use_ref:
        interpret = interpret_default(interpret)

    def call(x, m, v, rowp, mu, t0, n):
        vals = (coef0, mu, inv_scale, lr_scale, t0, 0.0, 0.0, 0.0)
        scal = jnp.stack([jnp.asarray(s, jnp.float32).reshape(())
                          for s in vals]).reshape(1, 8)
        kw = dict(mode=mode, k_steps=n, beta1=cfg.beta1, beta2=cfg.beta2,
                  eps=cfg.eps, day_hours=day_hours)
        if use_ref:
            return al_step_ref(x, m, v, usage, jobs, lo, hi, rowp, cvec,
                               scal, **kw)
        return al_step_pallas(x, m, v, usage, jobs, lo, hi, rowp, cvec,
                              scal, block_w=block_w, interpret=interpret,
                              **kw)

    def fused_inner(x, lam_eq, lam_in, mu):
        del lam_in  # CR1/CR2 carry no inequality multipliers
        x = x.astype(jnp.float32)
        if mode == "cr2":
            lam_col = lam_eq.astype(jnp.float32).reshape(W, 1)
        else:
            lam_col = jnp.zeros((W, 1), jnp.float32)
        rowp = jnp.concatenate([row_base, lam_col, step_col], axis=1)
        m0 = jnp.zeros((W, T), mdt)
        v0 = jnp.zeros((W, T), mdt)

        def chunk(c, _):
            xx, mm, vv, t0 = c
            xx, mm, vv = call(xx, mm, vv, rowp, mu, t0, k_steps)
            return (xx, mm, vv, t0 + jnp.asarray(k_steps, jnp.float32)), None

        c = (x, m0, v0, jnp.asarray(0.0, jnp.float32))
        if n_full:
            c, _ = jax.lax.scan(chunk, c, None, length=n_full)
        xx, mm, vv, t0 = c
        if rem:
            xx, _, _ = call(xx, mm, vv, rowp, mu, t0, rem)
        return xx

    return fused_inner
