"""Pallas TPU kernel for the fused CR1/CR2 AL inner step.

The engine's hot loop (`engine.al_minimize`) re-dispatches a chain of
~10 small elementwise/reduce ops per projected-Adam step — each one a
round-trip of (W, T) intermediates through HBM. This kernel fuses one
full inner step on a (block_w, T) workload tile held in VMEM:

  1. analytic augmented-Lagrangian gradient (RTS cubic + hinged batch
     queue-integral penalties, CR1 fixed-weight or CR2 multiplier form),
  2. bias-corrected Adam moment update,
  3. the box + day-mean-preserving projection,

and unrolls `k_steps` of them per invocation, so x and the Adam moments
(m, v) never leave VMEM between steps. `al_minimize`'s inner scan then
makes `inner_steps / k_steps` kernel calls instead of dispatching
`inner_steps × ~10` ops.

The day-mean projection is expressed as two matmuls against a static
(n_days, T) day-membership mask built with `broadcasted_iota` — no
reshapes, which the TPU vector layout dislikes. The gradient/projection
math is imported from `ref.py` so kernel-vs-oracle parity isolates what
Pallas adds (tiling, padding, VMEM residency); see the note there on
why the hinge subgradient makes formulation-level diffs chaotic.

Packed-parameter layout (`rowp` (W, 12), `scal` (1, 8)) is documented in
`ref.py`; `ops.pack_rows` builds the static row block.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu  # noqa: F401

from repro.kernels.al_step.ref import _pen_and_grad, _project
from repro.kernels.dispatch import tpu_compiler_params


def _al_step_kernel(x_ref, m_ref, v_ref, u_ref, j_ref, lo_ref, hi_ref,
                    rowp_ref, cvec_ref, scal_ref, xo_ref, mo_ref, vo_ref,
                    *, mode: str, k_steps: int, beta1: float, beta2: float,
                    eps: float, day_hours: int):
    f32 = jnp.float32
    x = x_ref[...].astype(f32)
    m = m_ref[...].astype(f32)
    v = v_ref[...].astype(f32)
    u = u_ref[...].astype(f32)
    lo = lo_ref[...].astype(f32)
    hi = hi_ref[...].astype(f32)
    rowp = rowp_ref[...].astype(f32)
    cvec = cvec_ref[...].astype(f32)
    scal = scal_ref[...].astype(f32)

    inv_u = 1.0 / u
    ju = j_ref[...].astype(f32) * inv_u
    isb = rowp[:, 8:9]
    refs, lam_eq, stepw = rowp[:, 9:10], rowp[:, 10:11], rowp[:, 11:12]
    coef0, mu = scal[0, 0], scal[0, 1]
    inv_scale, lr_scale, t0 = scal[0, 2], scal[0, 3], scal[0, 4]
    lb1, lb2 = jnp.log(f32(beta1)), jnp.log(f32(beta2))

    for i in range(k_steps):
        pen, dpen = _pen_and_grad(x, inv_u, ju, rowp)
        if mode == "cr1":
            coef = coef0
        else:
            h = (pen - refs) * inv_scale
            coef = (lam_eq + mu * h) * inv_scale
        g = coef * dpen + cvec
        t = t0 + f32(i + 1)
        m = beta1 * m + (1.0 - beta1) * g
        v = beta2 * v + (1.0 - beta2) * g * g
        mhat = m / (1.0 - jnp.exp(t * lb1))
        vhat = v / (1.0 - jnp.exp(t * lb2))
        x = _project(x - lr_scale * stepw * mhat / (jnp.sqrt(vhat) + eps),
                     lo, hi, isb, day_hours)

    xo_ref[...] = x
    mo_ref[...] = m.astype(mo_ref.dtype)
    vo_ref[...] = v.astype(vo_ref.dtype)


def al_step_pallas(x, m, v, usage, jobs, lo, hi, rowp, cvec, scal, *,
                   mode: str, k_steps: int, beta1: float = 0.9,
                   beta2: float = 0.999, eps: float = 1e-8,
                   day_hours: int = 24, block_w: int | None = None,
                   interpret: bool | None = None):
    """`k_steps` fused AL inner steps on (W, T) tiles; returns (x, m, v).

    Same signature/semantics as `ref.al_step_ref` plus tiling knobs.
    Padding: W to block_w — usage pads with ones (no 0/0), lo = hi = 0
    pins padded rows at zero, rowp pads with zeros (k = 0 ⇒ no penalty).
    `cvec` may be (1, T) (fleet-global carbon term, replicated to every
    tile) or (W, T) (per-row carbon weights, tiled like x and zero-padded
    — padded rows are pinned anyway). `block_w=None` picks min(128, W
    rounded up to 16) — the bf16 sublane floor, so bf16 moment tiles stay
    legal. `interpret=None` resolves backend-aware via
    `repro.kernels.dispatch.interpret_default`.
    """
    if interpret is None:
        from repro.kernels.dispatch import interpret_default
        interpret = interpret_default()
    W, T = x.shape
    if block_w is None:
        block_w = min(128, -(-W // 16) * 16)
    pw = (-W) % block_w

    def pad(a, cv=0.0):
        return jnp.pad(a, ((0, pw), (0, 0)), constant_values=cv)

    nw = (W + pw) // block_w
    kern = functools.partial(_al_step_kernel, mode=mode, k_steps=k_steps,
                             beta1=beta1, beta2=beta2, eps=eps,
                             day_hours=day_hours)

    def row(cols):
        return pl.BlockSpec((block_w, cols), lambda i: (i, 0))

    def rep(cols):
        return pl.BlockSpec((1, cols), lambda i: (0, 0))

    if cvec.shape[0] == 1:
        cvec_spec = rep(T)
    else:
        cvec_spec, cvec = row(T), pad(cvec)

    out = pl.pallas_call(
        kern,
        grid=(nw,),
        in_specs=[row(T)] * 7 + [row(rowp.shape[1]), cvec_spec, rep(8)],
        out_specs=[row(T)] * 3,
        out_shape=[jax.ShapeDtypeStruct((W + pw, T), jnp.float32),
                   jax.ShapeDtypeStruct((W + pw, T), m.dtype),
                   jax.ShapeDtypeStruct((W + pw, T), v.dtype)],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel",)),
        interpret=interpret,
    )(pad(x), pad(m), pad(v), pad(usage, 1.0), pad(jobs), pad(lo),
      pad(hi), pad(rowp), cvec, scal)
    return out[0][:W], out[1][:W], out[2][:W]
