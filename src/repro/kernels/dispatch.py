"""Backend-aware Pallas dispatch shared by all kernel wrappers.

Pallas kernels compile for real on TPU and fall back to interpret mode
elsewhere (CPU containers, CI). `REPRO_PALLAS_INTERPRET` overrides the
auto-detection in both directions: truthy forces interpret mode even on
TPU (debugging), falsy forces the compiled path.
"""
from __future__ import annotations

import os

import jax

_FALSY = ("0", "false", "no", "off")


def on_tpu() -> bool:
    try:
        return jax.default_backend() == "tpu"
    except RuntimeError:
        return False


def interpret_default(interpret: bool | None = None) -> bool:
    """Resolve an interpret flag: explicit > env override > backend."""
    if interpret is not None:
        return bool(interpret)
    env = os.environ.get("REPRO_PALLAS_INTERPRET")
    if env is not None:
        return env.strip().lower() not in _FALSY
    return not on_tpu()


def tpu_compiler_params(**kwargs):
    """`pltpu.CompilerParams` across jax versions (older: TPUCompilerParams)."""
    from jax.experimental.pallas import tpu as pltpu
    cls = getattr(pltpu, "CompilerParams", None) \
        or getattr(pltpu, "TPUCompilerParams")
    return cls(**kwargs)
