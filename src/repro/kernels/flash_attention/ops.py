"""jit'd public wrapper for the flash-attention kernel.

Accepts model-layout tensors (B, S, H, Dh) and handles the (B, H, S, Dh)
kernel layout, GQA head mapping, and interpret-mode selection (CPU container
-> interpret=True; real TPU -> compiled kernel).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.dispatch import interpret_default
from repro.kernels.flash_attention.kernel import flash_attention_pallas


@functools.partial(jax.jit, static_argnames=("causal", "block_q", "block_k",
                                             "interpret"))
def _flash_attention_jit(q, k, v, causal: bool, block_q: int, block_k: int,
                         interpret: bool):
    qt = q.transpose(0, 2, 1, 3)
    kt = k.transpose(0, 2, 1, 3)
    vt = v.transpose(0, 2, 1, 3)
    out = flash_attention_pallas(qt, kt, vt, causal=causal, block_q=block_q,
                                 block_k=block_k, interpret=interpret)
    return out.transpose(0, 2, 1, 3)


def flash_attention(q, k, v, causal: bool = True, block_q: int = 128,
                    block_k: int = 128, interpret: bool | None = None):
    """q: (B, Sq, H, Dh); k/v: (B, Skv, KV, Dh/Dv) -> (B, Sq, H, Dv).

    interpret resolved outside jit so env overrides aren't masked by a
    trace cached under the `None` key."""
    return _flash_attention_jit(q, k, v, causal, block_q, block_k,
                                interpret_default(interpret))
