"""jit'd public wrapper for the flash-attention kernel.

Accepts model-layout tensors (B, S, H, Dh) and handles the (B, H, S, Dh)
kernel layout, GQA head mapping, and interpret-mode selection (CPU container
-> interpret=True; real TPU -> compiled kernel).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention.kernel import flash_attention_pallas


def _on_tpu() -> bool:
    try:
        return jax.devices()[0].platform == "tpu"
    except RuntimeError:
        return False


@functools.partial(jax.jit, static_argnames=("causal", "block_q", "block_k",
                                             "interpret"))
def flash_attention(q, k, v, causal: bool = True, block_q: int = 128,
                    block_k: int = 128, interpret: bool | None = None):
    """q: (B, Sq, H, Dh); k/v: (B, Skv, KV, Dh/Dv) -> (B, Sq, H, Dv)."""
    if interpret is None:
        interpret = not _on_tpu()
    qt = q.transpose(0, 2, 1, 3)
    kt = k.transpose(0, 2, 1, 3)
    vt = v.transpose(0, 2, 1, 3)
    out = flash_attention_pallas(qt, kt, vt, causal=causal, block_q=block_q,
                                 block_k=block_k, interpret=interpret)
    return out.transpose(0, 2, 1, 3)
