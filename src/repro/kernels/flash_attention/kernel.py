"""Pallas TPU flash-attention (FA2-style) kernel.

Grid: (B, H, num_q_blocks, num_kv_blocks) — the kv dimension is innermost
("arbitrary" semantics) and carries running max / denominator / accumulator
in VMEM scratch across its iterations. Causal blocks that are fully masked
are skipped with pl.when. BlockSpecs tile (S, Dh) into (block_q, Dh) /
(block_k, Dh) VMEM-resident tiles; Dh is always ≤ 256 so a (128, Dh) tile is
well within VMEM, and block sizes are multiples of the 128-lane MXU width.

Validated in interpret mode against `ref.attention_ref` (CPU container);
TPU is the compilation target.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu  # noqa: F401

from repro.kernels.dispatch import tpu_compiler_params

NEG_INF = -1e30


def _fa_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
               causal: bool, scale: float, block_q: int, block_k: int,
               seq_q: int, seq_kv: int, q_offset: int):
    iq = pl.program_id(2)
    ik = pl.program_id(3)
    nk = pl.num_programs(3)

    @pl.when(ik == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q_pos = q_offset + iq * block_q + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, block_k), 0)
    kv_pos = ik * block_k + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, block_k), 1)
    # Skip blocks that are entirely above the causal diagonal.
    first_q = q_offset + iq * block_q
    last_q = first_q + block_q - 1
    first_kv = ik * block_k
    run = (first_kv <= last_q) if causal else True

    @pl.when(run)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)
        k = k_ref[0, 0].astype(jnp.float32)
        v = v_ref[0, 0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        dead = kv_pos >= seq_kv
        if causal:
            dead = dead | (kv_pos > q_pos)
        s = jnp.where(dead, NEG_INF, s)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, s.max(axis=1, keepdims=True))
        p = jnp.exp(s - m_new)
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * corr + p.sum(axis=1, keepdims=True)
        acc_ref[...] = acc_ref[...] * corr + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(ik == nk - 1)
    def _finalize():
        o_ref[0, 0] = (acc_ref[...]
                       / jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


def flash_attention_pallas(q, k, v, *, causal: bool = True,
                           block_q: int = 128, block_k: int = 128,
                           q_offset: int = 0, interpret: bool = True):
    """q: (B, H, Sq, Dh); k/v: (B, KV, Skv, Dh/Dv). Returns (B, H, Sq, Dv).

    H % KV == 0 (GQA). Sequences are padded to block multiples here and
    un-padded on return; masking handles the tail.
    """
    B, H, Sq, Dh = q.shape
    _, KV, Skv, _ = k.shape
    Dv = v.shape[-1]
    groups = H // KV
    scale = 1.0 / math.sqrt(Dh)
    pq = (-Sq) % block_q
    pk = (-Skv) % block_k
    qp = jnp.pad(q, ((0, 0), (0, 0), (0, pq), (0, 0)))
    kp = jnp.pad(k, ((0, 0), (0, 0), (0, pk), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, 0), (0, pk), (0, 0)))
    nq = qp.shape[2] // block_q
    nk = kp.shape[2] // block_k

    kernel = functools.partial(
        _fa_kernel, causal=causal, scale=scale, block_q=block_q,
        block_k=block_k, seq_q=Sq, seq_kv=Skv, q_offset=q_offset)

    out = pl.pallas_call(
        kernel,
        grid=(B, H, nq, nk),
        in_specs=[
            pl.BlockSpec((1, 1, block_q, Dh),
                         lambda b, h, iq, ik: (b, h, iq, 0)),
            pl.BlockSpec((1, 1, block_k, Dh),
                         lambda b, h, iq, ik, g=groups: (b, h // g, ik, 0)),
            pl.BlockSpec((1, 1, block_k, Dv),
                         lambda b, h, iq, ik, g=groups: (b, h // g, ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, Dv),
                               lambda b, h, iq, ik: (b, h, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, nq * block_q, Dv), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, Dv), jnp.float32),
        ],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary")),
        interpret=interpret,
    )(qp, kp, vp)
    return out[:, :, :Sq]
