"""Pure-jnp oracle for the flash-attention kernel (no chunking tricks —
direct softmax so the kernel's online-softmax is independently validated)."""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

Array = jax.Array
NEG_INF = -1e30


def attention_ref(q: Array, k: Array, v: Array, causal: bool = True) -> Array:
    """q: (B, Sq, H, Dh); k/v: (B, Skv, KV, Dh); GQA via head grouping.
    Direct (materializing) softmax attention in fp32."""
    B, Sq, H, Dh = q.shape
    _, Skv, KV, Dv = *k.shape[:3], v.shape[-1]
    groups = H // KV
    scale = 1.0 / math.sqrt(Dh)
    qg = q.reshape(B, Sq, KV, groups, Dh)
    s = jnp.einsum("bqkgd,bskd->bkgqs", qg.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    if causal:
        mask = jnp.arange(Skv)[None, :] > jnp.arange(Sq)[:, None]
        s = jnp.where(mask[None, None, None], NEG_INF, s)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgqs,bskd->bqkgd", p, v.astype(jnp.float32))
    return out.reshape(B, Sq, H, Dv).astype(q.dtype)
