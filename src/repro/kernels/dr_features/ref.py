"""Pure-jnp oracle for the DR feature kernel — MUST match
`repro.core.features` (shared definition of Table IV)."""
import jax.numpy as jnp

from repro.core import features as feat


def dr_features_ref(d, usage, jobs):
    """d/usage/jobs: (W, T) -> (W, 4): [wait_jobs, wait_power, wait_sq,
    njobs_delayed] (tardiness excluded — SLO lag is workload-specific)."""
    return jnp.stack([
        feat.waiting_time_jobs(d, usage, jobs),
        feat.waiting_time_power(d),
        feat.waiting_time_squared(d, usage, jobs),
        feat.num_jobs_delayed(d, usage, jobs),
    ], axis=-1)
