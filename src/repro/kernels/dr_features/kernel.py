"""Pallas TPU kernel for fleet-scale DR penalty features (Table IV).

The fleet solver evaluates the four queue-integral features for every
workload at every optimizer iteration — the hot loop when coordinating
thousands of jobs. The jnp path materializes four (W, T) cumsum
intermediates in HBM per evaluation; this kernel keeps a (block_w, T) tile
of workloads resident in VMEM and emits all four features in one pass
(arithmetic intensity: ~10 flops/byte on a (128, T=48→128-padded) tile,
bound by the single HBM read of d/usage/jobs).

Hours are padded to the 128-lane width; cumulative sums run along the lane
axis inside the tile.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu  # noqa: F401

from repro.kernels.dispatch import tpu_compiler_params


def _features_kernel(d_ref, u_ref, j_ref, o_ref):
    d = d_ref[...].astype(jnp.float32)
    u = u_ref[...].astype(jnp.float32)
    j = j_ref[...].astype(jnp.float32)
    rate = j * d / u
    wait_jobs = jnp.maximum(jnp.cumsum(rate, axis=1), 0.0).sum(axis=1)
    wait_power = jnp.maximum(jnp.cumsum(d, axis=1), 0.0).sum(axis=1)
    rate_sq = j * d * jnp.abs(d) / u
    wait_sq = jnp.maximum(jnp.cumsum(rate_sq, axis=1), 0.0).sum(axis=1)
    njobs = (j * jnp.maximum(d, 0.0) / u).sum(axis=1)
    o_ref[...] = jnp.stack([wait_jobs, wait_power, wait_sq, njobs], axis=1)


def dr_features_pallas(d, usage, jobs, block_w: int = 128,
                       interpret: bool | None = None):
    """d/usage/jobs: (W, T) -> (W, 4) feature matrix.

    Padding: W to block_w (zero rows are harmless — usage is padded with
    ones to avoid 0/0). `interpret=None` resolves backend-aware via
    `repro.kernels.dispatch.interpret_default`."""
    if interpret is None:
        from repro.kernels.dispatch import interpret_default
        interpret = interpret_default()
    W, T = d.shape
    pw = (-W) % block_w
    dp = jnp.pad(d, ((0, pw), (0, 0)))
    up = jnp.pad(usage, ((0, pw), (0, 0)), constant_values=1.0)
    jp = jnp.pad(jobs, ((0, pw), (0, 0)))
    nw = dp.shape[0] // block_w
    out = pl.pallas_call(
        _features_kernel,
        grid=(nw,),
        in_specs=[pl.BlockSpec((block_w, T), lambda i: (i, 0))] * 3,
        out_specs=pl.BlockSpec((block_w, 4), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((dp.shape[0], 4), jnp.float32),
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel",)),
        interpret=interpret,
    )(dp, up, jp)
    return out[:W]
