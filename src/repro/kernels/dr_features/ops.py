"""jit'd wrapper for the fleet DR feature kernel."""
from __future__ import annotations

import functools

import jax

from repro.kernels.dr_features.kernel import dr_features_pallas


@functools.partial(jax.jit, static_argnames=("interpret",))
def dr_features(d, usage, jobs, interpret: bool = True):
    """(W, T) fleet adjustment/usage/job matrices -> (W, 4) features."""
    return dr_features_pallas(d, usage, jobs, interpret=interpret)
