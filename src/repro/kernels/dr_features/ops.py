"""jit'd wrapper for the fleet DR feature kernel (backend-aware dispatch).

The wrapper carries an analytic custom VJP: the solver hot loop
differentiates penalties through these features every Adam step, and
`pallas_call` has no registered transpose. The backward pass is closed
form — each feature is Σ_t max(cumsum(r), 0) for a per-hour rate r, so
∂/∂d is a reversed cumulative sum of the active-hinge indicator times
∂r/∂d. Gradients flow to `d` only (usage/jobs are problem constants in
every solver path).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.dispatch import interpret_default
from repro.kernels.dr_features.kernel import dr_features_pallas


@functools.partial(jax.custom_vjp, nondiff_argnums=(0,))
def _dr_features(interpret: bool, d, usage, jobs):
    return dr_features_pallas(d, usage, jobs, interpret=interpret)


def _fwd(interpret, d, usage, jobs):
    return _dr_features(interpret, d, usage, jobs), (d, usage, jobs)


def _revcum(x):
    return jnp.flip(jnp.cumsum(jnp.flip(x, axis=1), axis=1), axis=1)


def _bwd(interpret, res, ct):
    d, usage, jobs = res
    ju = jobs / usage
    # Active-hinge indicators for the three cumulative features.
    a0 = (jnp.cumsum(ju * d, axis=1) > 0).astype(d.dtype)          # wait_jobs
    a1 = (jnp.cumsum(d, axis=1) > 0).astype(d.dtype)               # wait_power
    a2 = (jnp.cumsum(ju * d * jnp.abs(d), axis=1) > 0).astype(d.dtype)
    d_ct = (ct[:, 0:1] * ju * _revcum(a0)
            + ct[:, 1:2] * _revcum(a1)
            + ct[:, 2:3] * 2.0 * ju * jnp.abs(d) * _revcum(a2)
            + ct[:, 3:4] * ju * (d > 0).astype(d.dtype))
    return d_ct, jnp.zeros_like(usage), jnp.zeros_like(jobs)


_dr_features.defvjp(_fwd, _bwd)


@functools.partial(jax.jit, static_argnames=("interpret",))
def _dr_features_jit(d, usage, jobs, interpret: bool):
    return _dr_features(interpret, d, usage, jobs)


def dr_features(d, usage, jobs, interpret: bool | None = None):
    """(W, T) fleet adjustment/usage/job matrices -> (W, 4) features.

    `interpret=None` auto-selects: compiled kernel on TPU, interpret
    fallback on CPU (override with REPRO_PALLAS_INTERPRET). Resolved
    *outside* the jit boundary so a changed env override is not masked by
    a stale trace cached under the `None` key.
    """
    return _dr_features_jit(d, usage, jobs, interpret_default(interpret))
