"""jit'd wrapper for the SSD inter-chunk scan kernel."""
from __future__ import annotations

import functools

import jax

from repro.kernels.ssd_scan.kernel import ssd_scan_pallas


@functools.partial(jax.jit, static_argnames=("interpret",))
def ssd_scan(states, chunk_decay, interpret: bool = True):
    return ssd_scan_pallas(states, chunk_decay, interpret=interpret)
