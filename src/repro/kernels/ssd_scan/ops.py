"""jit'd wrapper for the SSD inter-chunk scan kernel."""
from __future__ import annotations

import functools

import jax

from repro.kernels.dispatch import interpret_default
from repro.kernels.ssd_scan.kernel import ssd_scan_pallas


@functools.partial(jax.jit, static_argnames=("interpret",))
def _ssd_scan_jit(states, chunk_decay, interpret: bool):
    return ssd_scan_pallas(states, chunk_decay, interpret=interpret)


def ssd_scan(states, chunk_decay, interpret: bool | None = None):
    # interpret resolved outside jit so env overrides aren't masked by a
    # trace cached under the `None` key.
    return _ssd_scan_jit(states, chunk_decay, interpret_default(interpret))
