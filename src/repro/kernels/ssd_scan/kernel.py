"""Pallas TPU kernel for the Mamba-2 SSD inter-chunk state recurrence.

The chunked SSD algorithm (repro.models.ssm.ssd_chunked) has one sequential
component: h_c = decay_c · h_{c-1} + S_c over chunks. In jnp this is a
lax.scan whose (B, H, P, N) carry round-trips through HBM every chunk; here
the carry lives in VMEM scratch for the whole sweep — the grid's chunk axis
is "arbitrary" (sequential) and the (B, H) axes are parallel.

Each program owns one (head, batch) state tile of (P, N) = (64, 128) fp32 =
32 KB — far under VMEM, so many heads pipeline concurrently.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu  # noqa: F401

from repro.kernels.dispatch import tpu_compiler_params


def _ssd_scan_kernel(states_ref, decay_ref, hprev_ref, hlast_ref, h_ref):
    ic = pl.program_id(2)
    nc = pl.num_programs(2)

    @pl.when(ic == 0)
    def _init():
        h_ref[...] = jnp.zeros_like(h_ref)

    h = h_ref[...]
    hprev_ref[0, 0, 0] = h.astype(hprev_ref.dtype)
    dec = decay_ref[0, 0, 0].astype(jnp.float32)
    h_ref[...] = h * dec + states_ref[0, 0, 0].astype(jnp.float32)

    @pl.when(ic == nc - 1)
    def _final():
        hlast_ref[0, 0] = h_ref[...].astype(hlast_ref.dtype)


def ssd_scan_pallas(states, chunk_decay, interpret: bool = True):
    """states: (B, NC, H, P, N); chunk_decay: (B, NC, H) -> (h_prev, h_last)
    with h_prev (B, NC, H, P, N), h_last (B, H, P, N)."""
    B, NC, H, P, N = states.shape
    # decay broadcast to (B, NC, H, 1, 1) lanes for BlockSpec tiling.
    dec = chunk_decay[..., None, None]
    hprev, hlast = pl.pallas_call(
        _ssd_scan_kernel,
        grid=(B, H, NC),
        in_specs=[
            pl.BlockSpec((1, 1, 1, P, N), lambda b, h, c: (b, c, h, 0, 0)),
            pl.BlockSpec((1, 1, 1, 1, 1), lambda b, h, c: (b, c, h, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, 1, P, N), lambda b, h, c: (b, c, h, 0, 0)),
            pl.BlockSpec((1, 1, P, N), lambda b, h, c: (b, h, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, NC, H, P, N), jnp.float32),
            jax.ShapeDtypeStruct((B, H, P, N), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((P, N), jnp.float32)],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(states, dec)
    return hprev, hlast
