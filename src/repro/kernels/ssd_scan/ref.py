"""Pure-jnp oracle for the SSD inter-chunk state scan."""
import jax
import jax.numpy as jnp


def ssd_scan_ref(states, chunk_decay, h0=None):
    """states: (B, NC, H, P, N) per-chunk contributions;
    chunk_decay: (B, NC, H) per-chunk carry decays.
    Returns (h_prev: (B, NC, H, P, N) state BEFORE each chunk,
             h_last: (B, H, P, N))."""
    B, NC, H, P, N = states.shape

    def scan_fn(h, inp):
        s_c, dec = inp
        h_new = h * dec[..., None, None] + s_c
        return h_new, h

    h_init = (jnp.zeros((B, H, P, N), jnp.float32) if h0 is None
              else h0.astype(jnp.float32))
    h_last, h_prev = jax.lax.scan(
        scan_fn, h_init,
        (states.transpose(1, 0, 2, 3, 4).astype(jnp.float32),
         chunk_decay.transpose(1, 0, 2).astype(jnp.float32)))
    return h_prev.transpose(1, 0, 2, 3, 4), h_last
