"""Data pipeline: deterministic synthetic token streams with host sharding
and background prefetch.

The stream is seeded per (epoch, step, host) so every host materializes only
its shard — no global array ever exists (the property that matters at
thousand-node scale). Prefetch runs on a background thread with a bounded
queue, overlapping host data generation with device compute.

In the Carbon Responder fleet, this pipeline is itself a "Data Pipeline"
batch workload: `throttle` lets the DR schedule cut its throughput (the
enforcement mechanism of §V).
"""
from __future__ import annotations

import dataclasses
import queue
import threading
from typing import Iterator

import numpy as np

from repro.configs.base import ArchConfig, ShapeCell


@dataclasses.dataclass(frozen=True)
class DataConfig:
    seed: int = 0
    host_index: int = 0
    host_count: int = 1
    prefetch: int = 2


def synthetic_batch(cfg: ArchConfig, shape: ShapeCell, step: int,
                    dc: DataConfig = DataConfig()) -> dict[str, np.ndarray]:
    """One host-shard of a global batch (tokens + labels [+ modality])."""
    assert shape.global_batch % dc.host_count == 0
    b = shape.global_batch // dc.host_count
    # Tuple seeding (SeedSequence entropy spreading): arithmetic mixing
    # of (seed, step, host) collides whenever the products overlap — the
    # same stream-collision class PR 5 fixed in the scenario registry.
    # The token stream differs from the old `(seed*1e6+step)*4093+host`
    # encoding, which is fine: the pipeline promises determinism per
    # (seed, step, host), not any particular byte stream.
    rng = np.random.default_rng((dc.seed, step, dc.host_index))
    S = shape.seq_len
    # Zipf-ish token distribution — realistic softmax pressure.
    toks = rng.zipf(1.3, size=(b, S)).astype(np.int64)
    toks = np.clip(toks, 0, cfg.vocab_size - 1).astype(np.int32)
    batch = {"tokens": toks, "labels": toks.copy()}
    if cfg.family == "encdec":
        batch["frames"] = rng.standard_normal(
            (b, cfg.encoder_seq, cfg.d_model)).astype(np.float32)
    if cfg.family == "vlm":
        sv = int(S * cfg.vision_tokens_frac)
        batch["tokens"] = batch["tokens"][:, : S - sv]
        batch["labels"] = batch["labels"][:, : S - sv]
        batch["vision_embeds"] = rng.standard_normal(
            (b, sv, cfg.d_model)).astype(np.float32)
        pos = np.arange(S, dtype=np.int32)
        batch["mrope_positions"] = np.broadcast_to(
            pos, (3, b, S)).copy()
    return batch


class PrefetchingLoader:
    """Background-thread loader with a bounded queue and a DR throttle.

    `set_throttle(frac)` scales effective throughput by delaying dequeues —
    the knob the FleetCoordinator drives from the CR schedule.
    """

    def __init__(self, cfg: ArchConfig, shape: ShapeCell,
                 dc: DataConfig = DataConfig(), start_step: int = 0):
        self.cfg, self.shape, self.dc = cfg, shape, dc
        self._q: queue.Queue = queue.Queue(maxsize=dc.prefetch)
        self._step = start_step
        self._stop = threading.Event()
        self._throttle = 1.0
        self._thread = threading.Thread(target=self._worker, daemon=True)
        self._thread.start()

    def _worker(self) -> None:
        while not self._stop.is_set():
            batch = synthetic_batch(self.cfg, self.shape, self._step, self.dc)
            self._step += 1
            while not self._stop.is_set():
                try:
                    self._q.put(batch, timeout=0.1)
                    break
                except queue.Full:
                    continue

    def set_throttle(self, frac: float) -> None:
        self._throttle = max(0.05, min(1.0, frac))

    def __iter__(self) -> Iterator[dict]:
        return self

    def __next__(self) -> dict:
        import time
        if self._throttle < 1.0:
            # DR enforcement: stretch inter-batch time by 1/throttle.
            time.sleep(0.01 * (1.0 / self._throttle - 1.0))
        return self._q.get()

    def close(self) -> None:
        self._stop.set()
        self._thread.join(timeout=1.0)
