"""Attention layers: GQA/MQA with RoPE, qk-norm, bias; DeepSeek MLA.

Full-sequence attention uses a chunked online-softmax formulation (flash
attention in pure jnp — lax.scan over KV blocks with running max/denominator)
so the S×S score matrix is never materialized. This is both the memory-safe
default for 32k prefill on TPU and the reference implementation mirrored by
the Pallas kernel in `repro.kernels.flash_attention`.

Decode uses a (B, S_max, kv, dh) cache (GQA) or a compressed latent cache
(MLA — the point of DeepSeek's design: 576 values/token vs 2·kv·dh).
"""
from __future__ import annotations

import math
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, MLAConfig
from repro.models import common
from repro.models.common import Array, apply_mrope, apply_rope, linear, linear_init

NEG_INF = -1e30


# ---------------------------------------------------------------------------
# Chunked (flash) attention — jnp reference used by models & Pallas oracle
# ---------------------------------------------------------------------------
def flash_attention_jnp(q: Array, k: Array, v: Array, causal: bool,
                        chunk: int = 1024, q_offset: int = 0) -> Array:
    """q: (B, Sq, H, Dh); k/v: (B, Skv, KV, Dh) with H % KV == 0.

    Online-softmax over KV chunks; fp32 accumulators; never builds Sq×Skv.
    `q_offset`: absolute position of q[0] (for causal masking vs a cache).
    """
    B, Sq, H, Dh = q.shape
    _, Skv, KV, _ = k.shape
    Dv = v.shape[-1]                                # may differ (MLA)
    groups = H // KV
    scale = 1.0 / math.sqrt(Dh)
    # Fold GQA: (B, KV, groups, Sq, Dh)
    qg = q.reshape(B, Sq, KV, groups, Dh).transpose(0, 2, 3, 1, 4)
    kg = k.transpose(0, 2, 1, 3)                    # (B, KV, Skv, Dh)
    vg = v.transpose(0, 2, 1, 3)
    nchunks = (Skv + chunk - 1) // chunk
    pad = nchunks * chunk - Skv
    if pad:
        kg = jnp.pad(kg, ((0, 0), (0, 0), (0, pad), (0, 0)))
        vg = jnp.pad(vg, ((0, 0), (0, 0), (0, pad), (0, 0)))
    kg = kg.reshape(B, KV, nchunks, chunk, Dh)
    vg = vg.reshape(B, KV, nchunks, chunk, Dv)
    q_pos = q_offset + jnp.arange(Sq)

    def body(carry, inputs):
        m, l, acc = carry
        kc, vc, idx = inputs
        kv_pos = idx * chunk + jnp.arange(chunk)
        s = jnp.einsum("bkgqd,bkcd->bkgqc", qg, kc,
                       preferred_element_type=jnp.float32) * scale
        mask = kv_pos[None, :] > q_pos[:, None] if causal else None
        pad_mask = kv_pos >= Skv
        dead = pad_mask[None, :] if mask is None else (mask | pad_mask[None, :])
        s = jnp.where(dead[None, None, None], NEG_INF, s)
        m_new = jnp.maximum(m, s.max(axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + p.sum(axis=-1)
        acc_new = acc * corr[..., None] + jnp.einsum(
            "bkgqc,bkcd->bkgqd", p.astype(vc.dtype), vc,
            preferred_element_type=jnp.float32)
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((B, KV, groups, Sq), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, KV, groups, Sq), jnp.float32)
    acc0 = jnp.zeros((B, KV, groups, Sq, Dv), jnp.float32)
    idxs = jnp.arange(nchunks)
    (m, l, acc), _ = jax.lax.scan(
        body, (m0, l0, acc0),
        (kg.transpose(2, 0, 1, 3, 4), vg.transpose(2, 0, 1, 3, 4), idxs))
    out = acc / jnp.maximum(l[..., None], 1e-30)
    return out.transpose(0, 3, 1, 2, 4).reshape(B, Sq, H, Dv).astype(q.dtype)


def decode_attention(q: Array, k_cache: Array, v_cache: Array,
                     length: Array | int) -> Array:
    """Single-step decode: q (B, 1, H, Dh), caches (B, S, KV, Dh).

    Attends over cache[:length]. Returns (B, 1, H, Dh)."""
    B, _, H, Dh = q.shape
    S, KV = k_cache.shape[1], k_cache.shape[2]
    groups = H // KV
    scale = 1.0 / math.sqrt(Dh)
    qg = q.reshape(B, KV, groups, Dh)
    s = jnp.einsum("bkgd,bskd->bkgs", qg, k_cache,
                   preferred_element_type=jnp.float32) * scale
    pos = jnp.arange(S)
    s = jnp.where(pos[None, None, None] >= length, NEG_INF, s)
    p = jax.nn.softmax(s, axis=-1).astype(v_cache.dtype)
    out = jnp.einsum("bkgs,bskd->bkgd", p, v_cache,
                     preferred_element_type=jnp.float32)
    return out.reshape(B, 1, H, Dh).astype(q.dtype)


# ---------------------------------------------------------------------------
# GQA attention layer
# ---------------------------------------------------------------------------
def gqa_init(key, cfg: ArchConfig, dtype) -> dict:
    d, H, KV, Dh = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.dh
    ks = jax.random.split(key, 4)
    p = {"wq": linear_init(ks[0], d, H * Dh, dtype, bias=cfg.qkv_bias),
         "wk": linear_init(ks[1], d, KV * Dh, dtype, bias=cfg.qkv_bias),
         "wv": linear_init(ks[2], d, KV * Dh, dtype, bias=cfg.qkv_bias),
         "wo": linear_init(ks[3], H * Dh, d, dtype)}
    if cfg.qk_norm:
        p["q_norm"] = common.rmsnorm_init(Dh, dtype)
        p["k_norm"] = common.rmsnorm_init(Dh, dtype)
    return p


def _project_qkv(p: dict, x: Array, cfg: ArchConfig, positions: Array,
                 mrope_positions: Array | None = None,
                 use_rope: bool = True):
    B, S, _ = x.shape
    H, KV, Dh = cfg.num_heads, cfg.num_kv_heads, cfg.dh
    q = linear(p["wq"], x).reshape(B, S, H, Dh)
    k = linear(p["wk"], x).reshape(B, S, KV, Dh)
    v = linear(p["wv"], x).reshape(B, S, KV, Dh)
    if cfg.qk_norm:
        q = common.rmsnorm(p["q_norm"], q)
        k = common.rmsnorm(p["k_norm"], k)
    if not use_rope:
        # Whisper-style absolute-position models: no rotary.
        return q, k, v
    if cfg.mrope_sections is not None and mrope_positions is not None:
        q = apply_mrope(q, mrope_positions, cfg.mrope_sections, cfg.rope_theta)
        k = apply_mrope(k, mrope_positions, cfg.mrope_sections, cfg.rope_theta)
    else:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def gqa_attend(p: dict, x: Array, cfg: ArchConfig, positions: Array,
               causal: bool = True, mrope_positions: Array | None = None,
               use_rope: bool = True) -> Array:
    q, k, v = _project_qkv(p, x, cfg, positions, mrope_positions, use_rope)
    out = flash_attention_jnp(q, k, v, causal=causal)
    B, S = x.shape[:2]
    return linear(p["wo"], out.reshape(B, S, cfg.num_heads * cfg.dh))


def gqa_prefill_cache(p: dict, x: Array, cfg: ArchConfig, positions: Array,
                      ) -> tuple[Array, dict]:
    """Prefill: returns (out, {k, v}) so serving can reuse the projections."""
    q, k, v = _project_qkv(p, x, cfg, positions)
    out = flash_attention_jnp(q, k, v, causal=True)
    B, S = x.shape[:2]
    return linear(p["wo"], out.reshape(B, S, cfg.num_heads * cfg.dh)), \
        {"k": k, "v": v}


def quantize_kv(x: Array) -> tuple[Array, Array]:
    """Per-(token, head) symmetric int8 over Dh. x: (..., Dh)."""
    scale = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1,
                    keepdims=True) / 127.0
    q = jnp.round(x.astype(jnp.float32)
                  / jnp.maximum(scale, 1e-12)).astype(jnp.int8)
    return q, scale


def dequantize_kv(q: Array, scale: Array, dtype) -> Array:
    return (q.astype(jnp.float32) * scale).astype(dtype)


def gqa_decode(p: dict, x: Array, cfg: ArchConfig, cache: dict,
               length: Array) -> tuple[Array, dict]:
    """x: (B, 1, d). cache: {k, v} (B, S_max, KV, Dh) — or the int8 variant
    {k_q, k_s, v_q, v_s} when cfg.kv_quant (HBM reads halve; the decode
    cells are KV-read bound at batch 128). `length` tokens are already
    cached; the new token is written at index `length`."""
    B = x.shape[0]
    pos = jnp.full((B, 1), length, jnp.int32)
    q, k, v = _project_qkv(p, x, cfg, pos)
    if cfg.kv_quant:
        kq, ks = quantize_kv(k)
        vq, vs = quantize_kv(v)
        upd = lambda c, u: jax.lax.dynamic_update_slice_in_dim(c, u, length,
                                                               axis=1)
        new_cache = {"k_q": upd(cache["k_q"], kq),
                     "k_s": upd(cache["k_s"], ks),
                     "v_q": upd(cache["v_q"], vq),
                     "v_s": upd(cache["v_s"], vs)}
        k_cache = dequantize_kv(new_cache["k_q"], new_cache["k_s"], x.dtype)
        v_cache = dequantize_kv(new_cache["v_q"], new_cache["v_s"], x.dtype)
    else:
        k_cache = jax.lax.dynamic_update_slice_in_dim(cache["k"], k, length,
                                                      axis=1)
        v_cache = jax.lax.dynamic_update_slice_in_dim(cache["v"], v, length,
                                                      axis=1)
        new_cache = {"k": k_cache, "v": v_cache}
    out = decode_attention(q, k_cache, v_cache, length + 1)
    y = linear(p["wo"], out.reshape(B, 1, cfg.num_heads * cfg.dh))
    return y, new_cache


# ---------------------------------------------------------------------------
# DeepSeek-V3 Multi-head Latent Attention (MLA)
# ---------------------------------------------------------------------------
def mla_init(key, cfg: ArchConfig, dtype) -> dict:
    m = cfg.mla
    d, H = cfg.d_model, cfg.num_heads
    qk_head = m.qk_nope_head_dim + m.qk_rope_head_dim
    ks = jax.random.split(key, 7)
    return {
        "wdq": linear_init(ks[0], d, m.q_lora_rank, dtype),
        "q_norm": common.rmsnorm_init(m.q_lora_rank, dtype),
        "wuq": linear_init(ks[1], m.q_lora_rank, H * qk_head, dtype),
        "wdkv": linear_init(ks[2], d, m.kv_lora_rank, dtype),
        "kv_norm": common.rmsnorm_init(m.kv_lora_rank, dtype),
        "wkr": linear_init(ks[3], d, m.qk_rope_head_dim, dtype),
        "wuk": linear_init(ks[4], m.kv_lora_rank, H * m.qk_nope_head_dim, dtype),
        "wuv": linear_init(ks[5], m.kv_lora_rank, H * m.v_head_dim, dtype),
        "wo": linear_init(ks[6], H * m.v_head_dim, d, dtype),
    }


def _mla_qkr(p: dict, x: Array, cfg: ArchConfig, positions: Array):
    """Query heads + rope-key + latent; shared by train and serve paths."""
    m = cfg.mla
    B, S, _ = x.shape
    H = cfg.num_heads
    qk_head = m.qk_nope_head_dim + m.qk_rope_head_dim
    q = linear(p["wuq"], common.rmsnorm(p["q_norm"], linear(p["wdq"], x)))
    q = q.reshape(B, S, H, qk_head)
    q_nope, q_rope = jnp.split(q, [m.qk_nope_head_dim], axis=-1)
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)
    c_kv = common.rmsnorm(p["kv_norm"], linear(p["wdkv"], x))   # (B,S,r_kv)
    k_rope = apply_rope(linear(p["wkr"], x), positions, cfg.rope_theta)
    return q_nope, q_rope, c_kv, k_rope


def mla_attend(p: dict, x: Array, cfg: ArchConfig, positions: Array,
               causal: bool = True) -> Array:
    """Training/prefill path: expand latents to per-head K/V, flash-attend."""
    m = cfg.mla
    B, S, _ = x.shape
    H = cfg.num_heads
    q_nope, q_rope, c_kv, k_rope = _mla_qkr(p, x, cfg, positions)
    k_nope = linear(p["wuk"], c_kv).reshape(B, S, H, m.qk_nope_head_dim)
    v = linear(p["wuv"], c_kv).reshape(B, S, H, m.v_head_dim)
    q = jnp.concatenate([q_nope, q_rope], axis=-1)
    k = jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_rope[:, :, None, :],
                                  (B, S, H, m.qk_rope_head_dim))], axis=-1)
    out = flash_attention_jnp(q, k, v, causal=causal)
    return linear(p["wo"], out.reshape(B, S, H * m.v_head_dim))


def mla_decode(p: dict, x: Array, cfg: ArchConfig, cache: dict,
               length: Array) -> tuple[Array, dict]:
    """Absorbed decode over the latent cache {c_kv (B,S,r), k_rope (B,S,dr)}.

    Scores = q_nope·W_uk·c_kv + q_rope·k_rope — W_uk is absorbed into the
    query so the cache stays compressed (DeepSeek-V2/V3 inference trick).
    """
    m = cfg.mla
    B = x.shape[0]
    H = cfg.num_heads
    pos = jnp.full((B, 1), length, jnp.int32)
    q_nope, q_rope, c_new, kr_new = _mla_qkr(p, x, cfg, pos)
    c_cache = jax.lax.dynamic_update_slice_in_dim(
        cache["c_kv"], c_new, length, axis=1)
    kr_cache = jax.lax.dynamic_update_slice_in_dim(
        cache["k_rope"], kr_new, length, axis=1)
    # Absorb W_uk: q_lat (B,H,r) = q_nope (B,1,H,dn) · W_uk (r, H·dn)
    wuk = p["wuk"]["w"].reshape(m.kv_lora_rank, H, m.qk_nope_head_dim)
    q_lat = jnp.einsum("bhd,rhd->bhr", q_nope[:, 0], wuk.astype(x.dtype))
    scale = 1.0 / math.sqrt(m.qk_nope_head_dim + m.qk_rope_head_dim)
    s = (jnp.einsum("bhr,bsr->bhs", q_lat, c_cache,
                    preferred_element_type=jnp.float32)
         + jnp.einsum("bhd,bsd->bhs", q_rope[:, 0], kr_cache,
                      preferred_element_type=jnp.float32)) * scale
    idx = jnp.arange(c_cache.shape[1])
    s = jnp.where(idx[None, None] >= length + 1, NEG_INF, s)
    pattn = jax.nn.softmax(s, axis=-1).astype(x.dtype)
    ctx = jnp.einsum("bhs,bsr->bhr", pattn, c_cache,
                     preferred_element_type=jnp.float32).astype(x.dtype)
    # Absorb W_uv: out head h = ctx·W_uv_h
    wuv = p["wuv"]["w"].reshape(m.kv_lora_rank, H, m.v_head_dim)
    out = jnp.einsum("bhr,rhv->bhv", ctx, wuv.astype(x.dtype))
    y = linear(p["wo"], out.reshape(B, 1, H * m.v_head_dim))
    return y, {"c_kv": c_cache, "k_rope": kr_cache}
