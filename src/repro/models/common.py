"""Shared model primitives: norms, rotary embeddings, FFNs, embeddings.

Functional style: params are nested dicts of jnp arrays; every layer is a
pure function `f(params, x, ...)`. Initializers take explicit PRNG keys so
`jax.eval_shape` can trace them without allocation (the dry-run path).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Sequence

import jax
import jax.numpy as jnp

Array = jax.Array


def dtype_of(name: str):
    return {"bfloat16": jnp.bfloat16, "float32": jnp.float32,
            "float16": jnp.float16}[name]


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------
def rmsnorm_init(d: int, dtype) -> dict:
    return {"scale": jnp.ones((d,), dtype)}


def rmsnorm(p: dict, x: Array, eps: float = 1e-6) -> Array:
    """RMSNorm in fp32 accumulation, cast back to input dtype."""
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    return (y * p["scale"].astype(jnp.float32)).astype(x.dtype)


# ---------------------------------------------------------------------------
# Rotary position embeddings (+ M-RoPE for Qwen2-VL)
# ---------------------------------------------------------------------------
def rope_freqs(head_dim: int, theta: float) -> Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2,
                                       dtype=jnp.float32) / head_dim))


def apply_rope(x: Array, positions: Array, theta: float = 1e6) -> Array:
    """x: (..., S, H, Dh) or (..., S, Dh); positions: (..., S)."""
    dh = x.shape[-1]
    freqs = rope_freqs(dh, theta)                       # (Dh/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., S, Dh/2)
    if x.ndim == angles.ndim + 1:                       # has head axis
        angles = angles[..., None, :]
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def apply_mrope(x: Array, positions: Array, sections: Sequence[int],
                theta: float = 1e6) -> Array:
    """Multimodal RoPE (Qwen2-VL): positions (3, ..., S) for (t, h, w);
    `sections` splits the rotary half-dim across the three components."""
    dh = x.shape[-1]
    freqs = rope_freqs(dh, theta)                       # (Dh/2,)
    # Build per-frequency positions by section.
    sec = jnp.asarray(
        sum(([i] * s for i, s in enumerate(sections)), []), jnp.int32)
    pos_sel = jnp.take(positions, sec, axis=0)          # (Dh/2 picks of pos)
    # pos_sel: (Dh/2, ..., S) -> (..., S, Dh/2)
    pos_sel = jnp.moveaxis(pos_sel, 0, -1)
    angles = pos_sel.astype(jnp.float32) * freqs
    if x.ndim == angles.ndim + 1:
        angles = angles[..., None, :]
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Dense / SwiGLU FFN
# ---------------------------------------------------------------------------
def linear_init(key, d_in: int, d_out: int, dtype, bias: bool = False) -> dict:
    w = jax.random.normal(key, (d_in, d_out), dtype) / math.sqrt(d_in)
    p = {"w": w}
    if bias:
        p["b"] = jnp.zeros((d_out,), dtype)
    return p


def linear(p: dict, x: Array) -> Array:
    y = x @ p["w"].astype(x.dtype)
    if "b" in p:
        y = y + p["b"].astype(x.dtype)
    return y


def swiglu_init(key, d: int, d_ff: int, dtype) -> dict:
    k1, k2, k3 = jax.random.split(key, 3)
    return {"w_gate": linear_init(k1, d, d_ff, dtype),
            "w_up": linear_init(k2, d, d_ff, dtype),
            "w_down": linear_init(k3, d_ff, d, dtype)}


def swiglu(p: dict, x: Array) -> Array:
    g = jax.nn.silu(linear(p["w_gate"], x))
    return linear(p["w_down"], g * linear(p["w_up"], x))


# ---------------------------------------------------------------------------
# Embeddings / LM head
# ---------------------------------------------------------------------------
def embedding_init(key, vocab: int, d: int, dtype) -> dict:
    return {"table": jax.random.normal(key, (vocab, d), dtype) * 0.02}


def embed(p: dict, tokens: Array, dtype) -> Array:
    return jnp.take(p["table"], tokens, axis=0).astype(dtype)


def lm_head(p: dict, x: Array) -> Array:
    """Logits in fp32 for a stable softmax/loss."""
    return (x @ p["table"].astype(x.dtype).T).astype(jnp.float32)


def cross_entropy(logits: Array, labels: Array, ignore_id: int = -1) -> Array:
    """Mean token cross-entropy; fp32 logits (B, S, V); labels (B, S)."""
    mask = (labels != ignore_id).astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(
        logits, jnp.maximum(labels, 0)[..., None], axis=-1)[..., 0]
    nll = (lse - gold) * mask
    return nll.sum() / jnp.maximum(mask.sum(), 1.0)


def sinusoidal_positions(seq: int, d: int) -> Array:
    """Whisper-style fixed sinusoidal embeddings (fp32)."""
    pos = jnp.arange(seq, dtype=jnp.float32)[:, None]
    dim = jnp.arange(0, d, 2, dtype=jnp.float32)[None, :]
    angle = pos / jnp.power(10000.0, dim / d)
    return jnp.concatenate([jnp.sin(angle), jnp.cos(angle)], axis=-1)
