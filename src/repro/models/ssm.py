"""Mamba-2 (SSD — state-space duality) blocks, TPU-idiomatic chunked form.

GPU Mamba implementations rely on a fused sequential selective-scan kernel.
That ports poorly to TPU; the SSD formulation (Dao & Gu, 2024) re-expresses
the same recurrence as block matrices: quadratic attention-like matmuls
within chunks (MXU-friendly) plus a tiny inter-chunk state recurrence. We
implement exactly that:

  y = SSD(x)   with  h_t = exp(dt·A)·h_{t-1} + dt·B_t x_t,   y_t = C_t h_t

  chunked:  Y = (L ∘ C Bᵀ) X   (intra-chunk, per-chunk matmuls)
           + C_c · states_{c-1} (inter-chunk, scanned)

Decode is the O(1) recurrence on the (H, P, N) state — the reason mamba2
runs the long_500k cell that full-attention models cannot.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, SSMConfig
from repro.models.common import Array, linear, linear_init, rmsnorm, rmsnorm_init


def ssm_dims(cfg: ArchConfig) -> tuple[int, int, int, int]:
    s = cfg.ssm
    d_in = s.expand * cfg.d_model
    nheads = d_in // s.head_dim
    return d_in, nheads, s.head_dim, s.d_state


def ssm_init(key, cfg: ArchConfig, dtype) -> dict:
    s = cfg.ssm
    d = cfg.d_model
    d_in, H, P, N = ssm_dims(cfg)
    G = s.n_groups
    ks = jax.random.split(key, 4)
    d_proj = 2 * d_in + 2 * G * N + H   # z, x, B, C, dt
    conv_dim = d_in + 2 * G * N
    return {
        "in_proj": linear_init(ks[0], d, d_proj, dtype),
        "conv_w": jax.random.normal(ks[1], (s.conv_kernel, conv_dim), dtype)
        / math.sqrt(s.conv_kernel),
        "conv_b": jnp.zeros((conv_dim,), dtype),
        "A_log": jnp.zeros((H,), jnp.float32),
        "D": jnp.ones((H,), jnp.float32),
        "dt_bias": jnp.zeros((H,), jnp.float32),
        "norm": rmsnorm_init(d_in, dtype),
        "out_proj": linear_init(ks[2], d_in, d, dtype),
    }


def _split_proj(proj: Array, cfg: ArchConfig):
    d_in, H, P, N = ssm_dims(cfg)
    G = cfg.ssm.n_groups
    z, xbc_dt = jnp.split(proj, [d_in], axis=-1)
    xbc, dt = jnp.split(xbc_dt, [d_in + 2 * G * N], axis=-1)
    return z, xbc, dt


def _causal_conv(xbc: Array, w: Array, b: Array) -> Array:
    """Depthwise causal conv over sequence. xbc: (B, S, Cdim)."""
    K = w.shape[0]
    pad = jnp.pad(xbc, ((0, 0), (K - 1, 0), (0, 0)))
    out = sum(pad[:, i:i + xbc.shape[1]] * w[i] for i in range(K))
    return jax.nn.silu(out + b)


def _segsum(a: Array) -> Array:
    """Lower-triangular pairwise cumulative sums: out[..., i, j] =
    Σ_{j<k<=i} a[..., k] for i >= j, −inf above the diagonal."""
    Q = a.shape[-1]
    cum = jnp.cumsum(a, axis=-1)
    diff = cum[..., :, None] - cum[..., None, :]
    i = jnp.arange(Q)
    mask = i[:, None] >= i[None, :]
    return jnp.where(mask, diff, -jnp.inf)


def ssd_chunked(xh: Array, dt: Array, A: Array, Bm: Array, Cm: Array,
                chunk: int, h0: Array | None = None
                ) -> tuple[Array, Array]:
    """Chunked SSD scan.

    Args:
      xh: (B, S, H, P) inputs per head.
      dt: (B, S, H) positive step sizes.
      A:  (H,) negative decay rates.
      Bm: (B, S, G, N) input maps;  Cm: (B, S, G, N) output maps.
      chunk: chunk length Q (S % Q == 0 assumed; callers pad).
      h0: optional initial state (B, H, P, N).

    Returns (y: (B, S, H, P), final_state: (B, H, P, N)).
    """
    Bsz, S, H, P = xh.shape
    G, N = Bm.shape[2], Bm.shape[3]
    Q = chunk
    nc = S // Q
    rep = H // G
    # Broadcast groups to heads.
    Bh = jnp.repeat(Bm, rep, axis=2)          # (B,S,H,N)
    Ch = jnp.repeat(Cm, rep, axis=2)
    # Reshape into chunks.
    xc = xh.reshape(Bsz, nc, Q, H, P)
    dtc = dt.reshape(Bsz, nc, Q, H)
    Bc = Bh.reshape(Bsz, nc, Q, H, N)
    Cc = Ch.reshape(Bsz, nc, Q, H, N)
    dA = dtc * A[None, None, None, :]          # (B,nc,Q,H) negative
    dA = dA.astype(jnp.float32)
    cum = jnp.cumsum(dA, axis=2)               # within-chunk cumulative
    # 1) intra-chunk (diagonal blocks): Y = (L ∘ C Bᵀ) · (dt·X)
    Lmat = jnp.exp(_segsum(dA.transpose(0, 1, 3, 2)))        # (B,nc,H,Q,Q)
    scores = jnp.einsum("bcqhn,bckhn->bchqk", Cc, Bc,
                        preferred_element_type=jnp.float32)
    scores = scores * Lmat
    xdt = xc * dtc[..., None]
    y_diag = jnp.einsum("bchqk,bckhp->bcqhp", scores.astype(xh.dtype),
                        xdt, preferred_element_type=jnp.float32)
    # 2) chunk states: S_c = Σ_q exp(cum_last − cum_q)·B_q ⊗ (dt·x)_q
    decay_out = jnp.exp(cum[:, :, -1:, :] - cum)             # (B,nc,Q,H)
    states = jnp.einsum("bcqhn,bcqh,bcqhp->bchpn", Bc, decay_out.astype(Bc.dtype),
                        xdt, preferred_element_type=jnp.float32)
    # 3) inter-chunk recurrence over chunk states.
    chunk_decay = jnp.exp(cum[:, :, -1, :])                  # (B,nc,H)

    def scan_fn(h, inp):
        s_c, dec = inp
        h_new = h * dec[..., None, None] + s_c
        return h_new, h

    h_init = (jnp.zeros((Bsz, H, P, N), jnp.float32) if h0 is None
              else h0.astype(jnp.float32))
    h_last, h_prev = jax.lax.scan(
        scan_fn,
        h_init,
        (states.transpose(1, 0, 2, 3, 4), chunk_decay.transpose(1, 0, 2)))
    h_prev = h_prev.transpose(1, 0, 2, 3, 4)                 # (B,nc,H,P,N)
    # 4) inter-chunk output: y_off = exp(cum)·C · h_prev
    y_off = jnp.einsum("bcqhn,bchpn,bcqh->bcqhp", Cc, h_prev.astype(Cc.dtype),
                       jnp.exp(cum).astype(Cc.dtype),
                       preferred_element_type=jnp.float32)
    y = (y_diag + y_off).reshape(Bsz, S, H, P).astype(xh.dtype)
    return y, h_last


def ssm_apply(p: dict, x: Array, cfg: ArchConfig) -> Array:
    """Full-sequence Mamba-2 block. x: (B, S, d) -> (B, S, d)."""
    s = cfg.ssm
    d_in, H, P, N = ssm_dims(cfg)
    G = s.n_groups
    B_, S, _ = x.shape
    proj = linear(p["in_proj"], x)
    z, xbc, dt = _split_proj(proj, cfg)
    xbc = _causal_conv(xbc, p["conv_w"].astype(x.dtype),
                       p["conv_b"].astype(x.dtype))
    xs, Bm, Cm = jnp.split(xbc, [d_in, d_in + G * N], axis=-1)
    dt = jax.nn.softplus(dt.astype(jnp.float32)
                         + p["dt_bias"][None, None, :])       # (B,S,H)
    A = -jnp.exp(p["A_log"])                                  # (H,) < 0
    xh = xs.reshape(B_, S, H, P)
    Bm = Bm.reshape(B_, S, G, N)
    Cm = Cm.reshape(B_, S, G, N)
    Q = min(s.chunk, S)
    pad = (-S) % Q
    if pad:
        xh = jnp.pad(xh, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0), (0, 0)))
    y, _ = ssd_chunked(xh, dt, A, Bm, Cm, Q)
    y = y[:, :S]
    y = y + xh[:, :S] * p["D"][None, None, :, None].astype(y.dtype)
    y = y.reshape(B_, S, d_in)
    y = rmsnorm(p["norm"], y * jax.nn.silu(z))
    return linear(p["out_proj"], y)


def ssm_decode(p: dict, x: Array, cfg: ArchConfig, state: dict,
               ) -> tuple[Array, dict]:
    """O(1) decode step. x: (B, 1, d); state: {h: (B,H,P,N),
    conv: (B, K-1, conv_dim)} (conv tail for the causal conv)."""
    s = cfg.ssm
    d_in, H, P, N = ssm_dims(cfg)
    G = s.n_groups
    B_ = x.shape[0]
    proj = linear(p["in_proj"], x)                            # (B,1,·)
    z, xbc, dt = _split_proj(proj, cfg)
    conv_in = jnp.concatenate([state["conv"], xbc], axis=1)   # (B,K,·)
    w = p["conv_w"].astype(x.dtype)
    out = (conv_in * w[None]).sum(axis=1, keepdims=True)
    xbc = jax.nn.silu(out + p["conv_b"].astype(x.dtype))
    xs, Bm, Cm = jnp.split(xbc, [d_in, d_in + G * N], axis=-1)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])  # (B,1,H)
    A = -jnp.exp(p["A_log"])
    xh = xs.reshape(B_, H, P)
    Bm = jnp.repeat(Bm.reshape(B_, G, N), H // G, axis=1)
    Cm = jnp.repeat(Cm.reshape(B_, G, N), H // G, axis=1)
    dA = jnp.exp(dt[:, 0, :] * A[None, :])                    # (B,H)
    h = state["h"] * dA[..., None, None] + jnp.einsum(
        "bhp,bhn->bhpn", xh * dt[:, 0, :, None], Bm)
    y = jnp.einsum("bhpn,bhn->bhp", h, Cm)
    y = y + xh * p["D"][None, :, None]
    y = y.reshape(B_, 1, d_in).astype(x.dtype)
    y = rmsnorm(p["norm"], y * jax.nn.silu(z))
    new_state = {"h": h, "conv": conv_in[:, 1:]}
    return linear(p["out_proj"], y), new_state


def ssm_init_state(cfg: ArchConfig, batch: int, dtype) -> dict:
    s = cfg.ssm
    d_in, H, P, N = ssm_dims(cfg)
    conv_dim = d_in + 2 * s.n_groups * s.d_state
    return {"h": jnp.zeros((batch, H, P, N), jnp.float32),
            "conv": jnp.zeros((batch, s.conv_kernel - 1, conv_dim), dtype)}
