"""Model assembly: dense / MoE / SSM / hybrid / VLM decoder LMs.

Layers are stacked on a leading axis and iterated with `jax.lax.scan`, so the
lowered HLO is O(1) in depth (critical for compiling 61–80-layer models with
512 host devices). Heterogeneous stacks (Jamba) scan over *block groups* —
the repeating [mamba×7 + attn×1] pattern — unrolling within the group.

Public entry points:
  init_params(cfg, key)                      -> param pytree
  forward(params, cfg, batch)                -> fp32 logits
  loss_fn(params, cfg, batch)                -> scalar loss
  init_cache(cfg, batch, max_len)            -> decode cache pytree
  prefill(params, cfg, tokens)               -> (logits, cache)
  decode_step(params, cfg, cache, token, t)  -> (logits, cache)
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import attention as attn
from repro.models import common, moe, ssm
from repro.models.common import Array, dtype_of


# ---------------------------------------------------------------------------
# Layer classification
# ---------------------------------------------------------------------------
def layer_kind(cfg: ArchConfig, layer: int) -> tuple[str, str]:
    """-> (mixer, ffn) for a layer index: mixer ∈ {attn, mla, ssm},
    ffn ∈ {dense, moe, none}."""
    if cfg.family == "ssm":
        return "ssm", "none"
    if cfg.family == "hybrid":
        mixer = ("attn" if cfg.attn_layer_period and
                 layer % cfg.attn_layer_period == cfg.attn_layer_offset
                 else "ssm")
        ffn = ("moe" if cfg.moe and layer % cfg.moe.layer_period
               == cfg.moe.layer_period - 1 else "dense")
        return mixer, ffn
    mixer = "mla" if cfg.mla is not None else "attn"
    ffn = "moe" if cfg.moe and layer % cfg.moe.layer_period == 0 else "dense"
    return mixer, ffn


def block_group_size(cfg: ArchConfig) -> int:
    """Layers per homogeneous scan step."""
    if cfg.family == "hybrid":
        period = cfg.attn_layer_period or 1
        if cfg.moe and cfg.moe.layer_period > 1:
            import math
            period = math.lcm(period, cfg.moe.layer_period)
        return period
    return 1


# ---------------------------------------------------------------------------
# Parameter init (pure; trace with eval_shape for the dry-run)
# ---------------------------------------------------------------------------
def _layer_init(key, cfg: ArchConfig, layer: int, dtype) -> dict:
    mixer, ffn = layer_kind(cfg, layer)
    ks = jax.random.split(key, 4)
    p: dict[str, Any] = {"ln1": common.rmsnorm_init(cfg.d_model, dtype)}
    if mixer == "attn":
        p["attn"] = attn.gqa_init(ks[0], cfg, dtype)
    elif mixer == "mla":
        p["attn"] = attn.mla_init(ks[0], cfg, dtype)
    else:
        p["ssm"] = ssm.ssm_init(ks[0], cfg, dtype)
    if ffn != "none":
        p["ln2"] = common.rmsnorm_init(cfg.d_model, dtype)
        if ffn == "moe":
            p["moe"] = moe.moe_init(ks[1], cfg, dtype)
        else:
            p["ffn"] = common.swiglu_init(ks[1], cfg.d_model, cfg.d_ff, dtype)
    return p


def _stack(trees: list) -> Any:
    return jax.tree.map(lambda *xs: jnp.stack(xs), *trees)


def init_params(cfg: ArchConfig, key) -> dict:
    pdtype = dtype_of(cfg.param_dtype)
    keys = jax.random.split(key, cfg.num_layers + 3)
    group = block_group_size(cfg)
    n_groups = cfg.num_layers // group
    groups = []
    for g in range(n_groups):
        sub = {f"l{j}": _layer_init(keys[g * group + j], cfg, g * group + j,
                                    pdtype)
               for j in range(group)}
        groups.append(sub)
    params: dict[str, Any] = {
        "embed": common.embedding_init(keys[-1], cfg.vocab_size, cfg.d_model,
                                       pdtype),
        "blocks": _stack(groups),
        "ln_f": common.rmsnorm_init(cfg.d_model, pdtype),
    }
    if not cfg.tie_embeddings:
        params["unembed"] = common.embedding_init(
            keys[-2], cfg.vocab_size, cfg.d_model, pdtype)
    if cfg.mtp_depth:
        params["mtp"] = {
            "proj": common.linear_init(keys[-3], 2 * cfg.d_model, cfg.d_model,
                                       pdtype),
            "ln_h": common.rmsnorm_init(cfg.d_model, pdtype),
            "ln_e": common.rmsnorm_init(cfg.d_model, pdtype),
            "layer": _layer_init(keys[-3], cfg, cfg.num_layers - 1, pdtype),
        }
    return params


# ---------------------------------------------------------------------------
# Forward (training / prefill-without-cache)
# ---------------------------------------------------------------------------
def _apply_layer(p: dict, x: Array, cfg: ArchConfig, layer: int,
                 positions: Array, mrope_positions: Array | None) -> Array:
    mixer, ffn = layer_kind(cfg, layer)
    h = common.rmsnorm(p["ln1"], x)
    if mixer == "attn":
        h = attn.gqa_attend(p["attn"], h, cfg, positions,
                            mrope_positions=mrope_positions)
    elif mixer == "mla":
        h = attn.mla_attend(p["attn"], h, cfg, positions)
    else:
        h = ssm.ssm_apply(p["ssm"], h, cfg)
    x = x + h
    if ffn != "none":
        h = common.rmsnorm(p["ln2"], x)
        h = (moe.moe_apply(p["moe"], h, cfg) if ffn == "moe"
             else common.swiglu(p["ffn"], h))
        x = x + h
    return x


def forward(params: dict, cfg: ArchConfig, batch: dict) -> Array:
    """batch: {tokens (B,S)[, vision_embeds (B,Sv,d), mrope_positions
    (3,B,S)]} -> fp32 logits (B, S_total, V)."""
    adtype = dtype_of(cfg.dtype)
    x = common.embed(params["embed"], batch["tokens"], adtype)
    if cfg.family == "vlm" and "vision_embeds" in batch:
        x = jnp.concatenate(
            [batch["vision_embeds"].astype(adtype), x], axis=1)
    B, S = x.shape[:2]
    positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    mrope_positions = batch.get("mrope_positions")
    group = block_group_size(cfg)

    def body(x, gp):
        for j in range(group):
            x = _apply_layer(gp[f"l{j}"], x, cfg, j, positions,
                             mrope_positions)
        return x, None

    body_fn = jax.checkpoint(body) if cfg.remat else body
    x, _ = jax.lax.scan(body_fn, x, params["blocks"])
    x = common.rmsnorm(params["ln_f"], x)
    table = params.get("unembed", params["embed"])
    return common.lm_head(table, x)


def _hidden_states(params: dict, cfg: ArchConfig, batch: dict) -> Array:
    """Forward up to (and including) the final norm — used by MTP."""
    adtype = dtype_of(cfg.dtype)
    x = common.embed(params["embed"], batch["tokens"], adtype)
    B, S = x.shape[:2]
    positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    group = block_group_size(cfg)

    def body(x, gp):
        for j in range(group):
            x = _apply_layer(gp[f"l{j}"], x, cfg, j, positions, None)
        return x, None

    body_fn = jax.checkpoint(body) if cfg.remat else body
    x, _ = jax.lax.scan(body_fn, x, params["blocks"])
    return common.rmsnorm(params["ln_f"], x)


def loss_fn(params: dict, cfg: ArchConfig, batch: dict) -> Array:
    """Next-token CE; adds the DeepSeek MTP auxiliary loss when configured."""
    if cfg.mtp_depth and cfg.family != "vlm":
        h = _hidden_states(params, cfg, batch)
        table = params.get("unembed", params["embed"])
        logits = common.lm_head(table, h)
        loss = common.cross_entropy(logits[:, :-1], batch["labels"][:, 1:])
        # MTP: predict t+2 from [norm(h_t) ; norm(emb(t+1))] (DeepSeek-V3).
        adtype = dtype_of(cfg.dtype)
        emb_next = common.embed(params["embed"], batch["tokens"], adtype)
        m = params["mtp"]
        cat = jnp.concatenate([common.rmsnorm(m["ln_h"], h[:, :-1]),
                               common.rmsnorm(m["ln_e"], emb_next[:, 1:])],
                              axis=-1)
        x2 = common.linear(m["proj"], cat)
        B, S2 = x2.shape[:2]
        pos = jnp.broadcast_to(jnp.arange(S2)[None], (B, S2))
        x2 = _apply_layer(m["layer"], x2, cfg, 0, pos, None)
        logits2 = common.lm_head(table, x2)
        mtp_loss = common.cross_entropy(logits2[:, :-1],
                                        batch["labels"][:, 2:])
        return loss + 0.3 * mtp_loss
    logits = forward(params, cfg, batch)
    labels = batch["labels"]
    if cfg.family == "vlm" and "vision_embeds" in batch:
        # Vision positions carry no next-token loss; score text tail only.
        sv = batch["vision_embeds"].shape[1]
        logits = logits[:, sv:]
    return common.cross_entropy(logits[:, :-1], labels[:, 1:])


# ---------------------------------------------------------------------------
# Serving: cache init / prefill / decode
# ---------------------------------------------------------------------------
def _layer_cache(cfg: ArchConfig, layer: int, batch: int, max_len: int,
                 adtype) -> dict:
    mixer, _ = layer_kind(cfg, layer)
    if mixer == "attn":
        KV, Dh = cfg.num_kv_heads, cfg.dh
        if cfg.kv_quant:
            return {"k_q": jnp.zeros((batch, max_len, KV, Dh), jnp.int8),
                    "k_s": jnp.zeros((batch, max_len, KV, 1), jnp.float32),
                    "v_q": jnp.zeros((batch, max_len, KV, Dh), jnp.int8),
                    "v_s": jnp.zeros((batch, max_len, KV, 1), jnp.float32)}
        return {"k": jnp.zeros((batch, max_len, KV, Dh), adtype),
                "v": jnp.zeros((batch, max_len, KV, Dh), adtype)}
    if mixer == "mla":
        m = cfg.mla
        return {"c_kv": jnp.zeros((batch, max_len, m.kv_lora_rank), adtype),
                "k_rope": jnp.zeros((batch, max_len, m.qk_rope_head_dim),
                                    adtype)}
    return ssm.ssm_init_state(cfg, batch, adtype)


def init_cache(cfg: ArchConfig, batch: int, max_len: int) -> dict:
    adtype = dtype_of(cfg.dtype)
    group = block_group_size(cfg)
    n_groups = cfg.num_layers // group
    per_group = [{f"l{j}": _layer_cache(cfg, g * group + j, batch, max_len,
                                        adtype)
                  for j in range(group)} for g in range(n_groups)]
    return _stack(per_group)


def _decode_layer(p: dict, x: Array, cfg: ArchConfig, layer: int,
                  cache: dict, length) -> tuple[Array, dict]:
    mixer, ffn = layer_kind(cfg, layer)
    h = common.rmsnorm(p["ln1"], x)
    if mixer == "attn":
        h, new_cache = attn.gqa_decode(p["attn"], h, cfg, cache, length)
    elif mixer == "mla":
        h, new_cache = attn.mla_decode(p["attn"], h, cfg, cache, length)
    else:
        h, new_cache = ssm.ssm_decode(p["ssm"], h, cfg, cache)
    x = x + h
    if ffn != "none":
        h = common.rmsnorm(p["ln2"], x)
        h = (moe.moe_apply(p["moe"], h, cfg) if ffn == "moe"
             else common.swiglu(p["ffn"], h))
        x = x + h
    return x, new_cache


def decode_step(params: dict, cfg: ArchConfig, cache: dict, token: Array,
                length) -> tuple[Array, dict]:
    """token: (B, 1) int32; `length` = tokens already cached. Returns
    (fp32 logits (B, 1, V), updated cache)."""
    adtype = dtype_of(cfg.dtype)
    x = common.embed(params["embed"], token, adtype)
    group = block_group_size(cfg)

    def body(x, inp):
        gp, gc = inp
        new_gc = {}
        for j in range(group):
            x, new_gc[f"l{j}"] = _decode_layer(gp[f"l{j}"], x, cfg, j,
                                               gc[f"l{j}"], length)
        return x, new_gc

    x, new_cache = jax.lax.scan(body, x, (params["blocks"], cache))
    x = common.rmsnorm(params["ln_f"], x)
    table = params.get("unembed", params["embed"])
    return common.lm_head(table, x), new_cache


def prefill(params: dict, cfg: ArchConfig, tokens: Array) -> Array:
    """Prefill logits (cacheless scoring path — serving keeps the full-cache
    variant in repro.launch.serve; this one is what the prefill_32k dry-run
    lowers: the compute-dominant part of serving)."""
    return forward(params, cfg, {"tokens": tokens})
