"""Mixture-of-Experts FFN with top-k routing and two dispatch strategies.

Tokens are processed in fixed-size *groups* (GShard's G axis): capacity and
dispatch tensors are per-group, so the one-hot dispatch intermediate is
O(tokens · group_size · k · cf) — independent of E — instead of the
intractable O(tokens · E · C_global).

  * "einsum"  — GShard/Mesh-TF one-hot dispatch. SPMD-robust (pure einsums;
    GSPMD shards them with an all-to-all on the expert axis) but pays
    O(tokens·E·C_g·d) matmul FLOPs for dispatch+combine — the classic GShard
    overhead. This is the roofline baseline.

  * "scatter" — scatter/gather dispatch: per-expert queue positions from an
    integer cumsum (no MXU FLOPs), tokens moved by scatter-add/gather.
    Removes the dispatch matmuls entirely — the §Perf hillclimb change.

Experts are stacked on a leading E axis so expert parallelism is a single
PartitionSpec("model", ...) on the stacked weights.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, MoEConfig
from repro.models.common import Array, linear, linear_init, swiglu, swiglu_init


def moe_init(key, cfg: ArchConfig, dtype) -> dict:
    e = cfg.moe
    d, f = cfg.d_model, e.d_expert_ff
    ks = jax.random.split(key, 5)
    p = {
        "router": linear_init(ks[0], d, e.num_experts, dtype),
        "w_gate": jax.random.normal(ks[1], (e.num_experts, d, f), dtype)
        / math.sqrt(d),
        "w_up": jax.random.normal(ks[2], (e.num_experts, d, f), dtype)
        / math.sqrt(d),
        "w_down": jax.random.normal(ks[3], (e.num_experts, f, d), dtype)
        / math.sqrt(f),
    }
    if e.num_shared:
        p["shared"] = swiglu_init(ks[4], d, e.num_shared * f, dtype)
    return p


def _router(p: dict, x: Array, e: MoEConfig):
    """Top-k routing. x: (..., d). Returns (weights, ids): (..., k)."""
    logits = (x.astype(jnp.float32)
              @ p["router"]["w"].astype(jnp.float32))        # (..., E)
    probs = jax.nn.softmax(logits, axis=-1)
    weights, ids = jax.lax.top_k(probs, e.top_k)
    weights = weights / jnp.maximum(
        weights.sum(axis=-1, keepdims=True), 1e-9)
    return weights, ids


def _group(x: Array, group_size: int) -> tuple[Array, int, int]:
    """(B, S, d) -> (G, gs, d) with gs the largest divisor of the token
    count ≤ group_size (assigned cells divide exactly; odd smoke shapes — or
    MTP's S−1 slice — fall back to a smaller group)."""
    B, S, d = x.shape
    n = B * S
    gs = min(group_size, n)
    while n % gs:
        gs -= 1
    return x.reshape(n // gs, gs, d), n // gs, gs


def _capacity(tokens_per_group: int, e: MoEConfig) -> int:
    c = int(math.ceil(tokens_per_group * e.top_k / e.num_experts
                      * e.capacity_factor))
    return max(4, -(-c // 4) * 4)  # round up to a multiple of 4


GROUP_SIZE = 256


def moe_apply_einsum(p: dict, x: Array, cfg: ArchConfig) -> Array:
    """GShard one-hot dispatch. x: (B, S, d) -> (B, S, d)."""
    e = cfg.moe
    B, S, d = x.shape
    xg, G, gs = _group(x, GROUP_SIZE)
    C = _capacity(gs, e)
    weights, ids = _router(p, xg, e)                         # (G,gs,k)
    onehot = jax.nn.one_hot(ids, e.num_experts, dtype=jnp.float32)  # (G,gs,k,E)
    # Queue position of each (token, slot) in its expert, within the group.
    flat = onehot.reshape(G, gs * e.top_k, e.num_experts)
    pos = jnp.cumsum(flat, axis=1) - flat                    # (G,gs*k,E)
    pos = pos.reshape(G, gs, e.top_k, e.num_experts)
    keep = (pos < C).astype(jnp.float32) * onehot            # (G,gs,k,E)
    pos_c = jax.nn.one_hot((pos * onehot).sum(-1).astype(jnp.int32), C,
                           dtype=jnp.float32)                # (G,gs,k,C)
    # combine[g,s,e,c] = Σ_k w_k · keep · onehot(position)
    combine = jnp.einsum("gske,gskc,gsk->gsec", keep, pos_c,
                         weights.astype(jnp.float32))
    dispatch = (combine > 0).astype(x.dtype)                 # (G,gs,E,C)
    xe = jnp.einsum("gsec,gsd->egcd", dispatch, xg)          # (E,G,C,d)
    h = jax.nn.silu(jnp.einsum("egcd,edf->egcf", xe,
                               p["w_gate"].astype(x.dtype)))
    h = h * jnp.einsum("egcd,edf->egcf", xe, p["w_up"].astype(x.dtype))
    ye = jnp.einsum("egcf,efd->egcd", h, p["w_down"].astype(x.dtype))
    y = jnp.einsum("gsec,egcd->gsd", combine.astype(x.dtype), ye)
    y = y.reshape(B, S, d)
    if e.num_shared:
        y = y + swiglu(p["shared"], x)
    return y


def moe_apply_scatter(p: dict, x: Array, cfg: ArchConfig) -> Array:
    """Scatter/gather dispatch — no dispatch matmuls (hillclimbed path)."""
    e = cfg.moe
    B, S, d = x.shape
    xg, G, gs = _group(x, GROUP_SIZE)
    C = _capacity(gs, e)
    weights, ids = _router(p, xg, e)                         # (G,gs,k)
    onehot = jax.nn.one_hot(ids, e.num_experts, dtype=jnp.int32)
    flat = onehot.reshape(G, gs * e.top_k, e.num_experts)
    pos = jnp.cumsum(flat, axis=1) - flat
    pos = (pos.reshape(G, gs, e.top_k, e.num_experts) * onehot).sum(-1)
    keep = pos < C                                           # (G,gs,k)
    eid = ids.reshape(G, gs * e.top_k)
    pidx = jnp.where(keep, pos, C).reshape(G, gs * e.top_k)

    def scatter_group(xi, ei, pi):
        buf = jnp.zeros((e.num_experts, C + 1, d), x.dtype)
        src = jnp.repeat(xi, e.top_k, axis=0)                # (gs*k, d)
        return buf.at[ei, pi].add(src)[:, :C]                # (E,C,d)

    xe = jax.vmap(scatter_group)(xg, eid, pidx)              # (G,E,C,d)
    h = jax.nn.silu(jnp.einsum("gecd,edf->gecf", xe,
                               p["w_gate"].astype(x.dtype)))
    h = h * jnp.einsum("gecd,edf->gecf", xe, p["w_up"].astype(x.dtype))
    ye = jnp.einsum("gecf,efd->gecd", h, p["w_down"].astype(x.dtype))
    ye = jnp.pad(ye, ((0, 0), (0, 0), (0, 1), (0, 0)))       # drop row C

    def gather_group(yi, ei, pi):
        return yi[ei, pi]                                    # (gs*k, d)

    out = jax.vmap(gather_group)(ye, eid, pidx)              # (G,gs*k,d)
    out = out.reshape(G, gs, e.top_k, d)
    y = (out * weights[..., None].astype(x.dtype)).sum(axis=2)
    y = y.reshape(B, S, d)
    if e.num_shared:
        y = y + swiglu(p["shared"], x)
    return y


def moe_apply(p: dict, x: Array, cfg: ArchConfig) -> Array:
    if cfg.moe.dispatch == "scatter":
        return moe_apply_scatter(p, x, cfg)
    return moe_apply_einsum(p, x, cfg)
