"""Whisper-style encoder–decoder backbone (audio frontend stubbed).

The conv frontend is a stub per the assignment: `input_specs()` provides
precomputed frame embeddings (B, 1500, d). Encoder = non-causal self-attn
stack; decoder = causal self-attn + cross-attn. Whisper uses absolute
sinusoidal (encoder) / learned (decoder) positions; we use sinusoidal for
both (backbone-equivalent, no RoPE — noted in DESIGN.md).
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import attention as attn
from repro.models import common
from repro.models.common import Array, dtype_of, linear, linear_init


def _xattn_init(key, cfg: ArchConfig, dtype) -> dict:
    d, H, KV, Dh = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.dh
    ks = jax.random.split(key, 4)
    return {"wq": linear_init(ks[0], d, H * Dh, dtype),
            "wk": linear_init(ks[1], d, KV * Dh, dtype),
            "wv": linear_init(ks[2], d, KV * Dh, dtype),
            "wo": linear_init(ks[3], H * Dh, d, dtype)}


def _enc_layer_init(key, cfg: ArchConfig, dtype) -> dict:
    ks = jax.random.split(key, 2)
    return {"ln1": common.rmsnorm_init(cfg.d_model, dtype),
            "attn": attn.gqa_init(ks[0], cfg, dtype),
            "ln2": common.rmsnorm_init(cfg.d_model, dtype),
            "ffn": common.swiglu_init(ks[1], cfg.d_model, cfg.d_ff, dtype)}


def _dec_layer_init(key, cfg: ArchConfig, dtype) -> dict:
    ks = jax.random.split(key, 3)
    return {"ln1": common.rmsnorm_init(cfg.d_model, dtype),
            "attn": attn.gqa_init(ks[0], cfg, dtype),
            "lnx": common.rmsnorm_init(cfg.d_model, dtype),
            "xattn": _xattn_init(ks[1], cfg, dtype),
            "ln2": common.rmsnorm_init(cfg.d_model, dtype),
            "ffn": common.swiglu_init(ks[2], cfg.d_model, cfg.d_ff, dtype)}


def init_params(cfg: ArchConfig, key) -> dict:
    pdtype = dtype_of(cfg.param_dtype)
    n_enc, n_dec = cfg.encoder_layers, cfg.num_layers
    keys = jax.random.split(key, n_enc + n_dec + 2)
    enc = [_enc_layer_init(keys[i], cfg, pdtype) for i in range(n_enc)]
    dec = [_dec_layer_init(keys[n_enc + i], cfg, pdtype)
           for i in range(n_dec)]
    stack = lambda ts: jax.tree.map(lambda *xs: jnp.stack(xs), *ts)
    return {
        "enc_blocks": stack(enc),
        "enc_ln": common.rmsnorm_init(cfg.d_model, pdtype),
        "embed": common.embedding_init(keys[-1], cfg.vocab_size, cfg.d_model,
                                       pdtype),
        "dec_blocks": stack(dec),
        "dec_ln": common.rmsnorm_init(cfg.d_model, pdtype),
    }


def _cross_attend(p: dict, x: Array, enc_kv: tuple[Array, Array],
                  cfg: ArchConfig) -> Array:
    B, S = x.shape[:2]
    H, KV, Dh = cfg.num_heads, cfg.num_kv_heads, cfg.dh
    q = linear(p["wq"], x).reshape(B, S, H, Dh)
    k, v = enc_kv
    out = attn.flash_attention_jnp(q, k, v, causal=False)
    return linear(p["wo"], out.reshape(B, S, H * Dh))


def encode(params: dict, cfg: ArchConfig, frames: Array) -> Array:
    """frames: (B, S_enc, d) precomputed frame embeddings (conv stub)."""
    adtype = dtype_of(cfg.dtype)
    x = frames.astype(adtype)
    x = x + common.sinusoidal_positions(x.shape[1],
                                        cfg.d_model).astype(adtype)[None]
    B, S = x.shape[:2]
    positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S))

    def body(x, p):
        h = common.rmsnorm(p["ln1"], x)
        x = x + attn.gqa_attend(p["attn"], h, cfg, positions, causal=False,
                                use_rope=False)
        h = common.rmsnorm(p["ln2"], x)
        return x + common.swiglu(p["ffn"], h), None

    body_fn = jax.checkpoint(body) if cfg.remat else body
    x, _ = jax.lax.scan(body_fn, x, params["enc_blocks"])
    return common.rmsnorm(params["enc_ln"], x)


def _enc_kv(p: dict, enc_out: Array, cfg: ArchConfig) -> tuple[Array, Array]:
    B, S = enc_out.shape[:2]
    KV, Dh = cfg.num_kv_heads, cfg.dh
    k = linear(p["wk"], enc_out).reshape(B, S, KV, Dh)
    v = linear(p["wv"], enc_out).reshape(B, S, KV, Dh)
    return k, v


def decode(params: dict, cfg: ArchConfig, tokens: Array, enc_out: Array,
           ) -> Array:
    """Teacher-forced decoder -> fp32 logits (B, S_dec, V)."""
    adtype = dtype_of(cfg.dtype)
    x = common.embed(params["embed"], tokens, adtype)
    x = x + common.sinusoidal_positions(x.shape[1],
                                        cfg.d_model).astype(adtype)[None]
    B, S = x.shape[:2]
    positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S))

    def body(x, p):
        h = common.rmsnorm(p["ln1"], x)
        x = x + attn.gqa_attend(p["attn"], h, cfg, positions, causal=True,
                                use_rope=False)
        h = common.rmsnorm(p["lnx"], x)
        x = x + _cross_attend(p["xattn"], h, _enc_kv(p["xattn"], enc_out, cfg),
                              cfg)
        h = common.rmsnorm(p["ln2"], x)
        return x + common.swiglu(p["ffn"], h), None

    body_fn = jax.checkpoint(body) if cfg.remat else body
    x, _ = jax.lax.scan(body_fn, x, params["dec_blocks"])
    x = common.rmsnorm(params["dec_ln"], x)
    return common.lm_head(params["embed"], x)


def forward(params: dict, cfg: ArchConfig, batch: dict) -> Array:
    enc_out = encode(params, cfg, batch["frames"])
    return decode(params, cfg, batch["tokens"], enc_out)


def loss_fn(params: dict, cfg: ArchConfig, batch: dict) -> Array:
    logits = forward(params, cfg, batch)
    return common.cross_entropy(logits[:, :-1], batch["labels"][:, 1:])


# ---------------------------------------------------------------------------
# Serving
# ---------------------------------------------------------------------------
def init_cache(cfg: ArchConfig, batch: int, max_len: int) -> dict:
    adtype = dtype_of(cfg.dtype)
    KV, Dh = cfg.num_kv_heads, cfg.dh
    L = cfg.num_layers
    return {
        "k": jnp.zeros((L, batch, max_len, KV, Dh), adtype),
        "v": jnp.zeros((L, batch, max_len, KV, Dh), adtype),
        "enc_k": jnp.zeros((L, batch, cfg.encoder_seq, KV, Dh), adtype),
        "enc_v": jnp.zeros((L, batch, cfg.encoder_seq, KV, Dh), adtype),
    }


def start_cache(params: dict, cfg: ArchConfig, enc_out: Array, batch: int,
                max_len: int) -> dict:
    """Precompute per-layer cross-attention K/V from encoder output."""
    cache = init_cache(cfg, batch, max_len)

    def kv_for_layer(p):
        return _enc_kv(p["xattn"], enc_out, cfg)

    ks, vs = jax.vmap(kv_for_layer)(params["dec_blocks"])
    return {**cache, "enc_k": ks, "enc_v": vs}


def decode_step(params: dict, cfg: ArchConfig, cache: dict, token: Array,
                length) -> tuple[Array, dict]:
    adtype = dtype_of(cfg.dtype)
    B = token.shape[0]
    x = common.embed(params["embed"], token, adtype)
    # position length for the new token
    pos_table = common.sinusoidal_positions(cache["k"].shape[2],
                                            cfg.d_model).astype(adtype)
    x = x + jax.lax.dynamic_slice_in_dim(pos_table, length, 1, axis=0)[None]
    H, KV, Dh = cfg.num_heads, cfg.num_kv_heads, cfg.dh

    def body(x, inp):
        p, kc, vc, ek, ev = inp
        h = common.rmsnorm(p["ln1"], x)
        q = linear(p["attn"]["wq"], h).reshape(B, 1, H, Dh)
        k = linear(p["attn"]["wk"], h).reshape(B, 1, KV, Dh)
        v = linear(p["attn"]["wv"], h).reshape(B, 1, KV, Dh)
        kc = jax.lax.dynamic_update_slice_in_dim(kc, k, length, axis=1)
        vc = jax.lax.dynamic_update_slice_in_dim(vc, v, length, axis=1)
        o = attn.decode_attention(q, kc, vc, length + 1)
        x = x + linear(p["attn"]["wo"], o.reshape(B, 1, H * Dh))
        h = common.rmsnorm(p["lnx"], x)
        q = linear(p["xattn"]["wq"], h).reshape(B, 1, H, Dh)
        o = attn.decode_attention(q, ek, ev, ek.shape[1])
        x = x + linear(p["xattn"]["wo"], o.reshape(B, 1, H * Dh))
        h = common.rmsnorm(p["ln2"], x)
        x = x + common.swiglu(p["ffn"], h)
        return x, (kc, vc)

    x, (ks, vs) = jax.lax.scan(
        body, x, (params["dec_blocks"], cache["k"], cache["v"],
                  cache["enc_k"], cache["enc_v"]))
    x = common.rmsnorm(params["dec_ln"], x)
    logits = common.lm_head(params["embed"], x)
    return logits, {**cache, "k": ks, "v": vs}
