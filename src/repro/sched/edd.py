"""Earliest-Due-Date (EDD) batch scheduler simulator (paper §IV-A2).

"We implement an earliest due date (EDD) scheduler ... The simulator's inputs
include hourly energy capacity, server capacity, and a trace of batch jobs.
The simulator reports waiting time and tardiness — the waiting time beyond
what can be tolerated by the SLO for each job."

The simulator is discrete-hour and non-preemptive: a job occupies `power` NP
for `duration` consecutive hours once started. Each hour, queued jobs are
considered in EDD order and started if their power reservation fits within
the remaining hourly capacity for every hour of their run. This is the
training-data generator for the Lasso penalty models; Carbon Responder
supports any scheduling framework — EDD is the paper's choice.
"""
from __future__ import annotations

import dataclasses
import heapq
from typing import Sequence

import numpy as np

from repro.sched.traces import JobTrace


@dataclasses.dataclass(frozen=True)
class ScheduleResult:
    """Outcome of one simulated schedule.

    Attributes:
      start: (J,) hour each job started (np.inf if never scheduled in-window).
      waiting: (J,) hours waited beyond arrival (start - arrival).
      tardiness: (J,) positive part of (completion - due) for SLO'd jobs; 0
        for jobs with slo=inf.
      total_waiting: scalar sum of waiting over scheduled jobs (+ penalty
        window overflow for unscheduled ones).
      total_tardiness: scalar sum of tardiness.
      utilization: (T,) NP actually consumed each hour.
    """

    start: np.ndarray
    waiting: np.ndarray
    tardiness: np.ndarray
    total_waiting: float
    total_tardiness: float
    utilization: np.ndarray


class EDDScheduler:
    """Non-preemptive EDD scheduler over hourly power capacity."""

    def __init__(self, horizon_slack: int = 24):
        # Jobs that cannot finish in-window are charged waiting time up to
        # the extended horizon; keeps penalties finite and monotone.
        self.horizon_slack = horizon_slack

    def run(self, trace: JobTrace, capacity: np.ndarray) -> ScheduleResult:
        """Simulate. `capacity` is (T,) hourly NP available to this service."""
        capacity = np.asarray(capacity, dtype=float)
        T = capacity.shape[0]
        H = T + self.horizon_slack
        # Extend the horizon at baseline (last-hour) capacity so deferred work
        # drains rather than vanishing.
        cap = np.concatenate([capacity, np.full(self.horizon_slack,
                                                capacity[-1] if T else 0.0)])
        free = cap.copy()
        J = trace.num_jobs
        due = trace.due()
        start = np.full(J, np.inf)
        # Priority queue keyed by (due, arrival, jid); jobs enter at arrival.
        order = np.lexsort((trace.arrival, due))
        pending: list[tuple[float, float, int]] = []
        by_arrival: dict[int, list[int]] = {}
        for jid in order:
            by_arrival.setdefault(int(trace.arrival[jid]), []).append(int(jid))
        for t in range(H):
            for jid in by_arrival.get(t, ()):
                heapq.heappush(pending, (float(due[jid]), float(trace.arrival[jid]), jid))
            # Try to start pending jobs in EDD order. One deferred pass per
            # hour: jobs that do not fit stay queued (EDD is a heuristic, not
            # an optimal packer — matching production schedulers).
            deferred: list[tuple[float, float, int]] = []
            while pending:
                key = heapq.heappop(pending)
                jid = key[2]
                p = trace.power[jid]
                dur = int(trace.duration[jid])
                end = min(t + dur, H)
                if np.all(free[t:end] >= p - 1e-9) and end - t == dur:
                    free[t:end] -= p
                    start[jid] = t
                else:
                    deferred.append(key)
            for key in deferred:
                heapq.heappush(pending, key)
        # Unstarted jobs (couldn't fit even in the slack window): charge
        # maximal waiting; they would run after the horizon.
        unstarted = ~np.isfinite(start)
        eff_start = np.where(unstarted, float(H), start)
        waiting = eff_start - trace.arrival
        completion = eff_start + trace.duration
        with np.errstate(invalid="ignore"):
            tard = np.where(np.isfinite(trace.slo),
                            np.maximum(completion - due, 0.0), 0.0)
        util = cap - free
        return ScheduleResult(
            start=start, waiting=waiting, tardiness=tard,
            total_waiting=float(waiting.sum()),
            total_tardiness=float(tard.sum()),
            utilization=util[:T])


def random_walk_curtailments(usage: np.ndarray, num: int, seed: int = 0,
                             step_frac: float = 0.08,
                             max_frac: float = 0.5) -> np.ndarray:
    """Sample diverse curtailment vectors d via a random walk (paper §IV-A2,
    citing [63]), keeping only those with positive average curtailment.

    Returns (num, T) array; each row satisfies |d_t| <= max_frac * usage_t.
    """
    rng = np.random.default_rng(seed)
    T = usage.shape[0]
    out = np.zeros((num, T))
    kept = 0
    while kept < num:
        steps = rng.standard_normal(T) * step_frac * usage
        d = np.cumsum(steps)
        # Re-center around a random positive offset so means vary.
        d = d - d.mean() + rng.uniform(0.0, 0.15) * usage.mean()
        d = np.clip(d, -max_frac * usage, max_frac * usage)
        if d.mean() > 0:
            out[kept] = d
            kept += 1
    return out


def dr_shaped_curtailments(usage: np.ndarray, num: int, seed: int = 0,
                           max_frac: float = 0.5) -> np.ndarray:
    """Sustained DR-window curtailments: cut a contiguous block of hours by a
    constant depth, optionally rebounding afterwards. This is the shape real
    DR schedules take (paper Fig. 7: defer 18:00–08:00, recover 08:00–18:00)
    and covers the deep-sustained region the random walk rarely reaches.

    Returns (num, T); |d_t| <= max_frac * usage_t.
    """
    rng = np.random.default_rng(seed)
    T = usage.shape[0]
    out = np.zeros((num, T))
    for n in range(num):
        start = int(rng.integers(0, T - 4))
        length = int(rng.integers(4, min(24, T - start) + 1))
        depth = float(rng.uniform(0.1, max_frac))
        d = np.zeros(T)
        d[start:start + length] = depth * usage[start:start + length]
        if rng.uniform() < 0.5:  # rebound: run the deferred work later
            rb_len = min(T - (start + length), length)
            if rb_len > 0:
                deferred = d.sum() * float(rng.uniform(0.3, 1.0))
                sl = slice(start + length, start + length + rb_len)
                d[sl] -= deferred / rb_len
        out[n] = np.clip(d, -max_frac * usage, max_frac * usage)
    return out


def mixed_curtailments(usage: np.ndarray, num: int, seed: int = 0,
                       max_frac: float = 0.5) -> np.ndarray:
    """Half random-walk (paper §IV-A2, [63]), half sustained DR windows."""
    n_walk = num // 2
    walk = random_walk_curtailments(usage, n_walk, seed=seed,
                                    max_frac=max_frac)
    shaped = dr_shaped_curtailments(usage, num - n_walk, seed=seed + 1,
                                    max_frac=max_frac)
    return np.concatenate([walk, shaped], axis=0)
