"""Synthetic production-like traces (paper Table II/III).

The paper uses confidential Meta traces: daily-average power usage for four
services over 2021, plus job-level traces for AI training and data pipeline
(10,000 jobs subsampled in a two-day window). We generate synthetic traces
matched to the published statistics:

  - Fig. 1: datacenter power is nearly flat hour-to-hour (±~5%); real-time
    services dominate the mix, batch (AI + pipeline) is a smaller share.
  - Data pipeline jobs carry 5 SLO tiers: [1, 2, 4, 8, inf] hours.
  - AI training jobs have no SLO.

Power is expressed in **NP (Normalized Power)** units, the paper's internal
currency (§IV "Model Input").
"""
from __future__ import annotations

import dataclasses
from typing import Mapping, Sequence

import numpy as np

SLO_TIERS_HOURS = (1.0, 2.0, 4.0, 8.0, np.inf)

# Fleet mix fractions of total datacenter power, shaped after Fig. 1
# (RTS-dominant; batch without SLOs is a small share — §VI-C notes B4 is
# ineffective "because batch workloads without SLOs constitute a small share").
DEFAULT_MIX = {
    "RTS1": 0.42,
    "RTS2": 0.28,
    "DataPipeline": 0.20,
    "AITraining": 0.10,
}


@dataclasses.dataclass(frozen=True)
class ServiceTrace:
    """Hourly power usage for one service.

    Attributes:
      name: service name.
      usage: (T,) hourly power usage in NP.
      entitlement: power capacity entitlement E_i in NP (max permissible).
      kind: "realtime" | "batch_slo" | "batch_noslo".
    """

    name: str
    usage: np.ndarray
    entitlement: float
    kind: str

    @property
    def hours(self) -> int:
        return int(self.usage.shape[0])


@dataclasses.dataclass(frozen=True)
class JobTrace:
    """Job-level batch trace.

    Attributes:
      arrival: (J,) arrival hour (integer-valued float, within [0, T)).
      power: (J,) power draw while running, NP.
      duration: (J,) run length in hours (integer >= 1).
      slo: (J,) SLO in hours after arrival (np.inf for no-SLO jobs).
    """

    arrival: np.ndarray
    power: np.ndarray
    duration: np.ndarray
    slo: np.ndarray

    @property
    def num_jobs(self) -> int:
        return int(self.arrival.shape[0])

    def due(self) -> np.ndarray:
        """Due hour = arrival + duration + slo (landing time)."""
        return self.arrival + self.duration + self.slo

    def jobs_per_hour(self, hours: int) -> np.ndarray:
        """|J_{i,t}|: number of jobs arriving at each hour (Table IV)."""
        counts = np.zeros(hours)
        idx = np.clip(self.arrival.astype(int), 0, hours - 1)
        np.add.at(counts, idx, 1.0)
        return counts


def fleet_power_traces(hours: int = 48, total_power: float = 100.0,
                       mix: Mapping[str, float] | None = None,
                       headroom: float = 1.18, seed: int = 0,
                       ) -> dict[str, ServiceTrace]:
    """Hourly power usage for the four representative services (Fig. 1).

    Datacenter usage is nearly flat: each service gets a small diurnal ripple
    (+ noise) around its share of `total_power` NP. Entitlements sit slightly
    above observed peak usage (`headroom`), mirroring provisioned capacity.
    """
    mix = dict(DEFAULT_MIX if mix is None else mix)
    rng = np.random.default_rng(seed)
    t = np.arange(hours)
    out: dict[str, ServiceTrace] = {}
    kinds = {"RTS1": "realtime", "RTS2": "realtime",
             "DataPipeline": "batch_slo", "AITraining": "batch_noslo"}
    phases = {"RTS1": 15.0, "RTS2": 14.0, "DataPipeline": 2.0, "AITraining": 7.0}
    for name, share in mix.items():
        base = share * total_power
        # Realtime follows user diurnal load (peaks mid-afternoon); batch is
        # flatter (schedulers keep utilization high — Fan et al. [16]).
        ripple = 0.05 if kinds[name] == "realtime" else 0.02
        usage = base * (1.0 + ripple * np.sin(2 * np.pi * (t - phases[name]) / 24.0)
                        + 0.01 * rng.standard_normal(hours))
        usage = np.clip(usage, 0.05 * base, None)
        out[name] = ServiceTrace(
            name=name, usage=usage,
            entitlement=float(usage.max() * headroom), kind=kinds[name])
    return out


def make_job_trace(kind: str, hours: int = 48, num_jobs: int = 10_000,
                   total_power: float = 20.0, seed: int = 0) -> JobTrace:
    """Job-level trace for a batch service (paper: 10,000 jobs / 2 days).

    Args:
      kind: "batch_slo" (data pipeline — 5 SLO tiers) or "batch_noslo"
        (AI training — SLO = inf).
      total_power: average aggregate NP drawn by this service; individual job
        power is scaled so that expected concurrent demand matches it.
    """
    rng = np.random.default_rng(seed)
    arrival = rng.integers(0, hours, size=num_jobs).astype(float)
    if kind == "batch_slo":
        # Pipeline jobs: short, bursty, heavy-tailed power.
        duration = rng.choice([1, 1, 1, 2, 2, 3], size=num_jobs).astype(float)
        tier = rng.choice(len(SLO_TIERS_HOURS), size=num_jobs,
                          p=[0.3, 0.3, 0.2, 0.15, 0.05])
        slo = np.asarray(SLO_TIERS_HOURS, dtype=float)[tier]
    elif kind == "batch_noslo":
        # Training jobs: longer, no deadline.
        duration = rng.choice([1, 2, 2, 3, 4, 6], size=num_jobs).astype(float)
        slo = np.full(num_jobs, np.inf)
    else:
        raise ValueError(f"unknown batch kind {kind!r}")
    raw_power = rng.lognormal(mean=0.0, sigma=0.6, size=num_jobs)
    # Scale so that sum(power*duration) spread across `hours` equals
    # total_power on average.
    scale = total_power * hours / float((raw_power * duration).sum())
    power = raw_power * scale
    return JobTrace(arrival=arrival, power=power, duration=duration, slo=slo)
