"""Batch-job scheduling substrate: trace generation + EDD simulator.

This is the data source for the paper's batch penalty models (§IV-A2):
"We obtain training data by implementing a scheduler, simulating schedules
under varied processor availabilities, and measuring tardiness."
"""
from repro.sched.traces import (  # noqa: F401
    JobTrace,
    ServiceTrace,
    fleet_power_traces,
    make_job_trace,
)
from repro.sched.edd import EDDScheduler, ScheduleResult  # noqa: F401
