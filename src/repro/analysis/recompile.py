"""`recompile_guard` — assert jit trace-cache stability at runtime.

The engine stack's performance story rests on claims the test suite
historically could not check directly: a warm streaming re-solve is
*the same compiled trace* as the cold solve (PR 2), and a scanned
streaming day is *one* XLA dispatch (PR 6). Both break silently — a
stray weak type, a drifting static argument, or an accidentally
non-hashable static turns "one trace" into "a fresh compile per tick"
with no error, just a 100x slowdown.

`recompile_guard` makes the claim executable::

    with recompile_guard() as stats:          # max_compiles=0
        solver.step()                          # must hit the jit cache
    # raises RecompileError on exit if anything was traced/lowered

It counts two signals while active:

  * ``stats.traces``    — fresh jaxpr traces (`pjit` trace-cache
    misses). A cold jit call counts several (one per nested pjit);
    a warm call counts zero.
  * ``stats.lowerings`` — jaxpr→MLIR module lowerings, i.e. actual
    compilations handed to XLA.

The guard fires when either count exceeds ``max_compiles`` on normal
exit (an exception inside the body propagates unchanged). Because a
single cold compile produces an implementation-defined number of
nested traces, the useful contract is ``max_compiles=0`` — "this
region must be compile-free" — which is exactly the warm/one-dispatch
claim. For diagnostics, read the counts off the yielded stats object.

Implementation note: the counters wrap two private-but-stable jax
hooks (`jax._src.pjit._create_pjit_jaxpr`, re-wrapped in `lu.cache`
so cache semantics are preserved, and
`jax._src.interpreters.mlir.lower_jaxpr_to_module`) — the same
technique `jax._src.test_util`'s counting helpers use. If a jax
upgrade moves both hooks, the guard raises at entry rather than
silently counting nothing.
"""
from __future__ import annotations

import contextlib
import dataclasses

__all__ = ["RecompileError", "RecompileStats", "recompile_guard"]


class RecompileError(RuntimeError):
    """A `recompile_guard` region compiled more than it promised."""


@dataclasses.dataclass
class RecompileStats:
    """Counters for one guard region (also usable purely for reporting
    with ``max_compiles=None``)."""
    traces: int = 0        # fresh pjit jaxpr traces (cache misses)
    lowerings: int = 0     # jaxpr->MLIR lowerings (XLA compiles)

    @property
    def compiled(self) -> bool:
        return self.traces > 0 or self.lowerings > 0


@contextlib.contextmanager
def recompile_guard(max_compiles: int | None = 0, *, label: str = ""):
    """Count jit traces/lowerings in the region; raise if over budget.

    Args:
      max_compiles: fail on exit when `traces` or `lowerings` exceeds
        this. 0 (default) asserts the region is compile-free — the
        warm-path/one-dispatch contract. None disables the check
        (pure measurement).
      label: prefix for the error message (e.g. the tick being run).

    Yields a `RecompileStats` whose counters update live.
    """
    from jax._src import linear_util as lu
    from jax._src import pjit as _pjit
    from jax._src.interpreters import mlir as _mlir

    stats = RecompileStats()
    orig_trace = getattr(_pjit, "_create_pjit_jaxpr", None)
    orig_lower = getattr(_mlir, "lower_jaxpr_to_module", None)
    if orig_trace is None and orig_lower is None:
        raise RecompileError(
            "recompile_guard found neither jax hook it counts with "
            "(jax internals moved?) — refusing to guard nothing")

    if orig_trace is not None:
        @lu.cache   # preserve the hook's memoization contract
        def trace_and_count(*args, **kwargs):
            stats.traces += 1
            return orig_trace(*args, **kwargs)
        _pjit._create_pjit_jaxpr = trace_and_count
    if orig_lower is not None:
        def lower_and_count(*args, **kwargs):
            stats.lowerings += 1
            return orig_lower(*args, **kwargs)
        _mlir.lower_jaxpr_to_module = lower_and_count
    try:
        yield stats
    finally:
        if orig_trace is not None:
            _pjit._create_pjit_jaxpr = orig_trace
        if orig_lower is not None:
            _mlir.lower_jaxpr_to_module = orig_lower
    if max_compiles is not None and (stats.traces > max_compiles
                                     or stats.lowerings > max_compiles):
        where = f"{label}: " if label else ""
        raise RecompileError(
            f"{where}guarded region compiled: {stats.traces} fresh "
            f"trace(s), {stats.lowerings} lowering(s) "
            f"(allowed {max_compiles}) — a static argument, shape, or "
            f"dtype drifted and the jit cache missed")
