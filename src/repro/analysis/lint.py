"""drlint CLI — run the repo's JAX-invariant rules over source trees.

Usage::

    python -m repro.analysis.lint [paths ...] [--fail-on-violation]
    python -m repro.analysis.lint --list-rules

With no paths, lints the installed `repro` package source tree (the
`src/repro` this module was imported from). Output is one
``path:line:col: rule message`` line per violation — the format
editors and pre-commit hooks parse — and the exit code is nonzero
when any unsuppressed violation is found (``--fail-on-violation`` is
accepted for explicitness in CI scripts; the behavior is the default).

Runs on the AST only: no JAX import, no repo import, millisecond
latency. See `repro.analysis.rules` for the rule registry and the
suppression-comment syntax.
"""
from __future__ import annotations

import argparse
import pathlib
import sys
from typing import Iterable, Sequence

from repro.analysis.rules import RULES, Violation, lint_source


def iter_python_files(paths: Iterable[str]) -> list[pathlib.Path]:
    files: list[pathlib.Path] = []
    for p in paths:
        path = pathlib.Path(p)
        if path.is_dir():
            files.extend(sorted(path.rglob("*.py")))
        elif path.suffix == ".py":
            files.append(path)
        else:
            raise FileNotFoundError(f"not a python file or directory: {p}")
    return files


def lint_paths(paths: Iterable[str]) -> list[Violation]:
    """Lint every .py file under `paths`; returns unsuppressed violations."""
    out: list[Violation] = []
    for f in iter_python_files(paths):
        out.extend(lint_source(str(f), f.read_text()))
    return out


def _default_paths() -> list[str]:
    return [str(pathlib.Path(__file__).resolve().parents[1])]


def main(argv: Sequence[str] | None = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis.lint",
        description="drlint: this repo's JAX invariants as an AST pass")
    ap.add_argument("paths", nargs="*",
                    help="files/directories to lint (default: the "
                         "installed repro package tree)")
    ap.add_argument("--fail-on-violation", action="store_true",
                    help="exit 1 on violations (the default; the flag "
                         "documents intent in CI scripts)")
    ap.add_argument("--list-rules", action="store_true",
                    help="print the rule registry and exit")
    args = ap.parse_args(argv)
    if args.list_rules:
        width = max(len(n) for n in RULES)
        for name in sorted(RULES):
            print(f"{name:<{width}}  {RULES[name].summary}")
        return 0
    violations = lint_paths(args.paths or _default_paths())
    for v in violations:
        print(v.format())
    n = len(violations)
    print(f"drlint: {n} violation{'s' if n != 1 else ''} "
          f"({len(RULES)} rules)", file=sys.stderr)
    return 1 if violations else 0


if __name__ == "__main__":
    sys.exit(main())
