"""Runtime sanitizer plumbing: checkify non-finite guards for the engine.

`SolveContext(sanitize=True)` routes CR1/CR2 solo solves through
checkify-wrapped twins of the same jitted impls; the engine's AL inner
loop then emits the finiteness checks defined here (gated on
`EngineConfig.sanitize`, so the default lanes compile zero check code).
A NaN or inf in the gradient, iterate, or multipliers surfaces as a
`JaxRuntimeError` naming the first check that failed — instead of
silently corrupting the plan and every warm re-solve chained after it.

The split keeps the layering clean: this module knows checkify and
nothing about the engine; `core.engine` emits checks through
`check_all_finite`; `core.api` owns the `checked_jit` twins and the
`err.throw()` at the call boundary.

`checkify.check` is only legal under a `checkify.checkify` transform —
which is why `EngineConfig.sanitize` must never be True outside the
`checked_jit` lanes (api.py enforces this pairing).
"""
from __future__ import annotations

from typing import Callable, Sequence

import jax
import jax.numpy as jnp
from jax.experimental import checkify

__all__ = ["SanitizeError", "check_all_finite", "checked_jit"]

#: What a fired sanitizer check raises from `err.throw()`.
SanitizeError = checkify.JaxRuntimeError


def check_all_finite(tag: str, **named) -> None:
    """Emit one checkify non-finite check per named array.

    `tag` names the engine site (e.g. ``"al-inner"``); the array's
    keyword name rides into the error message so a failure reads
    ``al-inner: non-finite values in grad``. Call only from code that
    executes under `checkify.checkify` (see module docstring).
    """
    for name, value in named.items():
        checkify.check(
            jnp.isfinite(jnp.asarray(value)).all(),
            f"{tag}: non-finite values in {name} — the solve diverged or "
            f"its inputs carry NaN/inf")


def checked_jit(fn: Callable, *,
                static_argnames: Sequence[str] = ()) -> Callable:
    """`jax.jit(checkify.checkify(fn))` — the sanitizer twin of a lane.

    Only user checks (`checkify.check`, i.e. `check_all_finite`) are
    functionalized: the sanitizer asserts the invariants the engine
    states explicitly, rather than paying for checkify's automatic
    div/index instrumentation on every primitive. The wrapped function
    returns ``(err, out)``; the caller must `err.throw()`.
    """
    return jax.jit(checkify.checkify(fn, errors=checkify.user_checks),
                   static_argnames=tuple(static_argnames))
