"""drlint rule registry: this repo's JAX invariants as AST checks.

Every rule encodes an invariant the engine stack actually relies on —
each one has a motivating incident (see `analysis/README.md` for the
full list with history):

  * ``jit-host-leak``        — no host-side Python on traced values in
                               jit-reachable functions.
  * ``donation-twin``        — every ``jit(donate_argnums=...)`` twin
                               mirrors a non-donated sibling (api.py's
                               twin pattern).
  * ``check-rep-justification`` — ``shard_map(..., check_rep=False)``
                               must carry a comment naming the
                               pallas_call that requires it.
  * ``tuple-seed``           — ``default_rng((seed, ...))`` tuple
                               seeding, never ``seed + idx`` arithmetic.
  * ``np-on-traced``         — no ``np.*`` value computation in
                               jit-reachable hot paths (shape/metadata
                               queries are whitelisted).
  * ``deprecated-shim``      — internal code must not call the legacy
                               ``solve_cr{1,2,3}_fleet`` shims.
  * ``adhoc-partition-spec`` — no string-literal axis names in
                               ``P(...)``; axis names flow from
                               `repro.launch.mesh` / `regional.norm_specs`.
  * ``host-sync-in-jit``     — no ``block_until_ready`` /
                               ``jax.device_get`` / ``obs.span`` inside
                               jit-reachable code; host syncs live
                               outside the trace (telemetry rides the
                               solve as stacked aux outputs instead).

Suppression: append ``# drlint: disable=<rule>[,<rule>] -- <rationale>``
to the flagged line, or put it on its own line directly above. The
rationale after ``--`` is mandatory — a suppression without one is
itself a violation (``suppression-rationale``).

Rules are module-local by design: the checker parses one file at a time
and never imports the code under analysis, so drlint runs in
milliseconds with no JAX (or any repo) import. Cross-module jit
reachability is approximated by `EXTRA_JIT_ROOTS` — the short table of
functions this repo documents as "jitted by their callers" (e.g.
`engine.al_minimize`, which adapters wrap in their own `jax.jit`).
"""
from __future__ import annotations

import ast
import dataclasses
import io
import re
import tokenize
from typing import Callable, Iterable

__all__ = ["EXTRA_JIT_ROOTS", "Module", "RULES", "Violation", "lint_source"]


# ---------------------------------------------------------------------------
# Infrastructure: violations, modules, suppressions, registry
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class Violation:
    """One rule hit at a source location."""
    rule: str
    path: str
    line: int
    col: int
    message: str

    def format(self) -> str:
        return f"{self.path}:{self.line}:{self.col}: {self.rule} " \
               f"{self.message}"


@dataclasses.dataclass(frozen=True)
class Suppression:
    line: int
    rules: frozenset[str]
    rationale: str


_SUPPRESS_RE = re.compile(
    r"#\s*drlint:\s*disable=([\w\-, ]+?)\s*(?:--\s*(.*\S))?\s*$")


@dataclasses.dataclass
class Module:
    """One parsed source file plus everything the rules need."""
    path: str
    source: str
    tree: ast.Module
    comments: dict[int, str]            # line -> comment text
    suppressions: dict[int, Suppression]  # line the suppression sits on

    @classmethod
    def parse(cls, path: str, source: str) -> "Module":
        tree = ast.parse(source, filename=path)
        comments: dict[int, str] = {}
        try:
            toks = tokenize.generate_tokens(io.StringIO(source).readline)
            for tok in toks:
                if tok.type == tokenize.COMMENT:
                    comments[tok.start[0]] = tok.string
        except tokenize.TokenError:
            pass
        sups = {}
        for line, text in comments.items():
            m = _SUPPRESS_RE.search(text)
            if m:
                names = frozenset(
                    s.strip() for s in m.group(1).split(",") if s.strip())
                sups[line] = Suppression(line, names, m.group(2) or "")
        return cls(path, source, tree, comments, sups)

    def suppressed(self, rule: str, line: int) -> bool:
        """A suppression covers its own line and the line below it."""
        for at in (line, line - 1):
            s = self.suppressions.get(at)
            if s is not None and rule in s.rules:
                return True
        return False


@dataclasses.dataclass(frozen=True)
class Rule:
    name: str
    summary: str
    check: Callable[[Module], list[Violation]]


RULES: dict[str, Rule] = {}


def rule(name: str, summary: str):
    def deco(fn):
        RULES[name] = Rule(name, summary, fn)
        return fn
    return deco


def lint_source(path: str, source: str) -> list[Violation]:
    """Run every registered rule over one file; apply suppressions."""
    mod = Module.parse(path, source)
    out: list[Violation] = []
    for r in RULES.values():
        for v in r.check(mod):
            if not mod.suppressed(v.rule, v.line):
                out.append(v)
    # A suppression that hides a rule must say why: rationale-free
    # suppressions defeat the point of the pass (rule of the pass itself,
    # so it cannot be suppressed).
    for s in mod.suppressions.values():
        if not s.rationale:
            out.append(Violation(
                "suppression-rationale", path, s.line, 0,
                "suppression without rationale — append '-- <why>'"))
    return sorted(out, key=lambda v: (v.line, v.col, v.rule))


# ---------------------------------------------------------------------------
# Shared AST helpers
# ---------------------------------------------------------------------------
def _dotted(node: ast.AST) -> str:
    """'jax.jit' for Attribute chains / Names; '' for anything else."""
    if isinstance(node, ast.Name):
        return node.id
    if isinstance(node, ast.Attribute):
        base = _dotted(node.value)
        return f"{base}.{node.attr}" if base else node.attr
    return ""


def _is_jax_jit(node: ast.AST) -> bool:
    return _dotted(node) in ("jax.jit", "jit")


def _jit_target(call: ast.Call) -> str | None:
    """The function name jitted by a `jax.jit(fn, ...)` call, if a Name."""
    if _is_jax_jit(call.func) and call.args \
            and isinstance(call.args[0], ast.Name):
        return call.args[0].id
    return None


def _partial_jit_decorator(dec: ast.AST) -> bool:
    """`@functools.partial(jax.jit, ...)` / `@partial(jit, ...)`."""
    return (isinstance(dec, ast.Call)
            and _dotted(dec.func) in ("functools.partial", "partial")
            and bool(dec.args) and _is_jax_jit(dec.args[0]))


#: path-suffix -> function names jitted by *callers* in other modules.
#: The engine is deliberately not jitted in its own module (adapters own
#: the jit so warm re-solves share one trace) — without this table the
#: reachability walk would never enter it.
EXTRA_JIT_ROOTS: dict[str, frozenset[str]] = {
    "core/engine.py": frozenset(
        {"al_minimize", "al_minimize_batched", "al_minimize_sharded"}),
    # fleet_solver helpers called from inside api.py's jitted impls.
    "core/fleet_solver.py": frozenset(
        {"fleet_penalties", "_projection", "_bounds", "_enter_tick"}),
    # regional norm builders ride inside the jitted lanes.
    # (`region_totals`/`cr3_reg_scale` are deliberately host-side numpy
    # — see their docstrings — so they are NOT roots.)
    "core/regional.py": frozenset(
        {"cr1_norms", "cr2_norms", "region_sum"}),
}


def _function_index(tree: ast.Module) -> dict[str, list[ast.FunctionDef]]:
    idx: dict[str, list[ast.FunctionDef]] = {}
    for node in ast.walk(tree):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            idx.setdefault(node.name, []).append(node)
    return idx


def _jit_reachable(mod: Module) -> list[ast.FunctionDef]:
    """FunctionDefs reachable (same module) from a jit root.

    Roots: `X = jax.jit(fn, ...)` assignments, `@jax.jit` /
    `@functools.partial(jax.jit, ...)` decorators, and EXTRA_JIT_ROOTS.
    Edges: any Name reference inside a reachable body that matches a
    module function (catches plain calls and functions handed to
    vmap/scan/shard_map alike — a deliberate over-approximation)."""
    idx = _function_index(mod.tree)
    roots: set[str] = set()
    for node in ast.walk(mod.tree):
        if isinstance(node, ast.Call):
            t = _jit_target(node)
            if t:
                roots.add(t)
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            for dec in node.decorator_list:
                if _is_jax_jit(dec) or _partial_jit_decorator(dec):
                    roots.add(node.name)
    norm = mod.path.replace("\\", "/")
    for suffix, names in EXTRA_JIT_ROOTS.items():
        if norm.endswith(suffix):
            roots |= names
    seen: set[int] = set()
    out: list[ast.FunctionDef] = []
    work = [fn for name in roots for fn in idx.get(name, [])]
    while work:
        fn = work.pop()
        if id(fn) in seen:
            continue
        seen.add(id(fn))
        out.append(fn)
        for node in ast.walk(fn):
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)) \
                    and node is not fn and id(node) not in seen:
                work.append(node)   # nested defs run under the same trace
            if isinstance(node, ast.Name) and node.id in idx:
                work.extend(f for f in idx[node.id] if id(f) not in seen)
    return out


def _own_statements(fn: ast.FunctionDef) -> Iterable[ast.AST]:
    """Walk `fn` excluding nested function bodies (they are reported as
    their own reachable functions — avoids double counting)."""
    work: list[ast.AST] = list(ast.iter_child_nodes(fn))
    while work:
        node = work.pop()
        yield node
        if not isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef,
                                 ast.Lambda)):
            work.extend(ast.iter_child_nodes(node))


# ---------------------------------------------------------------------------
# Rule 1: jit-host-leak
# ---------------------------------------------------------------------------
_STATIC_METADATA_ATTRS = {"shape", "ndim", "size", "dtype"}


def _is_static_expr(node: ast.AST) -> bool:
    """Heuristic: expressions whose value is trace-time static even when
    built from a traced array — shape/metadata queries and literals."""
    if isinstance(node, ast.Constant):
        return True
    for sub in ast.walk(node):
        if isinstance(sub, ast.Attribute) \
                and sub.attr in _STATIC_METADATA_ATTRS:
            return True
        if isinstance(sub, ast.Call) and _dotted(sub.func) in (
                "len", "np.ndim", "np.shape", "jnp.ndim", "jnp.shape"):
            return True
    return False


def _traced_test(test: ast.AST) -> bool:
    """True when an `if` test computes on traced values: any jnp.* call,
    or a .any()/.all() reduction. Static metadata (`x.ndim == 2`,
    `if n_eq:`) stays legal."""
    for sub in ast.walk(test):
        if isinstance(sub, ast.Call):
            name = _dotted(sub.func)
            if name.startswith("jnp.") or name.startswith("jax.numpy."):
                return True
            if isinstance(sub.func, ast.Attribute) \
                    and sub.func.attr in ("any", "all", "item"):
                return True
    return False


@rule("jit-host-leak",
      "host-side Python on traced values inside jit-reachable code")
def _check_host_leak(mod: Module) -> list[Violation]:
    out = []
    for fn in _jit_reachable(mod):
        for node in _own_statements(fn):
            if isinstance(node, ast.Call):
                name = _dotted(node.func)
                if name in ("float", "int", "bool") and node.args \
                        and not _is_static_expr(node.args[0]):
                    out.append(Violation(
                        "jit-host-leak", mod.path, node.lineno,
                        node.col_offset,
                        f"`{name}()` on a (potentially traced) value in "
                        f"jit-reachable `{fn.name}` — concretizes the "
                        f"tracer; keep it an array or hoist to the host"))
                if isinstance(node.func, ast.Attribute) \
                        and node.func.attr == "item":
                    out.append(Violation(
                        "jit-host-leak", mod.path, node.lineno,
                        node.col_offset,
                        f"`.item()` in jit-reachable `{fn.name}` — "
                        f"forces a device sync / fails under trace"))
            if isinstance(node, (ast.If, ast.While)) \
                    and _traced_test(node.test):
                out.append(Violation(
                    "jit-host-leak", mod.path, node.lineno,
                    node.col_offset,
                    f"Python branch on a traced condition in "
                    f"jit-reachable `{fn.name}` — use jnp.where/"
                    f"lax.cond instead"))
    return out


# ---------------------------------------------------------------------------
# Rule 2: donation-twin
# ---------------------------------------------------------------------------
@dataclasses.dataclass
class _JitEntry:
    var: str
    target: str
    call: ast.Call
    kwargs: dict[str, ast.AST]


def _top_level_constants(tree: ast.Module) -> dict[str, tuple]:
    """Resolve `_CR1_STATIC = ("steps", ...)`-style tuple constants."""
    consts: dict[str, tuple] = {}
    for node in tree.body:
        if isinstance(node, ast.Assign) and len(node.targets) == 1 \
                and isinstance(node.targets[0], ast.Name):
            try:
                consts[node.targets[0].id] = ast.literal_eval(node.value)
            except (ValueError, SyntaxError):
                pass
    return consts


def _resolve(node: ast.AST | None, consts: dict[str, tuple]):
    if node is None:
        return None
    if isinstance(node, ast.Name):
        return consts.get(node.id, ...)   # ... = unresolvable
    try:
        return ast.literal_eval(node)
    except (ValueError, SyntaxError):
        return ...


@rule("donation-twin",
      "jit(donate_argnums=...) must mirror a non-donated sibling")
def _check_donation_twin(mod: Module) -> list[Violation]:
    consts = _top_level_constants(mod.tree)
    fns = {n.name: n for n in mod.tree.body
           if isinstance(n, ast.FunctionDef)}
    entries: list[_JitEntry] = []
    for node in mod.tree.body:
        if isinstance(node, ast.Assign) and len(node.targets) == 1 \
                and isinstance(node.targets[0], ast.Name) \
                and isinstance(node.value, ast.Call):
            target = _jit_target(node.value)
            if target:
                entries.append(_JitEntry(
                    node.targets[0].id, target, node.value,
                    {kw.arg: kw.value for kw in node.value.keywords
                     if kw.arg}))
    out = []
    for e in entries:
        if "donate_argnums" not in e.kwargs:
            continue
        static = _resolve(e.kwargs.get("static_argnames"), consts)
        siblings = [
            s for s in entries
            if s.target == e.target and "donate_argnums" not in s.kwargs
            and _resolve(s.kwargs.get("static_argnames"), consts) == static]
        if not siblings:
            out.append(Violation(
                "donation-twin", mod.path, e.call.lineno,
                e.call.col_offset,
                f"`{e.var}` donates `{e.target}` buffers but no "
                f"non-donated jit of `{e.target}` with matching "
                f"static_argnames exists — the twin pattern needs both"))
            continue
        donated = _resolve(e.kwargs["donate_argnums"], consts)
        fn = fns.get(e.target)
        if fn is None or donated is ...:
            continue
        if isinstance(donated, int):
            donated = (donated,)
        pos = [a.arg for a in fn.args.posonlyargs + fn.args.args]
        static_names = set(static) if isinstance(static, tuple) else set()
        for i in donated:
            if not isinstance(i, int) or i >= len(pos):
                out.append(Violation(
                    "donation-twin", mod.path, e.call.lineno,
                    e.call.col_offset,
                    f"`{e.var}` donates position {i} but `{e.target}` "
                    f"has only {len(pos)} positional params"))
            elif pos[i] in static_names:
                out.append(Violation(
                    "donation-twin", mod.path, e.call.lineno,
                    e.call.col_offset,
                    f"`{e.var}` donates `{pos[i]}` (position {i}) which "
                    f"is static — donation applies to traced buffers"))
    return out


# ---------------------------------------------------------------------------
# Rule 3: check-rep-justification
# ---------------------------------------------------------------------------
@rule("check-rep-justification",
      "shard_map(check_rep=False) must name its pallas_call in a comment")
def _check_check_rep(mod: Module) -> list[Violation]:
    out = []
    for node in ast.walk(mod.tree):
        if not isinstance(node, ast.Call) \
                or "shard_map" not in _dotted(node.func):
            continue
        for kw in node.keywords:
            if kw.arg == "check_rep" and isinstance(kw.value, ast.Constant) \
                    and kw.value.value is False:
                lines = range(max(1, node.lineno - 6), kw.value.lineno + 1)
                justified = any(
                    "pallas" in mod.comments.get(ln, "").lower()
                    for ln in lines)
                if not justified:
                    out.append(Violation(
                        "check-rep-justification", mod.path,
                        kw.value.lineno, kw.value.col_offset,
                        "check_rep=False without a nearby comment naming "
                        "the pallas_call that requires it (pallas kernels "
                        "have no shard_map replication rule — say which "
                        "one, or drop the flag)"))
    return out


# ---------------------------------------------------------------------------
# Rule 4: tuple-seed
# ---------------------------------------------------------------------------
_ARITH = (ast.Add, ast.Sub, ast.Mult, ast.FloorDiv, ast.Mod, ast.BitXor,
          ast.LShift, ast.RShift)


def _has_tuple_operand(node: ast.AST) -> bool:
    return any(isinstance(sub, ast.Tuple) for sub in ast.walk(node))


@rule("tuple-seed",
      "RNG seeds must be tuples, never seed arithmetic")
def _check_tuple_seed(mod: Module) -> list[Violation]:
    out = []
    for node in ast.walk(mod.tree):
        if not isinstance(node, ast.Call) or not node.args:
            continue
        name = _dotted(node.func)
        if not (name.endswith("default_rng") or name.endswith("PRNGKey")):
            continue
        arg = node.args[0]
        if isinstance(arg, ast.BinOp) \
                and isinstance(arg.op, _ARITH) \
                and not _has_tuple_operand(arg):
            out.append(Violation(
                "tuple-seed", mod.path, node.lineno, node.col_offset,
                f"seed arithmetic in `{name}(...)` — streams collide "
                f"when index products overlap (the PR 5 incident class); "
                f"seed with a tuple: `{name}((seed, idx, ...))`"))
    return out


# ---------------------------------------------------------------------------
# Rule 5: np-on-traced
# ---------------------------------------------------------------------------
#: np.* calls that only read static metadata — safe on tracers.
_NP_METADATA_OK = frozenset(
    {"ndim", "shape", "dtype", "result_type", "issubdtype",
     "broadcast_shapes", "size"})


@rule("np-on-traced",
      "no numpy value computation in jit-reachable hot paths")
def _check_np_on_traced(mod: Module) -> list[Violation]:
    out = []
    for fn in _jit_reachable(mod):
        for node in _own_statements(fn):
            if not isinstance(node, ast.Call):
                continue
            name = _dotted(node.func)
            if name.startswith("np.") and not name.startswith("np.random."):
                attr = name.split(".", 1)[1]
                if attr not in _NP_METADATA_OK:
                    out.append(Violation(
                        "np-on-traced", mod.path, node.lineno,
                        node.col_offset,
                        f"`{name}(...)` in jit-reachable `{fn.name}` — "
                        f"numpy concretizes tracers (ConcretizationTypeError"
                        f" at best, silent host fallback at worst); use "
                        f"jnp, or hoist the computation out of the traced "
                        f"region"))
    return out


# ---------------------------------------------------------------------------
# Rule 6: deprecated-shim
# ---------------------------------------------------------------------------
_SHIMS = frozenset({"solve_cr1_fleet", "solve_cr1_fleet_sweep",
                    "solve_cr2_fleet", "solve_cr3_fleet"})


@rule("deprecated-shim",
      "internal code must not call the legacy solve_cr*_fleet shims")
def _check_deprecated_shim(mod: Module) -> list[Violation]:
    if mod.path.replace("\\", "/").endswith("core/fleet_solver.py"):
        return []   # the shims' own home (definitions + parity docs)
    out = []
    for node in ast.walk(mod.tree):
        if isinstance(node, ast.Call):
            name = _dotted(node.func)
            base = name.rsplit(".", 1)[-1]
            if base in _SHIMS:
                out.append(Violation(
                    "deprecated-shim", mod.path, node.lineno,
                    node.col_offset,
                    f"`{base}` is a deprecated shim — call "
                    f"`api.solve(problem, policy, ctx=...)` instead"))
    return out


# ---------------------------------------------------------------------------
# Rule 7: adhoc-partition-spec
# ---------------------------------------------------------------------------
@rule("adhoc-partition-spec",
      "PartitionSpec axis names must come from launch.mesh, not literals")
def _check_adhoc_pspec(mod: Module) -> list[Violation]:
    # Scoped to the fleet engine (core/): that is where specs and the
    # fleet mesh must stay in sync through `fleet_axes`/`norm_specs`.
    # The generic training scaffolding (launch/sharding.py) has its own
    # ("data", "model") axis vocabulary and is out of scope.
    if "/core/" not in mod.path.replace("\\", "/"):
        return []
    out = []
    for node in ast.walk(mod.tree):
        if not isinstance(node, ast.Call):
            continue
        name = _dotted(node.func)
        if name not in ("P", "PartitionSpec") \
                and not name.endswith(".PartitionSpec"):
            continue
        for arg in node.args:
            bad = [s for s in ast.walk(arg)
                   if isinstance(s, ast.Constant) and isinstance(s.value,
                                                                 str)]
            if bad:
                out.append(Violation(
                    "adhoc-partition-spec", mod.path, node.lineno,
                    node.col_offset,
                    f"string-literal axis name {bad[0].value!r} in "
                    f"`P(...)` — axis names flow from "
                    f"`launch.mesh.fleet_axes`/`FLEET_AXIS`/"
                    f"`REGION_AXIS` (and norm specs from "
                    f"`regional.norm_specs`) so mesh refactors can't "
                    f"silently desync specs"))
                break
    return out


# ---------------------------------------------------------------------------
# Rule 8: host-sync-in-jit
# ---------------------------------------------------------------------------
#: dotted names that force a host<->device synchronization (or, for
#: obs.span, deliberately block on device work before reading a clock).
_HOST_SYNC = frozenset({"jax.block_until_ready", "block_until_ready",
                        "jax.device_get", "device_get",
                        "obs.span", "span"})


@rule("host-sync-in-jit",
      "no block_until_ready/device_get/obs.span in jit-reachable code")
def _check_host_sync(mod: Module) -> list[Violation]:
    out = []
    for fn in _jit_reachable(mod):
        for node in _own_statements(fn):
            if not isinstance(node, ast.Call):
                continue
            name = _dotted(node.func)
            if name in _HOST_SYNC or name.endswith(".block_until_ready") \
                    or name.endswith(".device_get"):
                base = name.rsplit(".", 1)[-1]
                out.append(Violation(
                    "host-sync-in-jit", mod.path, node.lineno,
                    node.col_offset,
                    f"`{name}(...)` in jit-reachable `{fn.name}` — a host "
                    f"sync has no meaning under trace ({base} on a tracer "
                    f"is a no-op at best, a concretization error at "
                    f"worst) and pins the dispatch pipeline if the "
                    f"function also runs eagerly; keep host syncs and "
                    f"`obs.span` timing OUTSIDE jitted code — in-solve "
                    f"observability rides the solve as stacked aux "
                    f"outputs (see `repro.obs.telemetry`)"))
    return out
