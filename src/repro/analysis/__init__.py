"""`repro.analysis` — the correctness tooling tier.

Two halves:

  * **drlint** (`repro.analysis.lint` / `.rules`): an AST static-
    analysis pass encoding the repo's JAX invariants — jit twins,
    check_rep justifications, tuple seeding, host-leak bans — as a
    rule registry with per-rule suppression comments. Run it with
    ``python -m repro.analysis.lint``; `scripts/ci.sh` fails on
    violations.
  * **runtime sanitizers** (`.sanitize` / `.recompile`):
    `SolveContext(sanitize=True)` threads checkify non-finite guards
    through the CR1/CR2 lanes and the AL inner loop, and
    `recompile_guard()` asserts the warm-path one-trace and
    one-dispatch-per-day claims at runtime.

`analysis/README.md` documents every lint rule with its motivating
incident.
"""
from repro.analysis.recompile import (RecompileError, RecompileStats,
                                      recompile_guard)
from repro.analysis.sanitize import (SanitizeError, check_all_finite,
                                     checked_jit)

__all__ = ["RecompileError", "RecompileStats", "SanitizeError",
           "check_all_finite", "checked_jit", "recompile_guard"]
