"""Roofline → power models: the bridge from the LM fleet to Carbon Responder.

The paper's Table III sources per-service power from production meters. Our
fleet's "meters" are the compiled dry-run artifacts: per (arch × shape) the
three roofline terms give a step time and a utilization estimate, and chip
power follows the classic linear utilization model (Fan et al., 2007 — the
paper's ref [16]):

    P_chip = P_idle + (P_peak − P_idle) · u,   u = t_compute / t_step

DR enforcement is throughput throttling (steps-per-hour budgets): cutting a
training job's power by δ% means running (δ/dynamic_range)% fewer steps —
which is exactly the "batch without SLO" penalty family of §IV. Serving jobs
degrade QoS per the Dynamo latency curves. 1 NP ≡ 1 MW.
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass(frozen=True)
class ChipPower:
    """TPU v5e-class chip power envelope (W)."""
    idle: float = 95.0
    peak: float = 250.0
    host_overhead: float = 40.0   # per-chip share of host/interconnect/fans


@dataclasses.dataclass(frozen=True)
class JobPowerModel:
    """Power/throughput model for one fleet job."""
    name: str
    chips: int
    t_compute_s: float
    t_step_s: float               # max of the three roofline terms
    chip: ChipPower = ChipPower()

    @property
    def utilization(self) -> float:
        return min(1.0, self.t_compute_s / max(self.t_step_s, 1e-12))

    @property
    def power_watts(self) -> float:
        c = self.chip
        return self.chips * (c.idle + c.host_overhead
                             + (c.peak - c.idle) * self.utilization)

    @property
    def power_np(self) -> float:
        """NP units (1 NP = 1 MW)."""
        return self.power_watts / 1e6

    @property
    def dynamic_fraction(self) -> float:
        """Share of power that throttling can shed (idle floor stays)."""
        c = self.chip
        dyn = (c.peak - c.idle) * self.utilization
        return dyn / (c.idle + c.host_overhead + dyn)

    def steps_per_hour(self, throttle: float = 1.0) -> float:
        return 3600.0 / max(self.t_step_s, 1e-12) * min(max(throttle, 0.0),
                                                        1.0)

    def throttle_for_power_cut(self, cut_frac: float) -> float:
        """Throughput multiplier that sheds `cut_frac` of total job power.
        Cuts beyond the dynamic range saturate at the idle floor."""
        dyn = self.dynamic_fraction
        if dyn <= 0:
            return 1.0
        return float(np.clip(1.0 - cut_frac / dyn, 0.0, 1.0))


def job_power_from_roofline(name: str, roofline: dict, chips: int,
                            chip: ChipPower = ChipPower()) -> JobPowerModel:
    """Build from a dry-run record's roofline dict (§Dry-run JSON)."""
    tc = float(roofline["t_compute_s"])
    ts = max(float(roofline[k]) for k in
             ("t_compute_s", "t_memory_s", "t_collective_s"))
    return JobPowerModel(name=name, chips=chips, t_compute_s=tc,
                         t_step_s=ts, chip=chip)
