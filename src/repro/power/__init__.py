from repro.power.model import (  # noqa: F401
    ChipPower, JobPowerModel, job_power_from_roofline,
)
