from repro.optim.adamw import (  # noqa: F401
    AdamWConfig, adamw_init, adamw_update, apply_updates,
    cosine_schedule, global_norm,
)
