"""AdamW with gradient clipping and cosine schedule (no external deps).

Moment dtype is configurable: fp32 for quality, bf16 to halve optimizer
memory on the biggest configs (recorded per-arch in the dry-run report).
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    moment_dtype: str = "float32"


def cosine_schedule(cfg: AdamWConfig, step: Array) -> Array:
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    prog = jnp.clip((step - cfg.warmup_steps)
                    / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
                    0.0, 1.0)
    return cfg.lr * warm * 0.5 * (1.0 + jnp.cos(jnp.pi * prog))


def global_norm(tree: Any) -> Array:
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def adamw_init(params: Any, cfg: AdamWConfig) -> dict:
    mdtype = {"float32": jnp.float32, "bfloat16": jnp.bfloat16}[
        cfg.moment_dtype]
    zeros = lambda p: jnp.zeros(p.shape, mdtype)
    return {"m": jax.tree.map(zeros, params),
            "v": jax.tree.map(zeros, params),
            "step": jnp.zeros((), jnp.int32)}


def adamw_update(grads: Any, state: dict, params: Any, cfg: AdamWConfig,
                 ) -> tuple[Any, dict]:
    step = state["step"] + 1
    lr = cosine_schedule(cfg, step.astype(jnp.float32))
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-12))

    def upd(g, m, v, p):
        gf = g.astype(jnp.float32) * scale
        m_new = cfg.b1 * m.astype(jnp.float32) + (1 - cfg.b1) * gf
        v_new = cfg.b2 * v.astype(jnp.float32) + (1 - cfg.b2) * gf * gf
        mhat = m_new / (1 - cfg.b1 ** step.astype(jnp.float32))
        vhat = v_new / (1 - cfg.b2 ** step.astype(jnp.float32))
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps)
        delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        return (-lr * delta).astype(p.dtype), m_new.astype(m.dtype), \
            v_new.astype(v.dtype)

    out = jax.tree.map(upd, grads, state["m"], state["v"], params)
    updates = jax.tree.map(lambda t: t[0], out,
                           is_leaf=lambda x: isinstance(x, tuple))
    m = jax.tree.map(lambda t: t[1], out,
                     is_leaf=lambda x: isinstance(x, tuple))
    v = jax.tree.map(lambda t: t[2], out,
                     is_leaf=lambda x: isinstance(x, tuple))
    return updates, {"m": m, "v": v, "step": step}


def apply_updates(params: Any, updates: Any) -> Any:
    return jax.tree.map(lambda p, u: p + u.astype(p.dtype), params, updates)
