#!/usr/bin/env bash
# CI entry, runnable from a fresh checkout:
#   pip install -r requirements.txt && scripts/ci.sh          # fast lane
#   scripts/ci.sh --full                                      # tier-1 suite
#
# The fast lane deselects @pytest.mark.slow (the long solver-convergence
# and end-to-end tests, ~8 min on CPU) and finishes in a couple of
# minutes. The tier-1 verify documented in ROADMAP.md is the --full lane:
#   PYTHONPATH=src python -m pytest -x -q
#
# Both lanes finish with the multi-device lane: the fleet-sharding parity
# tests run under 8 virtual CPU devices
# (XLA_FLAGS=--xla_force_host_platform_device_count=8), so every PR
# exercises the sharded == single-device contract. The main suite's
# pytest process must stay single-device (see tests/conftest.py), so the
# sharding file is split out into its own invocation.
set -euo pipefail
cd "$(dirname "$0")/.."

echo "== drlint (JAX invariants as an AST pass) =="
# Millisecond static pass, so it runs first and fails fast: host leaks in
# jit-reachable code, donation twins, check_rep justifications, tuple
# seeding, np-on-traced, deprecated shims, ad-hoc PartitionSpecs. Exits
# nonzero with path:line:col output on any unsuppressed violation.
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} \
  python -m repro.analysis.lint --fail-on-violation src/repro

lane=(-m "not slow")
if [[ "${1:-}" == "--full" ]]; then
  shift
  lane=()
fi
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} \
  python -m pytest -x -q ${lane[@]+"${lane[@]}"} \
  --ignore=tests/test_fleet_sharding.py "$@"

# Targeted runs (extra pytest args) skip the extra lanes so e.g.
# `scripts/ci.sh -k fleetcache` stays fast; both default lanes run them.
if [[ $# -eq 0 ]]; then
  echo "== deprecation lane (legacy shims warn exactly once) =="
  # Re-run the API tests with DeprecationWarning as error: every legacy
  # shim call in tests/test_api.py is wrapped in an explicit capture that
  # asserts exactly one warning, so any stray DeprecationWarning — a shim
  # warning twice, or the new solve()/sweep() surface emitting one —
  # fails this lane.
  PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} \
    python -m pytest -x -q tests/test_api.py -W error::DeprecationWarning

  echo "== sanitizer smoke (CR1 + CR2 under sanitize=True) =="
  # The checkify debug lane end-to-end on both twinned policies: bitwise
  # parity with the unchecked lane, and an injected NaN in the carbon
  # trace must raise SanitizeError instead of silently shipping a NaN
  # plan.
  PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python - <<'PY'
import dataclasses
import numpy as np
from repro.analysis import SanitizeError
from repro.core.api import CR1, CR2, SolveContext, solve
from repro.core.fleet_solver import synthetic_fleet

p = synthetic_fleet(8, seed=3)
mci = np.asarray(p.mci, float).copy(); mci[5] = np.nan
bad = dataclasses.replace(p, mci=mci)
for pol in (CR1(lam=1.45), CR2(cap_frac=0.8, outer=2)):
    plain = solve(p, pol, ctx=SolveContext(steps=80))
    guard = solve(p, pol, ctx=SolveContext(steps=80, sanitize=True))
    np.testing.assert_array_equal(plain.D, guard.D)
    try:
        solve(bad, pol, ctx=SolveContext(steps=80, sanitize=True))
    except SanitizeError:
        pass
    else:
        raise AssertionError(f"{pol.name}: NaN injection did not fire")
print("sanitizer smoke OK")
PY

  echo "== examples smoke (quickstart + 2 streaming ticks) =="
  PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} \
    python examples/quickstart.py > /dev/null
  PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} \
    python examples/streaming_dr.py --ticks 2 > /dev/null

  echo "== ensemble smoke (S=4 x W=16 x 2 policies + risk example) =="
  # The scenario-ensemble subsystem end-to-end: batched CR1 + CR2 over a
  # mixed MCI/fleet scenario stack, with the batched-vs-loop parity
  # contract asserted, plus the risk-report example.
  PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python - <<'PY'
import numpy as np
from repro.core.api import CR1, CR2, SolveContext
from repro.core.ensemble import evaluate_ensemble
from repro.core.fleet_solver import synthetic_fleet
from repro.core.scenario import DuckPerturb, FleetJitter, resolve_scenarios

p = synthetic_fleet(16)
stack = resolve_scenarios([DuckPerturb(n_scenarios=2, seed=0),
                           FleetJitter(n_scenarios=2, seed=1)], p)
ctx = SolveContext(steps=80)
for pol in (CR1(lam=1.45), CR2(cap_frac=0.8, outer=2)):
    got = evaluate_ensemble(p, pol, stack, ctx=ctx)
    ref = evaluate_ensemble(p, pol, stack, ctx=ctx, batched=False)
    assert got.batched and got.D.shape == (4, 16, 48)
    gap = np.abs(got.carbon_reduction_pct - ref.carbon_reduction_pct).max()
    assert gap < 0.01, f"{pol.name} ensemble parity gap {gap}"
    got.report().lines()
print("ensemble smoke OK")
PY
  PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} \
    python examples/scenario_risk.py --scenarios 4 --workloads 8 \
    --steps 120 > /dev/null

  echo "== al_step kernel smoke (interpret parity + scanned day) =="
  # The fused AL inner-step kernel against its jnp oracle at small W,T,
  # and a 4-tick run_scanned() day against the per-tick step() loop —
  # the one-dispatch-day contract on every PR.
  PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python - <<'PY'
import dataclasses
import numpy as np
import jax.numpy as jnp
from repro.core.carbon import ForecastStream
from repro.core.fleet_solver import _bounds, synthetic_fleet
from repro.core.streaming import RollingHorizonSolver
from repro.kernels.al_step.kernel import al_step_pallas
from repro.kernels.al_step.ops import pack_rows
from repro.kernels.al_step.ref import al_step_ref

# kernel vs oracle, hinge-free rows (see kernels/al_step/ref.py)
p = synthetic_fleet(8, hours=48, seed=0)
p = dataclasses.replace(
    p, is_batch=np.zeros(8, bool), betas=np.zeros((8, 3)),
    rts_coeffs=np.where(np.asarray(p.is_batch)[:, None],
                        [2e-4, 1.5e-3, 0.04], p.rts_coeffs))
lo, hi = (np.asarray(a, np.float32) for a in _bounds(p))
rng = np.random.default_rng(0)
x = np.clip(rng.normal(0, .3, lo.shape), lo, hi).astype(np.float32)
m = np.zeros_like(x); v = np.zeros_like(x)
rowp = jnp.concatenate([pack_rows(p.rts_coeffs, p.betas, p.k, p.x2_kind,
                                  p.is_batch),
                        jnp.zeros((8, 2), jnp.float32)], axis=1)
cvec = rng.normal(-.5, .2, (1, p.T)).astype(np.float32)
scal = np.array([[1.45, 10., 0., .02, 0., 0, 0, 0]], np.float32)
args = [jnp.asarray(a) for a in
        (x, m, v, p.usage, p.jobs, lo, hi, rowp, cvec, scal)]
out = al_step_pallas(*args, mode="cr1", k_steps=4, interpret=True)
ref = al_step_ref(*args, mode="cr1", k_steps=4)
err = max(float(jnp.abs(o - r).max()) for o, r in zip(out, ref))
assert err <= 1e-5, f"al_step kernel-vs-oracle err {err}"

# 4-tick scanned day == per-tick loop
p = synthetic_fleet(6, seed=0)
mk = lambda: ForecastStream.caiso(n_ticks=4, horizon=p.T, seed=3)
kw = dict(policy="cr1", cold_steps=120, warm_steps=30)
loop = RollingHorizonSolver(p, mk(), **kw).run(4)
scan = RollingHorizonSolver(p, mk(), **kw).run_scanned(4)
gap = abs(loop.realized_reduction_pct - scan.realized_reduction_pct)
assert gap < 0.01, f"scanned-day parity gap {gap}pp"
print(f"al_step smoke OK (kernel err {err:.1e}, day gap {gap:.1e}pp)")
PY

  echo "== bench-record sanity (write + parse BENCH_*.json) =="
  # The micro-bench must run end-to-end and its freshly written record
  # must parse through the report renderer.
  PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} \
    python -m benchmarks.run --only al_step > /dev/null
  # (grep without -q: it must read the stream to EOF, otherwise the
  # early exit closes the pipe mid-print and pipefail trips on the
  # renderer's BrokenPipeError.)
  PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} \
    python -m benchmarks.report --bench | grep al_step_fused_solve \
    > /dev/null

  echo "== multi-region smoke (R=2 x W=16, CR1 + CR2, migration on/off) =="
  # The (region x workload) engine end-to-end: per-region pricing under
  # both policy families, the zero-bandwidth topology staying credit-free,
  # and the migration post-stage leaving D untouched while crediting the
  # net saving.
  PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python - <<'PY'
import dataclasses
import numpy as np
from repro.core.api import CR1, CR2, SolveContext, solve
from repro.core.fleet_solver import RegionTopology, synthetic_regional_fleet

p = synthetic_regional_fleet(16, ["CA", "TX"], hours=48, seed=0,
                             utc_offsets="auto")
off = dataclasses.replace(
    p, topology=RegionTopology(cost=np.full((2, 2), 2.0),
                               bandwidth=np.zeros((2, 2))))
ctx = SolveContext(steps=120)
for pol in (CR1(lam=1.45), CR2(cap_frac=0.8, outer=2)):
    r_on = solve(p, pol, ctx=ctx)
    r_off = solve(off, pol, ctx=ctx)
    assert "migration" not in r_off.extras
    plan = r_on.extras["migration"]
    np.testing.assert_array_equal(r_on.D, r_off.D)
    assert plan.net_saved > 0.0
    assert r_on.carbon_reduction_pct > r_off.carbon_reduction_pct
print("multi-region smoke OK")
PY

  echo "== multi-region day-scan smoke (R=2 on 2 virtual devices) =="
  # The ISSUE-8 regional-reductions layer end-to-end on a tiny mesh: the
  # whole-day scan with per-region norms riding the shard_map matches the
  # unsharded per-tick loop, and one coupled-migration solve never loses
  # to the post-stage at equal total curtailment.
  XLA_FLAGS="${XLA_FLAGS:-} --xla_force_host_platform_device_count=2" \
    PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python - <<'PY'
import dataclasses
import numpy as np
from repro.core.api import CR1, SolveContext, solve
from repro.core.fleet_solver import synthetic_regional_fleet
from repro.core.scenario import ForecastRegime
from repro.core.streaming import RollingHorizonSolver
from repro.launch.mesh import make_fleet_mesh

pr = dataclasses.replace(
    synthetic_regional_fleet(8, ["CA", "TX"], hours=48, seed=0,
                             utc_offsets="auto"),
    topology=None)
mk = lambda: ForecastRegime(n_scenarios=1, seed=5,
                            sigma=(0.03, 0.03)).streams(pr, n_ticks=3)[0]
kw = dict(policy=CR1(lam=1.45), cold_steps=150, warm_steps=50)
loop = RollingHorizonSolver(pr, mk(), **kw).run(3)
mesh = make_fleet_mesh()
assert len(mesh.devices.ravel()) == 2
scan = RollingHorizonSolver(pr, mk(), **kw, mesh=mesh).run_scanned(3)
gap = abs(loop.realized_reduction_pct - scan.realized_reduction_pct)
assert gap < 0.01, f"multi-region scanned-day parity gap {gap}pp"

p = synthetic_regional_fleet(12, ["CA", "TX"], hours=48, seed=0,
                             utc_offsets="auto")
post = solve(p, CR1(lam=1.45), ctx=SolveContext(steps=150))
coup = solve(p, CR1(lam=1.45),
             ctx=SolveContext(steps=150, coupled_migration=True))
assert coup.carbon_reduction_pct >= post.carbon_reduction_pct
tot_p, tot_c = (float(np.asarray(r.D).sum()) for r in (post, coup))
assert abs(tot_c - tot_p) <= 2e-3 * max(abs(tot_p), 1.0)
print(f"multi-region day-scan smoke OK (gap {gap:.1e}pp, coupled "
      f"{coup.carbon_reduction_pct:.2f}% vs post "
      f"{post.carbon_reduction_pct:.2f}%)")
PY

  echo "== observability smoke (telemetry ledger -> report) =="
  # PR 10's contract end-to-end: a telemetry-enabled 4-tick streaming
  # day (one scanned dispatch) writes the JSONL ledger via
  # examples/streaming_dr.py --telemetry, and the report CLI parses and
  # renders it with exit 0. drlint already ran above with the
  # host-sync-in-jit rule, so the instrumented tree is lint-clean.
  obs_ledger="$(mktemp -t obs_smoke.XXXXXX.jsonl)"
  rm -f "$obs_ledger"   # EventWriter writes the header on empty files
  PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} \
    python examples/streaming_dr.py --ticks 4 --cold-steps 120 \
    --warm-steps 30 --scan --telemetry "$obs_ledger" > /dev/null
  PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} \
    python -m repro.obs.report "$obs_ledger" | grep "tick ledger" \
    > /dev/null
  rm -f "$obs_ledger"
  echo "observability smoke OK"

  echo "== multi-device lane (8 virtual CPU devices) =="
  XLA_FLAGS="${XLA_FLAGS:-} --xla_force_host_platform_device_count=8" \
    PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} \
    python -m pytest -x -q tests/test_fleet_sharding.py
fi
