#!/usr/bin/env bash
# CI entry, runnable from a fresh checkout:
#   pip install -r requirements.txt && scripts/ci.sh          # fast lane
#   scripts/ci.sh --full                                      # tier-1 suite
#
# The fast lane deselects @pytest.mark.slow (the long solver-convergence
# and end-to-end tests, ~8 min on CPU) and finishes in a couple of
# minutes. The tier-1 verify documented in ROADMAP.md is the --full lane:
#   PYTHONPATH=src python -m pytest -x -q
set -euo pipefail
cd "$(dirname "$0")/.."
lane=(-m "not slow")
if [[ "${1:-}" == "--full" ]]; then
  shift
  lane=()
fi
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} \
  python -m pytest -x -q ${lane[@]+"${lane[@]}"} "$@"
