#!/usr/bin/env bash
# Tier-1 verify (ROADMAP.md), runnable from a fresh checkout:
#   pip install -r requirements.txt && scripts/ci.sh
set -euo pipefail
cd "$(dirname "$0")/.."
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python -m pytest -x -q "$@"
