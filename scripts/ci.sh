#!/usr/bin/env bash
# CI entry, runnable from a fresh checkout:
#   pip install -r requirements.txt && scripts/ci.sh          # fast lane
#   scripts/ci.sh --full                                      # tier-1 suite
#
# The fast lane deselects @pytest.mark.slow (the long solver-convergence
# and end-to-end tests, ~8 min on CPU) and finishes in a couple of
# minutes. The tier-1 verify documented in ROADMAP.md is the --full lane:
#   PYTHONPATH=src python -m pytest -x -q
#
# Both lanes finish with the multi-device lane: the fleet-sharding parity
# tests run under 8 virtual CPU devices
# (XLA_FLAGS=--xla_force_host_platform_device_count=8), so every PR
# exercises the sharded == single-device contract. The main suite's
# pytest process must stay single-device (see tests/conftest.py), so the
# sharding file is split out into its own invocation.
set -euo pipefail
cd "$(dirname "$0")/.."
lane=(-m "not slow")
if [[ "${1:-}" == "--full" ]]; then
  shift
  lane=()
fi
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} \
  python -m pytest -x -q ${lane[@]+"${lane[@]}"} \
  --ignore=tests/test_fleet_sharding.py "$@"

# Targeted runs (extra pytest args) skip the extra lanes so e.g.
# `scripts/ci.sh -k fleetcache` stays fast; both default lanes run them.
if [[ $# -eq 0 ]]; then
  echo "== deprecation lane (legacy shims warn exactly once) =="
  # Re-run the API tests with DeprecationWarning as error: every legacy
  # shim call in tests/test_api.py is wrapped in an explicit capture that
  # asserts exactly one warning, so any stray DeprecationWarning — a shim
  # warning twice, or the new solve()/sweep() surface emitting one —
  # fails this lane.
  PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} \
    python -m pytest -x -q tests/test_api.py -W error::DeprecationWarning

  echo "== examples smoke (quickstart + 2 streaming ticks) =="
  PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} \
    python examples/quickstart.py > /dev/null
  PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} \
    python examples/streaming_dr.py --ticks 2 > /dev/null

  echo "== ensemble smoke (S=4 x W=16 x 2 policies + risk example) =="
  # The scenario-ensemble subsystem end-to-end: batched CR1 + CR2 over a
  # mixed MCI/fleet scenario stack, with the batched-vs-loop parity
  # contract asserted, plus the risk-report example.
  PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python - <<'PY'
import numpy as np
from repro.core.api import CR1, CR2, SolveContext
from repro.core.ensemble import evaluate_ensemble
from repro.core.fleet_solver import synthetic_fleet
from repro.core.scenario import DuckPerturb, FleetJitter, resolve_scenarios

p = synthetic_fleet(16)
stack = resolve_scenarios([DuckPerturb(n_scenarios=2, seed=0),
                           FleetJitter(n_scenarios=2, seed=1)], p)
ctx = SolveContext(steps=80)
for pol in (CR1(lam=1.45), CR2(cap_frac=0.8, outer=2)):
    got = evaluate_ensemble(p, pol, stack, ctx=ctx)
    ref = evaluate_ensemble(p, pol, stack, ctx=ctx, batched=False)
    assert got.batched and got.D.shape == (4, 16, 48)
    gap = np.abs(got.carbon_reduction_pct - ref.carbon_reduction_pct).max()
    assert gap < 0.01, f"{pol.name} ensemble parity gap {gap}"
    got.report().lines()
print("ensemble smoke OK")
PY
  PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} \
    python examples/scenario_risk.py --scenarios 4 --workloads 8 \
    --steps 120 > /dev/null

  echo "== multi-device lane (8 virtual CPU devices) =="
  XLA_FLAGS="${XLA_FLAGS:-} --xla_force_host_platform_device_count=8" \
    PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} \
    python -m pytest -x -q tests/test_fleet_sharding.py
fi
